//! Offline stub of the `xla` crate surface `rfast::runtime` compiles
//! against (DESIGN.md §6).
//!
//! The real crate links the PJRT CPU client and is only present in
//! registry-backed environments. This stub keeps the whole workspace
//! buildable everywhere: every entry point fails fast at **runtime** with
//! [`Error::STUB`], so `repro check-artifacts` / `--oracle pjrt` report
//! "PJRT unavailable" instead of the build breaking. Swap the path
//! dependency in `rust/Cargo.toml` for the real `xla` crate to light up
//! the PJRT path; no call sites change.

use std::path::Path;

/// Stub error; carries the reason the operation cannot run.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl Error {
    /// The message every stub entry point returns.
    pub const STUB: &'static str =
        "xla stub: PJRT runtime not available in this build (swap \
         rust/vendor/xla for the real `xla` crate — DESIGN.md §6)";

    fn stub() -> Error {
        Error(Error::STUB.to_string())
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// PJRT client handle. [`PjRtClient::cpu`] always fails in the stub, so
/// the remaining methods are unreachable but keep call sites compiling.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error::stub())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation)
                   -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::stub())
    }
}

/// Parsed HLO module (text form).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P)
                                          -> Result<HloModuleProto, Error> {
        Err(Error::stub())
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[Literal])
                      -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::stub())
    }
}

/// Device-resident result buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::stub())
    }
}

/// Host literal (flat tensor value).
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(Error::stub())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        Err(Error::stub())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(Error::stub())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_fails_fast_with_stub_message() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("xla stub"), "{err}");
    }

    #[test]
    fn literal_construction_is_total() {
        // construction paths must not panic — engines build literals
        // before executing, and the failure must surface as Err, not panic
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert!(l.reshape(&[2]).is_err());
        assert!(l.to_vec::<f32>().is_err());
    }
}
