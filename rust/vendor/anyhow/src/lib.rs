//! Minimal, API-compatible shim of the `anyhow` crate (DESIGN.md §6).
//!
//! The offline build environment carries no registry, so the small slice
//! of `anyhow` the runtime layer uses — [`Error`], [`Result`], the
//! [`anyhow!`] macro and [`Context`] — is reimplemented here as a
//! string-backed error. Swapping this path dependency for the real crate
//! is a one-line change in `rust/Cargo.toml`; no call site changes.

use std::fmt;

/// String-backed error value (the shim keeps no cause chain).
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything printable — the target of [`anyhow!`].
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

/// `anyhow::Result<T>` — `std::result::Result` with [`Error`] defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error, mirroring `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

/// `anyhow!` — build an [`Error`] from a format string or any printable.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn macro_forms() {
        let plain = anyhow!("plain");
        assert_eq!(plain.to_string(), "plain");
        let owned = anyhow!(String::from("owned"));
        assert_eq!(owned.to_string(), "owned");
        let n = 3;
        let fmt = anyhow!("n = {n} and {}", 4);
        assert_eq!(fmt.to_string(), "n = 3 and 4");
    }

    #[test]
    fn context_prefixes() {
        let r: Result<()> = Err(Error::msg("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
        let r2: std::result::Result<(), String> = Err("deep".into());
        let e2 = r2.with_context(|| format!("lvl{}", 1)).unwrap_err();
        assert_eq!(e2.to_string(), "lvl1: deep");
    }

    #[test]
    fn question_mark_works() {
        fn inner() -> Result<u32> {
            Err(anyhow!("boom"))
        }
        fn outer() -> Result<u32> {
            let v = inner()?;
            Ok(v)
        }
        assert!(outer().is_err());
    }
}
