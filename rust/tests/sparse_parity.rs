//! Bitwise sparse-vs-dense equivalence suite (DESIGN.md §13): every
//! topology the repo can construct must come out of the sparse edge-list
//! funnel (`Topology::from_edges` / `SparseWeights`) with *exactly* the
//! same weights the dense densify-and-normalize reference
//! (`Topology::from_edges_dense`) produces — same f32 bits, same
//! neighbor lists, same `check_assumptions` verdicts (including
//! no-common-root rejections), and, end to end, byte-identical report
//! JSON from a seeded simulator run.
//!
//! Why bitwise equality is even possible: builder weights are uniform
//! 1/k with k unit entries per line, dense row sums of k ones are exact
//! integers in f64, and `(1.0 / k as f64) as f32` is precisely the scale
//! the dense normalize applies — see the `SparseWeights` module docs for
//! the full argument.

use rfast::algo::AlgoKind;
use rfast::config::SimConfig;
use rfast::exp::{Experiment, QuadSpec, Stop, Workload};
use rfast::graph::{ArchSpec, AssumptionError, Topology, TopologyKind};
use rfast::prng::Rng;

/// Every parameterless builder kind (Custom has no `build`).
const KINDS: [TopologyKind; 7] = [
    TopologyKind::BinaryTree,
    TopologyKind::Line,
    TopologyKind::Ring,
    TopologyKind::Exponential,
    TopologyKind::Mesh,
    TopologyKind::Star,
    TopologyKind::Gossip,
];

/// Re-derive the directed edge lists a topology was built from, straight
/// off its neighbor lists: W edge (j, i) ⇔ i pulls from j (j ∈ w_in[i]),
/// A edge (i, j) ⇔ i pushes to j (j ∈ a_out[i]).
fn edge_lists(t: &Topology) -> (Vec<(usize, usize)>, Vec<(usize, usize)>) {
    let wm = &t.weights;
    let w = (0..wm.n)
        .flat_map(|i| wm.w_in[i].iter().map(move |&j| (j, i)))
        .collect();
    let a = (0..wm.n)
        .flat_map(|i| wm.a_out[i].iter().map(move |&j| (i, j)))
        .collect();
    (w, a)
}

/// The core assertion: the dense reference twin built from the same edge
/// set is bitwise equal (weights, via `SparseWeights: PartialEq`, and
/// the full assumption report).
fn assert_dense_twin_parity(sparse: &Topology, ctx: &str) {
    let (w_edges, a_edges) = edge_lists(sparse);
    let dense = Topology::from_edges_dense(sparse.n(), &w_edges, &a_edges);
    assert_eq!(sparse.weights, dense.weights, "{ctx}: weights diverge");
    assert_eq!(sparse.weights.check_assumptions(),
               dense.weights.check_assumptions(),
               "{ctx}: assumption verdicts diverge");
    assert_eq!(sparse.weights.common_roots(), dense.weights.common_roots(),
               "{ctx}: root sets diverge");
}

#[test]
fn every_builder_kind_matches_the_dense_reference_bitwise() {
    for kind in KINDS {
        for n in [2usize, 3, 4, 5, 7, 8, 12, 16, 23, 32, 48, 64] {
            let topo = kind.build(n);
            assert_dense_twin_parity(&topo, &format!("{}({n})", kind.name()));
        }
    }
}

#[test]
fn metropolis_ring_matches_dense_normalization_bitwise() {
    // not a from_edges builder — its 1/3 weights come from
    // from_weighted_lists — but on a ring the dense normalize of the
    // unit adjacency produces the identical 1/3 bits
    for n in [3usize, 5, 16, 64] {
        let topo = Topology::undirected_ring_metropolis(n);
        assert_dense_twin_parity(&topo, &format!("metropolis({n})"));
    }
}

#[test]
fn paper_architecture_pairs_match_the_dense_reference_bitwise() {
    for spec in ArchSpec::paper_pairs() {
        for n in [2usize, 3, 5, 9, 17, 33, 64] {
            let topo = spec.build(n).unwrap();
            assert_dense_twin_parity(&topo,
                                     &format!("{}({n})", spec.name()));
        }
    }
}

#[test]
fn fifty_sampled_architecture_pairs_match_the_dense_reference() {
    let mut rng = Rng::stream(0x59a25e, 0);
    for case in 0..50u64 {
        let mut draw = Rng::stream(77, case);
        let spec = ArchSpec::sample(&mut draw);
        let n = 2 + rng.below(63);
        let topo = spec.build(n).unwrap();
        assert_dense_twin_parity(
            &topo, &format!("sample[{case}] {}({n})", spec.name()));
    }
}

#[test]
fn no_common_root_pairs_are_rejected_identically() {
    // the root-mismatched pair builds fine on both paths and fails
    // Assumption 2 with the same typed violation list
    for n in [2usize, 6, 17, 64] {
        let topo = ArchSpec::no_common_root_pair().build(n).unwrap();
        assert_dense_twin_parity(&topo, &format!("no_common_root({n})"));
        let errs = topo.weights.check_assumptions();
        assert!(errs.contains(&AssumptionError::NoCommonRoot),
                "n = {n}: {errs:?}");
        assert!(topo.weights.common_roots().is_empty(), "n = {n}");
    }
    // hand-built edge lists, both construction paths
    let w = [(0usize, 1usize), (0, 2)];
    let a = [(0usize, 1usize), (2, 1)];
    let s = Topology::from_edges(3, &w, &a);
    let d = Topology::from_edges_dense(3, &w, &a);
    assert_eq!(s.weights, d.weights);
    let errs = s.weights.check_assumptions();
    assert_eq!(errs, d.weights.check_assumptions());
    assert!(errs.contains(&AssumptionError::NoCommonRoot), "{errs:?}");
}

// ---- end to end: the report bytes, not just the matrices ---------------

fn quad() -> Workload {
    Workload::Quadratic(QuadSpec::heterogeneous(8, 0.5, 2.0))
}

fn fast_cfg(seed: u64) -> SimConfig {
    SimConfig {
        seed,
        gamma: 0.03,
        compute_mean: 0.01,
        link_latency: 0.002,
        latency_cap: 0.05,
        eval_every: 1.0,
        ..SimConfig::default()
    }
}

fn report_bytes(topo: &Topology, seed: u64) -> String {
    Experiment::new(quad(), AlgoKind::RFast)
        .topology(topo)
        .config(fast_cfg(seed))
        .stop(Stop::Iterations(2_000))
        .run()
        .unwrap()
        .report
        .to_json()
        .to_string()
}

#[test]
fn seeded_runs_emit_byte_identical_reports_on_both_construction_paths() {
    let cases: Vec<(Topology, &str)> = vec![
        (Topology::ring(8), "ring(8)"),
        (Topology::gossip(12, 2, 3), "gossip(12)"),
        (ArchSpec::paper_pairs()[0].build(16).unwrap(), "paper_pair(16)"),
    ];
    for (sparse, ctx) in cases {
        let (w_edges, a_edges) = edge_lists(&sparse);
        let dense = Topology::from_edges_dense(sparse.n(), &w_edges, &a_edges);
        assert_eq!(report_bytes(&sparse, 5), report_bytes(&dense, 5),
                   "{ctx}: report JSON diverges between construction paths");
    }
}
