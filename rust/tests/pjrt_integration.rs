//! PJRT ↔ pure-rust oracle cross-checks — the correctness bridge between
//! the AOT artifacts (L2/L1 lowered through XLA) and the rust twins used
//! by the fast benches. Skipped gracefully when `make artifacts` hasn't
//! run.

use rfast::data::Dataset;
use rfast::linalg;
use rfast::oracle::{eval_logreg, logreg_loss_grad, mlp_loss_grad_once};
use rfast::runtime::{default_artifact_dir, Engine, Input, Manifest, Output};

fn manifest() -> Option<Manifest> {
    let dir = default_artifact_dir()?;
    Manifest::load(&dir).ok()
}

fn run_f32(engine: &Engine, name: &str, inputs: &[Input<'_>]) -> Vec<Output> {
    engine.run(name, inputs).expect("pjrt execution")
}

#[test]
fn logreg_grad_artifact_matches_rust_oracle() {
    let Some(m) = manifest() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let engine = Engine::load(&m, &["logreg_grad"]).unwrap();
    let info = engine.artifact_info("logreg_grad").unwrap().clone();
    let b = info.inputs[1].shape[0];
    let d = info.inputs[1].shape[1];

    let data = Dataset::mnist01_like(3);
    let theta = m.load_init("logreg").unwrap();
    let idx: Vec<usize> = (0..b).map(|k| k * 7 % data.len()).collect();
    let mut x = Vec::with_capacity(b * d);
    let mut y = Vec::with_capacity(b);
    for &s in &idx {
        x.extend_from_slice(data.row(s));
        y.push(data.labels[s] as f32);
    }
    let out = run_f32(&engine, "logreg_grad",
                      &[Input::F32(&theta), Input::F32(&x), Input::F32(&y)]);
    let loss_pjrt = out[0].scalar_f32().unwrap();
    let grad_pjrt = match &out[1] {
        Output::F32(v) => v.clone(),
        _ => panic!("grad dtype"),
    };

    let mut grad_rust = vec![0.0f32; d + 1];
    let loss_rust =
        logreg_loss_grad(&data, &idx, &theta, 1e-4, &mut grad_rust);

    assert!(
        (loss_pjrt - loss_rust).abs() < 1e-4 * (1.0 + loss_rust.abs()),
        "loss: pjrt {loss_pjrt} vs rust {loss_rust}"
    );
    rfast::testutil::assert_close(&grad_pjrt, &grad_rust, 1e-3)
        .unwrap_or_else(|e| panic!("grad mismatch: {e}"));
}

#[test]
fn logreg_eval_artifact_matches_rust_eval() {
    let Some(m) = manifest() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let engine = Engine::load(&m, &["logreg_eval"]).unwrap();
    let info = engine.artifact_info("logreg_eval").unwrap().clone();
    let b = info.inputs[1].shape[0];
    let data = Dataset::mnist01_like(3);
    let theta = m.load_init("logreg").unwrap();
    let mut x = Vec::new();
    let mut y = Vec::new();
    for s in 0..b {
        x.extend_from_slice(data.row(s));
        y.push(data.labels[s] as f32);
    }
    let out = run_f32(&engine, "logreg_eval",
                      &[Input::F32(&theta), Input::F32(&x), Input::F32(&y)]);
    let correct_pjrt = out[1].scalar_i32().unwrap();

    let sub = Dataset {
        dim: data.dim,
        features: x.clone(),
        labels: (0..b).map(|s| data.labels[s]).collect(),
        classes: 2,
    };
    let e = eval_logreg(&sub, &theta, 1e-4);
    let correct_rust = (e.accuracy.unwrap() * b as f64).round() as i32;
    assert_eq!(correct_pjrt, correct_rust);
    assert!((out[0].scalar_f32().unwrap() as f64 - e.loss).abs() < 1e-4);
}

#[test]
fn mlp_grad_artifact_matches_rust_oracle() {
    let Some(m) = manifest() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let engine = Engine::load(&m, &["mlp_grad"]).unwrap();
    let info = engine.artifact_info("mlp_grad").unwrap().clone();
    let b = info.inputs[1].shape[0];
    let d = info.inputs[1].shape[1];
    let p = info.inputs[0].shape[0];

    let data = Dataset::imagenet_like(2_000, 5);
    let theta = m.load_init("mlp").unwrap();
    assert_eq!(theta.len(), p);
    let idx: Vec<usize> = (0..b).map(|k| k * 13 % data.len()).collect();
    let mut x = Vec::with_capacity(b * d);
    let mut labels = Vec::with_capacity(b);
    for &s in &idx {
        x.extend_from_slice(data.row(s));
        labels.push(data.labels[s] as i32);
    }
    let out = run_f32(&engine, "mlp_grad",
                      &[Input::F32(&theta), Input::F32(&x), Input::I32(&labels)]);
    let loss_pjrt = out[0].scalar_f32().unwrap();
    let grad_pjrt = match &out[1] {
        Output::F32(v) => v.clone(),
        _ => panic!("grad dtype"),
    };

    let (loss_rust, grad_rust) = mlp_loss_grad_once(&data, &idx, &theta);
    assert!(
        (loss_pjrt - loss_rust).abs() < 1e-3 * (1.0 + loss_rust.abs()),
        "loss: pjrt {loss_pjrt} vs rust {loss_rust}"
    );
    // ReLU kinks + summation order ⇒ slightly looser tolerance
    rfast::testutil::assert_close(&grad_pjrt, &grad_rust, 5e-3)
        .unwrap_or_else(|e| panic!("grad mismatch: {e}"));
}

#[test]
fn transformer_tiny_artifact_sane() {
    let Some(m) = manifest() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let names = ["transformer_tiny_grad", "transformer_tiny_eval"];
    let engine = Engine::load(&m, &names).unwrap();
    let ginfo = engine.artifact_info(names[0]).unwrap().clone();
    let p = ginfo.inputs[0].shape[0];
    let toks_n = ginfo.inputs[1].numel();
    let vocab = 512;

    let theta = m.load_init("transformer_tiny").unwrap();
    assert_eq!(theta.len(), p);
    let tokens: Vec<i32> = (0..toks_n).map(|k| (k * 31 % vocab) as i32).collect();

    let out = run_f32(&engine, names[0],
                      &[Input::F32(&theta), Input::I32(&tokens)]);
    let loss = out[0].scalar_f32().unwrap();
    let grad = match &out[1] {
        Output::F32(v) => v.clone(),
        _ => panic!(),
    };
    // at random init, next-token xent ≈ ln(vocab)
    let uniform = (vocab as f32).ln();
    assert!(
        (loss - uniform).abs() < 1.5,
        "init loss {loss} vs ln(V) {uniform}"
    );
    let gnorm = linalg::norm(&grad);
    assert!(gnorm.is_finite() && gnorm > 1e-3, "grad norm {gnorm}");

    // eval artifact agrees with grad artifact's loss on the same tokens
    let out_eval = run_f32(&engine, names[1],
                           &[Input::F32(&theta), Input::I32(&tokens)]);
    let loss_eval = out_eval[0].scalar_f32().unwrap();
    assert!(
        (loss - loss_eval).abs() < 1e-3,
        "grad-loss {loss} vs eval-loss {loss_eval}"
    );

    // one SGD step must reduce the loss on the SAME batch
    let mut theta2 = theta.clone();
    linalg::axpy(&mut theta2, -0.5, &grad);
    let out2 = run_f32(&engine, names[0],
                       &[Input::F32(&theta2), Input::I32(&tokens)]);
    let loss2 = out2[0].scalar_f32().unwrap();
    assert!(loss2 < loss, "sgd step: {loss} → {loss2}");
}

#[test]
fn pjrt_simulator_trains_logreg() {
    use rfast::algo::AlgoKind;
    use rfast::config::SimConfig;
    use rfast::data::Partition;
    use rfast::graph::Topology;
    use rfast::runtime::{build_pjrt_set, PjrtTask};
    use rfast::exp::Stop;
    use rfast::sim::Simulator;
    use std::sync::Arc;

    let Some(m) = manifest() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (train, eval) = Dataset::mnist01_like(7).split_eval(2000);
    let task = PjrtTask::LogReg {
        data: Arc::new(train.clone()),
        eval: Arc::new(eval),
        partition: Partition::iid(&train, 4, 7),
    };
    let set = build_pjrt_set(&m, &task, 4, 7).unwrap();
    let x0 = m.load_init("logreg").unwrap();
    let mut cfg = SimConfig::logreg_paper();
    cfg.seed = 7;
    cfg.eval_every = 2.0;
    let topo = Topology::binary_tree(4);
    let mut sim = Simulator::with_x0(cfg, &topo, AlgoKind::RFast, set, &x0);
    let report = sim.run(Stop::Time(20.0));
    let acc = report.series["acc_vs_time"].last_y().unwrap();
    assert!(acc > 0.95, "accuracy {acc}");
}
