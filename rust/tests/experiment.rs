//! The `exp::Experiment` surface: builder-misuse errors are typed (never
//! a panic or a bare string), the unified `Stop` vocabulary converts from
//! both legacy enums, and — the dashboard contract — the same run driven
//! through BOTH engines exposes the same scalar key set, so downstream
//! tooling never branches on the engine.
//!
//! The parity test spins real threads; CI runs this file in the
//! single-threaded wall-clock step alongside the runner suites.

use rfast::algo::AlgoKind;
use rfast::config::SimConfig;
use rfast::exp::{Engine, ExpError, Experiment, QuadSpec, Stop, Workload};
use rfast::graph::Topology;
use rfast::scenario::Scenario;

fn quad() -> Workload {
    Workload::Quadratic(QuadSpec::heterogeneous(6, 0.5, 2.0))
}

fn fast_cfg(seed: u64) -> SimConfig {
    SimConfig {
        seed,
        gamma: 0.03,
        compute_mean: 0.001,
        eval_every: 0.05,
        ..SimConfig::default()
    }
}

// ---- builder misuse is typed -------------------------------------------

#[test]
fn missing_topology_is_a_typed_error() {
    let err = Experiment::new(quad(), AlgoKind::RFast)
        .stop(Stop::Iterations(10))
        .run()
        .unwrap_err();
    assert_eq!(err, ExpError::MissingTopology);
    // and the message is self-explanatory
    assert!(err.to_string().contains("topology"), "{err}");
}

#[test]
fn missing_stop_is_a_typed_error() {
    let err = Experiment::new(quad(), AlgoKind::RFast)
        .topology(&Topology::ring(3))
        .run()
        .unwrap_err();
    assert_eq!(err, ExpError::MissingStop);
}

#[test]
fn epochs_without_an_epoch_mapping_is_a_typed_error() {
    // quadratics count steps, not passes over a dataset — Stop::Epochs
    // must be rejected up front on EITHER engine
    for engine in [Engine::Sim, Engine::threaded(Some(1e-4))] {
        let err = Experiment::new(quad(), AlgoKind::RFast)
            .topology(&Topology::ring(3))
            .config(fast_cfg(1))
            .engine(engine)
            .stop(Stop::Epochs(2.0))
            .run()
            .unwrap_err();
        match err {
            ExpError::NoEpochMapping { workload } => {
                assert_eq!(workload, "quadratic");
            }
            other => panic!("expected NoEpochMapping, got {other:?}"),
        }
    }
    // the same stop rule is fine on a dataset workload (sim side —
    // threaded epoch support is covered in runner_integration)
    let run = Experiment::new(Workload::LogReg, AlgoKind::RFast)
        .topology(&Topology::ring(3))
        .seed(1)
        .stop(Stop::Epochs(0.1))
        .run()
        .unwrap();
    assert!(run.report.scalars["epoch"] >= 0.1);
}

#[test]
fn mlp_on_threaded_surfaces_the_pjrt_hint() {
    let err = Experiment::new(Workload::Mlp, AlgoKind::RFast)
        .topology(&Topology::ring(3))
        .engine(Engine::threaded(None))
        .stop(Stop::Time(0.1))
        .run()
        .unwrap_err();
    match &err {
        ExpError::UnsupportedWorkload { workload, engine, hint } => {
            assert_eq!(*workload, "mlp");
            assert_eq!(*engine, "threaded");
            assert!(hint.contains("PJRT"), "{hint}");
            assert!(hint.contains("e2e_transformer"), "{hint}");
        }
        other => panic!("expected UnsupportedWorkload, got {other:?}"),
    }
    // the Display impl carries the hint through to string contexts
    assert!(err.to_string().contains("PJRT"), "{err}");
}

#[test]
fn scenario_validation_names_the_failing_field() {
    // straggler factor < 1 → stragglers[0].factor
    let mut sc = Scenario::named("bad_factor", "");
    sc.stragglers.push(rfast::scenario::StragglerSpec {
        node: 0,
        factor: 0.5,
        schedule: rfast::scenario::StragglerSchedule::Permanent,
    });
    let err = Experiment::new(quad(), AlgoKind::RFast)
        .topology(&Topology::ring(3))
        .config(fast_cfg(1))
        .scenario(&sc)
        .stop(Stop::Iterations(10))
        .run()
        .unwrap_err();
    match &err {
        ExpError::InvalidScenario { scenario, field, detail } => {
            assert_eq!(scenario, "bad_factor");
            assert_eq!(field, "stragglers[0].factor");
            assert!(detail.contains("≥ 1"), "{detail}");
        }
        other => panic!("expected InvalidScenario, got {other:?}"),
    }

    // node index beyond the topology → churn[1].node (the second entry)
    let mut sc = Scenario::named("bad_node", "");
    sc.churn.push(rfast::scenario::ChurnEvent {
        node: 0, pause_at: 0.0, resume_at: 1.0,
    });
    sc.churn.push(rfast::scenario::ChurnEvent {
        node: 9, pause_at: 0.0, resume_at: 1.0,
    });
    let err = Experiment::new(quad(), AlgoKind::RFast)
        .topology(&Topology::ring(3))
        .config(fast_cfg(1))
        .scenario(&sc)
        .stop(Stop::Iterations(10))
        .run()
        .unwrap_err();
    match &err {
        ExpError::InvalidScenario { field, detail, .. } => {
            assert_eq!(field, "churn[1].node");
            assert!(detail.contains("out of range"), "{detail}");
        }
        other => panic!("expected InvalidScenario, got {other:?}"),
    }
}

#[test]
fn invalid_config_is_a_typed_error() {
    let mut cfg = fast_cfg(1);
    cfg.gamma = -1.0;
    let err = Experiment::new(quad(), AlgoKind::RFast)
        .topology(&Topology::ring(3))
        .config(cfg)
        .stop(Stop::Iterations(10))
        .run()
        .unwrap_err();
    assert!(matches!(err, ExpError::InvalidConfig(_)), "{err:?}");
}

#[test]
fn seed_and_gamma_shortcuts_are_chain_order_independent() {
    // .seed()/.gamma() are overrides applied at run() time: chaining
    // .config() after them must NOT silently discard them
    let cfg = fast_cfg(1); // seed 1, gamma 0.03
    let before = Experiment::new(quad(), AlgoKind::RFast)
        .seed(7)
        .gamma(0.02)
        .config(cfg.clone())
        .topology(&Topology::ring(3))
        .stop(Stop::Iterations(500))
        .run()
        .unwrap();
    let after = Experiment::new(quad(), AlgoKind::RFast)
        .config(cfg)
        .seed(7)
        .gamma(0.02)
        .topology(&Topology::ring(3))
        .stop(Stop::Iterations(500))
        .run()
        .unwrap();
    // identical seed ⇒ identical deterministic sim trajectory
    assert_eq!(before.report.to_json().to_string(),
               after.report.to_json().to_string());
}

#[test]
fn engine_sweep_preflights_every_leg_before_running_any() {
    // MLP cannot run threaded: the sweep pre-flights all legs and must
    // return the typed error instead of running the sim leg first and
    // erroring halfway through
    let err = Experiment::new(Workload::Mlp, AlgoKind::RFast)
        .topology(&Topology::ring(3))
        .stop(Stop::Iterations(1))
        .sweep_engines(&[Engine::Sim, Engine::threaded(None)])
        .unwrap_err();
    assert!(matches!(err, ExpError::UnsupportedWorkload { .. }), "{err:?}");
}

// ---- legacy stop enums convert losslessly ------------------------------

#[test]
#[allow(deprecated)]
fn legacy_stop_enums_convert() {
    use rfast::runner::RunUntil;
    use rfast::sim::StopRule;
    assert_eq!(Stop::from(StopRule::VirtualTime(5.0)), Stop::Time(5.0));
    assert_eq!(Stop::from(StopRule::Iterations(7)), Stop::Iterations(7));
    assert_eq!(Stop::from(StopRule::Epochs(2.0)), Stop::Epochs(2.0));
    assert_eq!(
        Stop::from(StopRule::TargetLoss { loss: 0.1, max_time: 9.0 }),
        Stop::TargetLoss { loss: 0.1, max_time: 9.0 }
    );
    assert_eq!(Stop::from(RunUntil::WallSeconds(3.0)), Stop::Time(3.0));
    assert_eq!(Stop::from(RunUntil::TotalSteps(11)), Stop::Iterations(11));
    assert_eq!(
        Stop::from(RunUntil::TargetLoss { loss: 0.2, max_seconds: 4.0 }),
        Stop::TargetLoss { loss: 0.2, max_time: 4.0 }
    );
}

// ---- engine parity audit (the dashboard contract) ----------------------

/// The scalar keys every dashboard may rely on without branching on the
/// engine. Both engines must expose ALL of them.
const UNIFIED_SCALARS: [&str; 5] = [
    "msgs_lost",
    "bytes_sent",
    "msgs_backpressured",
    "msgs_paced",
    "epoch",
];

#[test]
fn both_engines_expose_the_same_unified_scalar_keys() {
    // same lossy_30pct logreg run through both engines via the new API
    let sc = Scenario::by_name("lossy_30pct").unwrap();
    let base = Experiment::new(Workload::LogReg, AlgoKind::RFast)
        .topology(&Topology::ring(3))
        .config(SimConfig {
            eval_every: 0.05,
            ..SimConfig::logreg_paper()
        })
        .scenario(&sc);
    let sim_run = base
        .clone()
        .engine(Engine::Sim)
        .stop(Stop::Time(2.0))
        .run()
        .unwrap();
    let thr_run = base
        .engine(Engine::threaded(Some(5e-4)))
        .stop(Stop::Time(0.3))
        .run()
        .unwrap();
    for key in UNIFIED_SCALARS {
        assert!(sim_run.report.scalars.contains_key(key),
                "sim report missing {key}: {:?}",
                sim_run.report.scalars.keys().collect::<Vec<_>>());
        assert!(thr_run.report.scalars.contains_key(key),
                "threaded report missing {key}: {:?}",
                thr_run.report.scalars.keys().collect::<Vec<_>>());
    }
    // the unified RunStats agrees with the report scalars on both
    for run in [&sim_run, &thr_run] {
        assert_eq!(run.stats.msgs_lost as f64,
                   run.report.scalars["msgs_lost"]);
        assert_eq!(run.stats.bytes_sent as f64,
                   run.report.scalars["bytes_sent"]);
        assert_eq!(run.stats.msgs_paced as f64,
                   run.report.scalars["msgs_paced"]);
    }
    // and the loss was genuinely injected on both engines
    assert!(sim_run.stats.msgs_lost > 0);
    assert!(thr_run.stats.msgs_lost > 0);
    // engine-specific extras stay engine-tagged
    assert!(sim_run.stats.virtual_time.is_some()
            && sim_run.stats.wall_seconds.is_none());
    assert!(thr_run.stats.wall_seconds.is_some()
            && thr_run.stats.virtual_time.is_none());
}

#[test]
fn engine_sweep_produces_the_side_by_side_artifacts() {
    // the `repro train --engine both` path as a library call: two labeled
    // runs, one scalars CSV whose columns are the engines
    let cmp = Experiment::new(Workload::LogReg, AlgoKind::RFast)
        .topology(&Topology::ring(3))
        .config(SimConfig {
            eval_every: 0.05,
            ..SimConfig::logreg_paper()
        })
        .stop(Stop::Iterations(200))
        .sweep_engines(&[Engine::Sim, Engine::threaded(Some(1e-4))])
        .unwrap();
    assert_eq!(cmp.runs.len(), 2);
    assert_eq!(cmp.runs[0].report.label, "sim");
    assert_eq!(cmp.runs[1].report.label, "threaded");
    let dir = std::env::temp_dir().join(format!(
        "rfast_engine_sweep_{}", std::process::id()));
    cmp.save_csvs(&dir, "both").unwrap();
    let scalars =
        std::fs::read_to_string(dir.join("both_scalars.csv")).unwrap();
    assert!(scalars.starts_with("metric,sim,threaded"), "{scalars}");
    for key in UNIFIED_SCALARS {
        let row = scalars
            .lines()
            .find(|l| l.starts_with(&format!("{key},")))
            .unwrap_or_else(|| panic!("no {key} row in:\n{scalars}"));
        // both engines filled their cell (no trailing empty column)
        let cells: Vec<&str> = row.split(',').collect();
        assert_eq!(cells.len(), 3, "{row}");
        assert!(!cells[1].is_empty() && !cells[2].is_empty(), "{row}");
    }
    // engine-exclusive series must carry the OWNING engine's label —
    // never the other column's (disjoint-series labeling regression)
    let wall =
        std::fs::read_to_string(dir.join("both_loss_vs_wall.csv")).unwrap();
    assert!(wall.starts_with("x,threaded"), "{wall}");
    let virt =
        std::fs::read_to_string(dir.join("both_loss_vs_time.csv")).unwrap();
    assert!(virt.starts_with("x,sim"), "{virt}");
    std::fs::remove_dir_all(&dir).ok();
}
