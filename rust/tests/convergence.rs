//! Cross-algorithm convergence matrix through the full simulator stack —
//! every algorithm × several topologies on closed-form quadratics, plus
//! the paper's structural claims (who works where). Driven through the
//! `exp::Experiment` builder (the engines' canonical entry point).

use rfast::algo::AlgoKind;
use rfast::config::SimConfig;
use rfast::exp::{Experiment, QuadSpec, Stop, Workload};
use rfast::graph::{Topology, TopologyKind};

fn cfg(seed: u64, gamma: f32) -> SimConfig {
    SimConfig {
        seed,
        gamma,
        compute_mean: 0.01,
        compute_jitter: 0.3,
        link_latency: 0.002,
        latency_jitter: 0.3,
        latency_cap: 0.05,
        eval_every: 5.0,
        ..SimConfig::default()
    }
}

fn final_gap(algo: AlgoKind, topo: &Topology, gamma: f32, spread: f32,
             iters: u64, seed: u64) -> f64 {
    let spec =
        QuadSpec { dim: 8, h_min: 0.5, h_max: 2.0, spread, noise: 0.0 };
    Experiment::new(Workload::Quadratic(spec), algo)
        .topology(topo)
        .config(cfg(seed, gamma))
        .stop(Stop::Iterations(iters))
        .run()
        .expect("quad run")
        .report
        .final_gap
        .unwrap()
}

#[test]
fn gradient_tracking_algorithms_are_exact_on_heterogeneous_objectives() {
    // R-FAST / Push-Pull / S-AB converge to the exact optimum despite
    // heterogeneity; gap limited only by fp precision and finite horizon.
    let topo = Topology::ring(5);
    for (algo, gamma) in [
        (AlgoKind::RFast, 0.04),
        (AlgoKind::PushPull, 0.04),
        (AlgoKind::SAb, 0.04),
        (AlgoKind::RingAllReduce, 0.10),
    ] {
        let gap = final_gap(algo, &topo, gamma, 1.5, 60_000, 3);
        assert!(gap < 5e-3, "{}: gap {gap}", algo.name());
    }
}

#[test]
fn non_tracking_algorithms_carry_heterogeneity_bias() {
    let topo = Topology::ring(5);
    for algo in [AlgoKind::DPsgd, AlgoKind::AdPsgd] {
        let gap = final_gap(algo, &topo, 0.04, 1.5, 60_000, 3);
        assert!(
            gap > 1e-2,
            "{}: expected ς-bias with fixed step, gap {gap}",
            algo.name()
        );
    }
}

#[test]
fn rfast_works_on_every_assumption2_topology() {
    for kind in [
        TopologyKind::BinaryTree,
        TopologyKind::Line,
        TopologyKind::Ring,
        TopologyKind::Exponential,
        TopologyKind::Mesh,
        TopologyKind::Star,
        TopologyKind::Gossip,
    ] {
        let topo = kind.build(7);
        // γ below every topology's stability threshold γ̄ — the line
        // graph's is the smallest (η = m̄^K1 smallest over its 6-hop
        // one-directional path): γ=0.03 slowly DIVERGES there while
        // γ=0.02 reaches 1e-7 gaps (Theorem 1's "sufficiently small γ"
        // is not vacuous!)
        let gap = final_gap(AlgoKind::RFast, &topo, 0.02, 1.0, 100_000,
                            kind.name().len() as u64);
        assert!(gap < 1e-2, "{}: gap {gap}", kind.name());
    }
}

#[test]
fn rfast_scales_with_more_nodes() {
    // time-to-target must decrease when more nodes share the work
    // (Fig 4b, on the paper's logreg workload)
    let time_for = |n: usize| -> f64 {
        let topo = Topology::binary_tree(n);
        let run = Experiment::new(Workload::LogReg, AlgoKind::RFast)
            .topology(&topo)
            .seed(5)
            .stop(Stop::TargetLoss { loss: 0.12, max_time: 2_000.0 })
            .run()
            .expect("logreg run");
        run.report.series["loss_vs_time"]
            .time_to_reach(0.12)
            .unwrap_or(f64::INFINITY)
    };
    let t3 = time_for(3);
    let t15 = time_for(15);
    assert!(
        t15 < t3,
        "15 nodes should beat 3 nodes to target: {t3} vs {t15}"
    );
}

#[test]
fn synchronous_rfast_schedule_matches_pushpull_asymptote() {
    // Remark 2: under a synchronous schedule R-FAST is Push-Pull. Run both
    // under near-synchronous timing (no jitter, tiny latency) and compare
    // the reached optimum.
    let topo = Topology::ring(4);
    let mk_cfg = |seed| SimConfig {
        seed,
        gamma: 0.03,
        compute_mean: 0.01,
        compute_jitter: 0.0,
        link_latency: 1e-4,
        latency_jitter: 0.0,
        latency_cap: 1e-3,
        eval_every: 10.0,
        ..SimConfig::default()
    };
    let run = |algo| {
        Experiment::new(
                Workload::Quadratic(QuadSpec::heterogeneous(8, 0.5, 2.0)),
                algo)
            .topology(&topo)
            .config(mk_cfg(9))
            .stop(Stop::Iterations(40_000))
            .run()
            .expect("sync run")
            .report
            .final_gap
            .unwrap()
    };
    let g_rfast = run(AlgoKind::RFast);
    let g_pp = run(AlgoKind::PushPull);
    assert!(g_rfast < 1e-3, "rfast {g_rfast}");
    assert!(g_pp < 1e-3, "push-pull {g_pp}");
}

#[test]
fn straggler_immunity_is_asynchrony_specific() {
    // stronger form of the sim unit test: sweep factor and check the
    // monotone response of the sync slowdown while async stays flat
    let time_for = |algo: AlgoKind, factor: Option<f64>| -> f64 {
        let topo = Topology::ring(4);
        let mut c = cfg(13, 0.03);
        c.straggler = factor.map(|f| (2, f));
        let run = Experiment::new(
                Workload::Quadratic(QuadSpec::heterogeneous(8, 0.5, 2.0)),
                algo)
            .topology(&topo)
            .config(c)
            .stop(Stop::Iterations(8_000))
            .run()
            .expect("straggler run");
        run.stats.virtual_time.unwrap()
    };
    let sync_base = time_for(AlgoKind::RingAllReduce, None);
    let async_base = time_for(AlgoKind::RFast, None);
    let mut last_sync = sync_base;
    for factor in [2.0, 4.0, 8.0] {
        let s = time_for(AlgoKind::RingAllReduce, Some(factor));
        assert!(s > last_sync, "sync time must grow with factor {factor}");
        last_sync = s;
        let a = time_for(AlgoKind::RFast, Some(factor));
        assert!(
            a < async_base * 1.7,
            "async time must stay near-flat at factor {factor}: {a} vs {async_base}"
        );
    }
}
