//! Scale smoke (DESIGN.md §13): the sparse topology representation and
//! the calendar-queue scheduler must make 10k-node simulator runs
//! routine. This binary installs the counting allocator so the memory
//! ceilings are *asserted*, not eyeballed:
//!
//! * a 10k-node logreg run finishes inside a wall-clock and peak-heap
//!   budget;
//! * no public topology constructor allocates anything resembling an
//!   n × n buffer at large n.
//!
//! CI runs this file in its own step; the peak-heap gauge is process
//! global, so the tests serialize on a mutex.

use rfast::algo::AlgoKind;
use rfast::exp::bench::{self, CountingAllocator};
use rfast::exp::{Experiment, Stop, Workload};
use rfast::graph::Topology;
use std::sync::Mutex;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

/// Serializes the peak-gauge windows across the tests in this binary.
static GAUGE: Mutex<()> = Mutex::new(());

#[test]
fn ten_thousand_node_logreg_run_fits_time_and_memory_budget() {
    let _window = GAUGE.lock().unwrap();
    assert!(bench::counting_allocator_active());

    let topo = Topology::from_spec("tree:random@0:7+random@0:21", 10_000)
        .unwrap();
    let mut cfg = Workload::LogReg.paper_config();
    cfg.seed = 2;

    bench::reset_peak();
    let t0 = std::time::Instant::now();
    let run = Experiment::new(Workload::LogReg, AlgoKind::RFast)
        .topology(&topo)
        .config(cfg)
        .stop(Stop::Iterations(12_000))
        .run()
        .unwrap();
    let wall = t0.elapsed().as_secs_f64();
    let (_, peak) = bench::live_peak_stats();

    let wakes = run.report.scalars["grad_wakes"];
    assert!(wakes >= 12_000.0, "budget not consumed: {wakes}");
    assert!(run.report.final_gap.is_none()
                || run.report.final_gap.unwrap().is_finite());
    // wall budget: generous for CI runners — a dense-era n² layer blew
    // this by an order of magnitude before it blew the allocator
    assert!(wall < 180.0, "10k-node run took {wall:.1}s");
    // peak-heap ceiling: n² f64 link state alone would be 800 MB; the
    // whole run (dataset, 10k node states, sparse link layer) must stay
    // under 1.5 GB
    assert!(peak < 1_500_000_000, "peak heap {peak} bytes");
}

#[test]
fn no_topology_constructor_allocates_n_squared_at_large_n() {
    let _window = GAUGE.lock().unwrap();
    let n = 30_000usize;
    let specs = ["star", "line", "binary_tree", "gossip",
                 "tree:random@0:7+random@0:21"];
    for spec in specs {
        let before = bench::alloc_stats().1;
        let topo = Topology::from_spec(spec, n).unwrap();
        let delta = bench::alloc_stats().1 - before;
        // cumulative bytes requested while building: O(edges), with
        // generous per-node Vec overhead — an n × n f32 buffer alone
        // would be 3.6 GB
        let budget = 6_000u64 * n as u64;
        assert!(delta < budget,
                "{spec}: building n = {n} requested {delta} bytes \
                 (budget {budget})");
        assert_eq!(topo.n(), n);
        drop(topo);
    }
}
