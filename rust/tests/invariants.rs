//! Property tests on R-FAST's core invariants, driven by an adversarial
//! random scheduler with full control over wake order, message delay,
//! reordering and drops — the conditions of Assumption 3 and worse.

use rfast::algo::{Msg, MsgKind, NodeState, RFastNode, RFastParams};
use rfast::graph::{Topology, TopologyKind};
use rfast::linalg;
use rfast::oracle::{GradOracle, NodeOracle, QuadraticOracle};
use rfast::prng::Rng;
use rfast::testutil::forall;

/// Adversarial harness: messages sit in a pool; each round a random node
/// wakes and a random subset of pooled messages is delivered (possibly out
/// of order); ρ/v messages are dropped with probability `drop_p`.
struct Adversary {
    nodes: Vec<RFastNode>,
    oracles: Vec<Box<dyn NodeOracle>>,
    pool: Vec<Msg>,
    rng: Rng,
    drop_p: f64,
}

impl Adversary {
    fn new(topo: &Topology, dim: usize, gamma: f32, robust: bool,
           drop_p: f64, seed: u64) -> Adversary {
        let quad = QuadraticOracle::heterogeneous(dim, topo.n(), 0.5, 2.0, seed);
        let set = quad.into_set();
        let x0 = vec![0.25f32; dim];
        let nodes = (0..topo.n())
            .map(|i| RFastNode::new(i, topo, &x0, gamma, RFastParams { robust }))
            .collect();
        Adversary {
            nodes,
            oracles: set.nodes,
            pool: Vec::new(),
            rng: Rng::stream(seed, 0xad5e),
            drop_p,
        }
    }

    fn step(&mut self) {
        let i = self.rng.below(self.nodes.len());
        let mut out = Vec::new();
        self.nodes[i].wake(self.oracles[i].as_mut(), &mut out);
        for m in out {
            if self.rng.chance(self.drop_p) {
                continue; // adversarial loss
            }
            self.pool.push(m);
        }
        // deliver a random subset, in random order
        let deliver = self.rng.below(self.pool.len() + 1);
        self.rng.shuffle(&mut self.pool);
        let mut replies = Vec::new();
        for m in self.pool.drain(..deliver) {
            let to = m.to;
            self.nodes[to].receive(m, &mut replies);
        }
        assert!(replies.is_empty());
    }

    /// Lemma 3 analogue over the real (non-augmented) system — delegates
    /// to the shared oracle in `rfast::testutil` (one definition for the
    /// property tests AND the fuzzer's conservation invariant).
    fn conservation_residual(&self) -> f64 {
        let refs: Vec<&RFastNode> = self.nodes.iter().collect();
        rfast::testutil::rho_mass_residual(&refs)
    }
}

#[test]
fn mass_conservation_under_arbitrary_schedules() {
    forall(25, 0x5eed, |rng| {
        let kinds = [
            TopologyKind::Ring,
            TopologyKind::BinaryTree,
            TopologyKind::Line,
            TopologyKind::Star,
            TopologyKind::Exponential,
        ];
        let kind = kinds[rng.below(kinds.len())];
        let n = 2 + rng.below(7);
        let topo = kind.build(n);
        let drop_p = rng.f64() * 0.5;
        let mut adv = Adversary::new(&topo, 4, 0.02, true, drop_p,
                                     rng.next_u64());
        for step in 0..300 {
            adv.step();
            // f64 ρ pipeline keeps the residual at fp-noise level even
            // though z is f32
            let r = adv.conservation_residual();
            if r > 2e-3 {
                return Err(format!(
                    "{:?} n={n} drop={drop_p:.2}: residual {r} at step {step}",
                    kind
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn naive_gt_conserves_only_without_loss() {
    // with drop_p = 0 the naive one-shot scheme conserves mass up to
    // in-flight deltas (which our residual cannot see — the pool holds
    // them); so instead verify the *behavioural* consequence: naive == ok
    // without loss, biased with loss, robust ok with loss.
    let gap = |robust: bool, drop_p: f64, seed: u64| -> f64 {
        let topo = Topology::ring(5);
        let quad = QuadraticOracle::heterogeneous(4, 5, 0.5, 2.0, seed);
        let xs = quad.optimum();
        let mut adv = Adversary::new(&topo, 4, 0.03, robust, drop_p, seed);
        for _ in 0..30_000 {
            adv.step();
        }
        // deliver all leftovers so the final state is quiescent
        let mut replies = Vec::new();
        for m in adv.pool.drain(..) {
            let to = m.to;
            adv.nodes[to].receive(m, &mut replies);
        }
        adv.nodes
            .iter()
            .map(|nd| linalg::dist(nd.param(), &xs))
            .sum::<f64>()
            / adv.nodes.len() as f64
    };
    let robust_lossy = gap(true, 0.3, 7);
    let naive_clean = gap(false, 0.0, 7);
    let naive_lossy = gap(false, 0.3, 7);
    assert!(robust_lossy < 1e-2, "robust under loss: {robust_lossy}");
    assert!(naive_clean < 1e-2, "naive without loss: {naive_clean}");
    assert!(
        naive_lossy > 10.0 * naive_clean.max(1e-4),
        "naive should break under loss: clean {naive_clean} lossy {naive_lossy}"
    );
}

#[test]
fn convergence_under_adversarial_scheduling() {
    // random wake orders + reordering + moderate drops must still converge
    // to the exact optimum (robust mode)
    forall(8, 0xc0ffee, |rng| {
        let topo = Topology::binary_tree(2 + rng.below(6));
        let quad =
            QuadraticOracle::heterogeneous(4, topo.n(), 0.5, 2.0, rng.next_u64());
        let xs = quad.optimum();
        let mut adv =
            Adversary::new(&topo, 4, 0.03, true, rng.f64() * 0.3, rng.next_u64());
        // seed oracle parity: Adversary rebuilds its own oracle from its
        // seed, so compute the optimum from ITS instance instead
        let _ = xs;
        for _ in 0..40_000 {
            adv.step();
        }
        let mut replies = Vec::new();
        for m in adv.pool.drain(..) {
            let to = m.to;
            adv.nodes[to].receive(m, &mut replies);
        }
        // consensus: all nodes close to each other
        let spread: f64 = (1..adv.nodes.len())
            .map(|i| linalg::dist(adv.nodes[i].param(), adv.nodes[0].param()))
            .fold(0.0, f64::max);
        if spread > 5e-2 {
            return Err(format!("consensus spread {spread}"));
        }
        Ok(())
    });
}

#[test]
fn consensus_with_zero_gradients() {
    // γ = 0 ⇒ pure consensus dynamics: all x_i must agree eventually and
    // stay inside the convex hull of the initial values
    let topo = Topology::binary_tree(7);
    let quad = QuadraticOracle::heterogeneous(3, 7, 1.0, 1.0, 1);
    let set = quad.into_set();
    let mut oracles = set.nodes;
    let mut nodes: Vec<RFastNode> = (0..7)
        .map(|i| {
            let x0 = vec![i as f32, -(i as f32), 1.0];
            RFastNode::new(i, &topo, &x0, 0.0, RFastParams::default())
        })
        .collect();
    let mut rng = Rng::new(5);
    let mut pool: Vec<Msg> = Vec::new();
    for _ in 0..30_000 {
        let i = rng.below(7);
        let mut out = Vec::new();
        nodes[i].wake(oracles[i].as_mut(), &mut out);
        pool.extend(out);
        rng.shuffle(&mut pool);
        let k = rng.below(pool.len() + 1);
        let mut replies = Vec::new();
        for m in pool.drain(..k) {
            let to = m.to;
            nodes[to].receive(m, &mut replies);
        }
    }
    let spread: f64 = (1..7)
        .map(|i| linalg::dist(nodes[i].param(), nodes[0].param()))
        .fold(0.0, f64::max);
    assert!(spread < 1e-3, "consensus spread {spread}");
    for v in nodes[0].param() {
        assert!((-7.0..=7.0).contains(v), "left the convex hull: {v}");
    }
}

#[test]
fn v_messages_use_freshest_stamp_under_reordering() {
    let topo = Topology::line(2);
    let quad = QuadraticOracle::heterogeneous(2, 2, 1.0, 1.0, 3);
    let mut set = quad.into_set();
    let mut n0 = RFastNode::new(0, &topo, &[1.0, 1.0], 0.1,
                                RFastParams::default());
    let mut n1 = RFastNode::new(1, &topo, &[0.0, 0.0], 0.1,
                                RFastParams::default());
    // node 0 wakes three times; deliver its v messages to node 1 in
    // REVERSE order; node 1 must keep the stamp-3 payload
    let mut msgs: Vec<Msg> = Vec::new();
    for _ in 0..3 {
        let mut out = Vec::new();
        n0.wake(set.nodes[0].as_mut(), &mut out);
        msgs.extend(out.into_iter().filter(|m| m.kind == MsgKind::V));
    }
    assert_eq!(msgs.len(), 3);
    let freshest = msgs.last().unwrap().payload.clone();
    msgs.reverse();
    let mut replies = Vec::new();
    for m in msgs {
        n1.receive(m, &mut replies);
    }
    // wake node 1 once; its x must mix the stamp-3 v (w = 1/2 each side)
    let mut out = Vec::new();
    n1.wake(set.nodes[1].as_mut(), &mut out);
    // x1 = 0.5*v_self + 0.5*freshest, and v_self = x0_1 − γ z (z=g(x) at init)
    let x1 = n1.param();
    // bound check is enough to prove the right payload was used: with the
    // stale (stamp-1) payload the mix would differ
    let mut g = vec![0.0f32; 2];
    let _ = set.nodes[1].grad(&[0.0, 0.0], &mut g);
    for d in 0..2 {
        let contrib = 0.5 * freshest[d];
        assert!(
            (x1[d] - contrib).abs() < 1.0,
            "x1[{d}]={} vs freshest contrib {contrib}",
            x1[d]
        );
    }
}
