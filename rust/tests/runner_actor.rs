//! Actor-pool integration gates for the threaded engine (DESIGN.md §15):
//! the M:N scheduler must multiplex far more actors than workers, the
//! bounded-mailbox overflow policies must surface through the unified
//! counter set without wedging a channel, and nothing on the actor hot
//! path may pace by sleeping an OS thread.
//!
//! Like `runner_scenario`, these tests burn real wall time; CI runs them
//! single-threaded with a job timeout, and every assertion is
//! directional, never exact.

use rfast::algo::AlgoKind;
use rfast::config::SimConfig;
use rfast::exp::{Engine, Experiment, QuadSpec, Stop, Workload};
use rfast::graph::Topology;
use rfast::oracle::QuadraticOracle;
use rfast::runner::{MailboxCfg, OverflowPolicy, RunnerStats, ThreadedRunner};
use rfast::scenario::Scenario;
use rfast::testutil::{tracking_quad_eval, QuadFactory};

/// The scalar set the ISSUE's acceptance gate names for the 512-actor
/// smoke — the same unified set `runner_scenario` checks per preset.
const UNIFIED_SCALARS: [&str; 5] = [
    "msgs_lost",
    "bytes_sent",
    "msgs_backpressured",
    "msgs_paced",
    "epoch",
];

/// Acceptance smoke: 512 node actors multiplexed onto 4 OS workers under
/// the paper's Fig. 6 straggler preset (node 3 slowed 5×, 2% loss). The
/// old thread-per-node engine would need 512 OS threads here; the pool
/// must finish a short wall-clock run with every actor making progress
/// and the full unified scalar set reported.
#[test]
fn straggler_512_actors_on_4_workers_smoke() {
    let mut cfg = SimConfig {
        seed: 101,
        gamma: 0.02,
        compute_mean: 0.001,
        eval_every: 0.1,
        ..SimConfig::default()
    };
    cfg.scenario = Some(Scenario::by_name("paper_fig6_straggler").unwrap());
    let run = Experiment::new(
            Workload::Quadratic(QuadSpec::heterogeneous(4, 0.5, 2.0)),
            AlgoKind::RFast)
        .topology(&Topology::ring(512))
        .config(cfg)
        .engine(Engine::Threaded {
            pace: Some(2e-4),
            workers: Some(4),
            mailbox: MailboxCfg::default(),
        })
        .stop(Stop::Time(0.6))
        .run()
        .expect("512-actor straggler smoke");

    assert_eq!(run.stats.workers, Some(4), "pool size must be honored");
    assert_eq!(run.stats.steps_per_node.len(), 512);
    let starved =
        run.stats.steps_per_node.iter().filter(|&&s| s == 0).count();
    assert_eq!(starved, 0, "{starved} of 512 actors never ran a step");
    for key in UNIFIED_SCALARS {
        assert!(run.report.scalars.contains_key(key),
                "acceptance scalar {key} missing");
    }
    assert!(run.stats.msgs_lost > 0, "preset carries 2% loss");
    // default mailbox depth (1024) never overflows on a ring: drops are
    // an opt-in policy outcome, not a pool side effect
    assert_eq!(run.report.scalars.get("msgs_dropped"), Some(&0.0));
}

/// Run a small ring with a severely straggled receiver (node 0 slowed
/// 40×) so its neighbors outpace its mailbox drain, under the given
/// mailbox bound.
fn run_slow_receiver(mailbox: MailboxCfg)
    -> (rfast::metrics::Report, RunnerStats)
{
    let q = QuadraticOracle::heterogeneous(6, 4, 0.5, 2.0, 77);
    let mut cfg = SimConfig {
        seed: 33,
        gamma: 0.02,
        compute_mean: 0.001,
        eval_every: 0.05,
        ..SimConfig::default()
    };
    cfg.scenario = Some(Scenario::single_straggler(0, 40.0));
    let runner = ThreadedRunner::new(cfg, &Topology::ring(4),
                                     AlgoKind::RFast, vec![0.0; 6])
        .with_pace(1e-3)
        .with_workers(2)
        .with_mailbox(mailbox);
    let (mut eval, _) = tracking_quad_eval(q.clone());
    runner.run(&QuadFactory(q), &mut eval, Stop::Time(0.4))
}

#[test]
fn drop_oldest_policy_sheds_into_msgs_dropped() {
    let (report, stats) = run_slow_receiver(MailboxCfg {
        capacity: 1,
        policy: OverflowPolicy::DropOldest,
    });
    assert!(stats.msgs_dropped > 0, "capacity 1 never overflowed: {stats:?}");
    assert_eq!(report.scalars.get("msgs_dropped"),
               Some(&(stats.msgs_dropped as f64)),
               "report must agree with the engine counter");
    // dropping a message releases its (link, channel) slot — the channel
    // must not wedge, so every node keeps stepping (the no_stuck shape
    // the fuzzer's threaded oracle checks)
    for (i, &s) in stats.steps_per_node.iter().enumerate() {
        assert!(s > 0, "node {i} starved: {:?}", stats.steps_per_node);
    }
}

#[test]
fn drop_newest_policy_sheds_into_msgs_dropped() {
    let (_, stats) = run_slow_receiver(MailboxCfg {
        capacity: 1,
        policy: OverflowPolicy::DropNewest,
    });
    assert!(stats.msgs_dropped > 0, "capacity 1 never overflowed: {stats:?}");
    for (i, &s) in stats.steps_per_node.iter().enumerate() {
        assert!(s > 0, "node {i} starved: {:?}", stats.steps_per_node);
    }
}

#[test]
fn backpressure_policy_rejects_instead_of_dropping() {
    let (_, stats) = run_slow_receiver(MailboxCfg {
        capacity: 1,
        policy: OverflowPolicy::Backpressure,
    });
    assert_eq!(stats.msgs_dropped, 0,
               "backpressure must never drop: {stats:?}");
    assert!(stats.msgs_backpressured > 0,
            "full mailbox must reject like a busy link: {stats:?}");
    for (i, &s) in stats.steps_per_node.iter().enumerate() {
        assert!(s > 0, "node {i} starved: {:?}", stats.steps_per_node);
    }
}

/// ISSUE acceptance gate: pacing, stragglers, latency and bandwidth are
/// timer-wheel suspends now — no actor-pool source file may sleep an OS
/// thread. (`runner/mod.rs` keeps one sleep in the coordinator's eval
/// loop, which runs on the caller's thread, not a pool worker.)
#[test]
fn no_thread_sleep_on_the_actor_hot_path() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("src")
        .join("runner");
    for file in ["actor.rs", "mailbox.rs", "pool.rs", "timer.rs"] {
        let text = std::fs::read_to_string(root.join(file))
            .unwrap_or_else(|e| panic!("read {file}: {e}"));
        assert!(!text.contains("thread::sleep"),
                "{file} sleeps on the actor hot path");
        assert!(!text.contains("sleep("),
                "{file} sleeps on the actor hot path");
    }
}
