//! Scenario-subsystem integration: JSON round-trips of the shipped
//! presets, determinism of scenario runs, and the paper-shaped behavioural
//! claims (R-FAST converges under heavy loss; synchronous baselines pay
//! the straggler at the barrier) driven through the scenario layer.

use rfast::algo::AlgoKind;
use rfast::config::SimConfig;
use rfast::exp::{Experiment, QuadSpec, RunStats, Stop, Workload};
use rfast::graph::Topology;
use rfast::jsonio;
use rfast::oracle::{GradOracle, QuadraticOracle};
use rfast::scenario::Scenario;
use rfast::sim::Simulator;

fn fast_cfg(seed: u64) -> SimConfig {
    SimConfig {
        seed,
        gamma: 0.04,
        compute_mean: 0.01,
        compute_jitter: 0.3,
        link_latency: 0.002,
        latency_jitter: 0.3,
        latency_cap: 0.05,
        eval_every: 5.0,
        ..SimConfig::default()
    }
}

fn run_quad(algo: AlgoKind, n: usize, scenario: Option<Scenario>, seed: u64,
            iters: u64) -> (f64, RunStats) {
    let run = Experiment::new(
            Workload::Quadratic(QuadSpec::heterogeneous(8, 0.5, 2.0)), algo)
        .topology(&Topology::ring(n))
        .config(fast_cfg(seed))
        .maybe_scenario(scenario.as_ref())
        .stop(Stop::Iterations(iters))
        .run()
        .expect("scenario run");
    (run.report.final_gap.unwrap(), run.stats)
}

#[test]
fn presets_roundtrip_through_json_files() {
    // the acceptance-criteria loop: serialize every preset to a file on
    // disk, load it back through the same path the CLI uses, compare
    let dir = std::env::temp_dir().join("rfast_scenario_roundtrip");
    std::fs::create_dir_all(&dir).unwrap();
    let names = Scenario::preset_names();
    assert!(names.len() >= 4, "ship at least 4 presets, have {names:?}");
    for name in names {
        let s = Scenario::by_name(name).unwrap();
        let path = dir.join(format!("{name}.json"));
        std::fs::write(&path, s.to_json().to_string()).unwrap();
        let loaded = Scenario::load(&path).unwrap();
        assert_eq!(loaded, s, "{name} changed across disk round-trip");
        // and through the generic JSON value layer
        let j = jsonio::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(Scenario::from_json(&j).unwrap(), s, "{name}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn same_seed_and_scenario_is_bitwise_deterministic() {
    let sc = Scenario::by_name("degrading_network").unwrap();
    let a = run_quad(AlgoKind::RFast, 5, Some(sc.clone()), 9, 5_000);
    let b = run_quad(AlgoKind::RFast, 5, Some(sc), 9, 5_000);
    assert_eq!(a.0, b.0, "final gap must match exactly");
    assert_eq!(a.1.msgs_sent, b.1.msgs_sent);
    assert_eq!(a.1.msgs_lost, b.1.msgs_lost);
    assert_eq!(a.1.msgs_backpressured, b.1.msgs_backpressured);
    assert_eq!(a.1.virtual_time, b.1.virtual_time);
}

#[test]
fn rfast_converges_under_lossy_30pct_preset() {
    let sc = Scenario::by_name("lossy_30pct").unwrap();
    let (gap, stats) = run_quad(AlgoKind::RFast, 5, Some(sc), 7, 40_000);
    assert!(stats.msgs_lost > 100, "loss injection active: {stats:?}");
    assert!(gap < 2e-2, "R-FAST gap under 30% loss: {gap}");
}

#[test]
fn sync_baseline_pays_the_straggler_scenario_rfast_does_not() {
    // §VI-B through the scenario layer: the synchronous baseline's wall
    // time inflates toward the straggler factor, R-FAST barely moves.
    // (Packet loss never applies to the synchronous algorithms — they
    // would deadlock; paper §VI ¶1 — so the slowdown is the sync-visible
    // fault channel.)
    let sc = Scenario::single_straggler(1, 5.0);
    let clean_sync = run_quad(AlgoKind::RingAllReduce, 4, None, 13, 4_000);
    let slow_sync =
        run_quad(AlgoKind::RingAllReduce, 4, Some(sc.clone()), 13, 4_000);
    let clean_async = run_quad(AlgoKind::RFast, 4, None, 13, 4_000);
    let slow_async = run_quad(AlgoKind::RFast, 4, Some(sc), 13, 4_000);
    let sync_ratio = slow_sync.1.elapsed_seconds() / clean_sync.1.elapsed_seconds();
    let async_ratio = slow_async.1.elapsed_seconds() / clean_async.1.elapsed_seconds();
    assert!(sync_ratio > 2.0, "sync should stall: {sync_ratio}");
    assert!(async_ratio < 1.6, "async should shrug: {async_ratio}");
}

#[test]
fn late_straggler_onset_only_bites_after_t() {
    // run a sync algorithm (most straggler-sensitive) to a fixed iteration
    // budget twice: the onset-at-T scenario must land strictly between
    // clean and permanently-slow
    let mut late = Scenario::named("late", "");
    late.stragglers.push(rfast::scenario::StragglerSpec {
        node: 1,
        factor: 5.0,
        schedule: rfast::scenario::StragglerSchedule::FromTime { at: 15.0 },
    });
    let clean = run_quad(AlgoKind::RingAllReduce, 4, None, 21, 4_000);
    let perm = run_quad(AlgoKind::RingAllReduce, 4,
                        Some(Scenario::single_straggler(1, 5.0)), 21, 4_000);
    let lately = run_quad(AlgoKind::RingAllReduce, 4, Some(late), 21, 4_000);
    assert!(
        clean.1.elapsed_seconds() < lately.1.elapsed_seconds()
            && lately.1.elapsed_seconds() < perm.1.elapsed_seconds(),
        "onset ordering: clean {} < late {} < permanent {}",
        clean.1.elapsed_seconds(), lately.1.elapsed_seconds(),
        perm.1.elapsed_seconds()
    );
}

#[test]
fn churn_pauses_reduce_a_nodes_share_but_not_convergence() {
    // pause node 1 repeatedly: R-FAST keeps converging (asynchrony), and
    // total progress still reaches the iteration budget
    let mut sc = Scenario::named("test_churn", "");
    for k in 0..20 {
        let t0 = 5.0 + 10.0 * k as f64;
        sc.churn.push(rfast::scenario::ChurnEvent {
            node: 1,
            pause_at: t0,
            resume_at: t0 + 5.0,
        });
    }
    let (gap, stats) = run_quad(AlgoKind::RFast, 4, Some(sc), 31, 30_000);
    assert_eq!(stats.total_steps(), 30_000);
    assert!(gap < 5e-2, "R-FAST gap under churn: {gap}");
}

#[test]
fn bandwidth_caps_congest_links() {
    // the cap delays delivery, which delays the ack, which keeps the
    // one-unacked-packet channel busy across whole compute steps: the
    // sender-side backpressure counter must climb well above the clean
    // run's jitter-tail level (async wake cadence itself is unchanged —
    // compute, not links, drives the event clock)
    let mut sc = Scenario::named("tight_bw", "");
    sc.bandwidth.push(rfast::scenario::BandwidthCap {
        from: None,
        to: None,
        bytes_per_sec: 2.0 * 1024.0, // 2 KiB/s: a 32-byte payload ≈ 16 ms
    });
    let free = run_quad(AlgoKind::RFast, 4, None, 17, 3_000);
    let capped = run_quad(AlgoKind::RFast, 4, Some(sc), 17, 3_000);
    assert!(
        capped.1.msgs_backpressured > free.1.msgs_backpressured * 2 + 100,
        "cap must congest the ack channel: {} vs {}",
        capped.1.msgs_backpressured, free.1.msgs_backpressured
    );
    assert!(capped.1.msgs_delivered.unwrap() > 0);
    assert!(capped.1.msgs_paced > 0, "bw cap must pace sim sends");
}

#[test]
fn scenario_node_bounds_checked_against_topology() {
    let topo = Topology::ring(3);
    let quad = QuadraticOracle::heterogeneous(4, 3, 0.5, 2.0, 1);
    let mut cfg = fast_cfg(1);
    cfg.scenario = Some(Scenario::single_straggler(7, 2.0)); // node 7 of 3
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        Simulator::new(cfg, &topo, AlgoKind::RFast, quad.into_set())
    }));
    assert!(result.is_err(), "out-of-range scenario node must be rejected");
}
