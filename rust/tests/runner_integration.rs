//! Threaded-runner integration: real asynchronous training on the logreg
//! workload with the pure-rust oracle, plus stats sanity.

use rfast::algo::AlgoKind;
use rfast::config::SimConfig;
use rfast::data::{Dataset, Partition};
use rfast::graph::Topology;
use rfast::oracle::{eval_logreg, LogRegFactory, OracleFactory};
use rfast::runner::{RunUntil, ThreadedRunner};
use std::sync::Arc;

fn workload(n: usize, seed: u64) -> (LogRegFactory, Arc<Dataset>) {
    let (train, eval) = Dataset::mnist01_like(seed).split_eval(2000);
    let train = Arc::new(train);
    let partition = Partition::iid(&train, n, seed);
    let eval = Arc::new(eval);
    (
        LogRegFactory {
            train: Arc::clone(&train),
            eval_set: Arc::clone(&eval),
            partition,
            batch: 32,
            l2: 1e-4,
            seed,
        },
        eval,
    )
}

#[test]
fn threaded_rfast_trains_logreg_to_high_accuracy() {
    let n = 4;
    let (factory, eval_set) = workload(n, 3);
    let topo = Topology::binary_tree(n);
    let cfg = SimConfig {
        seed: 3,
        gamma: 2e-3,
        compute_mean: 0.001,
        eval_every: 0.1,
        ..SimConfig::default()
    };
    let runner = ThreadedRunner::new(cfg, &topo, AlgoKind::RFast,
                                     vec![0.0; factory.dim()])
        .with_pace(2e-4);
    let mut eval_fn = {
        let eval_set = Arc::clone(&eval_set);
        move |x: &[f32]| eval_logreg(&eval_set, x, 1e-4)
    };
    let (report, stats) = runner.run(&factory, &mut eval_fn,
                                     RunUntil::TargetLoss {
                                         loss: 0.08,
                                         max_seconds: 30.0,
                                     });
    let acc = report.scalars.get("final_accuracy").copied().unwrap_or(0.0);
    assert!(acc > 0.97, "accuracy {acc}");
    assert!(stats.steps_per_node.iter().all(|&s| s > 50),
            "{:?}", stats.steps_per_node);
    assert!(stats.msgs_sent > 0);
}

#[test]
fn threaded_runner_all_async_algorithms_progress() {
    for algo in [AlgoKind::RFast, AlgoKind::AdPsgd, AlgoKind::Osgp] {
        let n = 3;
        let (factory, eval_set) = workload(n, 9);
        let topo = Topology::ring(n);
        let cfg = SimConfig {
            seed: 9,
            gamma: 3e-3,
            compute_mean: 0.001,
            eval_every: 0.1,
            ..SimConfig::default()
        };
        // OSGP's push-sum mass is destroyed by send discards, so it needs
        // compute ≫ RTT (the paper's regime): pace well above the
        // in-process round trip.
        let runner = ThreadedRunner::new(cfg, &topo, algo,
                                         vec![0.0; factory.dim()])
            .with_pace(5e-4);
        let mut eval_fn = {
            let eval_set = Arc::clone(&eval_set);
            move |x: &[f32]| eval_logreg(&eval_set, x, 1e-4)
        };
        let (report, _) = runner.run(&factory, &mut eval_fn,
                                     RunUntil::TotalSteps(9_000));
        let s = &report.series["loss_vs_wall"];
        assert!(
            s.last_y().unwrap() < s.points[0].1,
            "{}: {:?}",
            algo.name(),
            s.points
        );
    }
}

#[test]
fn threaded_runner_straggler_counts_fewer_steps() {
    let n = 4;
    let (factory, eval_set) = workload(n, 11);
    let topo = Topology::ring(n);
    let mut cfg = SimConfig {
        seed: 11,
        gamma: 1e-3,
        compute_mean: 0.001,
        eval_every: 0.1,
        ..SimConfig::default()
    };
    cfg.straggler = Some((2, 4.0));
    let runner = ThreadedRunner::new(cfg, &topo, AlgoKind::RFast,
                                     vec![0.0; factory.dim()])
        .with_pace(2e-4);
    let mut eval_fn = {
        let eval_set = Arc::clone(&eval_set);
        move |x: &[f32]| eval_logreg(&eval_set, x, 1e-4)
    };
    let (_, stats) =
        runner.run(&factory, &mut eval_fn, RunUntil::WallSeconds(1.5));
    let s = &stats.steps_per_node;
    let others_min = (0..n).filter(|&i| i != 2).map(|i| s[i]).min().unwrap();
    assert!(
        (s[2] as f64) < 0.6 * others_min as f64,
        "straggler {} vs others min {others_min}",
        s[2]
    );
}
