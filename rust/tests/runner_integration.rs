//! Threaded-runner integration: real asynchronous training on the logreg
//! workload, driven through the `exp::Experiment` builder (the same
//! paper_workload data/partition derivation the simulator uses), plus
//! stats sanity.

use rfast::algo::AlgoKind;
use rfast::config::SimConfig;
use rfast::exp::{Engine, Experiment, Stop, Workload};
use rfast::graph::Topology;

#[test]
fn threaded_rfast_trains_logreg_to_high_accuracy() {
    let cfg = SimConfig {
        seed: 3,
        gamma: 2e-3,
        compute_mean: 0.001,
        eval_every: 0.1,
        ..SimConfig::default()
    };
    let run = Experiment::new(Workload::LogReg, AlgoKind::RFast)
        .topology(&Topology::binary_tree(4))
        .config(cfg)
        .engine(Engine::threaded(Some(2e-4)))
        .stop(Stop::TargetLoss { loss: 0.08, max_time: 30.0 })
        .run()
        .expect("threaded logreg run");
    let acc = run.report.scalars.get("final_accuracy").copied().unwrap_or(0.0);
    assert!(acc > 0.97, "accuracy {acc}");
    assert!(run.stats.steps_per_node.iter().all(|&s| s > 50),
            "{:?}", run.stats.steps_per_node);
    assert!(run.stats.msgs_sent > 0);
    assert!(run.stats.wall_seconds.is_some());
}

#[test]
fn threaded_runner_all_async_algorithms_progress() {
    for algo in [AlgoKind::RFast, AlgoKind::AdPsgd, AlgoKind::Osgp] {
        let cfg = SimConfig {
            seed: 9,
            gamma: 3e-3,
            compute_mean: 0.001,
            eval_every: 0.1,
            ..SimConfig::default()
        };
        // OSGP's push-sum mass is destroyed by send discards, so it needs
        // compute ≫ RTT (the paper's regime): pace well above the
        // in-process round trip.
        let run = Experiment::new(Workload::LogReg, algo)
            .topology(&Topology::ring(3))
            .config(cfg)
            .engine(Engine::threaded(Some(5e-4)))
            .stop(Stop::Iterations(9_000))
            .run()
            .expect("threaded run");
        let s = &run.report.series["loss_vs_wall"];
        assert!(
            s.last_y().unwrap() < s.points[0].1,
            "{}: {:?}",
            algo.name(),
            s.points
        );
    }
}

#[test]
fn threaded_runner_straggler_counts_fewer_steps() {
    let n = 4;
    let mut cfg = SimConfig {
        seed: 11,
        gamma: 1e-3,
        compute_mean: 0.001,
        eval_every: 0.1,
        ..SimConfig::default()
    };
    cfg.straggler = Some((2, 4.0));
    let run = Experiment::new(Workload::LogReg, AlgoKind::RFast)
        .topology(&Topology::ring(n))
        .config(cfg)
        .engine(Engine::threaded(Some(2e-4)))
        .stop(Stop::Time(1.5))
        .run()
        .expect("straggler run");
    let s = &run.stats.steps_per_node;
    let others_min = (0..n).filter(|&i| i != 2).map(|i| s[i]).min().unwrap();
    assert!(
        (s[2] as f64) < 0.6 * others_min as f64,
        "straggler {} vs others min {others_min}",
        s[2]
    );
}

#[test]
fn threaded_stop_epochs_uses_the_coordinator_mapping() {
    // Stop::Epochs on the threaded engine: the coordinator converts total
    // steps × epoch-per-node-batch into global epochs and stops there —
    // the same mapping the `epoch` scalar reports
    let cfg = SimConfig {
        seed: 5,
        gamma: 1e-3,
        compute_mean: 0.001,
        eval_every: 0.05,
        ..SimConfig::default()
    };
    let run = Experiment::new(Workload::LogReg, AlgoKind::RFast)
        .topology(&Topology::ring(3))
        .config(cfg)
        .engine(Engine::threaded(Some(1e-3)))
        .stop(Stop::Epochs(0.05))
        .run()
        .expect("epoch-stopped run");
    let epoch = run.report.scalars["epoch"];
    assert!(epoch >= 0.05, "stopped before the epoch budget: {epoch}");
    // a small budget must stop early, not run to the safety deadline
    assert!(epoch < 5.0, "overshot the epoch budget wildly: {epoch}");
    assert!(run.stats.total_steps() > 0);
}
