//! Zero-copy message-fabric integration (DESIGN.md §8): payload sharing
//! must be an *invisible* optimisation. These tests pin down the three
//! claims the fabric makes:
//!
//! 1. broadcasts really share one allocation (`Payload::ptr_eq` across
//!    sibling messages);
//! 2. `make_mut` is genuine copy-on-write — aliased holders never
//!    observe a mutation;
//! 3. the math cannot tell shared payloads from deep-copied ones:
//!    driving identical node sets with shared vs `deep_clone`d messages
//!    yields bitwise-identical states, and a fixed-seed simulator run
//!    emits byte-identical `Report` JSON every time (the golden-run
//!    oracle that held across the owned-Vec → Arc fabric swap).

use rfast::algo::{AlgoKind, Msg, MsgKind, NodeState, Payload};
use rfast::config::SimConfig;
use rfast::exp::{Experiment, QuadSpec, Stop, Workload};
use rfast::graph::Topology;
use rfast::oracle::{GradOracle, QuadraticOracle};
use rfast::sim::Simulator;

fn fast_cfg(seed: u64) -> SimConfig {
    SimConfig {
        seed,
        gamma: 0.04,
        compute_mean: 0.01,
        compute_jitter: 0.3,
        link_latency: 0.002,
        latency_jitter: 0.3,
        latency_cap: 0.05,
        eval_every: 1.0,
        ..SimConfig::default()
    }
}

/// Collect the f32-lane messages of one wake of `node_id`.
fn wake_once(algo: AlgoKind, topo: &Topology, node_id: usize) -> Vec<Msg> {
    let n = topo.n();
    let quad = QuadraticOracle::heterogeneous(6, n, 0.5, 2.0, 11);
    let mut set = quad.into_set();
    let mut nodes = algo.build(topo, &vec![0.1; 6], 0.05, 1);
    let mut out = Vec::new();
    nodes[node_id].wake(set.nodes[node_id].as_mut(), &mut out);
    out
}

#[test]
fn broadcasts_share_one_allocation_across_out_neighbors() {
    // R-FAST: the binary-tree root pushes v to both children
    let out = wake_once(AlgoKind::RFast, &Topology::binary_tree(7), 0);
    let v: Vec<&Msg> = out.iter().filter(|m| m.kind == MsgKind::V).collect();
    assert_eq!(v.len(), 2, "root has two W-out children");
    assert!(Payload::ptr_eq(&v[0].payload, &v[1].payload),
            "v broadcast must share one allocation");

    // exponential graph: out-degree 4 — all four V messages alias
    let out = wake_once(AlgoKind::RFast, &Topology::exponential(16), 0);
    let v: Vec<&Msg> = out.iter().filter(|m| m.kind == MsgKind::V).collect();
    assert_eq!(v.len(), 4, "exp-16 has out-degree 4");
    for m in &v[1..] {
        assert!(Payload::ptr_eq(&v[0].payload, &m.payload));
    }

    // D-PSGD gossips x to both ring neighbors
    let out = wake_once(AlgoKind::DPsgd, &Topology::ring(4), 0);
    let x: Vec<&Msg> = out.iter().filter(|m| m.kind == MsgKind::X).collect();
    assert_eq!(x.len(), 2);
    assert!(Payload::ptr_eq(&x[0].payload, &x[1].payload));

    // Push-Pull / S-AB broadcast their consensus variable on the
    // exponential graph (out-degree 2 at n=4)
    for (algo, kind) in [(AlgoKind::PushPull, MsgKind::V),
                         (AlgoKind::SAb, MsgKind::X)] {
        let out = wake_once(algo, &Topology::exponential(4), 0);
        let b: Vec<&Msg> = out.iter().filter(|m| m.kind == kind).collect();
        assert_eq!(b.len(), 2, "{algo:?}");
        assert!(Payload::ptr_eq(&b[0].payload, &b[1].payload), "{algo:?}");
        // the per-receiver weighted payloads must NOT alias (different
        // contents by construction)
        let w: Vec<&Msg> =
            out.iter().filter(|m| m.kind == MsgKind::ZDelta).collect();
        assert_eq!(w.len(), 2, "{algo:?}");
        assert!(!Payload::ptr_eq(&w[0].payload, &w[1].payload), "{algo:?}");
    }
}

#[test]
fn make_mut_is_copy_on_write_under_aliasing() {
    let mut a = Payload::from_slice(&[1.0, 2.0, 3.0]);
    // unique owner: mutation happens in place (pointer stable)
    let before = a.as_slice().as_ptr();
    a.make_mut()[0] = 10.0;
    assert_eq!(a.as_slice().as_ptr(), before, "unique ⇒ no copy");

    // aliased: the writer gets a private copy, the reader keeps the old
    // bytes — receivers holding freshest-stamp buffers can never be
    // corrupted by a later sender-side mutation
    let reader = a.clone();
    let mut writer = a.clone();
    writer.make_mut()[2] = -3.0;
    assert_eq!(&reader[..], &[10.0, 2.0, 3.0][..]);
    assert_eq!(&writer[..], &[10.0, 2.0, -3.0][..]);
    assert!(!Payload::ptr_eq(&reader, &writer));
    assert!(Payload::ptr_eq(&a, &reader), "untouched alias still shares");
}

/// Round-robin drive two identical R-FAST node sets; `deep` decides
/// whether messages are delivered as emitted (shared payloads) or
/// re-materialized through `Msg::deep_clone` (the owned-Vec semantics of
/// the pre-fabric code). Returns the concatenated per-node (x, z) state.
fn drive_rfast(deep: bool, iters: usize) -> Vec<f32> {
    let topo = Topology::binary_tree(7);
    let quad = QuadraticOracle::heterogeneous(6, 7, 0.5, 2.0, 9);
    let mut set = quad.into_set();
    let mut nodes = AlgoKind::RFast.build(&topo, &vec![0.0; 6], 0.03, 1);
    let mut out = Vec::new();
    let mut replies = Vec::new();
    for _ in 0..iters {
        for i in 0..nodes.len() {
            nodes[i].wake(set.nodes[i].as_mut(), &mut out);
            for msg in out.drain(..) {
                let to = msg.to;
                let delivered = if deep { msg.deep_clone() } else { msg };
                nodes[to].receive(delivered, &mut replies);
            }
            assert!(replies.is_empty());
        }
    }
    nodes.iter().flat_map(|n| n.param().iter().copied()).collect()
}

#[test]
fn shared_vs_deep_copied_delivery_is_bitwise_identical() {
    // aliasing stress: rho_tilde aliases rho_in's Arc between wakes, the
    // freshest-wins buffers hold sender allocations — none of it may
    // change a single bit of the trajectory vs fully-owned payloads
    let shared = drive_rfast(false, 300);
    let deep = drive_rfast(true, 300);
    assert_eq!(shared.len(), deep.len());
    for (i, (a, b)) in shared.iter().zip(&deep).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "param scalar {i}: {a} vs {b}");
    }
}

fn golden_run(seed: u64) -> (String, rfast::sim::SimStats) {
    let topo = Topology::ring(5);
    let quad = QuadraticOracle::heterogeneous(8, 5, 0.5, 2.0, seed);
    let mut sim = Simulator::new(fast_cfg(seed), &topo, AlgoKind::RFast,
                                 quad.into_set());
    let report = sim.run(Stop::Iterations(3_000));
    (report.to_json().to_string(), sim.stats())
}

#[test]
fn golden_seed_run_emits_byte_identical_report_json() {
    // the determinism oracle of the fabric swap: same seed ⇒ the full
    // serialized Report (every series point, every counter) is
    // byte-identical — payload sharing draws no RNG, reorders no event,
    // and perturbs no float
    let (json_a, stats_a) = golden_run(42);
    let (json_b, stats_b) = golden_run(42);
    assert_eq!(json_a, json_b, "Report JSON must be byte-identical");
    assert_eq!(stats_a.bytes_sent, stats_b.bytes_sent);
    assert!(stats_a.bytes_sent > 0, "byte accounting active");
    // and a different seed must actually change the bytes (the oracle
    // has teeth)
    let (json_c, _) = golden_run(43);
    assert_ne!(json_a, json_c);
}

#[test]
fn experiment_builder_reproduces_the_golden_report_bitwise() {
    // the api_redesign acceptance gate: the Experiment chain is a pure
    // re-plumbing of the sim entry point — same seed through the builder
    // emits the byte-identical Report JSON the direct Simulator does
    // (same oracle family, same zero x0, same event trajectory)
    let (direct_json, direct_stats) = golden_run(42);
    let run = Experiment::new(
            Workload::Quadratic(QuadSpec::heterogeneous(8, 0.5, 2.0)),
            AlgoKind::RFast)
        .topology(&Topology::ring(5))
        .config(fast_cfg(42))
        .stop(Stop::Iterations(3_000))
        .run()
        .expect("builder golden run");
    assert_eq!(run.report.to_json().to_string(), direct_json,
               "builder sim path must be bitwise identical");
    assert_eq!(run.stats.bytes_sent, direct_stats.bytes_sent);
    assert_eq!(run.stats.msgs_sent, direct_stats.msgs_sent);
    assert_eq!(run.stats.total_steps(), direct_stats.grad_wakes);
}

#[test]
fn bytes_sent_matches_payload_sizes_exactly_on_reliable_ring() {
    // Ring-AllReduce is loss-free and backpressure-free (reliable links
    // bypass the channel discipline), so every sent message transmits:
    // with p = 8, n = 4 every chunk is exactly 2 f32 = 8 bytes, hence
    // bytes_sent == 8 × msgs_sent with no slack
    let topo = Topology::ring(4);
    let quad = QuadraticOracle::heterogeneous(8, 4, 0.5, 2.0, 21);
    let mut sim = Simulator::new(fast_cfg(3), &topo, AlgoKind::RingAllReduce,
                                 quad.into_set());
    sim.run(Stop::Iterations(400));
    let s = sim.stats();
    assert!(s.msgs_sent > 0);
    assert_eq!(s.bytes_sent, s.msgs_sent * 8,
               "exact byte accounting: {s:?}");
}

#[test]
fn rho_messages_carry_f64_and_v_messages_f32_lanes_only() {
    // lane discipline survives the fabric: the unused lane is the shared
    // empty payload, so per-message empties cost no allocation and
    // payload_bytes charges only the live lane
    let out = wake_once(AlgoKind::RFast, &Topology::binary_tree(3), 1);
    let rho = out.iter().find(|m| m.kind == MsgKind::Rho).expect("leaf sends ρ");
    assert!(rho.payload.is_empty());
    assert!(!rho.payload64.is_empty());
    let out0 = wake_once(AlgoKind::RFast, &Topology::binary_tree(3), 0);
    let v = out0.iter().find(|m| m.kind == MsgKind::V).expect("root sends v");
    assert!(v.payload64.is_empty());
    // all empty lanes across messages alias one global empty
    assert!(Payload::ptr_eq(&rho.payload, &Payload::empty()));
}
