// Fixture: total_cmp float ordering, clean in sim scope.

pub fn pick_min(xs: &[f64]) -> Option<f64> {
    xs.iter().copied().min_by(f64::total_cmp)
}

pub fn sort_times(xs: &mut Vec<(f64, usize)>) {
    xs.sort_by(|a, b| a.0.total_cmp(&b.0));
}
