// Fixture: ambient randomness in sim scope breaks seed replay.

pub fn jitter() -> u64 {
    let h = std::collections::hash_map::DefaultHasher::new();
    let _ = h;
    let r = rand::thread_rng();
    let _ = r;
    0
}
