// Fixture: nondeterministic collections in sim scope.

use std::collections::{HashMap, HashSet};

pub fn tally(xs: &[u32]) -> HashMap<u32, usize> {
    let mut m = HashMap::new();
    let mut seen = HashSet::new();
    for &x in xs {
        *m.entry(x).or_insert(0) += 1;
        seen.insert(x);
    }
    m
}
