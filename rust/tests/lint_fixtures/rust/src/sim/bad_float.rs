// Fixture: float-ord violations in sim scope (not compiled by cargo).

pub fn pick_min(xs: &[f64]) -> Option<f64> {
    xs.iter()
        .copied()
        .min_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal))
}

pub fn sort_times(xs: &mut Vec<(f64, usize)>) {
    xs.sort_by_key(|p| p.0 as f64 as u64);
}
