// Fixture: ordered collections, clean in sim scope.

use std::collections::{BTreeMap, BTreeSet};

pub fn tally(xs: &[u32]) -> BTreeMap<u32, usize> {
    let mut m = BTreeMap::new();
    let mut seen = BTreeSet::new();
    for &x in xs {
        *m.entry(x).or_insert(0) += 1;
        seen.insert(x);
    }
    let _ = seen;
    m
}
