// Fixture: wall clock leaking into virtual time.

pub fn stamp() -> std::time::Instant {
    let t = std::time::Instant::now();
    std::thread::sleep(std::time::Duration::from_millis(1));
    let _ = std::time::SystemTime::UNIX_EPOCH;
    t
}
