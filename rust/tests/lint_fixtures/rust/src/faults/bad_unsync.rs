// Fixture: unsynchronized shared mutable state (unsync-shared).

pub static mut TICKS: u64 = 0;

pub struct Cell(pub *mut u64);

unsafe impl Send for Cell {}
