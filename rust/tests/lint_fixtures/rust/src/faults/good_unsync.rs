// Fixture: OnceLock-published state needs no unsafe sharing — clean.

use std::sync::OnceLock;

pub static TICKS: OnceLock<u64> = OnceLock::new();

pub fn ticks() -> u64 {
    *TICKS.get_or_init(|| 0)
}
