// Fixture: dropping the guard before the blocking send is clean.

pub struct Hub {
    pub work: std::sync::Mutex<Vec<u64>>,
}

pub fn push(hub: &Hub, tx: &std::sync::mpsc::Sender<u64>, v: u64) {
    let g = hub.work.lock();
    drop(g);
    tx.send(v);
}
