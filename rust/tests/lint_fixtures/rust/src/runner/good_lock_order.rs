// Fixture: a consistent global acquisition order (lo before hi, in
// every function) never forms a cycle — clean.

pub struct Pair {
    pub lo: std::sync::Mutex<u64>,
    pub hi: std::sync::Mutex<u64>,
}

impl Pair {
    pub fn sum(&self) -> u64 {
        let glo = self.lo.lock();
        let ghi = self.hi.lock();
        0
    }

    pub fn swap(&self) {
        let glo = self.lo.lock();
        let ghi = self.hi.lock();
    }
}
