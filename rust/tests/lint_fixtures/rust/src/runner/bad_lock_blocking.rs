// Fixture: a guard held across a blocking channel send — the receiver
// may need the same lock to drain (lock-across-blocking).

pub struct Hub {
    pub queue: std::sync::Mutex<Vec<u64>>,
}

pub fn push(hub: &Hub, tx: &std::sync::mpsc::Sender<u64>, v: u64) {
    let g = hub.queue.lock();
    tx.send(v);
}
