// Fixture: wall-clock constructs are legal in runner/ (behind the Clock
// abstraction) — only the panic rule applies here.

pub fn pace() -> std::time::Instant {
    std::thread::sleep(std::time::Duration::from_millis(1));
    std::time::Instant::now()
}
