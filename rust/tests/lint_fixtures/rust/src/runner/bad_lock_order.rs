// Fixture: two functions acquire the same pair of locks in opposite
// orders — the classic two-lock deadlock shape (lock-order).

pub struct Pair {
    pub a: std::sync::Mutex<u64>,
    pub b: std::sync::Mutex<u64>,
}

impl Pair {
    pub fn ab(&self) -> u64 {
        let ga = self.a.lock();
        let gb = self.b.lock();
        0
    }

    pub fn ba(&self) -> u64 {
        let gb = self.b.lock();
        let ga = self.a.lock();
        0
    }
}
