// Fixture: report counters use AcqRel RMWs and Acquire loads — clean.

use std::sync::atomic::{AtomicU64, Ordering};

pub fn bump(msgs_sent: &AtomicU64) -> u64 {
    msgs_sent.fetch_add(1, Ordering::AcqRel);
    msgs_sent.load(Ordering::Acquire)
}
