// Fixture: Ordering::Relaxed on a report counter — readers may see a
// stale total in RunnerStats (relaxed-counter).

use std::sync::atomic::{AtomicU64, Ordering};

pub fn bump(msgs_sent: &AtomicU64) {
    msgs_sent.fetch_add(1, Ordering::Relaxed);
}
