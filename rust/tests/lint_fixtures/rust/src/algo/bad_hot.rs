// Fixture: allocations inside the per-event hot path.

pub struct Node {
    buf: Vec<f32>,
}

impl Node {
    pub fn wake(&mut self) -> Vec<f32> {
        let scratch = vec![0.0f32; self.buf.len()];
        scratch
    }

    pub fn receive(&mut self, payload: &[f32]) {
        self.buf = payload.to_vec();
    }

    pub fn on_send_failed(&mut self) {
        let _copy = self.buf.clone();
    }
}
