// Fixture: allocations confined to construction; the hot path reuses
// preallocated buffers.

pub struct Node {
    buf: Vec<f32>,
    scratch: Vec<f32>,
}

impl Node {
    pub fn new(dim: usize) -> Node {
        Node { buf: vec![0.0; dim], scratch: vec![0.0; dim] }
    }

    pub fn wake(&mut self) -> &[f32] {
        self.scratch.copy_from_slice(&self.buf);
        &self.scratch
    }

    pub fn receive(&mut self, payload: &[f32]) {
        self.buf.copy_from_slice(payload);
    }
}
