// Fixture: testutil/ is exempt from the panic rule — its panics are
// assertions by design.

pub fn must(x: Option<u32>) -> u32 {
    x.unwrap()
}
