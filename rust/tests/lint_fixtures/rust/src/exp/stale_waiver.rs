// Fixture: a well-formed waiver whose rule never fires on its target
// line is itself an error (stale-waiver) and can never be baselined.

pub fn total(xs: &[u64]) -> u64 {
    // lint:allow(panic-path): nothing on the next line can panic
    xs.iter().sum()
}
