// Fixture: panics inside #[cfg(test)] regions are out of scope.

pub fn double(x: u32) -> u32 {
    x * 2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn doubles() {
        let xs = vec![1u32];
        assert_eq!(double(*xs.first().unwrap()), 2);
        let m: std::collections::HashMap<u32, u32> = Default::default();
        let _ = m;
    }
}
