// Fixture: unwaived panics in library code.

pub fn first(xs: &[u32]) -> u32 {
    let head = xs.first().unwrap();
    if *head > 10 {
        panic!("too big");
    }
    *head
}
