// Fixture: a reasonless waiver is itself a finding and suppresses nothing.

pub fn first(xs: &[u32]) -> u32 {
    let head = xs.first().unwrap(); // lint:allow(panic-path)
    *head
}
