// Fixture: panics carrying waivers with reasons.

pub fn first(xs: &[u32]) -> u32 {
    // lint:allow(panic-path): callers guarantee a non-empty slice
    let head = xs.first().unwrap();
    *head
}
