//! The fuzzer's own regression suite (DESIGN.md §11):
//!
//! * a fixed seed corpus runs green and bitwise-deterministically — the
//!   same guarantee CI's `repro fuzz --seed 0 --budget 50` gate relies on;
//! * every generated case validates and round-trips through repro JSON
//!   bitwise;
//! * every committed repro in `tests/repros/` replays with its recorded
//!   verdict;
//! * a deliberately-diverging case demonstrably shrinks to the committed
//!   minimal repro (the shrinker's end-to-end contract).

use rfast::fuzz::{self, shrink, FuzzCase, Repro};
use rfast::jsonio;
use std::path::{Path, PathBuf};

fn repros_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/repros")
}

#[test]
fn seed_corpus_is_green_and_bitwise_deterministic() {
    // the exact corpus CI runs: seed 0, budget 50
    let first = fuzz::run_corpus(0, 50, false);
    let second = fuzz::run_corpus(0, 50, false);
    assert_eq!(first, second, "fuzz verdicts depend on ambient state");
    assert!(
        first.failures.is_empty(),
        "seed-0 corpus regressed: {:?}",
        first
            .failures
            .iter()
            .map(|f| format!("case {}: {} — {}", f.case_index, f.violation,
                             f.detail))
            .collect::<Vec<_>>()
    );
}

#[test]
fn second_seed_corpus_is_green() {
    // a disjoint PRNG stream, so a generator bias that seed 0 happens to
    // miss still gets coverage
    let report = fuzz::run_corpus(0xFA57, 20, false);
    assert!(
        report.failures.is_empty(),
        "seed-0xFA57 corpus regressed: {:?}",
        report
            .failures
            .iter()
            .map(|f| format!("case {}: {} — {}", f.case_index, f.violation,
                             f.detail))
            .collect::<Vec<_>>()
    );
}

#[test]
fn generated_cases_validate_and_roundtrip_bitwise() {
    // satellite: every sampled case passes validate_detailed and its
    // repro JSON reproduces byte-identically after a parse round-trip —
    // covering the randomized fields (arch pair, seed, gamma, scenario)
    for case_index in 0..50 {
        let case = FuzzCase::sample(3, case_index);
        case.scenario
            .validate_detailed(Some(case.n))
            .unwrap_or_else(|(field, detail)| {
                panic!("case {case_index}: generated scenario invalid at \
                        {field}: {detail}")
            });
        assert!(case.n >= 2);
        assert!(case.iters >= fuzz::ITERS_FLOOR);
        assert!(case.gamma > 0.0);
        // both generated trees are rooted at 0 (the shrinker's n-shrink
        // soundness condition)
        let topo = case.arch.build(case.n).expect("generated pair builds");
        assert_eq!(topo.weights.common_roots(), vec![0]);

        let repro = Repro {
            case: case.clone(),
            expect: "pass".into(),
            violation: None,
        };
        let text = repro.to_json().to_string();
        let parsed = jsonio::parse(&text).expect("emitted JSON parses");
        let back = Repro::from_json(&parsed).expect("emitted JSON loads");
        assert_eq!(back, repro, "case {case_index}: lossy round-trip");
        assert_eq!(
            back.to_json().to_string(),
            text,
            "case {case_index}: JSON not bitwise-stable"
        );
    }
}

#[test]
fn committed_repros_replay_with_recorded_verdicts() {
    let dir = repros_dir();
    let mut paths: Vec<PathBuf> = std::fs::read_dir(&dir)
        .expect("tests/repros exists")
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().and_then(|x| x.to_str()) == Some("json"))
        .collect();
    paths.sort();
    assert!(!paths.is_empty(), "seed corpus must hold at least one repro");
    for path in &paths {
        let repro = Repro::load(path).expect("committed repro parses");
        repro
            .replay()
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
    }
}

#[test]
fn committed_repros_are_bitwise_stable_on_the_calendar_scheduler() {
    // the committed corpus predates the calendar-queue scheduler; its
    // verdicts AND detail strings must replay bitwise-identically on it
    // (and keep doing so), twice in one process to rule out ambient state
    let dir = repros_dir();
    let mut paths: Vec<PathBuf> = std::fs::read_dir(&dir)
        .expect("tests/repros exists")
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().and_then(|x| x.to_str()) == Some("json"))
        .collect();
    paths.sort();
    for path in &paths {
        let repro = Repro::load(path).expect("committed repro parses");
        let first = repro.case.run();
        let second = repro.case.run();
        assert_eq!(first, second,
                   "{}: outcome depends on ambient state", path.display());
        let expect_fail = repro.expect == "fail";
        assert_eq!(first.violation.is_some(), expect_fail,
                   "{}: verdict drifted: {first:?}", path.display());
        assert_eq!(first.violation.map(str::to_string),
                   repro.violation.clone(),
                   "{}: oracle drifted: {first:?}", path.display());
    }
}

#[test]
fn diverging_example_shrinks_to_the_committed_minimal_repro() {
    // end-to-end shrinker contract: a case failing by construction
    // (γ = 16 on h ∈ [0.5, 2] quadratics ⇒ per-step blow-up factor ≥ 7)
    // reduces to exactly the minimal repro committed in tests/repros/
    let case = FuzzCase::diverging_example();
    let outcome = case.run();
    assert_eq!(
        outcome.violation,
        Some("gap_bounded"),
        "diverging example no longer diverges: {}",
        outcome.detail
    );

    let shrunk = shrink::shrink(&case, "gap_bounded");
    let committed = Repro::load(&repros_dir().join("diverging_gamma.json"))
        .expect("committed minimal repro parses");
    assert_eq!(committed.expect, "fail");
    assert_eq!(committed.violation.as_deref(), Some("gap_bounded"));
    assert_eq!(
        shrunk, committed.case,
        "shrink endpoint drifted from tests/repros/diverging_gamma.json — \
         if the shrinker's candidate order changed intentionally, \
         regenerate the file with `repro fuzz`-style to_json output"
    );
    // the committed endpoint is a true fixpoint AND the committed bytes
    // are canonical (what Repro::to_json would write today)
    assert_eq!(shrink::shrink(&committed.case, "gap_bounded"),
               committed.case);
    let text = std::fs::read_to_string(
        repros_dir().join("diverging_gamma.json"),
    )
    .unwrap();
    assert_eq!(text.trim_end(), committed.to_json().to_string());
}

#[test]
fn shrinking_is_deterministic() {
    let case = FuzzCase::diverging_example();
    let a = shrink::shrink(&case, "gap_bounded");
    let b = shrink::shrink(&case, "gap_bounded");
    assert_eq!(a, b);
}
