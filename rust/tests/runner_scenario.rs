//! Engine parity for the scenario layer: every fault preset that drives
//! the virtual-time simulator must drive the wall-clock threaded runner
//! through the same shared `faults` layer, and the scenario-specific
//! counters must move in the expected direction.
//!
//! These tests sleep real wall time; CI runs them single-threaded
//! (`--test-threads=1`) with a job timeout so they stay honest about
//! their clock and can't hang the pipeline. Assertions are directional
//! (counter moved / ordering holds), never exact — wall-clock runs are
//! not bitwise repeatable.

use rfast::algo::AlgoKind;
use rfast::config::SimConfig;
use rfast::exp::{Engine, Experiment, QuadSpec, RunStats, Stop, Workload};
use rfast::graph::Topology;
use rfast::oracle::QuadraticOracle;
use rfast::runner::ThreadedRunner;
use rfast::scenario::{BandwidthCap, ChurnEvent, Phase, Scenario};
use rfast::testutil::{tracking_quad_eval, QuadFactory};

fn fast_cfg(seed: u64) -> SimConfig {
    SimConfig {
        seed,
        gamma: 0.03,
        compute_mean: 0.001,
        eval_every: 0.05,
        ..SimConfig::default()
    }
}

/// Run a heterogeneous quadratic on the threaded runner via the builder;
/// returns the report, the unified stats and the gap the builder measures
/// on the last evaluated mean (surfaced as `Report::final_gap`).
fn run_quad(
    algo: AlgoKind,
    n: usize,
    dim: usize,
    cfg: SimConfig,
    pace: f64,
    until: Stop,
) -> (rfast::metrics::Report, RunStats, f64) {
    let run = Experiment::new(
            Workload::Quadratic(QuadSpec::heterogeneous(dim, 0.5, 2.0)), algo)
        .topology(&Topology::ring(n))
        .config(cfg)
        .engine(Engine::threaded(Some(pace)))
        .stop(until)
        .run()
        .expect("threaded quad run");
    let gap = run.report.final_gap.expect("quadratic runs report final_gap");
    (run.report, run.stats, gap)
}

/// The scalar keys every actor-engine run must report, preset or not —
/// the set the 512-actor CI smoke and the fuzz `scalar_sanity` oracle key
/// off, so a preset silently dropping one would break both downstream.
const UNIFIED_SCALARS: [&str; 5] = [
    "msgs_lost",
    "bytes_sent",
    "msgs_backpressured",
    "msgs_paced",
    "epoch",
];

#[test]
fn every_preset_runs_in_the_threaded_engine() {
    // acceptance loop: each named preset loads, passes validation against
    // the topology, and completes a short wall-clock run on the actor
    // pool reporting the unified scalar key set
    assert_eq!(Scenario::preset_names().len(), 6, "preset census drifted");
    for name in Scenario::preset_names() {
        let mut cfg = fast_cfg(17);
        cfg.scenario = Some(Scenario::by_name(name).unwrap());
        let (report, stats, _) =
            run_quad(AlgoKind::RFast, 4, 6, cfg, 1e-4,
                     Stop::Time(0.2));
        assert!(stats.steps_per_node.iter().sum::<u64>() > 0,
                "{name}: no progress");
        assert!(report.series.contains_key("loss_vs_wall"), "{name}");
        for key in UNIFIED_SCALARS {
            assert!(report.scalars.contains_key(key),
                    "{name}: scalar {key} missing from actor-engine run");
        }
    }
}

#[test]
fn churn_pause_window_freezes_the_paused_node() {
    // window covering the whole run: the paused node must take ZERO steps
    // inside its pause window while the others keep training
    let mut sc = Scenario::named("pause_whole_run", "");
    sc.churn.push(ChurnEvent { node: 1, pause_at: 0.0, resume_at: 60.0 });
    let mut cfg = fast_cfg(19);
    cfg.scenario = Some(sc);
    let (_, stats, _) = run_quad(AlgoKind::RFast, 4, 6, cfg, 1e-4,
                                 Stop::Time(0.3));
    assert_eq!(stats.steps_per_node[1], 0,
               "paused node stepped: {:?}", stats.steps_per_node);
    for i in [0usize, 2, 3] {
        assert!(stats.steps_per_node[i] > 50,
                "active node {i} starved: {:?}", stats.steps_per_node);
    }

    // window ending mid-run: the node must resume and step afterwards
    let mut sc = Scenario::named("pause_then_resume", "");
    sc.churn.push(ChurnEvent { node: 1, pause_at: 0.0, resume_at: 0.15 });
    let mut cfg = fast_cfg(19);
    cfg.scenario = Some(sc);
    let (_, stats, _) = run_quad(AlgoKind::RFast, 4, 6, cfg, 1e-4,
                                 Stop::Time(0.5));
    assert!(stats.steps_per_node[1] > 0, "node 1 never resumed");
}

#[test]
fn lossy_30pct_keeps_rfast_converging() {
    // also the threaded-engine gate for the zero-copy message fabric:
    // payloads crossing the actor mailboxes are shared Arcs
    // (DESIGN.md §8), and R-FAST must still converge under 30% loss with
    // the byte accounting live
    let mut cfg = fast_cfg(23);
    cfg.gamma = 0.02;
    cfg.scenario = Some(Scenario::by_name("lossy_30pct").unwrap());
    let (report, stats, gap) = run_quad(AlgoKind::RFast, 4, 6, cfg, 1e-4,
                                        Stop::Iterations(8_000));
    assert!(stats.msgs_lost > 0, "loss injection active: {stats:?}");
    assert!(stats.bytes_sent > 0, "payload byte accounting active");
    // lost/backpressured sends transmit nothing, so the transmitted
    // volume is bounded by DELIVERED sends times the largest message on
    // this workload (a ρ packet, 6 f64 = 48 bytes) — charging rejected
    // sends would push bytes_sent past this bound
    let delivered = stats.msgs_sent - stats.msgs_lost - stats.msgs_backpressured;
    assert!(stats.bytes_sent <= delivered * 48,
            "rejected sends must not be charged: {stats:?}");
    let first = report.series["loss_vs_wall"].points[0].1;
    let last = report.series["loss_vs_wall"].last_y().unwrap();
    // directional: no divergence (both points may already sit at the
    // optimum, so allow fp-level jitter)
    assert!(last <= first + 0.1, "diverged under loss: {first} → {last}");
    assert!(gap < 0.5, "R-FAST gap under 30% loss: {gap}");
}

#[test]
fn gamma_decay_lowers_the_noise_floor_threaded() {
    // stochastic gradients: the steady-state gap scales with γ, so the
    // epoch-indexed decay schedule must land closer to the optimum than
    // constant γ — the same claim `sim::tests::gamma_decay_schedule_applies`
    // makes in virtual time
    let run = |decay: Option<(f64, f32)>| -> f64 {
        let q = QuadraticOracle::noisy(8, 4, 0.5, 21);
        let xs = q.optimum();
        let topo = Topology::ring(4);
        let mut cfg = fast_cfg(8);
        cfg.gamma = 0.05;
        cfg.gamma_decay = decay;
        let runner = ThreadedRunner::new(cfg, &topo, AlgoKind::RFast,
                                         vec![0.0; 8])
            .with_pace(5e-5);
        let (mut eval, last_mean) = tracking_quad_eval(q.clone());
        runner.run(&QuadFactory(q), &mut eval, Stop::Iterations(40_000));
        rfast::linalg::dist(&last_mean.lock().unwrap(), &xs)
    };
    let constant = run(None);
    let decayed = run(Some((8_000.0, 0.5))); // quadratic epoch == 1 per wake
    assert!(
        decayed < constant * 0.8,
        "decay should cut the noise floor: constant {constant} vs decayed \
         {decayed}"
    );
}

#[test]
fn straggler_preset_skews_step_counts() {
    // paper_fig6_straggler slows node 3 by 5x: its wall-clock step count
    // must fall well behind the healthy nodes
    let mut cfg = fast_cfg(31);
    cfg.scenario = Some(Scenario::by_name("paper_fig6_straggler").unwrap());
    let (_, stats, _) = run_quad(AlgoKind::RFast, 4, 6, cfg, 2e-4,
                                 Stop::Time(0.6));
    let s = &stats.steps_per_node;
    let others_min = (0..4).filter(|&i| i != 3).map(|i| s[i]).min().unwrap();
    assert!(
        (s[3] as f64) < 0.5 * others_min as f64,
        "straggler {} vs healthy min {others_min}", s[3]
    );
    assert!(stats.msgs_lost > 0, "preset also carries 2% loss");
}

#[test]
fn bandwidth_caps_pace_the_senders() {
    // a tight byte rate forces the sending threads to sleep through the
    // FIFO serialization delay: the paced counter must move and the
    // training cadence must drop vs the clean run
    let clean = {
        let cfg = fast_cfg(37);
        let (_, stats, _) = run_quad(AlgoKind::RFast, 3, 6, cfg, 1e-4,
                                     Stop::Time(0.3));
        stats
    };
    let capped = {
        let mut sc = Scenario::named("tight_bw", "");
        sc.bandwidth.push(BandwidthCap {
            from: None,
            to: None,
            bytes_per_sec: 16.0 * 1024.0, // a ~50-byte payload ≈ 3 ms
        });
        let mut cfg = fast_cfg(37);
        cfg.scenario = Some(sc);
        let (_, stats, _) = run_quad(AlgoKind::RFast, 3, 6, cfg, 1e-4,
                                     Stop::Time(0.3));
        stats
    };
    assert_eq!(clean.msgs_paced, 0, "clean run must not pace");
    assert!(capped.msgs_paced > 0, "cap never paced a send: {capped:?}");
    let clean_steps: u64 = clean.steps_per_node.iter().sum();
    let capped_steps: u64 = capped.steps_per_node.iter().sum();
    assert!(
        (capped_steps as f64) < 0.7 * clean_steps as f64,
        "cap should throttle training: {capped_steps} vs {clean_steps}"
    );
}

#[test]
fn latency_ramp_injects_wall_clock_delay() {
    let mut sc = Scenario::named("slow_links", "");
    sc.latency_ramp.push(Phase { from_time: 0.0, value: 11.0 });
    let mut cfg = fast_cfg(41);
    cfg.link_latency = 0.002; // injected (11 − 1) × 2 ms = 20 ms / message
    cfg.latency_cap = 0.5;
    cfg.scenario = Some(sc);
    let (_, stats, _) = run_quad(AlgoKind::RFast, 3, 6, cfg, 1e-4,
                                 Stop::Time(0.3));
    assert!(stats.msgs_paced > 0, "ramp never paced a send: {stats:?}");
    assert!(stats.steps_per_node.iter().sum::<u64>() > 0);
}

#[test]
fn runner_rejects_scenarios_that_overflow_the_topology() {
    let cfg = {
        let mut c = fast_cfg(43);
        c.scenario = Some(Scenario::single_straggler(7, 2.0)); // node 7 of 3
        c
    };
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        ThreadedRunner::new(cfg, &Topology::ring(3), AlgoKind::RFast,
                            vec![0.0; 4])
    }));
    assert!(result.is_err(), "out-of-range scenario node must be rejected");
}
