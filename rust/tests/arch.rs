//! The asymmetric-architecture subsystem end to end: (G_R, G_C) pairs
//! built from two independent spanning trees drive both engines, a pair
//! with no common root is a typed pre-flight rejection (never a silent
//! divergent run), seeded random-spanning-tree runs are bitwise
//! deterministic, and a root-churn scenario probes the "at least one
//! common root" assumption on both engines.
//!
//! The threaded halves spin real threads; CI runs this file in the
//! single-threaded wall-clock step.

use rfast::algo::AlgoKind;
use rfast::config::SimConfig;
use rfast::exp::{Engine, ExpError, Experiment, QuadSpec, Stop, Workload};
use rfast::graph::{ArchSpec, Topology};
use rfast::scenario::{ChurnEvent, Scenario};

fn quad() -> Workload {
    Workload::Quadratic(QuadSpec::heterogeneous(8, 0.5, 2.0))
}

fn fast_cfg(seed: u64) -> SimConfig {
    SimConfig {
        seed,
        gamma: 0.03,
        compute_mean: 0.01,
        link_latency: 0.002,
        latency_cap: 0.05,
        eval_every: 1.0,
        ..SimConfig::default()
    }
}

// ---- the flexibility claim: asymmetric pairs converge ------------------

#[test]
fn rfast_converges_on_every_paper_pair() {
    for spec in ArchSpec::paper_pairs() {
        let topo = spec.build(8).unwrap();
        let run = Experiment::new(quad(), AlgoKind::RFast)
            .topology(&topo)
            .config(fast_cfg(3))
            .stop(Stop::Iterations(40_000))
            .run()
            .unwrap_or_else(|e| panic!("{}: {e}", spec.name()));
        let gap = run.report.final_gap.unwrap();
        assert!(gap < 5e-2, "{}: gap {gap}", spec.name());
    }
}

// ---- common-root rejection (typed, pre-flight) -------------------------

#[test]
fn no_common_root_pair_is_rejected_not_run() {
    let err = Experiment::new(quad(), AlgoKind::RFast)
        .config(fast_cfg(1))
        .stop(Stop::Iterations(100))
        .sweep_architectures(&[ArchSpec::no_common_root_pair()], 6)
        .unwrap_err();
    match &err {
        ExpError::InvalidTopology { topology, detail } => {
            // the error names the offending pair and the violated
            // assumption
            assert_eq!(topology, "balanced@0+star@1");
            assert!(detail.contains("common root"), "{detail}");
        }
        other => panic!("expected InvalidTopology, got {other:?}"),
    }
    assert!(err.to_string().contains("balanced@0+star@1"), "{err}");
}

#[test]
fn hand_built_edge_pair_without_common_root_is_rejected_on_both_engines() {
    // previously this ran silently and diverged: G(W) rooted only at 0,
    // G(Aᵀ) rooted only at 1 — Assumption 2 fails, run() must pre-flight
    let topo = Topology::from_edges(
        3,
        &[(0, 1), (0, 2)], // 1 and 2 pull from 0 ⇒ roots_w = {0}
        &[(0, 1), (2, 1)], // 0 and 2 push to 1 ⇒ roots_at = {1}
    );
    assert!(topo.weights.common_roots().is_empty());
    for engine in [Engine::Sim, Engine::threaded(Some(1e-4))] {
        let err = Experiment::new(quad(), AlgoKind::RFast)
            .topology(&topo)
            .config(fast_cfg(1))
            .engine(engine)
            .stop(Stop::Iterations(100))
            .run()
            .unwrap_err();
        assert!(
            matches!(err, ExpError::InvalidTopology { .. }),
            "{engine:?}: {err:?}"
        );
    }
}

// ---- seeded determinism ------------------------------------------------

#[test]
fn random_tree_pair_runs_are_bitwise_deterministic_by_seed() {
    let mk = |tree_seed: u64| {
        let spec =
            ArchSpec::parse(&format!("random@0:{tree_seed}+random@0:21"))
                .unwrap();
        let topo = spec.build(10).unwrap();
        Experiment::new(quad(), AlgoKind::RFast)
            .topology(&topo)
            .config(fast_cfg(5))
            .stop(Stop::Iterations(3_000))
            .run()
            .unwrap()
    };
    let a = mk(7);
    let b = mk(7);
    // bitwise: identical tree ⇒ identical event sequence ⇒ identical JSON
    assert_eq!(a.report.to_json().to_string(),
               b.report.to_json().to_string());
    assert_eq!(a.stats, b.stats);
    // a different tree seed changes the topology, hence the trajectory
    let c = mk(9);
    assert_ne!(a.report.to_json().to_string(),
               c.report.to_json().to_string());
}

// ---- engine parity on an asymmetric pair -------------------------------

/// Same unified scalar contract as `tests/experiment.rs`, now on a
/// two-tree architecture: dashboards must not branch on the engine.
const UNIFIED_SCALARS: [&str; 5] = [
    "msgs_lost",
    "bytes_sent",
    "msgs_backpressured",
    "msgs_paced",
    "epoch",
];

#[test]
fn sim_and_threaded_expose_the_same_scalar_keys_on_an_asymmetric_pair() {
    let topo = ArchSpec::parse("chain@0+balanced@0").unwrap().build(4).unwrap();
    let base = Experiment::new(Workload::LogReg, AlgoKind::RFast)
        .topology(&topo)
        .config(SimConfig {
            eval_every: 0.05,
            ..SimConfig::logreg_paper()
        });
    let sim_run = base
        .clone()
        .engine(Engine::Sim)
        .stop(Stop::Time(2.0))
        .run()
        .unwrap();
    let thr_run = base
        .engine(Engine::threaded(Some(5e-4)))
        .stop(Stop::Time(0.3))
        .run()
        .unwrap();
    for key in UNIFIED_SCALARS {
        assert!(sim_run.report.scalars.contains_key(key),
                "sim missing {key}");
        assert!(thr_run.report.scalars.contains_key(key),
                "threaded missing {key}");
    }
    assert!(sim_run.stats.total_steps() > 0);
    assert!(thr_run.stats.total_steps() > 0);
}

// ---- root churn: probing the common-root assumption under faults -------

#[test]
fn paused_common_root_stalls_but_does_not_kill_the_sim_run() {
    // chain-pull/star-push rooted at 0: node 0 is the ONLY common root.
    // Pause it for a third of the run — the asynchronous others keep
    // stepping (a stalled root is not a crash), the root's own step
    // count drops, and the run still finishes with a finite loss.
    let topo = ArchSpec::parse("chain@0+star@0").unwrap().build(5).unwrap();
    let mut sc = Scenario::named(
        "root_churn",
        "the unique common root goes dark mid-run",
    );
    sc.churn.push(ChurnEvent { node: 0, pause_at: 10.0, resume_at: 25.0 });
    let run = |scenario: Option<&Scenario>| {
        Experiment::new(quad(), AlgoKind::RFast)
            .topology(&topo)
            .config(fast_cfg(11))
            .maybe_scenario(scenario)
            .stop(Stop::Time(40.0))
            .run()
            .unwrap()
    };
    let churned = run(Some(&sc));
    let clean = run(None);
    let steps = &churned.stats.steps_per_node;
    let others: u64 = steps[1..].iter().sum();
    assert!(others > 0, "non-root nodes kept stepping: {steps:?}");
    // the root lost ~15 s of a 40 s run: it must trail the per-node mean
    let mean_other = others as f64 / (steps.len() - 1) as f64;
    assert!(
        (steps[0] as f64) < 0.85 * mean_other,
        "root should trail while paused: {steps:?}"
    );
    assert!((steps[0] as f64) > 0.0, "root ran outside the window");
    // and progress survives: final gap finite and no worse than 10× clean
    let g_churn = churned.report.final_gap.unwrap();
    let g_clean = clean.report.final_gap.unwrap();
    assert!(g_churn.is_finite());
    assert!(g_churn < (10.0 * g_clean).max(0.5),
            "churned {g_churn} vs clean {g_clean}");
}

#[test]
fn root_churn_runs_on_the_threaded_engine_too() {
    // wall-clock twin, compressed: pause the common root for the middle
    // ~0.15 s of a 0.45 s run; others keep stepping, run terminates
    let topo = ArchSpec::parse("chain@0+star@0").unwrap().build(3).unwrap();
    let mut sc = Scenario::named("root_churn_wall", "");
    sc.churn.push(ChurnEvent { node: 0, pause_at: 0.15, resume_at: 0.30 });
    let run = Experiment::new(Workload::LogReg, AlgoKind::RFast)
        .topology(&topo)
        .config(SimConfig {
            eval_every: 0.05,
            ..SimConfig::logreg_paper()
        })
        .scenario(&sc)
        .engine(Engine::threaded(Some(1e-3)))
        .stop(Stop::Time(0.45))
        .run()
        .unwrap();
    let steps = &run.stats.steps_per_node;
    assert!(steps[1] > 0 && steps[2] > 0,
            "non-root nodes kept stepping: {steps:?}");
    assert!(run.stats.wall_seconds.unwrap() >= 0.45);
    assert!(run.report.label.contains("root_churn_wall"));
}
