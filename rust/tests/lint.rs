//! Integration tests for `repro lint` (DESIGN.md §12, §14): fixture
//! corpus (determinism + concurrency rule families), waiver policy
//! (malformed and stale), baseline ratchet with v1 → v2 migration, and
//! the live-tree self-scan against the committed `LINT_BASELINE.json`.

use rfast::lint::{self, Baseline, LintConfig};
use std::path::{Path, PathBuf};

fn fixtures_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/lint_fixtures")
}

fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ has a parent")
        .to_path_buf()
}

/// Scan the fixture corpus (exclude_dirs emptied — the corpus IS the
/// lint_fixtures directory the default config prunes).
fn scan_fixtures() -> lint::LintReport {
    let cfg = LintConfig {
        root: fixtures_root(),
        paths: vec!["rust/src".to_string()],
        exclude_dirs: vec![],
    };
    lint::run(&cfg).expect("fixture scan")
}

fn findings_for<'a>(
    report: &'a lint::LintReport,
    file: &str,
) -> Vec<(&'a str, usize)> {
    report
        .findings
        .iter()
        .filter(|f| f.file == file)
        .map(|f| (f.rule, f.line))
        .collect()
}

#[test]
fn bad_fixtures_trip_their_rule_and_good_pairs_stay_clean() {
    let r = scan_fixtures();

    // float ordering: partial_cmp always, sort_by_key only next to floats
    assert_eq!(
        findings_for(&r, "rust/src/sim/bad_float.rs"),
        vec![("float-ord", 6), ("float-ord", 10)]
    );
    assert!(findings_for(&r, "rust/src/sim/good_float.rs").is_empty());

    // unordered collections, including the use declaration itself
    let coll = findings_for(&r, "rust/src/sim/bad_collections.rs");
    assert_eq!(coll.len(), 5);
    assert!(coll.iter().all(|&(rule, _)| rule == "det-collections"));
    assert!(findings_for(&r, "rust/src/sim/good_collections.rs").is_empty());

    // wall clock and ambient randomness
    let wc = findings_for(&r, "rust/src/sim/bad_wallclock.rs");
    assert_eq!(wc.len(), 3);
    assert!(wc.iter().all(|&(rule, _)| rule == "det-wallclock"));
    let rand = findings_for(&r, "rust/src/sim/bad_rand.rs");
    assert_eq!(rand.len(), 3);
    assert!(rand.iter().all(|&(rule, _)| rule == "det-rand"));

    // hot-path allocation: one hit per wake/receive/on_send_failed body,
    // none for construction-time allocation
    assert_eq!(
        findings_for(&r, "rust/src/algo/bad_hot.rs"),
        vec![("hot-alloc", 9), ("hot-alloc", 14), ("hot-alloc", 18)]
    );
    assert!(findings_for(&r, "rust/src/algo/good_hot.rs").is_empty());

    // panic discipline, with a reasoned waiver clearing the good pair
    assert_eq!(
        findings_for(&r, "rust/src/exp/bad_panic.rs"),
        vec![("panic-path", 4), ("panic-path", 6)]
    );
    assert!(findings_for(&r, "rust/src/exp/good_panic.rs").is_empty());
}

#[test]
fn conc_bad_fixtures_trip_and_good_pairs_stay_clean() {
    let r = scan_fixtures();

    // the seeded two-lock inversion: the cross-file acquisition graph
    // holds a -> b and b -> a, so BOTH nested-acquisition sites are on
    // the cycle and each function is flagged at its second lock()
    assert_eq!(
        findings_for(&r, "rust/src/runner/bad_lock_order.rs"),
        vec![("lock-order", 12), ("lock-order", 18)]
    );
    // a consistent global order contributes edges but no cycle
    assert!(findings_for(&r, "rust/src/runner/good_lock_order.rs").is_empty());

    // guard held across a blocking channel send vs dropped first
    assert_eq!(
        findings_for(&r, "rust/src/runner/bad_lock_blocking.rs"),
        vec![("lock-across-blocking", 10)]
    );
    assert!(
        findings_for(&r, "rust/src/runner/good_lock_blocking.rs").is_empty()
    );

    // Relaxed on a report counter vs AcqRel/Acquire discipline
    assert_eq!(
        findings_for(&r, "rust/src/runner/bad_relaxed.rs"),
        vec![("relaxed-counter", 7)]
    );
    assert!(findings_for(&r, "rust/src/runner/good_relaxed.rs").is_empty());

    // static mut, raw pointer, unsafe impl Send — one finding each
    assert_eq!(
        findings_for(&r, "rust/src/faults/bad_unsync.rs"),
        vec![
            ("unsync-shared", 3),
            ("unsync-shared", 5),
            ("unsync-shared", 7)
        ]
    );
    assert!(findings_for(&r, "rust/src/faults/good_unsync.rs").is_empty());
}

#[test]
fn stale_waiver_is_an_error_not_a_finding() {
    let r = scan_fixtures();
    let errs: Vec<_> = r
        .waiver_errors
        .iter()
        .filter(|f| f.file == "rust/src/exp/stale_waiver.rs")
        .collect();
    assert_eq!(errs.len(), 1);
    assert_eq!((errs[0].rule, errs[0].line), ("stale-waiver", 5));
    assert!(errs[0].detail.contains("suppresses nothing"));
    // stale waivers route through waiver_errors, never findings — so
    // they can never be grandfathered into a baseline
    assert!(findings_for(&r, "rust/src/exp/stale_waiver.rs").is_empty());
}

#[test]
fn v1_baseline_files_still_load_and_ratchet() {
    let dir = std::env::temp_dir().join("rfast_lint_v1_migration");
    std::fs::create_dir_all(&dir).expect("tmp dir");
    let path = dir.join("LINT_BASELINE_v1.json");
    let r = scan_fixtures();
    let b = Baseline::from_report(&r);
    let text = lint::to_pretty(&b.to_json())
        .replace("rfast-lint-baseline/v2", "rfast-lint-baseline/v1");
    std::fs::write(&path, text).expect("write v1 baseline");
    let loaded = Baseline::load(&path).expect("v1 baseline parses");
    assert_eq!(loaded, b);
    assert!(loaded.diff(&b).is_clean());
    // any rewrite emits the v2 schema tag
    assert!(lint::to_pretty(&loaded.to_json())
        .contains("rfast-lint-baseline/v2"));
}

#[test]
fn scope_exemptions_hold() {
    let r = scan_fixtures();
    // #[cfg(test)] regions are out of scope even in lib paths
    assert!(findings_for(&r, "rust/src/exp/cfg_test_exempt.rs").is_empty());
    // wall clock is legal in runner/ (Clock abstraction territory)
    assert!(findings_for(&r, "rust/src/runner/wallclock_ok.rs").is_empty());
    // testutil/ panics are assertions by design
    assert!(findings_for(&r, "rust/src/testutil/panics_ok.rs").is_empty());
}

#[test]
fn reasonless_waiver_is_rejected_and_suppresses_nothing() {
    let r = scan_fixtures();
    let errs: Vec<_> = r
        .waiver_errors
        .iter()
        .filter(|f| f.file == "rust/src/exp/bad_waiver.rs")
        .collect();
    assert_eq!(errs.len(), 1);
    assert!(errs[0].detail.contains("reason"));
    // the finding the malformed waiver tried to cover is still reported
    assert_eq!(
        findings_for(&r, "rust/src/exp/bad_waiver.rs"),
        vec![("panic-path", 4)]
    );
}

#[test]
fn ratchet_accepts_decrease_and_rejects_increase() {
    let r = scan_fixtures();
    let grandfathered = Baseline::from_report(&r);

    // identical scan: clean, no deltas
    let same = grandfathered.diff(&Baseline::from_report(&r));
    assert!(same.is_clean());
    assert!(same.improvements.is_empty());

    // one more finding in a known cell: regression, gate fails
    let mut worse = Baseline::from_report(&r);
    if let Some(n) = worse
        .counts
        .get_mut("hot-alloc")
        .and_then(|m| m.get_mut("rust/src/algo/bad_hot.rs"))
    {
        *n += 1;
    }
    let d = grandfathered.diff(&worse);
    assert!(!d.is_clean());
    assert_eq!(d.regressions.len(), 1);

    // a brand-new rule/file cell is also a regression (from zero)
    let mut new_cell = Baseline::from_report(&r);
    new_cell
        .counts
        .entry("float-ord".to_string())
        .or_default()
        .insert("rust/src/sim/fresh.rs".to_string(), 1);
    assert!(!grandfathered.diff(&new_cell).is_clean());

    // fixing a finding: improvement, gate passes and suggests shrink
    let mut better = Baseline::from_report(&r);
    if let Some(m) = better.counts.get_mut("panic-path") {
        m.remove("rust/src/exp/bad_panic.rs");
    }
    let d = grandfathered.diff(&better);
    assert!(d.is_clean());
    assert!(d
        .improvements
        .iter()
        .any(|x| x.file == "rust/src/exp/bad_panic.rs" && x.cur == 0));
}

#[test]
fn baseline_file_round_trips_through_fix_baseline_format() {
    let r = scan_fixtures();
    let b = Baseline::from_report(&r);
    let text = lint::to_pretty(&b.to_json());
    let parsed = rfast::jsonio::parse(&text).expect("pretty output parses");
    assert_eq!(Baseline::from_json(&parsed).expect("schema"), b);
}

/// The tentpole gate, run as a test: the live tree must match the
/// committed baseline EXACTLY — no regressions (ratchet) and no stale
/// grandfathered cells (a fixed finding must shrink the baseline too, so
/// the register never overstates the debt).
#[test]
fn self_scan_matches_committed_baseline_exactly() {
    let root = repo_root();
    let baseline_path = root.join("LINT_BASELINE.json");
    let committed = Baseline::load(&baseline_path).expect("committed baseline");
    let report = lint::run(&LintConfig::new(root)).expect("self scan");
    assert!(
        report.waiver_errors.is_empty(),
        "malformed waivers in tree: {:?}",
        report.waiver_errors
    );
    let live = Baseline::from_report(&report);
    assert_eq!(
        live, committed,
        "live tree diverges from LINT_BASELINE.json — fix the new \
         findings or run `repro lint --baseline LINT_BASELINE.json \
         --fix-baseline` after a genuine improvement"
    );
    // sanity: the scan actually covered the tree
    assert!(report.files_scanned > 30, "only {} files", report.files_scanned);
}
