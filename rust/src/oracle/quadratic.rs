//! Heterogeneous quadratic objectives with closed-form optimum.
//!
//! f_i(x) = ½ (x − b_i)ᵀ H_i (x − b_i), H_i diagonal positive.
//! F = Σ_i f_i is τ-strongly convex with τ = λ_min(Σ H_i); the optimum is
//! x* = (Σ H_i)⁻¹ Σ H_i b_i (element-wise for diagonal H).
//!
//! Heterogeneity knob: the spread of the b_i. With `spread = 0` every node
//! shares the same minimizer (ς = 0); growing spread grows ς exactly as in
//! Definition 2 — this is what the heterogeneity ablation bench sweeps.
//! Stochasticity: `noise_sigma` adds i.i.d. N(0, σ²) to each gradient
//! entry (Assumption 5 with variance p·σ²).

use super::{Eval, GradOracle, NodeOracle, OracleSet};
use crate::prng::Rng;

/// Builder for the family (owns all nodes' H_i, b_i).
#[derive(Clone, Debug)]
pub struct QuadraticOracle {
    pub dim: usize,
    pub n_nodes: usize,
    /// h[i] — diagonal of H_i.
    pub h: Vec<Vec<f32>>,
    /// b[i] — per-node shift.
    pub b: Vec<Vec<f32>>,
    pub noise_sigma: f32,
    pub seed: u64,
}

impl QuadraticOracle {
    /// Random instance: curvatures log-uniform in [h_min, h_max], shifts
    /// uniform in [-spread, spread] around a common center.
    pub fn new(dim: usize, n_nodes: usize, h_min: f32, h_max: f32,
               spread: f32, noise_sigma: f32, seed: u64) -> QuadraticOracle {
        assert!(h_min > 0.0 && h_max >= h_min);
        let mut rng = Rng::stream(seed, 0x9ad);
        let center: Vec<f32> = (0..dim).map(|_| 2.0 * rng.f32() - 1.0).collect();
        let mut h = Vec::with_capacity(n_nodes);
        let mut b = Vec::with_capacity(n_nodes);
        for _ in 0..n_nodes {
            h.push(
                (0..dim)
                    .map(|_| {
                        let t = rng.f32();
                        (h_min.ln() + t * (h_max.ln() - h_min.ln())).exp()
                    })
                    .collect(),
            );
            b.push(
                center
                    .iter()
                    .map(|c| c + spread * (2.0 * rng.f32() - 1.0))
                    .collect(),
            );
        }
        QuadraticOracle { dim, n_nodes, h, b, noise_sigma, seed }
    }

    /// Standard heterogeneous test instance (spread 1, no gradient noise).
    pub fn heterogeneous(dim: usize, n_nodes: usize, h_min: f32, h_max: f32,
                         seed: u64) -> QuadraticOracle {
        QuadraticOracle::new(dim, n_nodes, h_min, h_max, 1.0, 0.0, seed)
    }

    /// With stochastic gradients.
    pub fn noisy(dim: usize, n_nodes: usize, sigma: f32, seed: u64) -> QuadraticOracle {
        QuadraticOracle::new(dim, n_nodes, 0.5, 4.0, 1.0, sigma, seed)
    }

    /// Closed-form minimizer of F = Σ f_i.
    pub fn optimum(&self) -> Vec<f32> {
        let mut num = vec![0.0f64; self.dim];
        let mut den = vec![0.0f64; self.dim];
        for i in 0..self.n_nodes {
            for d in 0..self.dim {
                num[d] += self.h[i][d] as f64 * self.b[i][d] as f64;
                den[d] += self.h[i][d] as f64;
            }
        }
        num.iter().zip(&den).map(|(n, d)| (n / d) as f32).collect()
    }

    /// Exact F(x) = Σ_i f_i(x).
    pub fn global_loss(&self, x: &[f32]) -> f64 {
        let mut total = 0.0f64;
        for i in 0..self.n_nodes {
            for d in 0..self.dim {
                let e = (x[d] - self.b[i][d]) as f64;
                total += 0.5 * self.h[i][d] as f64 * e * e;
            }
        }
        total
    }

    /// ς² of Definition 2 at the optimum: (1/n)Σ‖∇f_i(x*) − ∇F(x*)/n‖².
    pub fn heterogeneity_at_optimum(&self) -> f64 {
        let xs = self.optimum();
        let mut grads = vec![vec![0.0f64; self.dim]; self.n_nodes];
        for i in 0..self.n_nodes {
            for d in 0..self.dim {
                grads[i][d] =
                    self.h[i][d] as f64 * (xs[d] - self.b[i][d]) as f64;
            }
        }
        let mut mean = vec![0.0f64; self.dim];
        for g in &grads {
            for (m, v) in mean.iter_mut().zip(g) {
                *m += v / self.n_nodes as f64;
            }
        }
        grads
            .iter()
            .map(|g| {
                g.iter()
                    .zip(&mean)
                    .map(|(v, m)| (v - m) * (v - m))
                    .sum::<f64>()
            })
            .sum::<f64>()
            / self.n_nodes as f64
    }
}

impl GradOracle for QuadraticOracle {
    fn into_set(self) -> OracleSet {
        let mut nodes: Vec<Box<dyn NodeOracle>> = Vec::new();
        for i in 0..self.n_nodes {
            nodes.push(Box::new(QuadraticNode {
                h: self.h[i].clone(),
                b: self.b[i].clone(),
                noise_sigma: self.noise_sigma,
                rng: Rng::stream(self.seed, 0x3100 + i as u64),
            }));
        }
        let optimum = self.optimum();
        let dim = self.dim;
        let this = self;
        OracleSet {
            nodes,
            eval: Box::new(move |x| Eval {
                loss: this.global_loss(x),
                accuracy: None,
            }),
            optimum: Some(optimum),
            dim,
            epoch_per_node_batch: 1.0, // one "epoch" per deterministic step
        }
    }
}

/// Per-node quadratic gradient: ∇f_i(x) = H_i(x − b_i) (+ noise).
pub struct QuadraticNode {
    h: Vec<f32>,
    b: Vec<f32>,
    noise_sigma: f32,
    rng: Rng,
}

impl NodeOracle for QuadraticNode {
    fn dim(&self) -> usize {
        self.h.len()
    }

    fn grad(&mut self, x: &[f32], grad_out: &mut [f32]) -> f32 {
        let mut loss = 0.0f64;
        for d in 0..self.h.len() {
            let e = x[d] - self.b[d];
            loss += 0.5 * (self.h[d] * e * e) as f64;
            let mut g = self.h[d] * e;
            if self.noise_sigma > 0.0 {
                g += self.rng.normal_f32(0.0, self.noise_sigma);
            }
            grad_out[d] = g;
        }
        loss as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg;

    #[test]
    fn optimum_has_zero_total_gradient() {
        let q = QuadraticOracle::heterogeneous(16, 5, 0.5, 8.0, 42);
        let xs = q.optimum();
        let mut set = q.into_set();
        let mut total = vec![0.0f32; 16];
        let mut g = vec![0.0f32; 16];
        for node in set.nodes.iter_mut() {
            node.grad(&xs, &mut g);
            linalg::axpy(&mut total, 1.0, &g);
        }
        assert!(linalg::norm(&total) < 1e-4, "{}", linalg::norm(&total));
    }

    #[test]
    fn global_loss_minimized_at_optimum() {
        let q = QuadraticOracle::heterogeneous(8, 4, 1.0, 3.0, 7);
        let xs = q.optimum();
        let l_star = q.global_loss(&xs);
        let mut perturbed = xs.clone();
        perturbed[3] += 0.1;
        assert!(q.global_loss(&perturbed) > l_star);
    }

    #[test]
    fn spread_zero_means_zero_heterogeneity() {
        let q = QuadraticOracle::new(8, 4, 1.0, 1.0, 0.0, 0.0, 5);
        assert!(q.heterogeneity_at_optimum() < 1e-10);
        let q2 = QuadraticOracle::new(8, 4, 0.5, 4.0, 2.0, 0.0, 5);
        assert!(q2.heterogeneity_at_optimum() > 0.01);
    }

    #[test]
    fn noise_is_zero_mean() {
        let q = QuadraticOracle::noisy(4, 1, 0.5, 9);
        let xs = q.optimum();
        let mut set = q.into_set();
        let mut acc = vec![0.0f64; 4];
        let mut g = vec![0.0f32; 4];
        let reps = 20_000;
        for _ in 0..reps {
            set.nodes[0].grad(&xs, &mut g);
            for (a, &v) in acc.iter_mut().zip(&g) {
                *a += v as f64;
            }
        }
        for a in &acc {
            assert!((a / reps as f64).abs() < 0.02, "{a}");
        }
    }

    #[test]
    fn deterministic_across_builds() {
        let a = QuadraticOracle::heterogeneous(4, 2, 1.0, 2.0, 11);
        let b = QuadraticOracle::heterogeneous(4, 2, 1.0, 2.0, 11);
        assert_eq!(a.h, b.h);
        assert_eq!(a.b, b.b);
    }
}
