//! Pure-rust MLP classifier oracle (784-128-64-10, ReLU, softmax xent) —
//! functional twin of `python/compile/model.py::mlp_grad` over the same
//! flat-θ layout ([w0; b0; w1; b1; w2; b2], row-major weights).
//!
//! Exists for two reasons: (1) the Table II / Figs 5-7 benches drive ~10⁵
//! simulated gradient steps per algorithm — a hand-rolled fwd/bwd at
//! ~0.1 ms/batch keeps every bench regenerable in seconds; (2) it
//! cross-checks the PJRT `mlp_grad` artifact (integration test asserts
//! agreement on identical batches).

use super::{Eval, GradOracle, NodeOracle, OracleSet};
use crate::data::{Batcher, Dataset, Partition};
use std::sync::Arc;

/// Layer dims — MUST match `model.MLP_DIMS` in python.
pub const MLP_DIMS: [usize; 4] = [784, 128, 64, 10];

/// Total parameter count p.
pub fn mlp_p() -> usize {
    (0..3).map(|i| MLP_DIMS[i] * MLP_DIMS[i + 1] + MLP_DIMS[i + 1]).sum()
}

/// Offsets of (w_i, b_i) inside flat θ.
fn offsets() -> [(usize, usize); 3] {
    let mut out = [(0, 0); 3];
    let mut off = 0;
    for i in 0..3 {
        let w = off;
        off += MLP_DIMS[i] * MLP_DIMS[i + 1];
        let b = off;
        off += MLP_DIMS[i + 1];
        out[i] = (w, b);
    }
    out
}

/// Builder over the synthetic 10-class set (ImageNet proxy, DESIGN.md §4).
pub struct MlpOracle {
    pub train: Arc<Dataset>,
    pub eval_set: Arc<Dataset>,
    pub partition: Partition,
    pub batch: usize,
    pub seed: u64,
}

impl MlpOracle {
    /// Paper §VI-B proxy workload.
    pub fn paper_workload(n_nodes: usize, batch: usize, skew_alpha: f64,
                          seed: u64) -> MlpOracle {
        let (train, eval_set) =
            Dataset::imagenet_like(20_000, seed).split_eval(2_000);
        let partition = if skew_alpha <= 0.0 {
            Partition::iid(&train, n_nodes, seed)
        } else {
            Partition::label_skew(&train, n_nodes, skew_alpha, seed)
        };
        MlpOracle {
            train: Arc::new(train),
            eval_set: Arc::new(eval_set),
            partition,
            batch,
            seed,
        }
    }

    /// Deterministic init matching the python scale (He init, zero bias) —
    /// exact values differ (different PRNG), distributional match only.
    pub fn init_theta(seed: u64) -> Vec<f32> {
        let mut rng = crate::prng::Rng::stream(seed, 0x1417);
        let mut theta = vec![0.0f32; mlp_p()];
        let offs = offsets();
        for i in 0..3 {
            let scale = (2.0 / MLP_DIMS[i] as f32).sqrt();
            let (w, b) = offs[i];
            for v in theta[w..w + MLP_DIMS[i] * MLP_DIMS[i + 1]].iter_mut() {
                *v = rng.normal_f32(0.0, scale);
            }
            let _ = b; // biases stay zero
        }
        theta
    }
}

impl GradOracle for MlpOracle {
    fn into_set(self) -> OracleSet {
        let p = mlp_p();
        let n = self.partition.n_nodes();
        let mut nodes: Vec<Box<dyn NodeOracle>> = Vec::new();
        // one node-batch advances the GLOBAL epoch by batch / N_total
        let total: usize = self.partition.shards.iter().map(|s| s.len()).sum();
        let epoch_frac = self.batch as f64 / total as f64;
        for i in 0..n {
            let b = Batcher::new(&self.partition.shards[i], self.batch,
                                 self.seed ^ (0x3170 + i as u64));
            nodes.push(Box::new(MlpNode {
                data: Arc::clone(&self.train),
                batcher: b,
                ws: Workspace::new(self.batch),
            }));
        }
        let eval_set = Arc::clone(&self.eval_set);
        let mut ews = Workspace::new(256);
        OracleSet {
            nodes,
            eval: Box::new(move |x| eval_mlp(&eval_set, x, &mut ews)),
            optimum: None,
            dim: p,
            epoch_per_node_batch: epoch_frac,
        }
    }
}

/// Per-batch activation/gradient scratch (no allocation on the hot path).
pub struct Workspace {
    h1: Vec<f32>,
    h2: Vec<f32>,
    logits: Vec<f32>,
    d2: Vec<f32>,
    d1: Vec<f32>,
    dlog: Vec<f32>,
    cap: usize,
}

impl Workspace {
    pub fn new(batch: usize) -> Workspace {
        Workspace {
            h1: vec![0.0; batch * MLP_DIMS[1]],
            h2: vec![0.0; batch * MLP_DIMS[2]],
            logits: vec![0.0; batch * MLP_DIMS[3]],
            d2: vec![0.0; batch * MLP_DIMS[2]],
            d1: vec![0.0; batch * MLP_DIMS[1]],
            dlog: vec![0.0; batch * MLP_DIMS[3]],
            cap: batch,
        }
    }
}

pub struct MlpNode {
    data: Arc<Dataset>,
    batcher: Batcher,
    ws: Workspace,
}

impl MlpNode {
    pub fn next_batch_indices(&mut self) -> Vec<usize> {
        self.batcher.next_batch()
    }

    pub fn grad_on(&mut self, idx: &[usize], theta: &[f32],
                   grad_out: &mut [f32]) -> f32 {
        mlp_loss_grad(&self.data, idx, theta, grad_out, &mut self.ws)
    }
}

impl NodeOracle for MlpNode {
    fn dim(&self) -> usize {
        mlp_p()
    }

    fn grad(&mut self, x: &[f32], grad_out: &mut [f32]) -> f32 {
        let idx = self.batcher.next_batch();
        mlp_loss_grad(&self.data, &idx, x, grad_out, &mut self.ws)
    }
}

/// y[b, o] = x[b, i] @ w[i, o] + bias[o]
fn dense_fwd(x: &[f32], w: &[f32], bias: &[f32], y: &mut [f32], b: usize,
             din: usize, dout: usize) {
    for r in 0..b {
        let yr = &mut y[r * dout..(r + 1) * dout];
        yr.copy_from_slice(bias);
        let xr = &x[r * din..(r + 1) * din];
        for i in 0..din {
            let xv = xr[i];
            if xv != 0.0 {
                crate::linalg::axpy(yr, xv, &w[i * dout..(i + 1) * dout]);
            }
        }
    }
}

/// Backward through dense: dW += xᵀ dy, db += Σ dy, dx = dy Wᵀ.
fn dense_bwd(x: &[f32], w: &[f32], dy: &[f32], dw: &mut [f32],
             db: &mut [f32], dx: Option<&mut [f32]>, b: usize, din: usize,
             dout: usize) {
    for r in 0..b {
        let dyr = &dy[r * dout..(r + 1) * dout];
        let xr = &x[r * din..(r + 1) * din];
        for i in 0..din {
            let xv = xr[i];
            if xv != 0.0 {
                crate::linalg::axpy(&mut dw[i * dout..(i + 1) * dout], xv, dyr);
            }
        }
        crate::linalg::axpy(db, 1.0, dyr);
    }
    if let Some(dx) = dx {
        for r in 0..b {
            let dyr = &dy[r * dout..(r + 1) * dout];
            let dxr = &mut dx[r * din..(r + 1) * din];
            for i in 0..din {
                dxr[i] = crate::linalg::dot(dyr, &w[i * dout..(i + 1) * dout])
                    as f32;
            }
        }
    }
}

fn forward(data: &Dataset, idx: &[usize], theta: &[f32],
           ws: &mut Workspace) -> f64 {
    let b = idx.len();
    assert!(b <= ws.cap);
    let offs = offsets();
    let d = MLP_DIMS;
    // gather rows contiguously via per-row fwd (x rows borrowed directly)
    for (r, &s) in idx.iter().enumerate() {
        let xr = data.row(s);
        let (w0, b0) = offs[0];
        dense_fwd(xr, &theta[w0..w0 + d[0] * d[1]],
                  &theta[b0..b0 + d[1]],
                  &mut ws.h1[r * d[1]..(r + 1) * d[1]], 1, d[0], d[1]);
    }
    for v in ws.h1[..b * d[1]].iter_mut() {
        *v = v.max(0.0);
    }
    let (w1, b1) = offs[1];
    dense_fwd(&ws.h1, &theta[w1..w1 + d[1] * d[2]], &theta[b1..b1 + d[2]],
              &mut ws.h2, b, d[1], d[2]);
    for v in ws.h2[..b * d[2]].iter_mut() {
        *v = v.max(0.0);
    }
    let (w2, b2) = offs[2];
    dense_fwd(&ws.h2, &theta[w2..w2 + d[2] * d[3]], &theta[b2..b2 + d[3]],
              &mut ws.logits, b, d[2], d[3]);
    // stable mean xent + dlogits = (softmax − onehot)/B
    let mut loss = 0.0f64;
    for r in 0..b {
        let lr = &mut ws.logits[r * d[3]..(r + 1) * d[3]];
        let label = data.labels[idx[r]] as usize;
        let m = lr.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut denom = 0.0f32;
        for v in lr.iter() {
            denom += (v - m).exp();
        }
        let lse = m + denom.ln();
        loss += (lse - lr[label]) as f64;
        let dlr = &mut ws.dlog[r * d[3]..(r + 1) * d[3]];
        for (o, v) in lr.iter().enumerate() {
            dlr[o] = ((v - lse).exp() - f32::from(o == label)) / b as f32;
        }
    }
    loss / b as f64
}

/// One-shot convenience wrapper (tests / cross-checks): allocates its own
/// workspace.
pub fn mlp_loss_grad_once(data: &Dataset, idx: &[usize],
                          theta: &[f32]) -> (f32, Vec<f32>) {
    let mut ws = Workspace::new(idx.len());
    let mut grad = vec![0.0f32; mlp_p()];
    let loss = mlp_loss_grad(data, idx, theta, &mut grad, &mut ws);
    (loss, grad)
}

/// Fused loss+grad (the oracle hot path).
pub fn mlp_loss_grad(data: &Dataset, idx: &[usize], theta: &[f32],
                     grad_out: &mut [f32], ws: &mut Workspace) -> f32 {
    let b = idx.len();
    let d = MLP_DIMS;
    let offs = offsets();
    let loss = forward(data, idx, theta, ws);
    grad_out.iter_mut().for_each(|v| *v = 0.0);

    let (w2, b2) = offs[2];
    let (w1, b1) = offs[1];
    let (w0, b0) = offs[0];
    // split grad_out disjointly
    let (g01, g2) = grad_out.split_at_mut(w2);
    let (g0, g1) = g01.split_at_mut(w1);
    let (gw2, gb2) = g2.split_at_mut(b2 - w2);
    let (gw1, gb1) = g1.split_at_mut(b1 - w1);
    let (gw0, gb0) = g0.split_at_mut(b0 - w0);

    dense_bwd(&ws.h2, &theta[w2..w2 + d[2] * d[3]], &ws.dlog, gw2, gb2,
              Some(&mut ws.d2), b, d[2], d[3]);
    for (dv, hv) in ws.d2[..b * d[2]].iter_mut().zip(&ws.h2) {
        if *hv <= 0.0 {
            *dv = 0.0;
        }
    }
    dense_bwd(&ws.h1, &theta[w1..w1 + d[1] * d[2]], &ws.d2, gw1, gb1,
              Some(&mut ws.d1), b, d[1], d[2]);
    for (dv, hv) in ws.d1[..b * d[1]].iter_mut().zip(&ws.h1) {
        if *hv <= 0.0 {
            *dv = 0.0;
        }
    }
    for (r, &s) in idx.iter().enumerate() {
        let xr = data.row(s);
        dense_bwd(xr, &theta[w0..w0 + d[0] * d[1]],
                  &ws.d1[r * d[1]..(r + 1) * d[1]], gw0, gb0, None, 1, d[0],
                  d[1]);
    }
    loss as f32
}

/// Held-out loss + accuracy.
pub fn eval_mlp(data: &Dataset, theta: &[f32], ws: &mut Workspace) -> Eval {
    let d = MLP_DIMS;
    let chunk = ws.cap;
    let mut total_loss = 0.0f64;
    let mut correct = 0usize;
    let mut counted = 0usize;
    let idx_all: Vec<usize> = (0..data.len()).collect();
    for c in idx_all.chunks(chunk) {
        let loss = forward(data, c, theta, ws);
        total_loss += loss * c.len() as f64;
        counted += c.len();
        for (r, &s) in c.iter().enumerate() {
            // dlog holds softmax/B − onehot/B; recover argmax from logits
            let lr = &ws.logits[r * d[3]..(r + 1) * d[3]];
            let mut best = 0;
            for o in 1..d[3] {
                if lr[o] > lr[best] {
                    best = o;
                }
            }
            if best == data.labels[s] as usize {
                correct += 1;
            }
        }
    }
    Eval {
        loss: total_loss / counted as f64,
        accuracy: Some(correct as f64 / counted as f64),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_data() -> Dataset {
        Dataset::synthetic_digits(300, 784, 10, 0.3, 7)
    }

    #[test]
    fn p_matches_python() {
        assert_eq!(mlp_p(), 109_386); // asserted equal to model.MLP_P
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let data = tiny_data();
        let idx: Vec<usize> = (0..8).collect();
        let theta = MlpOracle::init_theta(3);
        let mut ws = Workspace::new(8);
        let mut g = vec![0.0f32; mlp_p()];
        let _ = mlp_loss_grad(&data, &idx, &theta, &mut g, &mut ws);
        let offs = offsets();
        // probe a few coordinates across all six tensors
        let probes = [
            offs[0].0 + 5,
            offs[0].1 + 3,
            offs[1].0 + 17,
            offs[1].1 + 1,
            offs[2].0 + 9,
            offs[2].1 + 2,
        ];
        let eps = 5e-3f32;
        for &k in &probes {
            let mut tp = theta.clone();
            tp[k] += eps;
            let mut tm = theta.clone();
            tm[k] -= eps;
            let mut scratch = vec![0.0f32; mlp_p()];
            let lp = mlp_loss_grad(&data, &idx, &tp, &mut scratch, &mut ws);
            let lm = mlp_loss_grad(&data, &idx, &tm, &mut scratch, &mut ws);
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - g[k]).abs() < 5e-2 * (1.0 + fd.abs().max(g[k].abs())),
                "coord {k}: fd {fd} vs analytic {}",
                g[k]
            );
        }
    }

    #[test]
    fn sgd_learns_synthetic_classes() {
        let o = MlpOracle::paper_workload(1, 32, 0.0, 5);
        let eval_set = Arc::clone(&o.eval_set);
        let mut set = o.into_set();
        let mut theta = MlpOracle::init_theta(1);
        let mut g = vec![0.0f32; mlp_p()];
        for _ in 0..300 {
            set.nodes[0].grad(&theta, &mut g);
            crate::linalg::axpy(&mut theta, -0.05, &g);
        }
        let mut ws = Workspace::new(256);
        let e = eval_mlp(&eval_set, &theta, &mut ws);
        assert!(e.accuracy.unwrap() > 0.8, "acc {:?}", e.accuracy);
    }

    #[test]
    fn eval_random_theta_near_chance() {
        let o = MlpOracle::paper_workload(1, 32, 0.0, 9);
        let theta = MlpOracle::init_theta(2);
        let mut ws = Workspace::new(256);
        let e = eval_mlp(&o.eval_set, &theta, &mut ws);
        assert!((e.loss - (10.0f64).ln()).abs() < 0.8, "loss {}", e.loss);
        assert!(e.accuracy.unwrap() < 0.45);
    }
}
