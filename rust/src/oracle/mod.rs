//! Gradient oracles — what a node computes when it wakes (step S1/S2b).
//!
//! Three families:
//! * [`QuadraticOracle`] — heterogeneous quadratics with a closed-form
//!   global optimum; drives convergence *proofs-as-tests* (optimality gap,
//!   mass conservation) at high event rates.
//! * [`LogRegOracle`] — pure-rust logistic regression over the synthetic
//!   digit set: exact twin of the Pallas `logreg_grad` kernel, used to
//!   cross-check the PJRT path and for fast virtual-time benches.
//! * [`PjrtOracle`](crate::runtime::PjrtOracle) — the production path:
//!   gradients come from the AOT-compiled XLA executables.
//!
//! The per-node handle is [`NodeOracle`] (`Send`, owned by a sim node or a
//! runner thread); centralized evaluation goes through [`EvalFn`].

mod logreg;
mod mlp;
mod quadratic;

pub use logreg::{eval_logreg, logreg_loss_grad, LogRegFactory, LogRegNode,
                 LogRegOracle};
pub use mlp::{mlp_loss_grad_once, mlp_p, MlpNode, MlpOracle};
pub use quadratic::{QuadraticNode, QuadraticOracle};

/// Per-node stochastic gradient source.
///
/// `grad` writes ∇f_node(x; ζ) into `grad_out` and returns the minibatch
/// loss. Implementations advance their own sampling state (ζ) per call.
///
/// Deliberately **not** `Send`: the PJRT client is `Rc`-based, so PJRT
/// oracles must live on the thread that built them. The threaded runner
/// therefore takes an [`OracleFactory`] and constructs each node's oracle
/// inside its worker thread; the single-threaded simulator owns its
/// oracles directly.
pub trait NodeOracle {
    fn dim(&self) -> usize;
    fn grad(&mut self, x: &[f32], grad_out: &mut [f32]) -> f32;
}

/// Thread-safe builder of per-node oracles (used by `runner`).
pub trait OracleFactory: Send + Sync {
    fn dim(&self) -> usize;
    fn make(&self, node: usize) -> Box<dyn NodeOracle>;

    /// Fraction of a global epoch consumed by one node-batch — the
    /// factory twin of [`OracleSet::epoch_per_node_batch`]; it drives the
    /// runner's epoch-indexed γ-decay schedule. Default 1.0 (one "epoch"
    /// per deterministic step — quadratics).
    fn epoch_per_node_batch(&self) -> f64 {
        1.0
    }
}

/// Evaluation snapshot on held-out data / the full objective.
#[derive(Clone, Copy, Debug, Default)]
pub struct Eval {
    pub loss: f64,
    /// Classification accuracy in [0,1] when defined for the task.
    pub accuracy: Option<f64>,
}

/// Centralized evaluation closure (runs on the coordinator thread only).
pub type EvalFn = Box<dyn FnMut(&[f32]) -> Eval>;

/// Everything the engines need: one oracle per node + evaluation + the
/// closed-form optimum when the objective has one.
pub struct OracleSet {
    pub nodes: Vec<Box<dyn NodeOracle>>,
    pub eval: EvalFn,
    pub optimum: Option<Vec<f32>>,
    pub dim: usize,
    /// Fraction of a global epoch consumed by one minibatch at one node
    /// (Σ over nodes of their per-batch fractions ≈ n · this for even
    /// shards); lets reports convert iterations → epochs like the paper.
    pub epoch_per_node_batch: f64,
}

impl OracleSet {
    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }
}

/// Marker trait for oracle builders (each concrete oracle type provides
/// `fn build(&self, ...) -> OracleSet`); kept as a free convention rather
/// than a trait because builders differ in their inputs.
pub trait GradOracle {
    fn into_set(self) -> OracleSet;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quadratic_set_shapes() {
        let q = QuadraticOracle::heterogeneous(8, 3, 1.0, 4.0, 7);
        let set = q.into_set();
        assert_eq!(set.n_nodes(), 3);
        assert_eq!(set.dim, 8);
        assert!(set.optimum.is_some());
    }

    #[test]
    fn eval_fn_runs() {
        let q = QuadraticOracle::heterogeneous(4, 2, 1.0, 2.0, 3);
        let mut set = q.into_set();
        let x = vec![0.0f32; 4];
        let e = (set.eval)(&x);
        assert!(e.loss >= 0.0);
        assert!(e.accuracy.is_none());
    }
}
