//! Pure-rust logistic-regression oracle — exact functional twin of the
//! Pallas `logreg_grad` kernel / `ref.py` oracle (same stable BCE, same
//! ℓ2 term), over the synthetic digit set. Used for:
//!   * high-rate virtual-time benches (no PJRT per-call overhead),
//!   * cross-checking the PJRT path (integration test asserts the two
//!     oracles agree to fp tolerance on identical batches).

use super::{Eval, GradOracle, NodeOracle, OracleFactory, OracleSet};
use crate::data::{Batcher, Dataset, Partition};
use std::sync::Arc;

/// Builder: dataset + partition + hyper-parameters.
pub struct LogRegOracle {
    pub train: Arc<Dataset>,
    pub eval_set: Arc<Dataset>,
    pub partition: Partition,
    pub batch: usize,
    pub l2: f32,
    pub seed: u64,
}

impl LogRegOracle {
    /// The paper's §VI-A workload: 12k synthetic two-digit samples split
    /// into train/eval, IID or label-skew partition over `n_nodes`.
    pub fn paper_workload(n_nodes: usize, batch: usize, skew_alpha: f64,
                          seed: u64) -> LogRegOracle {
        let (train, eval_set) = Dataset::mnist01_like(seed).split_eval(2_000);
        let partition = if skew_alpha <= 0.0 {
            Partition::iid(&train, n_nodes, seed)
        } else {
            Partition::label_skew(&train, n_nodes, skew_alpha, seed)
        };
        LogRegOracle {
            train: Arc::new(train),
            eval_set: Arc::new(eval_set),
            partition,
            batch,
            l2: 1e-4,
            seed,
        }
    }

    pub fn dim_p(&self) -> usize {
        self.train.dim + 1
    }
}

impl GradOracle for LogRegOracle {
    fn into_set(self) -> OracleSet {
        let p = self.dim_p();
        let n_nodes = self.partition.n_nodes();
        let mut nodes: Vec<Box<dyn NodeOracle>> = Vec::new();
        // one node-batch advances the GLOBAL epoch by batch / N_total
        let total: usize = self.partition.shards.iter().map(|s| s.len()).sum();
        let epoch_frac = self.batch as f64 / total as f64;
        for i in 0..n_nodes {
            let b = Batcher::new(&self.partition.shards[i], self.batch,
                                 self.seed ^ (0xb000 + i as u64));
            nodes.push(Box::new(LogRegNode {
                data: Arc::clone(&self.train),
                batcher: b,
                l2: self.l2,
            }));
        }
        let eval_set = Arc::clone(&self.eval_set);
        let l2 = self.l2;
        OracleSet {
            nodes,
            eval: Box::new(move |x| eval_logreg(&eval_set, x, l2)),
            optimum: None,
            dim: p,
            epoch_per_node_batch: epoch_frac,
        }
    }
}

/// Thread-safe logreg factory for the wall-clock runner: per-node
/// oracles share the dataset (`Arc`) and the shard plan, so the threaded
/// engine trains the exact workload the simulator does.
pub struct LogRegFactory {
    pub train: Arc<Dataset>,
    pub eval_set: Arc<Dataset>,
    pub partition: Partition,
    pub batch: usize,
    pub l2: f32,
    pub seed: u64,
}

impl LogRegFactory {
    /// The paper's §VI-A workload (same data/partition derivation as
    /// [`LogRegOracle::paper_workload`]).
    pub fn paper_workload(n_nodes: usize, batch: usize, skew_alpha: f64,
                          seed: u64) -> LogRegFactory {
        let o = LogRegOracle::paper_workload(n_nodes, batch, skew_alpha, seed);
        LogRegFactory {
            train: o.train,
            eval_set: o.eval_set,
            partition: o.partition,
            batch: o.batch,
            l2: o.l2,
            seed: o.seed,
        }
    }

    /// Held-out evaluation closure for the coordinator thread.
    pub fn eval_fn(&self) -> impl FnMut(&[f32]) -> Eval + 'static {
        let eval_set = Arc::clone(&self.eval_set);
        let l2 = self.l2;
        move |x: &[f32]| eval_logreg(&eval_set, x, l2)
    }
}

impl OracleFactory for LogRegFactory {
    fn dim(&self) -> usize {
        self.train.dim + 1
    }

    fn make(&self, node: usize) -> Box<dyn NodeOracle> {
        Box::new(LogRegNode {
            data: Arc::clone(&self.train),
            batcher: Batcher::new(&self.partition.shards[node], self.batch,
                                  self.seed ^ (0xb000 + node as u64)),
            l2: self.l2,
        })
    }

    fn epoch_per_node_batch(&self) -> f64 {
        let total: usize = self.partition.shards.iter().map(|s| s.len()).sum();
        self.batch as f64 / total as f64
    }
}

/// Per-node handle: shard batcher + shared dataset.
pub struct LogRegNode {
    data: Arc<Dataset>,
    batcher: Batcher,
    l2: f32,
}

impl LogRegNode {
    /// Expose the next batch indices (PJRT cross-check tests drive both
    /// oracles with identical batches through this).
    pub fn next_batch_indices(&mut self) -> Vec<usize> {
        self.batcher.next_batch()
    }

    /// Gradient on an explicit batch (shared by `grad` and the tests).
    pub fn grad_on(&self, idx: &[usize], x: &[f32],
                   grad_out: &mut [f32]) -> f32 {
        logreg_loss_grad(&self.data, idx, x, self.l2, grad_out)
    }
}

impl NodeOracle for LogRegNode {
    fn dim(&self) -> usize {
        self.data.dim + 1
    }

    fn grad(&mut self, x: &[f32], grad_out: &mut [f32]) -> f32 {
        let idx = self.batcher.next_batch();
        self.grad_on(&idx, x, grad_out)
    }
}

/// Stable BCE-with-logits loss + gradient over a batch of rows — the same
/// arithmetic as `kernels/logreg.py::_kernel` (and `ref.py`).
pub fn logreg_loss_grad(data: &Dataset, idx: &[usize], theta: &[f32],
                        l2: f32, grad_out: &mut [f32]) -> f32 {
    let d = data.dim;
    assert_eq!(theta.len(), d + 1);
    assert_eq!(grad_out.len(), d + 1);
    let (w, bias) = theta.split_at(d);
    let inv_b = 1.0 / idx.len() as f32;

    // grad = l2 * theta  (filled first; batch terms accumulate on top)
    for (g, &t) in grad_out.iter_mut().zip(theta.iter()) {
        *g = l2 * t;
    }
    let mut loss = 0.0f64;
    for &s in idx {
        let row = data.row(s);
        let y = data.labels[s] as f32;
        let z = crate::linalg::dot(row, w) as f32 + bias[0];
        // max(z,0) − z·y + log1p(exp(−|z|))
        loss += (z.max(0.0) - z * y + (-z.abs()).exp().ln_1p()) as f64;
        let sig = 1.0 / (1.0 + (-z).exp());
        let r = (sig - y) * inv_b;
        crate::linalg::axpy(&mut grad_out[..d], r, row);
        grad_out[d] += r;
    }
    let theta_sq: f64 = theta.iter().map(|&t| (t as f64) * (t as f64)).sum();
    (loss * inv_b as f64 + 0.5 * l2 as f64 * theta_sq) as f32
}

/// Held-out loss + accuracy.
pub fn eval_logreg(data: &Dataset, theta: &[f32], l2: f32) -> Eval {
    let d = data.dim;
    let (w, bias) = theta.split_at(d);
    let mut loss = 0.0f64;
    let mut correct = 0usize;
    for s in 0..data.len() {
        let row = data.row(s);
        let y = data.labels[s] as f32;
        let z = crate::linalg::dot(row, w) as f32 + bias[0];
        loss += (z.max(0.0) - z * y + (-z.abs()).exp().ln_1p()) as f64;
        let pred = if z > 0.0 { 1.0 } else { 0.0 };
        if pred == y {
            correct += 1;
        }
    }
    let theta_sq: f64 = theta.iter().map(|&t| (t as f64) * (t as f64)).sum();
    Eval {
        loss: loss / data.len() as f64 + 0.5 * l2 as f64 * theta_sq,
        accuracy: Some(correct as f64 / data.len() as f64),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_oracle() -> LogRegOracle {
        let (train, eval_set) =
            Dataset::synthetic_digits(400, 16, 2, 0.25, 3).split_eval(100);
        let partition = Partition::iid(&train, 3, 0);
        LogRegOracle {
            train: Arc::new(train),
            eval_set: Arc::new(eval_set),
            partition,
            batch: 16,
            l2: 1e-4,
            seed: 1,
        }
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let o = small_oracle();
        let data = Arc::clone(&o.train);
        let node = LogRegNode {
            data: Arc::clone(&data),
            batcher: Batcher::new(&o.partition.shards[0], 8, 0),
            l2: 1e-3,
        };
        let idx: Vec<usize> = o.partition.shards[0][..8].to_vec();
        let p = node.dim();
        let theta: Vec<f32> = (0..p).map(|i| ((i * 7 % 13) as f32 - 6.0) * 0.05).collect();
        let mut g = vec![0.0f32; p];
        let l0 = node.grad_on(&idx, &theta, &mut g);
        let eps = 1e-3f32;
        for d in [0usize, 3, p - 1] {
            let mut tp = theta.clone();
            tp[d] += eps;
            let mut tm = theta.clone();
            tm[d] -= eps;
            let mut scratch = vec![0.0f32; p];
            let lp = node.grad_on(&idx, &tp, &mut scratch);
            let lm = node.grad_on(&idx, &tm, &mut scratch);
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - g[d]).abs() < 2e-2 * (1.0 + g[d].abs()),
                "dim {d}: fd {fd} vs analytic {}",
                g[d]
            );
        }
        assert!(l0 > 0.0);
    }

    #[test]
    fn sgd_reaches_high_accuracy_on_separable_data() {
        let o = small_oracle();
        let mut set = o.into_set();
        let p = set.dim;
        let mut theta = vec![0.0f32; p];
        let mut g = vec![0.0f32; p];
        for step in 0..600 {
            let node = step % set.nodes.len();
            set.nodes[node].grad(&theta, &mut g);
            crate::linalg::axpy(&mut theta, -0.5, &g);
        }
        let e = (set.eval)(&theta);
        assert!(e.accuracy.unwrap() > 0.95, "acc {:?}", e.accuracy);
        assert!(e.loss < 0.3, "loss {}", e.loss);
    }

    #[test]
    fn eval_zero_theta_is_chance() {
        let o = small_oracle();
        let e = eval_logreg(&o.eval_set, &vec![0.0; o.dim_p()], 0.0);
        // z = 0 everywhere ⇒ predicts class 0; balanced set ⇒ ~50%
        assert!((e.accuracy.unwrap() - 0.5).abs() < 0.15);
        assert!((e.loss - std::f64::consts::LN_2).abs() < 1e-6);
    }

    #[test]
    fn loss_matches_bce_identity_small_case() {
        // hand-checked 1-sample case: d=1, w=1, b=0, x=2, y=1
        let data = Dataset {
            dim: 1,
            features: vec![2.0],
            labels: vec![1],
            classes: 2,
        };
        let theta = [1.0f32, 0.0];
        let mut g = [0.0f32; 2];
        let loss = logreg_loss_grad(&data, &[0], &theta, 0.0, &mut g);
        let z = 2.0f32;
        let expect = (1.0 + (-z).exp()).ln();
        assert!((loss - expect).abs() < 1e-6);
        let sig = 1.0 / (1.0 + (-z).exp());
        assert!((g[0] - (sig - 1.0) * 2.0).abs() < 1e-6);
        assert!((g[1] - (sig - 1.0)).abs() < 1e-6);
    }
}
