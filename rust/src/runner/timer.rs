//! Deterministic timer wheel for the actor scheduler.
//!
//! Every wall-clock delay the old thread-per-node engine expressed as a
//! `thread::sleep` — pacing floors, straggler factors, injected latency,
//! bandwidth serialization, churn resume polls — becomes an entry here:
//! the owning worker schedules an event at an absolute deadline, parks
//! until the earliest one, and fires whatever is due at the top of its
//! loop (DESIGN.md §15).
//!
//! Structure mirrors the simulator's [`CalendarQueue`](crate::sim::sched):
//! a hashed wheel of `slots` buckets, each a binary heap keyed by
//! `(time bits, insertion seq)`. Deadlines are non-negative seconds, so
//! the IEEE-754 bit pattern is order-isomorphic to the float and the key
//! is a total order with FIFO tie-breaks — two wheels fed the same
//! schedule calls pop identically, regardless of bucket geometry, which
//! is what the suspend/resume determinism tests pin.
//!
//! Unlike the calendar queue this wheel must answer "is anything due at
//! wall time `now`?" without popping, so the API is [`pop_due`] +
//! [`next_deadline`] rather than an unconditional pop. The global
//! minimum is found by scanning the bucket tops (O(slots), slots ≤ 64) —
//! no fast path keyed on the cursor bucket, because a past-deadline entry
//! clamped into the cursor bucket could then overtake an older equal-time
//! entry parked in an earlier bucket and break the FIFO tie-break.

use std::cmp::{Ordering as CmpOrdering, Reverse};
use std::collections::BinaryHeap;

/// One scheduled event: key is `(bits, seq)`; `day` only routes the entry
/// to its bucket and advances the clamp cursor.
struct Entry<T> {
    day: u64,
    /// `f64::to_bits` of the (non-negative) deadline — sortable as u64.
    bits: u64,
    seq: u64,
    ev: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Entry<T>) -> bool {
        self.bits == other.bits && self.seq == other.seq
    }
}
impl<T> Eq for Entry<T> {}
impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Entry<T>) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Entry<T>) -> CmpOrdering {
        (self.bits, self.seq).cmp(&(other.bits, other.seq))
    }
}

pub(crate) struct TimerWheel<T> {
    slots: Vec<BinaryHeap<Reverse<Entry<T>>>>,
    mask: u64,
    tick: f64,
    /// Bucket of the last popped entry; schedules clamp below it so a
    /// past-deadline entry stays findable (same trick as the calendar
    /// queue's day cursor).
    cur_day: u64,
    seq: u64,
    len: usize,
}

impl<T> TimerWheel<T> {
    /// `tick` is the bucket width in seconds, `slots` is rounded up to a
    /// power of two.
    pub fn new(tick: f64, slots: usize) -> TimerWheel<T> {
        debug_assert!(tick > 0.0);
        let slots = slots.max(2).next_power_of_two();
        TimerWheel {
            slots: (0..slots).map(|_| BinaryHeap::new()).collect(),
            mask: slots as u64 - 1,
            tick,
            cur_day: 0,
            seq: 0,
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    /// Schedule `ev` at absolute time `at` (seconds; clamped to ≥ 0).
    /// Equal deadlines fire in schedule order.
    pub fn schedule(&mut self, at: f64, ev: T) {
        let t = if at.is_finite() { at.max(0.0) } else { 0.0 };
        let day = ((t / self.tick) as u64).max(self.cur_day);
        let bits = t.to_bits();
        let seq = self.seq;
        self.seq += 1;
        let slot = (day & self.mask) as usize;
        self.slots[slot].push(Reverse(Entry { day, bits, seq, ev }));
        self.len += 1;
    }

    /// Bucket holding the global minimum entry, by `(bits, seq)`.
    fn best_slot(&self) -> Option<usize> {
        let mut best: Option<(u64, u64, usize)> = None;
        for (i, h) in self.slots.iter().enumerate() {
            if let Some(Reverse(e)) = h.peek() {
                let key = (e.bits, e.seq, i);
                if best.map_or(true, |b| key < b) {
                    best = Some(key);
                }
            }
        }
        best.map(|(_, _, i)| i)
    }

    /// Earliest pending deadline, if any.
    pub fn next_deadline(&self) -> Option<f64> {
        self.best_slot().map(|i| {
            // lint:allow(panic-path): best_slot only returns non-empty buckets
            let Reverse(e) = self.slots[i].peek().expect("non-empty slot");
            f64::from_bits(e.bits)
        })
    }

    /// Pop the earliest event if its deadline is ≤ `now`. Call in a loop
    /// to drain everything due.
    pub fn pop_due(&mut self, now: f64) -> Option<T> {
        let i = self.best_slot()?;
        {
            // lint:allow(panic-path): best_slot only returns non-empty buckets
            let Reverse(e) = self.slots[i].peek().expect("non-empty slot");
            if f64::from_bits(e.bits) > now {
                return None;
            }
        }
        // lint:allow(panic-path): peek above proved the bucket non-empty
        let Reverse(e) = self.slots[i].pop().expect("non-empty slot");
        self.cur_day = self.cur_day.max(e.day);
        self.len -= 1;
        Some(e.ev)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn drain_all(w: &mut TimerWheel<u32>) -> Vec<u32> {
        let mut out = Vec::new();
        while let Some(ev) = w.pop_due(f64::INFINITY) {
            out.push(ev);
        }
        out
    }

    #[test]
    fn pops_in_time_order_across_buckets() {
        let mut w = TimerWheel::new(0.001, 8);
        w.schedule(0.030, 3);
        w.schedule(0.001, 1);
        w.schedule(5.0, 4);
        w.schedule(0.0205, 2);
        assert_eq!(drain_all(&mut w), vec![1, 2, 3, 4]);
    }

    #[test]
    fn equal_deadlines_fire_in_schedule_order() {
        let mut w = TimerWheel::new(0.001, 8);
        for i in 0..20 {
            w.schedule(0.5, i);
        }
        assert_eq!(drain_all(&mut w), (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn pop_due_respects_now() {
        let mut w = TimerWheel::new(0.001, 8);
        w.schedule(0.010, 1);
        w.schedule(0.020, 2);
        assert_eq!(w.pop_due(0.005), None);
        assert_eq!(w.next_deadline(), Some(0.010));
        assert_eq!(w.pop_due(0.010), Some(1));
        assert_eq!(w.pop_due(0.010), None);
        assert_eq!(w.pop_due(0.025), Some(2));
        assert_eq!(w.len(), 0);
        assert_eq!(w.next_deadline(), None);
    }

    #[test]
    fn past_deadline_after_cursor_advance_still_found_in_order() {
        let mut w = TimerWheel::new(0.001, 4);
        w.schedule(0.100, 1);
        assert_eq!(w.pop_due(1.0), Some(1));
        // cursor now sits at day 100; a past-time entry must clamp into a
        // reachable bucket and pop before later deadlines
        w.schedule(0.050, 2);
        w.schedule(0.200, 3);
        assert_eq!(drain_all(&mut w), vec![2, 3]);
    }

    /// Suspend/resume ordering determinism under a seeded schedule: the
    /// pop sequence equals the reference sort by (time, insertion seq)
    /// and is identical across wheels with different bucket geometry.
    #[test]
    fn seeded_schedule_is_deterministic_and_geometry_independent() {
        let mut rng = Rng::stream(7, 0xABC);
        let times: Vec<f64> = (0..500)
            // quantized so ties actually occur
            .map(|_| (rng.f64() * 50.0).floor() * 0.01)
            .collect();
        let mut a = TimerWheel::new(0.001, 8);
        let mut b = TimerWheel::new(0.05, 64);
        for (i, &t) in times.iter().enumerate() {
            a.schedule(t, i as u32);
            b.schedule(t, i as u32);
        }
        let got_a = drain_all(&mut a);
        let got_b = drain_all(&mut b);
        let mut want: Vec<(u64, usize)> = times
            .iter()
            .enumerate()
            .map(|(i, t)| (t.to_bits(), i))
            .collect();
        want.sort();
        let want: Vec<u32> = want.into_iter().map(|(_, i)| i as u32).collect();
        assert_eq!(got_a, want);
        assert_eq!(got_b, want);
    }

    #[test]
    fn interleaved_schedule_and_pop_keeps_order() {
        let mut w = TimerWheel::new(0.01, 8);
        w.schedule(0.02, 1);
        w.schedule(0.08, 4);
        assert_eq!(w.pop_due(0.03), Some(1));
        w.schedule(0.04, 2);
        w.schedule(0.06, 3);
        assert_eq!(drain_all(&mut w), vec![2, 3, 4]);
    }
}
