//! Bounded per-actor mailboxes with an explicit overflow policy.
//!
//! The thread-per-node engine used unbounded `mpsc` channels: the only
//! queueing limit was the *implicit* one-slot at-most-one-unacked packet
//! the [`faults`](crate::faults) layer enforces per (link, channel). The
//! actor engine makes receiver-side queueing explicit — every actor owns
//! one bounded mailbox, and what happens when it fills is a configured
//! [`OverflowPolicy`], not a side effect (DESIGN.md §15):
//!
//! | policy        | full mailbox on a data push                        |
//! |---------------|----------------------------------------------------|
//! | `Backpressure`| reject: the sender sees the same `on_send_failed`  |
//! |               | path as a busy link; nothing is queued (default)   |
//! | `DropNewest`  | discard the incoming message                       |
//! | `DropOldest`  | evict the oldest queued *data* message, queue new  |
//!
//! Capacity counts **data** envelopes only. Control traffic (acks)
//! always enters: dropping an ack would wedge its (link, channel) slot
//! forever — the `no_stuck` fuzz oracle exists to catch exactly that
//! class of bug, so the bypass is load-bearing, not a convenience.
//!
//! The queue is a plain `Mutex<VecDeque>` rather than a lock-free ring:
//! pushes come from remote workers, drains from the owner, and both are
//! short critical sections with no blocking calls inside (the §14
//! `lock-across-blocking` lint checks that). The mutex also gives the
//! release/acquire edge the actor state machine's lost-wakeup protocol
//! relies on (see [`super::pool`]).

use crate::algo::Msg;
use std::collections::VecDeque;
use std::sync::Mutex;

/// A message in flight between actors: data payloads take the fault
/// layer's verdict/latency path; acks are control traffic that frees the
/// sender's (link, channel) slot and bypasses mailbox capacity.
pub(crate) enum Envelope {
    Data(Msg),
    Ack { from: usize, chan: usize },
}

/// What a full mailbox does with the next data message.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OverflowPolicy {
    /// Reject the push; the sender handles it like a busy link
    /// (`msgs_backpressured` + `on_send_failed`). The default.
    Backpressure,
    /// Discard the incoming message (`msgs_dropped`).
    DropNewest,
    /// Evict the oldest queued data message, then accept the new one
    /// (`msgs_dropped`).
    DropOldest,
}

impl OverflowPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            OverflowPolicy::Backpressure => "backpressure",
            OverflowPolicy::DropNewest => "drop-newest",
            OverflowPolicy::DropOldest => "drop-oldest",
        }
    }

    pub fn from_name(s: &str) -> Option<OverflowPolicy> {
        match s {
            "backpressure" => Some(OverflowPolicy::Backpressure),
            "drop-newest" => Some(OverflowPolicy::DropNewest),
            "drop-oldest" => Some(OverflowPolicy::DropOldest),
            _ => None,
        }
    }
}

/// Mailbox knobs carried by `Engine::Threaded` (and the CLI's
/// `--mailbox CAP[:POLICY]`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MailboxCfg {
    /// Maximum queued data envelopes per actor (≥ 1; acks are exempt).
    pub capacity: usize,
    pub policy: OverflowPolicy,
}

impl Default for MailboxCfg {
    /// Deep enough that well-behaved runs never overflow — the old
    /// unbounded-channel behavior is preserved by default; the bound is a
    /// safety net plus an experiment knob, not a new failure mode.
    fn default() -> MailboxCfg {
        MailboxCfg { capacity: 1024, policy: OverflowPolicy::Backpressure }
    }
}

impl MailboxCfg {
    /// Parse `CAP` or `CAP:POLICY` (policy one of `backpressure`,
    /// `drop-newest`, `drop-oldest`), e.g. `64:drop-oldest`.
    pub fn parse(s: &str) -> Result<MailboxCfg, String> {
        let (cap_s, pol_s) = match s.split_once(':') {
            Some((c, p)) => (c, Some(p)),
            None => (s, None),
        };
        let capacity: usize = cap_s
            .parse()
            .map_err(|_| format!("invalid mailbox capacity {cap_s:?}"))?;
        if capacity == 0 {
            return Err("mailbox capacity must be >= 1".to_string());
        }
        let policy = match pol_s {
            None => OverflowPolicy::Backpressure,
            Some(p) => OverflowPolicy::from_name(p).ok_or_else(|| {
                format!(
                    "unknown overflow policy {p:?} (want backpressure | \
                     drop-newest | drop-oldest)"
                )
            })?,
        };
        Ok(MailboxCfg { capacity, policy })
    }
}

/// Outcome of a data push; drop/reject variants return the affected
/// envelope so the scheduler can count it and release its link channel.
pub(crate) enum PushOutcome {
    Accepted,
    /// Policy `Backpressure`: the incoming message comes back.
    Rejected(Msg),
    /// Policy `DropNewest`: the incoming message comes back, discarded.
    DroppedNewest(Msg),
    /// Policy `DropOldest`: the evicted oldest data message.
    DroppedOldest(Msg),
}

struct Queue {
    q: VecDeque<Envelope>,
    /// Data envelopes currently queued (capacity counts only these).
    data_len: usize,
}

pub(crate) struct Mailbox {
    mail: Mutex<Queue>,
    capacity: usize,
    policy: OverflowPolicy,
}

impl Mailbox {
    pub fn new(cfg: MailboxCfg) -> Mailbox {
        Mailbox {
            mail: Mutex::new(Queue { q: VecDeque::new(), data_len: 0 }),
            capacity: cfg.capacity.max(1),
            policy: cfg.policy,
        }
    }

    /// Push a data message under the capacity/overflow policy.
    pub fn push_data(&self, m: Msg) -> PushOutcome {
        // lint:allow(panic-path): mailbox poisoning means a worker already panicked
        let mut g = self.mail.lock().unwrap();
        if g.data_len < self.capacity {
            g.data_len += 1;
            g.q.push_back(Envelope::Data(m));
            return PushOutcome::Accepted;
        }
        match self.policy {
            OverflowPolicy::Backpressure => PushOutcome::Rejected(m),
            OverflowPolicy::DropNewest => PushOutcome::DroppedNewest(m),
            OverflowPolicy::DropOldest => {
                let pos = g
                    .q
                    .iter()
                    .position(|e| matches!(e, Envelope::Data(_)));
                // capacity ≥ 1 and data_len == capacity ⇒ a data envelope
                // exists; fall back to accepting if it somehow doesn't
                match pos.and_then(|p| g.q.remove(p)) {
                    Some(Envelope::Data(old)) => {
                        g.q.push_back(Envelope::Data(m));
                        PushOutcome::DroppedOldest(old)
                    }
                    _ => {
                        g.data_len += 1;
                        g.q.push_back(Envelope::Data(m));
                        PushOutcome::Accepted
                    }
                }
            }
        }
    }

    /// Push control traffic (acks): always accepted, never counted
    /// against capacity.
    pub fn push_control(&self, env: Envelope) {
        // lint:allow(panic-path): mailbox poisoning means a worker already panicked
        let mut g = self.mail.lock().unwrap();
        g.q.push_back(env);
    }

    /// Move every queued envelope into `into` (owner-side drain).
    pub fn drain_into(&self, into: &mut Vec<Envelope>) {
        // lint:allow(panic-path): mailbox poisoning means a worker already panicked
        let mut g = self.mail.lock().unwrap();
        g.data_len = 0;
        into.extend(g.q.drain(..));
    }

    pub fn is_empty(&self) -> bool {
        // lint:allow(panic-path): mailbox poisoning means a worker already panicked
        self.mail.lock().unwrap().q.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::MsgKind;

    fn msg(from: usize, stamp: u64) -> Msg {
        Msg::new(from, 0, MsgKind::V, stamp, vec![0.0; 2])
    }

    fn stamps(mb: &Mailbox) -> Vec<u64> {
        let mut envs = Vec::new();
        mb.drain_into(&mut envs);
        envs.iter()
            .filter_map(|e| match e {
                Envelope::Data(m) => Some(m.stamp),
                Envelope::Ack { .. } => None,
            })
            .collect()
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let mb = Mailbox::new(MailboxCfg {
            capacity: 2,
            policy: OverflowPolicy::Backpressure,
        });
        assert!(matches!(mb.push_data(msg(1, 0)), PushOutcome::Accepted));
        assert!(matches!(mb.push_data(msg(1, 1)), PushOutcome::Accepted));
        match mb.push_data(msg(1, 2)) {
            PushOutcome::Rejected(m) => assert_eq!(m.stamp, 2),
            _ => panic!("expected rejection"),
        }
        assert_eq!(stamps(&mb), vec![0, 1]);
    }

    #[test]
    fn drop_newest_discards_incoming() {
        let mb = Mailbox::new(MailboxCfg {
            capacity: 2,
            policy: OverflowPolicy::DropNewest,
        });
        mb.push_data(msg(1, 0));
        mb.push_data(msg(1, 1));
        match mb.push_data(msg(1, 2)) {
            PushOutcome::DroppedNewest(m) => assert_eq!(m.stamp, 2),
            _ => panic!("expected drop-newest"),
        }
        assert_eq!(stamps(&mb), vec![0, 1]);
    }

    #[test]
    fn drop_oldest_evicts_head_and_queues_new() {
        let mb = Mailbox::new(MailboxCfg {
            capacity: 2,
            policy: OverflowPolicy::DropOldest,
        });
        mb.push_data(msg(1, 0));
        mb.push_data(msg(1, 1));
        match mb.push_data(msg(1, 2)) {
            PushOutcome::DroppedOldest(m) => assert_eq!(m.stamp, 0),
            _ => panic!("expected drop-oldest"),
        }
        assert_eq!(stamps(&mb), vec![1, 2]);
    }

    #[test]
    fn acks_bypass_capacity_and_survive_drop_oldest() {
        let mb = Mailbox::new(MailboxCfg {
            capacity: 1,
            policy: OverflowPolicy::DropOldest,
        });
        mb.push_data(msg(1, 0));
        mb.push_control(Envelope::Ack { from: 3, chan: 1 });
        // full of data: evicts stamp 0, never the ack
        match mb.push_data(msg(1, 1)) {
            PushOutcome::DroppedOldest(m) => assert_eq!(m.stamp, 0),
            _ => panic!("expected drop-oldest"),
        }
        let mut envs = Vec::new();
        mb.drain_into(&mut envs);
        assert_eq!(envs.len(), 2);
        assert!(matches!(envs[0], Envelope::Ack { from: 3, chan: 1 }));
        assert!(matches!(&envs[1], Envelope::Data(m) if m.stamp == 1));
    }

    #[test]
    fn drain_resets_capacity_accounting() {
        let mb = Mailbox::new(MailboxCfg {
            capacity: 1,
            policy: OverflowPolicy::Backpressure,
        });
        mb.push_data(msg(1, 0));
        assert!(matches!(mb.push_data(msg(1, 1)), PushOutcome::Rejected(_)));
        let mut envs = Vec::new();
        mb.drain_into(&mut envs);
        assert!(mb.is_empty());
        assert!(matches!(mb.push_data(msg(1, 2)), PushOutcome::Accepted));
    }

    #[test]
    fn cfg_parse_roundtrips() {
        assert_eq!(
            MailboxCfg::parse("64").unwrap(),
            MailboxCfg { capacity: 64, policy: OverflowPolicy::Backpressure }
        );
        assert_eq!(
            MailboxCfg::parse("8:drop-oldest").unwrap(),
            MailboxCfg { capacity: 8, policy: OverflowPolicy::DropOldest }
        );
        assert_eq!(
            MailboxCfg::parse("16:drop-newest").unwrap().policy.name(),
            "drop-newest"
        );
        assert!(MailboxCfg::parse("0").is_err());
        assert!(MailboxCfg::parse("x").is_err());
        assert!(MailboxCfg::parse("4:teleport").is_err());
    }
}
