//! M:N scheduling substrate: actor state machine + worker run queues.
//!
//! M node actors are multiplexed over N OS worker threads. Actors are
//! **statically pinned**: actor `i` belongs to worker `i % N`, its
//! mutable body (algorithm state, oracle, RNG) is owned by that worker's
//! stack and never crosses threads — which is what lets PJRT oracles
//! (deliberately `!Send`, `Rc`-based) run under the pool exactly as they
//! did under thread-per-node, and keeps every per-actor hot structure
//! lock-free. Cross-thread surface is exactly three things (DESIGN.md
//! §15): the bounded [`Mailbox`](super::mailbox), the actor's atomic
//! scheduling state, and the owner's run queue + condvar.
//!
//! ## Scheduling states and the lost-wakeup protocol
//!
//! ```text
//!          pop (owner)                    mail push / timer fire
//! QUEUED ─────────────▶ RUNNING          (CAS by any thread)
//!    ▲                     │ end of slice      ▲
//!    │                     ├──▶ QUEUED (yield: still ready)
//!    │                     ├──▶ PACED  (timer-armed suspend; mail does
//!    │                     │           NOT wake — pacing is the old
//!    │                     │           engine's uninterruptible sleep)
//!    │                     └──▶ WAITING (blocked on mail; mail or a
//!    │                               churn-resume timer re-queues)
//!    └── every enqueue is gated by a successful CAS *→QUEUED, so an
//!        actor is never in a run queue twice
//! ```
//!
//! Lost wakeups are closed Dekker-style: a sender pushes the envelope
//! (mailbox mutex, release on unlock) *then* tries `WAITING→QUEUED`; the
//! owner stores `WAITING` *then* re-checks the mailbox (mutex acquire)
//! and re-queues itself if non-empty. Whichever CAS succeeds enqueues —
//! exactly one of them can.
//!
//! ## Lock order (§14 lint notes)
//!
//! Declared locks in this engine: `mail` (per-actor mailbox queue),
//! `runq` (per-worker run queue), plus the coordinator-facing `snapshots`
//! / `train_loss` slots in [`super::Shared`]. No function holds one while
//! acquiring another — every acquisition lives in its own helper whose
//! guard dies before the next lock — so the cross-file acquisition graph
//! stays edge-free. Workers park on `cv.wait_timeout` under the `runq`
//! guard only (the condvar releases it atomically while parked, and
//! nothing else blocks under a guard).

use super::mailbox::{Envelope, Mailbox, MailboxCfg};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// In a run queue (or about to be), will be executed.
pub(crate) const QUEUED: u8 = 0;
/// A worker is executing its slice.
pub(crate) const RUNNING: u8 = 1;
/// Timer-armed suspend (pacing / straggler / send delay); mail does not
/// wake it.
pub(crate) const PACED: u8 = 2;
/// Blocked on mail (or a churn pause); mail and timers wake it.
pub(crate) const WAITING: u8 = 3;

/// The cross-thread half of one actor. The mutable body lives on the
/// owning worker's stack (see [`super::actor::ActorBody`]).
pub(crate) struct ActorShared {
    state: AtomicU8,
    pub mailbox: Mailbox,
}

impl ActorShared {
    fn new(mailbox: MailboxCfg) -> ActorShared {
        ActorShared {
            state: AtomicU8::new(QUEUED),
            mailbox: Mailbox::new(mailbox),
        }
    }

    /// Mail arrived: wake only out of WAITING (PACED suspends through
    /// mail by design; QUEUED/RUNNING will drain it anyway).
    pub fn try_queue_for_mail(&self) -> bool {
        self.state
            .compare_exchange(WAITING, QUEUED, Ordering::AcqRel,
                              Ordering::Acquire)
            .is_ok()
    }

    /// Timer fired: wake out of PACED or WAITING.
    pub fn try_queue_for_timer(&self) -> bool {
        self.state
            .compare_exchange(PACED, QUEUED, Ordering::AcqRel,
                              Ordering::Acquire)
            .is_ok()
            || self.try_queue_for_mail()
    }

    /// Owner popped this actor from its run queue.
    pub fn begin_running(&self) -> bool {
        self.state
            .compare_exchange(QUEUED, RUNNING, Ordering::AcqRel,
                              Ordering::Acquire)
            .is_ok()
    }

    /// Owner ends a slice (state is RUNNING): publish the next state.
    pub fn finish(&self, next: u8) {
        debug_assert!(next == QUEUED || next == PACED || next == WAITING);
        self.state.store(next, Ordering::Release);
    }
}

struct WorkerShared {
    runq: Mutex<VecDeque<u32>>,
    cv: Condvar,
}

/// Shared scheduling state: one entry per actor, one queue per worker.
pub(crate) struct PoolShared {
    pub actors: Vec<ActorShared>,
    workers: Vec<WorkerShared>,
}

impl PoolShared {
    pub fn new(n: usize, workers: usize, mailbox: MailboxCfg) -> PoolShared {
        debug_assert!(workers >= 1);
        PoolShared {
            actors: (0..n).map(|_| ActorShared::new(mailbox)).collect(),
            workers: (0..workers)
                .map(|_| WorkerShared {
                    runq: Mutex::new(VecDeque::new()),
                    cv: Condvar::new(),
                })
                .collect(),
        }
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Owning worker of actor `id` (static pinning).
    pub fn owner(&self, id: usize) -> usize {
        id % self.workers.len()
    }

    /// Put an already-QUEUED actor on its owner's run queue and wake the
    /// owner if parked. Callers must have won the `*→QUEUED` CAS.
    pub fn enqueue(&self, id: usize) {
        let ws = &self.workers[self.owner(id)];
        {
            // lint:allow(panic-path): runq poisoning means a worker already panicked
            let mut q = ws.runq.lock().unwrap();
            q.push_back(id as u32);
        }
        ws.cv.notify_one();
    }

    /// Mail was pushed to `id`'s mailbox: re-queue it if it was WAITING.
    pub fn wake_for_mail(&self, id: usize) {
        if self.actors[id].try_queue_for_mail() {
            self.enqueue(id);
        }
    }

    /// Deliver control traffic (an ack) to `dst`, bypassing capacity.
    pub fn push_control(&self, dst: usize, env: Envelope) {
        self.actors[dst].mailbox.push_control(env);
        self.wake_for_mail(dst);
    }

    /// Owner-side pop: next runnable actor for worker `w`, transitioned
    /// to RUNNING.
    pub fn pop_runnable(&self, w: usize) -> Option<usize> {
        loop {
            let id = {
                // lint:allow(panic-path): runq poisoning means a worker already panicked
                let mut q = self.workers[w].runq.lock().unwrap();
                q.pop_front()
            }?;
            // the CAS gate on enqueue makes double-queueing impossible,
            // so this only fails if an invariant broke; skip defensively
            if self.actors[id as usize].begin_running() {
                return Some(id as usize);
            }
            debug_assert!(false, "popped actor {id} not QUEUED");
        }
    }

    /// Park worker `w` for at most `dur` (bounded so the stop flag is
    /// re-checked promptly even with no timers pending). Returns early if
    /// work was enqueued before or during the wait.
    pub fn park(&self, w: usize, dur: Duration) {
        let ws = &self.workers[w];
        // lint:allow(panic-path): runq poisoning means a worker already panicked
        let q = ws.runq.lock().unwrap();
        if q.is_empty() {
            // condvar wait releases the runq guard atomically while
            // parked; nothing blocks while it is held
            // lint:allow(panic-path): runq poisoning means a worker already panicked
            let _ = ws.cv.wait_timeout(q, dur).unwrap();
        }
    }

    /// Wake every worker (stop-flag broadcast).
    pub fn notify_all(&self) {
        for ws in &self.workers {
            ws.cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mail_wakes_waiting_but_not_paced() {
        let pool = PoolShared::new(2, 1, MailboxCfg::default());
        let a = &pool.actors[0];
        assert!(a.begin_running());
        a.finish(WAITING);
        assert!(a.try_queue_for_mail(), "mail must wake WAITING");
        assert!(a.begin_running());
        a.finish(PACED);
        assert!(!a.try_queue_for_mail(), "mail must not wake PACED");
        assert!(a.try_queue_for_timer(), "timer must wake PACED");
    }

    #[test]
    fn cas_gate_prevents_double_queueing() {
        let pool = PoolShared::new(1, 1, MailboxCfg::default());
        let a = &pool.actors[0];
        assert!(a.begin_running());
        a.finish(WAITING);
        assert!(a.try_queue_for_mail());
        // second waker loses the race: no second enqueue
        assert!(!a.try_queue_for_mail());
        assert!(!a.try_queue_for_timer());
    }

    #[test]
    fn pop_runnable_drains_fifo() {
        let pool = PoolShared::new(3, 1, MailboxCfg::default());
        // actors start QUEUED; emulate the initial seeding
        pool.enqueue(0);
        pool.enqueue(1);
        pool.enqueue(2);
        assert_eq!(pool.pop_runnable(0), Some(0));
        assert_eq!(pool.pop_runnable(0), Some(1));
        assert_eq!(pool.pop_runnable(0), Some(2));
        assert_eq!(pool.pop_runnable(0), None);
    }

    #[test]
    fn ownership_is_modular() {
        let pool = PoolShared::new(10, 4, MailboxCfg::default());
        assert_eq!(pool.owner(0), 0);
        assert_eq!(pool.owner(5), 1);
        assert_eq!(pool.owner(7), 3);
        assert_eq!(pool.n_workers(), 4);
    }
}
