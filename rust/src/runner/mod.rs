//! Real asynchronous runtime: M node actors over N worker threads.
//!
//! This is the wall-clock counterpart of [`crate::sim`] and mirrors the
//! paper's implementation ("each process runs its own code independently
//! and messages are transmitted in a fully-asynchronous way without any
//! blocking", §VI ¶1). Since PR 10 it is an **actor scheduler**, not a
//! thread-per-node farm: each node is a suspendable actor with a bounded
//! [`mailbox`] (explicit [`OverflowPolicy`] instead of the old implicit
//! one-slot `LinkSlots` side effect), executed by a pool of N OS threads
//! multiplexing M ≫ N runnable actors — which is what lets a 512-node
//! straggler scenario run on a 4-thread pool (DESIGN.md §15):
//!
//! * actor slice: drain mailbox → if `ready`, run one local iteration
//!   (for PJRT oracles the gradient is a real XLA execution on the
//!   owning worker — actors are pinned, so `!Send` oracles never move) →
//!   send messages; payloads are shared
//!   ([`Payload`](crate::algo::Payload) is an `Arc`), so a cross-actor
//!   push moves a pointer-sized handle (DESIGN.md §8);
//! * links: the shared [`faults`](crate::faults) layer over the
//!   topology's sparse [`LinkIndex`](crate::faults::LinkIndex) —
//!   sender-side Bernoulli drop + at-most-one-unacked-packet per (link,
//!   channel), O(edges) state even at 10⁵ nodes;
//! * **no `thread::sleep` on the actor path**: pacing floors, straggler
//!   factors, injected latency, bandwidth serialization and churn-resume
//!   polls are all [`timer`] wheel suspend/resume entries — a suspended
//!   actor costs its worker nothing;
//! * the coordinator thread snapshots per-node parameters, evaluates the
//!   mean model periodically, applies the epoch-indexed γ-decay schedule,
//!   and stops everyone at the deadline.
//!
//! Declarative [`Scenario`](crate::scenario::Scenario)s drive this engine
//! through the same four hooks as the simulator, with virtual seconds
//! read as wall seconds since the run started:
//!
//! * **straggler schedules** scale the per-iteration pacing factor;
//! * **churn windows** stop a node from starting new iterations (it keeps
//!   receiving — a stalled worker, not a crash);
//! * **loss ramps** set the sender-side drop probability;
//! * **latency ramps and bandwidth caps** delay *delivery*: the injected
//!   excess latency and the FIFO serialization delay advance the sender's
//!   virtual send cursor, the message arrives that much later through the
//!   timer wheel, and the sender actor stays suspended until its cursor —
//!   so a capped link still genuinely bounds throughput, without holding
//!   an OS thread hostage.

pub mod mailbox;
pub(crate) mod actor;
pub(crate) mod pool;
pub(crate) mod timer;

pub use mailbox::{MailboxCfg, OverflowPolicy};

use crate::algo::AlgoKind;
use crate::config::SimConfig;
use crate::exp::Stop;
use crate::faults::{BwPacer, Clock, FaultSpec, LinkIndex, RunnerFaultLayer,
                    WallClock};
use crate::graph::Topology;
use crate::metrics::Report;
use crate::oracle::{Eval, OracleFactory};
use actor::{run_slice, ActorBody, TimerEvent};
use pool::PoolShared;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};
use timer::TimerWheel;

/// Timer-wheel bucket width. Purely a bucketing choice — expiry order is
/// exact regardless (see [`timer`]) — sized so pacing-scale deadlines
/// (tens of µs to ms) land in nearby buckets.
const WHEEL_TICK: f64 = 0.001;
/// Timer-wheel bucket count per worker.
const WHEEL_SLOTS: usize = 64;
/// Longest a worker parks before re-checking the stop flag when it has
/// no nearer timer deadline.
const MAX_PARK: f64 = 0.025;

/// Wall-clock stopping criteria (legacy runner-only spelling).
///
/// Superseded by the engine-agnostic [`Stop`](crate::exp::Stop):
/// `ThreadedRunner::run` takes `impl Into<Stop>`, so existing `RunUntil`
/// call sites keep compiling through the `From` conversion below. The
/// unified enum also adds `Stop::Epochs` on this engine (the coordinator
/// maps total steps × `OracleFactory::epoch_per_node_batch` to epochs).
#[deprecated(note = "use exp::Stop (Stop::Time is wall seconds on the \
                     threaded runner)")]
#[derive(Clone, Copy, Debug)]
pub enum RunUntil {
    WallSeconds(f64),
    /// Stop when the mean-model eval loss reaches `loss`, or at the
    /// deadline.
    TargetLoss { loss: f64, max_seconds: f64 },
    /// Stop when total gradient steps across nodes reach this count.
    TotalSteps(u64),
}

#[allow(deprecated)]
impl From<RunUntil> for Stop {
    fn from(u: RunUntil) -> Stop {
        match u {
            RunUntil::WallSeconds(s) => Stop::Time(s),
            RunUntil::TargetLoss { loss, max_seconds } => {
                Stop::TargetLoss { loss, max_time: max_seconds }
            }
            RunUntil::TotalSteps(k) => Stop::Iterations(k),
        }
    }
}

/// Final counters for the run.
#[derive(Clone, Debug, Default)]
pub struct RunnerStats {
    pub wall_seconds: f64,
    pub steps_per_node: Vec<u64>,
    pub msgs_sent: u64,
    pub msgs_lost: u64,
    pub msgs_backpressured: u64,
    /// Messages whose delivery was delayed by a scenario latency ramp or
    /// bandwidth cap (the sender actor suspended through the timer wheel
    /// instead of sleeping).
    pub msgs_paced: u64,
    /// Messages discarded by a full mailbox under a `DropNewest` /
    /// `DropOldest` overflow policy (zero under the default
    /// `Backpressure`).
    pub msgs_dropped: u64,
    /// Payload bytes actually sent (Deliver verdicts only) — the logical
    /// communication volume; shared payloads are charged by length, not
    /// by the pointer-sized handle that crosses the mailbox.
    pub bytes_sent: u64,
    /// Worker threads the actor pool actually ran on.
    pub workers: usize,
}

pub(crate) struct Shared {
    pub stop: AtomicBool,
    /// shared fault/link layer: wall clock + atomic per-(link, channel)
    /// in-flight flags + scalar/scenario fault queries, sparse-addressed
    /// over the topology's links
    pub faults: RunnerFaultLayer,
    // Report-counter ordering contract (DESIGN.md §14, `relaxed-counter`):
    // every counter below feeds RunnerStats/report scalars, so writers
    // use AcqRel RMWs and readers Acquire loads — a coordinator-side read
    // then observes everything the worker published before bumping the
    // counter. `gamma_bits` and `stop` are single-value signals, not
    // counters; Relaxed remains sound for them.
    pub total_steps: AtomicU64,
    pub msgs_sent: AtomicU64,
    pub msgs_lost: AtomicU64,
    pub msgs_backpressured: AtomicU64,
    pub msgs_paced: AtomicU64,
    pub msgs_dropped: AtomicU64,
    pub bytes_sent: AtomicU64,
    /// current step size as f32 bits; the coordinator writes decays, the
    /// workers pick them up at the top of each slice
    pub gamma_bits: AtomicU32,
    /// per-node rolling (sum, count) of minibatch losses between eval
    /// ticks — per-node so the hot training loop never contends on a
    /// shared lock (same pattern as `steps`/`snapshots`)
    pub train_loss: Vec<Mutex<(f64, u64)>>,
    /// latest parameter snapshot per node (written post-wake)
    pub snapshots: Vec<Mutex<Vec<f32>>>,
    pub steps: Vec<AtomicU64>,
}

/// Actor-pool engine. Generic over the oracle factory so the same runner
/// drives quadratics (tests), rust logreg, and PJRT models.
pub struct ThreadedRunner {
    cfg: SimConfig,
    algo: AlgoKind,
    topo: Topology,
    x0: Vec<f32>,
    pace: Option<f64>,
    workers: Option<usize>,
    mailbox: MailboxCfg,
}

impl ThreadedRunner {
    pub fn new(cfg: SimConfig, topo: &Topology, algo: AlgoKind,
               x0: Vec<f32>) -> ThreadedRunner {
        // lint:allow(panic-path): engine-level constructor fails fast; Experiment pre-validates into typed errors
        cfg.validate().expect("invalid SimConfig");
        if let Some(sc) = &cfg.scenario {
            // bound-check node indices against this topology, like the
            // simulator does
            sc.validate(Some(topo.n()))
                // lint:allow(panic-path): engine-level constructor fails fast; Experiment pre-validates into typed errors
                .expect("invalid scenario for this topology");
        }
        ThreadedRunner {
            cfg,
            algo,
            topo: topo.clone(),
            x0,
            pace: None,
            workers: None,
            mailbox: MailboxCfg::default(),
        }
    }

    /// Enforce a minimum per-iteration duration. Needed when the oracle is
    /// much faster than the links (e.g. closed-form quadratics): without a
    /// pace, nodes run thousands of local iterations per delivered message,
    /// i.e. the effective delay bound D of Assumption 3 explodes and the
    /// fixed step size is no longer stable. Real model oracles (PJRT) are
    /// naturally paced by their compute.
    pub fn with_pace(mut self, seconds: f64) -> ThreadedRunner {
        self.pace = Some(seconds);
        self
    }

    /// Size of the worker pool (clamped to `[1, n]`). Default: one worker
    /// per available core, at most one per node.
    pub fn with_workers(mut self, workers: usize) -> ThreadedRunner {
        self.workers = Some(workers);
        self
    }

    /// Per-actor mailbox capacity and overflow policy.
    pub fn with_mailbox(mut self, mailbox: MailboxCfg) -> ThreadedRunner {
        self.mailbox = mailbox;
        self
    }

    fn resolve_workers(&self, n: usize) -> usize {
        let requested = self.workers.unwrap_or_else(|| {
            std::thread::available_parallelism().map_or(4, |c| c.get())
        });
        requested.clamp(1, n.max(1))
    }

    /// Run to completion; `eval` is called on the coordinator thread with
    /// the mean parameter snapshot every `cfg.eval_every` *wall* seconds.
    ///
    /// Takes the engine-agnostic [`Stop`]; `Stop::Time` means *wall*
    /// seconds here, `Stop::Iterations` counts total gradient steps
    /// across nodes, and `Stop::Epochs` uses the factory's epoch mapping.
    /// Legacy [`RunUntil`] values convert transparently.
    pub fn run(
        &self,
        factory: &dyn OracleFactory,
        eval: &mut dyn FnMut(&[f32]) -> Eval,
        until: impl Into<Stop>,
    ) -> (Report, RunnerStats) {
        let until: Stop = until.into();
        let n = self.topo.n();
        let p = self.x0.len();
        assert_eq!(factory.dim(), p, "factory dim vs x0");
        let nodes = self.algo.build(&self.topo, &self.x0, self.cfg.gamma,
                                    self.cfg.seed);
        let workers = self.resolve_workers(n);

        let links = LinkIndex::from_weights(&self.topo.weights);
        let shared = Arc::new(Shared {
            stop: AtomicBool::new(false),
            faults: RunnerFaultLayer::with_links(
                links,
                WallClock::start_now(),
                FaultSpec::from_config(&self.cfg),
            ),
            total_steps: AtomicU64::new(0),
            msgs_sent: AtomicU64::new(0),
            msgs_lost: AtomicU64::new(0),
            msgs_backpressured: AtomicU64::new(0),
            msgs_paced: AtomicU64::new(0),
            msgs_dropped: AtomicU64::new(0),
            bytes_sent: AtomicU64::new(0),
            gamma_bits: AtomicU32::new(self.cfg.gamma.to_bits()),
            train_loss: (0..n).map(|_| Mutex::new((0.0, 0))).collect(),
            snapshots: (0..n).map(|_| Mutex::new(self.x0.clone())).collect(),
            steps: (0..n).map(|_| AtomicU64::new(0)).collect(),
        });
        let pool = PoolShared::new(n, workers, self.mailbox);

        // actor bodies, sharded by owning worker (actor i → worker i % N)
        let mut shards: Vec<Vec<ActorBody>> =
            (0..workers).map(|_| Vec::new()).collect();
        for (i, node) in nodes.into_iter().enumerate() {
            shards[i % workers].push(ActorBody::new(i, node, self.cfg.seed));
        }

        let start = Instant::now();
        let epoch_per_batch = factory.epoch_per_node_batch();
        let mut report = Report::new(self.algo.name());
        let mut mean = vec![0.0f32; p];
        let lossy = self.algo.tolerates_loss();
        let pace = self.pace;
        std::thread::scope(|scope| {
            for (w, bodies) in shards.into_iter().enumerate() {
                let pool = &pool;
                let shared_w = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("rfast-worker-{w}"))
                    .spawn_scoped(scope, move || {
                        worker_main(w, bodies, pool, shared_w, factory,
                                    lossy, pace);
                    })
                    // lint:allow(panic-path): thread spawn failure is unrecoverable resource exhaustion
                    .expect("spawn worker");
            }

            // coordinator loop: evaluate + γ-decay + check stop condition
            let eval_every =
                Duration::from_secs_f64(self.cfg.eval_every.max(0.05));
            let mut decay_steps: u32 = 0;
            loop {
                std::thread::sleep(eval_every);
                let elapsed = start.elapsed().as_secs_f64();
                self.snapshot_mean(&shared, &mut mean);
                let e = eval(&mean);
                report
                    .series_mut("loss_vs_wall", "wall_seconds", "eval_loss")
                    .push(elapsed, e.loss);
                if let Some(acc) = e.accuracy {
                    report
                        .series_mut("acc_vs_wall", "wall_seconds", "accuracy")
                        .push(elapsed, acc);
                }
                let total = shared.total_steps.load(Ordering::Acquire);
                report
                    .series_mut("steps_vs_wall", "wall_seconds", "total_steps")
                    .push(elapsed, total as f64);
                // minibatch-loss series — the runner twin of the
                // simulator's train_loss_vs_epoch, on the wall axis
                {
                    let (mut sum, mut count) = (0.0f64, 0u64);
                    for slot in &shared.train_loss {
                        // lint:allow(panic-path): lock poisoning means a worker already panicked
                        let mut acc = slot.lock().unwrap();
                        sum += acc.0;
                        count += acc.1;
                        *acc = (0.0, 0);
                    }
                    if count > 0 {
                        report
                            .series_mut("train_loss_vs_wall", "wall_seconds",
                                        "train_loss")
                            .push(elapsed, sum / count as f64);
                    }
                }
                // γ-decay: the same epoch-indexed γ·factor^k schedule the
                // simulator applies per wake, driven here by the global
                // step counter (epoch ≈ total steps × epoch-per-batch)
                if let Some((interval, factor)) = self.cfg.gamma_decay {
                    let due = (total as f64 * epoch_per_batch / interval) as u32;
                    if due > decay_steps {
                        decay_steps = due;
                        let g = self.cfg.gamma * factor.powi(due as i32);
                        shared.gamma_bits.store(g.to_bits(), Ordering::Relaxed);
                    }
                }
                let done = match until {
                    Stop::Time(s) => elapsed >= s,
                    Stop::TargetLoss { loss, max_time } => {
                        e.loss <= loss || elapsed >= max_time
                    }
                    Stop::Iterations(k) => total >= k,
                    // the coordinator's epoch mapping: total steps ×
                    // epoch-per-node-batch, same conversion the γ-decay
                    // schedule and the `epoch` scalar use
                    Stop::Epochs(target) => {
                        total as f64 * epoch_per_batch >= target
                    }
                };
                if done {
                    break;
                }
            }
            shared.stop.store(true, Ordering::SeqCst);
            pool.notify_all();
            // scope joins all workers here
        });
        let wall = start.elapsed().as_secs_f64();

        self.snapshot_mean(&shared, &mut mean);
        let e = eval(&mean);
        report
            .series_mut("loss_vs_wall", "wall_seconds", "eval_loss")
            .push(wall, e.loss);

        let stats = RunnerStats {
            wall_seconds: wall,
            steps_per_node: shared
                .steps
                .iter()
                .map(|s| s.load(Ordering::Acquire))
                .collect(),
            msgs_sent: shared.msgs_sent.load(Ordering::Acquire),
            msgs_lost: shared.msgs_lost.load(Ordering::Acquire),
            msgs_backpressured: shared.msgs_backpressured.load(Ordering::Acquire),
            msgs_paced: shared.msgs_paced.load(Ordering::Acquire),
            msgs_dropped: shared.msgs_dropped.load(Ordering::Acquire),
            bytes_sent: shared.bytes_sent.load(Ordering::Acquire),
            workers,
        };
        let total_steps = stats.steps_per_node.iter().sum::<u64>();
        report.set_scalar("wall_seconds", stats.wall_seconds);
        report.set_scalar("total_steps", total_steps as f64);
        report.set_scalar("epoch", total_steps as f64 * epoch_per_batch);
        report.set_scalar("msgs_sent", stats.msgs_sent as f64);
        report.set_scalar("msgs_lost", stats.msgs_lost as f64);
        report.set_scalar("msgs_backpressured",
                          stats.msgs_backpressured as f64);
        report.set_scalar("msgs_paced", stats.msgs_paced as f64);
        report.set_scalar("msgs_dropped", stats.msgs_dropped as f64);
        report.set_scalar("bytes_sent", stats.bytes_sent as f64);
        report.set_scalar("final_loss", e.loss);
        if let Some(acc) = e.accuracy {
            report.set_scalar("final_accuracy", acc);
        }
        (report, stats)
    }

    fn snapshot_mean(&self, shared: &Shared, mean: &mut [f32]) {
        mean.iter_mut().for_each(|v| *v = 0.0);
        for snap in &shared.snapshots {
            // lint:allow(panic-path): lock poisoning means a worker already panicked
            let guard = snap.lock().unwrap();
            crate::linalg::axpy(mean, 1.0, &guard);
        }
        crate::linalg::scale(mean, 1.0 / shared.snapshots.len() as f32);
    }
}

/// One pool worker: owns its shard of actor bodies (and builds their
/// oracles on this thread — they may be `!Send`), its timer wheel and
/// its bandwidth pacer, and loops fire-due-timers → run-one-slice →
/// park-until-deadline until the coordinator raises the stop flag.
fn worker_main(
    w: usize,
    mut bodies: Vec<ActorBody>,
    pool: &PoolShared,
    shared: Arc<Shared>,
    factory: &dyn OracleFactory,
    lossy: bool,
    pace: Option<f64>,
) {
    for b in &mut bodies {
        b.make_oracle(factory);
    }
    let workers = pool.n_workers();
    // actor id → index in this worker's shard (ids are w, w+N, w+2N, …)
    let local = |id: usize| id / workers;
    let mut wheel: TimerWheel<TimerEvent> =
        TimerWheel::new(WHEEL_TICK, WHEEL_SLOTS);
    let mut bw = BwPacer::new(shared.faults.link_count());
    // seed the run queue: every actor starts QUEUED
    for b in &bodies {
        pool.enqueue(b.id);
    }

    while !shared.stop.load(Ordering::Relaxed) {
        // fire everything due before running the next slice, so timer
        // fidelity degrades gracefully under load instead of starving
        let now = shared.faults.clock.now();
        while let Some(ev) = wheel.pop_due(now) {
            match ev {
                TimerEvent::Resume { id, gen } => {
                    if bodies[local(id)].take_resume(gen)
                        && pool.actors[id].try_queue_for_timer()
                    {
                        pool.enqueue(id);
                    }
                }
                TimerEvent::Deliver(m) => {
                    // fires on the sender's worker: its body (and its
                    // on_send_failed hook) is in reach for rejections
                    let sender = &mut bodies[local(m.from)];
                    actor::deliver(sender.node.as_mut(), pool, &shared,
                                   lossy, m);
                }
            }
        }
        if let Some(id) = pool.pop_runnable(w) {
            run_slice(&mut bodies[local(id)], &mut wheel, &mut bw, pool,
                      &shared, lossy, pace);
            continue;
        }
        // idle: park until the next timer deadline (bounded, so the stop
        // flag is re-checked even when no timers are pending)
        let dt = wheel
            .next_deadline()
            .map_or(MAX_PARK, |t| (t - now).clamp(0.0, MAX_PARK));
        if dt > 0.0 {
            pool.park(w, Duration::from_secs_f64(dt));
        }
    }
    // final snapshots
    for b in &bodies {
        // lint:allow(panic-path): lock poisoning means a sibling worker already panicked
        let mut guard = shared.snapshots[b.id].lock().unwrap();
        guard.copy_from_slice(b.node.param());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::QuadraticOracle;
    use crate::testutil::{tracking_quad_eval, QuadFactory};

    #[test]
    fn threaded_rfast_converges_on_quadratic() {
        let q = QuadraticOracle::heterogeneous(8, 4, 0.5, 2.0, 21);
        let xs = q.optimum();
        let f_star = q.global_loss(&xs);
        let topo = Topology::ring(4);
        let cfg = SimConfig {
            seed: 5,
            gamma: 0.03,
            compute_mean: 0.001,
            eval_every: 0.05,
            ..SimConfig::default()
        };
        let runner = ThreadedRunner::new(cfg, &topo, AlgoKind::RFast,
                                         vec![0.0; 8])
            .with_pace(5e-5);
        // keep the last evaluated mean so the near-optimum claim can be
        // checked in parameter space, not just through the loss
        let (mut eval, last_mean) = tracking_quad_eval(q.clone());
        let (report, stats) =
            runner.run(&QuadFactory(q), &mut eval,
                       Stop::Iterations(60_000));
        assert!(stats.steps_per_node.iter().all(|&s| s > 100),
                "{:?}", stats.steps_per_node);
        let last = report.series["loss_vs_wall"].last_y().unwrap();
        let first = report.series["loss_vs_wall"].points[0].1;
        assert!(last < first, "{first} → {last}");
        // mean model near optimum: loss within a margin of f*, iterate
        // within a ball around x*
        assert!(last < f_star + 0.5, "final loss {last} vs f* {f_star}");
        let d = crate::linalg::dist(&last_mean.lock().unwrap(), &xs);
        assert!(d < 0.5, "‖x̄ − x*‖ = {d}");
    }

    #[test]
    fn threaded_sync_allreduce_no_deadlock() {
        let q = QuadraticOracle::heterogeneous(6, 3, 0.5, 2.0, 33);
        let topo = Topology::ring(3);
        let cfg = SimConfig {
            seed: 6,
            gamma: 0.1,
            compute_mean: 0.001,
            eval_every: 0.05,
            ..SimConfig::default()
        };
        let runner = ThreadedRunner::new(cfg, &topo, AlgoKind::RingAllReduce,
                                         vec![0.0; 6]);
        let (mut eval, _) = tracking_quad_eval(q.clone());
        let (_, stats) =
            runner.run(&QuadFactory(q), &mut eval, Stop::Iterations(300));
        assert!(stats.steps_per_node.iter().sum::<u64>() >= 300);
        // lock-step: per-node counts within one round of each other
        let min = *stats.steps_per_node.iter().min().unwrap();
        let max = *stats.steps_per_node.iter().max().unwrap();
        assert!(max - min <= 2, "{:?}", stats.steps_per_node);
    }

    #[test]
    fn packet_loss_counters_active() {
        let q = QuadraticOracle::heterogeneous(4, 3, 0.5, 2.0, 41);
        let topo = Topology::ring(3);
        let mut cfg = SimConfig {
            seed: 7,
            gamma: 0.02,
            compute_mean: 0.001,
            eval_every: 0.05,
            ..SimConfig::default()
        };
        cfg.loss_prob = 0.3;
        let runner =
            ThreadedRunner::new(cfg, &topo, AlgoKind::RFast, vec![0.0; 4])
                .with_pace(1e-4);
        let (mut eval, _) = tracking_quad_eval(q.clone());
        let (_, stats) =
            runner.run(&QuadFactory(q), &mut eval, Stop::Iterations(5_000));
        assert!(stats.msgs_lost > 0);
    }

    /// M ≫ N: more actors than workers, on an explicit 2-thread pool —
    /// every node must still make progress.
    #[test]
    fn many_actors_on_small_pool_all_progress() {
        let q = QuadraticOracle::heterogeneous(8, 16, 0.5, 2.0, 55);
        let topo = Topology::ring(16);
        let cfg = SimConfig {
            seed: 9,
            gamma: 0.02,
            compute_mean: 0.001,
            eval_every: 0.05,
            ..SimConfig::default()
        };
        let runner = ThreadedRunner::new(cfg, &topo, AlgoKind::RFast,
                                         vec![0.0; 8])
            .with_pace(2e-4)
            .with_workers(2);
        let (mut eval, _) = tracking_quad_eval(q.clone());
        let (report, stats) =
            runner.run(&QuadFactory(q), &mut eval, Stop::Iterations(4_000));
        assert_eq!(stats.workers, 2);
        assert!(stats.steps_per_node.iter().all(|&s| s > 10),
                "{:?}", stats.steps_per_node);
        assert!(report.scalars.contains_key("msgs_dropped"));
    }
}
