//! Real asynchronous runtime: one OS thread per node, mailbox channels.
//!
//! This is the wall-clock counterpart of [`crate::sim`] and mirrors the
//! paper's implementation ("each process runs its own code independently
//! and messages are transmitted in a fully-asynchronous way without any
//! blocking", §VI ¶1) — with `std::thread` + `mpsc` in place of
//! process-per-GPU + torch.distributed:
//!
//! * every node thread loops: drain mailbox → if `ready`, run one local
//!   iteration (for PJRT oracles the gradient is a real XLA execution on
//!   this thread) → send messages; payloads are shared
//!   ([`Payload`](crate::algo::Payload) is an `Arc`, hence `Send`), so a
//!   cross-thread `mpsc` send moves a pointer-sized handle and a
//!   broadcast's messages all reference one allocation (DESIGN.md §8);
//! * links: the shared [`faults`](crate::faults) layer — sender-side
//!   Bernoulli drop + at-most-one-unacked-packet per (link, channel),
//!   with an atomic in-flight flag the receiver's ack clears — exactly
//!   the semantics the simulator models (loss only for loss-tolerant
//!   algorithms);
//! * a straggler is emulated by sleeping `(factor−1)×` the measured step
//!   time, exactly like the paper slows one GPU with extra load;
//! * the coordinator thread snapshots per-node parameters, evaluates the
//!   mean model periodically, applies the epoch-indexed γ-decay schedule,
//!   and stops everyone at the deadline.
//!
//! Declarative [`Scenario`](crate::scenario::Scenario)s drive this engine
//! too, through the same four hooks as the simulator, with virtual
//! seconds read as wall seconds since the run started:
//!
//! * **straggler schedules** scale the per-iteration pacing factor;
//! * **churn windows** stop a node from starting new iterations (it keeps
//!   receiving — a stalled worker, not a crash);
//! * **loss ramps** set the sender-side drop probability;
//! * **latency ramps and bandwidth caps** pace the *sending thread*: the
//!   injected excess latency and the FIFO serialization delay are slept
//!   before the channel send, so delivery genuinely arrives later and a
//!   capped link genuinely bounds throughput.

use crate::algo::{AlgoKind, Msg, NodeState};
use crate::config::SimConfig;
use crate::exp::Stop;
use crate::faults::{BwPacer, Clock, FaultSpec, RunnerFaultLayer, SendVerdict,
                    WallClock};
use crate::graph::Topology;
use crate::metrics::Report;
use crate::oracle::{Eval, OracleFactory};
use crate::prng::Rng;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Injected pacing sleeps are taken in chunks of at most this many
/// seconds, re-checking the stop flag between chunks, so a worker
/// notices a stop request promptly even under extreme scenario
/// parameters while still sleeping the *full* delay (truncating would
/// let a bandwidth-capped link transmit above its configured rate).
const MAX_PACING_SLEEP: f64 = 0.05;

/// Wall-clock stopping criteria (legacy runner-only spelling).
///
/// Superseded by the engine-agnostic [`Stop`](crate::exp::Stop):
/// `ThreadedRunner::run` takes `impl Into<Stop>`, so existing `RunUntil`
/// call sites keep compiling through the `From` conversion below. The
/// unified enum also adds `Stop::Epochs` on this engine (the coordinator
/// maps total steps × `OracleFactory::epoch_per_node_batch` to epochs).
#[deprecated(note = "use exp::Stop (Stop::Time is wall seconds on the \
                     threaded runner)")]
#[derive(Clone, Copy, Debug)]
pub enum RunUntil {
    WallSeconds(f64),
    /// Stop when the mean-model eval loss reaches `loss`, or at the
    /// deadline.
    TargetLoss { loss: f64, max_seconds: f64 },
    /// Stop when total gradient steps across nodes reach this count.
    TotalSteps(u64),
}

#[allow(deprecated)]
impl From<RunUntil> for Stop {
    fn from(u: RunUntil) -> Stop {
        match u {
            RunUntil::WallSeconds(s) => Stop::Time(s),
            RunUntil::TargetLoss { loss, max_seconds } => {
                Stop::TargetLoss { loss, max_time: max_seconds }
            }
            RunUntil::TotalSteps(k) => Stop::Iterations(k),
        }
    }
}

/// Final counters for the run.
#[derive(Clone, Debug, Default)]
pub struct RunnerStats {
    pub wall_seconds: f64,
    pub steps_per_node: Vec<u64>,
    pub msgs_sent: u64,
    pub msgs_lost: u64,
    pub msgs_backpressured: u64,
    /// Messages whose send was delayed by a scenario latency ramp or
    /// bandwidth cap (the sender thread slept before the channel send).
    pub msgs_paced: u64,
    /// Payload bytes actually sent (Deliver verdicts only) — the logical
    /// communication volume; shared payloads are charged by length, not
    /// by the pointer-sized handle that crosses the channel.
    pub bytes_sent: u64,
}

struct Shared {
    stop: AtomicBool,
    /// shared fault/link layer: wall clock + atomic per-(link, channel)
    /// in-flight flags + scalar/scenario fault queries
    faults: RunnerFaultLayer,
    // Report-counter ordering contract (DESIGN.md §14, `relaxed-counter`):
    // every counter below feeds RunnerStats/report scalars, so writers
    // use AcqRel RMWs and readers Acquire loads — a coordinator-side read
    // then observes everything the worker published before bumping the
    // counter. `gamma_bits` and `stop` are single-value signals, not
    // counters; Relaxed remains sound for them.
    total_steps: AtomicU64,
    msgs_sent: AtomicU64,
    msgs_lost: AtomicU64,
    msgs_backpressured: AtomicU64,
    msgs_paced: AtomicU64,
    bytes_sent: AtomicU64,
    /// current step size as f32 bits; the coordinator writes decays, the
    /// workers pick them up at the top of their loop
    gamma_bits: AtomicU32,
    /// per-node rolling (sum, count) of minibatch losses between eval
    /// ticks — per-node so the hot training loop never contends on a
    /// shared lock (same pattern as `steps`/`snapshots`)
    train_loss: Vec<Mutex<(f64, u64)>>,
    /// latest parameter snapshot per node (written post-wake)
    snapshots: Vec<Mutex<Vec<f32>>>,
    steps: Vec<AtomicU64>,
}

/// Thread-per-node engine. Generic over the oracle factory so the same
/// runner drives quadratics (tests), rust logreg, and PJRT models.
pub struct ThreadedRunner {
    cfg: SimConfig,
    algo: AlgoKind,
    topo: Topology,
    x0: Vec<f32>,
    pace: Option<Duration>,
}

impl ThreadedRunner {
    pub fn new(cfg: SimConfig, topo: &Topology, algo: AlgoKind,
               x0: Vec<f32>) -> ThreadedRunner {
        // lint:allow(panic-path): engine-level constructor fails fast; Experiment pre-validates into typed errors
        cfg.validate().expect("invalid SimConfig");
        if let Some(sc) = &cfg.scenario {
            // bound-check node indices against this topology, like the
            // simulator does
            sc.validate(Some(topo.n()))
                // lint:allow(panic-path): engine-level constructor fails fast; Experiment pre-validates into typed errors
                .expect("invalid scenario for this topology");
        }
        ThreadedRunner { cfg, algo, topo: topo.clone(), x0, pace: None }
    }

    /// Enforce a minimum per-iteration duration. Needed when the oracle is
    /// much faster than the links (e.g. closed-form quadratics): without a
    /// pace, nodes run thousands of local iterations per delivered message,
    /// i.e. the effective delay bound D of Assumption 3 explodes and the
    /// fixed step size is no longer stable. Real model oracles (PJRT) are
    /// naturally paced by their compute.
    pub fn with_pace(mut self, seconds: f64) -> ThreadedRunner {
        self.pace = Some(Duration::from_secs_f64(seconds));
        self
    }

    /// Run to completion; `eval` is called on the coordinator thread with
    /// the mean parameter snapshot every `cfg.eval_every` *wall* seconds.
    ///
    /// Takes the engine-agnostic [`Stop`]; `Stop::Time` means *wall*
    /// seconds here, `Stop::Iterations` counts total gradient steps
    /// across nodes, and `Stop::Epochs` uses the factory's epoch mapping.
    /// Legacy [`RunUntil`] values convert transparently.
    pub fn run(
        &self,
        factory: &dyn OracleFactory,
        eval: &mut dyn FnMut(&[f32]) -> Eval,
        until: impl Into<Stop>,
    ) -> (Report, RunnerStats) {
        let until: Stop = until.into();
        let n = self.topo.n();
        let p = self.x0.len();
        assert_eq!(factory.dim(), p, "factory dim vs x0");
        let nodes = self.algo.build(&self.topo, &self.x0, self.cfg.gamma,
                                    self.cfg.seed);

        let shared = Arc::new(Shared {
            stop: AtomicBool::new(false),
            faults: RunnerFaultLayer::new(n, WallClock::start_now(),
                                          FaultSpec::from_config(&self.cfg)),
            total_steps: AtomicU64::new(0),
            msgs_sent: AtomicU64::new(0),
            msgs_lost: AtomicU64::new(0),
            msgs_backpressured: AtomicU64::new(0),
            msgs_paced: AtomicU64::new(0),
            bytes_sent: AtomicU64::new(0),
            gamma_bits: AtomicU32::new(self.cfg.gamma.to_bits()),
            train_loss: (0..n).map(|_| Mutex::new((0.0, 0))).collect(),
            snapshots: (0..n).map(|_| Mutex::new(self.x0.clone())).collect(),
            steps: (0..n).map(|_| AtomicU64::new(0)).collect(),
        });

        // mailboxes
        let mut senders: Vec<Sender<Envelope>> = Vec::with_capacity(n);
        let mut receivers: Vec<Option<Receiver<Envelope>>> = Vec::new();
        for _ in 0..n {
            let (tx, rx) = channel();
            senders.push(tx);
            receivers.push(Some(rx));
        }

        let start = Instant::now();
        let epoch_per_batch = factory.epoch_per_node_batch();
        let mut report = Report::new(self.algo.name());
        let mut mean = vec![0.0f32; p];
        std::thread::scope(|scope| {
            for (i, node) in nodes.into_iter().enumerate() {
                // lint:allow(panic-path): each receiver is taken exactly once, i is unique per iteration
                let rx = receivers[i].take().unwrap();
                let routes = senders.clone();
                let shared_i = Arc::clone(&shared);
                let cfg = self.cfg.clone();
                let algo = self.algo;
                let pace = self.pace;
                std::thread::Builder::new()
                    .name(format!("rfast-node-{i}"))
                    .spawn_scoped(scope, move || {
                        worker_loop(i, node, factory, rx, routes, shared_i,
                                    cfg, algo, pace);
                    })
                    // lint:allow(panic-path): thread spawn failure is unrecoverable resource exhaustion
                    .expect("spawn worker");
            }
            drop(senders);

            // coordinator loop: evaluate + γ-decay + check stop condition
            let eval_every =
                Duration::from_secs_f64(self.cfg.eval_every.max(0.05));
            let mut decay_steps: u32 = 0;
            loop {
                std::thread::sleep(eval_every);
                let elapsed = start.elapsed().as_secs_f64();
                self.snapshot_mean(&shared, &mut mean);
                let e = eval(&mean);
                report
                    .series_mut("loss_vs_wall", "wall_seconds", "eval_loss")
                    .push(elapsed, e.loss);
                if let Some(acc) = e.accuracy {
                    report
                        .series_mut("acc_vs_wall", "wall_seconds", "accuracy")
                        .push(elapsed, acc);
                }
                let total = shared.total_steps.load(Ordering::Acquire);
                report
                    .series_mut("steps_vs_wall", "wall_seconds", "total_steps")
                    .push(elapsed, total as f64);
                // minibatch-loss series — the runner twin of the
                // simulator's train_loss_vs_epoch, on the wall axis
                {
                    let (mut sum, mut count) = (0.0f64, 0u64);
                    for slot in &shared.train_loss {
                        // lint:allow(panic-path): lock poisoning means a worker already panicked
                        let mut acc = slot.lock().unwrap();
                        sum += acc.0;
                        count += acc.1;
                        *acc = (0.0, 0);
                    }
                    if count > 0 {
                        report
                            .series_mut("train_loss_vs_wall", "wall_seconds",
                                        "train_loss")
                            .push(elapsed, sum / count as f64);
                    }
                }
                // γ-decay: the same epoch-indexed γ·factor^k schedule the
                // simulator applies per wake, driven here by the global
                // step counter (epoch ≈ total steps × epoch-per-batch)
                if let Some((interval, factor)) = self.cfg.gamma_decay {
                    let due = (total as f64 * epoch_per_batch / interval) as u32;
                    if due > decay_steps {
                        decay_steps = due;
                        let g = self.cfg.gamma * factor.powi(due as i32);
                        shared.gamma_bits.store(g.to_bits(), Ordering::Relaxed);
                    }
                }
                let done = match until {
                    Stop::Time(s) => elapsed >= s,
                    Stop::TargetLoss { loss, max_time } => {
                        e.loss <= loss || elapsed >= max_time
                    }
                    Stop::Iterations(k) => total >= k,
                    // the coordinator's epoch mapping: total steps ×
                    // epoch-per-node-batch, same conversion the γ-decay
                    // schedule and the `epoch` scalar use
                    Stop::Epochs(target) => {
                        total as f64 * epoch_per_batch >= target
                    }
                };
                if done {
                    break;
                }
            }
            shared.stop.store(true, Ordering::SeqCst);
            // scope joins all workers here
        });
        let wall = start.elapsed().as_secs_f64();

        self.snapshot_mean(&shared, &mut mean);
        let e = eval(&mean);
        report
            .series_mut("loss_vs_wall", "wall_seconds", "eval_loss")
            .push(wall, e.loss);

        let stats = RunnerStats {
            wall_seconds: wall,
            steps_per_node: shared
                .steps
                .iter()
                .map(|s| s.load(Ordering::Acquire))
                .collect(),
            msgs_sent: shared.msgs_sent.load(Ordering::Acquire),
            msgs_lost: shared.msgs_lost.load(Ordering::Acquire),
            msgs_backpressured: shared.msgs_backpressured.load(Ordering::Acquire),
            msgs_paced: shared.msgs_paced.load(Ordering::Acquire),
            bytes_sent: shared.bytes_sent.load(Ordering::Acquire),
        };
        let total_steps = stats.steps_per_node.iter().sum::<u64>();
        report.set_scalar("wall_seconds", stats.wall_seconds);
        report.set_scalar("total_steps", total_steps as f64);
        report.set_scalar("epoch", total_steps as f64 * epoch_per_batch);
        report.set_scalar("msgs_sent", stats.msgs_sent as f64);
        report.set_scalar("msgs_lost", stats.msgs_lost as f64);
        report.set_scalar("msgs_backpressured",
                          stats.msgs_backpressured as f64);
        report.set_scalar("msgs_paced", stats.msgs_paced as f64);
        report.set_scalar("bytes_sent", stats.bytes_sent as f64);
        report.set_scalar("final_loss", e.loss);
        if let Some(acc) = e.accuracy {
            report.set_scalar("final_accuracy", acc);
        }
        (report, stats)
    }

    fn snapshot_mean(&self, shared: &Shared, mean: &mut [f32]) {
        mean.iter_mut().for_each(|v| *v = 0.0);
        for snap in &shared.snapshots {
            // lint:allow(panic-path): lock poisoning means a worker already panicked
            let guard = snap.lock().unwrap();
            crate::linalg::axpy(mean, 1.0, &guard);
        }
        crate::linalg::scale(mean, 1.0 / shared.snapshots.len() as f32);
    }
}

enum Envelope {
    Data(Msg),
    Ack { from: usize, chan: usize },
}

/// Send every queued message through the shared link layer. Scenario
/// link degradation paces the *sending thread*: the FIFO bandwidth
/// serialization delay and the injected excess latency are slept before
/// the channel send, so delivery is genuinely later on the wall clock.
#[allow(clippy::too_many_arguments)]
fn send_all(
    node: &mut dyn NodeState,
    msgs: &mut Vec<Msg>,
    rng: &mut Rng,
    bw: &mut BwPacer,
    routes: &[Sender<Envelope>],
    shared: &Shared,
    lossy: bool,
    n: usize,
) {
    for m in msgs.drain(..) {
        shared.msgs_sent.fetch_add(1, Ordering::AcqRel);
        match shared.faults.send_verdict(lossy, &m, rng) {
            SendVerdict::Backpressured => {
                shared.msgs_backpressured.fetch_add(1, Ordering::AcqRel);
                node.on_send_failed(m);
                continue;
            }
            SendVerdict::Lost => {
                shared.msgs_lost.fetch_add(1, Ordering::AcqRel);
                node.on_send_failed(m);
                continue;
            }
            SendVerdict::Deliver => {}
        }
        let bytes = FaultSpec::payload_bytes(&m);
        shared.bytes_sent.fetch_add(bytes as u64, Ordering::AcqRel);
        let now = shared.faults.clock.now();
        let mut delay = shared.faults.spec.injected_latency(now);
        let bw_delay = shared.faults.spec.bandwidth_delay(m.from, m.to, bytes);
        if bw_delay > 0.0 {
            // each directed link has exactly one sender (this thread), so
            // the per-worker FIFO queue is the link's transmission queue
            delay += bw.sent_at(m.from * n + m.to, now, bw_delay) - now;
        }
        if delay > 0.0 {
            shared.msgs_paced.fetch_add(1, Ordering::AcqRel);
            let mut remaining = delay;
            while remaining > 0.0 && !shared.stop.load(Ordering::Relaxed) {
                let chunk = remaining.min(MAX_PACING_SLEEP);
                std::thread::sleep(Duration::from_secs_f64(chunk));
                remaining -= chunk;
            }
        }
        // receiver gone ⇒ shutting down; ignore
        let _ = routes[m.to].send(Envelope::Data(m));
    }
}

/// Deliver one envelope to this worker's node: data messages go to the
/// algorithm (ack'd back for loss-tolerant ones, protocol replies routed
/// out), acks free the channel this node holds toward the ack's sender.
#[allow(clippy::too_many_arguments)]
fn handle_envelope(
    env: Envelope,
    id: usize,
    node: &mut dyn NodeState,
    routes: &[Sender<Envelope>],
    shared: &Shared,
    outbox: &mut Vec<Msg>,
    replies: &mut Vec<Msg>,
    rng: &mut Rng,
    bw: &mut BwPacer,
    lossy: bool,
    n: usize,
) {
    match env {
        Envelope::Data(m) => {
            let from = m.from;
            let chan = m.kind.chan();
            node.receive(m, replies);
            if lossy {
                // receipt confirmation back to the sender
                let _ = routes[from].send(Envelope::Ack { from: id, chan });
            }
            if !replies.is_empty() {
                outbox.append(replies);
                send_all(node, outbox, rng, bw, routes, shared, lossy, n);
            }
        }
        Envelope::Ack { from, chan } => {
            // we are the original sender: channel (id → from) free
            shared.faults.ack(id, from, chan);
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    id: usize,
    mut node: Box<dyn NodeState>,
    factory: &dyn OracleFactory,
    rx: Receiver<Envelope>,
    routes: Vec<Sender<Envelope>>,
    shared: Arc<Shared>,
    cfg: SimConfig,
    algo: AlgoKind,
    pace: Option<Duration>,
) {
    let n = routes.len();
    let mut oracle = factory.make(id);
    let mut rng = Rng::stream(cfg.seed, 0x70_000 + id as u64);
    let lossy = algo.tolerates_loss();
    let mut outbox: Vec<Msg> = Vec::new();
    let mut replies: Vec<Msg> = Vec::new();
    let mut bw = BwPacer::new(n * n);
    let mut gamma_seen = shared.gamma_bits.load(Ordering::Relaxed);

    while !shared.stop.load(Ordering::Relaxed) {
        // pick up γ-decay steps pushed by the coordinator
        let g = shared.gamma_bits.load(Ordering::Relaxed);
        if g != gamma_seen {
            gamma_seen = g;
            node.set_gamma(f32::from_bits(g));
        }

        // drain mailbox
        while let Ok(env) = rx.try_recv() {
            handle_envelope(env, id, node.as_mut(), &routes, &shared,
                            &mut outbox, &mut replies, &mut rng, &mut bw,
                            lossy, n);
        }

        let now = shared.faults.clock.now();
        // scenario churn: a paused node starts no new iteration but keeps
        // receiving below — a stalled worker, not a crashed one (same
        // semantics as the simulator's pause windows)
        let paused = shared.faults.spec.is_paused(id, now);

        if !paused && node.ready() {
            let t0 = Instant::now();
            let computed = node.wake_computes_gradient();
            let loss = node.wake(oracle.as_mut(), &mut outbox);
            let step_time = t0.elapsed();
            send_all(node.as_mut(), &mut outbox, &mut rng, &mut bw, &routes,
                     &shared, lossy, n);
            if computed {
                shared.steps[id].fetch_add(1, Ordering::AcqRel);
                shared.total_steps.fetch_add(1, Ordering::AcqRel);
                if let Some(l) = loss {
                    // uncontended: this node's own accumulator
                    // lint:allow(panic-path): lock poisoning means a sibling worker already panicked
                    let mut acc = shared.train_loss[id].lock().unwrap();
                    acc.0 += l as f64;
                    acc.1 += 1;
                }
                // snapshot for the coordinator
                {
                    // lint:allow(panic-path): lock poisoning means a sibling worker already panicked
                    let mut guard = shared.snapshots[id].lock().unwrap();
                    guard.copy_from_slice(node.param());
                }
                // pace + straggler emulation: the target duration of this
                // iteration is max(real step, pace) × straggler factor —
                // the paper slows one GPU by extra load, which scales its
                // *whole* step time. The factor is re-queried per step so
                // scenario schedules (onset-at-T, intermittent) apply.
                let factor = shared.faults.spec.compute_factor(id, now);
                let base = pace.map_or(step_time, |min| step_time.max(min));
                let target = base.mul_f64(factor);
                if target > step_time {
                    std::thread::sleep(target - step_time);
                }
            }
        } else {
            // paused, or blocked on a barrier: wait for mail (with a
            // stop-check timeout that also rechecks the pause window)
            match rx.recv_timeout(Duration::from_millis(2)) {
                Ok(env) => {
                    handle_envelope(env, id, node.as_mut(), &routes, &shared,
                                    &mut outbox, &mut replies, &mut rng,
                                    &mut bw, lossy, n);
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
    }
    // final snapshot
    // lint:allow(panic-path): lock poisoning means a sibling worker already panicked
    let mut guard = shared.snapshots[id].lock().unwrap();
    guard.copy_from_slice(node.param());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::QuadraticOracle;
    use crate::testutil::{tracking_quad_eval, QuadFactory};

    #[test]
    fn threaded_rfast_converges_on_quadratic() {
        let q = QuadraticOracle::heterogeneous(8, 4, 0.5, 2.0, 21);
        let xs = q.optimum();
        let f_star = q.global_loss(&xs);
        let topo = Topology::ring(4);
        let cfg = SimConfig {
            seed: 5,
            gamma: 0.03,
            compute_mean: 0.001,
            eval_every: 0.05,
            ..SimConfig::default()
        };
        let runner = ThreadedRunner::new(cfg, &topo, AlgoKind::RFast,
                                         vec![0.0; 8])
            .with_pace(5e-5);
        // keep the last evaluated mean so the near-optimum claim can be
        // checked in parameter space, not just through the loss
        let (mut eval, last_mean) = tracking_quad_eval(q.clone());
        let (report, stats) =
            runner.run(&QuadFactory(q), &mut eval,
                       Stop::Iterations(60_000));
        assert!(stats.steps_per_node.iter().all(|&s| s > 100),
                "{:?}", stats.steps_per_node);
        let last = report.series["loss_vs_wall"].last_y().unwrap();
        let first = report.series["loss_vs_wall"].points[0].1;
        assert!(last < first, "{first} → {last}");
        // mean model near optimum: loss within a margin of f*, iterate
        // within a ball around x*
        assert!(last < f_star + 0.5, "final loss {last} vs f* {f_star}");
        let d = crate::linalg::dist(&last_mean.lock().unwrap(), &xs);
        assert!(d < 0.5, "‖x̄ − x*‖ = {d}");
    }

    #[test]
    fn threaded_sync_allreduce_no_deadlock() {
        let q = QuadraticOracle::heterogeneous(6, 3, 0.5, 2.0, 33);
        let topo = Topology::ring(3);
        let cfg = SimConfig {
            seed: 6,
            gamma: 0.1,
            compute_mean: 0.001,
            eval_every: 0.05,
            ..SimConfig::default()
        };
        let runner = ThreadedRunner::new(cfg, &topo, AlgoKind::RingAllReduce,
                                         vec![0.0; 6]);
        let (mut eval, _) = tracking_quad_eval(q.clone());
        let (_, stats) =
            runner.run(&QuadFactory(q), &mut eval, Stop::Iterations(300));
        assert!(stats.steps_per_node.iter().sum::<u64>() >= 300);
        // lock-step: per-node counts within one round of each other
        let min = *stats.steps_per_node.iter().min().unwrap();
        let max = *stats.steps_per_node.iter().max().unwrap();
        assert!(max - min <= 2, "{:?}", stats.steps_per_node);
    }

    #[test]
    fn packet_loss_counters_active() {
        let q = QuadraticOracle::heterogeneous(4, 3, 0.5, 2.0, 41);
        let topo = Topology::ring(3);
        let mut cfg = SimConfig {
            seed: 7,
            gamma: 0.02,
            compute_mean: 0.001,
            eval_every: 0.05,
            ..SimConfig::default()
        };
        cfg.loss_prob = 0.3;
        let runner =
            ThreadedRunner::new(cfg, &topo, AlgoKind::RFast, vec![0.0; 4])
                .with_pace(1e-4);
        let (mut eval, _) = tracking_quad_eval(q.clone());
        let (_, stats) =
            runner.run(&QuadFactory(q), &mut eval, Stop::Iterations(5_000));
        assert!(stats.msgs_lost > 0);
    }
}
