//! Real asynchronous runtime: one OS thread per node, mailbox channels.
//!
//! This is the wall-clock counterpart of [`crate::sim`] and mirrors the
//! paper's implementation ("each process runs its own code independently
//! and messages are transmitted in a fully-asynchronous way without any
//! blocking", §VI ¶1) — with `std::thread` + `mpsc` in place of
//! process-per-GPU + torch.distributed:
//!
//! * every node thread loops: drain mailbox → if `ready`, run one local
//!   iteration (for PJRT oracles the gradient is a real XLA execution on
//!   this thread) → send messages;
//! * links: sender-side Bernoulli drop + at-most-one-unacked-packet per
//!   link, implemented with an atomic in-flight flag the receiver clears —
//!   the same semantics the simulator models (loss only for loss-tolerant
//!   algorithms);
//! * a straggler is emulated by sleeping `(factor−1)×` the measured step
//!   time, exactly like the paper slows one GPU with extra load;
//! * the coordinator thread snapshots per-node parameters, evaluates the
//!   mean model periodically, and stops everyone at the deadline.

use crate::algo::{AlgoKind, Msg, NodeState};
use crate::config::SimConfig;
use crate::graph::Topology;
use crate::metrics::Report;
use crate::oracle::{Eval, OracleFactory};
use crate::prng::Rng;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Wall-clock stopping criteria.
#[derive(Clone, Copy, Debug)]
pub enum RunUntil {
    WallSeconds(f64),
    /// Stop when the mean-model eval loss reaches `loss`, or at the
    /// deadline.
    TargetLoss { loss: f64, max_seconds: f64 },
    /// Stop when total gradient steps across nodes reach this count.
    TotalSteps(u64),
}

/// Final counters for the run.
#[derive(Clone, Debug, Default)]
pub struct RunnerStats {
    pub wall_seconds: f64,
    pub steps_per_node: Vec<u64>,
    pub msgs_sent: u64,
    pub msgs_lost: u64,
    pub msgs_backpressured: u64,
}

struct Shared {
    stop: AtomicBool,
    /// in-flight flag per (directed link, message channel):
    /// (from*n + to)*CHANNELS + chan
    link_busy: Vec<AtomicBool>,
    total_steps: AtomicU64,
    msgs_sent: AtomicU64,
    msgs_lost: AtomicU64,
    msgs_backpressured: AtomicU64,
    /// latest parameter snapshot per node (written post-wake)
    snapshots: Vec<Mutex<Vec<f32>>>,
    steps: Vec<AtomicU64>,
}

/// Thread-per-node engine. Generic over the oracle factory so the same
/// runner drives quadratics (tests), rust logreg, and PJRT models.
pub struct ThreadedRunner {
    cfg: SimConfig,
    algo: AlgoKind,
    topo: Topology,
    x0: Vec<f32>,
    pace: Option<Duration>,
}

impl ThreadedRunner {
    pub fn new(cfg: SimConfig, topo: &Topology, algo: AlgoKind,
               x0: Vec<f32>) -> ThreadedRunner {
        cfg.validate().expect("invalid SimConfig");
        assert!(
            cfg.scenario.is_none(),
            "fault-injection scenarios drive the virtual-time simulator \
             only; the threaded runner takes the scalar SimConfig knobs \
             (wall-clock scenario support is a ROADMAP item)"
        );
        ThreadedRunner { cfg, algo, topo: topo.clone(), x0, pace: None }
    }

    /// Enforce a minimum per-iteration duration. Needed when the oracle is
    /// much faster than the links (e.g. closed-form quadratics): without a
    /// pace, nodes run thousands of local iterations per delivered message,
    /// i.e. the effective delay bound D of Assumption 3 explodes and the
    /// fixed step size is no longer stable. Real model oracles (PJRT) are
    /// naturally paced by their compute.
    pub fn with_pace(mut self, seconds: f64) -> ThreadedRunner {
        self.pace = Some(Duration::from_secs_f64(seconds));
        self
    }

    /// Run to completion; `eval` is called on the coordinator thread with
    /// the mean parameter snapshot every `cfg.eval_every` *wall* seconds.
    pub fn run(
        &self,
        factory: &dyn OracleFactory,
        eval: &mut dyn FnMut(&[f32]) -> Eval,
        until: RunUntil,
    ) -> (Report, RunnerStats) {
        let n = self.topo.n();
        let p = self.x0.len();
        assert_eq!(factory.dim(), p, "factory dim vs x0");
        let nodes = self.algo.build(&self.topo, &self.x0, self.cfg.gamma,
                                    self.cfg.seed);

        let shared = Arc::new(Shared {
            stop: AtomicBool::new(false),
            link_busy: (0..n * n * crate::algo::MsgKind::CHANNELS)
                .map(|_| AtomicBool::new(false))
                .collect(),
            total_steps: AtomicU64::new(0),
            msgs_sent: AtomicU64::new(0),
            msgs_lost: AtomicU64::new(0),
            msgs_backpressured: AtomicU64::new(0),
            snapshots: (0..n).map(|_| Mutex::new(self.x0.clone())).collect(),
            steps: (0..n).map(|_| AtomicU64::new(0)).collect(),
        });

        // mailboxes
        let mut senders: Vec<Sender<Envelope>> = Vec::with_capacity(n);
        let mut receivers: Vec<Option<Receiver<Envelope>>> = Vec::new();
        for _ in 0..n {
            let (tx, rx) = channel();
            senders.push(tx);
            receivers.push(Some(rx));
        }

        let start = Instant::now();
        let mut report = Report::new(self.algo.name());
        let mut mean = vec![0.0f32; p];
        std::thread::scope(|scope| {
            for (i, node) in nodes.into_iter().enumerate() {
                let rx = receivers[i].take().unwrap();
                let routes = senders.clone();
                let shared_i = Arc::clone(&shared);
                let cfg = self.cfg.clone();
                let algo = self.algo;
                let pace = self.pace;
                std::thread::Builder::new()
                    .name(format!("rfast-node-{i}"))
                    .spawn_scoped(scope, move || {
                        worker_loop(i, node, factory, rx, routes, shared_i,
                                    cfg, algo, pace);
                    })
                    .expect("spawn worker");
            }
            drop(senders);

            // coordinator loop: evaluate + check stop condition
            let eval_every =
                Duration::from_secs_f64(self.cfg.eval_every.max(0.05));
            loop {
                std::thread::sleep(eval_every);
                let elapsed = start.elapsed().as_secs_f64();
                self.snapshot_mean(&shared, &mut mean);
                let e = eval(&mean);
                report
                    .series_mut("loss_vs_wall", "wall_seconds", "eval_loss")
                    .push(elapsed, e.loss);
                if let Some(acc) = e.accuracy {
                    report
                        .series_mut("acc_vs_wall", "wall_seconds", "accuracy")
                        .push(elapsed, acc);
                }
                report
                    .series_mut("steps_vs_wall", "wall_seconds", "total_steps")
                    .push(elapsed,
                          shared.total_steps.load(Ordering::Relaxed) as f64);
                let done = match until {
                    RunUntil::WallSeconds(s) => elapsed >= s,
                    RunUntil::TargetLoss { loss, max_seconds } => {
                        e.loss <= loss || elapsed >= max_seconds
                    }
                    RunUntil::TotalSteps(k) => {
                        shared.total_steps.load(Ordering::Relaxed) >= k
                    }
                };
                if done {
                    break;
                }
            }
            shared.stop.store(true, Ordering::SeqCst);
            // scope joins all workers here
        });
        let wall = start.elapsed().as_secs_f64();

        self.snapshot_mean(&shared, &mut mean);
        let e = eval(&mean);
        report
            .series_mut("loss_vs_wall", "wall_seconds", "eval_loss")
            .push(wall, e.loss);

        let stats = RunnerStats {
            wall_seconds: wall,
            steps_per_node: shared
                .steps
                .iter()
                .map(|s| s.load(Ordering::Relaxed))
                .collect(),
            msgs_sent: shared.msgs_sent.load(Ordering::Relaxed),
            msgs_lost: shared.msgs_lost.load(Ordering::Relaxed),
            msgs_backpressured: shared.msgs_backpressured.load(Ordering::Relaxed),
        };
        report.set_scalar("wall_seconds", stats.wall_seconds);
        report.set_scalar("total_steps",
                          stats.steps_per_node.iter().sum::<u64>() as f64);
        report.set_scalar("msgs_sent", stats.msgs_sent as f64);
        report.set_scalar("msgs_lost", stats.msgs_lost as f64);
        report.set_scalar("final_loss", e.loss);
        if let Some(acc) = e.accuracy {
            report.set_scalar("final_accuracy", acc);
        }
        (report, stats)
    }

    fn snapshot_mean(&self, shared: &Shared, mean: &mut [f32]) {
        mean.iter_mut().for_each(|v| *v = 0.0);
        for snap in &shared.snapshots {
            let guard = snap.lock().unwrap();
            crate::linalg::axpy(mean, 1.0, &guard);
        }
        crate::linalg::scale(mean, 1.0 / shared.snapshots.len() as f32);
    }
}

enum Envelope {
    Data(Msg),
    Ack { from: usize, chan: usize },
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    id: usize,
    mut node: Box<dyn NodeState>,
    factory: &dyn OracleFactory,
    rx: Receiver<Envelope>,
    routes: Vec<Sender<Envelope>>,
    shared: Arc<Shared>,
    cfg: SimConfig,
    algo: AlgoKind,
    pace: Option<Duration>,
) {
    let n = routes.len();
    let mut oracle = factory.make(id);
    let mut rng = Rng::stream(cfg.seed, 0x70_000 + id as u64);
    let lossy = algo.tolerates_loss();
    let straggle_factor = match cfg.straggler {
        Some((s, f)) if s == id => f,
        _ => 1.0,
    };
    let mut outbox: Vec<Msg> = Vec::new();
    let mut replies: Vec<Msg> = Vec::new();

    let send_all = |node: &mut dyn NodeState, msgs: &mut Vec<Msg>,
                    rng: &mut Rng| {
        for m in msgs.drain(..) {
            shared.msgs_sent.fetch_add(1, Ordering::Relaxed);
            if lossy {
                let link = &shared.link_busy
                    [(m.from * n + m.to) * crate::algo::MsgKind::CHANNELS
                     + m.kind.chan()];
                if link.load(Ordering::Acquire) {
                    shared.msgs_backpressured.fetch_add(1, Ordering::Relaxed);
                    node.on_send_failed(m);
                    continue;
                }
                if cfg.loss_prob > 0.0 && rng.chance(cfg.loss_prob) {
                    shared.msgs_lost.fetch_add(1, Ordering::Relaxed);
                    node.on_send_failed(m);
                    continue;
                }
                link.store(true, Ordering::Release);
            }
            let to = m.to;
            // receiver gone ⇒ shutting down; ignore
            let _ = routes[to].send(Envelope::Data(m));
        }
    };

    while !shared.stop.load(Ordering::Relaxed) {
        // drain mailbox
        loop {
            match rx.try_recv() {
                Ok(Envelope::Data(m)) => {
                    let from = m.from;
                    let chan = m.kind.chan();
                    node.receive(m, &mut replies);
                    if lossy {
                        // receipt confirmation back to the sender
                        let _ = routes[from]
                            .send(Envelope::Ack { from: id, chan });
                    }
                    if !replies.is_empty() {
                        outbox.append(&mut replies);
                        send_all(node.as_mut(), &mut outbox, &mut rng);
                    }
                }
                Ok(Envelope::Ack { from, chan }) => {
                    // we are the original sender: channel (id → from) free
                    shared.link_busy
                        [(id * n + from) * crate::algo::MsgKind::CHANNELS + chan]
                        .store(false, Ordering::Release);
                }
                Err(_) => break,
            }
        }

        if node.ready() {
            let t0 = Instant::now();
            let computed = node.wake_computes_gradient();
            node.wake(oracle.as_mut(), &mut outbox);
            let step_time = t0.elapsed();
            send_all(node.as_mut(), &mut outbox, &mut rng);
            if computed {
                shared.steps[id].fetch_add(1, Ordering::Relaxed);
                shared.total_steps.fetch_add(1, Ordering::Relaxed);
                // snapshot for the coordinator
                {
                    let mut guard = shared.snapshots[id].lock().unwrap();
                    guard.copy_from_slice(node.param());
                }
                // pace + straggler emulation: the target duration of this
                // iteration is max(real step, pace) × straggler factor —
                // the paper slows one GPU by extra load, which scales its
                // *whole* step time.
                let base = pace.map_or(step_time, |min| step_time.max(min));
                let target = base.mul_f64(straggle_factor);
                if target > step_time {
                    std::thread::sleep(target - step_time);
                }
            }
        } else {
            // blocked on a barrier: wait for mail (with a stop-check timeout)
            match rx.recv_timeout(Duration::from_millis(2)) {
                Ok(Envelope::Data(m)) => {
                    let from = m.from;
                    let chan = m.kind.chan();
                    node.receive(m, &mut replies);
                    if lossy {
                        let _ = routes[from]
                            .send(Envelope::Ack { from: id, chan });
                    }
                    if !replies.is_empty() {
                        outbox.append(&mut replies);
                        send_all(node.as_mut(), &mut outbox, &mut rng);
                    }
                }
                Ok(Envelope::Ack { from, chan }) => {
                    shared.link_busy
                        [(id * n + from) * crate::algo::MsgKind::CHANNELS + chan]
                        .store(false, Ordering::Release);
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
    }
    // final snapshot
    let mut guard = shared.snapshots[id].lock().unwrap();
    guard.copy_from_slice(node.param());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::{GradOracle, NodeOracle, QuadraticOracle};

    struct QuadFactory(QuadraticOracle);
    impl OracleFactory for QuadFactory {
        fn dim(&self) -> usize {
            self.0.dim
        }
        fn make(&self, node: usize) -> Box<dyn NodeOracle> {
            let mut set = self.0.clone().into_set();
            set.nodes.remove(node)
        }
    }

    #[test]
    fn threaded_rfast_converges_on_quadratic() {
        let q = QuadraticOracle::heterogeneous(8, 4, 0.5, 2.0, 21);
        let xs = q.optimum();
        let q_eval = q.clone();
        let factory = QuadFactory(q);
        let topo = Topology::ring(4);
        let cfg = SimConfig {
            seed: 5,
            gamma: 0.03,
            compute_mean: 0.001,
            eval_every: 0.05,
            ..SimConfig::default()
        };
        let runner = ThreadedRunner::new(cfg, &topo, AlgoKind::RFast,
                                         vec![0.0; 8])
            .with_pace(5e-5);
        let mut eval = move |x: &[f32]| Eval {
            loss: q_eval.global_loss(x),
            accuracy: None,
        };
        let (report, stats) =
            runner.run(&factory, &mut eval, RunUntil::TotalSteps(60_000));
        assert!(stats.steps_per_node.iter().all(|&s| s > 100),
                "{:?}", stats.steps_per_node);
        let last = report.series["loss_vs_wall"].last_y().unwrap();
        let first = report.series["loss_vs_wall"].points[0].1;
        assert!(last < first, "{first} → {last}");
        // mean model near optimum
        let mut mean = vec![0.0f32; 8];
        // recompute from report scalar: use final loss proxy instead
        let _ = &mut mean;
        let f_star = {
            let q2 = QuadraticOracle::heterogeneous(8, 4, 0.5, 2.0, 21);
            let o = q2.optimum();
            q2.global_loss(&o)
        };
        assert!(last < f_star + 0.5, "final loss {last} vs f* {f_star}");
        let _ = xs;
    }

    #[test]
    fn threaded_sync_allreduce_no_deadlock() {
        let q = QuadraticOracle::heterogeneous(6, 3, 0.5, 2.0, 33);
        let q_eval = q.clone();
        let factory = QuadFactory(q);
        let topo = Topology::ring(3);
        let cfg = SimConfig {
            seed: 6,
            gamma: 0.1,
            compute_mean: 0.001,
            eval_every: 0.05,
            ..SimConfig::default()
        };
        let runner = ThreadedRunner::new(cfg, &topo, AlgoKind::RingAllReduce,
                                         vec![0.0; 6]);
        let mut eval = move |x: &[f32]| Eval {
            loss: q_eval.global_loss(x),
            accuracy: None,
        };
        let (_, stats) =
            runner.run(&factory, &mut eval, RunUntil::TotalSteps(300));
        assert!(stats.steps_per_node.iter().sum::<u64>() >= 300);
        // lock-step: per-node counts within one round of each other
        let min = *stats.steps_per_node.iter().min().unwrap();
        let max = *stats.steps_per_node.iter().max().unwrap();
        assert!(max - min <= 2, "{:?}", stats.steps_per_node);
    }

    #[test]
    fn packet_loss_counters_active() {
        let q = QuadraticOracle::heterogeneous(4, 3, 0.5, 2.0, 41);
        let q_eval = q.clone();
        let factory = QuadFactory(q);
        let topo = Topology::ring(3);
        let mut cfg = SimConfig {
            seed: 7,
            gamma: 0.02,
            compute_mean: 0.001,
            eval_every: 0.05,
            ..SimConfig::default()
        };
        cfg.loss_prob = 0.3;
        let runner =
            ThreadedRunner::new(cfg, &topo, AlgoKind::RFast, vec![0.0; 4])
                .with_pace(1e-4);
        let mut eval = move |x: &[f32]| Eval {
            loss: q_eval.global_loss(x),
            accuracy: None,
        };
        let (_, stats) =
            runner.run(&factory, &mut eval, RunUntil::TotalSteps(5_000));
        assert!(stats.msgs_lost > 0);
    }
}
