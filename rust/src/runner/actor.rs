//! Per-actor execution: one scheduling slice of a node actor.
//!
//! A slice is the actor-model rewrite of one iteration of the old
//! thread-per-node `worker_loop`, with every blocking sleep replaced by
//! a [`TimerWheel`](super::timer) suspend (DESIGN.md §15):
//!
//! 1. pick up coordinator γ-decay;
//! 2. drain the mailbox — data messages go to the algorithm (ack'd back
//!    for loss-tolerant ones, protocol replies queued), acks free the
//!    (link, channel) this actor holds toward the acker;
//! 3. unless paused (churn) or blocked (`!ready`), run one local
//!    iteration; counters, train-loss accumulator and the parameter
//!    snapshot publish exactly as before;
//! 4. send the outbox through the shared fault layer. Latency ramps and
//!    bandwidth caps advance a *virtual send cursor* instead of sleeping:
//!    each delayed message becomes a `Deliver` timer entry at its arrival
//!    time (`msgs_paced`), and the cursor accumulates exactly the delays
//!    the old engine slept, preserving its sender-side throughput bound;
//! 5. suspend: until `max(send cursor, pacing target)` when that is in
//!    the future (PACED — the straggler/pace emulation), until mail or a
//!    churn-resume timer otherwise (WAITING).
//!
//! Pacing semantics are carried over verbatim: the target duration of an
//! iteration is `max(real step time, pace) × straggler factor`, re-paced
//! on top of any send delays — the paper slows a GPU by loading it, which
//! scales its whole step.

use super::mailbox::{Envelope, PushOutcome};
use super::pool::{PoolShared, PACED, QUEUED, WAITING};
use super::timer::TimerWheel;
use super::Shared;
use crate::algo::{Msg, NodeState};
use crate::faults::{BwPacer, Clock, FaultSpec, SendVerdict};
use crate::oracle::{NodeOracle, OracleFactory};
use crate::prng::Rng;
use std::sync::atomic::Ordering;
use std::time::Instant;

/// Poll interval while paused with no scheduled resume time (open-ended
/// churn windows) — the actor re-checks the pause predicate at this
/// cadence, mirroring the old engine's `recv_timeout` loop.
const PAUSE_POLL: f64 = 0.002;

/// Events on a worker's timer wheel.
pub(crate) enum TimerEvent {
    /// Resume actor `id` (pacing over / churn re-check). `gen` guards
    /// against stale entries: a resume is honored only if it matches the
    /// actor's latest armed generation, so a leftover churn poll can
    /// never cut a pacing suspend short.
    Resume { id: usize, gen: u64 },
    /// A delayed message (latency ramp / bandwidth cap) reaching its
    /// arrival time; fires on the *sender's* worker, which owns the
    /// link's FIFO ordering.
    Deliver(Msg),
}

/// The worker-owned mutable half of one actor. Never crosses threads —
/// which is why the oracle (possibly `!Send`, e.g. PJRT) is created by
/// the owning worker itself and lives here.
pub(crate) struct ActorBody {
    pub id: usize,
    pub node: Box<dyn NodeState>,
    pub oracle: Option<Box<dyn NodeOracle>>,
    pub rng: Rng,
    outbox: Vec<Msg>,
    replies: Vec<Msg>,
    inbox: Vec<Envelope>,
    gamma_seen: u32,
    /// Latest armed resume `(deadline, generation)` — dedupes churn polls
    /// and invalidates stale wheel entries.
    armed: Option<(f64, u64)>,
    gen: u64,
}

impl ActorBody {
    pub fn new(id: usize, node: Box<dyn NodeState>, seed: u64) -> ActorBody {
        ActorBody {
            id,
            node,
            oracle: None,
            // same per-node stream ids as the thread-per-node engine
            rng: Rng::stream(seed, 0x70_000 + id as u64),
            outbox: Vec::new(),
            replies: Vec::new(),
            inbox: Vec::new(),
            gamma_seen: 0,
            armed: None,
            gen: 0,
        }
    }

    pub fn make_oracle(&mut self, factory: &dyn OracleFactory) {
        self.gamma_seen = 0; // force a γ re-read on first slice
        self.oracle = Some(factory.make(self.id));
    }

    /// A `Resume { gen }` fired: is it the live one?
    pub fn take_resume(&mut self, gen: u64) -> bool {
        match self.armed {
            Some((_, g)) if g == gen => {
                self.armed = None;
                true
            }
            _ => false,
        }
    }

    /// Arm a resume timer at `at` unless an equal-or-earlier one is
    /// already armed.
    fn arm_resume(&mut self, at: f64, wheel: &mut TimerWheel<TimerEvent>) {
        if let Some((t, _)) = self.armed {
            if t <= at {
                return;
            }
        }
        self.gen += 1;
        self.armed = Some((at, self.gen));
        wheel.schedule(at, TimerEvent::Resume { id: self.id, gen: self.gen });
    }

    /// Arm a pacing suspend: always a fresh generation, so any stale
    /// churn-poll entry is invalidated and cannot end the suspend early.
    fn arm_pacing(&mut self, at: f64, wheel: &mut TimerWheel<TimerEvent>) {
        self.gen += 1;
        self.armed = Some((at, self.gen));
        wheel.schedule(at, TimerEvent::Resume { id: self.id, gen: self.gen });
    }
}

/// Run one slice of actor `body`. Publishes the actor's next scheduling
/// state before returning.
pub(crate) fn run_slice(
    body: &mut ActorBody,
    wheel: &mut TimerWheel<TimerEvent>,
    bw: &mut BwPacer,
    pool: &PoolShared,
    shared: &Shared,
    lossy: bool,
    pace: Option<f64>,
) {
    let id = body.id;

    // coordinator-pushed γ-decay
    let g = shared.gamma_bits.load(Ordering::Relaxed);
    if g != body.gamma_seen {
        body.gamma_seen = g;
        body.node.set_gamma(f32::from_bits(g));
    }

    // drain mailbox: receive data (ack it back when loss-tolerant, queue
    // protocol replies), apply acks to the shared link layer
    pool.actors[id].mailbox.drain_into(&mut body.inbox);
    for env in body.inbox.drain(..) {
        match env {
            Envelope::Data(m) => {
                let from = m.from;
                let chan = m.kind.chan();
                body.node.receive(m, &mut body.replies);
                if lossy {
                    // receipt confirmation back to the sender (control
                    // traffic: bypasses mailbox capacity)
                    pool.push_control(from,
                                      Envelope::Ack { from: id, chan });
                }
                body.outbox.append(&mut body.replies);
            }
            Envelope::Ack { from, chan } => {
                // we are the original sender: channel (id → from) free
                shared.faults.ack(id, from, chan);
            }
        }
    }

    let now = shared.faults.clock.now();
    // scenario churn: a paused node starts no new iteration but keeps
    // receiving/acking above — a stalled worker, not a crashed one
    let paused = shared.faults.spec.is_paused(id, now);

    // one local iteration
    let mut pacing_extra = 0.0f64;
    if !paused && body.node.ready() {
        let t0 = Instant::now();
        let computed = body.node.wake_computes_gradient();
        let oracle = body
            .oracle
            .as_deref_mut()
            // lint:allow(panic-path): make_oracle runs before the first slice; a missing oracle is a scheduler bug
            .expect("oracle built by owning worker");
        let loss = body.node.wake(oracle, &mut body.outbox);
        let step_time = t0.elapsed().as_secs_f64();
        if computed {
            shared.steps[id].fetch_add(1, Ordering::AcqRel);
            shared.total_steps.fetch_add(1, Ordering::AcqRel);
            if let Some(l) = loss {
                // uncontended: this actor's own accumulator
                // lint:allow(panic-path): lock poisoning means a worker already panicked
                let mut acc = shared.train_loss[id].lock().unwrap();
                acc.0 += l as f64;
                acc.1 += 1;
            }
            // snapshot for the coordinator
            {
                // lint:allow(panic-path): lock poisoning means a worker already panicked
                let mut guard = shared.snapshots[id].lock().unwrap();
                guard.copy_from_slice(body.node.param());
            }
            // pace + straggler emulation (same law as the old engine):
            // target iteration duration = max(real step, pace) × factor;
            // the excess over the real step becomes a PACED suspend
            let factor = shared.faults.spec.compute_factor(id, now);
            let base = pace.map_or(step_time, |min| step_time.max(min));
            pacing_extra = (base * factor - step_time).max(0.0);
        }
    }

    // send phase: everything the drain + wake queued
    let send_start = shared.faults.clock.now();
    let send_end = send_phase(body, wheel, bw, pool, shared, lossy,
                              send_start);
    let resume_at = send_end + pacing_extra;

    // publish the next scheduling state
    let actor = &pool.actors[id];
    let now2 = shared.faults.clock.now();
    if resume_at > now2 {
        // suspended by pacing/straggler/send delays: mail must NOT cut
        // this short (the old engine's sleeps were uninterruptible)
        actor.finish(PACED);
        body.arm_pacing(resume_at, wheel);
        return;
    }
    if !paused && body.node.ready() {
        // more work available right now: yield for fairness
        actor.finish(QUEUED);
        pool.enqueue(id);
        return;
    }
    // blocked on mail (or paused): go WAITING, then close the lost-wakeup
    // window — re-check the mailbox after publishing WAITING and re-queue
    // self if a sender slipped in before the state store
    actor.finish(WAITING);
    if paused {
        let at = shared
            .faults
            .spec
            .next_resume(id, now)
            .unwrap_or(now2 + PAUSE_POLL);
        body.arm_resume(at.max(now2), wheel);
    }
    if !actor.mailbox.is_empty() {
        pool.wake_for_mail(id);
    }
}

/// Send every queued message through the shared link layer. The virtual
/// cursor starts at `start` and advances by each message's injected
/// latency + FIFO bandwidth serialization delay — the same cumulative
/// schedule the old engine produced by sleeping before each channel
/// send; delayed messages become `Deliver` wheel entries at their
/// arrival times. Returns the cursor (= when this sender's link work is
/// finished and it may resume).
fn send_phase(
    body: &mut ActorBody,
    wheel: &mut TimerWheel<TimerEvent>,
    bw: &mut BwPacer,
    pool: &PoolShared,
    shared: &Shared,
    lossy: bool,
    start: f64,
) -> f64 {
    let ActorBody { node, outbox, rng, .. } = body;
    let mut cursor = start;
    for m in outbox.drain(..) {
        shared.msgs_sent.fetch_add(1, Ordering::AcqRel);
        match shared.faults.send_verdict(lossy, &m, rng) {
            SendVerdict::Backpressured => {
                shared.msgs_backpressured.fetch_add(1, Ordering::AcqRel);
                node.on_send_failed(m);
                continue;
            }
            SendVerdict::Lost => {
                shared.msgs_lost.fetch_add(1, Ordering::AcqRel);
                node.on_send_failed(m);
                continue;
            }
            SendVerdict::Deliver => {}
        }
        let bytes = FaultSpec::payload_bytes(&m);
        shared.bytes_sent.fetch_add(bytes as u64, Ordering::AcqRel);
        let mut delay = shared.faults.spec.injected_latency(cursor);
        let bw_delay = shared.faults.spec.bandwidth_delay(m.from, m.to, bytes);
        if bw_delay > 0.0 {
            // each directed link has exactly one sender (this actor), and
            // this actor is pinned to this worker, so the worker-local
            // pacer owns the link's FIFO transmission queue
            if let Some(link) = shared.faults.link_id(m.from, m.to) {
                delay += bw.sent_at(link, cursor, bw_delay) - cursor;
            }
        }
        if delay > 0.0 {
            shared.msgs_paced.fetch_add(1, Ordering::AcqRel);
            cursor += delay;
            wheel.schedule(cursor, TimerEvent::Deliver(m));
        } else {
            deliver(node.as_mut(), pool, shared, lossy, m);
        }
    }
    cursor
}

/// Put `m` in its destination mailbox under the overflow policy. Runs on
/// the sender's worker (immediately, or when the `Deliver` timer fires),
/// so the sender's `on_send_failed` hook is in reach for rejections.
///
/// Any data message that leaves the system here releases its (link,
/// channel) slot: the receiver will never process it, so it would never
/// be acked, and a wedged channel is exactly what the `no_stuck` oracle
/// rejects.
pub(crate) fn deliver(
    sender: &mut dyn NodeState,
    pool: &PoolShared,
    shared: &Shared,
    lossy: bool,
    m: Msg,
) {
    let dst = m.to;
    match pool.actors[dst].mailbox.push_data(m) {
        PushOutcome::Accepted => pool.wake_for_mail(dst),
        PushOutcome::Rejected(m) => {
            // Backpressure policy: same observable path as a busy link
            shared.msgs_backpressured.fetch_add(1, Ordering::AcqRel);
            if lossy {
                shared.faults.ack(m.from, m.to, m.kind.chan());
            }
            sender.on_send_failed(m);
        }
        PushOutcome::DroppedNewest(m) => {
            shared.msgs_dropped.fetch_add(1, Ordering::AcqRel);
            if lossy {
                shared.faults.ack(m.from, m.to, m.kind.chan());
            }
        }
        PushOutcome::DroppedOldest(old) => {
            shared.msgs_dropped.fetch_add(1, Ordering::AcqRel);
            if lossy {
                shared.faults.ack(old.from, old.to, old.kind.chan());
            }
            pool.wake_for_mail(dst);
        }
    }
}
