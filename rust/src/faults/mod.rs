//! Shared fault/link layer — the single home of the semantics both
//! engines used to duplicate:
//!
//! * **link discipline** — per (directed link, message channel) at most
//!   one unacked packet in flight (the paper's send-until-receipt
//!   emulation, §VI ¶1), with sender-side Bernoulli loss for the
//!   loss-tolerant algorithms; the check order (backpressure, then loss
//!   draw, then channel acquisition) is fixed here so counters and RNG
//!   streams mean the same thing in both engines;
//! * **fault queries** — the scalar `SimConfig` knobs (`straggler`,
//!   `loss_prob`, `link_latency`) composed with the declarative
//!   [`Scenario`](crate::scenario::Scenario) hooks (straggler schedules,
//!   loss/latency ramps, churn windows, bandwidth caps) behind one
//!   [`FaultSpec`], every query a pure function of a time `t`;
//! * **bandwidth pacing** — [`BwPacer`], the FIFO per-link transmission
//!   queue that turns a byte rate into a real throughput bound.
//!
//! Time itself is abstracted by [`Clock`]: the simulator advances a
//! [`VirtualClock`] from its event loop, the threaded runner reads a
//! [`WallClock`] (seconds since the run started). Both time bases are
//! "seconds since t = 0 of the run", so one scenario file means the same
//! thing under either engine; how a computed delay is *applied* stays
//! engine-specific — the simulator schedules an event at `t + d`, the
//! runner sleeps `d` on the sending thread.

use crate::algo::{Msg, MsgKind};
use crate::config::SimConfig;
use crate::graph::WeightMatrices;
use crate::prng::Rng;
use crate::scenario::Scenario;
use std::cell::Cell;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

/// CSR-style index over the directed links a topology can actually use —
/// the sparse alternative to addressing `n × n` dense link ids. Built
/// once per run from the union of a node's neighbor lists in *every*
/// message direction (W in/out, A in/out): v-broadcasts travel to
/// `w_out`, ρ-pushes to `a_out`, and protocol replies (the AD-PSGD leg)
/// return along the corresponding in-lists, so the union covers every
/// `(from, to)` the engines route.
#[derive(Clone, Debug)]
pub struct LinkIndex {
    n: usize,
    /// `offsets[u]..offsets[u+1]` indexes `targets` for from-node u.
    offsets: Vec<u32>,
    /// Per from-node sorted target lists, concatenated.
    targets: Vec<u32>,
}

impl LinkIndex {
    /// Union of per-node neighbor lists (each `lists[k][u]` a set of
    /// peers of u); duplicates collapse, targets sort ascending.
    pub fn from_neighbor_lists(n: usize, lists: [&[Vec<usize>]; 4]) -> LinkIndex {
        let mut offsets = Vec::with_capacity(n + 1);
        offsets.push(0u32);
        let mut targets = Vec::new();
        let mut buf: Vec<u32> = Vec::new();
        for u in 0..n {
            buf.clear();
            for l in lists {
                buf.extend(l[u].iter().map(|&v| v as u32));
            }
            buf.sort_unstable();
            buf.dedup();
            targets.extend_from_slice(&buf);
            assert!(targets.len() < u32::MAX as usize, "link count overflow");
            offsets.push(targets.len() as u32);
        }
        LinkIndex { n, offsets, targets }
    }

    /// The link universe of a topology's weight structure.
    pub fn from_weights(wm: &WeightMatrices) -> LinkIndex {
        LinkIndex::from_neighbor_lists(
            wm.n,
            [&wm.w_in, &wm.w_out, &wm.a_in, &wm.a_out],
        )
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Total directed links indexed.
    pub fn links(&self) -> usize {
        self.targets.len()
    }

    /// Dense id of directed link `from → to`, `None` when the topology
    /// holds no such link. O(log degree).
    pub fn link_id(&self, from: usize, to: usize) -> Option<usize> {
        debug_assert!(from < self.n && to < self.n);
        let (s, e) = (self.offsets[from] as usize, self.offsets[from + 1] as usize);
        self.targets[s..e]
            .binary_search(&(to as u32))
            .ok()
            .map(|k| s + k)
    }
}

/// Engine time base: seconds since the start of the run.
pub trait Clock {
    fn now(&self) -> f64;
}

/// Virtual time, advanced explicitly by the simulator's event loop.
/// Single-threaded by construction (`Cell`).
#[derive(Debug, Default)]
pub struct VirtualClock {
    t: Cell<f64>,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock { t: Cell::new(0.0) }
    }

    /// Set the current virtual time (called once per popped event).
    pub fn advance_to(&self, t: f64) {
        self.t.set(t);
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> f64 {
        self.t.get()
    }
}

/// Wall time since [`WallClock::start_now`]; `Copy`, so every worker
/// thread carries the same epoch.
#[derive(Clone, Copy, Debug)]
pub struct WallClock {
    start: Instant,
}

impl WallClock {
    pub fn start_now() -> WallClock {
        WallClock { start: Instant::now() }
    }
}

impl Clock for WallClock {
    fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// One busy-flag per (directed link, channel) slot. The simulator uses
/// the single-threaded [`LocalLinks`]; the runner shares [`SharedLinks`]
/// across worker threads.
pub trait LinkSlots: Sized {
    fn with_slots(slots: usize) -> Self;
    fn busy(&self, i: usize) -> bool;
    fn acquire(&self, i: usize);
    fn release(&self, i: usize);
}

/// `Cell`-backed slots — single-threaded engines.
pub struct LocalLinks {
    slots: Vec<Cell<bool>>,
}

impl LinkSlots for LocalLinks {
    fn with_slots(slots: usize) -> LocalLinks {
        LocalLinks { slots: (0..slots).map(|_| Cell::new(false)).collect() }
    }
    fn busy(&self, i: usize) -> bool {
        self.slots[i].get()
    }
    fn acquire(&self, i: usize) {
        self.slots[i].set(true);
    }
    fn release(&self, i: usize) {
        self.slots[i].set(false);
    }
}

/// Atomic slots — the runner's worker threads share them through `Arc`.
pub struct SharedLinks {
    slots: Vec<AtomicBool>,
}

impl LinkSlots for SharedLinks {
    fn with_slots(slots: usize) -> SharedLinks {
        SharedLinks { slots: (0..slots).map(|_| AtomicBool::new(false)).collect() }
    }
    fn busy(&self, i: usize) -> bool {
        self.slots[i].load(Ordering::Acquire)
    }
    fn acquire(&self, i: usize) {
        self.slots[i].store(true, Ordering::Release);
    }
    fn release(&self, i: usize) {
        self.slots[i].store(false, Ordering::Release);
    }
}

/// The scalar fault knobs of a [`SimConfig`] composed with its optional
/// [`Scenario`]. Every query is a pure function of `t` (seconds since
/// run start, either time base), so consulting it never perturbs engine
/// determinism.
#[derive(Clone, Debug)]
pub struct FaultSpec {
    pub scenario: Option<Scenario>,
    /// `SimConfig::loss_prob` — applies until a loss-ramp phase starts.
    pub base_loss: f64,
    /// `SimConfig::straggler` — multiplies with scenario schedules.
    pub straggler: Option<(usize, f64)>,
    /// `SimConfig::link_latency` — the mean the latency ramp scales, and
    /// the unit of the wall-clock injected delay.
    pub link_latency: f64,
}

impl FaultSpec {
    pub fn from_config(cfg: &SimConfig) -> FaultSpec {
        FaultSpec {
            scenario: cfg.scenario.clone(),
            base_loss: cfg.loss_prob,
            straggler: cfg.straggler,
            link_latency: cfg.link_latency,
        }
    }

    /// Compute-time multiplier for `node` at `t`: the scalar straggler
    /// knob times the product of active scenario schedules.
    pub fn compute_factor(&self, node: usize, t: f64) -> f64 {
        let scalar = match self.straggler {
            Some((s, f)) if s == node => f,
            _ => 1.0,
        };
        let scheduled = self
            .scenario
            .as_ref()
            .map_or(1.0, |sc| sc.compute_factor(node, t));
        scalar * scheduled
    }

    /// Effective Bernoulli drop probability at `t` (the loss ramp
    /// overrides the scalar knob from its first phase on).
    pub fn loss_prob(&self, t: f64) -> f64 {
        match &self.scenario {
            Some(sc) => sc.loss_prob(self.base_loss, t),
            None => self.base_loss,
        }
    }

    /// Multiplier on the mean link latency at `t` (1.0 when clean).
    pub fn latency_multiplier(&self, t: f64) -> f64 {
        self.scenario.as_ref().map_or(1.0, |sc| sc.latency_multiplier(t))
    }

    /// Extra one-way delay the wall-clock engine injects per message:
    /// `(multiplier − 1) × link_latency`, never negative. The simulator
    /// instead scales its lognormal latency draw by the multiplier — the
    /// runner's baseline latency is whatever the real channel costs, so
    /// only the *excess* over the configured mean is injected.
    pub fn injected_latency(&self, t: f64) -> f64 {
        (self.latency_multiplier(t) - 1.0).max(0.0) * self.link_latency
    }

    /// Is `node` inside a churn pause window at `t`? (A paused node
    /// starts no new iteration; receipt and in-flight work continue.)
    pub fn is_paused(&self, node: usize, t: f64) -> bool {
        self.scenario.as_ref().is_some_and(|sc| sc.is_paused(node, t))
    }

    /// Latest `resume_at` over the windows pausing `node` at `t`.
    pub fn next_resume(&self, node: usize, t: f64) -> Option<f64> {
        self.scenario.as_ref().and_then(|sc| sc.next_resume(node, t))
    }

    /// Serialization seconds for `bytes` on `from → to` under the
    /// tightest matching bandwidth cap (0 when uncapped).
    pub fn bandwidth_delay(&self, from: usize, to: usize, bytes: f64) -> f64 {
        self.scenario
            .as_ref()
            .map_or(0.0, |sc| sc.bandwidth_delay(from, to, bytes))
    }

    /// Payload size in bytes as the link layer charges it (f32 + f64
    /// lanes). Payloads are shared `Arc` slices (DESIGN.md §8), but the
    /// wire cost is the *logical* length — a zero-copy broadcast still
    /// pays full serialization per link under a bandwidth cap, exactly
    /// like a real NIC transmitting the same buffer to n peers.
    pub fn payload_bytes(msg: &Msg) -> f64 {
        (msg.payload.len() * 4 + msg.payload64.len() * 8) as f64
    }
}

/// Outcome of one send attempt through the link layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SendVerdict {
    /// The message goes out (and, for lossy algorithms, now owns its
    /// channel until the ack returns).
    Deliver,
    /// The channel still has an unacked packet — the sender withholds.
    Backpressured,
    /// The Bernoulli loss draw dropped it sender-side.
    Lost,
}

/// How `(from, to)` pairs map to channel-slot indices: the dense `n × n`
/// address space (unit tests that probe arbitrary pairs) or a
/// [`LinkIndex`] over the topology's actual links (slots scale with edge
/// count, not n²). Both engines route exclusively through the sparse
/// form; the dense form survives only behind the `#[cfg(test)]`
/// constructor below.
enum LinkMap {
    #[cfg_attr(not(test), allow(dead_code))]
    Dense { n: usize },
    Sparse(LinkIndex),
}

impl LinkMap {
    fn n(&self) -> usize {
        match self {
            LinkMap::Dense { n } => *n,
            LinkMap::Sparse(ix) => ix.n(),
        }
    }

    fn slots(&self) -> usize {
        match self {
            LinkMap::Dense { n } => n * n * MsgKind::CHANNELS,
            LinkMap::Sparse(ix) => ix.links() * MsgKind::CHANNELS,
        }
    }

    fn link_id(&self, from: usize, to: usize) -> Option<usize> {
        match self {
            LinkMap::Dense { n } => Some(from * n + to),
            LinkMap::Sparse(ix) => ix.link_id(from, to),
        }
    }
}

/// The shared fault/link layer: a clock, the fault spec, and the
/// one-unacked-packet channel slots, indexed identically in both engines.
pub struct FaultLayer<C: Clock, L: LinkSlots> {
    map: LinkMap,
    pub clock: C,
    pub spec: FaultSpec,
    links: L,
}

/// The simulator's instantiation (virtual time, single-threaded slots).
pub type SimFaultLayer = FaultLayer<VirtualClock, LocalLinks>;
/// The threaded runner's instantiation (wall time, atomic slots).
pub type RunnerFaultLayer = FaultLayer<WallClock, SharedLinks>;

impl<C: Clock, L: LinkSlots> FaultLayer<C, L> {
    /// Dense-addressed layer (`n² × CHANNELS` slots) — a test-only
    /// convenience for probing arbitrary `(from, to)` pairs without
    /// building a topology. Production engines construct via
    /// [`with_links`](Self::with_links) so channel-slot state scales with
    /// edge count.
    #[cfg(test)]
    pub fn new(n: usize, clock: C, spec: FaultSpec) -> FaultLayer<C, L> {
        Self::with_map(LinkMap::Dense { n }, clock, spec)
    }

    /// Sparse-addressed layer: slots only for the links `index` holds.
    pub fn with_links(index: LinkIndex, clock: C,
                      spec: FaultSpec) -> FaultLayer<C, L> {
        Self::with_map(LinkMap::Sparse(index), clock, spec)
    }

    fn with_map(map: LinkMap, clock: C, spec: FaultSpec) -> FaultLayer<C, L> {
        let slots = map.slots();
        FaultLayer { map, clock, spec, links: L::with_slots(slots) }
    }

    pub fn n(&self) -> usize {
        self.map.n()
    }

    /// Stable per-link id for `from → to` (`None` only under sparse
    /// addressing, for a pair the topology never routes). Callers size
    /// auxiliary per-link state (e.g. [`BwPacer`]) by `link_count` and
    /// index it with this.
    pub fn link_id(&self, from: usize, to: usize) -> Option<usize> {
        self.map.link_id(from, to)
    }

    /// Number of distinct link ids `link_id` can return.
    pub fn link_count(&self) -> usize {
        match &self.map {
            LinkMap::Dense { n } => n * n,
            LinkMap::Sparse(ix) => ix.links(),
        }
    }

    fn idx(&self, from: usize, to: usize, chan: usize) -> Option<usize> {
        self.map.link_id(from, to).map(|l| l * MsgKind::CHANNELS + chan)
    }

    /// Decide one send. For loss-tolerant algorithms: backpressure if the
    /// channel is busy, then the Bernoulli loss draw (consuming `rng`
    /// only when the drop probability is positive), then acquire the
    /// channel. Reliable algorithms always deliver.
    pub fn send_verdict(&self, lossy: bool, msg: &Msg,
                        rng: &mut Rng) -> SendVerdict {
        if !lossy {
            return SendVerdict::Deliver;
        }
        let Some(i) = self.idx(msg.from, msg.to, msg.kind.chan()) else {
            // Engines only send along topology links, so a sparse miss is
            // a routing bug; deliver rather than wedge a release build.
            debug_assert!(false, "send on unindexed link {} -> {}",
                          msg.from, msg.to);
            return SendVerdict::Deliver;
        };
        if self.links.busy(i) {
            return SendVerdict::Backpressured;
        }
        let p = self.spec.loss_prob(self.clock.now());
        if p > 0.0 && rng.chance(p) {
            return SendVerdict::Lost;
        }
        self.links.acquire(i);
        SendVerdict::Deliver
    }

    /// The receipt confirmation for channel `(from → to, chan)` arrived
    /// back at the sender: the channel is free again.
    pub fn ack(&self, from: usize, to: usize, chan: usize) {
        if let Some(i) = self.idx(from, to, chan) {
            self.links.release(i);
        } else {
            debug_assert!(false, "ack on unindexed link {from} -> {to}");
        }
    }
}

/// FIFO transmission queue per directed link: bandwidth-capped payloads
/// serialize behind each other, so the configured byte rate is a real
/// throughput bound (not just a fixed per-message delay) in either time
/// base. Index with `from * n + to`.
pub struct BwPacer {
    free_at: Vec<f64>,
}

impl BwPacer {
    pub fn new(links: usize) -> BwPacer {
        BwPacer { free_at: vec![0.0; links] }
    }

    /// Completion time of a payload needing `delay` seconds of link time,
    /// queued FIFO behind the link's previous transmissions.
    pub fn sent_at(&mut self, link: usize, now: f64, delay: f64) -> f64 {
        let start = self.free_at[link].max(now);
        self.free_at[link] = start + delay;
        self.free_at[link]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::{BandwidthCap, ChurnEvent, Phase};

    fn msg(from: usize, to: usize) -> Msg {
        Msg::new(from, to, MsgKind::V, 0, vec![0.0; 4])
    }

    #[test]
    fn clocks_report_their_time_base() {
        let v = VirtualClock::new();
        assert_eq!(v.now(), 0.0);
        v.advance_to(12.5);
        assert_eq!(v.now(), 12.5);
        let w = WallClock::start_now();
        let t0 = w.now();
        assert!(t0 >= 0.0);
        assert!(w.now() >= t0, "wall time is monotone");
    }

    #[test]
    fn spec_composes_scalar_and_scenario_faults() {
        let mut cfg = SimConfig::default();
        cfg.straggler = Some((1, 4.0));
        let mut sc = Scenario::single_straggler(1, 2.0);
        sc.loss_ramp.push(Phase { from_time: 10.0, value: 0.5 });
        sc.latency_ramp.push(Phase { from_time: 5.0, value: 3.0 });
        sc.churn.push(ChurnEvent { node: 2, pause_at: 1.0, resume_at: 2.0 });
        cfg.loss_prob = 0.1;
        cfg.link_latency = 0.02;
        cfg.scenario = Some(sc);
        let spec = FaultSpec::from_config(&cfg);

        // scalar straggler × scenario schedule
        assert_eq!(spec.compute_factor(1, 0.0), 8.0);
        assert_eq!(spec.compute_factor(0, 0.0), 1.0);
        // loss ramp overrides the scalar knob from its first phase on
        assert_eq!(spec.loss_prob(0.0), 0.1);
        assert_eq!(spec.loss_prob(10.0), 0.5);
        // latency ramp → injected wall delay is the excess over the mean
        assert_eq!(spec.injected_latency(0.0), 0.0);
        assert!((spec.injected_latency(5.0) - 0.04).abs() < 1e-12);
        // churn
        assert!(spec.is_paused(2, 1.5));
        assert_eq!(spec.next_resume(2, 1.5), Some(2.0));
        assert!(!spec.is_paused(2, 2.0));
    }

    #[test]
    fn verdict_order_backpressure_before_loss() {
        let mut cfg = SimConfig::default();
        cfg.loss_prob = 0.5;
        let spec = FaultSpec::from_config(&cfg);
        let layer: FaultLayer<VirtualClock, LocalLinks> =
            FaultLayer::new(3, VirtualClock::new(), spec);
        let mut rng = Rng::new(7);
        // send until one delivery occupies the channel (p(all 64 drawn
        // lost) = 2^-64: the loop observes both Lost and Deliver verdicts
        // while the channel is free, never Backpressured)
        let m = msg(0, 1);
        let mut got_deliver = false;
        for _ in 0..64 {
            match layer.send_verdict(true, &m, &mut rng) {
                SendVerdict::Deliver => {
                    got_deliver = true;
                    break;
                }
                SendVerdict::Lost => {}
                SendVerdict::Backpressured => {
                    panic!("channel was free; backpressure impossible")
                }
            }
        }
        assert!(got_deliver, "p = 0.5 must deliver within 64 tries");
        // now the channel is busy: verdict must be backpressure, and the
        // rng must NOT be consumed by the rejected sends
        let snapshot = rng.clone();
        assert_eq!(layer.send_verdict(true, &m, &mut rng),
                   SendVerdict::Backpressured);
        assert_eq!(layer.send_verdict(true, &m, &mut rng),
                   SendVerdict::Backpressured);
        let mut probe = snapshot;
        assert_eq!(probe.next_u64(), rng.clone().next_u64(),
                   "backpressured sends must not advance the loss rng");
        // ack frees exactly this channel
        layer.ack(0, 1, m.kind.chan());
        assert_ne!(layer.send_verdict(true, &m, &mut rng),
                   SendVerdict::Backpressured);
    }

    #[test]
    fn reliable_algorithms_bypass_the_link_discipline() {
        let spec = FaultSpec::from_config(&SimConfig::default());
        let layer: FaultLayer<VirtualClock, LocalLinks> =
            FaultLayer::new(2, VirtualClock::new(), spec);
        let mut rng = Rng::new(1);
        for _ in 0..4 {
            assert_eq!(layer.send_verdict(false, &msg(0, 1), &mut rng),
                       SendVerdict::Deliver);
        }
    }

    #[test]
    fn distinct_channels_do_not_collide() {
        let spec = FaultSpec::from_config(&SimConfig::default());
        let layer: FaultLayer<VirtualClock, LocalLinks> =
            FaultLayer::new(2, VirtualClock::new(), spec);
        let mut rng = Rng::new(2);
        let v = msg(0, 1); // chan 0
        let rho = Msg::new64(0, 1, MsgKind::Rho, 0, vec![0.0; 4]); // chan 1
        assert_eq!(layer.send_verdict(true, &v, &mut rng), SendVerdict::Deliver);
        // same link, different kind: its own socket
        assert_eq!(layer.send_verdict(true, &rho, &mut rng),
                   SendVerdict::Deliver);
        // reverse direction unaffected
        assert_eq!(layer.send_verdict(true, &msg(1, 0), &mut rng),
                   SendVerdict::Deliver);
        // but the v channel itself is now busy
        assert_eq!(layer.send_verdict(true, &v, &mut rng),
                   SendVerdict::Backpressured);
    }

    #[test]
    fn link_index_matches_neighbor_lists() {
        // node 0 ↔ 1 (both matrices), 1 → 2 in W only, duplicates across
        // the four direction lists collapse to one link id.
        let w_in = vec![vec![1], vec![0], vec![1]];
        let w_out = vec![vec![1], vec![0, 2], vec![]];
        let a_in = vec![vec![1], vec![0], vec![]];
        let a_out = vec![vec![1], vec![0], vec![]];
        let ix = LinkIndex::from_neighbor_lists(3, [&w_in, &w_out, &a_in, &a_out]);
        assert_eq!(ix.n(), 3);
        assert_eq!(ix.links(), 3); // 0→1, 1→0, 1→2
        assert_eq!(ix.link_id(0, 1), Some(0));
        assert_eq!(ix.link_id(1, 0), Some(1));
        assert_eq!(ix.link_id(1, 2), Some(2));
        assert_eq!(ix.link_id(2, 1), None, "W-in-only peers point the other way");
        assert_eq!(ix.link_id(0, 2), None);
        assert_eq!(ix.link_id(0, 0), None, "self-links are never indexed");
    }

    #[test]
    fn link_index_from_weights_covers_every_routed_pair() {
        let topo = crate::graph::Topology::binary_tree(7);
        let ix = LinkIndex::from_weights(&topo.weights);
        let wm = &topo.weights;
        for i in 0..7 {
            for &j in wm.w_out[i].iter().chain(&wm.w_in[i])
                .chain(&wm.a_out[i]).chain(&wm.a_in[i])
            {
                assert!(ix.link_id(i, j).is_some(), "missing link {i} -> {j}");
            }
        }
        // ids are dense and unique
        let mut seen = vec![false; ix.links()];
        for i in 0..7 {
            for j in 0..7 {
                if let Some(l) = ix.link_id(i, j) {
                    assert!(!seen[l], "duplicate link id {l}");
                    seen[l] = true;
                }
            }
        }
        assert!(seen.iter().all(|&s| s), "link ids must be dense 0..links()");
    }

    #[test]
    fn sparse_layer_mirrors_dense_verdicts_on_topology_links() {
        let topo = crate::graph::Topology::ring(4);
        let mut cfg = SimConfig::default();
        cfg.loss_prob = 0.5;
        let spec = FaultSpec::from_config(&cfg);
        let dense: FaultLayer<VirtualClock, LocalLinks> =
            FaultLayer::new(4, VirtualClock::new(), spec.clone());
        let sparse: FaultLayer<VirtualClock, LocalLinks> =
            FaultLayer::with_links(LinkIndex::from_weights(&topo.weights),
                                   VirtualClock::new(), spec);
        assert_eq!(sparse.n(), 4);
        assert!(sparse.link_count() < dense.link_count());
        let mut rd = Rng::new(11);
        let mut rs = Rng::new(11);
        // replay an identical lossy traffic pattern on ring links; the
        // verdict sequence (and hence rng consumption) must be identical
        let pattern = [(0, 1), (1, 2), (0, 1), (2, 3), (3, 0), (1, 2)];
        for (k, &(f, t)) in pattern.iter().enumerate() {
            let m = msg(f, t);
            let vd = dense.send_verdict(true, &m, &mut rd);
            let vs = sparse.send_verdict(true, &m, &mut rs);
            assert_eq!(vd, vs, "verdict diverged at step {k}");
            if vd == SendVerdict::Deliver && k % 2 == 0 {
                dense.ack(f, t, m.kind.chan());
                sparse.ack(f, t, m.kind.chan());
            }
        }
        assert_eq!(rd.next_u64(), rs.next_u64(),
                   "loss rng streams must stay in lockstep");
    }

    #[test]
    fn bw_pacer_serializes_fifo() {
        let mut bw = BwPacer::new(4);
        // two back-to-back 1-second payloads on link 0 queue up
        assert_eq!(bw.sent_at(0, 0.0, 1.0), 1.0);
        assert_eq!(bw.sent_at(0, 0.0, 1.0), 2.0);
        // a later send after the queue drained starts fresh
        assert_eq!(bw.sent_at(0, 5.0, 1.0), 6.0);
        // other links are independent
        assert_eq!(bw.sent_at(1, 0.0, 0.5), 0.5);
    }

    #[test]
    fn bandwidth_delay_through_spec() {
        let mut cfg = SimConfig::default();
        let mut sc = Scenario::named("bw", "");
        sc.bandwidth.push(BandwidthCap {
            from: None,
            to: None,
            bytes_per_sec: 100.0,
        });
        cfg.scenario = Some(sc);
        let spec = FaultSpec::from_config(&cfg);
        let m = msg(0, 1); // 4 f32 = 16 bytes
        assert!((spec.bandwidth_delay(0, 1, FaultSpec::payload_bytes(&m))
                 - 0.16)
                    .abs()
                < 1e-12);
        assert_eq!(FaultSpec::payload_bytes(
                       &Msg::new64(0, 1, MsgKind::Rho, 0, vec![0.0; 2])),
                   16.0);
    }
}
