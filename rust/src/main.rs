//! `repro` — the R-FAST launcher.
//!
//! ```text
//! repro train   --algo rfast --topology ring --nodes 8 --model logreg
//!               [--engine sim|threaded] [--scenario NAME|FILE.json]
//!               [--gamma G] [--seed S] [--straggler NODE:FACTOR]
//!               [--loss-prob P] [--skew ALPHA] [--pace SECONDS]
//!               [--time T | --iters K] [--oracle pjrt|rust]
//!               [--out runs/NAME]
//! repro scenarios [--export DIR]       # list / export the fault presets
//! repro fuzz    [--seed S] [--budget N] [--shrink] [--out DIR]
//!               [--replay DIR]         # deterministic fault-space fuzzer
//! repro bench-baseline [--out DIR]     # perf baselines: hot-path suite +
//!                                      # scaling sweep → BENCH_*.json
//! repro lint    [--baseline LINT_BASELINE.json] [--fix-baseline]
//!               [--root DIR] [--paths a,b,c] [--out FILE]
//!                                      # determinism & hot-path analyzer
//! repro graph   --topology binary_tree --nodes 7      # inspect W/A, roots
//! repro check-artifacts                               # load + smoke-run
//! repro algos                                         # list algorithms
//! repro help
//!
//! A bare option list defaults to `train`, so
//! `repro --scenario paper_fig6_straggler` runs the paper's straggler
//! regime end-to-end.
//! ```

use rfast::algo::AlgoKind;
use rfast::cli::Args;
use rfast::config::SimConfig;
use rfast::data::{Dataset, Partition};
use rfast::exp::{Engine, Experiment, Stop, Workload};
use rfast::graph::Topology;
use rfast::metrics::Table;
use rfast::runner::MailboxCfg;
use rfast::runtime::{self, Manifest, PjrtTask};
use rfast::scenario::Scenario;
use rfast::sim::Simulator;
use std::path::PathBuf;
use std::sync::Arc;

/// Counting allocator (exp::bench) so `bench-baseline` and the hot-path
/// suite report real allocations-per-wake; two relaxed atomic adds per
/// allocation, negligible for every other subcommand.
#[global_allocator]
static ALLOC: rfast::exp::bench::CountingAllocator =
    rfast::exp::bench::CountingAllocator;

fn main() {
    let mut raw: Vec<String> = std::env::args().skip(1).collect();
    // a bare option list (e.g. `repro --scenario lossy_30pct`) is a train run
    if raw
        .first()
        .map(|a| a.starts_with("--") && a != "--help")
        .unwrap_or(false)
    {
        raw.insert(0, "train".to_string());
    }
    let args = match Args::parse(raw) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n");
            print_help();
            std::process::exit(2);
        }
    };
    let result = match args.subcommand.as_str() {
        "train" => cmd_train(&args),
        "graph" => cmd_graph(&args),
        "check-artifacts" => cmd_check_artifacts(),
        "scenarios" => cmd_scenarios(&args),
        "fuzz" => cmd_fuzz(&args),
        "bench-baseline" => cmd_bench_baseline(&args),
        "lint" => cmd_lint(&args),
        "algos" => {
            cmd_algos();
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => Err(format!("unknown subcommand {other:?} (try `repro help`)")),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "repro — R-FAST reproduction launcher\n\n\
         subcommands:\n  \
         train            run one training experiment (virtual-time simulator or\n                          wall-clock threaded runner; see --engine)\n  \
         scenarios        list fault-injection presets (--export DIR writes JSON)\n  \
         fuzz             deterministic fault-space fuzzer: --seed S (default 0)\n                          generates --budget N cases (default 50; env\n                          RFAST_FUZZ_BUDGET) of random scenarios × random\n                          spanning-tree pairs, checks the invariant oracles,\n                          exits 1 on any violation. --shrink reduces each\n                          failure to a minimal JSON repro in --out (default\n                          rust/tests/repros). --replay DIR re-checks every\n                          committed repro instead (DESIGN.md \u{a7}11).\n                          --engine threaded replays a small budget (default 8)\n                          on the wall-clock actor runner, checking the\n                          schedule-independent oracles (no shrink)\n  \
         bench-baseline   run the hot-path suite + scaling sweep (8→64-node\n                          binary tree, then the 1k–50k sparse-era points) and\n                          write BENCH_hotpath.json / BENCH_scaling.json to --out\n                          (default .). RFAST_BENCH_EPOCHS sets the sweep's epoch\n                          budget (default 3; ≤1 implies quick mode);\n                          RFAST_BENCH_SCALE_MAX caps the large points by node\n                          count (0 drops them). Fails if the emitted JSON is\n                          schema-invalid (EXPERIMENTS.md).\n  \
         lint             determinism, hot-path & concurrency static analyzer\n                          (DESIGN.md \u{a7}12, \u{a7}14): scans rust/src, rust/benches,\n                          rust/tests, examples; --baseline LINT_BASELINE.json\n                          gates on the ratchet (counts may only shrink),\n                          --fix-baseline rewrites it, --out FILE writes the\n                          findings JSON, --format github emits ::error\n                          annotations, --root/--paths override the scan set.\n                          Waive a finding in place with\n                          `// lint:allow(RULE): reason` (reason mandatory;\n                          a waiver that suppresses nothing is itself an error)\n  \
         graph            print a topology's W/A structure, roots, assumption check\n                          (--analyze [--delay D]: Lemma-1 contraction/ψ analysis)\n  \
         check-artifacts  load every AOT artifact and smoke-run it\n  \
         algos            list implemented algorithms\n  \
         help             this text\n\n\
         train options:\n  \
         --algo NAME        rfast|rfast-naive|pushpull|sab|dpsgd|adpsgd|osgp|allreduce\n  \
         --topology SPEC    binary_tree|line|ring|exponential|mesh|star|gossip, or\n                          an asymmetric pull+push spanning-tree pair\n                          [tree:]PULL+PUSH with PULL/PUSH = KIND[@ROOT][:SEED],\n                          KIND = bfs|dfs|balanced|chain|star|random —\n                          e.g. tree:bfs@0+star@0 (DESIGN.md \u{a7}10)\n  \
         --nodes N          node count (default 8)\n  \
         --model NAME       logreg|mlp (which oracle/workload; default logreg)\n  \
         --engine E         sim (virtual time, default) | threaded (actor pool,\n                          wall clock; logreg + rust oracle) | both (run\n                          sim AND threaded, emit side-by-side comparison CSVs)\n  \
         --oracle KIND      rust|pjrt (default rust; pjrt needs `make artifacts`)\n  \
         --scenario S       fault preset name or scenario .json path; drives\n                          either engine (see `repro scenarios`)\n  \
         --gamma G          step size\n  --seed S\n  \
         --straggler N:F    slow node N down by factor F\n  \
         --loss-prob P      packet loss probability (async algos)\n  \
         --skew A           label-skew heterogeneity in [0,1]\n  \
         --pace S           threaded engine: min seconds per local iteration\n                          (default compute_mean; 0 disables)\n  \
         --workers N        threaded engine: OS worker threads multiplexing the\n                          node actors (default: one per core, \u{2264} node count)\n  \
         --mailbox C[:P]    threaded engine: per-actor mailbox capacity + overflow\n                          policy backpressure|drop-newest|drop-oldest\n                          (default 1024:backpressure)\n  \
         --stop SPEC        unified stop rule: time:T | iters:K | epochs:E |\n                          loss:L[:MAX_T]  (time is virtual s on sim, wall s on\n                          threaded — DESIGN.md \u{a7}9)\n  \
         --time T           shorthand for --stop time:T (default 300; threaded:\n                          30). Rejected with --engine both (clock-ambiguous;\n                          default there is iters:2000 — use --stop to override)\n  \
         --iters K          shorthand for --stop iters:K\n  \
         --out PATH         write the JSON report here (default runs/train.json;\n                          --engine both also writes PATH-stem comparison CSVs)"
    );
}

fn cmd_algos() {
    let mut t = Table::new("algorithms", &["name", "async", "loss-tolerant"]);
    for k in [
        AlgoKind::RFast,
        AlgoKind::RFastNaive,
        AlgoKind::PushPull,
        AlgoKind::SAb,
        AlgoKind::DPsgd,
        AlgoKind::AdPsgd,
        AlgoKind::Osgp,
        AlgoKind::RingAllReduce,
    ] {
        t.row(vec![
            k.name().to_string(),
            k.is_async().to_string(),
            k.tolerates_loss().to_string(),
        ]);
    }
    t.print();
}

/// List the built-in fault-injection presets; `--export DIR` writes each
/// as `DIR/<name>.json` (edit + pass back via `--scenario FILE.json`).
fn cmd_scenarios(args: &Args) -> Result<(), String> {
    let mut t = Table::new("fault-injection scenario presets",
                           &["name", "description"]);
    for name in Scenario::preset_names() {
        let s = Scenario::by_name(name)
            .ok_or_else(|| format!("preset {name:?} missing from registry"))?;
        t.row(vec![name.to_string(), s.description.clone()]);
    }
    t.print();
    if let Some(dir) = args.get("export") {
        let dir = PathBuf::from(dir);
        std::fs::create_dir_all(&dir).map_err(|e| e.to_string())?;
        for name in Scenario::preset_names() {
            let s = Scenario::by_name(name)
                .ok_or_else(|| format!("preset {name:?} missing from registry"))?;
            let path = dir.join(format!("{name}.json"));
            std::fs::write(&path, s.to_json().to_string())
                .map_err(|e| format!("write {}: {e}", path.display()))?;
            println!("wrote {}", path.display());
        }
    } else {
        println!("\nrun one with:  repro train --scenario NAME");
        println!("export JSON:   repro scenarios --export DIR");
    }
    Ok(())
}

/// `repro fuzz` — the deterministic fault-space fuzzer (DESIGN.md §11).
/// Output is a pure function of (--seed, --budget, --shrink): no wall
/// clock, no ambient randomness — two invocations print identical bytes,
/// which CI relies on. Exit 1 on any invariant violation (generated or
/// replayed), so the command is a gate, not a report.
fn cmd_fuzz(args: &Args) -> Result<(), String> {
    use rfast::fuzz::{self, Repro};

    if let Some(dir) = args.get("replay") {
        return fuzz_replay(PathBuf::from(dir));
    }
    let engine = args.get_or("engine", "sim");
    if !["sim", "threaded"].contains(&engine.as_str()) {
        return Err(format!(
            "fuzz: unknown --engine {engine:?} (sim|threaded)"
        ));
    }
    let seed: u64 = args.parse_num("seed", 0u64)?;
    let default_budget = if engine == "threaded" {
        fuzz::DEFAULT_THREADED_BUDGET
    } else {
        fuzz::DEFAULT_BUDGET
    };
    let budget: u64 = match args.get("budget") {
        Some(v) => v
            .parse()
            .map_err(|_| format!("--budget: bad count {v:?}"))?,
        None => match std::env::var("RFAST_FUZZ_BUDGET") {
            Ok(v) => v.parse().map_err(|_| {
                format!("RFAST_FUZZ_BUDGET: bad value {v:?}")
            })?,
            Err(_) => default_budget,
        },
    };
    let do_shrink = args.has_flag("shrink");
    if engine == "threaded" {
        // wall-clock verdicts depend on real scheduling: no shrinker, no
        // committed repros — reproduce the fault schedule under the
        // virtual-time engine for a deterministic minimal case
        if do_shrink {
            return Err("fuzz: --shrink needs the deterministic engine \
                        (drop --engine threaded)"
                .into());
        }
        println!("fuzz: engine=threaded seed={seed} budget={budget}");
        let report = fuzz::run_corpus_threaded(seed, budget);
        if report.failures.is_empty() {
            println!(
                "fuzz: {budget} cases on the actor runner, liveness and \
                 counter oracles held"
            );
            return Ok(());
        }
        for f in &report.failures {
            println!("case {}: VIOLATION {} — {}", f.case_index,
                     f.violation, f.detail);
            println!(
                "  generated: n={} arch={} iters={} gamma={} seed={} \
                 clauses={}",
                f.case.n, f.case.arch.name(), f.case.iters, f.case.gamma,
                f.case.seed, fault_clauses(&f.case),
            );
        }
        return Err(format!(
            "fuzz: {} of {budget} cases violated an invariant on the \
             actor runner",
            report.failures.len()
        ));
    }
    println!("fuzz: seed={seed} budget={budget} shrink={do_shrink}");

    let report = fuzz::run_corpus(seed, budget, do_shrink);
    if report.failures.is_empty() {
        println!("fuzz: {budget} cases, every invariant held");
        return Ok(());
    }
    let out_dir = PathBuf::from(args.get_or("out", "rust/tests/repros"));
    for f in &report.failures {
        println!("case {}: VIOLATION {} — {}", f.case_index, f.violation,
                 f.detail);
        println!(
            "  generated: n={} arch={} iters={} gamma={} seed={} \
             clauses={}",
            f.case.n, f.case.arch.name(), f.case.iters, f.case.gamma,
            f.case.seed, fault_clauses(&f.case),
        );
        let minimal = f.shrunk.as_ref().unwrap_or(&f.case);
        if f.shrunk.is_some() {
            println!(
                "  shrunk to: n={} arch={} iters={} gamma={} clauses={}",
                minimal.n, minimal.arch.name(), minimal.iters,
                minimal.gamma, fault_clauses(minimal),
            );
        }
        std::fs::create_dir_all(&out_dir)
            .map_err(|e| format!("create {}: {e}", out_dir.display()))?;
        let path = out_dir
            .join(format!("fuzz_seed{}_case{}.json", seed, f.case_index));
        let repro = Repro {
            case: minimal.clone(),
            expect: "fail".into(),
            violation: Some(f.violation.to_string()),
        };
        std::fs::write(&path, repro.to_json().to_string())
            .map_err(|e| format!("write {}: {e}", path.display()))?;
        println!("  repro: {}", path.display());
    }
    Err(format!(
        "fuzz: {} of {budget} cases violated an invariant",
        report.failures.len()
    ))
}

fn fault_clauses(c: &rfast::fuzz::FuzzCase) -> usize {
    let s = &c.scenario;
    s.stragglers.len() + s.loss_ramp.len() + s.latency_ramp.len()
        + s.churn.len() + s.bandwidth.len()
}

/// `repro fuzz --replay DIR`: re-run every committed `*.json` repro and
/// compare against its recorded verdict.
fn fuzz_replay(dir: PathBuf) -> Result<(), String> {
    use rfast::fuzz::Repro;

    let mut paths: Vec<PathBuf> = std::fs::read_dir(&dir)
        .map_err(|e| format!("read {}: {e}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().and_then(|x| x.to_str()) == Some("json"))
        .collect();
    paths.sort();
    if paths.is_empty() {
        return Err(format!("no *.json repros in {}", dir.display()));
    }
    let mut regressed = 0usize;
    for path in &paths {
        let repro = Repro::load(path)?;
        match repro.replay() {
            Ok(()) => println!(
                "replay {}: ok (expect {})",
                path.display(), repro.expect
            ),
            Err(e) => {
                println!("replay {}: REGRESSED — {e}", path.display());
                regressed += 1;
            }
        }
    }
    if regressed > 0 {
        Err(format!("{regressed} of {} repro(s) regressed", paths.len()))
    } else {
        println!("replay: {} repro(s) behave as committed", paths.len());
        Ok(())
    }
}

/// `repro lint` — the determinism & hot-path static analyzer (DESIGN.md
/// §12). Scans the default path set (or `--paths a,b,c`) under `--root`
/// (auto-detected: the nearest ancestor holding `rust/src`), prints every
/// finding, and gates:
///
/// * with `--baseline FILE`: diff against the grandfathered counts —
///   regressions or malformed waivers exit non-zero, improvements pass
///   with a nudge to `--fix-baseline`;
/// * with `--fix-baseline`: rewrite FILE from this scan (refused while
///   malformed or stale waivers exist — they are never baselineable);
/// * with neither: any finding at all exits non-zero.
///
/// `--out FILE` additionally writes the findings JSON
/// (`rfast-lint-findings/v2`) — CI uploads it on failure. `--format
/// github` switches the per-finding lines (and ratchet regressions) to
/// GitHub Actions `::error` annotations so CI failures land on the
/// offending line in the PR diff; the summary/nudge lines stay plain.
fn cmd_lint(args: &Args) -> Result<(), String> {
    use rfast::lint;

    let root = match args.get("root") {
        Some(r) => PathBuf::from(r),
        None => detect_repo_root()?,
    };
    let mut cfg = lint::LintConfig::new(root);
    if let Some(paths) = args.get("paths") {
        cfg.paths = paths
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
        if cfg.paths.is_empty() {
            return Err("--paths: empty list".into());
        }
    }
    let github = match args.get("format") {
        None => false,
        Some("github") => true,
        Some(other) => {
            return Err(format!("--format {other}: expected `github`"));
        }
    };
    let report = lint::run(&cfg)?;

    for f in report.findings.iter().chain(report.waiver_errors.iter()) {
        if github {
            println!("{}", lint::github_annotation(f));
        } else {
            println!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.detail);
        }
    }
    println!(
        "lint: {} file(s), {} finding(s), {} waiver(s) used, {} bad \
         waiver(s)",
        report.files_scanned,
        report.findings.len(),
        report.waivers_used,
        report.waiver_errors.len(),
    );

    let baseline_path = args.get("baseline").map(PathBuf::from);
    let current = lint::Baseline::from_report(&report);

    let ratchet = match &baseline_path {
        Some(path) if !args.has_flag("fix-baseline") => {
            Some(lint::Baseline::load(path)?.diff(&current))
        }
        _ => None,
    };
    if let Some(out) = args.get("out") {
        let j = lint::findings_json(&report, ratchet.as_ref());
        std::fs::write(out, lint::to_pretty(&j))
            .map_err(|e| format!("write {out}: {e}"))?;
        println!("findings: {out}");
    }
    if !report.waiver_errors.is_empty() {
        return Err(format!(
            "{} malformed or stale waiver pragma(s) — fix or remove them; \
             they are never baselineable",
            report.waiver_errors.len()
        ));
    }
    match (baseline_path, args.has_flag("fix-baseline")) {
        (Some(path), true) => {
            std::fs::write(&path, lint::to_pretty(&current.to_json()))
                .map_err(|e| format!("write {}: {e}", path.display()))?;
            println!("baseline rewritten: {}", path.display());
            Ok(())
        }
        (None, true) => Err("--fix-baseline needs --baseline FILE".into()),
        (Some(path), false) => {
            // ratchet was computed above; unwrap-free by construction
            let r = ratchet.unwrap_or_default();
            for d in &r.regressions {
                if github {
                    println!("{}", lint::github_delta_annotation(d));
                } else {
                    println!(
                        "RATCHET: {} in {} went {} -> {} (new findings \
                         need a fix or a waiver, not a bigger baseline)",
                        d.rule, d.file, d.base, d.cur
                    );
                }
            }
            if !r.improvements.is_empty() {
                println!(
                    "ratchet: {} cell(s) improved — run `repro lint \
                     --baseline {} --fix-baseline` to lock the gain in",
                    r.improvements.len(),
                    path.display()
                );
            }
            if r.is_clean() {
                println!("lint: clean against {}", path.display());
                Ok(())
            } else {
                Err(format!(
                    "{} ratchet regression(s) vs {}",
                    r.regressions.len(),
                    path.display()
                ))
            }
        }
        (None, false) => {
            if report.findings.is_empty() {
                Ok(())
            } else {
                Err(format!(
                    "{} finding(s) (no --baseline given)",
                    report.findings.len()
                ))
            }
        }
    }
}

/// Nearest ancestor of the cwd containing `rust/src` — lets `repro lint`
/// run from the repo root or anywhere inside it.
fn detect_repo_root() -> Result<PathBuf, String> {
    let cwd = std::env::current_dir().map_err(|e| e.to_string())?;
    let mut dir = cwd.as_path();
    loop {
        if dir.join("rust/src").is_dir() {
            return Ok(dir.to_path_buf());
        }
        match dir.parent() {
            Some(p) => dir = p,
            None => {
                return Err(format!(
                    "no rust/src above {} — pass --root DIR",
                    cwd.display()
                ))
            }
        }
    }
}

/// `repro bench-baseline [--out DIR]` — seed/refresh the perf trajectory:
/// run the hot-path micro suite (ns/iter + allocs/iter via the counting
/// allocator installed above) and the 8→64-node scaling sweep, write
/// `BENCH_hotpath.json` / `BENCH_scaling.json`, then re-read both and
/// fail on schema-invalid output (the CI bench-smoke gate). Methodology
/// and schema: EXPERIMENTS.md.
fn cmd_bench_baseline(args: &Args) -> Result<(), String> {
    use rfast::exp::bench;

    let out = PathBuf::from(args.get_or("out", "."));
    std::fs::create_dir_all(&out)
        .map_err(|e| format!("create {}: {e}", out.display()))?;
    let epochs: f64 = match std::env::var("RFAST_BENCH_EPOCHS") {
        Ok(v) => v
            .parse()
            .map_err(|_| format!("RFAST_BENCH_EPOCHS: bad value {v:?}"))?,
        Err(_) => 3.0,
    };
    if !(epochs > 0.0) {
        return Err(format!("RFAST_BENCH_EPOCHS must be > 0, got {epochs}"));
    }
    let quick = std::env::var("RFAST_BENCH_QUICK").is_ok() || epochs <= 1.0;
    // RFAST_BENCH_SCALE_MAX caps the sparse-era large points (1k–50k
    // nodes) by node count: 0 drops them, unset runs them all.
    let scale_max: usize = match std::env::var("RFAST_BENCH_SCALE_MAX") {
        Ok(v) => v
            .parse()
            .map_err(|_| format!("RFAST_BENCH_SCALE_MAX: bad value {v:?}"))?,
        Err(_) => usize::MAX,
    };
    let mut specs: Vec<bench::ScalingSpec> = bench::SCALING_NODES
        .iter()
        .map(|&n| bench::ScalingSpec {
            nodes: n,
            topology: "binary_tree",
            workload: "logreg",
        })
        .collect();
    specs.extend(bench::SCALING_LARGE
        .iter()
        .filter(|s| s.nodes <= scale_max)
        .copied());
    println!(
        "bench-baseline: hot-path suite (quick={quick}, allocs \
         counted={}) + scaling sweep ({epochs} epochs, nodes {:?})",
        bench::counting_allocator_active(),
        specs.iter().map(|s| s.nodes).collect::<Vec<_>>(),
    );

    let hot = bench::hotpath_suite(quick);
    println!("\n== hot-path suite ==");
    for r in &hot {
        println!("{}", r.report());
    }
    let hot_path = out.join("BENCH_hotpath.json");
    std::fs::write(&hot_path, bench::hotpath_json(&hot, quick).to_string())
        .map_err(|e| format!("write {}: {e}", hot_path.display()))?;

    let points = bench::scaling_sweep_specs(&specs, epochs);
    let mut t = Table::new(
        "scaling sweep (R-FAST)",
        &["nodes", "topology", "workload", "virtual s", "wall s",
          "grad wakes", "MB sent", "MB/epoch"],
    );
    for p in &points {
        t.row(vec![
            p.nodes.to_string(),
            p.topology.clone(),
            p.workload.clone(),
            format!("{:.2}", p.virtual_time),
            format!("{:.2}", p.wall_seconds),
            format!("{:.0}", p.grad_wakes),
            format!("{:.2}", p.bytes_sent / 1e6),
            format!("{:.2}", p.bytes_sent / 1e6 / p.epoch.max(1e-9)),
        ]);
    }
    t.print();
    let scaling_path = out.join("BENCH_scaling.json");
    std::fs::write(&scaling_path,
                   bench::scaling_json(&points, epochs).to_string())
        .map_err(|e| format!("write {}: {e}", scaling_path.display()))?;

    // the gate: re-read what landed on disk and validate the schema
    type Validator = fn(&rfast::jsonio::Json) -> Result<(), String>;
    let gates: [(&PathBuf, Validator); 2] = [
        (&hot_path, bench::validate_hotpath_json),
        (&scaling_path, bench::validate_scaling_json),
    ];
    for (path, validate) in gates {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("re-read {}: {e}", path.display()))?;
        let j = rfast::jsonio::parse(&text)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        validate(&j)
            .map_err(|e| format!("{}: schema invalid: {e}", path.display()))?;
        println!("schema-valid: {}", path.display());
    }
    Ok(())
}

fn cmd_graph(args: &Args) -> Result<(), String> {
    let n: usize = args.parse_num("nodes", 7usize)?;
    let topo =
        Topology::from_spec(&args.get_or("topology", "binary_tree"), n)?;
    let wm = &topo.weights;
    println!("topology {} over {} nodes", topo.name(), n);
    println!("G(W) edges (j→i, i pulls from j):");
    for i in 0..n {
        for &j in &wm.w_in[i] {
            println!("  {j} → {i}   w[{i}][{j}] = {:.3}", wm.w.get(i, j));
        }
    }
    println!("G(A) edges (i→j, i pushes to j):");
    for i in 0..n {
        for &j in &wm.a_out[i] {
            println!("  {i} → {j}   a[{j}][{i}] = {:.3}", wm.a.get(j, i));
        }
    }
    println!("roots of G(W):  {:?}", wm.roots_w());
    println!("roots of G(Aᵀ): {:?}", wm.roots_at());
    println!("common roots R: {:?}", wm.common_roots());
    let errs = wm.check_assumptions();
    if errs.is_empty() {
        println!("Assumptions 1-2: OK (m̄ = {:.4})", wm.min_weight());
    } else {
        for e in errs {
            println!("VIOLATION: {e}");
        }
    }
    if args.has_flag("analyze") {
        let delay: usize = args.parse_num("delay", 2usize)?;
        let a = rfast::graph::AugmentedAnalysis::estimate(&topo, delay);
        println!("\naugmented-system analysis (Lemma 1, D = {delay}):");
        println!("  contraction ρ̂        = {:.5}", a.rho_w);
        println!("  iters to consensus   = {}", a.iters_to_consensus);
        println!("  Lemma-1 η bound      = {:.3e} (K1 = {})", a.eta_bound, a.k1);
        for (r, p) in &a.psi_roots {
            println!("  ψ mass at root {r}    = {p:.4}");
        }
        println!("  γ̄ hint (L=1)         ≈ {:.4}", a.gamma_hint(1.0));
    }
    Ok(())
}

fn cmd_check_artifacts() -> Result<(), String> {
    let dir = runtime::default_artifact_dir()
        .ok_or("no artifacts/ found — run `make artifacts`")?;
    println!("artifacts: {}", dir.display());
    let manifest = Manifest::load(&dir)?;
    let mut t = Table::new("artifacts", &["name", "inputs", "outputs", "status"]);
    for (name, info) in &manifest.artifacts {
        let status = match rfast::runtime::Engine::load(&manifest, &[name]) {
            Ok(engine) => {
                // smoke-run with zero inputs of the right shapes
                let zeros_f: Vec<Vec<f32>> = info
                    .inputs
                    .iter()
                    .map(|s| vec![0.0f32; s.numel()])
                    .collect();
                let zeros_i: Vec<Vec<i32>> = info
                    .inputs
                    .iter()
                    .map(|s| vec![0i32; s.numel()])
                    .collect();
                let inputs: Vec<rfast::runtime::Input<'_>> = info
                    .inputs
                    .iter()
                    .enumerate()
                    .map(|(k, s)| match s.dtype.as_str() {
                        "int32" => rfast::runtime::Input::I32(&zeros_i[k]),
                        _ => rfast::runtime::Input::F32(&zeros_f[k]),
                    })
                    .collect();
                match engine.run(name, &inputs) {
                    Ok(_) => "ok".to_string(),
                    Err(e) => format!("EXEC FAIL: {e}"),
                }
            }
            Err(e) => format!("COMPILE FAIL: {e}"),
        };
        t.row(vec![
            name.clone(),
            format!("{}", info.inputs.len()),
            format!("{}", info.outputs.len()),
            status,
        ]);
    }
    t.print();
    for (name, m) in &manifest.models {
        let init = manifest.load_init(name)?;
        println!("model {name}: p = {} (init ‖θ‖ = {:.3})", m.p,
                 rfast::linalg::norm(&init));
    }
    Ok(())
}

/// The unified stop rule: `--stop kind:value` wins, then the `--iters` /
/// `--time` shorthands, then the per-engine default.
fn resolve_stop(args: &Args, engine: &str) -> Result<Stop, String> {
    // Stop::Time reads each engine's own clock, so --time is ambiguous
    // with --engine both; rejected up front so it can never be silently
    // shadowed by --stop/--iters either
    if engine == "both" && args.get("time").is_some() {
        return Err("--time is ambiguous with --engine both (virtual \
                    seconds on sim, wall seconds on threaded); use \
                    --stop time:T to opt into the per-engine clocks, or \
                    --stop iters:K"
            .into());
    }
    if let Some(spec) = args.get("stop") {
        return Stop::parse(spec);
    }
    if let Some(iters) = args.get("iters") {
        return Ok(Stop::Iterations(
            iters.parse().map_err(|_| "--iters: bad count")?));
    }
    match engine {
        // default for both engines at once: an iteration budget — the
        // one rule meaning the same amount of work on both
        "both" => Ok(Stop::Iterations(2_000)),
        "threaded" => Ok(Stop::Time(args.parse_num("time", 30.0f64)?)),
        _ => Ok(Stop::Time(args.parse_num("time", 300.0f64)?)),
    }
}

fn cmd_train(args: &Args) -> Result<(), String> {
    let algo = AlgoKind::from_name(&args.get_or("algo", "rfast"))
        .ok_or("unknown --algo (see `repro algos`)")?;
    let n: usize = args.parse_num("nodes", 8usize)?;
    // plain name (ring, binary_tree, ...) or an asymmetric architecture
    // pair (tree:bfs@0+star@0) — Assumption 1-2 violations surface as a
    // typed error from Experiment::run, not a silent divergent run
    let topo = Topology::from_spec(&args.get_or("topology", "ring"), n)?;
    let model = args.get_or("model", "logreg");
    let oracle_kind = args.get_or("oracle", "rust");

    let mut cfg = SimConfig::logreg_paper();
    cfg.seed = args.parse_num("seed", 1u64)?;
    cfg.gamma = args.parse_num("gamma", cfg.gamma)?;
    cfg.loss_prob = args.parse_num("loss-prob", 0.0f64)?;
    cfg.skew_alpha = args.parse_num("skew", 0.0f64)?;
    if let Some(s) = args.get("straggler") {
        cfg.apply_kv("straggler", s)?;
    }
    if let Some(spec) = args.get("scenario") {
        let sc = Scenario::resolve(spec)?;
        // bound-check node indices here so a mismatch is a CLI error,
        // not a panic out of the simulator
        sc.validate(Some(n))?;
        cfg.scenario = Some(sc);
    }
    if model == "mlp" {
        let base = SimConfig::resnet_paper();
        cfg.compute_mean = base.compute_mean;
        cfg.link_latency = base.link_latency;
        cfg.eval_every = base.eval_every;
        cfg.gamma = args.parse_num("gamma", base.gamma)?;
    }
    cfg.validate()?;

    let engine = args.get_or("engine", "sim");
    if !["sim", "threaded", "both"].contains(&engine.as_str()) {
        return Err(format!("unknown --engine {engine:?} (sim|threaded|both)"));
    }
    let stop = resolve_stop(args, &engine)?;

    println!(
        "train: {} on {} ({} nodes), engine={engine} model={model} \
         oracle={oracle_kind} γ={} seed={} stop={stop:?}",
        algo.name(), topo.name(), n, cfg.gamma, cfg.seed
    );
    if let Some(sc) = &cfg.scenario {
        println!("scenario: {} — {}", sc.name, sc.description);
    }

    // the PJRT oracle stays an engine-level path (the builder drives the
    // pure-rust workloads); sim-only for now
    if oracle_kind == "pjrt" {
        if engine != "sim" {
            return Err("--oracle pjrt runs on --engine sim; the PJRT \
                        wall-clock path is examples/e2e_transformer.rs"
                .into());
        }
        let dir = runtime::default_artifact_dir()
            .ok_or("no artifacts/ — run `make artifacts`")?;
        let manifest = Manifest::load(&dir)?;
        let task = pjrt_task_for(&model, n, &cfg)?;
        let set = runtime::build_pjrt_set(&manifest, &task, n, cfg.seed)
            .map_err(|e| e.to_string())?;
        let x0 = manifest.load_init(&task.model_name())?;
        let report =
            Simulator::with_x0(cfg.clone(), &topo, algo, set, &x0).run(stop);
        return save_and_print(&report, args, "loss_vs_time");
    }
    if oracle_kind != "rust" {
        return Err(format!("unknown --oracle {oracle_kind:?} (rust|pjrt)"));
    }

    let workload = match model.as_str() {
        "logreg" => Workload::LogReg,
        "mlp" => Workload::Mlp,
        other => return Err(format!("unknown --model {other:?} (logreg|mlp)")),
    };
    // default pace = compute_mean: the wall-clock cadence matches the
    // virtual-time calibration unless overridden (0 disables pacing)
    let pace: f64 = args.parse_num("pace", cfg.compute_mean)?;
    // actor-pool knobs: --workers N (default: one per core, clamped to
    // the node count) and --mailbox CAP[:POLICY]
    let workers: Option<usize> = match args.get("workers") {
        Some(_) => Some(args.parse_num("workers", 0usize)?).filter(|&w| w > 0),
        None => None,
    };
    let mailbox = match args.get("mailbox") {
        Some(spec) => MailboxCfg::parse(&spec)?,
        None => MailboxCfg::default(),
    };
    let threaded = Engine::Threaded {
        pace: (pace > 0.0).then_some(pace),
        workers,
        mailbox,
    };
    // pass the scenario through the builder's own setter so the saved
    // report labels carry the ` [scenario]` suffix on every engine
    let scenario = cfg.scenario.take();
    let exp = Experiment::new(workload, algo)
        .topology(&topo)
        .config(cfg)
        .maybe_scenario(scenario.as_ref())
        .stop(stop);

    if engine == "both" {
        // one chain, two engines, one side-by-side artifact set
        let cmp = exp
            .sweep_engines(&[Engine::Sim, threaded])
            .map_err(|e| e.to_string())?;
        let (dir, stem) = out_dir_and_stem(args);
        let mut headers = vec!["metric"];
        headers.extend(cmp.labels());
        let mut t = Table::new("engine comparison (scalars)", &headers);
        for (key, cells) in cmp.scalar_rows() {
            let mut row = vec![key];
            row.extend(cells.iter().map(|c| {
                c.map(|v| format!("{v:.4}")).unwrap_or_else(|| "—".into())
            }));
            t.row(row);
        }
        t.print();
        for run in &cmp.runs {
            // file names key on the engine, not the display label — a
            // scenario-suffixed label would put spaces/brackets in paths
            let name = format!("{stem}_{}", run.engine.name());
            run.report.save(&dir, &name).map_err(|e| e.to_string())?;
            println!("report: {}", dir.join(format!("{name}.json")).display());
        }
        let prefix = format!("{stem}_cmp");
        cmp.save_csvs(&dir, &prefix).map_err(|e| e.to_string())?;
        println!("side-by-side scalars: {}",
                 dir.join(format!("{prefix}_scalars.csv")).display());
        return Ok(());
    }

    let run = if engine == "threaded" {
        exp.engine(threaded).run().map_err(|e| e.to_string())?
    } else {
        exp.run().map_err(|e| e.to_string())?
    };
    if engine == "threaded" {
        println!("steps/node: {:?}", run.stats.steps_per_node);
        save_and_print(&run.report, args, "loss_vs_wall")
    } else {
        save_and_print(&run.report, args, "loss_vs_time")
    }
}

/// One rule for where `--out PATH` lands, shared by every train branch:
/// dir = PATH's parent (cwd for a bare filename, `runs/` when absent),
/// stem = PATH's file stem (default `train`).
fn out_dir_and_stem(args: &Args) -> (PathBuf, String) {
    let out = PathBuf::from(args.get_or("out", "runs/train.json"));
    let dir = out
        .parent()
        .unwrap_or(std::path::Path::new("runs"))
        .to_path_buf();
    let stem = out
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("train")
        .to_string();
    (dir, stem)
}

/// Persist the report JSON and print the result table (shared by both
/// engines; `loss_series` is `loss_vs_time` or `loss_vs_wall`).
fn save_and_print(report: &rfast::metrics::Report, args: &Args,
                  loss_series: &str) -> Result<(), String> {
    let out = PathBuf::from(args.get_or("out", "runs/train.json"));
    let (dir, name) = out_dir_and_stem(args);
    report.save(&dir, &name).map_err(|e| e.to_string())?;

    let mut t = Table::new("result", &["metric", "value"]);
    for (k, v) in &report.scalars {
        t.row(vec![k.clone(), format!("{v:.4}")]);
    }
    if let Some(s) = report.series.get(loss_series) {
        if let Some(y) = s.last_y() {
            t.row(vec!["final_eval_loss".into(), format!("{y:.5}")]);
        }
        if let Some(tt) = s.time_to_reach(0.1) {
            t.row(vec!["time_to_loss_0.1".into(), format!("{tt:.1}s")]);
        }
    }
    if let Some(g) = report.final_gap {
        t.row(vec!["final_gap".into(), format!("{g:.3e}")]);
    }
    t.print();
    println!("report: {}", out.display());
    Ok(())
}

fn pjrt_task_for(model: &str, n: usize, cfg: &SimConfig) -> Result<PjrtTask, String> {
    match model {
        "logreg" => {
            let (train, eval) = Dataset::mnist01_like(cfg.seed).split_eval(2000);
            let partition = if cfg.skew_alpha > 0.0 {
                Partition::label_skew(&train, n, cfg.skew_alpha, cfg.seed)
            } else {
                Partition::iid(&train, n, cfg.seed)
            };
            Ok(PjrtTask::LogReg {
                data: Arc::new(train),
                eval: Arc::new(eval),
                partition,
            })
        }
        "mlp" => {
            let (train, eval) =
                Dataset::imagenet_like(20_000, cfg.seed).split_eval(2000);
            let partition = if cfg.skew_alpha > 0.0 {
                Partition::label_skew(&train, n, cfg.skew_alpha, cfg.seed)
            } else {
                Partition::iid(&train, n, cfg.seed)
            };
            Ok(PjrtTask::Mlp {
                data: Arc::new(train),
                eval: Arc::new(eval),
                partition,
            })
        }
        other => Err(format!("unknown model {other:?}")),
    }
}
