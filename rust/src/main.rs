//! `repro` — the R-FAST launcher.
//!
//! ```text
//! repro train   --algo rfast --topology ring --nodes 8 --model logreg
//!               [--engine sim|threaded] [--scenario NAME|FILE.json]
//!               [--gamma G] [--seed S] [--straggler NODE:FACTOR]
//!               [--loss-prob P] [--skew ALPHA] [--pace SECONDS]
//!               [--time T | --iters K] [--oracle pjrt|rust]
//!               [--out runs/NAME]
//! repro scenarios [--export DIR]       # list / export the fault presets
//! repro bench-baseline [--out DIR]     # perf baselines: hot-path suite +
//!                                      # scaling sweep → BENCH_*.json
//! repro graph   --topology binary_tree --nodes 7      # inspect W/A, roots
//! repro check-artifacts                               # load + smoke-run
//! repro algos                                         # list algorithms
//! repro help
//!
//! A bare option list defaults to `train`, so
//! `repro --scenario paper_fig6_straggler` runs the paper's straggler
//! regime end-to-end.
//! ```

use rfast::algo::AlgoKind;
use rfast::cli::Args;
use rfast::config::SimConfig;
use rfast::data::{Dataset, Partition};
use rfast::exp;
use rfast::graph::TopologyKind;
use rfast::metrics::Table;
use rfast::oracle::{GradOracle, LogRegOracle};
use rfast::runner::RunUntil;
use rfast::runtime::{self, Manifest, PjrtTask};
use rfast::scenario::Scenario;
use rfast::sim::{Simulator, StopRule};
use std::path::PathBuf;
use std::sync::Arc;

/// Counting allocator (exp::bench) so `bench-baseline` and the hot-path
/// suite report real allocations-per-wake; two relaxed atomic adds per
/// allocation, negligible for every other subcommand.
#[global_allocator]
static ALLOC: rfast::exp::bench::CountingAllocator =
    rfast::exp::bench::CountingAllocator;

fn main() {
    let mut raw: Vec<String> = std::env::args().skip(1).collect();
    // a bare option list (e.g. `repro --scenario lossy_30pct`) is a train run
    if raw
        .first()
        .map(|a| a.starts_with("--") && a != "--help")
        .unwrap_or(false)
    {
        raw.insert(0, "train".to_string());
    }
    let args = match Args::parse(raw) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n");
            print_help();
            std::process::exit(2);
        }
    };
    let result = match args.subcommand.as_str() {
        "train" => cmd_train(&args),
        "graph" => cmd_graph(&args),
        "check-artifacts" => cmd_check_artifacts(),
        "scenarios" => cmd_scenarios(&args),
        "bench-baseline" => cmd_bench_baseline(&args),
        "algos" => {
            cmd_algos();
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => Err(format!("unknown subcommand {other:?} (try `repro help`)")),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "repro — R-FAST reproduction launcher\n\n\
         subcommands:\n  \
         train            run one training experiment (virtual-time simulator or\n                          wall-clock threaded runner; see --engine)\n  \
         scenarios        list fault-injection presets (--export DIR writes JSON)\n  \
         bench-baseline   run the hot-path suite + 8→64-node scaling sweep and\n                          write BENCH_hotpath.json / BENCH_scaling.json to --out\n                          (default .). RFAST_BENCH_EPOCHS sets the sweep's epoch\n                          budget (default 3; ≤1 implies quick mode). Fails if\n                          the emitted JSON is schema-invalid (EXPERIMENTS.md).\n  \
         graph            print a topology's W/A structure, roots, assumption check\n                          (--analyze [--delay D]: Lemma-1 contraction/ψ analysis)\n  \
         check-artifacts  load every AOT artifact and smoke-run it\n  \
         algos            list implemented algorithms\n  \
         help             this text\n\n\
         train options:\n  \
         --algo NAME        rfast|rfast-naive|pushpull|sab|dpsgd|adpsgd|osgp|allreduce\n  \
         --topology NAME    binary_tree|line|ring|exponential|mesh|star|gossip\n  \
         --nodes N          node count (default 8)\n  \
         --model NAME       logreg|mlp (which oracle/workload; default logreg)\n  \
         --engine E         sim (virtual time, default) | threaded\n                          (thread-per-node, wall clock; logreg + rust oracle)\n  \
         --oracle KIND      rust|pjrt (default rust; pjrt needs `make artifacts`)\n  \
         --scenario S       fault preset name or scenario .json path; drives\n                          either engine (see `repro scenarios`)\n  \
         --gamma G          step size\n  --seed S\n  \
         --straggler N:F    slow node N down by factor F\n  \
         --loss-prob P      packet loss probability (async algos)\n  \
         --skew A           label-skew heterogeneity in [0,1]\n  \
         --pace S           threaded engine: min seconds per local iteration\n                          (default compute_mean; 0 disables)\n  \
         --time T           stop after T virtual seconds (default 300; threaded:\n                          wall seconds, default 30)\n  \
         --iters K          stop after K total gradient steps\n  \
         --out PATH         write the JSON report here (default runs/train.json)"
    );
}

fn cmd_algos() {
    let mut t = Table::new("algorithms", &["name", "async", "loss-tolerant"]);
    for k in [
        AlgoKind::RFast,
        AlgoKind::RFastNaive,
        AlgoKind::PushPull,
        AlgoKind::SAb,
        AlgoKind::DPsgd,
        AlgoKind::AdPsgd,
        AlgoKind::Osgp,
        AlgoKind::RingAllReduce,
    ] {
        t.row(vec![
            k.name().to_string(),
            k.is_async().to_string(),
            k.tolerates_loss().to_string(),
        ]);
    }
    t.print();
}

/// List the built-in fault-injection presets; `--export DIR` writes each
/// as `DIR/<name>.json` (edit + pass back via `--scenario FILE.json`).
fn cmd_scenarios(args: &Args) -> Result<(), String> {
    let mut t = Table::new("fault-injection scenario presets",
                           &["name", "description"]);
    for name in Scenario::preset_names() {
        let s = Scenario::by_name(name).expect("preset");
        t.row(vec![name.to_string(), s.description.clone()]);
    }
    t.print();
    if let Some(dir) = args.get("export") {
        let dir = PathBuf::from(dir);
        std::fs::create_dir_all(&dir).map_err(|e| e.to_string())?;
        for name in Scenario::preset_names() {
            let s = Scenario::by_name(name).expect("preset");
            let path = dir.join(format!("{name}.json"));
            std::fs::write(&path, s.to_json().to_string())
                .map_err(|e| format!("write {}: {e}", path.display()))?;
            println!("wrote {}", path.display());
        }
    } else {
        println!("\nrun one with:  repro train --scenario NAME");
        println!("export JSON:   repro scenarios --export DIR");
    }
    Ok(())
}

/// `repro bench-baseline [--out DIR]` — seed/refresh the perf trajectory:
/// run the hot-path micro suite (ns/iter + allocs/iter via the counting
/// allocator installed above) and the 8→64-node scaling sweep, write
/// `BENCH_hotpath.json` / `BENCH_scaling.json`, then re-read both and
/// fail on schema-invalid output (the CI bench-smoke gate). Methodology
/// and schema: EXPERIMENTS.md.
fn cmd_bench_baseline(args: &Args) -> Result<(), String> {
    use rfast::exp::bench;

    let out = PathBuf::from(args.get_or("out", "."));
    std::fs::create_dir_all(&out)
        .map_err(|e| format!("create {}: {e}", out.display()))?;
    let epochs: f64 = match std::env::var("RFAST_BENCH_EPOCHS") {
        Ok(v) => v
            .parse()
            .map_err(|_| format!("RFAST_BENCH_EPOCHS: bad value {v:?}"))?,
        Err(_) => 3.0,
    };
    if !(epochs > 0.0) {
        return Err(format!("RFAST_BENCH_EPOCHS must be > 0, got {epochs}"));
    }
    let quick = std::env::var("RFAST_BENCH_QUICK").is_ok() || epochs <= 1.0;
    println!(
        "bench-baseline: hot-path suite (quick={quick}, allocs \
         counted={}) + scaling sweep ({epochs} epochs, nodes {:?})",
        bench::counting_allocator_active(),
        bench::SCALING_NODES,
    );

    let hot = bench::hotpath_suite(quick);
    println!("\n== hot-path suite ==");
    for r in &hot {
        println!("{}", r.report());
    }
    let hot_path = out.join("BENCH_hotpath.json");
    std::fs::write(&hot_path, bench::hotpath_json(&hot, quick).to_string())
        .map_err(|e| format!("write {}: {e}", hot_path.display()))?;

    let points = bench::scaling_sweep(bench::SCALING_NODES, epochs);
    let mut t = Table::new(
        "scaling sweep (R-FAST, logreg, binary tree)",
        &["nodes", "virtual s", "wall s", "grad wakes", "MB sent",
          "MB/epoch"],
    );
    for p in &points {
        t.row(vec![
            p.nodes.to_string(),
            format!("{:.2}", p.virtual_time),
            format!("{:.2}", p.wall_seconds),
            format!("{:.0}", p.grad_wakes),
            format!("{:.2}", p.bytes_sent / 1e6),
            format!("{:.2}", p.bytes_sent / 1e6 / p.epoch.max(1e-9)),
        ]);
    }
    t.print();
    let scaling_path = out.join("BENCH_scaling.json");
    std::fs::write(&scaling_path,
                   bench::scaling_json(&points, epochs).to_string())
        .map_err(|e| format!("write {}: {e}", scaling_path.display()))?;

    // the gate: re-read what landed on disk and validate the schema
    type Validator = fn(&rfast::jsonio::Json) -> Result<(), String>;
    let gates: [(&PathBuf, Validator); 2] = [
        (&hot_path, bench::validate_hotpath_json),
        (&scaling_path, bench::validate_scaling_json),
    ];
    for (path, validate) in gates {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("re-read {}: {e}", path.display()))?;
        let j = rfast::jsonio::parse(&text)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        validate(&j)
            .map_err(|e| format!("{}: schema invalid: {e}", path.display()))?;
        println!("schema-valid: {}", path.display());
    }
    Ok(())
}

fn cmd_graph(args: &Args) -> Result<(), String> {
    let kind = TopologyKind::from_name(&args.get_or("topology", "binary_tree"))
        .ok_or("unknown --topology")?;
    let n: usize = args.parse_num("nodes", 7usize)?;
    let topo = kind.build(n);
    let wm = &topo.weights;
    println!("topology {} over {} nodes", kind.name(), n);
    println!("G(W) edges (j→i, i pulls from j):");
    for i in 0..n {
        for &j in &wm.w_in[i] {
            println!("  {j} → {i}   w[{i}][{j}] = {:.3}", wm.w.get(i, j));
        }
    }
    println!("G(A) edges (i→j, i pushes to j):");
    for i in 0..n {
        for &j in &wm.a_out[i] {
            println!("  {i} → {j}   a[{j}][{i}] = {:.3}", wm.a.get(j, i));
        }
    }
    println!("roots of G(W):  {:?}", wm.roots_w());
    println!("roots of G(Aᵀ): {:?}", wm.roots_at());
    println!("common roots R: {:?}", wm.common_roots());
    let errs = wm.check_assumptions();
    if errs.is_empty() {
        println!("Assumptions 1-2: OK (m̄ = {:.4})", wm.min_weight());
    } else {
        for e in errs {
            println!("VIOLATION: {e}");
        }
    }
    if args.has_flag("analyze") {
        let delay: usize = args.parse_num("delay", 2usize)?;
        let a = rfast::graph::AugmentedAnalysis::estimate(&topo, delay);
        println!("\naugmented-system analysis (Lemma 1, D = {delay}):");
        println!("  contraction ρ̂        = {:.5}", a.rho_w);
        println!("  iters to consensus   = {}", a.iters_to_consensus);
        println!("  Lemma-1 η bound      = {:.3e} (K1 = {})", a.eta_bound, a.k1);
        for (r, p) in &a.psi_roots {
            println!("  ψ mass at root {r}    = {p:.4}");
        }
        println!("  γ̄ hint (L=1)         ≈ {:.4}", a.gamma_hint(1.0));
    }
    Ok(())
}

fn cmd_check_artifacts() -> Result<(), String> {
    let dir = runtime::default_artifact_dir()
        .ok_or("no artifacts/ found — run `make artifacts`")?;
    println!("artifacts: {}", dir.display());
    let manifest = Manifest::load(&dir)?;
    let mut t = Table::new("artifacts", &["name", "inputs", "outputs", "status"]);
    for (name, info) in &manifest.artifacts {
        let status = match rfast::runtime::Engine::load(&manifest, &[name]) {
            Ok(engine) => {
                // smoke-run with zero inputs of the right shapes
                let zeros_f: Vec<Vec<f32>> = info
                    .inputs
                    .iter()
                    .map(|s| vec![0.0f32; s.numel()])
                    .collect();
                let zeros_i: Vec<Vec<i32>> = info
                    .inputs
                    .iter()
                    .map(|s| vec![0i32; s.numel()])
                    .collect();
                let inputs: Vec<rfast::runtime::Input<'_>> = info
                    .inputs
                    .iter()
                    .enumerate()
                    .map(|(k, s)| match s.dtype.as_str() {
                        "int32" => rfast::runtime::Input::I32(&zeros_i[k]),
                        _ => rfast::runtime::Input::F32(&zeros_f[k]),
                    })
                    .collect();
                match engine.run(name, &inputs) {
                    Ok(_) => "ok".to_string(),
                    Err(e) => format!("EXEC FAIL: {e}"),
                }
            }
            Err(e) => format!("COMPILE FAIL: {e}"),
        };
        t.row(vec![
            name.clone(),
            format!("{}", info.inputs.len()),
            format!("{}", info.outputs.len()),
            status,
        ]);
    }
    t.print();
    for (name, m) in &manifest.models {
        let init = manifest.load_init(name)?;
        println!("model {name}: p = {} (init ‖θ‖ = {:.3})", m.p,
                 rfast::linalg::norm(&init));
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<(), String> {
    let algo = AlgoKind::from_name(&args.get_or("algo", "rfast"))
        .ok_or("unknown --algo (see `repro algos`)")?;
    let kind = TopologyKind::from_name(&args.get_or("topology", "ring"))
        .ok_or("unknown --topology")?;
    let n: usize = args.parse_num("nodes", 8usize)?;
    let model = args.get_or("model", "logreg");
    let oracle_kind = args.get_or("oracle", "rust");

    let mut cfg = SimConfig::logreg_paper();
    cfg.seed = args.parse_num("seed", 1u64)?;
    cfg.gamma = args.parse_num("gamma", cfg.gamma)?;
    cfg.loss_prob = args.parse_num("loss-prob", 0.0f64)?;
    cfg.skew_alpha = args.parse_num("skew", 0.0f64)?;
    if let Some(s) = args.get("straggler") {
        cfg.apply_kv("straggler", s)?;
    }
    if let Some(spec) = args.get("scenario") {
        let sc = Scenario::resolve(spec)?;
        // bound-check node indices here so a mismatch is a CLI error,
        // not a panic out of the simulator
        sc.validate(Some(n))?;
        cfg.scenario = Some(sc);
    }
    if model == "mlp" {
        let base = SimConfig::resnet_paper();
        cfg.compute_mean = base.compute_mean;
        cfg.link_latency = base.link_latency;
        cfg.eval_every = base.eval_every;
        cfg.gamma = args.parse_num("gamma", base.gamma)?;
    }
    cfg.validate()?;

    let topo = kind.build(n);
    let engine = args.get_or("engine", "sim");

    println!(
        "train: {} on {} ({} nodes), engine={engine} model={model} \
         oracle={oracle_kind} γ={} seed={}",
        algo.name(), kind.name(), n, cfg.gamma, cfg.seed
    );
    if let Some(sc) = &cfg.scenario {
        println!("scenario: {} — {}", sc.name, sc.description);
    }

    if engine == "threaded" {
        if model != "logreg" || oracle_kind != "rust" {
            return Err("--engine threaded drives --model logreg --oracle \
                        rust; the PJRT wall-clock path is \
                        examples/e2e_transformer.rs"
                .into());
        }
        let until = if let Some(iters) = args.get("iters") {
            RunUntil::TotalSteps(iters.parse().map_err(|_| "--iters")?)
        } else {
            RunUntil::WallSeconds(args.parse_num("time", 30.0f64)?)
        };
        // default pace = compute_mean: the wall-clock cadence matches the
        // virtual-time calibration unless overridden (0 disables pacing)
        let pace: f64 = args.parse_num("pace", cfg.compute_mean)?;
        let scenario = cfg.scenario.take();
        let (report, stats) = exp::run_threaded_under(
            exp::Workload::LogReg, algo, &topo, &cfg, scenario.as_ref(),
            (pace > 0.0).then_some(pace), until)?;
        println!("steps/node: {:?}", stats.steps_per_node);
        return save_and_print(&report, args, "loss_vs_wall");
    }
    if engine != "sim" {
        return Err(format!("unknown --engine {engine:?} (sim|threaded)"));
    }

    let stop = if let Some(iters) = args.get("iters") {
        StopRule::Iterations(iters.parse().map_err(|_| "--iters")?)
    } else {
        StopRule::VirtualTime(args.parse_num("time", 300.0f64)?)
    };

    let report = match (model.as_str(), oracle_kind.as_str()) {
        ("logreg", "rust") => {
            let oracle = LogRegOracle::paper_workload(n, cfg.batch,
                                                      cfg.skew_alpha, cfg.seed);
            let set = oracle.into_set();
            Simulator::new(cfg.clone(), &topo, algo, set).run(stop)
        }
        (m, "pjrt") => {
            let dir = runtime::default_artifact_dir()
                .ok_or("no artifacts/ — run `make artifacts`")?;
            let manifest = Manifest::load(&dir)?;
            let task = pjrt_task_for(m, n, &cfg)?;
            let set = runtime::build_pjrt_set(&manifest, &task, n, cfg.seed)
                .map_err(|e| e.to_string())?;
            let x0 = manifest.load_init(&task.model_name())?;
            Simulator::with_x0(cfg.clone(), &topo, algo, set, &x0).run(stop)
        }
        ("mlp", "rust") => {
            return Err("mlp requires --oracle pjrt (the MLP lives in the \
                        AOT artifacts)".into())
        }
        (m, o) => return Err(format!("unsupported --model {m} / --oracle {o}")),
    };

    save_and_print(&report, args, "loss_vs_time")
}

/// Persist the report JSON and print the result table (shared by both
/// engines; `loss_series` is `loss_vs_time` or `loss_vs_wall`).
fn save_and_print(report: &rfast::metrics::Report, args: &Args,
                  loss_series: &str) -> Result<(), String> {
    let out = PathBuf::from(args.get_or("out", "runs/train.json"));
    let (dir, name) = (
        out.parent().unwrap_or(std::path::Path::new("runs")),
        out.file_stem().and_then(|s| s.to_str()).unwrap_or("train"),
    );
    report.save(dir, name).map_err(|e| e.to_string())?;

    let mut t = Table::new("result", &["metric", "value"]);
    for (k, v) in &report.scalars {
        t.row(vec![k.clone(), format!("{v:.4}")]);
    }
    if let Some(s) = report.series.get(loss_series) {
        if let Some(y) = s.last_y() {
            t.row(vec!["final_eval_loss".into(), format!("{y:.5}")]);
        }
        if let Some(tt) = s.time_to_reach(0.1) {
            t.row(vec!["time_to_loss_0.1".into(), format!("{tt:.1}s")]);
        }
    }
    if let Some(g) = report.final_gap {
        t.row(vec!["final_gap".into(), format!("{g:.3e}")]);
    }
    t.print();
    println!("report: {}", out.display());
    Ok(())
}

fn pjrt_task_for(model: &str, n: usize, cfg: &SimConfig) -> Result<PjrtTask, String> {
    match model {
        "logreg" => {
            let (train, eval) = Dataset::mnist01_like(cfg.seed).split_eval(2000);
            let partition = if cfg.skew_alpha > 0.0 {
                Partition::label_skew(&train, n, cfg.skew_alpha, cfg.seed)
            } else {
                Partition::iid(&train, n, cfg.seed)
            };
            Ok(PjrtTask::LogReg {
                data: Arc::new(train),
                eval: Arc::new(eval),
                partition,
            })
        }
        "mlp" => {
            let (train, eval) =
                Dataset::imagenet_like(20_000, cfg.seed).split_eval(2000);
            let partition = if cfg.skew_alpha > 0.0 {
                Partition::label_skew(&train, n, cfg.skew_alpha, cfg.seed)
            } else {
                Partition::iid(&train, n, cfg.seed)
            };
            Ok(PjrtTask::Mlp {
                data: Arc::new(train),
                eval: Arc::new(eval),
                partition,
            })
        }
        other => Err(format!("unknown model {other:?}")),
    }
}
