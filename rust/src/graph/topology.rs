//! Topology builders — every graph from the paper's experiments (§VI,
//! Fig 3, Appendix G) plus the parameter-server and random-gossip
//! structures Remark 1 calls out as special cases.
//!
//! Convention (paper §III): an edge `j → i` in G(W) means `W[i][j] > 0`
//! (node i pulls from j); an edge `i → j` in G(A) means `A[j][i] > 0`
//! (node i pushes to j). Weights are uniform over {self} ∪ neighbors — the
//! Appendix-G construction: W rows and A columns are `1/(1+deg)`.

use super::{Axis, Mat, SparseWeights, WeightMatrices};
use crate::prng::Rng;

/// Which builder produced a topology (benches/reports key on this).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TopologyKind {
    BinaryTree,
    Line,
    Ring,
    Exponential,
    Mesh,
    Star,
    Gossip,
    Custom,
}

impl TopologyKind {
    pub fn name(&self) -> &'static str {
        match self {
            TopologyKind::BinaryTree => "binary_tree",
            TopologyKind::Line => "line",
            TopologyKind::Ring => "ring",
            TopologyKind::Exponential => "exponential",
            TopologyKind::Mesh => "mesh",
            TopologyKind::Star => "star",
            TopologyKind::Gossip => "gossip",
            TopologyKind::Custom => "custom",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        Some(match s {
            "binary_tree" | "tree" => TopologyKind::BinaryTree,
            "line" => TopologyKind::Line,
            "ring" => TopologyKind::Ring,
            "exponential" | "exp" => TopologyKind::Exponential,
            "mesh" | "grid" => TopologyKind::Mesh,
            "star" | "ps" => TopologyKind::Star,
            "gossip" => TopologyKind::Gossip,
            _ => return None,
        })
    }

    /// Build with default parameters (gossip uses degree 3, seed 0).
    pub fn build(&self, n: usize) -> Topology {
        match self {
            TopologyKind::BinaryTree => Topology::binary_tree(n),
            TopologyKind::Line => Topology::line(n),
            TopologyKind::Ring => Topology::ring(n),
            TopologyKind::Exponential => Topology::exponential(n),
            TopologyKind::Mesh => Topology::mesh(n),
            TopologyKind::Star => Topology::star(n),
            TopologyKind::Gossip => Topology::gossip(n, 3, 0),
            // lint:allow(panic-path): Custom is constructed only by from_edges, never routed here
            TopologyKind::Custom => panic!("custom topologies use Topology::from_edges"),
        }
    }
}

/// A named communication topology: the (W, A) pair plus provenance.
#[derive(Clone, Debug)]
pub struct Topology {
    pub kind: TopologyKind,
    pub weights: WeightMatrices,
    /// Display label for topologies the flat [`TopologyKind`] can't name
    /// (asymmetric architecture pairs, hand-built edge lists). `None`
    /// falls back to the kind's name — see [`Topology::name`].
    pub label: Option<String>,
}

impl Topology {
    pub fn n(&self) -> usize {
        self.weights.n
    }

    /// Human-readable name: the explicit label when set (architecture
    /// pairs like `bfs@0+star@0`), else the builder kind's name.
    pub fn name(&self) -> &str {
        self.label.as_deref().unwrap_or_else(|| self.kind.name())
    }

    /// Attach a display label (sweep columns, error messages).
    pub fn labeled(mut self, label: impl Into<String>) -> Topology {
        self.label = Some(label.into());
        self
    }

    /// Resolve a CLI `--topology` spec over `n` nodes: a plain
    /// [`TopologyKind`] name (`ring`, `binary_tree`, ...) or the
    /// asymmetric pair grammar `[tree:]PULL+PUSH` of
    /// [`ArchSpec`](super::arch::ArchSpec) (`tree:bfs@0+star@0`).
    pub fn from_spec(spec: &str, n: usize) -> Result<Topology, String> {
        if let Some(kind) = TopologyKind::from_name(spec) {
            return Ok(kind.build(n));
        }
        if super::arch::ArchSpec::is_arch_spec(spec) {
            return super::arch::ArchSpec::parse(spec)?.build(n);
        }
        Err(format!(
            "unknown topology {spec:?} (a name like ring|binary_tree|line|\
             exponential|mesh|star|gossip, or an architecture pair like \
             tree:bfs@0+star@0)"
        ))
    }

    /// Build from explicit directed edge lists — the single construction
    /// funnel every builder (and [`ArchSpec`](super::arch::ArchSpec))
    /// routes through. O(edges): no n×n buffer is ever allocated.
    ///
    /// `w_edges`: `(j, i)` meaning i pulls from j in G(W).
    /// `a_edges`: `(i, j)` meaning i pushes to j in G(A).
    /// Weights are uniform (Appendix-G style), bitwise-identical to the
    /// dense densify-and-normalize reference [`Topology::from_edges_dense`]
    /// (see `SparseWeights` docs for the exactness argument).
    pub fn from_edges(
        n: usize,
        w_edges: &[(usize, usize)],
        a_edges: &[(usize, usize)],
    ) -> Topology {
        let mut w_adj: Vec<Vec<u32>> = vec![Vec::new(); n];
        for &(j, i) in w_edges {
            assert!(i < n && j < n && i != j, "bad W edge ({j},{i})");
            w_adj[i].push(j as u32);
        }
        let mut a_adj: Vec<Vec<u32>> = vec![Vec::new(); n];
        for &(i, j) in a_edges {
            assert!(i < n && j < n && i != j, "bad A edge ({i},{j})");
            a_adj[j].push(i as u32);
        }
        Topology {
            kind: TopologyKind::Custom,
            weights: WeightMatrices::from_sparse(
                SparseWeights::from_unit_adjacency(n, Axis::Row, w_adj),
                SparseWeights::from_unit_adjacency(n, Axis::Col, a_adj),
            ),
            label: None,
        }
    }

    /// Dense reference twin of [`Topology::from_edges`]: densify the same
    /// edges into `Mat::identity` and normalize with dense arithmetic.
    /// Exists so the sparse-vs-dense parity suite can diff the two
    /// construction paths bit-for-bit; allocates n×n, so it is *not* a
    /// production path.
    pub fn from_edges_dense(
        n: usize,
        w_edges: &[(usize, usize)],
        a_edges: &[(usize, usize)],
    ) -> Topology {
        let mut w = Mat::identity(n);
        for &(j, i) in w_edges {
            assert!(i < n && j < n && i != j, "bad W edge ({j},{i})");
            w.set(i, j, 1.0);
        }
        w.normalize_rows();

        let mut a = Mat::identity(n);
        for &(i, j) in a_edges {
            assert!(i < n && j < n && i != j, "bad A edge ({i},{j})");
            a.set(j, i, 1.0);
        }
        a.normalize_cols();

        Topology {
            kind: TopologyKind::Custom,
            weights: WeightMatrices::new(w, a),
            label: None,
        }
    }

    fn with_kind(mut self, kind: TopologyKind) -> Topology {
        self.kind = kind;
        self
    }

    /// Binary tree (paper Fig 3a): G(W) is the tree oriented root→leaves
    /// (node 0 the root, children of k at 2k+1, 2k+2), G(A) its inverse —
    /// exactly the "oriented acyclic tree + inverse graph" construction of
    /// §VI-A. Parameters flow down; gradient mass flows up. Root set = {0}.
    pub fn binary_tree(n: usize) -> Topology {
        assert!(n >= 1);
        let mut w_edges = Vec::new(); // (parent j) → (child i)
        let mut a_edges = Vec::new(); // child i → parent j
        for i in 1..n {
            let parent = (i - 1) / 2;
            w_edges.push((parent, i));
            a_edges.push((i, parent));
        }
        Topology::from_edges(n, &w_edges, &a_edges)
            .with_kind(TopologyKind::BinaryTree)
    }

    /// Line graph (paper Fig 3c): 0→1→…→n−1 in G(W), reversed in G(A).
    pub fn line(n: usize) -> Topology {
        assert!(n >= 1);
        let w_edges: Vec<_> = (1..n).map(|i| (i - 1, i)).collect();
        let a_edges: Vec<_> = (1..n).map(|i| (i, i - 1)).collect();
        Topology::from_edges(n, &w_edges, &a_edges).with_kind(TopologyKind::Line)
    }

    /// Directed ring (paper Fig 3b): i→i+1 (mod n) in both graphs — the
    /// topology of the ResNet-50 experiments (§VI-B). Strongly connected,
    /// so every node is a common root.
    pub fn ring(n: usize) -> Topology {
        assert!(n >= 2);
        let edges: Vec<_> = (0..n).map(|i| (i, (i + 1) % n)).collect();
        Topology::from_edges(n, &edges, &edges).with_kind(TopologyKind::Ring)
    }

    /// Exponential graph (Appendix G, Fig 13): i → (i + 2^k) mod n for all
    /// 2^k < n. The classic O(log n)-diameter digraph.
    pub fn exponential(n: usize) -> Topology {
        assert!(n >= 2);
        let mut edges = Vec::new();
        let mut hop = 1;
        while hop < n {
            for i in 0..n {
                let j = (i + hop) % n;
                if j != i {
                    edges.push((i, j));
                }
            }
            hop *= 2;
        }
        edges.sort_unstable();
        edges.dedup();
        Topology::from_edges(n, &edges, &edges)
            .with_kind(TopologyKind::Exponential)
    }

    /// 2-D mesh/grid (Appendix G, Fig 14): nodes in a ⌈√n⌉-wide grid,
    /// undirected lattice edges used in both directions for both graphs.
    pub fn mesh(n: usize) -> Topology {
        assert!(n >= 2);
        let cols = (n as f64).sqrt().ceil() as usize;
        let mut edges = Vec::new();
        for i in 0..n {
            let (r, c) = (i / cols, i % cols);
            if c + 1 < cols && i + 1 < n {
                edges.push((i, i + 1));
                edges.push((i + 1, i));
            }
            let down = (r + 1) * cols + c;
            if down < n {
                edges.push((i, down));
                edges.push((down, i));
            }
        }
        Topology::from_edges(n, &edges, &edges).with_kind(TopologyKind::Mesh)
    }

    /// Star / parameter-server (Remark 1, Fig 15 bottom): node 0 is the
    /// server; G(W) = server→workers, G(A) = workers→server.
    pub fn star(n: usize) -> Topology {
        assert!(n >= 1);
        let w_edges: Vec<_> = (1..n).map(|i| (0, i)).collect();
        let a_edges: Vec<_> = (1..n).map(|i| (i, 0)).collect();
        Topology::from_edges(n, &w_edges, &a_edges).with_kind(TopologyKind::Star)
    }

    /// Random gossip digraph: a directed ring (guaranteeing strong
    /// connectivity ⇒ Assumption 2) plus `extra_deg` random out-edges per
    /// node; same graph for W and A.
    pub fn gossip(n: usize, extra_deg: usize, seed: u64) -> Topology {
        assert!(n >= 2);
        let mut rng = Rng::stream(seed, 0x90551b);
        let mut edges: Vec<_> = (0..n).map(|i| (i, (i + 1) % n)).collect();
        for i in 0..n {
            for _ in 0..extra_deg {
                let j = rng.below(n);
                if j != i && j != (i + 1) % n {
                    edges.push((i, j));
                }
            }
        }
        edges.sort_unstable();
        edges.dedup();
        Topology::from_edges(n, &edges, &edges).with_kind(TopologyKind::Gossip)
    }

    /// Undirected ring with doubly-stochastic Metropolis weights — what
    /// D-PSGD / AD-PSGD require (they cannot run on directed graphs).
    /// Returned as a Topology whose W **is** doubly stochastic and A = W.
    pub fn undirected_ring_metropolis(n: usize) -> Topology {
        assert!(n >= 3);
        // Metropolis–Hastings: w_ij = 1/(1+max(d_i,d_j)) = 1/3 on a ring.
        let third = 1.0f32 / 3.0;
        let rows: Vec<Vec<(u32, f32)>> = (0..n)
            .map(|i| {
                let prev = ((i + n - 1) % n) as u32;
                let next = ((i + 1) % n) as u32;
                vec![(prev, third), (i as u32, third), (next, third)]
            })
            .collect();
        // the matrix is symmetric, so the column-primary lists of A = W
        // are the same index/weight lists
        Topology {
            kind: TopologyKind::Ring,
            weights: WeightMatrices::from_sparse(
                SparseWeights::from_weighted_lists(n, Axis::Row, rows.clone()),
                SparseWeights::from_weighted_lists(n, Axis::Col, rows),
            ),
            label: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binary_tree_edges() {
        let t = Topology::binary_tree(7);
        // node 3's parent is 1: W[3][1] > 0, A[1][3] > 0
        assert!(t.weights.w.get(3, 1) > 0.0);
        assert!(t.weights.a.get(1, 3) > 0.0);
        // no reverse edge in W
        assert_eq!(t.weights.w.get(1, 3), 0.0);
    }

    #[test]
    fn star_structure() {
        let t = Topology::star(5);
        for i in 1..5 {
            assert!(t.weights.w.get(i, 0) > 0.0); // workers pull from server
            assert!(t.weights.a.get(0, i) > 0.0); // workers push to server
        }
        assert_eq!(t.weights.common_roots(), vec![0]);
    }

    #[test]
    fn mesh_is_strongly_connected() {
        for n in [4, 6, 9, 12, 16] {
            let t = Topology::mesh(n);
            assert_eq!(t.weights.common_roots().len(), n, "n={n}");
        }
    }

    #[test]
    fn exponential_has_log_edges() {
        let t = Topology::exponential(8);
        // out-degree of each node = log2(8) = 3
        for i in 0..8 {
            assert_eq!(t.weights.w_out[i].len(), 3);
        }
    }

    #[test]
    fn gossip_deterministic_by_seed() {
        let a = Topology::gossip(10, 2, 7);
        let b = Topology::gossip(10, 2, 7);
        assert_eq!(a.weights.w, b.weights.w);
        let c = Topology::gossip(10, 2, 8);
        assert_ne!(a.weights.w, c.weights.w);
    }

    #[test]
    fn metropolis_ring_is_doubly_stochastic() {
        let t = Topology::undirected_ring_metropolis(6);
        for i in 0..6 {
            assert!((t.weights.w.row_sum(i) - 1.0).abs() < 1e-6);
            assert!((t.weights.w.col_sum(i) - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn kind_name_roundtrip() {
        for k in [
            TopologyKind::BinaryTree,
            TopologyKind::Line,
            TopologyKind::Ring,
            TopologyKind::Exponential,
            TopologyKind::Mesh,
            TopologyKind::Star,
            TopologyKind::Gossip,
        ] {
            assert_eq!(TopologyKind::from_name(k.name()), Some(k));
        }
    }

    #[test]
    fn from_edges_rejects_self_loops() {
        let r = std::panic::catch_unwind(|| {
            Topology::from_edges(3, &[(1, 1)], &[])
        });
        assert!(r.is_err());
        let r = std::panic::catch_unwind(|| {
            Topology::from_edges_dense(3, &[(1, 1)], &[])
        });
        assert!(r.is_err());
    }

    #[test]
    fn sparse_and_dense_construction_paths_agree_bitwise() {
        // full property coverage lives in tests/sparse_parity.rs; this
        // pins the funnel itself on a lopsided edge set with duplicates
        let w_edges = [(0, 1), (0, 2), (1, 2), (0, 2), (3, 0)];
        let a_edges = [(1, 0), (2, 0), (2, 1), (0, 3)];
        let s = Topology::from_edges(4, &w_edges, &a_edges);
        let d = Topology::from_edges_dense(4, &w_edges, &a_edges);
        assert_eq!(s.weights, d.weights);
    }

    #[test]
    fn from_spec_resolves_names_and_pairs() {
        let t = Topology::from_spec("ring", 4).unwrap();
        assert_eq!(t.kind, TopologyKind::Ring);
        assert_eq!(t.name(), "ring");
        let t = Topology::from_spec("tree:bfs@0+star@0", 6).unwrap();
        assert_eq!(t.kind, TopologyKind::Custom);
        assert_eq!(t.name(), "bfs@0+star@0");
        assert!(t.weights.check_assumptions().is_empty());
        assert!(Topology::from_spec("nope", 4).is_err());
        assert!(Topology::from_spec("bogus@0+star@0", 4).is_err());
    }

    #[test]
    fn single_node_degenerate_topologies() {
        let t = Topology::binary_tree(1);
        assert_eq!(t.weights.common_roots(), vec![0]);
        let t = Topology::line(1);
        assert_eq!(t.weights.common_roots(), vec![0]);
    }
}
