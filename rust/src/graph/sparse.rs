//! Sparse mixing-weight storage: per-node edge lists instead of n×n.
//!
//! Spanning trees — the structures Assumption 2 actually requires — have
//! O(n) edges, so the dense [`Mat`] wastes quadratic memory the moment n
//! leaves the tens. [`SparseWeights`] stores one sorted `(index, weight)`
//! list per node along a primary [`Axis`]: row-primary for the
//! row-stochastic pull matrix W, column-primary for the column-stochastic
//! push matrix A. Lookups off the primary axis binary-search, so the
//! whole dense read surface (`get`/`row_sum`/`col_sum`) survives
//! unchanged for the `algo/` state machines and the analysis code.
//!
//! **Bitwise parity with the dense path** (DESIGN.md §13) rests on two
//! facts the construction exploits:
//!
//! 1. `Topology::from_edges` densifies unit entries (identity diagonal +
//!    1.0 per edge) and normalizes; the dense row/column sum of k ones
//!    plus zeros is the exact f64 integer k, so
//!    `(1.0 / k as f64) as f32` here reproduces the dense scale factor
//!    bit-for-bit, and `1.0f32 * inv == inv` exactly.
//! 2. Dense sums iterate indices ascending and adding an exact `0.0`
//!    never changes an f64 accumulator, so summing only the stored
//!    entries in ascending index order yields bitwise-identical sums.

use super::matrix::Mat;

/// Which index the per-node lists are keyed by.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Axis {
    /// `lists[i]` holds row i: entries `(j, M[i][j])` sorted by j.
    Row,
    /// `lists[j]` holds column j: entries `(i, M[i][j])` sorted by i.
    Col,
}

/// Largest n for which the dense compatibility view may be materialized.
pub const DENSE_COMPAT_MAX: usize = 4096;

/// A square mixing matrix stored as per-node sorted edge lists.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseWeights {
    n: usize,
    axis: Axis,
    /// `lists[k]` sorted ascending by the secondary index; weights are
    /// the exact f32 values the dense construction would produce.
    lists: Vec<Vec<(u32, f32)>>,
}

impl SparseWeights {
    /// Unit adjacency + implicit diagonal, normalized along the primary
    /// axis — bitwise-identical to densifying the same edges into
    /// `Mat::identity` and calling `normalize_rows`/`normalize_cols`
    /// (see the module docs for why the arithmetic matches exactly).
    ///
    /// `adj[k]` lists the off-diagonal secondary indices of node k's
    /// unit entries; duplicates are deduplicated, matching the dense
    /// path where setting the same cell twice is idempotent.
    pub fn from_unit_adjacency(n: usize, axis: Axis, adj: Vec<Vec<u32>>) -> SparseWeights {
        assert_eq!(adj.len(), n);
        let mut lists = Vec::with_capacity(n);
        for (k, mut others) in adj.into_iter().enumerate() {
            others.push(k as u32);
            others.sort_unstable();
            others.dedup();
            debug_assert!(others.last().map_or(true, |&m| (m as usize) < n));
            // exact: the dense row/col sum of `others.len()` unit
            // entries is this same f64 integer
            let inv = (1.0 / others.len() as f64) as f32;
            lists.push(others.into_iter().map(|j| (j, inv)).collect());
        }
        SparseWeights { n, axis, lists }
    }

    /// Explicitly weighted lists (diagonal included), for constructions
    /// like Metropolis weights that don't normalize unit entries.
    /// Entries are sorted here; indices must be in-range and unique.
    pub fn from_weighted_lists(
        n: usize,
        axis: Axis,
        mut lists: Vec<Vec<(u32, f32)>>,
    ) -> SparseWeights {
        assert_eq!(lists.len(), n);
        for l in &mut lists {
            l.sort_unstable_by_key(|e| e.0);
            for pair in l.windows(2) {
                assert!(pair[0].0 < pair[1].0, "duplicate index in weighted list");
            }
            assert!(l.last().map_or(true, |&(m, _)| (m as usize) < n));
        }
        SparseWeights { n, axis, lists }
    }

    /// Compatibility conversion from a dense matrix: stores every
    /// non-zero entry (including negatives, so `check_assumptions` sees
    /// exactly what the dense matrix held).
    pub fn from_mat(m: &Mat, axis: Axis) -> SparseWeights {
        let n = m.n();
        let mut lists = vec![Vec::new(); n];
        for i in 0..n {
            for j in 0..n {
                let v = m.get(i, j);
                if v != 0.0 {
                    match axis {
                        Axis::Row => lists[i].push((j as u32, v)),
                        Axis::Col => lists[j].push((i as u32, v)),
                    }
                }
            }
        }
        SparseWeights { n, axis, lists }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn axis(&self) -> Axis {
        self.axis
    }

    /// Stored entry count (nnz).
    pub fn entry_count(&self) -> usize {
        self.lists.iter().map(|l| l.len()).sum()
    }

    /// The sorted `(secondary index, weight)` list of primary line k —
    /// row k for a [`Axis::Row`] matrix, column k for [`Axis::Col`].
    #[inline]
    pub fn line(&self, k: usize) -> &[(u32, f32)] {
        &self.lists[k]
    }

    /// `M[i][j]`, 0.0 when absent. O(log deg) off the stored cell.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        let (k, s) = match self.axis {
            Axis::Row => (i, j as u32),
            Axis::Col => (j, i as u32),
        };
        match self.lists[k].binary_search_by_key(&s, |e| e.0) {
            Ok(p) => self.lists[k][p].1,
            Err(_) => 0.0,
        }
    }

    /// f64 sum of row i in ascending-j order — bitwise-equal to the
    /// dense `Mat::row_sum` (skipped zeros contribute exactly nothing).
    pub fn row_sum(&self, i: usize) -> f64 {
        match self.axis {
            Axis::Row => self.lists[i].iter().map(|&(_, v)| v as f64).sum(),
            Axis::Col => (0..self.n).map(|j| self.get(i, j) as f64).sum(),
        }
    }

    /// f64 sum of column j in ascending-i order (see [`Self::row_sum`]).
    pub fn col_sum(&self, j: usize) -> f64 {
        match self.axis {
            Axis::Col => self.lists[j].iter().map(|&(_, v)| v as f64).sum(),
            Axis::Row => (0..self.n).map(|i| self.get(i, j) as f64).sum(),
        }
    }

    /// Smallest strictly positive stored weight, `f64::INFINITY` if none.
    pub fn min_positive(&self) -> f64 {
        let mut m = f64::INFINITY;
        for l in &self.lists {
            for &(_, v) in l {
                if v > 0.0 {
                    m = m.min(v as f64);
                }
            }
        }
        m
    }

    /// Re-bucket the entries along the *other* axis: for a [`Axis::Col`]
    /// matrix, per-row `(j, v)` lists with j ascending (and vice versa).
    /// O(E); built once by `check_assumptions` to merge W rows with A
    /// rows without n² probing.
    pub fn off_axis_lists(&self) -> Vec<Vec<(u32, f32)>> {
        let mut out = vec![Vec::new(); self.n];
        for (k, l) in self.lists.iter().enumerate() {
            for &(s, v) in l {
                // outer k ascends, so each out-list stays sorted by k
                out[s as usize].push((k as u32, v));
            }
        }
        out
    }

    /// Dense compatibility view for small-n analysis and diagnostics.
    /// Refuses to materialize n×n beyond [`DENSE_COMPAT_MAX`] — large
    /// topologies must stay on the sparse read surface.
    pub fn to_dense(&self) -> Mat {
        assert!(
            self.n <= DENSE_COMPAT_MAX,
            "to_dense is a small-n compatibility accessor (n = {} > {})",
            self.n,
            DENSE_COMPAT_MAX
        );
        let mut m = Mat::zeros(self.n);
        for (k, l) in self.lists.iter().enumerate() {
            for &(s, v) in l {
                match self.axis {
                    Axis::Row => m.set(k, s as usize, v),
                    Axis::Col => m.set(s as usize, k, v),
                }
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Dense twin of `from_unit_adjacency` — the exact arithmetic the
    /// old `Topology::from_edges` ran.
    fn dense_unit(n: usize, axis: Axis, adj: &[Vec<u32>]) -> Mat {
        let mut m = Mat::identity(n);
        for (k, others) in adj.iter().enumerate() {
            for &s in others {
                match axis {
                    Axis::Row => m.set(k, s as usize, 1.0),
                    Axis::Col => m.set(s as usize, k, 1.0),
                }
            }
        }
        match axis {
            Axis::Row => m.normalize_rows(),
            Axis::Col => m.normalize_cols(),
        }
        m
    }

    fn bits(x: f32) -> u32 {
        x.to_bits()
    }

    #[test]
    fn unit_construction_matches_dense_normalization_bitwise() {
        let adj = vec![vec![1, 2], vec![0], vec![], vec![0, 1, 2]];
        for axis in [Axis::Row, Axis::Col] {
            let s = SparseWeights::from_unit_adjacency(4, axis, adj.clone());
            let d = dense_unit(4, axis, &adj);
            for i in 0..4 {
                for j in 0..4 {
                    assert_eq!(
                        bits(s.get(i, j)),
                        bits(d.get(i, j)),
                        "axis {axis:?} cell ({i},{j})"
                    );
                }
                assert_eq!(s.row_sum(i).to_bits(), d.row_sum(i).to_bits());
                assert_eq!(s.col_sum(i).to_bits(), d.col_sum(i).to_bits());
            }
        }
    }

    #[test]
    fn duplicate_edges_are_idempotent_like_dense_set() {
        let s = SparseWeights::from_unit_adjacency(3, Axis::Row, vec![vec![1, 1, 2], vec![], vec![]]);
        let d = dense_unit(3, Axis::Row, &[vec![1, 1, 2], vec![], vec![]]);
        assert_eq!(bits(s.get(0, 1)), bits(d.get(0, 1)));
        assert_eq!(s.line(0).len(), 3); // {0, 1, 2} once each
    }

    #[test]
    fn from_mat_round_trips_including_negatives() {
        let mut m = Mat::zeros(3);
        m.set(0, 0, 1.0);
        m.set(0, 2, -0.25);
        m.set(2, 1, 0.5);
        for axis in [Axis::Row, Axis::Col] {
            let s = SparseWeights::from_mat(&m, axis);
            for i in 0..3 {
                for j in 0..3 {
                    assert_eq!(bits(s.get(i, j)), bits(m.get(i, j)));
                }
                assert_eq!(s.row_sum(i).to_bits(), m.row_sum(i).to_bits());
                assert_eq!(s.col_sum(i).to_bits(), m.col_sum(i).to_bits());
            }
            assert_eq!(s.to_dense(), m);
        }
        assert_eq!(SparseWeights::from_mat(&m, Axis::Row).min_positive(), 0.5);
    }

    #[test]
    fn off_axis_lists_rebucket_sorted() {
        let s = SparseWeights::from_unit_adjacency(
            3,
            Axis::Col,
            vec![vec![1, 2], vec![2], vec![]],
        );
        let rows = s.off_axis_lists();
        for (i, r) in rows.iter().enumerate() {
            for pair in r.windows(2) {
                assert!(pair[0].0 < pair[1].0);
            }
            for &(j, v) in r {
                assert_eq!(bits(v), bits(s.get(i, j as usize)));
            }
        }
        assert_eq!(rows.iter().map(Vec::len).sum::<usize>(), s.entry_count());
    }

    #[test]
    fn single_node_is_exactly_one() {
        let s = SparseWeights::from_unit_adjacency(1, Axis::Row, vec![vec![]]);
        assert_eq!(bits(s.get(0, 0)), bits(1.0f32));
        assert_eq!(s.row_sum(0), 1.0);
    }
}
