//! Asymmetric architectures — (G_R, G_C) pairs built from **two
//! independent spanning trees** (paper §II, Fig. 3).
//!
//! R-FAST's headline structural claim is that the pull graph G_R = G(W)
//! and the push graph G_C = G(Aᵀ) need not be related at all: each only
//! has to contain a spanning tree, and the two trees must share at least
//! one common root (Assumption 2). Every [`TopologyKind`](super::TopologyKind) builder derives
//! W and A from ONE base graph and its inverse, so that flexibility was
//! previously unreachable. An [`ArchSpec`] makes it first-class: two
//! [`TreeSpec`]s — one for the pull side, one for the push side — each
//! naming a spanning-tree construction and its root, compiled together
//! into a [`Topology`] whose W is row-stochastic over the pull tree and
//! whose A is column-stochastic over the push tree (the Appendix-G
//! uniform weighting, via [`Topology::from_edges`]).
//!
//! Constructions ([`TreeKind`]):
//!
//! * `balanced` — the depth-balanced binary tree of Fig 3a, re-rooted at
//!   any node by label rotation;
//! * `chain` — the line graph of Fig 3c, rooted anywhere;
//! * `star` — the parameter-server shape of Remark 1;
//! * `bfs` / `dfs` — breadth-first / depth-first spanning trees of the
//!   exponential base digraph (`i → (i + 2^k) mod n`): shallow vs deep
//!   trees over one base, rooted anywhere;
//! * `random` — a loop-erased-random-walk (Wilson) spanning tree of the
//!   complete digraph, seeded and deterministic like
//!   [`Topology::gossip`].
//!
//! Grammar (the CLI's `--topology` accepts it wherever a plain name is
//! accepted; the optional `tree:` prefix is cosmetic):
//!
//! ```text
//! [tree:]PULL+PUSH        PULL, PUSH := KIND[@ROOT][:SEED]
//! tree:bfs@0+star@0       # BFS pull tree and star push tree, root 0
//! chain@2+balanced@2      # chain-pull / tree-push, both rooted at 2
//! random@0:7+random@0:21  # two independent random spanning trees
//! ```
//!
//! A pair whose trees have different roots violates Assumption 2 (a pure
//! tree's root set is exactly its root), which
//! [`Experiment::run`](crate::exp::Experiment::run) pre-flights through
//! [`WeightMatrices::check_assumptions`](super::WeightMatrices::check_assumptions)
//! into a typed
//! [`ExpError::InvalidTopology`](crate::exp::ExpError::InvalidTopology)
//! naming the pair — never a silent divergent run. DESIGN.md §10.

use super::Topology;
use crate::prng::Rng;

/// Which spanning-tree construction builds one side of an [`ArchSpec`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TreeKind {
    /// Breadth-first tree of the exponential base digraph (shallow).
    Bfs,
    /// Depth-first tree of the exponential base digraph (deep).
    Dfs,
    /// Depth-balanced binary tree (Fig 3a, re-rooted by label rotation).
    Balanced,
    /// Line graph rooted anywhere (Fig 3c).
    Chain,
    /// Star / parameter-server shape (Remark 1).
    Star,
    /// Loop-erased-random-walk (Wilson) spanning tree of the complete
    /// digraph; seeded, deterministic.
    Random,
}

impl TreeKind {
    pub fn name(&self) -> &'static str {
        match self {
            TreeKind::Bfs => "bfs",
            TreeKind::Dfs => "dfs",
            TreeKind::Balanced => "balanced",
            TreeKind::Chain => "chain",
            TreeKind::Star => "star",
            TreeKind::Random => "random",
        }
    }

    pub fn from_name(s: &str) -> Option<TreeKind> {
        Some(match s {
            "bfs" => TreeKind::Bfs,
            "dfs" => TreeKind::Dfs,
            "balanced" | "tree" => TreeKind::Balanced,
            "chain" | "line" => TreeKind::Chain,
            "star" | "ps" => TreeKind::Star,
            "random" | "lerw" | "wilson" => TreeKind::Random,
            _ => return None,
        })
    }
}

/// One spanning tree: a construction, its root, and (for
/// [`TreeKind::Random`]) the seed of the loop-erased random walk.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TreeSpec {
    pub kind: TreeKind,
    pub root: usize,
    /// Consumed only by [`TreeKind::Random`]; 0 otherwise by convention.
    pub seed: u64,
}

impl TreeSpec {
    pub fn new(kind: TreeKind, root: usize) -> TreeSpec {
        TreeSpec { kind, root, seed: 0 }
    }

    /// Parse one side of the pair grammar: `KIND[@ROOT][:SEED]`.
    pub fn parse(s: &str) -> Result<TreeSpec, String> {
        let (body, seed) = match s.split_once(':') {
            Some((b, sd)) => (
                b,
                sd.parse::<u64>()
                    .map_err(|_| format!("tree spec {s:?}: bad seed {sd:?}"))?,
            ),
            None => (s, 0),
        };
        let (kind_s, root) = match body.split_once('@') {
            Some((k, r)) => (
                k,
                r.parse::<usize>()
                    .map_err(|_| format!("tree spec {s:?}: bad root {r:?}"))?,
            ),
            None => (body, 0),
        };
        let kind = TreeKind::from_name(kind_s).ok_or_else(|| {
            format!(
                "tree spec {s:?}: unknown construction {kind_s:?} \
                 (bfs|dfs|balanced|chain|star|random)"
            )
        })?;
        Ok(TreeSpec { kind, root, seed })
    }

    /// Stable display name, `kind@root[:seed]`.
    pub fn name(&self) -> String {
        match self.kind {
            TreeKind::Random => {
                format!("{}@{}:{}", self.kind.name(), self.root, self.seed)
            }
            _ => format!("{}@{}", self.kind.name(), self.root),
        }
    }

    /// Parent array of the spanning tree over `n` nodes:
    /// `parents[i]` is `i`'s parent, and `parents[root] == root`.
    pub fn parents(&self, n: usize) -> Result<Vec<usize>, String> {
        if n == 0 {
            return Err("tree over 0 nodes".into());
        }
        if self.root >= n {
            return Err(format!(
                "tree {}: root {} out of range (n = {n})",
                self.name(),
                self.root
            ));
        }
        let r = self.root;
        let mut parents = vec![usize::MAX; n];
        parents[r] = r;
        match self.kind {
            TreeKind::Balanced => {
                // heap positions 0..n hold labels (r + p) mod n; the
                // parent of position p is (p − 1)/2 — Fig 3a re-rooted
                for p in 1..n {
                    let child = (r + p) % n;
                    let parent = (r + (p - 1) / 2) % n;
                    parents[child] = parent;
                }
            }
            TreeKind::Chain => {
                for p in 1..n {
                    parents[(r + p) % n] = (r + p - 1) % n;
                }
            }
            TreeKind::Star => {
                for i in 0..n {
                    if i != r {
                        parents[i] = r;
                    }
                }
            }
            TreeKind::Bfs => {
                let mut queue = std::collections::VecDeque::from([r]);
                while let Some(u) = queue.pop_front() {
                    for v in exp_neighbors(u, n) {
                        if parents[v] == usize::MAX {
                            parents[v] = u;
                            queue.push_back(v);
                        }
                    }
                }
            }
            TreeKind::Dfs => {
                let mut stack = vec![r];
                while let Some(u) = stack.pop() {
                    // reversed push order: the smallest hop is explored
                    // first, giving long hop-1 paths (a deep tree)
                    for v in exp_neighbors(u, n).into_iter().rev() {
                        if parents[v] == usize::MAX {
                            parents[v] = u;
                            stack.push(v);
                        }
                    }
                }
            }
            TreeKind::Random => {
                // Wilson's algorithm on the complete digraph: from each
                // node not yet in the tree, random-walk until the tree is
                // hit, overwriting the walk's exit pointer (loop erasure),
                // then commit the loop-erased path. Deterministic per
                // seed, like Topology::gossip.
                let mut rng = Rng::stream(self.seed, 0xa2c4_7e11);
                let mut in_tree = vec![false; n];
                in_tree[r] = true;
                for start in 0..n {
                    if in_tree[start] {
                        continue;
                    }
                    let mut u = start;
                    while !in_tree[u] {
                        let v = loop {
                            let v = rng.below(n);
                            if v != u {
                                break v;
                            }
                        };
                        parents[u] = v;
                        u = v;
                    }
                    let mut u = start;
                    while !in_tree[u] {
                        in_tree[u] = true;
                        u = parents[u];
                    }
                }
            }
        }
        debug_assert!(
            parents.iter().enumerate().all(|(i, &p)| p < n && (i == r) == (p == i)),
            "not a spanning tree rooted at {r}: {parents:?}"
        );
        Ok(parents)
    }
}

/// An asymmetric (G_R, G_C) architecture: an independent spanning tree
/// per side, compiled to row-stochastic W over the pull tree and
/// column-stochastic A over the push tree (see the module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ArchSpec {
    /// G_R = G(W): parameters flow root → leaves; children pull from
    /// their parent.
    pub pull: TreeSpec,
    /// G_C = G(Aᵀ): gradient ρ-mass flows leaves → root; children push
    /// to their parent.
    pub push: TreeSpec,
}

impl ArchSpec {
    pub fn new(pull: TreeSpec, push: TreeSpec) -> ArchSpec {
        ArchSpec { pull, push }
    }

    /// Parse the pair grammar `[tree:]PULL+PUSH` (module docs).
    pub fn parse(spec: &str) -> Result<ArchSpec, String> {
        let s = spec.strip_prefix("tree:").unwrap_or(spec);
        let (a, b) = s.split_once('+').ok_or_else(|| {
            format!(
                "architecture spec wants PULL+PUSH \
                 (e.g. tree:bfs@0+star@0), got {spec:?}"
            )
        })?;
        Ok(ArchSpec { pull: TreeSpec::parse(a)?, push: TreeSpec::parse(b)? })
    }

    /// Does `spec` look like pair grammar (vs a plain topology name)?
    pub fn is_arch_spec(spec: &str) -> bool {
        spec.contains('+') || spec.starts_with("tree:")
    }

    /// Stable display name, `pull+push` — labels sweeps, reports and the
    /// typed `InvalidTopology` error.
    pub fn name(&self) -> String {
        format!("{}+{}", self.pull.name(), self.push.name())
    }

    /// Compile to a [`Topology`] over `n` nodes: uniform Appendix-G
    /// weights on {self} ∪ tree-neighbors per side. Errs on out-of-range
    /// roots; a *root mismatch* is deliberately NOT an error here — it
    /// builds fine and fails Assumption 2, which
    /// [`Experiment::run`](crate::exp::Experiment::run) (and `repro
    /// graph`) surface as the typed violation the test suite probes.
    pub fn build(&self, n: usize) -> Result<Topology, String> {
        let pull = self.pull.parents(n)?;
        let push = self.push.parents(n)?;
        // pull tree: child i pulls from its parent ⇒ W edge (parent, i)
        let w_edges: Vec<(usize, usize)> = (0..n)
            .filter(|&i| pull[i] != i)
            .map(|i| (pull[i], i))
            .collect();
        // push tree: child i pushes to its parent ⇒ A edge (i, parent)
        let a_edges: Vec<(usize, usize)> = (0..n)
            .filter(|&i| push[i] != i)
            .map(|i| (i, push[i]))
            .collect();
        Ok(Topology::from_edges(n, &w_edges, &a_edges).labeled(self.name()))
    }

    /// The standard comparison set of the fig3 bench (`repro` +
    /// EXPERIMENTS.md): four structurally distinct valid pairs sharing
    /// root 0. A fifth, root-mismatched pair for the rejection tests is
    /// [`ArchSpec::no_common_root_pair`].
    pub fn paper_pairs() -> Vec<ArchSpec> {
        ["balanced@0+star@0",
         "chain@0+balanced@0",
         "bfs@0+dfs@0",
         "random@0:7+random@0:21"]
            .iter()
            // lint:allow(panic-path): literal builtin specs, parse covered by tests
            .map(|s| ArchSpec::parse(s).expect("builtin pair"))
            .collect()
    }

    /// A pair whose trees are rooted at different nodes — G(W)'s root set
    /// is {0}, G(Aᵀ)'s is {1}, so Assumption 2's common-root set is empty.
    pub fn no_common_root_pair() -> ArchSpec {
        // lint:allow(panic-path): literal builtin spec, parse covered by tests
        ArchSpec::parse("balanced@0+star@1").expect("builtin pair")
    }

    /// Seeded random pair for the fault-space fuzzer
    /// ([`fuzz`](crate::fuzz)): each side draws an independent
    /// construction (all six [`TreeKind`]s, so [`TreeKind::Random`]
    /// Wilson trees appear too, with their own sub-seed). Both sides are
    /// rooted at node 0, so the pair satisfies Assumption 2 at EVERY
    /// `n ≥ 1` — the shrinker can reduce the node count without ever
    /// invalidating the architecture. Deterministic per RNG state.
    pub fn sample(rng: &mut Rng) -> ArchSpec {
        const KINDS: [TreeKind; 6] = [TreeKind::Bfs, TreeKind::Dfs,
                                      TreeKind::Balanced, TreeKind::Chain,
                                      TreeKind::Star, TreeKind::Random];
        let mut side = |rng: &mut Rng| {
            let kind = KINDS[rng.below(KINDS.len())];
            let seed = match kind {
                // small seeds keep the pair-grammar name readable
                TreeKind::Random => rng.below(1_000_000) as u64,
                _ => 0,
            };
            TreeSpec { kind, root: 0, seed }
        };
        ArchSpec { pull: side(rng), push: side(rng) }
    }
}

/// Out-neighbors of `u` in the exponential base digraph
/// (`u → (u + 2^k) mod n` for all `2^k < n`), in increasing hop order.
fn exp_neighbors(u: usize, n: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut hop = 1;
    while hop < n {
        let v = (u + hop) % n;
        if v != u && !out.contains(&v) {
            out.push(v);
        }
        hop *= 2;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::AssumptionError;

    fn tree(kind: TreeKind, root: usize) -> TreeSpec {
        TreeSpec::new(kind, root)
    }

    fn is_spanning_tree(parents: &[usize], root: usize) {
        let n = parents.len();
        assert_eq!(parents[root], root);
        for i in 0..n {
            // every node walks up to the root without cycling
            let mut u = i;
            for _ in 0..=n {
                if u == root {
                    break;
                }
                u = parents[u];
            }
            assert_eq!(u, root, "node {i} does not reach root {root}");
        }
    }

    #[test]
    fn every_construction_spans_at_every_root() {
        for n in [1usize, 2, 3, 5, 8, 13, 16] {
            for kind in [TreeKind::Bfs, TreeKind::Dfs, TreeKind::Balanced,
                         TreeKind::Chain, TreeKind::Star, TreeKind::Random] {
                for root in [0, n / 2, n - 1] {
                    let p = TreeSpec { kind, root, seed: 5 }
                        .parents(n)
                        .unwrap_or_else(|e| panic!("{kind:?}@{root} n={n}: {e}"));
                    is_spanning_tree(&p, root);
                }
            }
        }
    }

    #[test]
    fn out_of_range_root_is_an_error() {
        let e = tree(TreeKind::Star, 7).parents(4).unwrap_err();
        assert!(e.contains("out of range"), "{e}");
        assert!(ArchSpec::parse("star@7+star@7").unwrap().build(4).is_err());
    }

    #[test]
    fn grammar_roundtrip() {
        for s in ["bfs@0+star@0", "chain@2+balanced@2", "random@1:7+dfs@1",
                  "random@0:7+random@0:21"] {
            let a = ArchSpec::parse(s).unwrap();
            assert_eq!(a.name(), s);
            // the cosmetic tree: prefix parses to the same spec
            assert_eq!(ArchSpec::parse(&format!("tree:{s}")).unwrap(), a);
        }
        // defaults: root 0, seed 0
        let a = ArchSpec::parse("bfs+star").unwrap();
        assert_eq!(a.pull, tree(TreeKind::Bfs, 0));
        assert_eq!(a.push, tree(TreeKind::Star, 0));
        assert!(ArchSpec::parse("bfs@0").is_err()); // no pair
        assert!(ArchSpec::parse("bogus@0+star@0").is_err());
        assert!(ArchSpec::parse("bfs@x+star@0").is_err());
        assert!(ArchSpec::parse("random@0:z+star@0").is_err());
        assert!(ArchSpec::is_arch_spec("bfs@0+star@0"));
        assert!(ArchSpec::is_arch_spec("tree:bfs@0+star@0"));
        assert!(!ArchSpec::is_arch_spec("ring"));
    }

    #[test]
    fn shared_root_pairs_satisfy_assumption_2() {
        for n in [2usize, 3, 7, 8, 16] {
            for spec in ArchSpec::paper_pairs() {
                let t = spec.build(n).unwrap();
                let errs = t.weights.check_assumptions();
                assert!(errs.is_empty(), "{} n={n}: {errs:?}", spec.name());
                assert_eq!(t.weights.common_roots(), vec![0],
                           "{} n={n}", spec.name());
                assert_eq!(t.name(), spec.name());
            }
        }
    }

    #[test]
    fn root_mismatch_has_no_common_root() {
        let t = ArchSpec::no_common_root_pair().build(6).unwrap();
        assert_eq!(t.weights.roots_w(), vec![0]);
        assert_eq!(t.weights.roots_at(), vec![1]);
        let errs = t.weights.check_assumptions();
        assert!(errs.contains(&AssumptionError::NoCommonRoot), "{errs:?}");
    }

    #[test]
    fn pull_and_push_sides_are_genuinely_independent() {
        // chain pull / star push: W rows follow the chain, A columns the
        // star — no relation between the two edge sets
        let t = ArchSpec::parse("chain@0+star@0").unwrap().build(5).unwrap();
        for i in 1..5 {
            assert!(t.weights.w.get(i, i - 1) > 0.0, "chain pull edge {i}");
            assert!(t.weights.a.get(0, i) > 0.0, "star push edge {i}");
        }
        // the star's direct pull edges do NOT exist in W (beyond 0→1)
        assert_eq!(t.weights.w.get(3, 0), 0.0);
        // and the chain's hop edges do NOT exist in A
        assert_eq!(t.weights.a.get(2, 3), 0.0);
    }

    #[test]
    fn sampled_pairs_build_and_satisfy_assumption_2_at_every_n() {
        use crate::prng::Rng;
        for seed in 0..50u64 {
            let mut rng = Rng::new(seed);
            let spec = ArchSpec::sample(&mut rng);
            // the pair grammar round-trips the sampled spec (repro JSON
            // stores the name string)
            assert_eq!(ArchSpec::parse(&spec.name()).unwrap(), spec);
            for n in [2usize, 3, 7, 10] {
                let t = spec.build(n).unwrap_or_else(|e| {
                    panic!("{} n={n}: {e}", spec.name())
                });
                let errs = t.weights.check_assumptions();
                assert!(errs.is_empty(), "{} n={n}: {errs:?}", spec.name());
                assert_eq!(t.weights.common_roots(), vec![0]);
            }
        }
        // deterministic per RNG state
        let mk = || ArchSpec::sample(&mut Rng::new(11));
        assert_eq!(mk(), mk());
    }

    #[test]
    fn random_trees_are_seed_deterministic_and_seed_sensitive() {
        let mk = |seed| {
            ArchSpec {
                pull: TreeSpec { kind: TreeKind::Random, root: 2, seed },
                push: tree(TreeKind::Star, 2),
            }
            .build(12)
            .unwrap()
        };
        let a = mk(7);
        let b = mk(7);
        // bitwise: SparseWeights is PartialEq over the raw weight storage
        assert_eq!(a.weights.w, b.weights.w);
        assert_eq!(a.weights.a, b.weights.a);
        let c = mk(8);
        assert_ne!(a.weights.w, c.weights.w, "seed must matter");
    }

    #[test]
    fn bfs_is_shallower_than_dfs() {
        let depth = |parents: &[usize], root: usize| -> usize {
            (0..parents.len())
                .map(|i| {
                    let mut d = 0;
                    let mut u = i;
                    while u != root {
                        u = parents[u];
                        d += 1;
                    }
                    d
                })
                .max()
                .unwrap()
        };
        let n = 16;
        let bfs = tree(TreeKind::Bfs, 0).parents(n).unwrap();
        let dfs = tree(TreeKind::Dfs, 0).parents(n).unwrap();
        assert!(depth(&bfs, 0) < depth(&dfs, 0),
                "bfs {} vs dfs {}", depth(&bfs, 0), depth(&dfs, 0));
    }
}
