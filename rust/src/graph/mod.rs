//! Directed topologies + the paper's two-matrix communication structure.
//!
//! R-FAST communicates over two induced graphs (paper §III):
//!
//! * `G(W)` — **pull/consensus** graph, `W` row-stochastic. Edge `(j, i)`
//!   (i.e. `W[i][j] > 0`) means node *i* pulls `v_j` from node *j*.
//! * `G(A)` — **push/tracking** graph, `A` column-stochastic. `A[j][i] > 0`
//!   means node *i* pushes ρ-mass to node *j*.
//!
//! Assumption 1: positive diagonals, non-zero entries ≥ m̄, stochasticity.
//! Assumption 2: `G(W)` and `G(Aᵀ)` each contain a spanning tree and share
//! at least one common root — *much* weaker than strong connectivity, and
//! the reason the paper can run on plain trees/lines (Fig 3, Appendix G).
//!
//! [`Topology`] bundles both matrices plus builders for every topology used
//! in the paper's experiments (binary tree, line, directed ring,
//! exponential, mesh) and the structures Appendix G calls out as special
//! cases (star/parameter-server, random gossip). Every one of those
//! derives W and A from a single base graph; the [`arch`] module builds
//! the *asymmetric* case — [`ArchSpec`] pairs of two independent spanning
//! trees (Fig. 3), reachable from the CLI via [`Topology::from_spec`].

pub mod arch;
pub mod augmented;
mod matrix;
mod sparse;
mod topology;

pub use arch::{ArchSpec, TreeKind, TreeSpec};
pub use augmented::AugmentedAnalysis;
pub use matrix::Mat;
pub use sparse::{Axis, SparseWeights, DENSE_COMPAT_MAX};
pub use topology::{Topology, TopologyKind};

/// The (W, A) pair with cached neighbor lists, ready for algorithm use.
///
/// Storage is sparse ([`SparseWeights`], O(edges) — DESIGN.md §13); the
/// dense [`Mat`] survives only as a small-n compatibility boundary
/// ([`WeightMatrices::new`] converts in, [`SparseWeights::to_dense`]
/// converts out).
#[derive(Clone, Debug, PartialEq)]
pub struct WeightMatrices {
    pub n: usize,
    /// Row-stochastic pull matrix (row-primary sparse storage).
    pub w: SparseWeights,
    /// Column-stochastic push matrix (column-primary sparse storage).
    pub a: SparseWeights,
    /// `w_in[i]` = in-neighbors j (≠ i) of i in G(W): `W[i][j] > 0`.
    pub w_in: Vec<Vec<usize>>,
    /// `w_out[i]` = out-neighbors j (≠ i) of i in G(W): `W[j][i] > 0`.
    pub w_out: Vec<Vec<usize>>,
    /// `a_in[i]` = in-neighbors j of i in G(A): `A[i][j] > 0`.
    pub a_in: Vec<Vec<usize>>,
    /// `a_out[i]` = out-neighbors j of i in G(A): `A[j][i] > 0`.
    pub a_out: Vec<Vec<usize>>,
}

/// Assumption-violation report (all violations, not just the first).
#[derive(Debug, Clone, PartialEq)]
pub enum AssumptionError {
    /// `W[i][i] == 0` or `A[i][i] == 0`.
    ZeroDiagonal { matrix: char, node: usize },
    /// Row of W (resp. column of A) does not sum to 1.
    NotStochastic { matrix: char, index: usize, sum: f64 },
    /// A present entry is negative.
    NegativeEntry { matrix: char, row: usize, col: usize },
    /// G(W) has no spanning tree (no node reaches all others).
    NoSpanningTreeW,
    /// G(Aᵀ) has no spanning tree.
    NoSpanningTreeAt,
    /// Spanning trees exist but share no common root (Assumption 2 fails).
    NoCommonRoot,
}

impl std::fmt::Display for AssumptionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AssumptionError::ZeroDiagonal { matrix, node } => {
                write!(f, "{matrix}[{node}][{node}] must be > 0 (Assumption 1i)")
            }
            AssumptionError::NotStochastic { matrix, index, sum } => {
                let kind = if *matrix == 'W' { "row" } else { "column" };
                write!(f, "{matrix} {kind} {index} sums to {sum} ≠ 1 (Assumption 1ii)")
            }
            AssumptionError::NegativeEntry { matrix, row, col } => {
                write!(f, "{matrix}[{row}][{col}] < 0")
            }
            AssumptionError::NoSpanningTreeW => {
                write!(f, "G(W) contains no spanning tree (Assumption 2)")
            }
            AssumptionError::NoSpanningTreeAt => {
                write!(f, "G(Aᵀ) contains no spanning tree (Assumption 2)")
            }
            AssumptionError::NoCommonRoot => {
                write!(f, "R_W ∩ R_Aᵀ = ∅: no common root (Assumption 2)")
            }
        }
    }
}

impl WeightMatrices {
    /// Dense compatibility constructor: convert and cache. Small-n only
    /// (hand-built matrices in tests, analysis code); builders go
    /// through [`WeightMatrices::from_sparse`].
    pub fn new(w: Mat, a: Mat) -> Self {
        assert_eq!(w.n(), a.n());
        Self::from_sparse(
            SparseWeights::from_mat(&w, Axis::Row),
            SparseWeights::from_mat(&a, Axis::Col),
        )
    }

    /// Build from sparse matrices, caching neighbor lists. The lists
    /// come out index-sorted exactly as the old dense n² scan produced
    /// them (ascending secondary index per node).
    pub fn from_sparse(w: SparseWeights, a: SparseWeights) -> Self {
        assert_eq!(w.n(), a.n());
        assert_eq!(w.axis(), Axis::Row, "W must be row-primary");
        assert_eq!(a.axis(), Axis::Col, "A must be column-primary");
        let n = w.n();
        let mut w_in = vec![Vec::new(); n];
        let mut w_out = vec![Vec::new(); n];
        let mut a_in = vec![Vec::new(); n];
        let mut a_out = vec![Vec::new(); n];
        for i in 0..n {
            // row i of W sorted by j: w_in[i] ascending; and since the
            // outer i ascends, every w_out[j] ascends too
            for &(j, v) in w.line(i) {
                let j = j as usize;
                if j != i && v > 0.0 {
                    w_in[i].push(j);
                    w_out[j].push(i);
                }
            }
        }
        for j in 0..n {
            // column j of A sorted by i: a_out[j] ascending; outer j
            // ascending keeps every a_in[i] ascending
            for &(i, v) in a.line(j) {
                let i = i as usize;
                if i != j && v > 0.0 {
                    a_in[i].push(j);
                    a_out[j].push(i);
                }
            }
        }
        WeightMatrices { n, w, a, w_in, w_out, a_in, a_out }
    }

    /// Roots of spanning trees of G(W): nodes that reach every node along
    /// edges `j → i` whenever `W[i][j] > 0`. O(V+E) via the cached
    /// neighbor lists (out-neighbors of u in G(W) are `w_out[u]`).
    pub fn roots_w(&self) -> Vec<usize> {
        roots_fast(self.n, &self.w_out, &self.w_in)
    }

    /// Roots of spanning trees of G(Aᵀ): edges `j → i` whenever
    /// `Aᵀ[i][j] = A[j][i] > 0` — so out-neighbors of u are `a_in[u]`
    /// (the nodes u pushes to) and in-neighbors are `a_out[u]`.
    pub fn roots_at(&self) -> Vec<usize> {
        roots_fast(self.n, &self.a_in, &self.a_out)
    }

    /// `R = R_W ∩ R_Aᵀ` — the common roots whose activations drive the
    /// optimality-gap contraction (paper's two-time-scale analysis).
    pub fn common_roots(&self) -> Vec<usize> {
        let rw = self.roots_w();
        let ra = self.roots_at();
        rw.into_iter().filter(|r| ra.contains(r)).collect()
    }

    /// Smallest non-zero mixing weight m̄ (Assumption 1i).
    pub fn min_weight(&self) -> f64 {
        self.w.min_positive().min(self.a.min_positive())
    }

    /// Validate Assumptions 1 and 2, returning every violation. O(V+E):
    /// the negative-entry scan merges the stored entries of W row i and
    /// A row i in ascending-j order (absent cells are exact zeros and
    /// can't be negative), so the violation *order* matches the old
    /// dense j-loop exactly — W(i,j) before A(i,j) for each j.
    pub fn check_assumptions(&self) -> Vec<AssumptionError> {
        let mut errs = Vec::new();
        const TOL: f64 = 1e-5;
        let a_rows = self.a.off_axis_lists();
        for i in 0..self.n {
            if self.w.get(i, i) <= 0.0 {
                errs.push(AssumptionError::ZeroDiagonal { matrix: 'W', node: i });
            }
            if self.a.get(i, i) <= 0.0 {
                errs.push(AssumptionError::ZeroDiagonal { matrix: 'A', node: i });
            }
            let rs = self.w.row_sum(i);
            if (rs - 1.0).abs() > TOL {
                errs.push(AssumptionError::NotStochastic {
                    matrix: 'W', index: i, sum: rs,
                });
            }
            let cs = self.a.col_sum(i);
            if (cs - 1.0).abs() > TOL {
                errs.push(AssumptionError::NotStochastic {
                    matrix: 'A', index: i, sum: cs,
                });
            }
            let wr = self.w.line(i);
            let ar = &a_rows[i];
            let (mut p, mut q) = (0, 0);
            while p < wr.len() || q < ar.len() {
                let jw = wr.get(p).map(|e| e.0);
                let ja = ar.get(q).map(|e| e.0);
                let take_w = match (jw, ja) {
                    (Some(x), Some(y)) => x <= y,
                    (Some(_), None) => true,
                    (None, _) => false,
                };
                if take_w {
                    let (j, v) = wr[p];
                    if v < 0.0 {
                        errs.push(AssumptionError::NegativeEntry {
                            matrix: 'W', row: i, col: j as usize,
                        });
                    }
                    p += 1;
                    if jw == ja {
                        let (j, v) = ar[q];
                        if v < 0.0 {
                            errs.push(AssumptionError::NegativeEntry {
                                matrix: 'A', row: i, col: j as usize,
                            });
                        }
                        q += 1;
                    }
                } else {
                    let (j, v) = ar[q];
                    if v < 0.0 {
                        errs.push(AssumptionError::NegativeEntry {
                            matrix: 'A', row: i, col: j as usize,
                        });
                    }
                    q += 1;
                }
            }
        }
        let rw = self.roots_w();
        let ra = self.roots_at();
        if rw.is_empty() {
            errs.push(AssumptionError::NoSpanningTreeW);
        }
        if ra.is_empty() {
            errs.push(AssumptionError::NoSpanningTreeAt);
        }
        if !rw.is_empty() && !ra.is_empty() && self.common_roots().is_empty() {
            errs.push(AssumptionError::NoCommonRoot);
        }
        errs
    }

    /// Edge count of G(A) — |E(A)|, sizing the augmented tracking system.
    pub fn a_edge_count(&self) -> usize {
        self.a_in.iter().map(|v| v.len()).sum()
    }
}

/// Root set of a digraph given by adjacency lists, in O(V+E).
///
/// Kosaraju's candidate trick: run one full DFS sweep (iterative — a
/// 50k-node chain would blow the call stack) and take the last-finished
/// vertex `c`, which lies in a *source* SCC of the condensation. If any
/// root exists, its SCC is a source that reaches everything, so it is
/// the unique source SCC and contains `c`. Therefore: roots exist iff
/// `c` reaches all n vertices, and then v is a root iff v reaches `c`
/// (v → c → everything). Output ascending, identical to the dense
/// all-candidates BFS (`roots_of`, kept below as the test oracle).
fn roots_fast(n: usize, out_adj: &[Vec<usize>], in_adj: &[Vec<usize>]) -> Vec<usize> {
    if n == 0 {
        return Vec::new();
    }
    // 1. full-sweep iterative DFS; `candidate` ends as the last finisher
    let mut state = vec![0u8; n]; // 0 = unvisited, 1 = in progress, 2 = done
    let mut candidate = 0usize;
    let mut stack: Vec<(usize, usize)> = Vec::new(); // (vertex, next-child cursor)
    for s in 0..n {
        if state[s] != 0 {
            continue;
        }
        state[s] = 1;
        stack.push((s, 0));
        while let Some(top) = stack.last_mut() {
            let u = top.0;
            if top.1 < out_adj[u].len() {
                let v = out_adj[u][top.1];
                top.1 += 1;
                if state[v] == 0 {
                    state[v] = 1;
                    stack.push((v, 0));
                }
            } else {
                state[u] = 2;
                candidate = u;
                stack.pop();
            }
        }
    }
    // 2. candidate must reach every vertex, else there are no roots
    let mut fwd = vec![false; n];
    let mut queue = vec![candidate];
    fwd[candidate] = true;
    let mut count = 1;
    while let Some(u) = queue.pop() {
        for &v in &out_adj[u] {
            if !fwd[v] {
                fwd[v] = true;
                count += 1;
                queue.push(v);
            }
        }
    }
    if count != n {
        return Vec::new();
    }
    // 3. roots = everything that reaches the candidate
    let mut back = vec![false; n];
    let mut queue = vec![candidate];
    back[candidate] = true;
    while let Some(u) = queue.pop() {
        for &v in &in_adj[u] {
            if !back[v] {
                back[v] = true;
                queue.push(v);
            }
        }
    }
    (0..n).filter(|&v| back[v]).collect()
}

/// Nodes from which every node is reachable under `edge(from, to)`.
/// O(n · (V+E)) reference oracle for [`roots_fast`]; test-only.
#[cfg(test)]
fn roots_of(n: usize, edge: impl Fn(usize, usize) -> bool) -> Vec<usize> {
    (0..n)
        .filter(|&r| {
            // BFS from r
            let mut seen = vec![false; n];
            let mut queue = vec![r];
            seen[r] = true;
            let mut count = 1;
            while let Some(u) = queue.pop() {
                for v in 0..n {
                    if !seen[v] && edge(u, v) {
                        seen[v] = true;
                        count += 1;
                        queue.push(v);
                    }
                }
            }
            count == n
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_ok(t: &Topology) {
        let errs = t.weights.check_assumptions();
        assert!(errs.is_empty(), "{:?}: {:?}", t.kind, errs);
        assert!(!t.weights.common_roots().is_empty(), "{:?}", t.kind);
    }

    #[test]
    fn all_builders_satisfy_assumptions() {
        for n in [2, 3, 4, 7, 8, 15, 16, 31] {
            check_ok(&Topology::binary_tree(n));
            check_ok(&Topology::line(n));
            check_ok(&Topology::ring(n));
            check_ok(&Topology::exponential(n));
            check_ok(&Topology::star(n));
            if n >= 4 {
                check_ok(&Topology::mesh(n));
            }
            check_ok(&Topology::gossip(n, 3, 42));
        }
    }

    #[test]
    fn binary_tree_root_is_node_zero() {
        let t = Topology::binary_tree(7);
        assert_eq!(t.weights.roots_w(), vec![0]);
        assert_eq!(t.weights.roots_at(), vec![0]);
        assert_eq!(t.weights.common_roots(), vec![0]);
    }

    #[test]
    fn ring_every_node_is_root() {
        let t = Topology::ring(5);
        assert_eq!(t.weights.common_roots(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn line_has_single_root() {
        let t = Topology::line(6);
        assert_eq!(t.weights.common_roots(), vec![0]);
    }

    #[test]
    fn tree_is_not_strongly_connected() {
        // The whole point of Assumption 2: G(W) alone is NOT strongly
        // connected for a tree (leaves can't reach the root).
        let t = Topology::binary_tree(7);
        let leaf = 6;
        let roots = roots_of(t.n(), |from, to| t.weights.w.get(to, from) > 0.0);
        assert!(!roots.contains(&leaf));
    }

    #[test]
    fn broken_matrices_are_reported() {
        let n = 3;
        let mut w = Mat::zeros(n);
        let mut a = Mat::zeros(n);
        // identity-ish but disconnected and row 0 not stochastic
        for i in 0..n {
            w.set(i, i, 0.5);
            a.set(i, i, 1.0);
        }
        let wm = WeightMatrices::new(w, a);
        let errs = wm.check_assumptions();
        assert!(errs.iter().any(|e| matches!(e, AssumptionError::NotStochastic { matrix: 'W', .. })));
        assert!(errs.contains(&AssumptionError::NoSpanningTreeW));
    }

    #[test]
    fn no_common_root_detected() {
        // W: tree rooted at 0 (0→1, 0→2); A: tree rooted at... make G(Aᵀ)
        // rooted ONLY at 1 while G(W) rooted only at 0.
        let n = 3;
        let mut w = Mat::zeros(n);
        // W[i][j] > 0 means edge j→i: root 0 reaches 1 and 2.
        w.set(0, 0, 1.0);
        w.set(1, 1, 0.5);
        w.set(1, 0, 0.5);
        w.set(2, 2, 0.5);
        w.set(2, 0, 0.5);
        // A column-stochastic with G(Aᵀ) rooted at 1: edges 1→0, 1→2 in Aᵀ
        // mean A[1][0] > 0? Aᵀ[i][j] = A[j][i] > 0 edge j→i: want edges
        // 1→0 (A[0][1] > 0… wait A[j][i]: edge from j to i needs A_ji? —
        // Aᵀ edge (j,i) iff A[j][i] > 0. Edge 1→0 ⇒ A[1][0] > 0.
        let mut a = Mat::zeros(n);
        a.set(1, 0, 0.5); // edge 1→0 in G(Aᵀ)? A[1][0]>0 ⇒ Aᵀ[0][1]>0 ⇒ edge 1→0 ✓
        a.set(0, 0, 0.5);
        a.set(1, 1, 0.5);
        a.set(1, 2, 0.5); // A[1][2]>0 ⇒ Aᵀ[2][1]>0 ⇒ edge 1→2 ✓
        a.set(2, 2, 0.5);
        let wm = WeightMatrices::new(w, a);
        assert_eq!(wm.roots_w(), vec![0]);
        assert_eq!(wm.roots_at(), vec![1]);
        let errs = wm.check_assumptions();
        assert!(errs.contains(&AssumptionError::NoCommonRoot), "{errs:?}");
    }

    #[test]
    fn fast_roots_match_bfs_oracle() {
        let topos = [
            Topology::binary_tree(7),
            Topology::line(5),
            Topology::ring(6),
            Topology::exponential(8),
            Topology::star(9),
            Topology::mesh(9),
            Topology::gossip(10, 3, 7),
        ];
        for t in &topos {
            let wm = &t.weights;
            assert_eq!(
                wm.roots_w(),
                roots_of(wm.n, |from, to| wm.w.get(to, from) > 0.0),
                "{:?} W",
                t.kind
            );
            assert_eq!(
                wm.roots_at(),
                roots_of(wm.n, |from, to| wm.a.get(from, to) > 0.0),
                "{:?} At",
                t.kind
            );
        }
        // disconnected: no edges at all ⇒ no roots (n > 1)
        let wm = WeightMatrices::new(Mat::identity(4), Mat::identity(4));
        assert!(wm.roots_w().is_empty());
        assert!(wm.roots_at().is_empty());
        // degenerate single node: trivially its own root
        let wm1 = WeightMatrices::new(Mat::identity(1), Mat::identity(1));
        assert_eq!(wm1.roots_w(), vec![0]);
        assert_eq!(wm1.common_roots(), vec![0]);
    }

    #[test]
    fn min_weight_positive() {
        let t = Topology::binary_tree(15);
        assert!(t.weights.min_weight() > 0.0);
        assert!(t.weights.min_weight() <= 1.0);
    }

    #[test]
    fn neighbor_lists_consistent_with_matrices() {
        let t = Topology::exponential(8);
        let wm = &t.weights;
        for i in 0..8 {
            for &j in &wm.w_in[i] {
                assert!(wm.w.get(i, j) > 0.0);
                assert!(wm.w_out[j].contains(&i));
            }
            for &j in &wm.a_out[i] {
                assert!(wm.a.get(j, i) > 0.0);
                assert!(wm.a_in[j].contains(&i));
            }
        }
    }
}
