//! Dense n×n matrix — the *small-n compatibility boundary* for mixing
//! weights. Production topologies live in [`super::SparseWeights`]
//! (DESIGN.md §13); `Mat` remains for hand-built matrices in tests, the
//! dense reference construction path (`Topology::from_edges_dense`), and
//! small-n analysis code that iterates full rows.

/// Row-major dense square matrix of f32 weights.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    n: usize,
    data: Vec<f32>,
}

impl Mat {
    pub fn zeros(n: usize) -> Self {
        Mat { n, data: vec![0.0; n * n] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Mat::zeros(n);
        for i in 0..n {
            m.set(i, i, 1.0);
        }
        m
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.n + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.n + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.n..(i + 1) * self.n]
    }

    pub fn row_sum(&self, i: usize) -> f64 {
        self.row(i).iter().map(|&x| x as f64).sum()
    }

    pub fn col_sum(&self, j: usize) -> f64 {
        (0..self.n).map(|i| self.get(i, j) as f64).sum()
    }

    /// Normalize each row to sum 1 (build row-stochastic W from adjacency).
    pub fn normalize_rows(&mut self) {
        for i in 0..self.n {
            let s = self.row_sum(i);
            if s > 0.0 {
                let inv = (1.0 / s) as f32;
                for j in 0..self.n {
                    let v = self.get(i, j);
                    self.set(i, j, v * inv);
                }
            }
        }
    }

    /// Normalize each column to sum 1 (build column-stochastic A).
    pub fn normalize_cols(&mut self) {
        for j in 0..self.n {
            let s = self.col_sum(j);
            if s > 0.0 {
                let inv = (1.0 / s) as f32;
                for i in 0..self.n {
                    let v = self.get(i, j);
                    self.set(i, j, v * inv);
                }
            }
        }
    }

    /// Transpose (used to build G(A) from a W-style adjacency).
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.n);
        for i in 0..self.n {
            for j in 0..self.n {
                t.set(j, i, self.get(i, j));
            }
        }
        t
    }

    /// y = M · x for column vectors stacked as rows of a flat slice-of-slices
    /// (used by tests to iterate the consensus dynamics directly).
    pub fn apply_rows(&self, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        assert_eq!(xs.len(), self.n);
        let p = xs[0].len();
        let mut out = vec![vec![0.0f32; p]; self.n];
        for i in 0..self.n {
            for j in 0..self.n {
                let w = self.get(i, j);
                if w != 0.0 {
                    crate::linalg::axpy(&mut out[i], w, &xs[j]);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalize_rows_makes_stochastic() {
        let mut m = Mat::zeros(3);
        m.set(0, 0, 2.0);
        m.set(0, 1, 2.0);
        m.set(1, 1, 5.0);
        m.set(2, 0, 1.0);
        m.set(2, 2, 3.0);
        m.normalize_rows();
        for i in 0..3 {
            assert!((m.row_sum(i) - 1.0).abs() < 1e-6);
        }
        assert!((m.get(0, 0) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn normalize_cols_makes_col_stochastic() {
        let mut m = Mat::zeros(2);
        m.set(0, 0, 1.0);
        m.set(1, 0, 3.0);
        m.set(1, 1, 2.0);
        m.normalize_cols();
        for j in 0..2 {
            assert!((m.col_sum(j) - 1.0).abs() < 1e-6);
        }
        assert!((m.get(1, 0) - 0.75).abs() < 1e-6);
    }

    #[test]
    fn transpose_involution() {
        let mut m = Mat::zeros(3);
        m.set(0, 1, 1.0);
        m.set(2, 0, 5.0);
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose().get(1, 0), 1.0);
    }

    #[test]
    fn apply_rows_identity() {
        let m = Mat::identity(2);
        let xs = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        assert_eq!(m.apply_rows(&xs), xs);
    }

    #[test]
    fn apply_rows_mixes() {
        let mut m = Mat::zeros(2);
        m.set(0, 0, 0.5);
        m.set(0, 1, 0.5);
        m.set(1, 1, 1.0);
        let xs = vec![vec![0.0f32], vec![10.0f32]];
        let out = m.apply_rows(&xs);
        assert_eq!(out[0][0], 5.0);
        assert_eq!(out[1][0], 10.0);
    }
}
