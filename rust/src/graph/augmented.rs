//! The paper's augmented-system analysis (Appendix E/F) as executable
//! code: build the delay-augmented mixing matrices Ŵ^k / Â^k for a given
//! activation schedule and verify / exploit Lemmas 1-3 numerically.
//!
//! * Consensus side (Appendix E): D+1 virtual nodes per real node hold the
//!   delayed v-values; Ŵ^k ∈ R^{(D+2)n × (D+2)n} is row-stochastic and the
//!   products Ŵ^{k:t} contract to a rank-one 1·ψᵀ (Lemma 1).
//! * Tracking side (Appendix F): D+1 virtual nodes per edge of E(A) hold
//!   in-flight ρ-mass; Â^k = P^k S^k is column-stochastic and Â^{k:t}
//!   contracts columnwise to ξ (Lemma 2); mass is conserved (Lemma 3).
//!
//! Practical use: [`AugmentedAnalysis::estimate`] empirically measures the
//! contraction factor ρ̂ and the eigenvector masses (ψ_i, ξ_i) of the
//! common roots under a round-robin schedule — the quantities that govern
//! the stable-step-size window γ̄ and the effective step γ·ψ_i·ξ_i
//! (DESIGN.md §9.3/§9.5). `repro graph --analyze` exposes it on the CLI.

use super::{Topology, WeightMatrices};

/// Dense square matrix over the augmented index space (sizes are
/// (D+2)n or n + (D+1)|E(A)| — tens to hundreds; dense is fine).
#[derive(Clone, Debug)]
pub struct BigMat {
    pub n: usize,
    pub data: Vec<f64>,
}

impl BigMat {
    pub fn zeros(n: usize) -> BigMat {
        BigMat { n, data: vec![0.0; n * n] }
    }

    pub fn identity(n: usize) -> BigMat {
        let mut m = BigMat::zeros(n);
        for i in 0..n {
            m.set(i, i, 1.0);
        }
        m
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.n + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.n + j] = v;
    }

    pub fn matmul(&self, rhs: &BigMat) -> BigMat {
        assert_eq!(self.n, rhs.n);
        let n = self.n;
        let mut out = BigMat::zeros(n);
        for i in 0..n {
            for k in 0..n {
                let a = self.get(i, k);
                if a != 0.0 {
                    for j in 0..n {
                        out.data[i * n + j] += a * rhs.get(k, j);
                    }
                }
            }
        }
        out
    }

    pub fn row_sum(&self, i: usize) -> f64 {
        (0..self.n).map(|j| self.get(i, j)).sum()
    }

    pub fn col_sum(&self, j: usize) -> f64 {
        (0..self.n).map(|i| self.get(i, j)).sum()
    }

    /// max_j ‖column j − mean column‖₁ — distance from rank-one (columns
    /// all equal ⇒ 0). Used for the Â-side contraction.
    pub fn col_spread(&self) -> f64 {
        let n = self.n;
        let mut mean = vec![0.0; n];
        for i in 0..n {
            for j in 0..n {
                mean[i] += self.get(i, j) / n as f64;
            }
        }
        (0..n)
            .map(|j| {
                (0..n)
                    .map(|i| (self.get(i, j) - mean[i]).abs())
                    .sum::<f64>()
            })
            .fold(0.0, f64::max)
    }

    /// max_i ‖row i − mean row‖₁ (Ŵ-side: rows converge to ψᵀ).
    pub fn row_spread(&self) -> f64 {
        let n = self.n;
        let mut mean = vec![0.0; n];
        for i in 0..n {
            for j in 0..n {
                mean[j] += self.get(i, j) / n as f64;
            }
        }
        (0..n)
            .map(|i| {
                (0..n)
                    .map(|j| (self.get(i, j) - mean[j]).abs())
                    .sum::<f64>()
            })
            .fold(0.0, f64::max)
    }
}

/// Index helpers for the consensus augmentation: real node i ↦ i;
/// virtual i[d] (holding v_i^{k−d}) ↦ n·(d+1) + i, d = 0..=D.
pub struct ConsensusAug<'a> {
    wm: &'a WeightMatrices,
    pub delay: usize,
    pub size: usize,
}

impl<'a> ConsensusAug<'a> {
    pub fn new(wm: &'a WeightMatrices, delay: usize) -> ConsensusAug<'a> {
        ConsensusAug { wm, delay, size: (delay + 2) * wm.n }
    }

    /// Ŵ^k for global iteration k with active node `i_k` and per-in-
    /// neighbor delays `d_v[j] ≤ D` (paper eq. (85)).
    pub fn step_matrix(&self, i_k: usize, d_v: &dyn Fn(usize) -> usize) -> BigMat {
        let n = self.wm.n;
        let mut m = BigMat::zeros(self.size);
        // active node i_k: row mixes its own fresh v with delayed v_j
        m.set(i_k, i_k, self.wm.w.get(i_k, i_k) as f64);
        for &j in &self.wm.w_in[i_k] {
            let d = d_v(j).min(self.delay);
            // v_j^{k-d} lives at slot n·(d+1) + j
            m.set(i_k, n * (d + 1) + j, self.wm.w.get(i_k, j) as f64);
        }
        // other real nodes: unchanged
        for i in 0..n {
            if i != i_k {
                m.set(i, i, 1.0);
            }
        }
        // virtual chain: i_k[0] copies the fresh value from the real node
        // (which equals v^{k+1} of i_k); others shift i[d] ← i[d-1]
        for i in 0..n {
            if i == i_k {
                m.set(n + i, i, 1.0);
            } else {
                m.set(n + i, n + i, 1.0);
            }
            for d in 1..=self.delay {
                m.set(n * (d + 1) + i, n * d + i, 1.0);
            }
        }
        m
    }
}

/// Result of the empirical Lemma-1/2 analysis of a topology.
#[derive(Clone, Debug)]
pub struct AugmentedAnalysis {
    /// Empirical per-iteration contraction factor of Ŵ^{k:0} row-spread
    /// (Lemma 1's ρ).
    pub rho_w: f64,
    /// ψ-mass of each common root (Lemma 1's ψ_i ≥ η lower bound is on
    /// these entries).
    pub psi_roots: Vec<(usize, f64)>,
    /// Lemma 1's η = m̄^K1 *worst-case* bound for comparison.
    pub eta_bound: f64,
    /// K1 = (2n−1)T + nD with T = n (round-robin), the window length.
    pub k1: usize,
    /// Iterations until the row spread fell below 1e-6.
    pub iters_to_consensus: usize,
}

impl AugmentedAnalysis {
    /// Empirically measure Lemma 1's quantities for a topology under the
    /// synchronous round-robin schedule (Remark 2: T = n, delays ≤ D).
    pub fn estimate(topo: &Topology, delay: usize) -> AugmentedAnalysis {
        let wm = &topo.weights;
        let n = wm.n;
        let aug = ConsensusAug::new(wm, delay);
        let mut prod = BigMat::identity(aug.size);
        let mut spreads = Vec::new();
        let mut iters_to_consensus = 0;
        let max_iters = 40 * (delay + 2) * n;
        for k in 0..max_iters {
            let i_k = k % n;
            // adversarial-but-bounded delays: cycle 0..=D per neighbor
            let d_of = move |j: usize| (j + k) % (delay + 1);
            let step = aug.step_matrix(i_k, &d_of);
            prod = step.matmul(&prod);
            let s = prod.row_spread();
            spreads.push(s);
            if s < 1e-6 && iters_to_consensus == 0 {
                iters_to_consensus = k + 1;
            }
            if s < 1e-12 {
                break;
            }
        }
        // fit ρ over the geometric tail (last decade of samples)
        let rho_w = fit_rate(&spreads);
        // ψ = limit row of the product (any row once contracted)
        let psi: Vec<f64> = (0..aug.size).map(|j| prod.get(0, j)).collect();
        let roots = wm.common_roots();
        let psi_roots = roots.iter().map(|&r| (r, psi[r])).collect();
        let t = n;
        let k1 = (2 * n - 1) * t + n * delay;
        let eta_bound = (wm.min_weight()).powi(k1 as i32);
        AugmentedAnalysis {
            rho_w,
            psi_roots,
            eta_bound,
            k1,
            iters_to_consensus: if iters_to_consensus == 0 {
                max_iters
            } else {
                iters_to_consensus
            },
        }
    }

    /// Heuristic stable-step upper bound from the measured quantities:
    /// γ̄ ∝ (1 − ρ̂)/ψ_max — topologies with slow mixing or concentrated
    /// root mass need a smaller γ (matches DESIGN.md §9.5 empirics).
    pub fn gamma_hint(&self, curvature: f64) -> f64 {
        let psi_max = self
            .psi_roots
            .iter()
            .map(|&(_, p)| p)
            .fold(0.0f64, f64::max)
            .max(1e-12);
        (1.0 - self.rho_w).max(1e-6) / (curvature * psi_max.max(0.1))
    }
}

/// Tracking-side augmentation (Appendix F): real nodes 0..n, then D+1
/// virtual nodes per edge of E(A) holding in-flight ρ-mass. Index of
/// edge-slot: `n + edge_index·(D+1) + d`.
pub struct TrackingAug<'a> {
    wm: &'a WeightMatrices,
    pub delay: usize,
    /// edges of E(A) as (from j, to i)
    pub edges: Vec<(usize, usize)>,
    pub size: usize,
}

impl<'a> TrackingAug<'a> {
    pub fn new(wm: &'a WeightMatrices, delay: usize) -> TrackingAug<'a> {
        let mut edges = Vec::new();
        for i in 0..wm.n {
            for &j in &wm.a_in[i] {
                edges.push((j, i));
            }
        }
        let size = wm.n + edges.len() * (delay + 1);
        TrackingAug { wm, delay, edges, size }
    }

    fn slot(&self, edge: usize, d: usize) -> usize {
        self.wm.n + edge * (self.delay + 1) + d
    }

    /// Â^k = P^k·S^k for active node `i_k`, where i_k consumes the mass
    /// sitting at depths `d ≥ d_rho(j)` of each in-edge (j, i_k) (paper
    /// eqs. (90)-(96)), then pushes its a_ji-shares to depth 0 of its
    /// out-edges; all other edge chains shift one depth deeper (the last
    /// slot accumulates).
    pub fn step_matrix(&self, i_k: usize,
                       d_rho: &dyn Fn(usize) -> usize) -> BigMat {
        let n = self.wm.n;
        let d_max = self.delay;
        // S^k: sum step — i_k absorbs its awaited in-edge slots
        let mut s = BigMat::zeros(self.size);
        for i in 0..n {
            s.set(i, i, 1.0);
        }
        let mut absorbed = vec![false; self.size];
        for (e, &(j, i)) in self.edges.iter().enumerate() {
            if i == i_k {
                let d0 = d_rho(j).min(d_max);
                for d in d0..=d_max {
                    s.set(i_k, self.slot(e, d), 1.0);
                    absorbed[self.slot(e, d)] = true;
                }
                for d in 0..d0 {
                    s.set(self.slot(e, d), self.slot(e, d), 1.0);
                }
            } else {
                for d in 0..=d_max {
                    s.set(self.slot(e, d), self.slot(e, d), 1.0);
                }
            }
        }
        // P^k: push step — i_k keeps a_ii and seeds depth-0 of out-edges;
        // every edge chain shifts deeper; the deepest slot accumulates.
        let mut p = BigMat::zeros(self.size);
        for i in 0..n {
            p.set(i, i, if i == i_k {
                self.wm.a.get(i_k, i_k) as f64
            } else {
                1.0
            });
        }
        for (e, &(j, i)) in self.edges.iter().enumerate() {
            // shift: slot d ← slot d−1 (within the same edge)
            for d in (1..=d_max).rev() {
                p.set(self.slot(e, d), self.slot(e, d - 1), 1.0);
            }
            p.set(self.slot(e, d_max), self.slot(e, d_max), 1.0);
            // depth 0: refilled only by the active sender
            if j == i_k {
                p.set(self.slot(e, 0), i_k, self.wm.a.get(i, i_k) as f64);
            }
        }
        // absorbed slots were zeroed by S (their mass moved to i_k); the
        // shift in P then propagates zeros — handled implicitly since S
        // already removed their column mass.
        let _ = absorbed;
        p.matmul(&s)
    }
}

/// Fit the geometric decay rate of a positive sequence's tail.
fn fit_rate(xs: &[f64]) -> f64 {
    let tail: Vec<f64> = xs
        .iter()
        .copied()
        .filter(|&x| x > 1e-13 && x < 0.5)
        .collect();
    if tail.len() < 3 {
        return 1.0;
    }
    // geometric mean of successive ratios
    let mut acc = 0.0;
    let mut cnt = 0;
    for w in tail.windows(2) {
        if w[1] > 0.0 && w[0] > 0.0 {
            acc += (w[1] / w[0]).ln();
            cnt += 1;
        }
    }
    if cnt == 0 {
        1.0
    } else {
        (acc / cnt as f64).exp().clamp(0.0, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Topology;

    #[test]
    fn step_matrix_is_row_stochastic() {
        for delay in [0usize, 2, 4] {
            let topo = Topology::binary_tree(7);
            let aug = ConsensusAug::new(&topo.weights, delay);
            for k in 0..10 {
                let m = aug.step_matrix(k % 7, &|j| j % (delay + 1));
                for i in 0..aug.size {
                    let s = m.row_sum(i);
                    assert!((s - 1.0).abs() < 1e-12, "row {i} sums {s}");
                }
            }
        }
    }

    #[test]
    fn products_contract_to_rank_one() {
        // Lemma 1: Ŵ^{k:0} → 1·ψᵀ geometrically
        for topo in [Topology::ring(5), Topology::binary_tree(7),
                     Topology::line(4)] {
            let a = AugmentedAnalysis::estimate(&topo, 2);
            assert!(a.rho_w < 1.0, "{:?}: rho {}", topo.kind, a.rho_w);
            assert!(a.iters_to_consensus > 0);
            // every common root must hold positive ψ mass ≥ the η bound
            for &(r, p) in &a.psi_roots {
                assert!(p > 0.0, "root {r} has zero ψ mass");
                assert!(p >= a.eta_bound,
                        "ψ_{r} = {p} below Lemma-1 bound {}", a.eta_bound);
            }
        }
    }

    #[test]
    fn psi_sums_to_one() {
        let topo = Topology::star(6);
        let wm = &topo.weights;
        let aug = ConsensusAug::new(wm, 1);
        let mut prod = BigMat::identity(aug.size);
        for k in 0..600 {
            let step = aug.step_matrix(k % 6, &|j| j % 2);
            prod = step.matmul(&prod);
        }
        let total: f64 = (0..aug.size).map(|j| prod.get(0, j)).sum();
        assert!((total - 1.0).abs() < 1e-9, "ψ total {total}");
    }

    #[test]
    fn tree_concentrates_psi_at_root() {
        // the empirical basis of DESIGN.md §9.3: spanning trees put far
        // more ψ mass on the root than strongly-connected graphs do on
        // any node
        let tree = AugmentedAnalysis::estimate(&Topology::binary_tree(7), 1);
        let ring = AugmentedAnalysis::estimate(&Topology::ring(7), 1);
        let tree_root = tree.psi_roots[0].1;
        let ring_max = ring
            .psi_roots
            .iter()
            .map(|&(_, p)| p)
            .fold(0.0f64, f64::max);
        assert!(
            tree_root > 2.0 * ring_max,
            "tree root ψ {tree_root} vs ring max ψ {ring_max}"
        );
    }

    #[test]
    fn consensus_contraction_is_topology_dependent() {
        // Measured: the LINE contracts consensus FASTER than the ring
        // (ψ-mass concentrates at the root, which everyone copies within
        // n hops), ρ̂_line ≈ 0.93 < ρ̂_ring ≈ 0.99. So the line's small
        // stable-γ window (DESIGN.md §9.5) is NOT a Ŵ-contraction effect;
        // it comes from the joint x–z loop (tracking mass travels 6 hops
        // in the REVERSE direction of parameters, a long feedback delay).
        // This test pins the measured ordering so the doc claim stays
        // honest.
        let line = AugmentedAnalysis::estimate(&Topology::line(7), 2);
        let ring = AugmentedAnalysis::estimate(&Topology::ring(7), 2);
        assert!(line.rho_w < ring.rho_w,
                "line ρ {} vs ring ρ {}", line.rho_w, ring.rho_w);
        assert!(line.rho_w > 0.0 && ring.rho_w < 1.0);
    }

    #[test]
    fn tracking_step_matrix_is_column_stochastic() {
        // Lemma 2(i): Â^k = P^k·S^k is column-stochastic for any schedule
        for delay in [0usize, 1, 3] {
            for topo in [Topology::ring(5), Topology::binary_tree(7),
                         Topology::star(4)] {
                let aug = TrackingAug::new(&topo.weights, delay);
                for k in 0..12 {
                    let m = aug.step_matrix(k % topo.n(), &|j| j % (delay + 1));
                    for j in 0..aug.size {
                        let s = m.col_sum(j);
                        assert!(
                            (s - 1.0).abs() < 1e-12,
                            "{:?} D={delay} col {j} sums {s}",
                            topo.kind
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn tracking_products_contract_columnwise() {
        // Lemma 2(ii): Â^{k:t} columns converge to a common ξ
        let topo = Topology::ring(5);
        let aug = TrackingAug::new(&topo.weights, 1);
        let mut prod = BigMat::identity(aug.size);
        for k in 0..400 {
            let step = aug.step_matrix(k % 5, &|j| (j + k) % 2);
            prod = step.matmul(&prod);
        }
        let spread = prod.col_spread();
        assert!(spread < 1e-6, "column spread {spread}");
        // ξ mass on the real common roots is positive
        for &r in &topo.weights.common_roots() {
            assert!(prod.get(r, 0) > 1e-6, "ξ_{r} = {}", prod.get(r, 0));
        }
    }

    #[test]
    fn tracking_conserves_mass() {
        // Lemma 3: 1ᵀ ẑ^{k+1} = 1ᵀ Â^k ẑ^k = 1ᵀ ẑ^k (column stochasticity
        // transported through an actual vector evolution with injections)
        let topo = Topology::binary_tree(7);
        let aug = TrackingAug::new(&topo.weights, 2);
        let mut z = vec![0.0f64; aug.size];
        // initial mass: unit gradient at every real node
        for i in 0..7 {
            z[i] = 1.0;
        }
        for k in 0..200 {
            let m = aug.step_matrix(k % 7, &|j| (j + k) % 3);
            let mut nz = vec![0.0f64; aug.size];
            for i in 0..aug.size {
                for j in 0..aug.size {
                    let a = m.get(i, j);
                    if a != 0.0 {
                        nz[i] += a * z[j];
                    }
                }
            }
            z = nz;
            // inject a gradient difference at the active node (ε^k)
            z[k % 7] += 0.01;
            let total: f64 = z.iter().sum();
            let expect = 7.0 + 0.01 * (k + 1) as f64;
            assert!(
                (total - expect).abs() < 1e-9,
                "k={k}: mass {total} vs {expect}"
            );
        }
    }

    #[test]
    fn delay_slows_contraction() {
        let fast = AugmentedAnalysis::estimate(&Topology::ring(5), 0);
        let slow = AugmentedAnalysis::estimate(&Topology::ring(5), 4);
        assert!(
            slow.iters_to_consensus > fast.iters_to_consensus,
            "D=4 {} vs D=0 {}",
            slow.iters_to_consensus,
            fast.iters_to_consensus
        );
    }
}
