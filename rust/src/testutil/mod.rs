//! Property-testing harness (proptest is unavailable offline — DESIGN.md §6).
//!
//! [`forall`] runs a property over many seeded random cases and reports the
//! first failing seed, so a failure is reproducible with
//! `forall_one(<seed>, prop)`. No shrinking — cases are parameterized by a
//! seed, which is already a minimal reproducer.

use crate::oracle::{Eval, GradOracle, NodeOracle, OracleFactory,
                    QuadraticOracle};
use crate::prng::Rng;
use std::sync::{Arc, Mutex};

/// Thread-safe quadratic-oracle factory for the wall-clock runner:
/// clones the family per node, so integration tests and examples can
/// drive [`ThreadedRunner`](crate::runner::ThreadedRunner) on objectives
/// with a closed-form optimum.
pub struct QuadFactory(pub QuadraticOracle);

impl OracleFactory for QuadFactory {
    fn dim(&self) -> usize {
        self.0.dim
    }

    fn make(&self, node: usize) -> Box<dyn NodeOracle> {
        let mut set = self.0.clone().into_set();
        set.nodes.remove(node)
    }
}

/// Coordinator eval closure over a quadratic family that also records
/// the last evaluated mean. Wall-clock engines report no `final_gap`, so
/// tests and examples measure ‖x̄ − x*‖ through the returned handle
/// after the run.
pub fn tracking_quad_eval(
    q: QuadraticOracle,
) -> (impl FnMut(&[f32]) -> Eval + 'static, Arc<Mutex<Vec<f32>>>) {
    let last = Arc::new(Mutex::new(vec![0.0f32; q.dim]));
    let handle = Arc::clone(&last);
    let eval = move |x: &[f32]| {
        last.lock().unwrap().copy_from_slice(x);
        Eval { loss: q.global_loss(x), accuracy: None }
    };
    (eval, handle)
}

/// Run `cases` random instances of `prop`. `prop` receives a fresh RNG per
/// case and returns `Err(description)` to fail. Panics with the seed on
/// failure.
pub fn forall<F>(cases: usize, base_seed: u64, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property failed on case {case} (reproduce with seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Re-run a single failing case by seed.
pub fn forall_one<F>(seed: u64, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property failed (seed {seed:#x}): {msg}");
    }
}

/// Assert two slices are element-wise close; formats the first divergence.
pub fn assert_close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let scale = 1.0f32.max(x.abs()).max(y.abs());
        if (x - y).abs() > tol * scale {
            return Err(format!(
                "element {i}: {x} vs {y} (tol {tol}, scale {scale})"
            ));
        }
    }
    Ok(())
}

/// Random vector in [-1, 1]^p.
pub fn rand_vec(rng: &mut Rng, p: usize) -> Vec<f32> {
    (0..p).map(|_| 2.0 * rng.f32() - 1.0).collect()
}

/// Mass-conservation residual of the robust ρ/ρ̃ scheme — the Lemma 3
/// analogue over the real (non-augmented) system, shared by
/// `tests/invariants.rs` and the fuzzer's conservation oracle (one
/// definition, no drift).
///
/// `nodes[i]` must be node `i` (slice ordered by id) and every node must
/// run the **robust** scheme (`RFastParams { robust: true }`): tracked
/// mass Σ z_i plus every A-edge's generated-but-unconsumed running-sum
/// difference (ρ_ji at the sender minus ρ̃_ij at the receiver) equals the
/// sum of the latest gradient samples, at ANY point of ANY schedule —
/// ρ_ji accumulates at wake time before any send verdict, so in-flight,
/// dropped and backpressured packets all cancel edge-wise. Returns the
/// max absolute per-coordinate residual.
pub fn rho_mass_residual(nodes: &[&crate::algo::RFastNode]) -> f64 {
    let p = nodes[0].z().len();
    let mut lhs = vec![0.0f64; p];
    for nd in nodes {
        if !nd.is_initialized() {
            continue;
        }
        for (a, &z) in lhs.iter_mut().zip(nd.z()) {
            *a += z as f64;
        }
    }
    // edge mass: ρ_out at the sender minus ρ̃ at the receiver
    for (j, sender) in nodes.iter().enumerate() {
        let outs = sender.a_out_ids();
        for (k, &i) in outs.iter().enumerate() {
            let rho_out = &sender.rho_out_sums()[k];
            let recv = &nodes[i];
            let pos = recv
                .a_in_ids()
                .iter()
                .position(|&jj| jj == j)
                .expect("edge sets consistent");
            let rho_tilde = &recv.rho_tilde_sums()[pos];
            for ((a, &ro), &rt) in
                lhs.iter_mut().zip(rho_out.iter()).zip(rho_tilde.iter())
            {
                *a += ro - rt;
            }
        }
    }
    let mut rhs = vec![0.0f64; p];
    for nd in nodes {
        if !nd.is_initialized() {
            continue;
        }
        for (a, &g) in rhs.iter_mut().zip(nd.last_grad()) {
            *a += g as f64;
        }
    }
    lhs.iter()
        .zip(&rhs)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivially() {
        forall(50, 1, |rng| {
            let x = rng.f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure() {
        forall(50, 2, |rng| {
            if rng.f64() < 0.9 {
                Ok(())
            } else {
                Err("too big".into())
            }
        });
    }

    #[test]
    fn assert_close_behaviour() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-3).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-3).is_err());
        // relative tolerance at large scale
        assert!(assert_close(&[1e6], &[1e6 + 1.0], 1e-5).is_ok());
    }
}
