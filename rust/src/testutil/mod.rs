//! Property-testing harness (proptest is unavailable offline — DESIGN.md §6).
//!
//! [`forall`] runs a property over many seeded random cases and reports the
//! first failing seed, so a failure is reproducible with
//! `forall_one(<seed>, prop)`. No shrinking — cases are parameterized by a
//! seed, which is already a minimal reproducer.

use crate::oracle::{Eval, GradOracle, NodeOracle, OracleFactory,
                    QuadraticOracle};
use crate::prng::Rng;
use std::sync::{Arc, Mutex};

/// Thread-safe quadratic-oracle factory for the wall-clock runner:
/// clones the family per node, so integration tests and examples can
/// drive [`ThreadedRunner`](crate::runner::ThreadedRunner) on objectives
/// with a closed-form optimum.
pub struct QuadFactory(pub QuadraticOracle);

impl OracleFactory for QuadFactory {
    fn dim(&self) -> usize {
        self.0.dim
    }

    fn make(&self, node: usize) -> Box<dyn NodeOracle> {
        let mut set = self.0.clone().into_set();
        set.nodes.remove(node)
    }
}

/// Coordinator eval closure over a quadratic family that also records
/// the last evaluated mean. Wall-clock engines report no `final_gap`, so
/// tests and examples measure ‖x̄ − x*‖ through the returned handle
/// after the run.
pub fn tracking_quad_eval(
    q: QuadraticOracle,
) -> (impl FnMut(&[f32]) -> Eval + 'static, Arc<Mutex<Vec<f32>>>) {
    let last = Arc::new(Mutex::new(vec![0.0f32; q.dim]));
    let handle = Arc::clone(&last);
    let eval = move |x: &[f32]| {
        last.lock().unwrap().copy_from_slice(x);
        Eval { loss: q.global_loss(x), accuracy: None }
    };
    (eval, handle)
}

/// Run `cases` random instances of `prop`. `prop` receives a fresh RNG per
/// case and returns `Err(description)` to fail. Panics with the seed on
/// failure.
pub fn forall<F>(cases: usize, base_seed: u64, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property failed on case {case} (reproduce with seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Re-run a single failing case by seed.
pub fn forall_one<F>(seed: u64, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property failed (seed {seed:#x}): {msg}");
    }
}

/// Assert two slices are element-wise close; formats the first divergence.
pub fn assert_close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let scale = 1.0f32.max(x.abs()).max(y.abs());
        if (x - y).abs() > tol * scale {
            return Err(format!(
                "element {i}: {x} vs {y} (tol {tol}, scale {scale})"
            ));
        }
    }
    Ok(())
}

/// Random vector in [-1, 1]^p.
pub fn rand_vec(rng: &mut Rng, p: usize) -> Vec<f32> {
    (0..p).map(|_| 2.0 * rng.f32() - 1.0).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivially() {
        forall(50, 1, |rng| {
            let x = rng.f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure() {
        forall(50, 2, |rng| {
            if rng.f64() < 0.9 {
                Ok(())
            } else {
                Err("too big".into())
            }
        });
    }

    #[test]
    fn assert_close_behaviour() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-3).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-3).is_err());
        // relative tolerance at large scale
        assert!(assert_close(&[1e6], &[1e6 + 1.0], 1e-5).is_ok());
    }
}
