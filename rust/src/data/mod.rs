//! Synthetic datasets + heterogeneity-controlled partitioning.
//!
//! The paper trains on MNIST (two digits, logreg) and ImageNet-500
//! (ResNet-50); neither is downloadable offline, so we generate
//! deterministic synthetic equivalents that exercise identical code paths
//! (DESIGN.md §4): class-template images with Gaussian noise for the
//! classifiers, and a sparse order-1 Markov chain for the LM corpus (so a
//! transformer can actually drive the loss well below log V).
//!
//! Partitioning controls **data heterogeneity** — the ς of Definition 2.
//! `Partition::iid` shuffles globally; `Partition::label_skew(alpha)`
//! interpolates from IID (α=0) to completely class-segregated shards
//! (α=1), the regime where non-gradient-tracking baselines degrade.

use crate::prng::Rng;

/// A dense supervised dataset: row-major features + labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub dim: usize,
    pub features: Vec<f32>,
    /// Class ids (0-based). For binary tasks these are {0,1}.
    pub labels: Vec<u32>,
    pub classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.features[i * self.dim..(i + 1) * self.dim]
    }

    /// Synthetic "two handwritten digits" set (paper §VI-A: 12 000 MNIST
    /// images of 0 and 1). Each class c has a template t_c ∈ [0,1]^dim with
    /// a class-dependent active-pixel pattern; samples are
    /// `clip(t_c + N(0, σ))`, linearly separable in expectation but noisy
    /// enough that SGD takes real work (mirrors logreg-on-MNIST behaviour).
    pub fn synthetic_digits(n_samples: usize, dim: usize, classes: usize,
                            noise: f32, seed: u64) -> Dataset {
        let mut rng = Rng::stream(seed, 0xda7a);
        // class templates: smooth-ish blobs, ~25% active pixels per class
        let mut templates = vec![0.0f32; classes * dim];
        for c in 0..classes {
            for d in 0..dim {
                // deterministic pseudo-structure: stripes of active pixels
                // at class-dependent phase, plus small random texture
                let phase = (d * (c + 2)) % (4 * classes);
                let active = phase < classes;
                templates[c * dim + d] = if active {
                    0.7 + 0.3 * rng.f32()
                } else {
                    0.05 * rng.f32()
                };
            }
        }
        let mut features = Vec::with_capacity(n_samples * dim);
        let mut labels = Vec::with_capacity(n_samples);
        for s in 0..n_samples {
            let c = s % classes; // balanced
            labels.push(c as u32);
            let t = &templates[c * dim..(c + 1) * dim];
            for &tv in t {
                let v = (tv + rng.normal_f32(0.0, noise)).clamp(0.0, 1.0);
                features.push(v);
            }
        }
        Dataset { dim, features, labels, classes }
    }

    /// The paper's §VI-A workload: 12k samples, 784 features, 2 classes.
    pub fn mnist01_like(seed: u64) -> Dataset {
        Dataset::synthetic_digits(12_000, 784, 2, 0.30, seed)
    }

    /// Gaussian class-template task with a *controlled Bayes error*: class
    /// templates are `base + N(0, sep²)` perturbations, samples add
    /// `N(0, noise²)` pixel noise, and `label_flip` of the labels are
    /// resampled uniformly. The optimal pairwise margin is
    /// `sep·√(2·dim)/(2·noise)` standard deviations, so accuracy saturates
    /// strictly below 100% — giving the Fig 5/6 curves room to separate
    /// algorithms, like ImageNet top-1 does in the paper.
    pub fn gaussian_classes(n_samples: usize, dim: usize, classes: usize,
                            sep: f32, noise: f32, label_flip: f64,
                            seed: u64) -> Dataset {
        let mut rng = Rng::stream(seed, 0x9a55);
        let mut base = vec![0.0f32; dim];
        for b in base.iter_mut() {
            *b = 0.3 * rng.f32();
        }
        let mut templates = vec![0.0f32; classes * dim];
        for c in 0..classes {
            for d in 0..dim {
                templates[c * dim + d] = base[d] + rng.normal_f32(0.0, sep);
            }
        }
        let mut features = Vec::with_capacity(n_samples * dim);
        let mut labels = Vec::with_capacity(n_samples);
        for s in 0..n_samples {
            let c = s % classes;
            let label = if label_flip > 0.0 && rng.chance(label_flip) {
                rng.below(classes) as u32
            } else {
                c as u32
            };
            labels.push(label);
            let t = &templates[c * dim..(c + 1) * dim];
            for &tv in t {
                features.push(tv + rng.normal_f32(0.0, noise));
            }
        }
        Dataset { dim, features, labels, classes }
    }

    /// 10-class variant used as the ImageNet proxy for the MLP (§VI-B).
    /// sep/noise put the pairwise Bayes margin at ≈2.6σ and 3% of the labels
    /// are noise ⇒ top-1 saturates in the mid-80s (paper's ResNet: ~79%),
    /// not at 100%.
    pub fn imagenet_like(n_samples: usize, seed: u64) -> Dataset {
        Dataset::gaussian_classes(n_samples, 784, 10, 0.04, 0.30, 0.03, seed)
    }

    /// Split off a held-out evaluation set (last `k` samples).
    pub fn split_eval(mut self, k: usize) -> (Dataset, Dataset) {
        assert!(k < self.len());
        let train_n = self.len() - k;
        let eval = Dataset {
            dim: self.dim,
            features: self.features.split_off(train_n * self.dim),
            labels: self.labels.split_off(train_n),
            classes: self.classes,
        };
        (self, eval)
    }

    /// Labels as f32 (logreg targets).
    pub fn labels_f32(&self) -> Vec<f32> {
        self.labels.iter().map(|&l| l as f32).collect()
    }
}

/// A per-node shard: indices into the parent dataset.
#[derive(Clone, Debug)]
pub struct Partition {
    pub shards: Vec<Vec<usize>>,
}

impl Partition {
    /// IID: global shuffle, equal contiguous shards.
    pub fn iid(data: &Dataset, n_nodes: usize, seed: u64) -> Partition {
        let mut idx: Vec<usize> = (0..data.len()).collect();
        Rng::stream(seed, 0x11d).shuffle(&mut idx);
        Partition { shards: chunk_even(&idx, n_nodes) }
    }

    /// Label-skew heterogeneity: with probability `alpha` a sample is
    /// routed to the shard group "owning" its class; with probability
    /// `1−alpha` it is routed uniformly. α=0 ⇒ IID, α=1 ⇒ every node sees
    /// only its own class subset (maximal ς in Definition 2).
    pub fn label_skew(data: &Dataset, n_nodes: usize, alpha: f64,
                      seed: u64) -> Partition {
        assert!((0.0..=1.0).contains(&alpha));
        let mut rng = Rng::stream(seed, 0x5ca1e);
        let mut shards = vec![Vec::new(); n_nodes];
        for i in 0..data.len() {
            let class = data.labels[i] as usize;
            let node = if rng.chance(alpha) {
                // class-owner group: classes are striped across nodes
                let owners: Vec<usize> = (0..n_nodes)
                    .filter(|&k| k % data.classes.min(n_nodes) ==
                        class % data.classes.min(n_nodes))
                    .collect();
                owners[rng.below(owners.len())]
            } else {
                rng.below(n_nodes)
            };
            shards[node].push(i);
        }
        // guarantee non-empty shards (move from the largest)
        for k in 0..n_nodes {
            if shards[k].is_empty() {
                let donor = (0..n_nodes)
                    .max_by_key(|&d| shards[d].len())
                    .unwrap(); // lint:allow(panic-path): 0..n_nodes is non-empty (asserted by callers via n_nodes > 0)
                // lint:allow(panic-path): largest shard holds >= ceil(len/n) > 0 samples whenever data outnumbers nodes
                let take = shards[donor].pop().unwrap();
                shards[k].push(take);
            }
        }
        Partition { shards }
    }

    pub fn n_nodes(&self) -> usize {
        self.shards.len()
    }

    /// Empirical heterogeneity proxy: max over nodes of the total-variation
    /// distance between the shard's label histogram and the global one.
    pub fn label_skew_measure(&self, data: &Dataset) -> f64 {
        let c = data.classes;
        let mut global = vec![0.0f64; c];
        for &l in &data.labels {
            global[l as usize] += 1.0;
        }
        let total: f64 = global.iter().sum();
        for g in global.iter_mut() {
            *g /= total;
        }
        let mut worst = 0.0f64;
        for shard in &self.shards {
            let mut hist = vec![0.0f64; c];
            for &i in shard {
                hist[data.labels[i] as usize] += 1.0;
            }
            let s: f64 = hist.iter().sum();
            if s == 0.0 {
                continue;
            }
            let tv: f64 = hist
                .iter()
                .zip(&global)
                .map(|(h, g)| (h / s - g).abs())
                .sum::<f64>()
                / 2.0;
            worst = worst.max(tv);
        }
        worst
    }
}

fn chunk_even(idx: &[usize], n: usize) -> Vec<Vec<usize>> {
    let mut shards = vec![Vec::new(); n];
    for (pos, &i) in idx.iter().enumerate() {
        shards[pos % n].push(i);
    }
    shards
}

/// Cyclic minibatch sampler over one node's shard (with reshuffle between
/// epochs) — mirrors a PyTorch DataLoader with shuffle=True.
#[derive(Clone, Debug)]
pub struct Batcher {
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
    pub batch: usize,
}

impl Batcher {
    pub fn new(shard: &[usize], batch: usize, seed: u64) -> Batcher {
        assert!(!shard.is_empty());
        let mut rng = Rng::stream(seed, 0xba7c4);
        let mut order = shard.to_vec();
        rng.shuffle(&mut order);
        Batcher { order, cursor: 0, rng, batch }
    }

    /// Next minibatch of sample indices (wraps + reshuffles at epoch end;
    /// short shards repeat indices to fill the fixed batch the AOT
    /// executable expects).
    pub fn next_batch(&mut self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.batch);
        while out.len() < self.batch {
            if self.cursor >= self.order.len() {
                self.rng.shuffle(&mut self.order);
                self.cursor = 0;
            }
            out.push(self.order[self.cursor]);
            self.cursor += 1;
        }
        out
    }

    /// Fraction of an epoch consumed per batch (for epoch bookkeeping).
    pub fn epoch_per_batch(&self) -> f64 {
        self.batch as f64 / self.order.len() as f64
    }
}

/// Synthetic LM corpus: a sparse order-1 Markov chain over the vocabulary.
/// Each token has `branching` plausible successors (plus smoothing), so the
/// achievable cross-entropy is ≈ log(branching) ≪ log(vocab) — a transformer
/// that learns shows a real loss curve (e2e driver).
#[derive(Clone, Debug)]
pub struct TokenStream {
    pub vocab: usize,
    succ: Vec<u32>, // [vocab * branching]
    branching: usize,
    state: u32,
    rng: Rng,
}

impl TokenStream {
    pub fn new(vocab: usize, branching: usize, seed: u64) -> TokenStream {
        assert!(vocab >= 2 && branching >= 1);
        let mut rng = Rng::stream(seed, 0x70ce5);
        let mut succ = Vec::with_capacity(vocab * branching);
        for _ in 0..vocab {
            for _ in 0..branching {
                succ.push(rng.below(vocab) as u32);
            }
        }
        let state = rng.below(vocab) as u32;
        TokenStream { vocab, succ, branching, state, rng }
    }

    /// Per-node stream: same chain (shared structure), independent walk.
    pub fn for_node(&self, node: usize, seed: u64) -> TokenStream {
        let mut ts = self.clone();
        ts.rng = Rng::stream(seed, 0xbeef ^ node as u64);
        ts.state = ts.rng.below(ts.vocab) as u32;
        ts
    }

    #[inline]
    pub fn next_token(&mut self) -> u32 {
        // 10% smoothing mass escapes to a uniform token
        let t = if self.rng.chance(0.10) {
            self.rng.below(self.vocab) as u32
        } else {
            let row = self.state as usize * self.branching;
            self.succ[row + self.rng.below(self.branching)]
        };
        self.state = t;
        t
    }

    /// Fill a [batch, seq_plus_one] token block (row-major i32) — the exact
    /// input layout of the transformer AOT artifact.
    pub fn next_block(&mut self, batch: usize, seq_plus_one: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * seq_plus_one);
        for _ in 0..batch {
            for _ in 0..seq_plus_one {
                out.push(self.next_token() as i32);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digits_deterministic_and_balanced() {
        let a = Dataset::synthetic_digits(100, 16, 2, 0.2, 5);
        let b = Dataset::synthetic_digits(100, 16, 2, 0.2, 5);
        assert_eq!(a.features, b.features);
        let ones = a.labels.iter().filter(|&&l| l == 1).count();
        assert_eq!(ones, 50);
        assert!(a.features.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn digits_are_separable_by_template_dot() {
        // mean feature vectors of the two classes must differ markedly
        let d = Dataset::synthetic_digits(400, 64, 2, 0.2, 1);
        let mut m0 = vec![0.0f64; 64];
        let mut m1 = vec![0.0f64; 64];
        let (mut c0, mut c1) = (0.0, 0.0);
        for i in 0..d.len() {
            let row = d.row(i);
            if d.labels[i] == 0 {
                c0 += 1.0;
                for (m, &v) in m0.iter_mut().zip(row) {
                    *m += v as f64;
                }
            } else {
                c1 += 1.0;
                for (m, &v) in m1.iter_mut().zip(row) {
                    *m += v as f64;
                }
            }
        }
        let diff: f64 = m0
            .iter()
            .zip(&m1)
            .map(|(a, b)| (a / c0 - b / c1).abs())
            .sum();
        assert!(diff > 1.0, "class means too close: {diff}");
    }

    #[test]
    fn split_eval_sizes() {
        let d = Dataset::synthetic_digits(100, 8, 2, 0.1, 3);
        let (tr, ev) = d.split_eval(20);
        assert_eq!(tr.len(), 80);
        assert_eq!(ev.len(), 20);
        assert_eq!(ev.features.len(), 20 * 8);
    }

    #[test]
    fn iid_partition_covers_all() {
        let d = Dataset::synthetic_digits(101, 4, 2, 0.1, 9);
        let p = Partition::iid(&d, 7, 0);
        let mut all: Vec<usize> = p.shards.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..101).collect::<Vec<_>>());
        // near-even shards
        for s in &p.shards {
            assert!((14..=15).contains(&s.len()));
        }
    }

    #[test]
    fn label_skew_monotone_in_alpha() {
        let d = Dataset::synthetic_digits(2000, 4, 2, 0.1, 11);
        let m0 = Partition::label_skew(&d, 4, 0.0, 2).label_skew_measure(&d);
        let m5 = Partition::label_skew(&d, 4, 0.5, 2).label_skew_measure(&d);
        let m1 = Partition::label_skew(&d, 4, 1.0, 2).label_skew_measure(&d);
        assert!(m0 < 0.1, "iid skew {m0}");
        assert!(m5 > m0, "{m5} vs {m0}");
        // 2 balanced classes ⇒ max possible TV distance is 0.5
        assert!(m1 > 0.45, "full skew {m1}");
    }

    #[test]
    fn label_skew_seed_replay_is_bitwise_identical() {
        // the determinism contract (DESIGN.md §12): the same seed must
        // reproduce the exact shard assignment — partition order feeds
        // every per-node gradient stream downstream
        let d = Dataset::synthetic_digits(500, 4, 2, 0.1, 21);
        let a = Partition::label_skew(&d, 6, 0.7, 42);
        let b = Partition::label_skew(&d, 6, 0.7, 42);
        assert_eq!(a.shards, b.shards);
        // and a different seed must actually move samples
        let c = Partition::label_skew(&d, 6, 0.7, 43);
        assert_ne!(a.shards, c.shards);
    }

    #[test]
    fn label_skew_no_empty_shards() {
        let d = Dataset::synthetic_digits(50, 4, 2, 0.1, 13);
        let p = Partition::label_skew(&d, 8, 1.0, 3);
        assert!(p.shards.iter().all(|s| !s.is_empty()));
    }

    #[test]
    fn batcher_cycles_and_fills() {
        let shard = vec![10, 11, 12];
        let mut b = Batcher::new(&shard, 2, 0);
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..6 {
            for i in b.next_batch() {
                assert!(shard.contains(&i));
                seen.insert(i);
            }
        }
        assert_eq!(seen.len(), 3);
        assert!((b.epoch_per_batch() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn token_stream_in_range_and_structured() {
        let mut ts = TokenStream::new(64, 4, 7);
        let block = ts.next_block(4, 17);
        assert_eq!(block.len(), 68);
        assert!(block.iter().all(|&t| (0..64).contains(&t)));
        // structure: successor entropy must be far below log2(64)=6 bits.
        // count distinct successors of the most common token
        let mut ts2 = TokenStream::new(64, 4, 7);
        // BTree keeps any iteration order reaching assertions deterministic
        let mut followers: std::collections::BTreeMap<u32, std::collections::BTreeSet<u32>> =
            Default::default();
        let mut prev = ts2.next_token();
        for _ in 0..20_000 {
            let t = ts2.next_token();
            followers.entry(prev).or_default().insert(t);
            prev = t;
        }
        // with 10% smoothing the follower sets grow, but the *typical* set
        // must be much smaller than the vocab
        let med = {
            let mut sizes: Vec<usize> =
                followers.values().map(|s| s.len()).collect();
            sizes.sort_unstable();
            sizes[sizes.len() / 2]
        };
        assert!(med < 40, "median follower set {med} ≥ 40: no structure");
    }

    #[test]
    fn per_node_streams_differ() {
        let base = TokenStream::new(32, 3, 1);
        let mut a = base.for_node(0, 99);
        let mut b = base.for_node(1, 99);
        let xa: Vec<u32> = (0..50).map(|_| a.next_token()).collect();
        let xb: Vec<u32> = (0..50).map(|_| b.next_token()).collect();
        assert_ne!(xa, xb);
    }
}
