//! `repro lint` — the determinism & hot-path static analyzer (DESIGN.md
//! §12).
//!
//! Every correctness claim in this repo — geometric convergence under
//! loss, byte-identical fuzz repros (§11), golden-JSON fabric tests —
//! rests on bitwise-deterministic simulation. This module makes the
//! conventions that determinism depends on *static, CI-gated invariants*
//! instead of reviewer folklore: no `HashMap` iteration order, no wall
//! clock, no `partial_cmp` float ordering inside sim-scope; no
//! per-event allocation inside the `algo/` hot path; no unwaived panics
//! in library code.
//!
//! The second rule family (DESIGN.md §14) guards the threaded engine's
//! shared state: a cross-file lock-acquisition-order graph flags
//! potential deadlocks (`lock-order`), guards held across blocking calls
//! (`lock-across-blocking`), `Ordering::Relaxed` on report counters
//! (`relaxed-counter`), and type-system escape hatches (`unsync-shared`).
//! The graph machinery lives in [`conc`]; the per-line matching rides the
//! same [`scan`] pass as the determinism rules.
//!
//! Dependency-free by construction (vendored-offline builds): the scanner
//! in [`scan`] is a hand-rolled tokenizing line scanner, JSON I/O rides
//! the in-tree [`crate::jsonio`].
//!
//! Findings diff against a committed, schema-tagged `LINT_BASELINE.json`
//! (same pattern as `BENCH_*.json`): pre-existing findings are
//! grandfathered per-rule-per-file, counts may only ratchet *down*, and
//! any new finding — or any malformed waiver pragma, which no baseline
//! can absorb — fails the gate. `repro lint --fix-baseline` rewrites the
//! baseline after a genuine improvement.

pub mod conc;
pub mod scan;

use crate::jsonio::{self, Json};
use std::collections::{BTreeMap, BTreeSet};
use std::fs;
use std::path::{Path, PathBuf};

/// Schema tag of `LINT_BASELINE.json`. v2 added the concurrency rule
/// family (DESIGN.md §14); v1 files still parse — `--fix-baseline`
/// rewrites them with the v2 tag.
pub const BASELINE_SCHEMA: &str = "rfast-lint-baseline/v2";
/// The predecessor tag, accepted on read for migration.
pub const BASELINE_SCHEMA_V1: &str = "rfast-lint-baseline/v1";
/// Schema tag of the findings artifact (`repro lint --out FILE`).
pub const FINDINGS_SCHEMA: &str = "rfast-lint-findings/v2";
/// Pseudo-rule for malformed waiver pragmas. Not waivable, never
/// baseline-absorbed: a broken waiver must be fixed, not grandfathered.
pub const BAD_WAIVER: &str = "bad-waiver";
/// Pseudo-rule for a valid waiver whose rule no longer fires on its
/// line. Like [`BAD_WAIVER`], never baseline-absorbed: a suppression
/// must not outlive its cause.
pub const STALE_WAIVER: &str = "stale-waiver";
/// The lock-acquisition-order rule name (findings are synthesized from
/// the cross-file graph in [`conc::cycle_findings`], not per line).
pub const LOCK_ORDER: &str = "lock-order";

/// One lint rule: the name waiver pragmas refer to, plus where and what
/// it guards (the full table lives in DESIGN.md §12).
pub struct Rule {
    pub name: &'static str,
    pub scope: &'static str,
    pub summary: &'static str,
}

/// The rule catalog. `bad-waiver` and `stale-waiver` are deliberately
/// absent — they cannot be waived.
pub const RULES: [Rule; 10] = [
    Rule {
        name: "det-collections",
        scope: "sim/ algo/ fuzz/ scenario/ graph/",
        summary: "HashMap/HashSet iteration order is nondeterministic; \
                  use BTreeMap/BTreeSet",
    },
    Rule {
        name: "det-wallclock",
        scope: "sim/ algo/ fuzz/ scenario/ graph/",
        summary: "Instant::now/SystemTime/thread::sleep leak wall clock \
                  into virtual time; use the Clock abstraction (runner/, \
                  faults/ are exempt)",
    },
    Rule {
        name: "det-rand",
        scope: "sim/ algo/ fuzz/ scenario/ graph/",
        summary: "ambient randomness breaks seed replay; use prng::Rng",
    },
    Rule {
        name: "float-ord",
        scope: "sim/ algo/ fuzz/ scenario/ graph/",
        summary: "partial_cmp (and float sort_by_key) is NaN-unsound and \
                  order-fragile; use total_cmp",
    },
    Rule {
        name: "hot-alloc",
        scope: "algo/* wake/receive/on_send_failed",
        summary: "to_vec/vec!/clone in per-event code violates the \
                  one-alloc-per-fan-out invariant (DESIGN.md, PR 3)",
    },
    Rule {
        name: "panic-path",
        scope: "rust/src/** except testutil/",
        summary: "unwrap/expect/panic in library code needs a waiver \
                  stating why it cannot fire",
    },
    Rule {
        name: "lock-order",
        scope: "rust/src/** except testutil/",
        summary: "this acquisition order is inverted elsewhere in the \
                  tree — a cycle in the lock-order graph is a potential \
                  deadlock; pick one global order",
    },
    Rule {
        name: "lock-across-blocking",
        scope: "rust/src/** except testutil/",
        summary: "a Mutex/RwLock guard held across send/recv/sleep/join \
                  stalls every contender for the blocking duration; drop \
                  the guard first",
    },
    Rule {
        name: "relaxed-counter",
        scope: "rust/src/** except testutil/",
        summary: "Ordering::Relaxed on an atomic that feeds report \
                  scalars; use AcqRel RMWs and Acquire loads (or a waiver \
                  stating why Relaxed is sound)",
    },
    Rule {
        name: "unsync-shared",
        scope: "rust/src/** except testutil/",
        summary: "static mut / unsafe impl Send|Sync / raw pointers \
                  bypass the type system's race freedom; justify with a \
                  waiver or use safe sharing",
    },
];

/// One finding: a rule hit at a file:line, with the matched token and
/// enclosing fn (when known) in `detail`.
#[derive(Clone, Debug, PartialEq)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub detail: String,
}

/// What to scan. `paths` are root-relative files or directories;
/// `exclude_dirs` prunes directory *names* anywhere under them (the
/// default keeps the deliberately-bad fixture corpus out of self-scans).
pub struct LintConfig {
    pub root: PathBuf,
    pub paths: Vec<String>,
    pub exclude_dirs: Vec<String>,
}

impl LintConfig {
    /// Default scan set: the whole library plus benches, integration
    /// tests, and examples (the CI gate scans exactly this).
    pub fn new(root: PathBuf) -> LintConfig {
        LintConfig {
            root,
            paths: ["rust/src", "rust/benches", "rust/tests", "examples"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
            exclude_dirs: vec!["lint_fixtures".to_string()],
        }
    }
}

/// Aggregate result of one lint run.
#[derive(Debug, Default)]
pub struct LintReport {
    pub findings: Vec<Finding>,
    pub waiver_errors: Vec<Finding>,
    pub files_scanned: usize,
    pub waivers_used: usize,
}

/// Scan every `.rs` file selected by `cfg`, in sorted path order. Two
/// phases (DESIGN.md §14): phase A collects declared `Mutex`/`RwLock`
/// names across the whole corpus (so a lock declared in `runner/` is
/// recognized when acquired from another module); phase B scans each
/// file with that name set, then the per-file lock edges aggregate into
/// the global acquisition-order graph and its cycles become `lock-order`
/// findings.
pub fn run(cfg: &LintConfig) -> Result<LintReport, String> {
    let mut files = Vec::new();
    for rel in walk(cfg)? {
        let text = fs::read_to_string(cfg.root.join(&rel))
            .map_err(|e| format!("read {rel}: {e}"))?;
        files.push((rel, text));
    }
    let mut locks: BTreeSet<String> = BTreeSet::new();
    for (_, text) in &files {
        conc::collect_lock_decls(text, &mut locks);
    }
    let mut report = LintReport::default();
    let mut edges: Vec<conc::LockEdge> = Vec::new();
    for (rel, text) in &files {
        let scanned = scan::scan_source_with(rel, text, &locks);
        report.findings.extend(scanned.findings);
        report.waiver_errors.extend(scanned.waiver_errors);
        report.waivers_used += scanned.waivers_used;
        edges.extend(scanned.lock_edges);
        report.files_scanned += 1;
    }
    report.findings.extend(conc::cycle_findings(&edges));
    // stable sort: cross-file findings interleave back into file order,
    // same-line findings keep rule-table emission order
    report.findings.sort_by(|a, b| {
        (a.file.as_str(), a.line).cmp(&(b.file.as_str(), b.line))
    });
    Ok(report)
}

/// Deterministic file discovery: sorted root-relative `/`-separated
/// paths. Missing entries in `cfg.paths` are tolerated (a fixture root
/// need not carry every default path).
fn walk(cfg: &LintConfig) -> Result<Vec<String>, String> {
    let mut out = Vec::new();
    for p in &cfg.paths {
        let full = cfg.root.join(p);
        if full.is_file() {
            out.push(p.replace('\\', "/"));
        } else if full.is_dir() {
            walk_dir(&cfg.root, &full, &cfg.exclude_dirs, &mut out)?;
        }
    }
    out.sort();
    out.dedup();
    Ok(out)
}

fn walk_dir(
    root: &Path,
    dir: &Path,
    exclude: &[String],
    out: &mut Vec<String>,
) -> Result<(), String> {
    let rd = fs::read_dir(dir)
        .map_err(|e| format!("read dir {}: {e}", dir.display()))?;
    let mut entries: Vec<PathBuf> =
        rd.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            let name = path
                .file_name()
                .and_then(|s| s.to_str())
                .unwrap_or("");
            if exclude.iter().any(|x| x == name) {
                continue;
            }
            walk_dir(root, &path, exclude, out)?;
        } else if path.extension().and_then(|s| s.to_str()) == Some("rs") {
            let rel = path
                .strip_prefix(root)
                .map_err(|_| format!("{} outside root", path.display()))?;
            out.push(rel.to_string_lossy().replace('\\', "/"));
        }
    }
    Ok(())
}

// ---- the ratcheted baseline -------------------------------------------

/// Grandfathered findings: per-rule, per-file counts. Waiver errors are
/// intentionally unrepresentable here.
#[derive(Debug, Default, PartialEq)]
pub struct Baseline {
    pub counts: BTreeMap<String, BTreeMap<String, usize>>,
}

/// One per-rule-per-file count change between baseline and current scan.
#[derive(Debug, Clone, PartialEq)]
pub struct Delta {
    pub rule: String,
    pub file: String,
    pub base: usize,
    pub cur: usize,
}

/// Result of diffing a scan against the baseline. The gate passes iff
/// `regressions` is empty (improvements pass, with a nudge to shrink the
/// baseline via `--fix-baseline`).
#[derive(Debug, Default)]
pub struct Ratchet {
    /// Cells where the current count exceeds the grandfathered count
    /// (including brand-new rule/file cells).
    pub regressions: Vec<Delta>,
    /// Cells where the current count dropped below the baseline.
    pub improvements: Vec<Delta>,
}

impl Ratchet {
    pub fn is_clean(&self) -> bool {
        self.regressions.is_empty()
    }
}

impl Baseline {
    /// Collapse a report's findings into per-rule-per-file counts.
    pub fn from_report(report: &LintReport) -> Baseline {
        let mut counts: BTreeMap<String, BTreeMap<String, usize>> =
            BTreeMap::new();
        for f in &report.findings {
            *counts
                .entry(f.rule.to_string())
                .or_default()
                .entry(f.file.clone())
                .or_default() += 1;
        }
        Baseline { counts }
    }

    pub fn load(path: &Path) -> Result<Baseline, String> {
        let text = fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let j = jsonio::parse(&text)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        Baseline::from_json(&j)
            .map_err(|e| format!("{}: {e}", path.display()))
    }

    /// Parse a baseline. Accepts the current [`BASELINE_SCHEMA`] and the
    /// v1 predecessor (identical shape, pre-concurrency rule set) —
    /// `--fix-baseline` migrates a v1 file to v2 on its next rewrite.
    pub fn from_json(j: &Json) -> Result<Baseline, String> {
        let schema = j
            .get("schema")
            .and_then(|s| s.as_str())
            .ok_or("missing schema tag")?;
        if schema != BASELINE_SCHEMA && schema != BASELINE_SCHEMA_V1 {
            return Err(format!(
                "schema {schema:?}, expected {BASELINE_SCHEMA:?} \
                 (or the readable predecessor {BASELINE_SCHEMA_V1:?})"
            ));
        }
        let raw = j
            .get("counts")
            .and_then(|c| c.as_obj())
            .ok_or("missing counts object")?;
        let mut counts: BTreeMap<String, BTreeMap<String, usize>> =
            BTreeMap::new();
        for (rule, files) in raw {
            if !RULES.iter().any(|r| r.name == rule) {
                return Err(format!("unknown rule in baseline: {rule:?}"));
            }
            let files = files
                .as_obj()
                .ok_or_else(|| format!("counts[{rule:?}] not an object"))?;
            let mut per_file = BTreeMap::new();
            for (file, n) in files {
                let n = n.as_usize().ok_or_else(|| {
                    format!("counts[{rule:?}][{file:?}] not a number")
                })?;
                per_file.insert(file.clone(), n);
            }
            counts.insert(rule.clone(), per_file);
        }
        Ok(Baseline { counts })
    }

    pub fn to_json(&self) -> Json {
        let counts = self
            .counts
            .iter()
            .map(|(rule, files)| {
                let files = files
                    .iter()
                    .map(|(f, n)| (f.clone(), Json::from(*n)))
                    .collect();
                (rule.clone(), Json::Obj(files))
            })
            .collect();
        Json::obj(vec![
            ("schema", Json::from(BASELINE_SCHEMA)),
            ("counts", Json::Obj(counts)),
        ])
    }

    /// Diff `current` against this (grandfathered) baseline.
    pub fn diff(&self, current: &Baseline) -> Ratchet {
        let mut cells: Vec<(&str, &str)> = Vec::new();
        for (rule, files) in self.counts.iter().chain(current.counts.iter())
        {
            for file in files.keys() {
                cells.push((rule, file));
            }
        }
        cells.sort();
        cells.dedup();
        let mut out = Ratchet::default();
        let count = |b: &Baseline, rule: &str, file: &str| {
            b.counts
                .get(rule)
                .and_then(|m| m.get(file))
                .copied()
                .unwrap_or(0)
        };
        for (rule, file) in cells {
            let base = count(self, rule, file);
            let cur = count(current, rule, file);
            let delta = Delta {
                rule: rule.to_string(),
                file: file.to_string(),
                base,
                cur,
            };
            if cur > base {
                out.regressions.push(delta);
            } else if cur < base {
                out.improvements.push(delta);
            }
        }
        out
    }
}

// ---- JSON artifacts ----------------------------------------------------

fn finding_json(f: &Finding) -> Json {
    Json::obj(vec![
        ("rule", Json::from(f.rule)),
        ("file", Json::from(f.file.clone())),
        ("line", Json::from(f.line)),
        ("detail", Json::from(f.detail.clone())),
    ])
}

fn delta_json(d: &Delta) -> Json {
    Json::obj(vec![
        ("rule", Json::from(d.rule.clone())),
        ("file", Json::from(d.file.clone())),
        ("baseline", Json::from(d.base)),
        ("current", Json::from(d.cur)),
    ])
}

/// The findings artifact CI uploads on failure (`--out FILE`).
pub fn findings_json(report: &LintReport, ratchet: Option<&Ratchet>) -> Json {
    let mut pairs = vec![
        ("schema", Json::from(FINDINGS_SCHEMA)),
        ("files_scanned", Json::from(report.files_scanned)),
        ("waivers_used", Json::from(report.waivers_used)),
        (
            "findings",
            Json::Arr(report.findings.iter().map(finding_json).collect()),
        ),
        (
            "waiver_errors",
            Json::Arr(
                report.waiver_errors.iter().map(finding_json).collect(),
            ),
        ),
    ];
    if let Some(r) = ratchet {
        pairs.push((
            "ratchet",
            Json::obj(vec![
                (
                    "regressions",
                    Json::Arr(r.regressions.iter().map(delta_json).collect()),
                ),
                (
                    "improvements",
                    Json::Arr(
                        r.improvements.iter().map(delta_json).collect(),
                    ),
                ),
            ]),
        ));
    }
    Json::obj(pairs)
}

/// GitHub Actions workflow-command annotation for one finding: printed
/// to stdout during a CI run, it surfaces as an inline error on the PR's
/// file view (`repro lint --format github`).
pub fn github_annotation(f: &Finding) -> String {
    format!(
        "::error file={},line={},title=repro-lint[{}]::{}",
        f.file, f.line, f.rule, f.detail
    )
}

/// GitHub annotation for a ratchet regression (no line — the cell is a
/// per-file count, so the annotation anchors to line 1).
pub fn github_delta_annotation(d: &Delta) -> String {
    format!(
        "::error file={},line=1,title=repro-lint-ratchet[{}]::count went \
         {} -> {} (fix or waive the new finding; baselines only shrink)",
        d.file, d.rule, d.base, d.cur
    )
}

/// Two-space-indent pretty printer (sorted keys come free from
/// `BTreeMap`). `LINT_BASELINE.json` is a committed, human-reviewed debt
/// register; one-line JSON would bury its diffs.
///
/// Schema migration note: `Baseline::to_json` always stamps the current
/// [`BASELINE_SCHEMA`] (v2), so pretty-printing a baseline parsed from a
/// v1 file *is* the v1 → v2 migration — the counts object is unchanged,
/// only the tag moves.
pub fn to_pretty(j: &Json) -> String {
    let mut out = String::new();
    pretty(j, 0, &mut out);
    out.push('\n');
    out
}

fn pretty(j: &Json, indent: usize, out: &mut String) {
    match j {
        Json::Obj(m) if !m.is_empty() => {
            out.push_str("{\n");
            for (i, (k, v)) in m.iter().enumerate() {
                if i > 0 {
                    out.push_str(",\n");
                }
                out.push_str(&" ".repeat(indent + 2));
                out.push_str(&Json::from(k.as_str()).to_string());
                out.push_str(": ");
                pretty(v, indent + 2, out);
            }
            out.push('\n');
            out.push_str(&" ".repeat(indent));
            out.push('}');
        }
        Json::Arr(v) if !v.is_empty() => {
            out.push_str("[\n");
            for (i, x) in v.iter().enumerate() {
                if i > 0 {
                    out.push_str(",\n");
                }
                out.push_str(&" ".repeat(indent + 2));
                pretty(x, indent + 2, out);
            }
            out.push('\n');
            out.push_str(&" ".repeat(indent));
            out.push(']');
        }
        other => out.push_str(&other.to_string()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn baseline(cells: &[(&str, &str, usize)]) -> Baseline {
        let mut b = Baseline::default();
        for &(rule, file, n) in cells {
            b.counts
                .entry(rule.to_string())
                .or_default()
                .insert(file.to_string(), n);
        }
        b
    }

    #[test]
    fn ratchet_rejects_increase_and_new_cells() {
        let base = baseline(&[("hot-alloc", "a.rs", 2)]);
        let cur = baseline(&[("hot-alloc", "a.rs", 3)]);
        let r = base.diff(&cur);
        assert!(!r.is_clean());
        assert_eq!(r.regressions.len(), 1);
        assert_eq!((r.regressions[0].base, r.regressions[0].cur), (2, 3));

        // a brand-new rule/file cell is a regression from 0
        let cur = baseline(&[("hot-alloc", "a.rs", 2), ("float-ord", "b.rs", 1)]);
        let r = base.diff(&cur);
        assert_eq!(r.regressions.len(), 1);
        assert_eq!(r.regressions[0].rule, "float-ord");
        assert_eq!(r.regressions[0].base, 0);
    }

    #[test]
    fn ratchet_accepts_decrease_as_improvement() {
        let base = baseline(&[("hot-alloc", "a.rs", 2), ("panic-path", "b.rs", 1)]);
        let cur = baseline(&[("hot-alloc", "a.rs", 1)]);
        let r = base.diff(&cur);
        assert!(r.is_clean());
        assert_eq!(r.improvements.len(), 2);
        // file vanishing from the scan counts as dropping to zero
        assert!(r
            .improvements
            .iter()
            .any(|d| d.rule == "panic-path" && d.cur == 0));
    }

    #[test]
    fn baseline_json_round_trips() {
        let b = baseline(&[("hot-alloc", "rust/src/algo/dpsgd.rs", 2)]);
        let j = b.to_json();
        assert_eq!(
            j.get("schema").and_then(|s| s.as_str()),
            Some(BASELINE_SCHEMA)
        );
        let text = to_pretty(&j);
        let parsed = crate::jsonio::parse(&text).map_err(|e| e.to_string());
        let b2 = parsed.and_then(|j| Baseline::from_json(&j));
        assert_eq!(b2.as_ref().ok(), Some(&b));
    }

    #[test]
    fn baseline_rejects_wrong_schema_and_unknown_rule() {
        let j = crate::jsonio::parse(
            "{\"schema\":\"rfast-lint-baseline/v0\",\"counts\":{}}",
        );
        assert!(j.is_ok_and(|j| Baseline::from_json(&j).is_err()));
        let j = crate::jsonio::parse(&format!(
            "{{\"schema\":\"{BASELINE_SCHEMA}\",\
             \"counts\":{{\"no-such-rule\":{{\"a.rs\":1}}}}}}"
        ));
        assert!(j.is_ok_and(|j| Baseline::from_json(&j).is_err()));
    }

    #[test]
    fn pretty_printer_shape() {
        let b = baseline(&[("hot-alloc", "a.rs", 2)]);
        let text = to_pretty(&b.to_json());
        let expect = "{\n  \"counts\": {\n    \"hot-alloc\": {\n      \
                      \"a.rs\": 2\n    }\n  },\n  \"schema\": \
                      \"rfast-lint-baseline/v2\"\n}\n";
        assert_eq!(text, expect);
    }

    #[test]
    fn v1_baseline_parses_and_rewrites_as_v2() {
        let v1 = format!(
            "{{\"schema\":\"{BASELINE_SCHEMA_V1}\",\
             \"counts\":{{\"hot-alloc\":{{\"a.rs\":2}}}}}}"
        );
        let j = crate::jsonio::parse(&v1).expect("v1 parses");
        let b = Baseline::from_json(&j).expect("v1 accepted");
        assert_eq!(b, baseline(&[("hot-alloc", "a.rs", 2)]));
        // the rewrite path stamps v2 with the counts untouched
        let out = b.to_json();
        assert_eq!(
            out.get("schema").and_then(|s| s.as_str()),
            Some(BASELINE_SCHEMA)
        );
        assert_eq!(
            Baseline::from_json(&out).expect("v2 round-trip"),
            b
        );
        // v1 cells may name the new concurrency rules once migrated
        let v2 = format!(
            "{{\"schema\":\"{BASELINE_SCHEMA}\",\
             \"counts\":{{\"relaxed-counter\":{{\"b.rs\":1}}}}}}"
        );
        let j = crate::jsonio::parse(&v2).expect("v2 parses");
        assert!(Baseline::from_json(&j).is_ok());
    }

    #[test]
    fn stale_and_bad_waiver_cells_are_unrepresentable() {
        for pseudo in [BAD_WAIVER, STALE_WAIVER] {
            let text = format!(
                "{{\"schema\":\"{BASELINE_SCHEMA}\",\
                 \"counts\":{{\"{pseudo}\":{{\"a.rs\":1}}}}}}"
            );
            let j = crate::jsonio::parse(&text).expect("parses");
            assert!(
                Baseline::from_json(&j).is_err(),
                "{pseudo} must not be baselineable"
            );
        }
    }

    #[test]
    fn github_annotations_format() {
        let f = Finding {
            rule: "lock-order",
            file: "rust/src/runner/mod.rs".to_string(),
            line: 42,
            detail: "acquires b while holding a".to_string(),
        };
        assert_eq!(
            github_annotation(&f),
            "::error file=rust/src/runner/mod.rs,line=42,\
             title=repro-lint[lock-order]::acquires b while holding a"
        );
        let d = Delta {
            rule: "hot-alloc".to_string(),
            file: "a.rs".to_string(),
            base: 2,
            cur: 3,
        };
        let s = github_delta_annotation(&d);
        assert!(s.starts_with("::error file=a.rs,line=1,"));
        assert!(s.contains("2 -> 3"));
    }
}
