//! The concurrency half of `repro lint` (DESIGN.md §14): lock-declaration
//! collection and the cross-file lock-acquisition-order graph.
//!
//! The per-line concurrency rules (`lock-across-blocking`,
//! `relaxed-counter`, `unsync-shared`) live in [`super::scan`] next to the
//! determinism rules — they need the scanner's stripped view, waiver
//! state, and guard stack. This module owns what spans files:
//!
//! * **Phase A** — [`collect_lock_decls`] walks every stripped line of
//!   every file and records the *names* of declared `Mutex`/`RwLock`
//!   values (struct fields, `let` bindings of `Mutex::new`, statics).
//!   The scanner then treats `.lock()`/`.read()`/`.write()` as a lock
//!   acquisition only when the receiver is a declared name — so
//!   `file.read()` or `stdout().lock()` never enter the analysis.
//! * **Phase B aggregation** — each file scan emits [`LockEdge`]s
//!   (lock B acquired while a guard of lock A is held). [`cycle_findings`]
//!   builds the global acquisition-order digraph and flags every edge
//!   that sits on a cycle: two functions acquiring the same pair of locks
//!   in opposite orders is the classic deadlock shape, and the cycle test
//!   generalizes it to any length (a self-edge — re-acquiring a lock
//!   already held — is a cycle of length one).
//!
//! Soundness caveats of the lexical approach are catalogued in DESIGN.md
//! §14: locks are identified by *name*, not by instance (two slots of one
//! `Vec<Mutex<_>>` alias), guard lifetimes are approximated by brace
//! depth and explicit `drop(..)`, and statements split across lines are
//! matched per line. The rules err toward silence on constructs they
//! cannot see; the sanitizer CI jobs (miri, ThreadSanitizer) backstop
//! them dynamically.

use super::scan::{has_token, is_ident, strip_lines};
use super::Finding;
use std::collections::{BTreeMap, BTreeSet};

/// One observed acquisition ordering: a guard of `first` was held when
/// `second` was acquired at `file:line`. Waived acquisitions
/// (`lint:allow(lock-order)`) never become edges, so one waiver removes
/// the edge — and with it any cycle that needed it.
#[derive(Clone, Debug, PartialEq)]
pub struct LockEdge {
    pub file: String,
    pub line: usize,
    pub first: String,
    pub second: String,
}

/// Characters that may appear between a field name's `:` and its
/// `Mutex<`/`RwLock<` token inside a type (`x: Arc<Mutex<T>>`,
/// `v: Vec<Mutex<(f64, u64)>>`). Anything else — `=`, `(`, `|`, `.` —
/// means the token is an expression, not a declared type.
fn is_typeish(b: u8) -> bool {
    b.is_ascii_alphanumeric()
        || matches!(b, b'_' | b'<' | b'>' | b' ' | b'&' | b'\'' | b',')
}

/// Collect declared lock names from one file's raw text into `out`.
/// Recognized declaration shapes (on the stripped view, so tokens inside
/// strings or comments are inert):
///
/// * `NAME: ..Mutex<..` / `NAME: ..RwLock<..` — struct fields, statics,
///   consts, typed lets, fn params;
/// * `let [mut] NAME = ..Mutex::new(..` / `..RwLock::new(..`.
///
/// Constructor lines inside struct literals (`field: Mutex::new(..)`)
/// deliberately match neither shape — the field's own declaration already
/// contributed the name.
pub fn collect_lock_decls(text: &str, out: &mut BTreeSet<String>) {
    for code in strip_lines(text) {
        let b = code.as_bytes();
        for tok in ["Mutex<", "RwLock<"] {
            let mut start = 0;
            while let Some(off) = code[start..].find(tok) {
                let i = start + off;
                start = i + tok.len();
                if i > 0 && is_ident(b[i - 1]) {
                    continue; // MyMutex< etc.
                }
                // walk back over the type to the declaring `:` (skipping
                // `::` path separators: `x: std::sync::Mutex<T>`)
                let mut k = i;
                loop {
                    if k == 0 {
                        break;
                    }
                    let c = b[k - 1];
                    if c == b':' {
                        if k >= 2 && b[k - 2] == b':' {
                            k -= 2;
                            continue;
                        }
                        break; // the declaration colon
                    }
                    if is_typeish(c) {
                        k -= 1;
                    } else {
                        break;
                    }
                }
                if k == 0 || b[k - 1] != b':' {
                    continue;
                }
                let e = k - 1;
                let mut s = e;
                while s > 0 && is_ident(b[s - 1]) {
                    s -= 1;
                }
                if s < e {
                    out.insert(code[s..e].to_string());
                }
            }
        }
        if has_token(&code, "Mutex::new") || has_token(&code, "RwLock::new") {
            if let Some(name) = let_binding_name(&code) {
                out.insert(name);
            }
        }
    }
}

/// Name bound by the first `let` on a stripped line, unwrapping a leading
/// `mut` (tuple/struct patterns yield `None`).
pub(crate) fn let_binding_name(code: &str) -> Option<String> {
    let b = code.as_bytes();
    let mut start = 0;
    let i = loop {
        let off = code[start..].find("let ")?;
        let i = start + off;
        if i > 0 && is_ident(b[i - 1]) {
            start = i + 4;
            continue;
        }
        break i;
    };
    let mut j = i + 4;
    while j < b.len() && b[j] == b' ' {
        j += 1;
    }
    if code[j..].starts_with("mut ") {
        j += 4;
        while j < b.len() && b[j] == b' ' {
            j += 1;
        }
    }
    for wrap in ["Ok(", "Some("] {
        if code[j..].starts_with(wrap) {
            j += wrap.len();
            break;
        }
    }
    let s = j;
    let mut k = j;
    while k < b.len() && is_ident(b[k]) {
        k += 1;
    }
    if k > s {
        Some(code[s..k].to_string())
    } else {
        None
    }
}

/// Flag every edge that lies on a cycle of the acquisition-order digraph:
/// edge `first -> second` is reported when `second` can reach `first`
/// (so the full cycle exists), which reports *each* offending acquisition
/// site of a two-lock inversion rather than an arbitrary one.
pub fn cycle_findings(edges: &[LockEdge]) -> Vec<Finding> {
    let mut adj: BTreeMap<&str, BTreeSet<&str>> = BTreeMap::new();
    for e in edges {
        adj.entry(&e.first).or_default().insert(&e.second);
    }
    let mut out: Vec<Finding> = Vec::new();
    for e in edges {
        if reaches(&adj, &e.second, &e.first) {
            out.push(Finding {
                rule: super::LOCK_ORDER,
                file: e.file.clone(),
                line: e.line,
                detail: format!(
                    "acquires {} while holding {}, but an opposite path \
                     {} -> {} exists elsewhere (potential deadlock)",
                    e.second, e.first, e.second, e.first
                ),
            });
        }
    }
    out.dedup();
    out
}

/// Is `target` reachable from `from` along >= 1 edge?
fn reaches(
    adj: &BTreeMap<&str, BTreeSet<&str>>,
    from: &str,
    target: &str,
) -> bool {
    let mut seen: BTreeSet<&str> = BTreeSet::new();
    let mut stack = vec![from];
    while let Some(n) = stack.pop() {
        for &m in adj.get(n).into_iter().flatten() {
            if m == target {
                return true;
            }
            if seen.insert(m) {
                stack.push(m);
            }
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn decls(src: &str) -> Vec<String> {
        let mut out = BTreeSet::new();
        collect_lock_decls(src, &mut out);
        out.into_iter().collect()
    }

    #[test]
    fn field_and_static_and_let_declarations_are_collected() {
        let src = "struct S {\n    slots: Vec<Mutex<(f64, u64)>>,\n    \
                   pub table: std::sync::RwLock<u8>,\n}\n\
                   static GAUGE: Mutex<()> = Mutex::new(());\n\
                   fn f() { let last = Arc::new(Mutex::new(Vec::new())); }\n";
        assert_eq!(decls(src), vec!["GAUGE", "last", "slots", "table"]);
    }

    #[test]
    fn constructor_lines_and_strings_do_not_declare() {
        // a struct-literal constructor re-using a field name, and the
        // token inside a string, both stay silent
        let src = "fn f() {\n    S { slots: (0..n).map(|_| \
                   Mutex::new(0)).collect() };\n    \
                   let s = \"a Mutex<u8> in prose\";\n}\n";
        assert!(decls(src).is_empty());
    }

    #[test]
    fn tuple_let_bindings_yield_no_name() {
        assert!(decls("fn f() { let (a, b) = (Mutex::new(0), 1); }\n")
            .is_empty());
        assert_eq!(
            let_binding_name("let mut guard = m.lock();"),
            Some("guard".to_string())
        );
        assert_eq!(
            let_binding_name("if let Ok(g) = m.lock() {"),
            Some("g".to_string())
        );
    }

    fn edge(file: &str, line: usize, a: &str, b: &str) -> LockEdge {
        LockEdge {
            file: file.to_string(),
            line,
            first: a.to_string(),
            second: b.to_string(),
        }
    }

    #[test]
    fn two_lock_inversion_flags_both_sites() {
        let edges = vec![edge("x.rs", 3, "a", "b"), edge("y.rs", 7, "b", "a")];
        let got = cycle_findings(&edges);
        assert_eq!(got.len(), 2);
        assert_eq!((got[0].file.as_str(), got[0].line), ("x.rs", 3));
        assert_eq!((got[1].file.as_str(), got[1].line), ("y.rs", 7));
    }

    #[test]
    fn consistent_global_order_is_clean() {
        let edges = vec![
            edge("x.rs", 3, "a", "b"),
            edge("y.rs", 7, "a", "b"),
            edge("z.rs", 2, "b", "c"),
            edge("z.rs", 9, "a", "c"),
        ];
        assert!(cycle_findings(&edges).is_empty());
    }

    #[test]
    fn longer_cycles_and_self_edges_are_cycles() {
        // a -> b -> c -> a: every edge sits on the cycle
        let edges = vec![
            edge("x.rs", 1, "a", "b"),
            edge("x.rs", 2, "b", "c"),
            edge("x.rs", 3, "c", "a"),
        ];
        assert_eq!(cycle_findings(&edges).len(), 3);
        // re-acquiring a held lock is a self-deadlock
        let edges = vec![edge("x.rs", 4, "m", "m")];
        assert_eq!(cycle_findings(&edges).len(), 1);
    }
}
