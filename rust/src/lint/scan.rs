//! The tokenizing line scanner behind `repro lint` (DESIGN.md §12).
//!
//! One pass per file, line by line, with persistent cross-line state for
//! block comments, multi-line string literals (plain and raw), brace
//! depth, `#[cfg(test)]`/`mod tests` regions, and the enclosing-function
//! stack (the hot-path rule cares whether a line sits inside `wake`/
//! `receive`). Rule matching runs on a *stripped* view of each line —
//! comments removed, string-literal contents emptied — so `"HashMap"`
//! inside a log message or a doc comment can never trip a rule, and
//! braces inside strings can never corrupt region tracking.
//!
//! Waiver pragmas are parsed out of the comment text of the original
//! line: `// lint:allow(RULE[, RULE...]): reason` waives the named rules
//! on its own line (trailing form) or, when the line carries no code, on
//! the next code-bearing line (standalone form). The reason is mandatory;
//! a reasonless or malformed pragma is itself reported as a `bad-waiver`
//! finding that no baseline can absorb. Every accepted pragma is also
//! *tracked*: one that suppressed nothing by end of file is reported as
//! `stale-waiver` (DESIGN.md §14) — equally un-baselineable — so a
//! suppression cannot outlive the finding that justified it.
//!
//! The concurrency rules (DESIGN.md §14) ride the same pass: a guard
//! stack models `Mutex`/`RwLock` guards acquired on *declared* lock names
//! (collected corpus-wide by [`super::conc::collect_lock_decls`]) and
//! released by brace depth or explicit `drop(..)`; nested acquisitions
//! emit [`LockEdge`]s for the cross-file order graph, blocking calls
//! under a held guard are `lock-across-blocking`, `Ordering::Relaxed`
//! beside a report-counter name is `relaxed-counter`, and `static mut` /
//! `unsafe impl Send/Sync` / raw pointers are `unsync-shared`.

use super::conc::{let_binding_name, LockEdge};
use super::{Finding, BAD_WAIVER, RULES, STALE_WAIVER};
use std::collections::BTreeSet;

/// Result of scanning one file.
#[derive(Debug, Default)]
pub struct FileScan {
    /// Rule findings (baseline-eligible), in line order.
    pub findings: Vec<Finding>,
    /// Malformed (`bad-waiver`) and unconsumed (`stale-waiver`) pragmas;
    /// never baseline-absorbed.
    pub waiver_errors: Vec<Finding>,
    /// Number of findings suppressed by valid waivers.
    pub waivers_used: usize,
    /// Observed lock-acquisition orderings, for the cross-file graph.
    pub lock_edges: Vec<LockEdge>,
}

static NO_LOCKS: BTreeSet<String> = BTreeSet::new();

/// Scan one file's source text with no declared-lock knowledge (the
/// lock-acquisition rules stay silent). `rel_path` is the
/// repo-root-relative, `/`-separated path — rule scoping keys on it
/// (DESIGN.md §12).
pub fn scan_source(rel_path: &str, text: &str) -> FileScan {
    scan_source_with(rel_path, text, &NO_LOCKS)
}

/// Full scan: determinism rules plus the concurrency rules, recognizing
/// `.lock()`/`.read()`/`.write()` acquisitions on the declared
/// `lock_names` (DESIGN.md §14).
pub fn scan_source_with(
    rel_path: &str,
    text: &str,
    lock_names: &BTreeSet<String>,
) -> FileScan {
    let mut sc = Scanner::new(rel_path, lock_names);
    for (idx, line) in text.lines().enumerate() {
        sc.feed(idx + 1, line);
    }
    let mut waiver_errors = sc.waiver_errors;
    for rec in &sc.waiver_recs {
        // test-region pragmas are inert (rules don't run there), so they
        // cannot prove themselves live — skip, don't punish
        if !rec.consumed && !rec.in_test {
            waiver_errors.push(Finding {
                rule: STALE_WAIVER,
                file: rel_path.to_string(),
                line: rec.line,
                detail: format!(
                    "waiver for {} suppresses nothing on its line — \
                     remove it",
                    rec.rule
                ),
            });
        }
    }
    waiver_errors.sort_by_key(|f| f.line);
    FileScan {
        findings: sc.findings,
        waiver_errors,
        waivers_used: sc.waivers_used,
        lock_edges: sc.lock_edges,
    }
}

/// Stripped view (comments removed, string contents emptied) of every
/// line — the declaration-collection pre-pass reuses the scanner's
/// tokenizer so `Mutex<` inside a string or doc comment stays inert.
pub(crate) fn strip_lines(text: &str) -> Vec<String> {
    let mut sc = Scanner::new("", &NO_LOCKS);
    text.lines().map(|l| sc.split_line(l).0).collect()
}

// ---- rule scoping by path (DESIGN.md §12 table) ------------------------

/// Directories whose code must stay bitwise-deterministic: everything the
/// virtual-time simulator executes or that feeds it inputs.
const SIM_SCOPE: [&str; 5] = [
    "rust/src/sim/",
    "rust/src/algo/",
    "rust/src/fuzz/",
    "rust/src/scenario/",
    "rust/src/graph/",
];

/// Functions the hot-path allocation rule watches inside `algo/`: the
/// per-event state-machine entry points (PR 3's one-alloc-per-fan-out
/// invariant lives here).
const HOT_FNS: [&str; 3] = ["wake", "receive", "on_send_failed"];

fn in_sim_scope(path: &str) -> bool {
    SIM_SCOPE.iter().any(|p| path.starts_with(p))
}

fn in_lib_scope(path: &str) -> bool {
    // testutil ships in the library but exists only to serve tests; its
    // panics are assertions by design
    path.starts_with("rust/src/") && !path.starts_with("rust/src/testutil/")
}

fn in_hot_file(path: &str) -> bool {
    path.starts_with("rust/src/algo/")
}

// ---- token tables ------------------------------------------------------

const DET_COLLECTIONS: [&str; 2] = ["HashMap", "HashSet"];
const DET_WALLCLOCK: [&str; 3] = ["Instant::now", "SystemTime", "thread::sleep"];
const DET_RAND: [&str; 4] =
    ["thread_rng", "rand::", "RandomState", "DefaultHasher"];
const FLOAT_ORD_ALWAYS: [&str; 1] = ["partial_cmp"];
const FLOAT_ORD_ON_FLOATS: [&str; 2] = ["sort_by_key", "sort_unstable_by_key"];
const HOT_ALLOC: [&str; 3] = [".to_vec()", "vec![", ".clone()"];
const PANIC_PATH: [&str; 6] = [
    ".unwrap()",
    ".expect(",
    "panic!",
    "unreachable!",
    "todo!",
    "unimplemented!",
];

/// Guard acquisition methods. The empty parens are load-bearing: they
/// match `Mutex::lock()`/`RwLock::read()`/`RwLock::write()` but not the
/// arg-taking `io::Read::read(buf)`/`io::Write::write(buf)`.
const ACQUIRE: [&str; 3] = [".lock()", ".read()", ".write()"];

/// Calls that block the current thread: holding a guard across one of
/// these stalls every contender for the lock's full blocking duration
/// (and `.send()` on a bounded channel can deadlock outright).
/// `try_recv`/`try_send` are non-blocking and deliberately absent;
/// `.join()`/`.recv()` keep their empty parens so `Path::join(..)` and
/// friends never match.
const BLOCKING: [&str; 6] = [
    ".send(",
    ".recv()",
    ".recv_timeout(",
    "thread::sleep",
    ".join()",
    ".wait(",
];

/// Atomic counter names whose values feed report scalars or stats
/// (`RunStats`, `Report` scalars, the allocator gauges). A
/// `fetch_add(.., Relaxed)` here can publish a count the reader's
/// `load(Relaxed)` never observes coherently with the data it counts —
/// writes must be `AcqRel`/`Release`, reads `Acquire` (DESIGN.md §14).
const REPORT_COUNTERS: [&str; 12] = [
    "msgs_sent",
    "msgs_lost",
    "msgs_backpressured",
    "msgs_paced",
    "msgs_dropped",
    "bytes_sent",
    "total_steps",
    "steps",
    "ALLOC_COUNT",
    "ALLOC_BYTES",
    "ALLOC_LIVE",
    "ALLOC_PEAK",
];

pub(crate) fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Word-boundary substring search: a match is rejected when a token end
/// that is an identifier character abuts another identifier character
/// (`do_panic!` does not match `panic!`; `unwrap_or(` does not match
/// `.unwrap()` because the parens differ).
pub fn has_token(code: &str, tok: &str) -> bool {
    let (c, t) = (code.as_bytes(), tok.as_bytes());
    if t.is_empty() || c.len() < t.len() {
        return false;
    }
    let (first, last) = (t[0], t[t.len() - 1]);
    let mut start = 0;
    while let Some(off) = find_bytes(&c[start..], t) {
        let i = start + off;
        let j = i + t.len();
        let left_ok = !is_ident(first) || i == 0 || !is_ident(c[i - 1]);
        let right_ok = !is_ident(last) || j >= c.len() || !is_ident(c[j]);
        if left_ok && right_ok {
            return true;
        }
        start = i + 1;
    }
    false
}

fn find_bytes(hay: &[u8], needle: &[u8]) -> Option<usize> {
    if needle.len() > hay.len() {
        return None;
    }
    (0..=hay.len() - needle.len()).find(|&i| &hay[i..i + needle.len()] == needle)
}

// ---- the scanner -------------------------------------------------------

struct Scanner<'a> {
    path: &'a str,
    /// `/* */` nesting depth (Rust block comments nest).
    block_comment: u32,
    /// Inside a plain `"..."` string (they may span lines).
    in_str: bool,
    /// Pending backslash escape inside the plain string.
    str_escape: bool,
    /// `Some(n)`: inside a raw string closed by `"` + n `#`s.
    raw_hashes: Option<usize>,
    /// Brace depth of code (strings/comments excluded).
    depth: i64,
    /// Entry depths of active `#[cfg(test)]`/`mod tests` regions.
    test_regions: Vec<i64>,
    /// Saw a test attribute; the next `{` opens its region.
    pending_test: bool,
    /// Named-function stack: (name, body depth).
    fn_stack: Vec<(String, i64)>,
    /// Saw `fn NAME`; the next `{` opens its body (`;` cancels — a
    /// body-less trait method declaration).
    pending_fn: Option<String>,
    /// Standalone pragmas (indices into `waiver_recs`) awaiting the next
    /// code-bearing line.
    pending_waiver: BTreeSet<usize>,
    /// Every accepted pragma, for stale-waiver accounting.
    waiver_recs: Vec<WaiverRec>,
    /// Corpus-wide declared Mutex/RwLock names (conc.rs phase A).
    lock_names: &'a BTreeSet<String>,
    /// Guards currently held, in acquisition order.
    guards: Vec<Guard>,
    lock_edges: Vec<LockEdge>,
    findings: Vec<Finding>,
    waiver_errors: Vec<Finding>,
    waivers_used: usize,
}

/// One accepted waiver pragma and whether it ever suppressed anything.
struct WaiverRec {
    line: usize,
    rule: &'static str,
    consumed: bool,
    /// Pragmas inside `#[cfg(test)]`/`mod tests` regions are exempt from
    /// staleness — rules never run there, so consumption is unprovable.
    in_test: bool,
}

/// A held lock guard: the lock's declared name, the `let` binding (for
/// explicit `drop(binding)`), and the brace depth it lives at — closing
/// below that depth releases it.
struct Guard {
    lock: String,
    binding: Option<String>,
    depth: i64,
}

impl<'a> Scanner<'a> {
    fn new(path: &'a str, lock_names: &'a BTreeSet<String>) -> Scanner<'a> {
        Scanner {
            path,
            block_comment: 0,
            in_str: false,
            str_escape: false,
            raw_hashes: None,
            depth: 0,
            test_regions: Vec::new(),
            pending_test: false,
            fn_stack: Vec::new(),
            pending_fn: None,
            pending_waiver: BTreeSet::new(),
            waiver_recs: Vec::new(),
            lock_names,
            guards: Vec::new(),
            lock_edges: Vec::new(),
            findings: Vec::new(),
            waiver_errors: Vec::new(),
            waivers_used: 0,
        }
    }

    /// Split one raw line into (code, comment): comments removed from
    /// `code`, string-literal contents emptied (the quotes remain so the
    /// syntactic shape survives), comment text collected for pragma
    /// parsing. Persistent string/comment state crosses lines.
    fn split_line(&mut self, line: &str) -> (String, String) {
        let b = line.as_bytes();
        let n = b.len();
        let mut code: Vec<u8> = Vec::with_capacity(n);
        let mut comment: Vec<u8> = Vec::new();
        let mut i = 0;
        while i < n {
            let c = b[i];
            if let Some(hashes) = self.raw_hashes {
                // inside a raw string: look for `"` + hashes closers
                if c == b'"'
                    && i + 1 + hashes <= n
                    && b[i + 1..i + 1 + hashes].iter().all(|&x| x == b'#')
                {
                    i += 1 + hashes;
                    self.raw_hashes = None;
                    code.push(b'"');
                } else {
                    i += 1;
                }
                continue;
            }
            if self.in_str {
                if self.str_escape {
                    self.str_escape = false;
                    i += 1;
                } else if c == b'\\' {
                    self.str_escape = true;
                    i += 1;
                } else if c == b'"' {
                    self.in_str = false;
                    code.push(b'"');
                    i += 1;
                } else {
                    i += 1;
                }
                continue;
            }
            if self.block_comment > 0 {
                // block comments carry no pragmas; skip their text
                if b[i..].starts_with(b"/*") {
                    self.block_comment += 1;
                    i += 2;
                } else if b[i..].starts_with(b"*/") {
                    self.block_comment -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
                continue;
            }
            // normal code state
            if b[i..].starts_with(b"//") {
                // pragmas live only in plain `//` comments: doc comments
                // (`///`, `//!`) describe syntax, they don't direct the tool
                let rest = &b[i + 2..];
                let is_doc =
                    rest.first().map(|&x| x == b'/' || x == b'!').unwrap_or(false);
                if !is_doc {
                    comment.extend_from_slice(rest);
                }
                break;
            }
            if b[i..].starts_with(b"/*") {
                self.block_comment = 1;
                i += 2;
                continue;
            }
            // raw string opener: r" r#" br" br#" (not part of an ident)
            if (c == b'r' || c == b'b') && (i == 0 || !is_ident(b[i - 1])) {
                let j = if b[i..].starts_with(b"br") {
                    i + 2
                } else if c == b'r' {
                    i + 1
                } else {
                    0
                };
                if j > 0 {
                    let mut h = 0;
                    while j + h < n && b[j + h] == b'#' {
                        h += 1;
                    }
                    if j + h < n && b[j + h] == b'"' {
                        self.raw_hashes = Some(h);
                        code.push(b'"');
                        i = j + h + 1;
                        continue;
                    }
                }
            }
            if c == b'"' {
                self.in_str = true;
                code.push(b'"');
                i += 1;
                continue;
            }
            if c == b'\'' {
                // char literal vs lifetime tick
                if i + 1 < n && b[i + 1] == b'\\' {
                    // escaped char literal: the escaped char sits at
                    // i + 2 (so '\'' works), the closer at or after i + 3
                    let mut k = i + 3;
                    while k < n && b[k] != b'\'' {
                        k += 1;
                    }
                    i = (k + 1).min(n);
                    code.extend_from_slice(b"' '");
                    continue;
                }
                if i + 2 < n && b[i + 2] == b'\'' {
                    i += 3; // plain char literal 'x'
                    code.extend_from_slice(b"' '");
                    continue;
                }
                code.push(c); // lifetime
                i += 1;
                continue;
            }
            code.push(c);
            i += 1;
        }
        (
            String::from_utf8_lossy(&code).into_owned(),
            String::from_utf8_lossy(&comment).into_owned(),
        )
    }

    /// Parse every `lint:allow(...)` pragma in the line's comment text.
    /// Valid pragmas are registered in `waiver_recs` (for stale-waiver
    /// accounting) and their record indices returned; malformed ones (no
    /// rule list, unknown rule, missing/empty reason) become `bad-waiver`
    /// findings.
    fn parse_waivers(&mut self, comment: &str, line_no: usize) -> BTreeSet<usize> {
        const KEY: &str = "lint:allow";
        let mut recs: BTreeSet<usize> = BTreeSet::new();
        let mut start = 0;
        while let Some(off) = comment[start..].find(KEY) {
            let k = start + off;
            let rest = &comment[k + KEY.len()..];
            match Self::parse_one_waiver(rest) {
                Ok(names) => {
                    for name in names {
                        recs.insert(self.waiver_recs.len());
                        self.waiver_recs.push(WaiverRec {
                            line: line_no,
                            rule: name,
                            consumed: false,
                            in_test: !self.test_regions.is_empty(),
                        });
                    }
                }
                Err(detail) => self.waiver_errors.push(Finding {
                    rule: BAD_WAIVER,
                    file: self.path.to_string(),
                    line: line_no,
                    detail,
                }),
            }
            start = k + KEY.len();
        }
        recs
    }

    fn parse_one_waiver(rest: &str) -> Result<Vec<&'static str>, String> {
        let Some(body) = rest.strip_prefix('(') else {
            return Err("expected ( after lint:allow".to_string());
        };
        let Some(close) = body.find(')') else {
            return Err("unclosed lint:allow(".to_string());
        };
        let after = &body[close + 1..];
        let reason_ok = after
            .strip_prefix(':')
            .map(|r| !r.trim().is_empty())
            .unwrap_or(false);
        if !reason_ok {
            return Err(
                "waiver needs a reason: lint:allow(RULE): reason".to_string()
            );
        }
        let mut names = Vec::new();
        for raw in body[..close].split(',') {
            let name = raw.trim();
            match RULES.iter().find(|r| r.name == name) {
                Some(r) => names.push(r.name),
                None => {
                    return Err(format!(
                        "unknown rule in waiver: {:?}",
                        if name.is_empty() { "<empty>" } else { name }
                    ))
                }
            }
        }
        Ok(names)
    }

    fn feed(&mut self, line_no: usize, line: &str) {
        let (code, comment) = self.split_line(line);
        let waive_here = self.parse_waivers(&comment, line_no);
        let has_code = !code.trim().is_empty();
        let mut active = waive_here;
        if has_code {
            active.extend(self.pending_waiver.iter());
        } else {
            // standalone pragma line: carry (accumulating) to the next
            // code-bearing line
            self.pending_waiver.extend(active.iter());
        }

        if code.contains("#[cfg(test") || code.contains("#[test]")
            || has_token(&code, "mod tests")
        {
            self.pending_test = true;
        }
        if let Some(name) = find_fn_name(&code) {
            self.pending_fn = Some(name);
        }

        let in_test = !self.test_regions.is_empty();
        if has_code && !in_test {
            self.match_rules(line_no, &code, &active);
        }

        // brace walk after matching: a region's own opening line (e.g.
        // `mod tests {`) is attribute-marked but not yet inside
        for &ch in code.as_bytes() {
            match ch {
                b'{' => {
                    self.depth += 1;
                    if self.pending_test {
                        self.test_regions.push(self.depth);
                        self.pending_test = false;
                    }
                    if let Some(name) = self.pending_fn.take() {
                        self.fn_stack.push((name, self.depth));
                    }
                }
                b'}' => {
                    if self.test_regions.last() == Some(&self.depth) {
                        self.test_regions.pop();
                    }
                    if self.fn_stack.last().map(|f| f.1) == Some(self.depth) {
                        self.fn_stack.pop();
                    }
                    self.depth -= 1;
                    // a guard lives while depth >= its recorded depth
                    let d = self.depth;
                    self.guards.retain(|g| g.depth <= d);
                }
                b';' => {
                    // a body-less declaration: `fn ready(&self) -> bool;`
                    self.pending_fn = None;
                }
                _ => {}
            }
        }

        if has_code {
            self.pending_waiver.clear();
        }
    }

    fn in_hot_context(&self) -> bool {
        if !in_hot_file(self.path) {
            return false;
        }
        // pending_fn covers single-line bodies (`fn receive(..) { .. }`):
        // matching runs before the brace walk pushes the frame
        self.fn_stack
            .iter()
            .map(|(name, _)| name)
            .chain(self.pending_fn.iter())
            .any(|name| HOT_FNS.contains(&name.as_str()))
    }

    /// Mark every active pragma for `rule` consumed (it suppressed
    /// something) and count the suppression.
    fn consume(&mut self, active: &BTreeSet<usize>, rule: &str) {
        for &i in active {
            if self.waiver_recs[i].rule == rule {
                self.waiver_recs[i].consumed = true;
            }
        }
        self.waivers_used += 1;
    }

    /// Report `rule` at `line_no` unless an active waiver suppresses it.
    fn emit(
        &mut self,
        line_no: usize,
        rule: &'static str,
        detail: String,
        active: &BTreeSet<usize>,
        waived: &BTreeSet<&'static str>,
    ) {
        if waived.contains(rule) {
            self.consume(active, rule);
        } else {
            self.findings.push(Finding {
                rule,
                file: self.path.to_string(),
                line: line_no,
                detail,
            });
        }
    }

    fn fn_ctx(&self) -> String {
        self.fn_stack
            .last()
            .map(|(name, _)| format!(" in fn {name}"))
            .unwrap_or_default()
    }

    fn match_rules(
        &mut self,
        line_no: usize,
        code: &str,
        active: &BTreeSet<usize>,
    ) {
        let waived: BTreeSet<&'static str> =
            active.iter().map(|&i| self.waiver_recs[i].rule).collect();
        let mut hits: Vec<(&'static str, &'static str)> = Vec::new();
        if in_sim_scope(self.path) {
            for tok in DET_COLLECTIONS {
                if has_token(code, tok) {
                    hits.push(("det-collections", tok));
                }
            }
            for tok in DET_WALLCLOCK {
                if has_token(code, tok) {
                    hits.push(("det-wallclock", tok));
                }
            }
            for tok in DET_RAND {
                if has_token(code, tok) {
                    hits.push(("det-rand", tok));
                }
            }
            for tok in FLOAT_ORD_ALWAYS {
                if has_token(code, tok) {
                    hits.push(("float-ord", tok));
                }
            }
            for tok in FLOAT_ORD_ON_FLOATS {
                if has_token(code, tok)
                    && (has_token(code, "f32") || has_token(code, "f64"))
                {
                    hits.push(("float-ord", tok));
                }
            }
        }
        if self.in_hot_context() {
            for tok in HOT_ALLOC {
                if has_token(code, tok) {
                    hits.push(("hot-alloc", tok));
                }
            }
        }
        if in_lib_scope(self.path) {
            for tok in PANIC_PATH {
                if has_token(code, tok) {
                    hits.push(("panic-path", tok));
                }
            }
        }
        for (rule, tok) in hits {
            let detail = format!("{tok}{}", self.fn_ctx());
            self.emit(line_no, rule, detail, active, &waived);
        }
        if in_lib_scope(self.path) {
            self.match_conc(line_no, code, active, &waived);
        }
    }

    /// The concurrency rules (DESIGN.md §14). Scope matches `panic-path`:
    /// all of `rust/src/` except `testutil/`.
    fn match_conc(
        &mut self,
        line_no: usize,
        code: &str,
        active: &BTreeSet<usize>,
        waived: &BTreeSet<&'static str>,
    ) {
        // position-independent per-line rules first
        if has_token(code, "Ordering::Relaxed") {
            if let Some(ctr) =
                REPORT_COUNTERS.iter().find(|c| has_token(code, c))
            {
                let detail =
                    format!("Ordering::Relaxed on {ctr}{}", self.fn_ctx());
                self.emit(line_no, "relaxed-counter", detail, active, waived);
            }
        }
        if has_token(code, "static mut") {
            self.emit(
                line_no,
                "unsync-shared",
                "static mut".to_string(),
                active,
                waived,
            );
        }
        if has_token(code, "unsafe impl")
            && (has_token(code, "Send") || has_token(code, "Sync"))
        {
            self.emit(
                line_no,
                "unsync-shared",
                "unsafe impl Send/Sync".to_string(),
                active,
                waived,
            );
        }
        for tok in ["*mut", "*const"] {
            if has_token(code, tok) {
                let detail = format!("raw pointer ({tok}){}", self.fn_ctx());
                self.emit(line_no, "unsync-shared", detail, active, waived);
            }
        }

        // positional events: acquisitions, explicit drops, blocking calls
        // — processed left to right so `drop(g); tx.send(x)` on one line
        // is already guard-free at the send
        enum Ev {
            Acq(String),
            Rel(String),
            Block(&'static str),
        }
        let mut evs: Vec<(usize, Ev)> = Vec::new();
        for (off, name) in find_acquisitions(code, self.lock_names) {
            evs.push((off, Ev::Acq(name)));
        }
        for (off, name) in find_drops(code) {
            evs.push((off, Ev::Rel(name)));
        }
        for tok in BLOCKING {
            for off in token_offsets(code, tok) {
                evs.push((off, Ev::Block(tok)));
            }
        }
        if evs.is_empty() {
            return;
        }
        evs.sort_by_key(|e| e.0);
        // a binding only attaches when the line acquires exactly once
        // (`let (a, b) = (m1.lock(), m2.lock())` keeps both anonymous)
        let n_acq =
            evs.iter().filter(|(_, e)| matches!(e, Ev::Acq(_))).count();
        let binding =
            if n_acq == 1 { let_binding_name(code) } else { None };
        for (off, ev) in evs {
            match ev {
                Ev::Acq(name) => {
                    // `let g = m.lock();` lives at the current depth; in
                    // `{ let g = m.lock(); }` the guard sits inside the
                    // braces before the token, and in `if let Ok(g) =
                    // m.lock() {` inside the block the line opens — take
                    // the deeper of the two approximations
                    let depth_at = self.depth
                        + line_brace_delta(&code[..off])
                            .max(line_brace_delta(code))
                            .max(0);
                    for held in
                        self.guards.iter().map(|g| g.lock.clone()).collect::<Vec<_>>()
                    {
                        if waived.contains("lock-order") {
                            self.consume(active, "lock-order");
                        } else {
                            self.lock_edges.push(LockEdge {
                                file: self.path.to_string(),
                                line: line_no,
                                first: held,
                                second: name.clone(),
                            });
                        }
                    }
                    self.guards.push(Guard {
                        lock: name,
                        binding: binding.clone(),
                        depth: depth_at,
                    });
                }
                Ev::Rel(name) => {
                    if let Some(pos) = self.guards.iter().rposition(|g| {
                        g.binding.as_deref() == Some(&name) || g.lock == name
                    }) {
                        self.guards.remove(pos);
                    }
                }
                Ev::Block(tok) => {
                    let held = self.guards.last().map(|g| g.lock.clone());
                    if let Some(lock) = held {
                        let detail = format!(
                            "guard of {lock} held across {tok}{}",
                            self.fn_ctx()
                        );
                        self.emit(
                            line_no,
                            "lock-across-blocking",
                            detail,
                            active,
                            waived,
                        );
                    }
                }
            }
        }
    }
}

/// All word-boundary match offsets of `tok` in `code` (the positional
/// twin of [`has_token`]).
fn token_offsets(code: &str, tok: &str) -> Vec<usize> {
    let (c, t) = (code.as_bytes(), tok.as_bytes());
    let mut out = Vec::new();
    if t.is_empty() || c.len() < t.len() {
        return out;
    }
    let (first, last) = (t[0], t[t.len() - 1]);
    let mut start = 0;
    while let Some(off) = find_bytes(&c[start..], t) {
        let i = start + off;
        let j = i + t.len();
        let left_ok = !is_ident(first) || i == 0 || !is_ident(c[i - 1]);
        let right_ok = !is_ident(last) || j >= c.len() || !is_ident(c[j]);
        if left_ok && right_ok {
            out.push(i);
        }
        start = i + 1;
    }
    out
}

/// Lock acquisitions on a stripped line: `(offset, lock name)` for every
/// `NAME.lock()`/`.read()`/`.write()` (one optional `[..]` index group
/// between name and method) whose NAME is a declared lock. A `)` before
/// the dot (`stdout().lock()`) means a call result, not a named lock —
/// skipped.
fn find_acquisitions(
    code: &str,
    locks: &BTreeSet<String>,
) -> Vec<(usize, String)> {
    let b = code.as_bytes();
    let mut out = Vec::new();
    if locks.is_empty() {
        return out;
    }
    for tok in ACQUIRE {
        let mut start = 0;
        while let Some(off) = code[start..].find(tok) {
            let i = start + off; // offset of the '.'
            start = i + tok.len();
            let mut k = i;
            if k > 0 && b[k - 1] == b']' {
                // hop backwards over one balanced [...] group
                let mut depth = 0i32;
                let mut p = k;
                let mut matched = false;
                while p > 0 {
                    p -= 1;
                    if b[p] == b']' {
                        depth += 1;
                    } else if b[p] == b'[' {
                        depth -= 1;
                        if depth == 0 {
                            matched = true;
                            break;
                        }
                    }
                }
                if !matched {
                    continue;
                }
                k = p;
            }
            let e = k;
            let mut s = e;
            while s > 0 && is_ident(b[s - 1]) {
                s -= 1;
            }
            if s == e {
                continue;
            }
            let name = &code[s..e];
            if locks.contains(name) {
                out.push((i, name.to_string()));
            }
        }
    }
    out
}

/// Explicit guard releases: `(offset, NAME)` for every `drop(NAME)` /
/// `mem::drop(NAME)` on the line.
fn find_drops(code: &str) -> Vec<(usize, String)> {
    let b = code.as_bytes();
    let mut out = Vec::new();
    let mut start = 0;
    while let Some(off) = code[start..].find("drop(") {
        let i = start + off;
        start = i + 5;
        if i > 0 && is_ident(b[i - 1]) {
            continue; // airdrop( etc.
        }
        let mut j = i + 5;
        while j < b.len() && b[j] == b' ' {
            j += 1;
        }
        let s = j;
        let mut k = j;
        while k < b.len() && is_ident(b[k]) {
            k += 1;
        }
        if k > s && k < b.len() && b[k] == b')' {
            out.push((i, code[s..k].to_string()));
        }
    }
    out
}

/// Net `{`/`}` count of a stripped line.
fn line_brace_delta(code: &str) -> i64 {
    let mut d = 0i64;
    for &c in code.as_bytes() {
        match c {
            b'{' => d += 1,
            b'}' => d -= 1,
            _ => {}
        }
    }
    d
}

/// First `fn NAME` on the (stripped) line, if any.
fn find_fn_name(code: &str) -> Option<String> {
    let b = code.as_bytes();
    let mut start = 0;
    while let Some(off) = code[start..].find("fn ") {
        let i = start + off;
        if i > 0 && is_ident(b[i - 1]) {
            start = i + 3;
            continue;
        }
        let mut j = i + 3;
        while j < b.len() && b[j] == b' ' {
            j += 1;
        }
        let mut k = j;
        while k < b.len() && is_ident(b[k]) {
            k += 1;
        }
        if k > j {
            return Some(code[j..k].to_string());
        }
        start = i + 3;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn findings(path: &str, src: &str) -> Vec<(String, usize)> {
        scan_source(path, src)
            .findings
            .iter()
            .map(|f| (f.rule.to_string(), f.line))
            .collect()
    }

    #[test]
    fn tokens_respect_word_boundaries() {
        assert!(has_token("let m: HashMap<u32, u32>;", "HashMap"));
        assert!(!has_token("let m = MyHashMap::new();", "HashMap"));
        assert!(!has_token("do_panic!()", "panic!"));
        assert!(has_token("panic!(\"boom\")", "panic!"));
        assert!(!has_token("x.unwrap_or(0)", ".unwrap()"));
        assert!(has_token("x.unwrap()", ".unwrap()"));
    }

    #[test]
    fn strings_and_comments_never_match() {
        let src = "fn f() {\n    let s = \"HashMap in a string\";\n    \
                   // a comment naming partial_cmp\n    \
                   /* Instant::now in a block comment */\n}\n";
        assert!(findings("rust/src/sim/x.rs", src).is_empty());
    }

    #[test]
    fn multiline_and_raw_strings_are_stripped() {
        let src = "fn f() {\n    let s = \"line one\n        \
                   HashMap line two\";\n    let r = r#\"raw HashMap \
                   \"quoted\" inside\"#;\n    let t = SystemTime::now();\n}\n";
        let got = findings("rust/src/sim/x.rs", src);
        assert_eq!(got, vec![("det-wallclock".to_string(), 5)]);
    }

    #[test]
    fn char_literals_do_not_derail_the_scanner() {
        let src = "fn f() {\n    let a = '\\'';\n    let b = '{';\n    \
                   let c = '\\u{7f}';\n    let m: HashSet<u8>;\n}\n";
        let got = findings("rust/src/sim/x.rs", src);
        assert_eq!(got, vec![("det-collections".to_string(), 5)]);
    }

    #[test]
    fn cfg_test_region_is_exempt() {
        let src = "fn f() {}\n#[cfg(test)]\nmod tests {\n    \
                   fn g() { x.partial_cmp(y); }\n}\n";
        assert!(findings("rust/src/sim/x.rs", src).is_empty());
        // ... and code after the region is scanned again
        let src2 = "#[cfg(test)]\nmod tests {\n    fn g() {}\n}\n\
                    fn h() { x.partial_cmp(y); }\n";
        assert_eq!(
            findings("rust/src/sim/x.rs", src2),
            vec![("float-ord".to_string(), 5)]
        );
    }

    #[test]
    fn scope_gates_rules_by_path() {
        let src = "fn f() { let t = Instant::now(); }\n";
        assert_eq!(findings("rust/src/sim/x.rs", src).len(), 1);
        // wall-clock constructs stay legal in runner/ and faults/
        assert!(findings("rust/src/runner/x.rs", src).is_empty());
        assert!(findings("rust/src/faults/x.rs", src).is_empty());

        let src = "fn f() { x.unwrap(); }\n";
        assert_eq!(findings("rust/src/metrics/x.rs", src).len(), 1);
        // testutil and non-src trees are outside the panic rule
        assert!(findings("rust/src/testutil/x.rs", src).is_empty());
        assert!(findings("rust/tests/x.rs", src).is_empty());
        assert!(findings("examples/x.rs", src).is_empty());
    }

    #[test]
    fn hot_alloc_only_inside_hot_fns_of_algo() {
        let src = "impl N {\n    pub fn new() -> N { let v = vec![0.0; 8]; \
                   N { v } }\n    fn wake(&mut self) {\n        \
                   let w = vec![0.0; 8];\n        let c = self.x.clone();\n    \
                   }\n    fn receive(&mut self) { let d = self.y.to_vec(); }\n}\n";
        let got = findings("rust/src/algo/x.rs", src);
        assert_eq!(
            got,
            vec![
                ("hot-alloc".to_string(), 4),
                ("hot-alloc".to_string(), 5),
                ("hot-alloc".to_string(), 7),
            ]
        );
        // same fns outside algo/: no rule
        assert!(findings("rust/src/exp/x.rs", src)
            .iter()
            .all(|(r, _)| r != "hot-alloc"));
    }

    #[test]
    fn trait_method_declarations_do_not_capture_fn_context() {
        // `fn wake(...);` has no body: the `;` cancels the pending fn, so
        // the next body is attributed to its own fn, not to `wake`
        let src = "trait T {\n    fn wake(&mut self);\n    \
                   fn other(&self) { let v = vec![0u8; 4]; }\n}\n";
        assert!(findings("rust/src/algo/x.rs", src).is_empty());
    }

    #[test]
    fn trailing_waiver_suppresses_with_reason() {
        let src = "fn f() {\n    x.partial_cmp(y); // lint:allow(float-ord): \
                   PartialOrd impl delegates to total order\n}\n";
        let scan = scan_source("rust/src/sim/x.rs", src);
        assert!(scan.findings.is_empty());
        assert!(scan.waiver_errors.is_empty());
        assert_eq!(scan.waivers_used, 1);
    }

    #[test]
    fn standalone_waiver_covers_next_code_line() {
        let src = "fn f() {\n    // lint:allow(panic-path): invariant \
                   upheld by caller\n\n    x.unwrap();\n    y.unwrap();\n}\n";
        let scan = scan_source("rust/src/exp/x.rs", src);
        // blank line skipped; first code line waived, second is not
        assert_eq!(scan.waivers_used, 1);
        assert_eq!(scan.findings.len(), 1);
        assert_eq!(scan.findings[0].line, 5);
    }

    #[test]
    fn waiver_without_reason_is_a_finding_and_does_not_suppress() {
        let src = "fn f() {\n    x.unwrap(); // lint:allow(panic-path)\n}\n";
        let scan = scan_source("rust/src/exp/x.rs", src);
        assert_eq!(scan.waiver_errors.len(), 1);
        assert_eq!(scan.findings.len(), 1, "malformed waiver must not waive");
        let src2 = "fn f() {\n    x.unwrap(); // lint:allow(panic-path):   \n}\n";
        assert_eq!(scan_source("rust/src/exp/x.rs", src2).waiver_errors.len(), 1);
    }

    #[test]
    fn doc_comments_describing_pragmas_are_inert() {
        // `///` and `//!` may spell out the pragma grammar without being
        // parsed as (malformed) waivers — and without waiving anything
        let src = "//! Use `// lint:allow(RULE): reason` to waive.\n\
                   /// Syntax: lint:allow(...) then a reason.\n\
                   fn f() { x.unwrap(); }\n";
        let scan = scan_source("rust/src/exp/x.rs", src);
        assert!(scan.waiver_errors.is_empty());
        assert_eq!(scan.waivers_used, 0);
        assert_eq!(scan.findings.len(), 1);
    }

    #[test]
    fn waiver_with_unknown_rule_is_rejected() {
        let src = "fn f() {\n    x.unwrap(); // lint:allow(no-such-rule): y\n}\n";
        let scan = scan_source("rust/src/exp/x.rs", src);
        assert_eq!(scan.waiver_errors.len(), 1);
        assert!(scan.waiver_errors[0].detail.contains("no-such-rule"));
    }

    #[test]
    fn waiver_list_covers_multiple_rules() {
        let src = "fn wake(&mut self) {\n    let v: HashMap<u8, u8> = \
                   x.clone(); // lint:allow(det-collections, hot-alloc): \
                   fixture of both rules\n}\n";
        let scan = scan_source("rust/src/algo/x.rs", src);
        assert!(scan.findings.is_empty());
        assert_eq!(scan.waivers_used, 2);
    }

    #[test]
    fn sort_by_key_flags_only_with_float_types() {
        let src = "fn f() { xs.sort_by_key(|x| x.id); }\n";
        assert!(findings("rust/src/graph/x.rs", src).is_empty());
        let src = "fn f() { xs.sort_by_key(|x| x.t as f64 as u64); }\n";
        assert_eq!(findings("rust/src/graph/x.rs", src).len(), 1);
    }

    #[test]
    fn fn_names_are_tracked_through_nested_braces() {
        let src = "impl N {\n    fn wake(&mut self) {\n        \
                   if x {\n            for _ in 0..3 { let v = vec![1]; }\n        \
                   }\n    }\n    fn calm(&self) { let v = vec![1]; }\n}\n";
        let got = findings("rust/src/algo/x.rs", src);
        assert_eq!(got, vec![("hot-alloc".to_string(), 4)]);
    }

    // ---- concurrency rules (DESIGN.md §14) ----------------------------

    fn conc_scan(path: &str, src: &str, locks: &[&str]) -> FileScan {
        let locks: BTreeSet<String> =
            locks.iter().map(|s| s.to_string()).collect();
        scan_source_with(path, src, &locks)
    }

    #[test]
    fn nested_acquisitions_record_edges() {
        let src = "fn f(&self) {\n    let ga = self.a.lock();\n    \
                   let gb = self.b.lock();\n}\n";
        let scan = conc_scan("rust/src/runner/x.rs", src, &["a", "b"]);
        assert_eq!(scan.lock_edges.len(), 1);
        let e = &scan.lock_edges[0];
        assert_eq!((e.first.as_str(), e.second.as_str(), e.line), ("a", "b", 3));
        // sibling (non-nested) acquisitions: no edge
        let src = "fn f(&self) {\n    { let ga = self.a.lock(); }\n    \
                   { let gb = self.b.lock(); }\n}\n";
        let scan = conc_scan("rust/src/runner/x.rs", src, &["a", "b"]);
        assert!(scan.lock_edges.is_empty());
    }

    #[test]
    fn drop_and_scope_release_guards() {
        // explicit drop before the second acquisition: no edge
        let src = "fn f(&self) {\n    let ga = self.a.lock();\n    \
                   drop(ga);\n    let gb = self.b.lock();\n}\n";
        let scan = conc_scan("rust/src/runner/x.rs", src, &["a", "b"]);
        assert!(scan.lock_edges.is_empty());
        // a guard from an `if let` head dies with its block
        let src = "fn f(&self) {\n    if let Ok(ga) = self.a.lock() {\n        \
                   x();\n    }\n    let gb = self.b.lock();\n}\n";
        let scan = conc_scan("rust/src/runner/x.rs", src, &["a", "b"]);
        assert!(scan.lock_edges.is_empty());
    }

    #[test]
    fn guard_held_across_blocking_call_is_flagged() {
        let src = "fn f(&self) {\n    let g = self.slots.lock();\n    \
                   tx.send(m);\n}\n";
        let scan = conc_scan("rust/src/runner/x.rs", src, &["slots"]);
        assert_eq!(scan.findings.len(), 1);
        assert_eq!(scan.findings[0].rule, "lock-across-blocking");
        assert!(scan.findings[0].detail.contains("slots"));
        // drop first (same line, left of the send): clean
        let src = "fn f(&self) {\n    let g = self.slots.lock();\n    \
                   drop(g); tx.send(m);\n}\n";
        let scan = conc_scan("rust/src/runner/x.rs", src, &["slots"]);
        assert!(scan.findings.is_empty());
        // Path::join and try_recv are not blocking calls
        let src = "fn f(&self) {\n    let g = self.slots.lock();\n    \
                   let p = dir.join(name);\n    let m = rx.try_recv();\n}\n";
        let scan = conc_scan("rust/src/runner/x.rs", src, &["slots"]);
        assert!(scan.findings.is_empty());
    }

    #[test]
    fn undeclared_receivers_never_acquire() {
        // io .read()/.write()/stdout().lock(): none of these names are
        // declared locks, so no guard state and no findings
        let src = "fn f(&self) {\n    let n = file.read();\n    \
                   out.write();\n    let h = io::stdout().lock();\n    \
                   tx.send(m);\n}\n";
        let scan = conc_scan("rust/src/runner/x.rs", src, &["slots"]);
        assert!(scan.findings.is_empty());
        assert!(scan.lock_edges.is_empty());
    }

    #[test]
    fn indexed_acquisition_resolves_the_field_name() {
        let src = "fn f(&self) {\n    let g = \
                   shared.snapshots[id].lock();\n    thread::sleep(d);\n}\n";
        let scan =
            conc_scan("rust/src/runner/x.rs", src, &["snapshots"]);
        assert_eq!(scan.findings.len(), 1);
        assert!(scan.findings[0].detail.contains("snapshots"));
    }

    #[test]
    fn relaxed_counter_only_for_report_counters() {
        let src = "fn f(&self) {\n    \
                   self.msgs_sent.fetch_add(1, Ordering::Relaxed);\n    \
                   self.gamma_bits.store(b, Ordering::Relaxed);\n}\n";
        let scan = conc_scan("rust/src/runner/x.rs", src, &[]);
        assert_eq!(scan.findings.len(), 1);
        assert_eq!(scan.findings[0].rule, "relaxed-counter");
        assert_eq!(scan.findings[0].line, 2);
        // AcqRel on the counter: clean
        let src = "fn f(&self) {\n    \
                   self.msgs_sent.fetch_add(1, Ordering::AcqRel);\n}\n";
        assert!(conc_scan("rust/src/runner/x.rs", src, &[])
            .findings
            .is_empty());
    }

    #[test]
    fn unsync_shared_tokens_flag_outside_testutil() {
        let src = "static mut GLOBAL: u64 = 0;\n\
                   unsafe impl Send for Raw {}\n\
                   fn f(p: *mut u8) {}\n";
        let scan = conc_scan("rust/src/exp/x.rs", src, &[]);
        let rules: Vec<_> = scan.findings.iter().map(|f| f.rule).collect();
        assert_eq!(
            rules,
            vec!["unsync-shared", "unsync-shared", "unsync-shared"]
        );
        // testutil/ is exempt; `unsafe impl GlobalAlloc` is not Send/Sync
        assert!(conc_scan("rust/src/testutil/x.rs", src, &[])
            .findings
            .is_empty());
        let src = "unsafe impl GlobalAlloc for A {\n}\n";
        assert!(conc_scan("rust/src/exp/x.rs", src, &[])
            .findings
            .is_empty());
    }

    #[test]
    fn conc_waivers_suppress_and_are_consumed() {
        let src = "fn f(&self) {\n    let g = self.slots.lock();\n    \
                   // lint:allow(lock-across-blocking): bounded 1ms sleep\n    \
                   thread::sleep(d);\n}\n";
        let scan = conc_scan("rust/src/runner/x.rs", src, &["slots"]);
        assert!(scan.findings.is_empty());
        assert!(scan.waiver_errors.is_empty(), "consumed, not stale");
        assert_eq!(scan.waivers_used, 1);
    }

    #[test]
    fn stale_waiver_is_reported_and_unbaselineable() {
        // the waived rule does not fire on the covered line
        let src = "fn f() {\n    let x = 1; \
                   // lint:allow(panic-path): nothing panics here\n}\n";
        let scan = scan_source("rust/src/exp/x.rs", src);
        assert!(scan.findings.is_empty());
        assert_eq!(scan.waiver_errors.len(), 1);
        assert_eq!(scan.waiver_errors[0].rule, STALE_WAIVER);
        assert_eq!(scan.waiver_errors[0].line, 2);
        assert!(scan.waiver_errors[0].detail.contains("panic-path"));
    }

    #[test]
    fn stale_tracking_skips_test_regions() {
        let src = "#[cfg(test)]\nmod tests {\n    fn g() {\n        \
                   x.unwrap(); // lint:allow(panic-path): test-only\n    }\n}\n";
        let scan = scan_source("rust/src/exp/x.rs", src);
        assert!(scan.waiver_errors.is_empty());
    }
}
