//! Deterministic discrete-event simulator (virtual time).
//!
//! Reproduces the paper's testbed semantics (§VI) without its hardware:
//! every node has its own compute pace (lognormal jitter, optional
//! straggler multiplier), every directed link has latency (lognormal,
//! capped — Assumption 3's bounded delay) and, for the asynchronous
//! algorithms, sender-side Bernoulli packet loss with at most one unacked
//! packet in flight per link (the paper's send-until-receipt emulation,
//! §VI ¶1). Synchronous algorithms get reliable links — they would
//! deadlock otherwise, which is why the paper only applies loss to the
//! async ones.
//!
//! Event loop invariants:
//! * a node is either *busy* (an iteration in flight, `NodeFinish`
//!   scheduled) or *idle*; idle nodes are re-examined whenever a message
//!   arrives, so synchronous barriers release exactly when the last input
//!   lands;
//! * ties in virtual time break on a monotone sequence number — the run is
//!   a pure function of (config, topology, algorithm, oracle seeds).
//!
//! Link discipline and fault queries are the shared
//! [`faults`](crate::faults) layer (the threaded runner drives the same
//! code against a wall clock). Messages carry shared payloads
//! ([`Payload`](crate::algo::Payload), DESIGN.md §8), so routing and
//! delivery move `Arc`s — a scheduled `Deliver` event never copies
//! payload bytes, and the byte accounting (`SimStats::bytes_sent`)
//! charges logical payload size, not allocations. Fault injection beyond the scalar knobs
//! goes through the declarative [`Scenario`](crate::scenario::Scenario)
//! in `SimConfig::scenario`. The scenario is consulted at exactly four
//! points, each a pure function of virtual time (so both invariants
//! above survive):
//! * start-of-iteration time: churn — a paused node starts no new
//!   iteration and a `Resume` event re-examines it when the window ends;
//! * compute-cost time: straggler schedules multiply the drawn cost;
//! * send time: the loss ramp overrides `loss_prob`, and bandwidth caps
//!   serialize payloads FIFO per directed link (a real throughput bound,
//!   not just a fixed delay) before the propagation latency;
//! * latency-draw time: the latency ramp scales the lognormal's mean (and
//!   the cap, so Assumption 3's bound stretches rather than truncates).

use crate::algo::{mean_param, AlgoKind, Msg, NodeState};
use crate::config::SimConfig;
use crate::exp::Stop;
use crate::faults::{BwPacer, FaultSpec, LinkIndex, SendVerdict,
                    SimFaultLayer, VirtualClock};
use crate::graph::Topology;
use crate::metrics::Report;
use crate::oracle::OracleSet;
use crate::prng::Rng;

mod sched;
use sched::{CalendarQueue, Key};

/// When to stop a run (legacy simulator-only spelling).
///
/// Superseded by the engine-agnostic [`Stop`](crate::exp::Stop):
/// `Simulator::run` takes `impl Into<Stop>`, so existing `StopRule` call
/// sites keep compiling through the `From` conversion below.
#[deprecated(note = "use exp::Stop (Stop::Time is virtual seconds on the \
                     simulator)")]
#[derive(Clone, Copy, Debug)]
pub enum StopRule {
    /// Total gradient computations across all nodes.
    Iterations(u64),
    /// Seconds of virtual time.
    VirtualTime(f64),
    /// Stop once the evaluated loss reaches `loss` (checked at every eval
    /// tick), or at `max_time` — whichever comes first.
    TargetLoss { loss: f64, max_time: f64 },
    /// Stop when the global epoch counter reaches this value — the paper's
    /// Table II protocol (fixed epoch budget, compare wall time + accuracy).
    Epochs(f64),
}

#[allow(deprecated)]
impl From<StopRule> for Stop {
    fn from(s: StopRule) -> Stop {
        match s {
            StopRule::Iterations(k) => Stop::Iterations(k),
            StopRule::VirtualTime(t) => Stop::Time(t),
            StopRule::TargetLoss { loss, max_time } => {
                Stop::TargetLoss { loss, max_time }
            }
            StopRule::Epochs(e) => Stop::Epochs(e),
        }
    }
}

/// Aggregate counters the report exposes.
#[derive(Clone, Copy, Debug, Default)]
pub struct SimStats {
    pub grad_wakes: u64,
    pub comm_wakes: u64,
    pub msgs_sent: u64,
    pub msgs_delivered: u64,
    pub msgs_lost: u64,
    /// Discarded because the link still had an unacked packet in flight.
    pub msgs_backpressured: u64,
    /// Sends whose transmission was delayed by a scenario bandwidth cap
    /// (the FIFO serialization queue pushed `sent_at` past the send
    /// time). The virtual-time twin of the runner's paced counter, so
    /// both engines expose a `msgs_paced` scalar.
    pub msgs_paced: u64,
    /// Payload bytes actually put on the wire (Deliver verdicts only —
    /// lost and backpressured sends transmit nothing). The communication
    /// volume the bench baseline tracks as bytes-per-epoch
    /// (EXPERIMENTS.md §Schema).
    pub bytes_sent: u64,
    pub virtual_time: f64,
}

#[derive(Debug)]
enum Event {
    /// Node finishes the iteration whose cost was charged when scheduled.
    NodeFinish(usize),
    Deliver(Msg),
    /// Ack returns to the sender; channel (from→to, chan) becomes free.
    Ack { from: usize, to: usize, chan: usize },
    EvalTick,
    /// A scenario churn window ended: re-examine the node.
    Resume(usize),
}

pub struct Simulator {
    cfg: SimConfig,
    algo: AlgoKind,
    nodes: Vec<Box<dyn NodeState>>,
    set: OracleSet,
    n: usize,
    time: f64,
    seq: u64,
    /// calendar-queue scheduler over (Key, event idx) — drains in the
    /// exact (time, seq) total order the old global heap produced
    /// ([`sched`] module docs + DESIGN.md §13)
    queue: CalendarQueue,
    events: Vec<Option<Event>>,
    /// recycled `events` slots (each slot lives exactly one push→pop
    /// cycle; without reuse the vec grows with total events, not with
    /// in-flight events)
    free_slots: Vec<usize>,
    busy: Vec<bool>,
    /// shared fault/link layer (virtual clock + one-unacked-packet
    /// channel slots + scalar/scenario fault queries); `faults.clock`
    /// mirrors `self.time` and is advanced at every event pop
    faults: SimFaultLayer,
    pace_rng: Vec<Rng>,
    link_rng: Rng,
    /// one pending `Resume` event per paused node at most
    resume_scheduled: Vec<bool>,
    /// FIFO transmission queue per directed link (bandwidth caps)
    bw: BwPacer,
    stats: SimStats,
    /// Per-node gradient-step counts (the simulator twin of
    /// `RunnerStats::steps_per_node`, surfaced through `exp::RunStats`).
    steps_per_node: Vec<u64>,
    mean_buf: Vec<f32>,
    epoch: f64,
    /// rolling sum/count of minibatch losses between eval ticks
    train_loss_acc: (f64, u64),
    /// number of γ-decay steps already applied
    decay_steps: u32,
}

impl Simulator {
    /// Build a simulator; nodes start from `x0 = 0` (override with
    /// [`Simulator::with_x0`] before the first `run`).
    ///
    /// Note: as an *entry point* for experiments this is superseded by
    /// [`exp::Experiment`](crate::exp::Experiment), which owns workload
    /// construction, validates misuse into typed errors, and returns
    /// unified stats. Construct a `Simulator` directly only when you need
    /// engine-level control (custom oracle sets, mid-run inspection).
    pub fn new(cfg: SimConfig, topo: &Topology, algo: AlgoKind,
               set: OracleSet) -> Simulator {
        // lint:allow(panic-path): engine-level constructor fails fast; Experiment pre-validates into typed errors
        cfg.validate().expect("invalid SimConfig");
        let n = topo.n();
        assert_eq!(set.n_nodes(), n, "oracle set vs topology node count");
        let x0 = vec![0.0f32; set.dim];
        Simulator::with_x0(cfg, topo, algo, set, &x0)
    }

    pub fn with_x0(cfg: SimConfig, topo: &Topology, algo: AlgoKind,
                   set: OracleSet, x0: &[f32]) -> Simulator {
        let n = topo.n();
        if let Some(sc) = &cfg.scenario {
            // lint:allow(panic-path): engine-level constructor fails fast; Experiment pre-validates into typed errors
            sc.validate(Some(n)).expect("invalid scenario for this topology");
        }
        let nodes = algo.build(topo, x0, cfg.gamma, cfg.seed);
        let pace_rng =
            (0..n).map(|i| Rng::stream(cfg.seed, 0xacce1 + i as u64)).collect();
        // sparse link universe: every direction a message can travel in
        // this topology (v-broadcasts, ρ-pushes, protocol replies) —
        // O(edges) channel slots and pacer lanes instead of n²
        let links = LinkIndex::from_weights(&topo.weights);
        let link_count = links.links();
        let faults = SimFaultLayer::with_links(links, VirtualClock::new(),
                                               FaultSpec::from_config(&cfg));
        Simulator {
            link_rng: Rng::stream(cfg.seed, 0x117c),
            cfg,
            algo,
            nodes,
            set,
            n,
            time: 0.0,
            seq: 0,
            queue: CalendarQueue::new(),
            events: Vec::new(),
            free_slots: Vec::new(),
            busy: vec![false; n],
            faults,
            pace_rng,
            resume_scheduled: vec![false; n],
            bw: BwPacer::new(link_count),
            stats: SimStats::default(),
            steps_per_node: vec![0; n],
            mean_buf: Vec::new(),
            epoch: 0.0,
            train_loss_acc: (0.0, 0),
            decay_steps: 0,
        }
    }

    fn push_event(&mut self, at: f64, ev: Event) {
        debug_assert!(at.is_finite(),
                      "non-finite event time {at} for {ev:?}");
        let idx = match self.free_slots.pop() {
            Some(i) => {
                self.events[i] = Some(ev);
                i
            }
            None => {
                self.events.push(Some(ev));
                self.events.len() - 1
            }
        };
        self.seq += 1;
        self.queue.push(Key(at, self.seq), idx);
    }

    fn compute_cost(&mut self, node: usize) -> f64 {
        let c = if self.cfg.compute_jitter > 0.0 {
            self.pace_rng[node].lognormal(self.cfg.compute_mean,
                                          self.cfg.compute_jitter)
        } else {
            self.cfg.compute_mean
        };
        c * self.faults.spec.compute_factor(node, self.time)
    }

    fn latency(&mut self) -> f64 {
        let mult = self.faults.spec.latency_multiplier(self.time);
        let mean = self.cfg.link_latency * mult;
        let l = if self.cfg.latency_jitter > 0.0 && mean > 0.0 {
            self.link_rng.lognormal(mean, self.cfg.latency_jitter)
        } else {
            mean
        };
        // the cap scales with the ramp: a degrading network stretches
        // Assumption 3's bound D rather than clipping against it
        l.min(self.cfg.latency_cap * mult.max(1.0))
    }

    /// Start node's next iteration if idle and ready.
    fn try_start(&mut self, node: usize) {
        if self.busy[node] || !self.nodes[node].ready() {
            return;
        }
        // scenario churn: a paused node starts nothing; one Resume event
        // re-examines it when the active window ends
        if self.faults.spec.is_paused(node, self.time) {
            if let Some(at) = self.faults.spec.next_resume(node, self.time) {
                if !self.resume_scheduled[node] {
                    self.resume_scheduled[node] = true;
                    self.push_event(at, Event::Resume(node));
                }
            }
            return;
        }
        self.busy[node] = true;
        let cost = if self.nodes[node].wake_computes_gradient() {
            self.compute_cost(node)
        } else {
            // communication micro-step (ring phases): message handling only
            1e-6
        };
        let at = self.time + cost;
        self.push_event(at, Event::NodeFinish(node));
    }

    /// Route freshly emitted messages through the shared link layer
    /// (backpressure → loss draw → channel acquisition, then bandwidth
    /// serialization and propagation latency).
    fn route(&mut self, msgs: &mut Vec<Msg>) {
        let lossy = self.algo.tolerates_loss();
        for msg in msgs.drain(..) {
            debug_assert!(msg.to < self.n && msg.from < self.n);
            self.stats.msgs_sent += 1;
            match self.faults.send_verdict(lossy, &msg, &mut self.link_rng) {
                SendVerdict::Backpressured => {
                    // previous packet unacked: paper semantics — discard,
                    // and tell the sender (it decided not to send)
                    self.stats.msgs_backpressured += 1;
                    let from = msg.from;
                    self.nodes[from].on_send_failed(msg);
                    continue;
                }
                SendVerdict::Lost => {
                    self.stats.msgs_lost += 1;
                    let from = msg.from;
                    self.nodes[from].on_send_failed(msg);
                    continue;
                }
                SendVerdict::Deliver => {}
            }
            // bandwidth caps: payload-proportional serialization delay,
            // FIFO per directed link — concurrent sends queue behind each
            // other so the configured byte rate is a real throughput
            // bound for every algorithm (for loss-tolerant ones the
            // one-unacked-packet channel already throttles on top)
            let bytes = FaultSpec::payload_bytes(&msg);
            self.stats.bytes_sent += bytes as u64;
            let bw_delay =
                self.faults.spec.bandwidth_delay(msg.from, msg.to, bytes);
            let sent_at = if bw_delay > 0.0 {
                self.stats.msgs_paced += 1;
                match self.faults.link_id(msg.from, msg.to) {
                    Some(l) => self.bw.sent_at(l, self.time, bw_delay),
                    None => {
                        // a routed message always travels an indexed
                        // link; fall back to plain serialization delay
                        debug_assert!(false, "unindexed link {} -> {}",
                                      msg.from, msg.to);
                        self.time + bw_delay
                    }
                }
            } else {
                self.time
            };
            let at = sent_at + self.latency();
            self.push_event(at, Event::Deliver(msg));
        }
    }

    fn record_train_loss(&mut self, node: usize, loss: Option<f32>) {
        if let Some(l) = loss {
            self.stats.grad_wakes += 1;
            self.steps_per_node[node] += 1;
            self.epoch += self.set.epoch_per_node_batch;
            if let Some((interval, factor)) = self.cfg.gamma_decay {
                let due = (self.epoch / interval) as u32;
                if due > self.decay_steps {
                    self.decay_steps = due;
                    let g = self.cfg.gamma * factor.powi(due as i32);
                    for nd in self.nodes.iter_mut() {
                        nd.set_gamma(g);
                    }
                }
            }
            self.train_loss_acc.0 += l as f64;
            self.train_loss_acc.1 += 1;
        } else {
            self.stats.comm_wakes += 1;
        }
    }

    fn eval_now(&mut self, report: &mut Report) -> f64 {
        mean_param(&self.nodes, &mut self.mean_buf);
        let e = (self.set.eval)(&self.mean_buf);
        report
            .series_mut("loss_vs_time", "virtual_seconds", "eval_loss")
            .push(self.time, e.loss);
        report
            .series_mut("loss_vs_epoch", "epoch", "eval_loss")
            .push(self.epoch, e.loss);
        if let Some(acc) = e.accuracy {
            report
                .series_mut("acc_vs_time", "virtual_seconds", "accuracy")
                .push(self.time, acc);
            report
                .series_mut("acc_vs_epoch", "epoch", "accuracy")
                .push(self.epoch, acc);
        }
        if self.train_loss_acc.1 > 0 {
            let avg = self.train_loss_acc.0 / self.train_loss_acc.1 as f64;
            report
                .series_mut("train_loss_vs_epoch", "epoch", "train_loss")
                .push(self.epoch, avg);
            self.train_loss_acc = (0.0, 0);
        }
        if let Some(opt) = &self.set.optimum {
            let gap = crate::linalg::dist(&self.mean_buf, opt);
            report
                .series_mut("gap_vs_time", "virtual_seconds", "optimality_gap")
                .push(self.time, gap);
        }
        e.loss
    }

    /// Run until the stop rule fires; returns the report (evaluations,
    /// counters, final optimality gap when the oracle has a closed form).
    ///
    /// Takes the engine-agnostic [`Stop`]; `Stop::Time` means seconds of
    /// *virtual* time here. Legacy [`StopRule`] values convert
    /// transparently. (Prefer driving whole runs through
    /// [`exp::Experiment`](crate::exp::Experiment) — it owns workload
    /// construction and returns unified stats for both engines.)
    pub fn run(&mut self, stop: impl Into<Stop>) -> Report {
        let stop: Stop = stop.into();
        let mut report = Report::new(self.algo.name());
        // kick off: every node attempts its first iteration at t=0
        for i in 0..self.n {
            self.try_start(i);
        }
        self.push_event(self.cfg.eval_every, Event::EvalTick);
        self.eval_now(&mut report);

        let mut outbox: Vec<Msg> = Vec::with_capacity(16);
        let mut replies: Vec<Msg> = Vec::with_capacity(4);
        let mut done = false;
        while !done {
            let Some((Key(at, _), idx)) = self.queue.pop() else {
                // drained queue: sync deadlock would land here
                report.set_scalar("drained_early", 1.0);
                break;
            };
            self.time = at;
            self.faults.clock.advance_to(at);
            // lint:allow(panic-path): queue index points at a live slot by construction; firing twice is a real bug
            let ev = self.events[idx].take().expect("event consumed twice");
            self.free_slots.push(idx);
            match ev {
                Event::NodeFinish(i) => {
                    self.busy[i] = false;
                    let loss =
                        self.nodes[i].wake(self.set.nodes[i].as_mut(), &mut outbox);
                    self.record_train_loss(i, loss);
                    self.route(&mut outbox);
                    self.try_start(i);
                    match stop {
                        Stop::Iterations(max) => {
                            if self.stats.grad_wakes >= max {
                                done = true;
                            }
                        }
                        Stop::Epochs(e) => {
                            if self.epoch >= e {
                                done = true;
                            }
                        }
                        _ => {}
                    }
                }
                Event::Deliver(msg) => {
                    self.stats.msgs_delivered += 1;
                    let (from, to, chan) = (msg.from, msg.to, msg.kind.chan());
                    self.nodes[to].receive(msg, &mut replies);
                    // ack travels back; channel frees on arrival
                    if self.algo.tolerates_loss() {
                        let ack_at = self.time + self.latency();
                        self.push_event(ack_at, Event::Ack { from, to, chan });
                    }
                    // protocol replies (AD-PSGD leg) go through the link layer
                    if !replies.is_empty() {
                        outbox.append(&mut replies);
                        self.route(&mut outbox);
                    }
                    self.try_start(to);
                }
                Event::Ack { from, to, chan } => {
                    self.faults.ack(from, to, chan);
                    // freed channel doesn't wake anyone by itself
                }
                Event::Resume(i) => {
                    self.resume_scheduled[i] = false;
                    // chained/overlapping pause windows re-arm in try_start
                    self.try_start(i);
                }
                Event::EvalTick => {
                    let loss = self.eval_now(&mut report);
                    let next = self.time + self.cfg.eval_every;
                    self.push_event(next, Event::EvalTick);
                    match stop {
                        Stop::TargetLoss { loss: target, max_time } => {
                            if loss <= target || self.time >= max_time {
                                done = true;
                            }
                        }
                        Stop::Time(t) => {
                            if self.time >= t {
                                done = true;
                            }
                        }
                        Stop::Iterations(_) | Stop::Epochs(_) => {}
                    }
                }
            }
        }
        self.stats.virtual_time = self.time;
        self.eval_now(&mut report);
        self.finalize_report(&mut report);
        report
    }

    fn finalize_report(&mut self, report: &mut Report) {
        let s = &self.stats;
        report.set_scalar("virtual_time", s.virtual_time);
        report.set_scalar("grad_wakes", s.grad_wakes as f64);
        report.set_scalar("comm_wakes", s.comm_wakes as f64);
        report.set_scalar("msgs_sent", s.msgs_sent as f64);
        report.set_scalar("msgs_delivered", s.msgs_delivered as f64);
        report.set_scalar("msgs_lost", s.msgs_lost as f64);
        report.set_scalar("msgs_backpressured", s.msgs_backpressured as f64);
        report.set_scalar("msgs_paced", s.msgs_paced as f64);
        report.set_scalar("bytes_sent", s.bytes_sent as f64);
        report.set_scalar("epoch", self.epoch);
        if let Some(opt) = &self.set.optimum {
            mean_param(&self.nodes, &mut self.mean_buf);
            report.final_gap = Some(crate::linalg::dist(&self.mean_buf, opt));
        }
    }

    pub fn stats(&self) -> SimStats {
        self.stats
    }

    /// Gradient steps per node so far (sums to `stats().grad_wakes`) —
    /// the simulator half of the unified `steps_per_node` stat.
    pub fn steps_per_node(&self) -> &[u64] {
        &self.steps_per_node
    }

    pub fn nodes(&self) -> &[Box<dyn NodeState>] {
        &self.nodes
    }

    pub fn virtual_time(&self) -> f64 {
        self.time
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::{GradOracle, QuadraticOracle};

    fn quad_set(n: usize, seed: u64) -> (OracleSet, Vec<f32>) {
        let q = QuadraticOracle::heterogeneous(8, n, 0.5, 2.0, seed);
        let xs = q.optimum();
        (q.into_set(), xs)
    }

    fn fast_cfg(seed: u64) -> SimConfig {
        SimConfig {
            seed,
            gamma: 0.04,
            compute_mean: 0.01,
            compute_jitter: 0.3,
            link_latency: 0.002,
            latency_jitter: 0.3,
            latency_cap: 0.05,
            eval_every: 1.0,
            ..SimConfig::default()
        }
    }

    #[test]
    fn rfast_converges_under_full_asynchrony() {
        let topo = Topology::binary_tree(7);
        let (set, xs) = quad_set(7, 3);
        let mut sim = Simulator::new(fast_cfg(1), &topo, AlgoKind::RFast, set);
        let report = sim.run(Stop::Iterations(40_000));
        let gap = report.final_gap.unwrap();
        assert!(gap < 1e-2, "gap {gap}");
        let _ = xs;
    }

    #[test]
    fn rfast_converges_with_packet_loss() {
        let topo = Topology::ring(5);
        let (set, _) = quad_set(5, 7);
        let mut cfg = fast_cfg(2);
        cfg.loss_prob = 0.25;
        let mut sim = Simulator::new(cfg, &topo, AlgoKind::RFast, set);
        let report = sim.run(Stop::Iterations(40_000));
        assert!(sim.stats().msgs_lost > 100, "loss emulation active");
        let gap = report.final_gap.unwrap();
        assert!(gap < 2e-2, "gap {gap} under 25% loss");
    }

    #[test]
    fn sync_algorithms_progress_without_deadlock() {
        for algo in [AlgoKind::PushPull, AlgoKind::SAb, AlgoKind::DPsgd,
                     AlgoKind::RingAllReduce] {
            let topo = Topology::ring(4);
            let (set, _) = quad_set(4, 11);
            let mut sim = Simulator::new(fast_cfg(3), &topo, algo, set);
            let report = sim.run(Stop::Iterations(2_000));
            assert!(report.scalars.get("drained_early").is_none(),
                    "{} drained", algo.name());
            assert!(sim.stats().grad_wakes >= 2_000, "{}", algo.name());
        }
    }

    #[test]
    fn deterministic_same_seed() {
        let mk = || {
            let topo = Topology::ring(4);
            let (set, _) = quad_set(4, 5);
            let mut sim =
                Simulator::new(fast_cfg(9), &topo, AlgoKind::RFast, set);
            let r = sim.run(Stop::Iterations(3_000));
            (r.final_gap.unwrap(), sim.stats().msgs_sent,
             sim.virtual_time())
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.0, b.0);
        assert_eq!(a.1, b.1);
        assert_eq!(a.2, b.2);
    }

    #[test]
    fn straggler_slows_sync_more_than_async() {
        let run = |algo: AlgoKind, straggler: Option<(usize, f64)>| -> f64 {
            let topo = Topology::ring(4);
            let (set, _) = quad_set(4, 13);
            let mut cfg = fast_cfg(4);
            cfg.straggler = straggler;
            let mut sim = Simulator::new(cfg, &topo, algo, set);
            sim.run(Stop::Iterations(4_000));
            sim.stats().virtual_time
        };
        let sync_clean = run(AlgoKind::RingAllReduce, None);
        let sync_slow = run(AlgoKind::RingAllReduce, Some((1, 5.0)));
        let async_clean = run(AlgoKind::RFast, None);
        let async_slow = run(AlgoKind::RFast, Some((1, 5.0)));
        let sync_ratio = sync_slow / sync_clean;
        let async_ratio = async_slow / async_clean;
        assert!(
            sync_ratio > 2.0,
            "ring-allreduce should stall on straggler: {sync_ratio}"
        );
        assert!(
            async_ratio < 1.6,
            "rfast should barely notice the straggler: {async_ratio}"
        );
    }

    #[test]
    fn backpressure_counts_under_ack_limit() {
        let topo = Topology::ring(3);
        let (set, _) = quad_set(3, 17);
        let mut cfg = fast_cfg(5);
        // latency >> compute: every wake's send finds the link busy
        cfg.link_latency = 0.2;
        cfg.latency_cap = 0.4;
        cfg.compute_mean = 0.001;
        let mut sim = Simulator::new(cfg, &topo, AlgoKind::RFast, set);
        sim.run(Stop::Iterations(2_000));
        assert!(sim.stats().msgs_backpressured > 0);
    }

    #[test]
    fn gamma_decay_schedule_applies() {
        // with an aggressive decay the steady-state gap under gradient
        // noise must shrink vs constant gamma (variance ∝ γ)
        let run = |decay: Option<(f64, f32)>| -> f64 {
            let topo = Topology::ring(4);
            let q = crate::oracle::QuadraticOracle::noisy(8, 4, 0.5, 21);
            let mut cfg = fast_cfg(8);
            cfg.gamma = 0.05;
            cfg.gamma_decay = decay;
            let mut sim = Simulator::new(cfg, &topo, AlgoKind::RFast,
                                         q.into_set());
            sim.run(Stop::Iterations(30_000)).final_gap.unwrap()
        };
        let constant = run(None);
        let decayed = run(Some((5_000.0, 0.5))); // quadratic epoch == 1 per wake
        assert!(
            decayed < constant * 0.7,
            "decay should cut the noise floor: {constant} vs {decayed}"
        );
    }

    #[test]
    fn eval_series_are_recorded() {
        let topo = Topology::ring(3);
        let (set, _) = quad_set(3, 19);
        let mut sim = Simulator::new(fast_cfg(6), &topo, AlgoKind::RFast, set);
        let report = sim.run(Stop::Time(20.0));
        let s = &report.series["loss_vs_time"];
        assert!(s.points.len() >= 10);
        assert!(report.series.contains_key("gap_vs_time"));
        // loss should broadly decrease
        let first = s.points.first().unwrap().1;
        let last = s.points.last().unwrap().1;
        assert!(last < first, "{first} → {last}");
    }
}
