//! Calendar-queue event scheduler (DESIGN.md §13).
//!
//! Replaces the simulator's single `BinaryHeap`: O(1)-amortized
//! push/pop against the near-sorted insert pattern a discrete-event
//! loop produces, instead of O(log m) on a heap whose size scales with
//! node count. Events hash into `nbuckets` day-wide buckets by
//! ⌊t/width⌋; each bucket is a tiny binary heap ordered by
//! `(day, Key)`.
//!
//! **Ordering is bitwise-compatible with the old global heap.** The
//! argument (§13 has the long form):
//!
//! 1. `day_of(t)` is monotone non-decreasing under `f64::total_cmp`
//!    for every non-NaN time (negatives and −0.0 saturate to day 0,
//!    +∞ to `u64::MAX`), so smaller times never land on later days.
//! 2. Pushes clamp the day to the current day, and the current day
//!    never exceeds any stored entry's day; so for coexisting entries,
//!    `Key(e1) < Key(e2)` implies `day(e1) ≤ day(e2)` even when one of
//!    them was clamped.
//! 3. A pop takes the global `(day, Key)` minimum — the fast path pops
//!    the current-day bucket (all current-day entries live there); the
//!    jump path scans every bucket's heap minimum. By (2) that entry
//!    is also the global `Key` minimum.
//! 4. `width`/`nbuckets` adaptation happens only at deterministic
//!    rebuild points driven by push/pop counts and popped times, so it
//!    affects *cost*, never order — and every seeded run replays the
//!    exact same rebuild sequence.
//!
//! Keys carry a unique sequence number, so the total order is strict
//! and bucket-heap tie-breaking can never be observed. NaN times are
//! rejected upstream (`Simulator::push_event` debug-asserts finite).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Min-heap key: (time, seq) — deterministic tie-break. Times are
/// compared with `f64::total_cmp` so the ordering is total even for the
/// values `push_event` debug-rejects (a NaN event time must fail loudly
/// in tests, not silently scramble the queue).
#[derive(Clone, Copy, Debug)]
pub struct Key(pub f64, pub u64);
impl PartialEq for Key {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for Key {}
impl PartialOrd for Key {
    // lint:allow(float-ord): delegates to the total order below (bit-keyed, NaN-free)
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Key {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0).then(self.1.cmp(&other.1))
    }
}

/// A scheduled event: bucket-day, key, and the event-slot index.
#[derive(Clone, Copy, Debug)]
struct Entry {
    day: u64,
    key: Key,
    idx: usize,
}
impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    // lint:allow(float-ord): delegates to the (day, Key) total order below
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // idx is deliberately NOT part of the order: keys are unique
        // (seq), so (day, key) is already a strict total order
        self.day.cmp(&other.day).then_with(|| self.key.cmp(&other.key))
    }
}

const MIN_BUCKETS: usize = 16;
/// Empty days to step through before giving up and jump-scanning all
/// bucket minima (sparse schedules would otherwise spin day by day).
const PROBE_DAYS: u32 = 8;
/// Initial bucket width in virtual seconds — resized adaptively, and by
/// the ordering argument above the value only matters for performance.
const INITIAL_WIDTH: f64 = 0.01;

/// Bucket day of time `t`: ⌊t/width⌋ with saturating conversion
/// (negatives/−0.0 → 0, +∞ → `u64::MAX`), monotone under `total_cmp`
/// for all non-NaN t.
#[inline]
fn day_of(t: f64, width: f64) -> u64 {
    (t / width).floor() as u64
}

pub struct CalendarQueue {
    buckets: Vec<BinaryHeap<Reverse<Entry>>>,
    /// `buckets.len() - 1`; bucket count is always a power of two.
    mask: u64,
    cur_day: u64,
    width: f64,
    len: usize,
    /// EMA of inter-pop time deltas; sampled only at rebuilds to pick a
    /// width that spreads the live horizon over the buckets.
    ema_gap: f64,
    last_pop: f64,
}

impl CalendarQueue {
    pub fn new() -> CalendarQueue {
        CalendarQueue {
            buckets: (0..MIN_BUCKETS).map(|_| BinaryHeap::new()).collect(),
            mask: (MIN_BUCKETS - 1) as u64,
            cur_day: 0,
            width: INITIAL_WIDTH,
            len: 0,
            ema_gap: 0.0,
            last_pop: 0.0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn push(&mut self, key: Key, idx: usize) {
        // clamp: a time before the current day files under the current
        // day, where intra-bucket Key order still pops it first
        let day = day_of(key.0, self.width).max(self.cur_day);
        let b = (day & self.mask) as usize;
        self.buckets[b].push(Reverse(Entry { day, key, idx }));
        self.len += 1;
        if self.len > 2 * self.buckets.len() {
            self.rebuild(self.buckets.len() * 2);
        }
    }

    pub fn pop(&mut self) -> Option<(Key, usize)> {
        if self.len == 0 {
            return None;
        }
        let mut probes = PROBE_DAYS;
        loop {
            let b = (self.cur_day & self.mask) as usize;
            let hit = matches!(self.buckets[b].peek(),
                               Some(Reverse(e)) if e.day == self.cur_day);
            if hit {
                if let Some(Reverse(e)) = self.buckets[b].pop() {
                    self.len -= 1;
                    self.note_pop(e.key.0);
                    if self.len < self.buckets.len() / 8
                        && self.buckets.len() > MIN_BUCKETS
                    {
                        self.rebuild(self.buckets.len() / 2);
                    }
                    return Some((e.key, e.idx));
                }
            }
            if probes == 0 {
                // sparse horizon: jump straight to the earliest
                // (day, key) among the per-bucket minima
                let mut best: Option<Entry> = None;
                for h in &self.buckets {
                    if let Some(Reverse(e)) = h.peek() {
                        if best.map_or(true, |b| *e < b) {
                            best = Some(*e);
                        }
                    }
                }
                match best {
                    Some(e) => self.cur_day = e.day, // next loop pops it
                    None => return None,             // len desynced: treat as empty
                }
                probes = PROBE_DAYS;
                continue;
            }
            probes -= 1;
            self.cur_day = self.cur_day.saturating_add(1);
        }
    }

    fn note_pop(&mut self, t: f64) {
        let delta = t - self.last_pop;
        self.last_pop = t;
        if delta > 0.0 && delta.is_finite() {
            self.ema_gap = 0.75 * self.ema_gap + 0.25 * delta;
        }
    }

    /// Deterministic re-bucketing: new width from the pop-gap EMA, new
    /// day origin at the last popped time, every entry re-clamped.
    /// Order-neutral (module tests + tests/sparse_parity.rs hold this).
    fn rebuild(&mut self, nbuckets: usize) {
        if self.ema_gap > 0.0 && self.ema_gap.is_finite() {
            // aim for a few events per day at the observed pop rate
            self.width = self.ema_gap * 4.0;
        }
        self.cur_day = day_of(self.last_pop, self.width);
        self.mask = (nbuckets - 1) as u64;
        let old = std::mem::replace(
            &mut self.buckets,
            (0..nbuckets).map(|_| BinaryHeap::new()).collect(),
        );
        for heap in old {
            for Reverse(e) in heap {
                let day = day_of(e.key.0, self.width).max(self.cur_day);
                let b = (day & self.mask) as usize;
                self.buckets[b].push(Reverse(Entry { day, ..e }));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The old scheduler, verbatim: one global heap over (Key, idx).
    struct HeapModel {
        heap: BinaryHeap<Reverse<(Key, usize)>>,
    }
    impl HeapModel {
        fn new() -> HeapModel {
            HeapModel { heap: BinaryHeap::new() }
        }
        fn push(&mut self, key: Key, idx: usize) {
            self.heap.push(Reverse((key, idx)));
        }
        fn pop(&mut self) -> Option<(Key, usize)> {
            self.heap.pop().map(|Reverse(p)| p)
        }
    }

    enum Op {
        Push(f64),
        Pop,
    }

    /// Run the op script against both schedulers and require identical
    /// (time-bits, seq, idx) pop sequences, including the final drain.
    fn assert_drain_parity(ops: &[Op]) {
        let mut cq = CalendarQueue::new();
        let mut model = HeapModel::new();
        let mut seq = 0u64;
        let mut idx = 0usize;
        let mut pops = 0usize;
        for op in ops {
            match op {
                Op::Push(t) => {
                    seq += 1;
                    cq.push(Key(*t, seq), idx);
                    model.push(Key(*t, seq), idx);
                    idx += 1;
                }
                Op::Pop => {
                    let a = cq.pop();
                    let b = model.pop();
                    assert_popped_eq(a, b, pops);
                    pops += 1;
                }
            }
        }
        loop {
            let a = cq.pop();
            let b = model.pop();
            assert_popped_eq(a, b, pops);
            pops += 1;
            if b.is_none() {
                assert!(cq.is_empty());
                break;
            }
        }
    }

    fn assert_popped_eq(a: Option<(Key, usize)>, b: Option<(Key, usize)>, k: usize) {
        match (a, b) {
            (None, None) => {}
            (Some((ka, ia)), Some((kb, ib))) => {
                assert_eq!(ka.0.to_bits(), kb.0.to_bits(), "pop {k}: time bits");
                assert_eq!(ka.1, kb.1, "pop {k}: seq");
                assert_eq!(ia, ib, "pop {k}: idx");
            }
            (a, b) => panic!("pop {k}: calendar {a:?} vs heap {b:?}"),
        }
    }

    #[test]
    fn mass_same_tick_inserts_drain_in_seq_order() {
        // hundreds of events at identical timestamps: order must fall
        // back to seq exactly like the global heap
        let mut ops = Vec::new();
        for round in 0..6 {
            for _ in 0..128 {
                ops.push(Op::Push(round as f64 * 0.5));
            }
            ops.push(Op::Pop);
            ops.push(Op::Pop);
        }
        assert_drain_parity(&ops);
    }

    #[test]
    fn total_cmp_boundary_values_order_identically() {
        // the adversarial corners of the total_cmp order the old heap
        // relied on: signed zeros, subnormals, extremes, infinities
        let ts = [
            0.0,
            -0.0,
            f64::MIN_POSITIVE,
            f64::MIN_POSITIVE / 4.0, // subnormal
            -f64::MIN_POSITIVE,
            1e-300,
            -1e-300,
            1e300,
            f64::MAX,
            f64::INFINITY,
            f64::NEG_INFINITY,
            1.0,
            1.0 + f64::EPSILON,
            -1.0,
        ];
        let mut ops: Vec<Op> = ts.iter().map(|&t| Op::Push(t)).collect();
        ops.push(Op::Pop);
        ops.push(Op::Pop);
        // interleave more pushes after partial drain (times in the past
        // relative to popped -∞/−1.0 exercise the clamp path)
        ops.extend(ts.iter().map(|&t| Op::Push(t * 0.5)));
        assert_drain_parity(&ops);
    }

    #[test]
    fn insert_during_drain_including_past_times() {
        // a sim pushes while popping, sometimes at times before the
        // current head (zero-latency acks): clamped entries must still
        // pop in Key order
        let mut ops = Vec::new();
        for i in 0..200 {
            ops.push(Op::Push(i as f64 * 0.01));
        }
        for i in 0..150 {
            ops.push(Op::Pop);
            if i % 3 == 0 {
                ops.push(Op::Push(i as f64 * 0.003)); // usually in the past
            }
            if i % 7 == 0 {
                ops.push(Op::Push(2.0 + i as f64 * 0.05));
            }
        }
        assert_drain_parity(&ops);
    }

    #[test]
    fn growth_and_shrink_rebuilds_preserve_order() {
        // push far past the grow threshold, then drain to force the
        // shrink rebuild; widths change, order must not
        let mut ops = Vec::new();
        for i in 0..1500 {
            // lumpy spacing so the EMA actually moves between rebuilds
            let t = (i / 100) as f64 + (i % 100) as f64 * 1e-4;
            ops.push(Op::Push(t));
        }
        for _ in 0..1400 {
            ops.push(Op::Pop);
        }
        for i in 0..64 {
            ops.push(Op::Push(100.0 + i as f64 * 3.0)); // sparse tail
        }
        assert_drain_parity(&ops);
    }

    #[test]
    fn sparse_horizon_exercises_jump_scan() {
        // gaps far wider than PROBE_DAYS × width force the jump path
        let mut ops = Vec::new();
        for i in 0..40 {
            ops.push(Op::Push(i as f64 * 1e4));
            ops.push(Op::Push(i as f64 * 1e4)); // same-tick pair
        }
        for _ in 0..30 {
            ops.push(Op::Pop);
        }
        ops.push(Op::Push(5.0)); // past, clamps
        assert_drain_parity(&ops);
    }

    #[test]
    fn pseudorandom_stress_against_model() {
        let mut rng = crate::prng::Rng::stream(42, 0x5c4ed);
        let mut ops = Vec::new();
        let mut live = 0i64;
        for _ in 0..5000 {
            if live > 0 && rng.below(3) == 0 {
                ops.push(Op::Pop);
                live -= 1;
            } else {
                // mixture of scales, exact ties, and integer times
                let t = match rng.below(4) {
                    0 => rng.f64() * 1e-3,
                    1 => rng.f64() * 1e3,
                    2 => rng.below(50) as f64,
                    _ => 7.25,
                };
                ops.push(Op::Push(t));
                live += 1;
            }
        }
        assert_drain_parity(&ops);
    }

    #[test]
    fn empty_queue_pops_none() {
        let mut cq = CalendarQueue::new();
        assert!(cq.pop().is_none());
        cq.push(Key(1.0, 1), 0);
        assert_eq!(cq.len(), 1);
        assert!(cq.pop().is_some());
        assert!(cq.pop().is_none());
        assert!(cq.is_empty());
    }
}
