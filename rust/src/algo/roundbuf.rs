//! Per-round message buffering for the synchronous baselines.
//!
//! A synchronous node at round `t` must combine exactly the round-`t`
//! payloads of each in-neighbor. Links may deliver out of order (latency
//! jitter), so arrivals are keyed by (peer, stamp); `has_all(t)` is the
//! barrier predicate behind [`super::NodeState::ready`]. Buffered entries
//! hold the messages' shared [`Payload`]s — buffering a broadcast round
//! costs refcount bumps, not deep copies.

use super::Payload;
use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct RoundBuf {
    peers: Vec<usize>,
    per: Vec<BTreeMap<u64, Payload>>,
}

impl RoundBuf {
    pub fn new(peers: Vec<usize>) -> RoundBuf {
        let per = peers.iter().map(|_| BTreeMap::new()).collect();
        RoundBuf { peers, per }
    }

    pub fn peers(&self) -> &[usize] {
        &self.peers
    }

    /// Store a payload; returns false if `from` is not a tracked peer.
    pub fn insert(&mut self, from: usize, stamp: u64,
                  payload: impl Into<Payload>) -> bool {
        match self.peers.iter().position(|&p| p == from) {
            Some(k) => {
                self.per[k].insert(stamp, payload.into());
                true
            }
            None => false,
        }
    }

    /// Have all peers delivered round `stamp`?
    pub fn has_all(&self, stamp: u64) -> bool {
        self.per.iter().all(|m| m.contains_key(&stamp))
    }

    /// Remove and return peer `k`'s round-`stamp` payload (panics if
    /// absent — callers must check `has_all` first).
    pub fn take(&mut self, k: usize, stamp: u64) -> Payload {
        self.per[k]
            .remove(&stamp)
            // lint:allow(panic-path): documented contract — callers must check has_all first
            .unwrap_or_else(|| panic!("round {stamp} payload missing for peer index {k}"))
    }

    /// Drop all rounds `< stamp` (bounded memory under jitter).
    pub fn gc_before(&mut self, stamp: u64) {
        for m in self.per.iter_mut() {
            *m = m.split_off(&stamp);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn barrier_semantics() {
        let mut b = RoundBuf::new(vec![3, 5]);
        assert!(!b.has_all(0));
        assert!(b.insert(3, 0, vec![1.0]));
        assert!(!b.has_all(0));
        assert!(b.insert(5, 0, vec![2.0]));
        assert!(b.has_all(0));
        assert!(!b.insert(9, 0, vec![0.0])); // unknown peer
    }

    #[test]
    fn out_of_order_rounds() {
        let mut b = RoundBuf::new(vec![1]);
        b.insert(1, 2, vec![2.0]);
        b.insert(1, 1, vec![1.0]);
        assert!(b.has_all(1));
        assert!(b.has_all(2));
        assert_eq!(b.take(0, 1), vec![1.0]);
        assert!(!b.has_all(1));
        assert!(b.has_all(2));
    }

    #[test]
    fn gc_drops_old() {
        let mut b = RoundBuf::new(vec![1]);
        b.insert(1, 0, vec![0.0]);
        b.insert(1, 5, vec![5.0]);
        b.gc_before(3);
        assert!(!b.has_all(0));
        assert!(b.has_all(5));
    }
}
