//! AD-PSGD (Lian et al. 2018): asynchronous decentralized parallel SGD.
//! On each wake a worker takes a local SGD step and *pairwise-averages*
//! its model with one randomly chosen undirected-ring neighbor.
//!
//! The original algorithm assumes an atomic averaging transaction between
//! the pair. Over a real message channel that atomicity is impossible, so
//! we implement the standard two-leg approximation (documented deviation,
//! DESIGN.md §4): the initiator sends its x; the responder averages on
//! receipt and replies with its *pre-mix* x; the initiator averages with
//! that. Under delays the two halves use slightly different snapshots —
//! exactly the staleness AD-PSGD's analysis tolerates. There is **no
//! gradient tracking and no running-sum robustness**: a dropped message
//! simply skips a mixing opportunity, and heterogeneity biases the fixed
//! point — both visible in the ablation benches.

use super::{Msg, MsgKind, NodeState, Payload};
use crate::oracle::NodeOracle;
use crate::prng::Rng;

pub fn build(n: usize, x0: &[f32], gamma: f32, seed: u64) -> Vec<Box<dyn NodeState>> {
    (0..n)
        .map(|i| Box::new(AdPsgdNode::new(i, n, x0, gamma, seed)) as Box<dyn NodeState>)
        .collect()
}

pub struct AdPsgdNode {
    id: usize,
    gamma: f32,
    t: u64,
    x: Vec<f32>,
    g: Vec<f32>,
    neighbors: Vec<usize>,
    rng: Rng,
}

impl AdPsgdNode {
    pub fn new(id: usize, n: usize, x0: &[f32], gamma: f32, seed: u64) -> AdPsgdNode {
        let neighbors: Vec<usize> = if n == 1 {
            vec![]
        } else if n == 2 {
            vec![1 - id]
        } else {
            vec![(id + n - 1) % n, (id + 1) % n]
        };
        AdPsgdNode {
            id,
            gamma,
            t: 0,
            x: x0.to_vec(),
            g: vec![0.0; x0.len()],
            neighbors,
            rng: Rng::stream(seed, 0xadb00 + id as u64),
        }
    }
}

impl NodeState for AdPsgdNode {
    fn ready(&self) -> bool {
        true // fully asynchronous
    }

    fn wake(&mut self, oracle: &mut dyn NodeOracle, out: &mut Vec<Msg>)
            -> Option<f32> {
        // local step at the (possibly stale-mixed) iterate
        let loss = oracle.grad(&self.x, &mut self.g);
        crate::linalg::axpy(&mut self.x, -self.gamma, &self.g);
        // initiate a pairwise average with one random neighbor
        if !self.neighbors.is_empty() {
            let j = self.neighbors[self.rng.below(self.neighbors.len())];
            out.push(Msg::new(self.id, j, MsgKind::X, self.t,
                              Payload::from_slice(&self.x)));
        }
        self.t += 1;
        Some(loss)
    }

    fn receive(&mut self, msg: Msg, out: &mut Vec<Msg>) {
        match msg.kind {
            MsgKind::X => {
                // responder leg: reply with pre-mix x, then average
                out.push(Msg::new(self.id, msg.from, MsgKind::XReply,
                                  msg.stamp, Payload::from_slice(&self.x)));
                average_into(&mut self.x, &msg.payload);
            }
            MsgKind::XReply => {
                // initiator leg
                average_into(&mut self.x, &msg.payload);
            }
            _ => {}
        }
    }

    fn set_gamma(&mut self, gamma: f32) {
        self.gamma = gamma;
    }

    fn param(&self) -> &[f32] {
        &self.x
    }

    fn local_iter(&self) -> u64 {
        self.t
    }
}

fn average_into(x: &mut [f32], other: &[f32]) {
    for (xi, oi) in x.iter_mut().zip(other) {
        *xi = 0.5 * (*xi + *oi);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::{GradOracle, QuadraticOracle};

    #[test]
    fn converges_homogeneous_random_activation() {
        let q = QuadraticOracle::new(6, 4, 0.5, 2.0, 0.0, 0.0, 3);
        let xs = q.optimum();
        let mut set = q.into_set();
        let mut nodes = build(4, &vec![0.0; 6], 0.05, 1);
        let mut rng = Rng::new(7);
        let mut out = Vec::new();
        let mut replies = Vec::new();
        for _ in 0..8000 {
            let i = rng.below(4);
            nodes[i].wake(set.nodes[i].as_mut(), &mut out);
            // deliver immediately (incl. reply legs)
            while let Some(m) = out.pop() {
                let to = m.to;
                nodes[to].receive(m, &mut replies);
                out.append(&mut replies);
            }
        }
        for nd in &nodes {
            let gap = crate::linalg::dist(nd.param(), &xs);
            assert!(gap < 5e-2, "gap {gap}");
        }
    }

    #[test]
    fn exchange_emits_reply() {
        let mut a = AdPsgdNode::new(0, 3, &[1.0, 1.0], 0.1, 1);
        let mut out = Vec::new();
        a.receive(Msg::new(1, 0, MsgKind::X, 4, vec![3.0, 3.0]), &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].kind, MsgKind::XReply);
        assert_eq!(out[0].to, 1);
        // a averaged: (1+3)/2 = 2
        assert_eq!(a.param(), &[2.0, 2.0]);
        // reply carries the PRE-mix value
        assert_eq!(out[0].payload, vec![1.0, 1.0]);
    }

    #[test]
    fn pairwise_average_preserves_sum() {
        let mut a = AdPsgdNode::new(0, 3, &[0.0, 4.0], 0.1, 1);
        let mut b = AdPsgdNode::new(1, 3, &[2.0, 0.0], 0.1, 2);
        let mut out = Vec::new();
        // simulate a full exchange with no interleaving
        let x_a = a.param().to_vec();
        b.receive(Msg::new(0, 1, MsgKind::X, 1, x_a), &mut out);
        let reply = out.pop().unwrap();
        a.receive(reply, &mut out);
        let sum0: f32 = a.param().iter().sum::<f32>() + b.param().iter().sum::<f32>();
        assert!((sum0 - 6.0).abs() < 1e-6);
        assert_eq!(a.param(), b.param());
    }
}
