//! S-AB (Xin, Sahu, Khan, Kar 2019): synchronous stochastic gradient
//! tracking over strongly-connected digraphs with a row-stochastic A and a
//! column-stochastic B:
//!
//!   x_i^{t+1} = Σ_j a_ij x_j^t − γ y_i^t
//!   y_i^{t+1} = Σ_j b_ij y_j^t + ∇f_i(x_i^{t+1};ζ^{t+1}) − ∇f_i(x_i^t;ζ^t)
//!
//! We reuse the topology's W as the row-stochastic A and its A as the
//! column-stochastic B (they coincide structurally on the directed ring the
//! paper benches S-AB on). Unlike Push-Pull/R-FAST, S-AB *requires* both
//! graphs strongly connected — running it on a tree violates its theory,
//! which `sim` tests demonstrate empirically.

use super::roundbuf::RoundBuf;
use super::{Msg, MsgKind, NodeState, Payload};
use crate::graph::Topology;
use crate::oracle::NodeOracle;

pub fn build(topo: &Topology, x0: &[f32], gamma: f32) -> Vec<Box<dyn NodeState>> {
    (0..topo.n())
        .map(|i| Box::new(SabNode::new(i, topo, x0, gamma)) as Box<dyn NodeState>)
        .collect()
}

pub struct SabNode {
    id: usize,
    gamma: f32,
    t: u64,
    a_ii: f32,
    a_in_weights: Vec<f32>,
    a_out_nodes: Vec<usize>,
    b_ii: f32,
    b_out: Vec<(usize, f32)>,
    x: Vec<f32>,
    y: Vec<f32>,
    g_prev: Vec<f32>,
    g_new: Vec<f32>,
    /// staging buffer for the per-receiver b_ji·y payloads
    scratch: Vec<f32>,
    xbuf: RoundBuf,
    ybuf: RoundBuf,
    initialized: bool,
}

impl SabNode {
    pub fn new(id: usize, topo: &Topology, x0: &[f32], gamma: f32) -> SabNode {
        let wm = &topo.weights;
        let p = x0.len();
        SabNode {
            id,
            gamma,
            t: 0,
            a_ii: wm.w.get(id, id),
            a_in_weights: wm.w_in[id].iter().map(|&j| wm.w.get(id, j)).collect(),
            a_out_nodes: wm.w_out[id].clone(),
            b_ii: wm.a.get(id, id),
            b_out: wm.a_out[id].iter().map(|&j| (j, wm.a.get(j, id))).collect(),
            x: x0.to_vec(),
            y: vec![0.0; p],
            g_prev: vec![0.0; p],
            g_new: vec![0.0; p],
            scratch: vec![0.0; p],
            xbuf: RoundBuf::new(wm.w_in[id].clone()),
            ybuf: RoundBuf::new(wm.a_in[id].clone()),
            initialized: false,
        }
    }

    fn send_round(&mut self, out: &mut Vec<Msg>) {
        // x broadcast: one shared allocation for every A-out-neighbor
        if !self.a_out_nodes.is_empty() {
            let x = Payload::from_slice(&self.x);
            for &j in &self.a_out_nodes {
                out.push(Msg::new(self.id, j, MsgKind::X, self.t, x.clone()));
            }
        }
        // b_ji-weighted y per receiver (contents differ, own allocation)
        for &(j, b_ji) in &self.b_out {
            crate::linalg::scale_into(&mut self.scratch, b_ji, &self.y);
            out.push(Msg::new(self.id, j, MsgKind::ZDelta, self.t,
                              Payload::from_slice(&self.scratch)));
        }
    }
}

impl NodeState for SabNode {
    fn ready(&self) -> bool {
        if !self.initialized {
            return true;
        }
        let prev = self.t - 1;
        self.xbuf.has_all(prev) && self.ybuf.has_all(prev)
    }

    fn wake(&mut self, oracle: &mut dyn NodeOracle, out: &mut Vec<Msg>)
            -> Option<f32> {
        if !self.initialized {
            let loss = oracle.grad(&self.x, &mut self.g_prev);
            self.y.copy_from_slice(&self.g_prev);
            self.initialized = true;
            self.send_round(out);
            self.t = 1;
            return Some(loss);
        }
        let prev = self.t - 1;
        // x ← A-mix(x) − γ y
        let mut x_new = vec![0.0f32; self.x.len()];
        crate::linalg::scale_into(&mut x_new, self.a_ii, &self.x);
        for k in 0..self.a_in_weights.len() {
            let xj = self.xbuf.take(k, prev);
            crate::linalg::axpy(&mut x_new, self.a_in_weights[k], &xj);
        }
        crate::linalg::axpy(&mut x_new, -self.gamma, &self.y);
        // y ← B-mix(y) + grad diff
        let mut y_new = vec![0.0f32; self.y.len()];
        crate::linalg::scale_into(&mut y_new, self.b_ii, &self.y);
        for k in 0..self.ybuf.peers().len() {
            let wy = self.ybuf.take(k, prev);
            crate::linalg::axpy(&mut y_new, 1.0, &wy);
        }
        let loss = oracle.grad(&x_new, &mut self.g_new);
        crate::linalg::add_diff(&mut y_new, &self.g_new, &self.g_prev);
        std::mem::swap(&mut self.g_prev, &mut self.g_new);

        self.x = x_new;
        self.y = y_new;
        self.send_round(out);
        self.t += 1;
        Some(loss)
    }

    fn receive(&mut self, msg: Msg, _out: &mut Vec<Msg>) {
        match msg.kind {
            MsgKind::X => {
                self.xbuf.insert(msg.from, msg.stamp, msg.payload);
            }
            MsgKind::ZDelta => {
                self.ybuf.insert(msg.from, msg.stamp, msg.payload);
            }
            _ => {}
        }
    }

    fn set_gamma(&mut self, gamma: f32) {
        self.gamma = gamma;
    }

    fn param(&self) -> &[f32] {
        &self.x
    }

    fn local_iter(&self) -> u64 {
        self.t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::{GradOracle, QuadraticOracle};

    #[test]
    fn converges_on_ring_quadratic() {
        let topo = Topology::ring(4);
        let q = QuadraticOracle::heterogeneous(6, 4, 0.5, 2.0, 31);
        let xs = q.optimum();
        let mut set = q.into_set();
        let mut nodes = build(&topo, &vec![0.2; 6], 0.04);
        let mut out = Vec::new();
        let mut replies = Vec::new();
        for _ in 0..4000 {
            for i in 0..nodes.len() {
                assert!(nodes[i].ready());
                nodes[i].wake(set.nodes[i].as_mut(), &mut out);
            }
            for msg in out.drain(..) {
                let to = msg.to;
                nodes[to].receive(msg, &mut replies);
            }
        }
        for nd in &nodes {
            let gap = crate::linalg::dist(nd.param(), &xs);
            assert!(gap < 2e-3, "gap {gap}");
        }
    }
}
