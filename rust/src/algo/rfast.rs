//! R-FAST (Algorithm 1 of the paper) — the core contribution.
//!
//! Per-node state, local view (the subscript i is this node):
//!
//! | paper | field | role |
//! |-------|-------|------|
//! | x_i^t | `x` | model estimate |
//! | z_i^t | `z` | tracked global-gradient estimate |
//! | v_i^{t+1} | `v_self` | post-descent intermediate |
//! | ∇f_i(x^t;ζ^t) | `g_prev` | last gradient sample (cleared out at S2b) |
//! | v_j^{τ_{v,ij}} | `v_in[j]` | freshest received v per W-in-neighbor |
//! | ρ_ij^{τ_{ρ,ij}} | `rho_in[j]` | freshest received running sum per A-in-neighbor |
//! | ρ̃_ij | `rho_tilde[j]` | last *consumed* running sum (buffer) |
//! | ρ_ji | `rho_out[j]` | running sum pushed to A-out-neighbor j |
//!
//! The robust part: ρ_ji accumulates `a_ji · z_i^{t+½}` forever, and the
//! receiver applies `ρ(latest) − ρ̃(consumed)`. A dropped ρ-packet is
//! subsumed by any later one, so packet loss delays — but never destroys —
//! gradient mass. The naive-GT ablation (`robust: false`) sends the
//! one-shot increment instead; a dropped packet then loses its mass
//! permanently, which is precisely what `benches/ablation_packet_loss.rs`
//! measures.
//!
//! Freshest-wins: every packet carries the sender's local iteration stamp
//! (S3); `receive` keeps the largest stamp per neighbor, which implements
//! the paper's τ_{v,ij} / τ_{ρ,ij} "most updated one" selection under
//! arbitrary reordering.

use super::{Msg, MsgKind, NodeState, Payload, Payload64};
use crate::graph::Topology;
use crate::oracle::NodeOracle;

/// Variant knobs (the ablation switch).
#[derive(Clone, Copy, Debug)]
pub struct RFastParams {
    /// `true` = paper's robust running-sum scheme; `false` = naive one-shot
    /// gradient-tracking increments.
    pub robust: bool,
}

impl Default for RFastParams {
    fn default() -> Self {
        RFastParams { robust: true }
    }
}

/// Build all node state machines for a topology.
pub fn build(topo: &Topology, x0: &[f32], gamma: f32,
             params: RFastParams) -> Vec<Box<dyn NodeState>> {
    (0..topo.n())
        .map(|i| {
            Box::new(RFastNode::new(i, topo, x0, gamma, params))
                as Box<dyn NodeState>
        })
        .collect()
}

/// Freshest-stamp buffer for one in-neighbor. Holds the shared payload
/// of the freshest message — a refcount bump, never a deep copy.
#[derive(Clone, Debug)]
struct Fresh {
    stamp: u64,
    data: Payload,
}

/// Freshest-stamp buffer for ρ (f64 — see `Msg::payload64`).
#[derive(Clone, Debug)]
struct Fresh64 {
    stamp: u64,
    data: Payload64,
}

pub struct RFastNode {
    id: usize,
    gamma: f32,
    params: RFastParams,
    t: u64,

    // mixing structure (weights resolved once at build time)
    w_ii: f32,
    /// (neighbor j, w_ij) for j ∈ N_i^in(W)
    w_in: Vec<(usize, f32)>,
    w_out: Vec<usize>,
    a_ii: f32,
    /// (neighbor j, a_ji) for j ∈ N_i^out(A)
    a_out: Vec<(usize, f32)>,
    a_in: Vec<usize>,

    // state vectors
    x: Vec<f32>,
    z: Vec<f32>,
    v_self: Vec<f32>,
    g_prev: Vec<f32>,
    g_new: Vec<f32>,
    z_half: Vec<f32>,

    /// freshest v per W-in-neighbor (parallel to `w_in`); paper init v⁰=0.
    v_in: Vec<Fresh>,
    /// freshest ρ per A-in-neighbor (parallel to `a_in`). f64: the
    /// running-sum difference ρ−ρ̃ cancels catastrophically in f32.
    rho_in: Vec<Fresh64>,
    /// consumed buffer ρ̃ per A-in-neighbor — an `Arc` alias of the
    /// ρ snapshot consumed at S4 (O(1) instead of a p-length memcpy;
    /// safe because payloads are immutable once received).
    rho_tilde: Vec<Payload64>,
    /// running sums ρ_ji per A-out-neighbor (parallel to `a_out`);
    /// in naive mode reused as the per-wake increment scratch.
    rho_out: Vec<Vec<f64>>,
    /// naive mode: accumulated received one-shot increments per A-in.
    pending_delta: Vec<f32>,

    initialized: bool,
}

impl RFastNode {
    pub fn new(id: usize, topo: &Topology, x0: &[f32], gamma: f32,
               params: RFastParams) -> RFastNode {
        let wm = &topo.weights;
        let p = x0.len();
        let w_in: Vec<(usize, f32)> =
            wm.w_in[id].iter().map(|&j| (j, wm.w.get(id, j))).collect();
        let a_out: Vec<(usize, f32)> =
            wm.a_out[id].iter().map(|&j| (j, wm.a.get(j, id))).collect();
        let a_in = wm.a_in[id].clone();
        RFastNode {
            id,
            gamma,
            params,
            t: 0,
            w_ii: wm.w.get(id, id),
            w_out: wm.w_out[id].clone(),
            a_ii: wm.a.get(id, id),
            a_in: a_in.clone(),
            x: x0.to_vec(),
            z: vec![0.0; p],
            v_self: vec![0.0; p],
            g_prev: vec![0.0; p],
            g_new: vec![0.0; p],
            z_half: vec![0.0; p],
            v_in: w_in
                .iter()
                .map(|_| Fresh { stamp: 0, data: Payload::zeros(p) })
                .collect(),
            rho_in: a_in
                .iter()
                .map(|_| Fresh64 { stamp: 0, data: Payload64::zeros(p) })
                .collect(),
            rho_tilde: a_in.iter().map(|_| Payload64::zeros(p)).collect(),
            rho_out: a_out.iter().map(|_| vec![0.0; p]).collect(),
            pending_delta: vec![0.0; p],
            w_in,
            a_out,
            initialized: false,
        }
    }

    /// Test/diagnostic access: current tracked gradient z_i.
    pub fn z(&self) -> &[f32] {
        &self.z
    }

    /// Test access: total un-consumed mass this node still owes the
    /// network view (for the conservation invariant): Σ_out ρ_ji minus
    /// what receivers have consumed lives on the *edges*; this exposes
    /// the sender-side running sums.
    pub fn rho_out_sums(&self) -> &[Vec<f64>] {
        &self.rho_out
    }

    pub fn rho_tilde_sums(&self) -> &[Payload64] {
        &self.rho_tilde
    }

    pub fn a_in_ids(&self) -> &[usize] {
        &self.a_in
    }

    pub fn a_out_ids(&self) -> Vec<usize> {
        self.a_out.iter().map(|&(j, _)| j).collect()
    }

    pub fn last_grad(&self) -> &[f32] {
        &self.g_prev
    }

    pub fn is_initialized(&self) -> bool {
        self.initialized
    }

    pub fn pending_delta_sum(&self) -> &[f32] {
        &self.pending_delta
    }
}

impl NodeState for RFastNode {
    fn ready(&self) -> bool {
        true // fully asynchronous: never blocks (paper §IV i)
    }

    fn wake(&mut self, oracle: &mut dyn NodeOracle, out: &mut Vec<Msg>)
            -> Option<f32> {
        let p = self.x.len();
        debug_assert_eq!(oracle.dim(), p);

        // Initialization (Algorithm 1 line 1): z_i^0 = ∇f_i(x_i^0; ζ_i^0).
        if !self.initialized {
            let _ = oracle.grad(&self.x, &mut self.g_prev);
            self.z.copy_from_slice(&self.g_prev);
            self.initialized = true;
        }

        // (S1) local descent: v^{t+1} = x^t − γ z^t
        self.v_self.copy_from_slice(&self.x);
        crate::linalg::axpy(&mut self.v_self, -self.gamma, &self.z);

        // (S2a) consensus pull: x^{t+1} = w_ii v^{t+1} + Σ w_ij v_j^{τ}
        {
            // reuse z_half as scratch for x_new to avoid allocation
            let x_new = &mut self.z_half;
            crate::linalg::scale_into(x_new, self.w_ii, &self.v_self);
            for (k, &(_, w_ij)) in self.w_in.iter().enumerate() {
                crate::linalg::axpy(x_new, w_ij, &self.v_in[k].data);
            }
            std::mem::swap(&mut self.x, &mut self.z_half);
        }

        // (S2b) z^{t+½} = z^t + Σ_j (ρ_ij^τ − ρ̃_ij) + ∇f(x^{t+1};ζ^{t+1}) − ∇f(x^t;ζ^t)
        self.z_half.copy_from_slice(&self.z);
        if self.params.robust {
            for k in 0..self.a_in.len() {
                // difference in f64, then cast: the whole point of the
                // f64 ρ pipeline (see Msg::payload64)
                for ((zh, riv), rtv) in self
                    .z_half
                    .iter_mut()
                    .zip(&self.rho_in[k].data)
                    .zip(&self.rho_tilde[k])
                {
                    *zh += (riv - rtv) as f32;
                }
            }
        } else {
            // naive GT: apply accumulated one-shot increments
            crate::linalg::axpy(&mut self.z_half, 1.0, &self.pending_delta);
            self.pending_delta.iter_mut().for_each(|v| *v = 0.0);
        }
        let loss = oracle.grad(&self.x, &mut self.g_new);
        crate::linalg::add_diff(&mut self.z_half, &self.g_new, &self.g_prev);
        std::mem::swap(&mut self.g_prev, &mut self.g_new);

        // (S2c) z^{t+1} = a_ii z^{t+½};  ρ_ji += a_ji z^{t+½}
        crate::linalg::scale_into(&mut self.z, self.a_ii, &self.z_half);
        for (k, &(_, a_ji)) in self.a_out.iter().enumerate() {
            if self.params.robust {
                for (r, &zh) in self.rho_out[k].iter_mut().zip(&self.z_half) {
                    *r += a_ji as f64 * zh as f64;
                }
            } else {
                // one-shot increment: overwrite the scratch with a_ji·z½
                for (r, &zh) in self.rho_out[k].iter_mut().zip(&self.z_half) {
                    *r = a_ji as f64 * zh as f64;
                }
            }
        }

        // (S3) sends, stamped t+1. The engine's link layer decides delay /
        // loss / in-flight limits; the algorithm just emits. The v
        // broadcast allocates ONCE; every W-out-neighbor's message shares
        // it (zero-copy fan-out). ρ payloads are per-neighbor by nature
        // (each edge has its own running sum), so those stay one
        // allocation per A-out-neighbor.
        let stamp = self.t + 1;
        if !self.w_out.is_empty() {
            let v = Payload::from_slice(&self.v_self);
            for &j in &self.w_out {
                out.push(Msg::new(self.id, j, MsgKind::V, stamp, v.clone()));
            }
        }
        for (k, &(j, _)) in self.a_out.iter().enumerate() {
            if self.params.robust {
                out.push(Msg::new64(self.id, j, MsgKind::Rho, stamp,
                                    Payload64::from_slice(&self.rho_out[k])));
            } else {
                let delta: Payload =
                    self.rho_out[k].iter().map(|&v| v as f32).collect();
                out.push(Msg::new(self.id, j, MsgKind::ZDelta, stamp, delta));
            }
        }

        // (S4) buffer update: ρ̃ ← ρ(consumed) — an Arc alias of the
        // snapshot just consumed at S2b, not a p-length copy (received
        // payloads are immutable, so aliasing is safe; a fresher ρ only
        // ever REPLACES rho_in's Arc in `receive`).
        if self.params.robust {
            for k in 0..self.a_in.len() {
                self.rho_tilde[k] = self.rho_in[k].data.clone();
            }
        }

        // (S5) t += 1
        self.t += 1;
        Some(loss)
    }

    fn receive(&mut self, msg: Msg, _out: &mut Vec<Msg>) {
        match msg.kind {
            MsgKind::V => {
                if let Some(k) =
                    self.w_in.iter().position(|&(j, _)| j == msg.from)
                {
                    // freshest-wins (τ_{v,ij} = largest stamp received)
                    if msg.stamp > self.v_in[k].stamp {
                        self.v_in[k].stamp = msg.stamp;
                        self.v_in[k].data = msg.payload;
                    }
                }
            }
            MsgKind::Rho => {
                if let Some(k) = self.a_in.iter().position(|&j| j == msg.from) {
                    if msg.stamp > self.rho_in[k].stamp {
                        self.rho_in[k].stamp = msg.stamp;
                        self.rho_in[k].data = msg.payload64;
                    }
                }
            }
            MsgKind::ZDelta => {
                // naive mode: increments accumulate regardless of order;
                // a dropped packet's mass is simply gone.
                if self.a_in.contains(&msg.from) {
                    crate::linalg::axpy(&mut self.pending_delta, 1.0,
                                        &msg.payload);
                }
            }
            _ => { /* other kinds are never routed to R-FAST nodes */ }
        }
    }

    fn set_gamma(&mut self, gamma: f32) {
        self.gamma = gamma;
    }

    fn param(&self) -> &[f32] {
        &self.x
    }

    fn local_iter(&self) -> u64 {
        self.t
    }

    fn as_any(&self) -> Option<&dyn std::any::Any> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::{GradOracle, QuadraticOracle};

    fn drive_round_robin(
        nodes: &mut [Box<dyn NodeState>],
        oracles: &mut [Box<dyn NodeOracle>],
        iters: usize,
    ) {
        // synchronous schedule of Remark 2: round-robin activation with
        // immediate delivery
        let mut outbox = Vec::new();
        let mut replies = Vec::new();
        for _ in 0..iters {
            for i in 0..nodes.len() {
                nodes[i].wake(oracles[i].as_mut(), &mut outbox);
                for msg in outbox.drain(..) {
                    let to = msg.to;
                    nodes[to].receive(msg, &mut replies);
                }
                assert!(replies.is_empty(), "R-FAST never replies");
            }
        }
    }

    #[test]
    fn converges_on_quadratic_ring_round_robin() {
        let topo = Topology::ring(4);
        let q = QuadraticOracle::heterogeneous(6, 4, 0.5, 2.0, 3);
        let xs = q.optimum();
        let mut set = q.into_set();
        let x0 = vec![0.0f32; 6];
        let mut nodes = build(&topo, &x0, 0.05, RFastParams::default());
        drive_round_robin(&mut nodes, &mut set.nodes, 12_000);
        for nd in &nodes {
            let gap = crate::linalg::dist(nd.param(), &xs);
            assert!(gap < 1e-3, "gap {gap}");
        }
    }

    #[test]
    fn converges_on_binary_tree() {
        // non-strongly-connected: the whole point of Assumption 2
        let topo = Topology::binary_tree(7);
        let q = QuadraticOracle::heterogeneous(4, 7, 0.5, 2.0, 9);
        let xs = q.optimum();
        let mut set = q.into_set();
        let mut nodes = build(&topo, &vec![0.0; 4], 0.03, RFastParams::default());
        drive_round_robin(&mut nodes, &mut set.nodes, 12_000);
        let gap = crate::linalg::dist(nodes[0].param(), &xs);
        assert!(gap < 5e-3, "gap {gap}");
    }

    #[test]
    fn stale_messages_are_ignored() {
        let topo = Topology::ring(3);
        let mut node = RFastNode::new(1, &topo, &[0.0, 0.0], 0.1,
                                      RFastParams::default());
        let fresh = Msg::new(0, 1, MsgKind::V, 5, vec![5.0, 5.0]);
        let stale = Msg::new(0, 1, MsgKind::V, 3, vec![3.0, 3.0]);
        node.receive(fresh, &mut Vec::new());
        node.receive(stale, &mut Vec::new());
        assert_eq!(node.v_in[0].data, vec![5.0, 5.0]);
        assert_eq!(node.v_in[0].stamp, 5);
    }

    #[test]
    fn messages_from_non_neighbors_are_dropped() {
        let topo = Topology::line(4); // W-in of node 2 = {1}
        let mut node = RFastNode::new(2, &topo, &[0.0], 0.1,
                                      RFastParams::default());
        node.receive(Msg::new(3, 2, MsgKind::V, 9, vec![9.0]), &mut Vec::new());
        assert!(node.v_in.iter().all(|f| f.stamp == 0));
    }

    #[test]
    fn emits_expected_message_set() {
        let topo = Topology::binary_tree(3); // 0 → {1,2} in W; {1,2} → 0 in A
        let q = QuadraticOracle::heterogeneous(2, 3, 1.0, 1.0, 1);
        let mut set = q.into_set();
        let mut root = RFastNode::new(0, &topo, &[0.0, 0.0], 0.1,
                                      RFastParams::default());
        let mut out = Vec::new();
        root.wake(set.nodes[0].as_mut(), &mut out);
        // root sends V to children (W-out), and ρ to nobody (A-out of root
        // in a tree: children push UP to root, so root has no A-out).
        let v_msgs: Vec<_> =
            out.iter().filter(|m| m.kind == MsgKind::V).collect();
        assert_eq!(v_msgs.len(), 2);
        assert!(out.iter().all(|m| m.kind != MsgKind::Rho));

        let mut leaf = RFastNode::new(1, &topo, &[0.0, 0.0], 0.1,
                                      RFastParams::default());
        out.clear();
        leaf.wake(set.nodes[1].as_mut(), &mut out);
        // leaf 1: no W-out (tree leaf), one A-out (to parent 0)
        assert_eq!(out.iter().filter(|m| m.kind == MsgKind::V).count(), 0);
        let rho: Vec<_> = out.iter().filter(|m| m.kind == MsgKind::Rho).collect();
        assert_eq!(rho.len(), 1);
        assert_eq!(rho[0].to, 0);
        assert_eq!(rho[0].stamp, 1);
    }

    #[test]
    fn naive_mode_sends_deltas() {
        let topo = Topology::ring(3);
        let q = QuadraticOracle::heterogeneous(2, 3, 1.0, 1.0, 1);
        let mut set = q.into_set();
        let mut node = RFastNode::new(0, &topo, &[1.0, 1.0], 0.1,
                                      RFastParams { robust: false });
        let mut out = Vec::new();
        node.wake(set.nodes[0].as_mut(), &mut out);
        assert!(out.iter().any(|m| m.kind == MsgKind::ZDelta));
        assert!(out.iter().all(|m| m.kind != MsgKind::Rho));
    }

    #[test]
    fn rho_running_sum_monotone_growth() {
        // after two wakes the ρ payload must equal the SUM of both
        // increments (that's what makes re-delivery subsume losses)
        let topo = Topology::line(2); // node 0 → 1 in W, 1 → 0 in A
        let q = QuadraticOracle::heterogeneous(2, 2, 1.0, 1.0, 5);
        let mut set = q.into_set();
        let mut node1 = RFastNode::new(1, &topo, &[1.0, -1.0], 0.1,
                                       RFastParams::default());
        let mut out = Vec::new();
        node1.wake(set.nodes[1].as_mut(), &mut out);
        let rho1 = out
            .iter()
            .find(|m| m.kind == MsgKind::Rho)
            .unwrap()
            .payload64
            .clone();
        out.clear();
        node1.wake(set.nodes[1].as_mut(), &mut out);
        let rho2 = out
            .iter()
            .find(|m| m.kind == MsgKind::Rho)
            .unwrap()
            .payload64
            .clone();
        // second running sum strictly extends the first (non-zero z½)
        let diff: f64 = rho2
            .iter()
            .zip(&rho1)
            .map(|(b, a)| (b - a).abs())
            .sum();
        assert!(diff > 0.0, "running sum did not grow");
    }
}
