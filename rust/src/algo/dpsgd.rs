//! D-PSGD (Lian et al. 2017): synchronous decentralized parallel SGD over
//! an undirected doubly-stochastic graph:
//!
//!   x_i^{t+1} = Σ_j w_ij x_j^t − γ ∇f_i(x_i^t; ζ_i^t)
//!
//! Requires undirected communication + doubly-stochastic W — the paper runs
//! it on an undirected ring (Metropolis weights, w = 1/3 each), which this
//! builder constructs internally regardless of the directed topology the
//! other algorithms use. No gradient tracking: convergence degrades with
//! data heterogeneity (ς-dependent rate), which the heterogeneity ablation
//! bench exhibits.

use super::roundbuf::RoundBuf;
use super::{Msg, MsgKind, NodeState, Payload};
use crate::oracle::NodeOracle;

pub fn build(n: usize, x0: &[f32], gamma: f32) -> Vec<Box<dyn NodeState>> {
    (0..n)
        .map(|i| Box::new(DPsgdNode::new(i, n, x0, gamma)) as Box<dyn NodeState>)
        .collect()
}

pub struct DPsgdNode {
    id: usize,
    n: usize,
    gamma: f32,
    t: u64,
    x: Vec<f32>,
    g: Vec<f32>,
    neighbors: Vec<usize>,
    buf: RoundBuf,
    started: bool,
}

impl DPsgdNode {
    pub fn new(id: usize, n: usize, x0: &[f32], gamma: f32) -> DPsgdNode {
        let neighbors: Vec<usize> = if n == 1 {
            vec![]
        } else if n == 2 {
            vec![1 - id]
        } else {
            vec![(id + n - 1) % n, (id + 1) % n]
        };
        DPsgdNode {
            id,
            n,
            gamma,
            t: 0,
            x: x0.to_vec(),
            g: vec![0.0; x0.len()],
            buf: RoundBuf::new(neighbors.clone()),
            neighbors,
            started: false,
        }
    }

    /// Metropolis weight on the ring: 1/(1+deg) with deg=2 ⇒ 1/3 (1/2 for
    /// the 2-node graph, 1 for a singleton).
    fn mix_weight(&self) -> f32 {
        1.0 / (self.neighbors.len() as f32 + 1.0)
    }
}

impl NodeState for DPsgdNode {
    fn ready(&self) -> bool {
        if !self.started {
            return true;
        }
        self.buf.has_all(self.t - 1)
    }

    fn wake(&mut self, oracle: &mut dyn NodeOracle, out: &mut Vec<Msg>)
            -> Option<f32> {
        if self.started {
            // mix round t−1 values
            let w = self.mix_weight();
            let prev = self.t - 1;
            let mut mixed = vec![0.0f32; self.x.len()];
            crate::linalg::scale_into(&mut mixed, w, &self.x);
            for k in 0..self.neighbors.len() {
                let xj = self.buf.take(k, prev);
                crate::linalg::axpy(&mut mixed, w, &xj);
            }
            self.x = mixed;
        }
        // local SGD step at the (mixed) iterate
        let loss = oracle.grad(&self.x, &mut self.g);
        crate::linalg::axpy(&mut self.x, -self.gamma, &self.g);
        // broadcast x^t: one shared allocation for every neighbor
        if !self.neighbors.is_empty() {
            let x = Payload::from_slice(&self.x);
            for &j in &self.neighbors {
                out.push(Msg::new(self.id, j, MsgKind::X, self.t, x.clone()));
            }
        }
        self.started = true;
        self.t += 1;
        let _ = self.n;
        Some(loss)
    }

    fn receive(&mut self, msg: Msg, _out: &mut Vec<Msg>) {
        if msg.kind == MsgKind::X {
            self.buf.insert(msg.from, msg.stamp, msg.payload);
        }
    }

    fn set_gamma(&mut self, gamma: f32) {
        self.gamma = gamma;
    }

    fn param(&self) -> &[f32] {
        &self.x
    }

    fn local_iter(&self) -> u64 {
        self.t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::{GradOracle, QuadraticOracle};

    #[test]
    fn converges_near_optimum_homogeneous() {
        // identical objectives at every node ⇒ D-PSGD is unbiased
        let q = QuadraticOracle::new(6, 4, 0.5, 2.0, 0.0, 0.0, 3);
        let xs = q.optimum();
        let mut set = q.into_set();
        let mut nodes = build(4, &vec![0.0; 6], 0.05);
        let mut out = Vec::new();
        let mut replies = Vec::new();
        for _ in 0..2500 {
            for i in 0..nodes.len() {
                assert!(nodes[i].ready());
                nodes[i].wake(set.nodes[i].as_mut(), &mut out);
            }
            for m in out.drain(..) {
                let to = m.to;
                nodes[to].receive(m, &mut replies);
            }
        }
        let gap = crate::linalg::dist(nodes[0].param(), &xs);
        assert!(gap < 1e-2, "gap {gap}");
    }

    #[test]
    fn heterogeneity_biases_dpsgd_fixed_step() {
        // with heterogeneous objectives and a fixed step, D-PSGD stalls at
        // a ς-dependent bias — the contrast that motivates gradient tracking
        let q = QuadraticOracle::new(6, 4, 0.5, 4.0, 2.0, 0.0, 5);
        let xs = q.optimum();
        let mut set = q.into_set();
        let mut nodes = build(4, &vec![0.0; 6], 0.05);
        let mut out = Vec::new();
        let mut replies = Vec::new();
        for _ in 0..4000 {
            for i in 0..nodes.len() {
                nodes[i].wake(set.nodes[i].as_mut(), &mut out);
            }
            for m in out.drain(..) {
                let to = m.to;
                nodes[to].receive(m, &mut replies);
            }
        }
        let gap = crate::linalg::dist(nodes[0].param(), &xs);
        assert!(gap > 1e-3, "expected heterogeneity bias, gap {gap}");
    }

    #[test]
    fn two_node_graph_works() {
        let q = QuadraticOracle::new(3, 2, 1.0, 1.0, 0.0, 0.0, 7);
        let xs = q.optimum();
        let mut set = q.into_set();
        let mut nodes = build(2, &vec![0.0; 3], 0.1);
        let mut out = Vec::new();
        let mut replies = Vec::new();
        for _ in 0..1500 {
            for i in 0..2 {
                nodes[i].wake(set.nodes[i].as_mut(), &mut out);
            }
            for m in out.drain(..) {
                let to = m.to;
                nodes[to].receive(m, &mut replies);
            }
        }
        assert!(crate::linalg::dist(nodes[0].param(), &xs) < 1e-2);
    }
}
