//! Synchronous Push-Pull (paper eq. (2); Pu et al. 2020) — the algorithm
//! R-FAST reduces to under the synchronous schedule of Remark 2.
//!
//!   x_i^{t+1} = Σ_j w_ij (x_j^t − γ z_j^t)
//!   z_i^{t+1} = Σ_j a_ij z_j^t + ∇f_i(x_i^{t+1};ζ^{t+1}) − ∇f_i(x_i^t;ζ^t)
//!
//! Message plan per round t: node j sends `m_j = x_j − γ z_j` on W-edges
//! and the pre-weighted `a_ij · z_j` on A-edges, both stamped t. A node
//! `ready`s for round t+1 only when every round-t input arrived — the
//! barrier that makes this (and every sync baseline) straggler-bound.

use super::roundbuf::RoundBuf;
use super::{Msg, MsgKind, NodeState, Payload};
use crate::graph::Topology;
use crate::oracle::NodeOracle;

pub fn build(topo: &Topology, x0: &[f32], gamma: f32) -> Vec<Box<dyn NodeState>> {
    (0..topo.n())
        .map(|i| Box::new(PushPullNode::new(i, topo, x0, gamma)) as Box<dyn NodeState>)
        .collect()
}

pub struct PushPullNode {
    id: usize,
    gamma: f32,
    t: u64,
    w_ii: f32,
    w_in_weights: Vec<f32>,
    w_out: Vec<usize>,
    a_ii: f32,
    /// (out-neighbor j, a_ji) — sender pre-weights its z by the receiver's
    /// column entry.
    a_out: Vec<(usize, f32)>,
    x: Vec<f32>,
    z: Vec<f32>,
    g_prev: Vec<f32>,
    g_new: Vec<f32>,
    /// staging buffer for outgoing payloads (m = x − γz, a_ji·z) so each
    /// send costs exactly one shared-payload allocation
    scratch: Vec<f32>,
    vbuf: RoundBuf,
    zbuf: RoundBuf,
    initialized: bool,
}

impl PushPullNode {
    pub fn new(id: usize, topo: &Topology, x0: &[f32], gamma: f32) -> PushPullNode {
        let wm = &topo.weights;
        let p = x0.len();
        PushPullNode {
            id,
            gamma,
            t: 0,
            w_ii: wm.w.get(id, id),
            w_in_weights: wm.w_in[id].iter().map(|&j| wm.w.get(id, j)).collect(),
            w_out: wm.w_out[id].clone(),
            a_ii: wm.a.get(id, id),
            a_out: wm.a_out[id].iter().map(|&j| (j, wm.a.get(j, id))).collect(),
            x: x0.to_vec(),
            z: vec![0.0; p],
            g_prev: vec![0.0; p],
            g_new: vec![0.0; p],
            scratch: vec![0.0; p],
            vbuf: RoundBuf::new(wm.w_in[id].clone()),
            zbuf: RoundBuf::new(wm.a_in[id].clone()),
            initialized: false,
        }
    }

    fn send_round(&mut self, out: &mut Vec<Msg>) {
        // m = x − γ z on W-edges: one shared allocation for the fan-out
        if !self.w_out.is_empty() {
            self.scratch.copy_from_slice(&self.x);
            crate::linalg::axpy(&mut self.scratch, -self.gamma, &self.z);
            let m = Payload::from_slice(&self.scratch);
            for &j in &self.w_out {
                out.push(Msg::new(self.id, j, MsgKind::V, self.t, m.clone()));
            }
        }
        // a_ij-weighted z on A-edges (contents differ per receiver, so
        // each is its own allocation)
        for &(j, a_ji) in &self.a_out {
            crate::linalg::scale_into(&mut self.scratch, a_ji, &self.z);
            out.push(Msg::new(self.id, j, MsgKind::ZDelta, self.t,
                              Payload::from_slice(&self.scratch)));
        }
    }
}

impl NodeState for PushPullNode {
    fn ready(&self) -> bool {
        if !self.initialized {
            return true;
        }
        let prev = self.t - 1;
        self.vbuf.has_all(prev) && self.zbuf.has_all(prev)
    }

    fn wake(&mut self, oracle: &mut dyn NodeOracle, out: &mut Vec<Msg>)
            -> Option<f32> {
        if !self.initialized {
            // round 0: z⁰ = ∇f(x⁰; ζ⁰), broadcast round-0 messages
            let loss = oracle.grad(&self.x, &mut self.g_prev);
            self.z.copy_from_slice(&self.g_prev);
            self.initialized = true;
            self.send_round(out);
            self.t = 1;
            return Some(loss);
        }
        let prev = self.t - 1;
        // pull: x ← w_ii (x − γ z) + Σ_j w_ij m_j
        let mut x_new = self.x.clone();
        crate::linalg::axpy(&mut x_new, -self.gamma, &self.z);
        crate::linalg::scale(&mut x_new, self.w_ii);
        for k in 0..self.w_in_weights.len() {
            let m = self.vbuf.take(k, prev);
            crate::linalg::axpy(&mut x_new, self.w_in_weights[k], &m);
        }
        // push: z ← a_ii z + Σ_j (a_ij z_j) + ∇f(x_new) − ∇f(x_old)
        let mut z_new = vec![0.0f32; self.z.len()];
        crate::linalg::scale_into(&mut z_new, self.a_ii, &self.z);
        for k in 0..self.zbuf.peers().len() {
            let wz = self.zbuf.take(k, prev);
            crate::linalg::axpy(&mut z_new, 1.0, &wz);
        }
        let loss = oracle.grad(&x_new, &mut self.g_new);
        crate::linalg::add_diff(&mut z_new, &self.g_new, &self.g_prev);
        std::mem::swap(&mut self.g_prev, &mut self.g_new);

        self.x = x_new;
        self.z = z_new;
        self.send_round(out);
        self.t += 1;
        Some(loss)
    }

    fn receive(&mut self, msg: Msg, _out: &mut Vec<Msg>) {
        match msg.kind {
            MsgKind::V => {
                self.vbuf.insert(msg.from, msg.stamp, msg.payload);
            }
            MsgKind::ZDelta => {
                self.zbuf.insert(msg.from, msg.stamp, msg.payload);
            }
            _ => {}
        }
    }

    fn set_gamma(&mut self, gamma: f32) {
        self.gamma = gamma;
    }

    fn param(&self) -> &[f32] {
        &self.x
    }

    fn local_iter(&self) -> u64 {
        self.t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::{GradOracle, QuadraticOracle};

    /// Lock-step driver honoring the barrier (all nodes each round).
    fn drive(nodes: &mut [Box<dyn NodeState>],
             oracles: &mut [Box<dyn NodeOracle>], rounds: usize) {
        let mut out = Vec::new();
        for _ in 0..rounds {
            for i in 0..nodes.len() {
                assert!(nodes[i].ready(), "barrier violated at node {i}");
                nodes[i].wake(oracles[i].as_mut(), &mut out);
            }
            let mut replies = Vec::new();
            for msg in out.drain(..) {
                let to = msg.to;
                nodes[to].receive(msg, &mut replies);
            }
        }
    }

    #[test]
    fn converges_on_ring_quadratic() {
        let topo = Topology::ring(5);
        let q = QuadraticOracle::heterogeneous(8, 5, 0.5, 2.0, 17);
        let xs = q.optimum();
        let mut set = q.into_set();
        let mut nodes = build(&topo, &vec![0.0; 8], 0.04);
        drive(&mut nodes, &mut set.nodes, 3000);
        for nd in &nodes {
            let gap = crate::linalg::dist(nd.param(), &xs);
            assert!(gap < 1e-3, "gap {gap}");
        }
    }

    #[test]
    fn converges_on_star_quadratic() {
        let topo = Topology::star(6);
        let q = QuadraticOracle::heterogeneous(4, 6, 0.5, 2.0, 23);
        let xs = q.optimum();
        let mut set = q.into_set();
        let mut nodes = build(&topo, &vec![0.5; 4], 0.04);
        drive(&mut nodes, &mut set.nodes, 5000);
        let gap = crate::linalg::dist(nodes[0].param(), &xs);
        assert!(gap < 2e-3, "gap {gap}");
    }

    #[test]
    fn not_ready_until_round_messages_arrive() {
        let topo = Topology::ring(3);
        let q = QuadraticOracle::heterogeneous(2, 3, 1.0, 1.0, 1);
        let mut set = q.into_set();
        let mut nodes = build(&topo, &[0.0, 0.0], 0.1);
        let mut out = Vec::new();
        assert!(nodes[0].ready());
        nodes[0].wake(set.nodes[0].as_mut(), &mut out);
        // round 1 requires round-0 inputs from the ring predecessor
        assert!(!nodes[0].ready());
    }
}
