//! Ring-AllReduce SGD (Horovod-style, paper baseline §VI-B): every round,
//! all nodes compute gradients and take the *exact* average via the classic
//! ring primitive — reduce-scatter (n−1 steps) then all-gather (n−1 steps),
//! each step moving one p/n-sized chunk to the ring successor.
//!
//! The real chunked message pattern is implemented (not a magic global
//! average): each communication step is a zero-compute `wake` gated on the
//! previous step's chunk having arrived, so a straggler — or one slow link —
//! stalls the entire ring, which is exactly the behaviour Table II's
//! straggler column quantifies.
//!
//! Chunk schedule (standard): at reduce step s (0-based), node i sends
//! chunk (i − s) mod n and receives chunk (i − s − 1) mod n; after n−1
//! steps node i owns the fully-reduced chunk (i + 1) mod n. All-gather
//! circulates the reduced chunks the same way.

use super::{Msg, MsgKind, NodeState, Payload};
use crate::oracle::NodeOracle;

pub fn build(n: usize, x0: &[f32], gamma: f32) -> Vec<Box<dyn NodeState>> {
    (0..n)
        .map(|i| Box::new(RingAllReduceNode::new(i, n, x0, gamma)) as Box<dyn NodeState>)
        .collect()
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum Phase {
    /// Compute the local gradient (the only compute-charged wake).
    Grad,
    /// Reduce-scatter step s: waiting to have received step s's chunk.
    Reduce(u32),
    /// All-gather step s.
    Gather(u32),
}

pub struct RingAllReduceNode {
    id: usize,
    n: usize,
    gamma: f32,
    round: u64,
    phase: Phase,
    x: Vec<f32>,
    /// gradient accumulation buffer (chunks get reduced in place)
    gbuf: Vec<f32>,
    /// chunks received but not yet applied, keyed by (round, is_gather,
    /// step). Latency jitter can deliver step s+1 (or even next round's
    /// reduce step 0) before step s is consumed, so a keyed map — not a
    /// single slot — is required. Entries hold the messages' shared
    /// payloads (the ring has one receiver per chunk, so no fan-out —
    /// but buffering still avoids a copy).
    pending: std::collections::BTreeMap<(u64, bool, u32), Payload>,
    chunks: Vec<(usize, usize)>, // chunk c → [start, end)
}

impl RingAllReduceNode {
    pub fn new(id: usize, n: usize, x0: &[f32], gamma: f32) -> RingAllReduceNode {
        let p = x0.len();
        // chunk boundaries: ceil-partition so every chunk is non-empty when
        // p ≥ n (for p < n some chunks are empty, still correct)
        let mut chunks = Vec::with_capacity(n);
        let base = p / n;
        let rem = p % n;
        let mut start = 0;
        for c in 0..n {
            let len = base + usize::from(c < rem);
            chunks.push((start, start + len));
            start += len;
        }
        RingAllReduceNode {
            id,
            n,
            gamma,
            round: 0,
            phase: Phase::Grad,
            x: x0.to_vec(),
            gbuf: vec![0.0; p],
            pending: std::collections::BTreeMap::new(),
            chunks,
        }
    }

    /// The (round, is_gather, step) key this node must consume next.
    fn awaited_key(&self) -> Option<(u64, bool, u32)> {
        match self.phase {
            Phase::Grad => None,
            Phase::Reduce(s) => Some((self.round, false, s)),
            Phase::Gather(s) => Some((self.round, true, s)),
        }
    }

    fn succ(&self) -> usize {
        (self.id + 1) % self.n
    }

    fn chunk(&self, c: usize) -> (usize, usize) {
        self.chunks[c % self.n]
    }

    /// Chunk index this node sends at reduce step s.
    fn reduce_send_chunk(&self, s: u32) -> usize {
        (self.id + self.n - s as usize % self.n) % self.n
    }

    /// Chunk index this node sends at gather step s (it owns (i+1) after
    /// the reduce phase, then forwards what it received).
    fn gather_send_chunk(&self, s: u32) -> usize {
        (self.id + 1 + self.n - s as usize % self.n) % self.n
    }

    fn send_chunk(&self, kind: MsgKind, step: u32, c: usize,
                  out: &mut Vec<Msg>) {
        let (a, b) = self.chunk(c);
        let mut m = Msg::new(self.id, self.succ(), kind, self.round,
                             Payload::from_slice(&self.gbuf[a..b]));
        m.slot = step;
        out.push(m);
    }

    fn apply_pending(&mut self) {
        // lint:allow(panic-path): only called from comm phases, where awaited_key is always Some
        let key = self.awaited_key().expect("apply only in comm phases");
        let payload = self
            .pending
            .remove(&key)
            // lint:allow(panic-path): wake() is gated on ready(), which requires this chunk
            .expect("wake gated on ready() ⇒ awaited chunk present");
        let (_, is_gather, step) = key;
        if !is_gather {
            // incoming chunk at reduce step s is (id − s − 1) mod n
            let c = (self.id + 2 * self.n - step as usize % self.n - 1) % self.n;
            let (a, b) = self.chunk(c);
            for (dst, src) in self.gbuf[a..b].iter_mut().zip(&payload) {
                *dst += *src;
            }
        } else {
            // incoming chunk at gather step s is (id − s) mod n
            let c = (self.id + 2 * self.n - step as usize % self.n) % self.n;
            let (a, b) = self.chunk(c);
            self.gbuf[a..b].copy_from_slice(&payload);
        }
    }
}

impl NodeState for RingAllReduceNode {
    fn ready(&self) -> bool {
        match self.awaited_key() {
            None => true,
            Some(key) => self.pending.contains_key(&key),
        }
    }

    fn wake_computes_gradient(&self) -> bool {
        self.phase == Phase::Grad
    }

    fn wake(&mut self, oracle: &mut dyn NodeOracle, out: &mut Vec<Msg>)
            -> Option<f32> {
        match self.phase {
            Phase::Grad => {
                let loss = oracle.grad(&self.x, &mut self.gbuf);
                if self.n == 1 {
                    crate::linalg::axpy(&mut self.x, -self.gamma, &self.gbuf);
                    self.round += 1;
                    return Some(loss);
                }
                self.send_chunk(MsgKind::Reduce, 0,
                                self.reduce_send_chunk(0), out);
                self.phase = Phase::Reduce(0);
                Some(loss)
            }
            Phase::Reduce(s) => {
                self.apply_pending();
                let next = s + 1;
                if (next as usize) < self.n - 1 {
                    self.send_chunk(MsgKind::Reduce, next,
                                    self.reduce_send_chunk(next), out);
                    self.phase = Phase::Reduce(next);
                } else {
                    // reduce done: start gather by sending the chunk we own
                    self.send_chunk(MsgKind::Gather, 0,
                                    self.gather_send_chunk(0), out);
                    self.phase = Phase::Gather(0);
                }
                None
            }
            Phase::Gather(s) => {
                self.apply_pending();
                let next = s + 1;
                if (next as usize) < self.n - 1 {
                    self.send_chunk(MsgKind::Gather, next,
                                    self.gather_send_chunk(next), out);
                    self.phase = Phase::Gather(next);
                } else {
                    // all-gather done: gbuf = Σ_j g_j; apply averaged step
                    let scale = self.gamma / self.n as f32;
                    crate::linalg::axpy(&mut self.x, -scale, &self.gbuf);
                    self.round += 1;
                    self.phase = Phase::Grad;
                }
                None
            }
        }
    }

    fn receive(&mut self, msg: Msg, _out: &mut Vec<Msg>) {
        match msg.kind {
            MsgKind::Reduce | MsgKind::Gather => {
                let key = (msg.stamp, msg.kind == MsgKind::Gather, msg.slot);
                let prev = self.pending.insert(key, msg.payload);
                debug_assert!(prev.is_none(), "duplicate ring chunk {key:?}");
            }
            _ => {}
        }
    }

    fn set_gamma(&mut self, gamma: f32) {
        self.gamma = gamma;
    }

    fn param(&self) -> &[f32] {
        &self.x
    }

    fn local_iter(&self) -> u64 {
        self.round
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::{GradOracle, NodeOracle, QuadraticOracle};

    /// Drive the ring until all nodes are back in Grad phase `rounds` times.
    fn drive(nodes: &mut [Box<dyn NodeState>],
             oracles: &mut [Box<dyn NodeOracle>], rounds: u64) {
        let mut out = Vec::new();
        let mut replies = Vec::new();
        let mut guard = 0u64;
        while nodes.iter().any(|n| n.local_iter() < rounds) {
            guard += 1;
            assert!(guard < 10_000_000, "ring deadlocked");
            let mut progressed = false;
            for i in 0..nodes.len() {
                if nodes[i].ready() && nodes[i].local_iter() < rounds {
                    nodes[i].wake(oracles[i].as_mut(), &mut out);
                    progressed = true;
                }
            }
            for m in out.drain(..) {
                let to = m.to;
                nodes[to].receive(m, &mut replies);
            }
            assert!(progressed, "no node could progress — deadlock");
        }
    }

    #[test]
    fn one_round_computes_exact_average() {
        for n in [2, 3, 4, 7] {
            // p not divisible by n on purpose
            let p = 10;
            let q = QuadraticOracle::heterogeneous(p, n, 0.5, 2.0, n as u64);
            let mut set = q.clone().into_set();
            let x0 = vec![0.3f32; p];
            let mut nodes = build(n, &x0, 1.0); // γ=1 ⇒ x1 = x0 − mean(g)
            drive(&mut nodes, &mut set.nodes, 1);

            // expected: x0 − (1/n) Σ g_i(x0), deterministic oracle
            let mut expect = x0.clone();
            let mut g = vec![0.0f32; p];
            let mut set2 = q.into_set();
            for node_oracle in set2.nodes.iter_mut() {
                node_oracle.grad(&x0, &mut g);
                crate::linalg::axpy(&mut expect, -1.0 / n as f32, &g);
            }
            for nd in &nodes {
                crate::testutil::assert_close(nd.param(), &expect, 1e-5)
                    .unwrap_or_else(|e| panic!("n={n}: {e}"));
            }
            // every node ends identical
            for nd in &nodes[1..] {
                assert_eq!(nd.param(), nodes[0].param());
            }
        }
    }

    #[test]
    fn converges_on_quadratic() {
        let q = QuadraticOracle::heterogeneous(8, 4, 0.5, 2.0, 77);
        let xs = q.optimum();
        let mut set = q.into_set();
        let mut nodes = build(4, &vec![0.0; 8], 0.2);
        drive(&mut nodes, &mut set.nodes, 400);
        let gap = crate::linalg::dist(nodes[0].param(), &xs);
        assert!(gap < 1e-3, "gap {gap}");
    }

    #[test]
    fn single_node_degenerates_to_sgd() {
        let q = QuadraticOracle::heterogeneous(4, 1, 1.0, 1.0, 5);
        let xs = q.optimum();
        let mut set = q.into_set();
        let mut nodes = build(1, &vec![0.0; 4], 0.5);
        drive(&mut nodes, &mut set.nodes, 100);
        assert!(crate::linalg::dist(nodes[0].param(), &xs) < 1e-4);
    }

    #[test]
    fn communication_wakes_charge_no_compute() {
        let q = QuadraticOracle::heterogeneous(4, 3, 1.0, 1.0, 9);
        let mut set = q.into_set();
        let mut nodes = build(3, &vec![0.0; 4], 0.1);
        let mut out = Vec::new();
        assert!(nodes[0].wake_computes_gradient());
        nodes[0].wake(set.nodes[0].as_mut(), &mut out);
        assert!(!nodes[0].wake_computes_gradient()); // now in Reduce phase
    }
}
