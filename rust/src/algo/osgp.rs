//! OSGP — Overlap Stochastic Gradient Push (Assran et al. 2019): an
//! asynchronous push-sum method over column-stochastic digraphs.
//!
//! Node state is the push-sum pair (x̃, w): x̃ the biased parameter mass, w
//! the scalar weight mass; the de-biased estimate is z = x̃ / w. Per wake:
//!
//!   1. g = ∇f(z; ζ);  x̃ ← x̃ − γ g
//!   2. push: send (a_ji·x̃, a_ji·w) to each A-out-neighbor, keep the
//!      a_ii share locally
//!   3. receive: accumulate arriving (x̃, w) mass whenever it lands
//!      ("overlap" — no blocking on arrivals)
//!
//! Push-sum's correctness hinges on mass conservation; a dropped message
//! destroys both x̃- and w-mass, biasing the average — the robustness gap
//! R-FAST's ρ/ρ̃ scheme closes (paper Table II: OSGP's accuracy drop under
//! loss). A corollary: OSGP needs compute-time ≫ link-RTT, because the
//! link layer's one-in-flight rule discards sends on busy channels and
//! every discard destroys mass; R-FAST's running sums are immune to both
//! failure modes.

use super::{Msg, MsgKind, NodeState, Payload};
use crate::graph::Topology;
use crate::oracle::NodeOracle;

pub fn build(topo: &Topology, x0: &[f32], gamma: f32) -> Vec<Box<dyn NodeState>> {
    (0..topo.n())
        .map(|i| Box::new(OsgpNode::new(i, topo, x0, gamma)) as Box<dyn NodeState>)
        .collect()
}

pub struct OsgpNode {
    id: usize,
    gamma: f32,
    t: u64,
    /// biased parameter mass x̃
    xt: Vec<f32>,
    /// push-sum weight w
    w: f64,
    /// de-biased estimate z = x̃/w (cached for param())
    z: Vec<f32>,
    g: Vec<f32>,
    /// staging buffer for the per-receiver a_ji·x̃ push shares
    share: Vec<f32>,
    a_ii: f32,
    a_out: Vec<(usize, f32)>,
}

impl OsgpNode {
    pub fn new(id: usize, topo: &Topology, x0: &[f32], gamma: f32) -> OsgpNode {
        let wm = &topo.weights;
        OsgpNode {
            id,
            gamma,
            t: 0,
            xt: x0.to_vec(),
            w: 1.0,
            z: x0.to_vec(),
            g: vec![0.0; x0.len()],
            share: vec![0.0; x0.len()],
            a_ii: wm.a.get(id, id),
            a_out: wm.a_out[id].iter().map(|&j| (j, wm.a.get(j, id))).collect(),
        }
    }

    fn rebias(&mut self) {
        // Under heavy packet loss w can collapse toward 0 (lost push-sum
        // mass). Floor the denominator so z stays finite — the estimate is
        // still biased, which is the honest failure mode Table II shows
        // for OSGP; we just avoid 0/0 = NaN in the metrics.
        let inv = (1.0 / self.w.max(1e-12)) as f32;
        crate::linalg::scale_into(&mut self.z, inv, &self.xt);
    }

    pub fn weight(&self) -> f64 {
        self.w
    }
}

impl NodeState for OsgpNode {
    fn ready(&self) -> bool {
        true // overlap: never blocks
    }

    fn wake(&mut self, oracle: &mut dyn NodeOracle, out: &mut Vec<Msg>)
            -> Option<f32> {
        // gradient at the de-biased estimate
        let loss = oracle.grad(&self.z, &mut self.g);
        // biased mass absorbs the step scaled by w (standard SGP form:
        // x̃ ← x̃ − γ·w·g keeps z's effective step ≈ γ regardless of bias)
        let scale = -(self.gamma as f64 * self.w) as f32;
        crate::linalg::axpy(&mut self.xt, scale, &self.g);
        // push shares: each a_ji·x̃ differs per receiver, so each is its
        // own shared-payload allocation (staged through `share`)
        for &(j, a_ji) in &self.a_out {
            crate::linalg::scale_into(&mut self.share, a_ji, &self.xt);
            let mut m = Msg::new(self.id, j, MsgKind::PushSum, self.t,
                                 Payload::from_slice(&self.share));
            m.aux = a_ji as f64 * self.w;
            out.push(m);
        }
        // keep own share
        crate::linalg::scale(&mut self.xt, self.a_ii);
        self.w *= self.a_ii as f64;
        self.rebias();
        self.t += 1;
        Some(loss)
    }

    fn receive(&mut self, msg: Msg, _out: &mut Vec<Msg>) {
        if msg.kind == MsgKind::PushSum {
            crate::linalg::axpy(&mut self.xt, 1.0, &msg.payload);
            self.w += msg.aux;
            self.rebias();
        }
    }

    fn set_gamma(&mut self, gamma: f32) {
        self.gamma = gamma;
    }

    fn on_send_failed(&mut self, msg: Msg) {
        // sender-side discard: reabsorb the push-sum mass instead of
        // destroying it (the sender knows it didn't send — paper §VI ¶1).
        // In-flight losses cannot be reabsorbed; they are what degrades
        // OSGP relative to R-FAST.
        if msg.kind == MsgKind::PushSum {
            crate::linalg::axpy(&mut self.xt, 1.0, &msg.payload);
            self.w += msg.aux;
            self.rebias();
        }
    }

    fn param(&self) -> &[f32] {
        &self.z
    }

    fn local_iter(&self) -> u64 {
        self.t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::{GradOracle, QuadraticOracle};
    use crate::prng::Rng;

    fn run(n: usize, spread: f32, iters: usize, drop_prob: f64,
           seed: u64) -> (Vec<Box<dyn NodeState>>, Vec<f32>) {
        let topo = Topology::ring(n);
        let q = QuadraticOracle::new(6, n, 0.5, 2.0, spread, 0.0, seed);
        let xs = q.optimum();
        let mut set = q.into_set();
        let mut nodes = build(&topo, &vec![0.0; 6], 0.03);
        let mut rng = Rng::new(seed ^ 0xfeed);
        let mut out = Vec::new();
        let mut replies = Vec::new();
        for _ in 0..iters {
            let i = rng.below(n);
            nodes[i].wake(set.nodes[i].as_mut(), &mut out);
            for m in out.drain(..) {
                if drop_prob > 0.0 && rng.chance(drop_prob) {
                    continue; // lost: push-sum mass destroyed
                }
                let to = m.to;
                nodes[to].receive(m, &mut replies);
            }
        }
        (nodes, xs)
    }

    #[test]
    fn weights_stay_positive_and_mass_conserved_without_loss() {
        let (nodes, _) = run(4, 1.0, 2000, 0.0, 3);
        for nd in nodes {
            assert!(nd.local_iter() > 0);
        }
    }

    #[test]
    fn converges_homogeneous_no_loss() {
        let (nodes, xs) = run(4, 0.0, 12_000, 0.0, 5);
        for nd in &nodes {
            let gap = crate::linalg::dist(nd.param(), &xs);
            assert!(gap < 5e-2, "gap {gap}");
        }
    }

    #[test]
    fn packet_loss_degrades_osgp() {
        // with HETEROGENEOUS objectives, lost push-sum mass biases the
        // consensus average — compare mean gaps over nodes
        let gap_of = |drop: f64| -> f64 {
            let (nodes, xs) = run(4, 2.0, 12_000, drop, 11);
            let g = nodes
                .iter()
                .map(|nd| crate::linalg::dist(nd.param(), &xs))
                .sum::<f64>()
                / nodes.len() as f64;
            if g.is_finite() { g } else { f64::MAX / 4.0 }
        };
        let g_clean = gap_of(0.0);
        let g_lossy = gap_of(0.35);
        assert!(
            g_lossy > 1.5 * g_clean,
            "loss should hurt OSGP: clean {g_clean} lossy {g_lossy}"
        );
    }
}
