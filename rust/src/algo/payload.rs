//! Zero-copy message payloads — the message fabric (DESIGN.md §8).
//!
//! A [`Msg`](super::Msg) used to own its vector payload, so a one-to-many
//! broadcast cloned a model-sized `Vec` once **per out-neighbor** and a
//! receiver that only ever reads (freshest-stamp buffers, ρ̃ consumption
//! snapshots) still paid a deep copy. [`PayloadOf`] replaces the owned
//! vectors with a reference-counted shared slice (`Arc<[T]>`) behind a
//! thin newtype:
//!
//! * a broadcast allocates **once** and every out-neighbor's message
//!   clones the `Arc` (pointer-sized, O(1));
//! * receivers hold the `Arc` instead of deep-copying — the freshest-wins
//!   buffers and the ρ̃ "consumed" snapshot become refcount bumps;
//! * cross-thread sends in the threaded runner move an `Arc`
//!   (`Arc<[T]>: Send + Sync` for these element types), so a channel send
//!   never touches payload bytes;
//! * mutation goes through the copy-on-write escape hatch
//!   [`PayloadOf::make_mut`], which copies **iff** the payload is aliased
//!   — the rule that keeps sharing invisible to the algorithms.
//!
//! Sharing changes no arithmetic, consumes no RNG draws, and reorders no
//! events, so simulator output is bitwise identical to the owned-vector
//! fabric (`rust/tests/fabric.rs` pins this down).
//!
//! ```
//! use rfast::algo::Payload;
//!
//! let a = Payload::from_slice(&[1.0, 2.0]);
//! let mut b = a.clone();                 // O(1): refcount bump
//! assert!(Payload::ptr_eq(&a, &b));
//! b.make_mut()[0] = 9.0;                 // aliased ⇒ copy-on-write
//! assert_eq!(&a[..], &[1.0, 2.0]);       // the original is untouched
//! assert_eq!(&b[..], &[9.0, 2.0]);
//! assert!(!Payload::ptr_eq(&a, &b));
//! ```

use std::fmt;
use std::ops::Deref;
use std::sync::{Arc, OnceLock};

/// A reference-counted, logically-immutable slice of scalars. Cloning is
/// O(1) (refcount bump); mutation goes through the copy-on-write
/// [`PayloadOf::make_mut`]. See the [module docs](self) for the sharing
/// rules.
pub struct PayloadOf<T>(Arc<[T]>);

/// The f32 payload lane of a [`Msg`](super::Msg) (model-sized vectors:
/// v, x, gradients, ring chunks).
pub type Payload = PayloadOf<f32>;

/// The f64 payload lane of a [`Msg`](super::Msg) — ρ running sums only
/// (see the catastrophic-cancellation note on
/// [`Msg::payload64`](super::Msg::payload64)).
pub type Payload64 = PayloadOf<f64>;

impl<T> PayloadOf<T> {
    /// Wrap an owned vector (one allocation: the `Vec`'s buffer is copied
    /// into the `Arc`'s inline slice). Prefer [`PayloadOf::from_slice`]
    /// when the data is borrowed — it skips the intermediate `Vec`.
    pub fn from_vec(v: Vec<T>) -> PayloadOf<T> {
        PayloadOf(v.into())
    }

    /// Borrow the payload as a plain slice (also available through
    /// `Deref`, so payloads coerce at `&[T]` call sites).
    pub fn as_slice(&self) -> &[T] {
        &self.0
    }

    /// Do two payloads share the same allocation? The zero-copy fan-out
    /// invariant: every message of one broadcast satisfies `ptr_eq` with
    /// its siblings.
    pub fn ptr_eq(a: &PayloadOf<T>, b: &PayloadOf<T>) -> bool {
        Arc::ptr_eq(&a.0, &b.0)
    }
}

impl<T: Clone> PayloadOf<T> {
    /// Copy a borrowed slice into a fresh shared payload (one allocation).
    pub fn from_slice(s: &[T]) -> PayloadOf<T> {
        PayloadOf(Arc::from(s))
    }

    /// Copy the contents out into an owned `Vec`.
    pub fn to_vec(&self) -> Vec<T> {
        self.0.to_vec()
    }

    /// Copy-on-write mutable access: if this payload is uniquely owned
    /// the slice is handed out in place (no copy); if it is aliased the
    /// contents are copied into a fresh allocation first, so the other
    /// holders never observe the mutation.
    pub fn make_mut(&mut self) -> &mut [T] {
        if Arc::get_mut(&mut self.0).is_none() {
            let copied: Arc<[T]> = Arc::from(&self.0[..]);
            self.0 = copied;
        }
        // lint:allow(panic-path): the branch above just restored unique ownership
        Arc::get_mut(&mut self.0).expect("uniquely owned after copy-on-write")
    }
}

impl<T: Clone + Default> PayloadOf<T> {
    /// A zero-initialized payload of length `n` (freshest-stamp buffers
    /// start at the paper's v⁰ = 0 / ρ⁰ = 0).
    pub fn zeros(n: usize) -> PayloadOf<T> {
        PayloadOf(vec![T::default(); n].into())
    }
}

impl PayloadOf<f32> {
    /// The shared empty f32 payload. Every [`Msg`](super::Msg) carries
    /// both lanes and uses only one, so the unused lane must not cost an
    /// allocation per message: all empties alias one global slice.
    pub fn empty() -> Payload {
        static EMPTY: OnceLock<Payload> = OnceLock::new();
        EMPTY.get_or_init(|| Payload::from_vec(Vec::new())).clone()
    }
}

impl PayloadOf<f64> {
    /// The shared empty f64 payload (see [`Payload::empty`]).
    pub fn empty() -> Payload64 {
        static EMPTY: OnceLock<Payload64> = OnceLock::new();
        EMPTY.get_or_init(|| Payload64::from_vec(Vec::new())).clone()
    }
}

impl<T> Clone for PayloadOf<T> {
    /// O(1): clones the `Arc`, never the contents.
    fn clone(&self) -> PayloadOf<T> {
        PayloadOf(Arc::clone(&self.0))
    }
}

impl<T> Deref for PayloadOf<T> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        &self.0
    }
}

impl<T: fmt::Debug> fmt::Debug for PayloadOf<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(&self.0[..], f)
    }
}

impl<T: PartialEq> PartialEq for PayloadOf<T> {
    /// Value equality (contents, not allocation identity — that is
    /// [`PayloadOf::ptr_eq`]).
    fn eq(&self, other: &PayloadOf<T>) -> bool {
        self.0[..] == other.0[..]
    }
}

impl<T: PartialEq> PartialEq<Vec<T>> for PayloadOf<T> {
    fn eq(&self, other: &Vec<T>) -> bool {
        self.0[..] == other[..]
    }
}

impl<T: PartialEq> PartialEq<[T]> for PayloadOf<T> {
    fn eq(&self, other: &[T]) -> bool {
        self.0[..] == other[..]
    }
}

impl<T> From<Vec<T>> for PayloadOf<T> {
    fn from(v: Vec<T>) -> PayloadOf<T> {
        PayloadOf::from_vec(v)
    }
}

impl<T: Clone> From<&[T]> for PayloadOf<T> {
    fn from(s: &[T]) -> PayloadOf<T> {
        PayloadOf::from_slice(s)
    }
}

impl<T> FromIterator<T> for PayloadOf<T> {
    fn from_iter<I: IntoIterator<Item = T>>(iter: I) -> PayloadOf<T> {
        PayloadOf(iter.into_iter().collect())
    }
}

impl<'a, T> IntoIterator for &'a PayloadOf<T> {
    type Item = &'a T;
    type IntoIter = std::slice::Iter<'a, T>;
    fn into_iter(self) -> Self::IntoIter {
        self.0.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clone_shares_make_mut_unshares() {
        let a = Payload::from_slice(&[1.0, 2.0, 3.0]);
        let mut b = a.clone();
        assert!(Payload::ptr_eq(&a, &b));
        assert_eq!(a, b);
        b.make_mut()[1] = 7.0;
        assert!(!Payload::ptr_eq(&a, &b));
        assert_eq!(&a[..], &[1.0, 2.0, 3.0]);
        assert_eq!(&b[..], &[1.0, 7.0, 3.0]);
    }

    #[test]
    fn make_mut_in_place_when_unique() {
        let mut a = Payload64::from_slice(&[0.5, 0.25]);
        let before = a.as_slice().as_ptr();
        a.make_mut()[0] = 1.5;
        assert_eq!(a.as_slice().as_ptr(), before, "unique ⇒ no copy");
        assert_eq!(a, vec![1.5, 0.25]);
    }

    #[test]
    fn empties_share_one_allocation() {
        let a = Payload::empty();
        let b = Payload::empty();
        assert!(Payload::ptr_eq(&a, &b));
        assert!(a.is_empty());
        let c = Payload64::empty();
        assert_eq!(c.len(), 0);
    }

    #[test]
    fn zeros_and_conversions() {
        let z = Payload::zeros(4);
        assert_eq!(z, vec![0.0; 4]);
        let v: Payload = vec![1.0f32, 2.0].into();
        assert_eq!(v.to_vec(), vec![1.0, 2.0]);
        let from_iter: Payload64 = (0..3).map(|i| i as f64).collect();
        assert_eq!(from_iter, vec![0.0, 1.0, 2.0]);
        // slice indexing + iteration through Deref / &IntoIterator
        assert_eq!(v[1], 2.0);
        let sum: f32 = (&v).into_iter().sum();
        assert_eq!(sum, 3.0);
    }
}
