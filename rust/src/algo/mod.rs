//! Distributed-training algorithms as event-driven node state machines.
//!
//! Every algorithm (R-FAST and the six baselines of paper §VI) is a set of
//! per-node [`NodeState`] objects that an *engine* drives:
//!
//! * [`crate::sim::Simulator`] — discrete-event, virtual time;
//! * [`crate::runner::ThreadedRunner`] — one OS thread per node, wall clock.
//!
//! The contract is engine-agnostic and has no notion of time:
//!
//! 1. engine calls [`NodeState::ready`]; if true and the node is idle it
//!    charges the node's compute time and then calls [`NodeState::wake`],
//!    which performs one local iteration (oracle call + state update) and
//!    emits messages;
//! 2. delivered messages go to [`NodeState::receive`] (possibly delayed,
//!    reordered, or — for loss-tolerant algorithms — dropped by the link
//!    layer, never by the algorithm).
//!
//! Fully-asynchronous algorithms are always `ready`; synchronous ones gate
//! `ready` on having every round-(t) message, which is exactly how barrier
//! stalls and straggler amplification emerge in the engines.

mod adpsgd;
mod allreduce;
mod dpsgd;
mod osgp;
pub mod payload;
mod push_pull;
mod rfast;
mod roundbuf;
mod sab;

pub use adpsgd::AdPsgdNode;
pub use allreduce::RingAllReduceNode;
pub use dpsgd::DPsgdNode;
pub use osgp::OsgpNode;
pub use payload::{Payload, Payload64, PayloadOf};
pub use push_pull::PushPullNode;
pub use rfast::{RFastNode, RFastParams};
pub use sab::SabNode;

use crate::graph::Topology;
use crate::oracle::NodeOracle;

/// Message kinds across all algorithms.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MsgKind {
    /// R-FAST / Push-Pull consensus variable v.
    V,
    /// R-FAST robust-tracking running sum ρ (payload is the *cumulative*
    /// sum — re-delivery of any later ρ subsumes lost ones).
    Rho,
    /// One-shot tracking increment (naive-GT ablation / push-pull z push).
    ZDelta,
    /// Raw parameter x (D-PSGD gossip, AD-PSGD exchange).
    X,
    /// AD-PSGD reply leg of the pairwise exchange.
    XReply,
    /// OSGP push-sum mass; `aux` carries the scalar weight share.
    PushSum,
    /// Ring-AllReduce reduce-scatter chunk; `slot` = ring step.
    Reduce,
    /// Ring-AllReduce all-gather chunk; `slot` = ring step.
    Gather,
}

/// A network message between nodes. `stamp` is the sender's local iteration
/// counter (the paper's `t+1` attached at S3); receivers keep only the
/// freshest stamp per (peer, kind) where the algorithm calls for it.
///
/// Payloads are **shared, not owned** ([`Payload`] / [`Payload64`] — the
/// zero-copy message fabric, DESIGN.md §8): cloning a `Msg` clones two
/// `Arc`s, a broadcast allocates its payload once for all out-neighbors,
/// and receivers that only read hold the `Arc` instead of deep-copying.
/// Payloads are logically immutable once inside a `Msg`; mutation goes
/// through the copy-on-write [`PayloadOf::make_mut`].
#[derive(Clone, Debug)]
pub struct Msg {
    pub from: usize,
    pub to: usize,
    pub kind: MsgKind,
    pub stamp: u64,
    /// Ring step / chunk index for the all-reduce phases.
    pub slot: u32,
    /// Scalar side-channel (OSGP push-sum weight).
    pub aux: f64,
    /// Shared f32 payload lane (empty for `Rho` messages).
    pub payload: Payload,
    /// f64 payload used ONLY by `Rho` messages: the running sums grow
    /// while their increments shrink, so the receiver-side difference
    /// ρ(latest) − ρ̃(consumed) cancels catastrophically in f32 — it floors
    /// R-FAST's optimality gap around 1e-3 (measured; EXPERIMENTS.md §Notes).
    /// Carrying ρ in f64 restores exact geometric convergence.
    pub payload64: Payload64,
}

impl MsgKind {
    /// Logical channel index for the link layer's one-unacked-packet rule.
    /// Distinct kinds are distinct "sockets" (the paper's v- and ρ-packets
    /// are independent transmissions): without this, on topologies where
    /// G(W) and G(A) share a directed edge, v-packets would permanently
    /// starve ρ-packets and the tracking mass would never flow.
    pub fn chan(&self) -> usize {
        match self {
            MsgKind::V => 0,
            MsgKind::Rho | MsgKind::ZDelta => 1,
            MsgKind::X | MsgKind::PushSum => 2,
            MsgKind::XReply => 3,
            MsgKind::Reduce => 0,
            MsgKind::Gather => 1,
        }
    }

    pub const CHANNELS: usize = 4;
}

impl Msg {
    /// An f32-lane message. Accepts anything convertible into a shared
    /// [`Payload`]: pass a `Payload` clone to fan one allocation out to
    /// many receivers, or a `Vec<f32>` for one-off construction.
    pub fn new(from: usize, to: usize, kind: MsgKind, stamp: u64,
               payload: impl Into<Payload>) -> Msg {
        Msg { from, to, kind, stamp, slot: 0, aux: 0.0,
              payload: payload.into(), payload64: Payload64::empty() }
    }

    /// An f64-lane (ρ) message; see [`Msg::new`] for the payload rules.
    pub fn new64(from: usize, to: usize, kind: MsgKind, stamp: u64,
                 payload64: impl Into<Payload64>) -> Msg {
        Msg { from, to, kind, stamp, slot: 0, aux: 0.0,
              payload: Payload::empty(), payload64: payload64.into() }
    }

    /// Payload length in scalar elements (either precision).
    pub fn len(&self) -> usize {
        self.payload.len() + self.payload64.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Clone with both payload lanes copied into fresh allocations,
    /// severing all sharing with this message. The test suite uses it to
    /// prove payload sharing is invisible to the algorithms
    /// (`rust/tests/fabric.rs`); production paths never need it.
    pub fn deep_clone(&self) -> Msg {
        Msg {
            payload: Payload::from_slice(&self.payload),
            payload64: Payload64::from_slice(&self.payload64),
            ..self.clone()
        }
    }
}

/// One node of a distributed algorithm (engine-agnostic; see module docs).
pub trait NodeState: Send {
    /// May this node start its next local iteration now? Async algorithms
    /// return `true` unconditionally; synchronous ones gate on messages.
    fn ready(&self) -> bool;

    /// One local iteration: consume buffered messages, call the oracle,
    /// update state, append outgoing messages to `out`. Returns the
    /// minibatch loss when a gradient was computed this wake (engines log
    /// it), or `None` for pure-communication steps.
    fn wake(&mut self, oracle: &mut dyn NodeOracle, out: &mut Vec<Msg>)
            -> Option<f32>;

    /// Deliver one message (any order, any delay). Protocol replies (e.g.
    /// AD-PSGD's exchange leg) are appended to `out`.
    fn receive(&mut self, msg: Msg, out: &mut Vec<Msg>);

    /// This node's current model estimate (de-biased where applicable).
    fn param(&self) -> &[f32];

    /// Local iteration counter t.
    fn local_iter(&self) -> u64;

    /// Does one `wake` include a gradient computation? (Ring-AllReduce
    /// communication micro-steps don't; engines charge compute time only
    /// when this is true for the upcoming wake.)
    fn wake_computes_gradient(&self) -> bool {
        true
    }

    /// Update the step size (γ^t schedules — Algorithm 1 allows a
    /// time-varying γ; the paper's §VI-B runs decay 10× per 30 epochs).
    fn set_gamma(&mut self, gamma: f32);

    /// The link layer could not send this message (sender-side loss
    /// emulation or an unacked channel — §VI ¶1: the *node* decides to
    /// send or discard, so the sender always knows). Default: drop.
    /// Mass-conserving protocols (OSGP's push-sum) reabsorb the payload.
    fn on_send_failed(&mut self, _msg: Msg) {}

    /// Concrete-type escape hatch for engine-level invariant probes
    /// (the fuzzer's conservation oracle downcasts to
    /// [`RFastNode`](rfast::RFastNode) through this). Algorithms that
    /// expose no probe-able internals keep the `None` default.
    fn as_any(&self) -> Option<&dyn std::any::Any> {
        None
    }
}

/// Algorithm selector (CLI / benches).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AlgoKind {
    RFast,
    /// R-FAST with the robust ρ/ρ̃ scheme replaced by one-shot z-deltas —
    /// the ablation isolating what robust tracking buys under packet loss.
    RFastNaive,
    PushPull,
    DPsgd,
    SAb,
    AdPsgd,
    Osgp,
    RingAllReduce,
}

impl AlgoKind {
    pub fn name(&self) -> &'static str {
        match self {
            AlgoKind::RFast => "R-FAST",
            AlgoKind::RFastNaive => "R-FAST(naive-GT)",
            AlgoKind::PushPull => "Push-Pull",
            AlgoKind::DPsgd => "D-PSGD",
            AlgoKind::SAb => "S-AB",
            AlgoKind::AdPsgd => "AD-PSGD",
            AlgoKind::Osgp => "OSGP",
            AlgoKind::RingAllReduce => "Ring-AllReduce",
        }
    }

    pub fn from_name(s: &str) -> Option<AlgoKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "rfast" | "r-fast" => AlgoKind::RFast,
            "rfast-naive" | "naive" | "r-fast(naive-gt)" => AlgoKind::RFastNaive,
            "pushpull" | "push-pull" => AlgoKind::PushPull,
            "dpsgd" | "d-psgd" => AlgoKind::DPsgd,
            "sab" | "s-ab" => AlgoKind::SAb,
            "adpsgd" | "ad-psgd" => AlgoKind::AdPsgd,
            "osgp" => AlgoKind::Osgp,
            "allreduce" | "ring-allreduce" => AlgoKind::RingAllReduce,
            _ => return None,
        })
    }

    /// Is the algorithm fully asynchronous (nodes never block)?
    pub fn is_async(&self) -> bool {
        matches!(
            self,
            AlgoKind::RFast | AlgoKind::RFastNaive | AlgoKind::AdPsgd | AlgoKind::Osgp
        )
    }

    /// May the link layer drop this algorithm's messages? (Paper §VI ¶1:
    /// packet loss is emulated for the asynchronous algorithms only —
    /// synchronous ones would deadlock.)
    pub fn tolerates_loss(&self) -> bool {
        self.is_async()
    }

    /// Build the per-node state machines over a topology.
    ///
    /// `x0` is the shared initial parameter vector; `gamma` the step size.
    /// D-PSGD / AD-PSGD require an undirected doubly-stochastic graph and
    /// therefore ignore the directed structure of `topo`, building a
    /// Metropolis ring over the same node count (exactly the paper's setup:
    /// "We run D-PSGD and AD-PSGD over an undirected ring graph").
    pub fn build(&self, topo: &Topology, x0: &[f32], gamma: f32,
                 seed: u64) -> Vec<Box<dyn NodeState>> {
        let n = topo.n();
        match self {
            AlgoKind::RFast => rfast::build(topo, x0, gamma, RFastParams {
                robust: true,
            }),
            AlgoKind::RFastNaive => rfast::build(topo, x0, gamma, RFastParams {
                robust: false,
            }),
            AlgoKind::PushPull => push_pull::build(topo, x0, gamma),
            AlgoKind::SAb => sab::build(topo, x0, gamma),
            AlgoKind::DPsgd => dpsgd::build(n, x0, gamma),
            AlgoKind::AdPsgd => adpsgd::build(n, x0, gamma, seed),
            AlgoKind::Osgp => osgp::build(topo, x0, gamma),
            AlgoKind::RingAllReduce => allreduce::build(n, x0, gamma),
        }
    }
}

/// Mean parameter across nodes (the x̄ the paper evaluates).
pub fn mean_param(nodes: &[Box<dyn NodeState>], out: &mut Vec<f32>) {
    let p = nodes[0].param().len();
    out.clear();
    out.resize(p, 0.0);
    for node in nodes {
        crate::linalg::axpy(out, 1.0, node.param());
    }
    crate::linalg::scale(out, 1.0 / nodes.len() as f32);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_name_roundtrip() {
        for k in [
            AlgoKind::RFast,
            AlgoKind::RFastNaive,
            AlgoKind::PushPull,
            AlgoKind::DPsgd,
            AlgoKind::SAb,
            AlgoKind::AdPsgd,
            AlgoKind::Osgp,
            AlgoKind::RingAllReduce,
        ] {
            let lower = k.name().to_ascii_lowercase();
            assert_eq!(AlgoKind::from_name(&lower), Some(k), "{lower}");
        }
        assert_eq!(AlgoKind::from_name("nope"), None);
    }

    #[test]
    fn async_set_matches_paper() {
        assert!(AlgoKind::RFast.is_async());
        assert!(AlgoKind::AdPsgd.is_async());
        assert!(AlgoKind::Osgp.is_async());
        assert!(!AlgoKind::DPsgd.is_async());
        assert!(!AlgoKind::RingAllReduce.is_async());
        assert!(!AlgoKind::SAb.is_async());
        assert!(!AlgoKind::PushPull.is_async());
    }

    #[test]
    fn builders_produce_n_nodes() {
        let topo = Topology::ring(5);
        let x0 = vec![0.0f32; 8];
        for k in [
            AlgoKind::RFast,
            AlgoKind::RFastNaive,
            AlgoKind::PushPull,
            AlgoKind::DPsgd,
            AlgoKind::SAb,
            AlgoKind::AdPsgd,
            AlgoKind::Osgp,
            AlgoKind::RingAllReduce,
        ] {
            let nodes = k.build(&topo, &x0, 0.1, 1);
            assert_eq!(nodes.len(), 5, "{}", k.name());
            for nd in &nodes {
                assert_eq!(nd.param().len(), 8);
                assert_eq!(nd.local_iter(), 0);
            }
        }
    }
}
