//! Flat-vector linear algebra — the L3 hot path.
//!
//! Every R-FAST state mutation is an O(p) dense-vector operation (the model
//! lives in a flat `Vec<f32>`, matching the paper's x, z, ρ ∈ R^p). These
//! routines are written so LLVM auto-vectorizes them (slice-of-equal-length
//! idiom, no bounds checks in the loop body) and the per-wake hot loop in
//! `algo::rfast` performs **zero allocations** — see EXPERIMENTS.md §Perf.

/// y += alpha * x
#[inline]
pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
    assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * *xi;
    }
}

/// y = alpha * x (overwrite)
#[inline]
pub fn scale_into(y: &mut [f32], alpha: f32, x: &[f32]) {
    assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi = alpha * *xi;
    }
}

/// x *= alpha
#[inline]
pub fn scale(x: &mut [f32], alpha: f32) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// out = a - b
#[inline]
pub fn sub_into(out: &mut [f32], a: &[f32], b: &[f32]) {
    assert_eq!(out.len(), a.len());
    assert_eq!(out.len(), b.len());
    for ((o, ai), bi) in out.iter_mut().zip(a.iter()).zip(b.iter()) {
        *o = ai - bi;
    }
}

/// y += (a - b), the ρ-difference accumulation of R-FAST step (S2b):
/// fused so the difference never materializes.
#[inline]
pub fn add_diff(y: &mut [f32], a: &[f32], b: &[f32]) {
    assert_eq!(y.len(), a.len());
    assert_eq!(y.len(), b.len());
    for ((yi, ai), bi) in y.iter_mut().zip(a.iter()).zip(b.iter()) {
        *yi += ai - bi;
    }
}

/// dot(a, b), block-compensated: full-speed f32 SIMD lanes inside
/// 4096-element blocks, each block's partial sum promoted to an f64
/// accumulator. Rounding error is O(√block·ε_f32) per block instead of
/// O(√p) — at p ~ 1e8 the result keeps ~6 significant digits while the
/// inner loop runs at axpy speed (5-6× faster than a serial f64 chain;
/// EXPERIMENTS.md §Perf).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    const LANES: usize = 8;
    const BLOCK: usize = 4096;
    let mut total = 0.0f64;
    let mut i = 0;
    while i < a.len() {
        let end = (i + BLOCK).min(a.len());
        let (ab, bb) = (&a[i..end], &b[i..end]);
        let chunks = ab.len() / LANES;
        let mut acc = [0.0f32; LANES];
        for c in 0..chunks {
            let base = c * LANES;
            for l in 0..LANES {
                acc[l] += ab[base + l] * bb[base + l];
            }
        }
        let mut block = 0.0f64;
        for l in 0..LANES {
            block += acc[l] as f64;
        }
        for k in chunks * LANES..ab.len() {
            block += ab[k] as f64 * bb[k] as f64;
        }
        total += block;
        i = end;
    }
    total
}

/// ||x||₂
#[inline]
pub fn norm(x: &[f32]) -> f64 {
    dot(x, x).sqrt()
}

/// ||a − b||₂ without materializing the difference (same unrolled
/// accumulation as [`dot`]).
#[inline]
pub fn dist(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    const LANES: usize = 8;
    let chunks = a.len() / LANES;
    let mut acc = [0.0f64; LANES];
    for c in 0..chunks {
        let base = c * LANES;
        for l in 0..LANES {
            let d = (a[base + l] - b[base + l]) as f64;
            acc[l] += d * d;
        }
    }
    let mut total = 0.0f64;
    for l in 0..LANES {
        total += acc[l];
    }
    for i in chunks * LANES..a.len() {
        let d = (a[i] - b[i]) as f64;
        total += d * d;
    }
    total.sqrt()
}

/// out = Σ_k w_k · x_k — the consensus mixing step (S2a). `out` is
/// overwritten; the first term initializes it so no zero-fill pass is needed.
pub fn weighted_sum_into(out: &mut [f32], terms: &[(f32, &[f32])]) {
    assert!(!terms.is_empty());
    let (w0, x0) = terms[0];
    scale_into(out, w0, x0);
    for &(w, x) in &terms[1..] {
        axpy(out, w, x);
    }
}

/// Mean of a set of equal-length vectors into `out`.
pub fn mean_into(out: &mut [f32], xs: &[&[f32]]) {
    assert!(!xs.is_empty());
    out.copy_from_slice(xs[0]);
    for x in &xs[1..] {
        axpy(out, 1.0, x);
    }
    scale(out, 1.0 / xs.len() as f32);
}

/// Squared consensus error: Σ_i ||x_i − x̄||² (paper's ‖x − 1x̄ᵀ‖²_F).
pub fn consensus_error_sq(xs: &[&[f32]]) -> f64 {
    let p = xs[0].len();
    let mut mean = vec![0.0f32; p];
    mean_into(&mut mean, xs);
    xs.iter().map(|x| {
        let d = dist(x, &mean);
        d * d
    }).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_basic() {
        let mut y = vec![1.0, 2.0, 3.0];
        axpy(&mut y, 2.0, &[10.0, 20.0, 30.0]);
        assert_eq!(y, vec![21.0, 42.0, 63.0]);
    }

    #[test]
    fn scale_into_overwrites() {
        let mut y = vec![9.0; 3];
        scale_into(&mut y, 0.5, &[2.0, 4.0, 6.0]);
        assert_eq!(y, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn add_diff_matches_two_step() {
        let mut y1 = vec![1.0, 1.0];
        let mut y2 = y1.clone();
        let a = [5.0, 7.0];
        let b = [2.0, 3.0];
        add_diff(&mut y1, &a, &b);
        axpy(&mut y2, 1.0, &a);
        axpy(&mut y2, -1.0, &b);
        assert_eq!(y1, y2);
    }

    #[test]
    fn dot_f64_accumulation() {
        let a = vec![1e-4f32; 1_000_000];
        let d = dot(&a, &a);
        assert!((d - 1e-2).abs() < 1e-6, "{d}");
    }

    #[test]
    fn dist_matches_norm_of_diff() {
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, 6.0, 3.0];
        assert!((dist(&a, &b) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn weighted_sum_simple() {
        let mut out = vec![0.0; 2];
        let x1 = [1.0, 0.0];
        let x2 = [0.0, 1.0];
        weighted_sum_into(&mut out, &[(0.25, &x1), (0.75, &x2)]);
        assert_eq!(out, vec![0.25, 0.75]);
    }

    #[test]
    fn mean_and_consensus_error() {
        let a = vec![0.0f32, 0.0];
        let b = vec![2.0f32, 2.0];
        let refs: Vec<&[f32]> = vec![&a, &b];
        let mut m = vec![0.0; 2];
        mean_into(&mut m, &refs);
        assert_eq!(m, vec![1.0, 1.0]);
        // each node is sqrt(2) from the mean ⇒ total squared = 4
        assert!((consensus_error_sq(&refs) - 4.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn axpy_len_mismatch_panics() {
        let mut y = vec![0.0; 2];
        axpy(&mut y, 1.0, &[1.0; 3]);
    }
}
