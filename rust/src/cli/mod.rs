//! Hand-rolled CLI argument parsing (clap is unavailable offline).
//!
//! Grammar: `repro <subcommand> [--key value]... [--flag]...`
//! Values may also be attached as `--key=value`.

use std::collections::BTreeMap;

/// Parsed command line: subcommand + options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: String,
    pub opts: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args, String> {
        let mut it = args.into_iter().peekable();
        let subcommand = it.next().unwrap_or_else(|| "help".to_string());
        Args::parse_rest(subcommand, it)
    }

    /// Parse options only — no subcommand (the `examples/` entry points).
    pub fn parse_opts<I: IntoIterator<Item = String>>(args: I) -> Result<Args, String> {
        Args::parse_rest(String::new(), args.into_iter().peekable())
    }

    fn parse_rest(
        subcommand: String,
        mut it: std::iter::Peekable<impl Iterator<Item = String>>,
    ) -> Result<Args, String> {
        let mut out = Args { subcommand, ..Default::default() };
        while let Some(a) = it.next() {
            let Some(key) = a.strip_prefix("--") else {
                return Err(format!("expected --option, got {a:?}"));
            };
            if let Some((k, v)) = key.split_once('=') {
                out.opts.insert(k.to_string(), v.to_string());
            } else if it.peek().map(|nx| !nx.starts_with("--")).unwrap_or(false)
            {
                // lint:allow(panic-path): peek() above just proved the next item exists
                out.opts.insert(key.to_string(), it.next().unwrap());
            } else {
                out.flags.push(key.to_string());
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn parse_num<T: std::str::FromStr>(&self, key: &str,
                                           default: T) -> Result<T, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|_| format!("--{key}: cannot parse {v:?}")),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Keys consumed as config overrides: everything not in `known`.
    pub fn unknown_keys<'a>(&'a self, known: &[&str]) -> Vec<&'a str> {
        self.opts
            .keys()
            .map(|s| s.as_str())
            .filter(|k| !known.contains(k))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn basic_parse() {
        let a = parse(&["train", "--algo", "rfast", "--nodes=8", "--verbose"]);
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.get("algo"), Some("rfast"));
        assert_eq!(a.get("nodes"), Some("8"));
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn numbers_and_defaults() {
        let a = parse(&["x", "--gamma", "0.5"]);
        assert_eq!(a.parse_num("gamma", 0.0f32).unwrap(), 0.5);
        assert_eq!(a.parse_num("seed", 42u64).unwrap(), 42);
        assert!(a.parse_num::<f32>("gamma", 0.0).is_ok());
        let b = parse(&["x", "--gamma", "abc"]);
        assert!(b.parse_num::<f32>("gamma", 0.0).is_err());
    }

    #[test]
    fn empty_is_help() {
        let a = Args::parse(std::iter::empty::<String>()).unwrap();
        assert_eq!(a.subcommand, "help");
    }

    #[test]
    fn rejects_positional_after_subcommand() {
        assert!(Args::parse(["x".to_string(), "oops".to_string()]).is_err());
    }

    #[test]
    fn unknown_keys_listed() {
        let a = parse(&["x", "--algo", "rfast", "--zzz", "1"]);
        assert_eq!(a.unknown_keys(&["algo"]), vec!["zzz"]);
    }
}
