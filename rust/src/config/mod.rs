//! Experiment configuration: defaults, a `key = value` file format, and
//! CLI-style overrides (serde/clap are unavailable offline — DESIGN.md §6).
//!
//! The timing model mirrors the paper's testbed (§VI-B): per-node compute
//! time (lognormal jitter), per-link latency, Bernoulli packet loss with
//! send-until-ack, and an optional straggler (a node slowed by a factor).
//! Defaults are calibrated so grad-step : link-latency ≈ a ResNet-50 step
//! (~200 ms) : intra-server transfer (~20 ms), matching the substitution
//! argument of DESIGN.md §4.

use crate::scenario::Scenario;
use std::path::Path;

/// All knobs of one simulated/threaded training run.
#[derive(Clone, Debug, PartialEq)]
pub struct SimConfig {
    /// Master seed; every stream (node paces, links, batchers) derives
    /// deterministically from it.
    pub seed: u64,
    /// Step size γ (paper: 1e-3 logreg, 0.1 ResNet).
    pub gamma: f32,
    /// Mean compute time per local iteration, seconds of virtual time.
    pub compute_mean: f64,
    /// Lognormal sigma of compute jitter (0 = deterministic pace).
    pub compute_jitter: f64,
    /// Straggler: (node, slowdown factor ≥ 1). Paper §VI-B slows one GPU.
    pub straggler: Option<(usize, f64)>,
    /// Mean one-way link latency, seconds.
    pub link_latency: f64,
    /// Lognormal sigma of latency jitter.
    pub latency_jitter: f64,
    /// Hard cap on link latency (enforces Assumption 3's bounded delay D).
    pub latency_cap: f64,
    /// Per-message Bernoulli drop probability (async algorithms only; the
    /// sender withholds re-sends until the ack arrives — paper §VI ¶1).
    pub loss_prob: f64,
    /// Minibatch size per node.
    pub batch: usize,
    /// Evaluate / record the loss every this many seconds of virtual time.
    pub eval_every: f64,
    /// Label-skew α of the partition (0 = IID).
    pub skew_alpha: f64,
    /// Step-size schedule: multiply γ by `factor` every `interval` epochs
    /// (paper §VI-B: 0.1 every 30 epochs). `None` = constant γ.
    pub gamma_decay: Option<(f64, f32)>,
    /// Declarative fault-injection scenario (straggler schedules, loss and
    /// latency ramps, churn, bandwidth caps — [`crate::scenario`]). Layers
    /// on top of the scalar knobs above: the scenario's ramps override
    /// `loss_prob`/latency once their first phase starts, and its
    /// straggler factors multiply with `straggler`. Drives both engines
    /// through the shared [`crate::faults`] layer — virtual seconds in the
    /// simulator, wall seconds since run start in the threaded runner.
    pub scenario: Option<Scenario>,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            seed: 1,
            gamma: 1e-3,
            compute_mean: 0.2,
            compute_jitter: 0.08,
            straggler: None,
            link_latency: 0.02,
            latency_jitter: 0.25,
            latency_cap: 0.5,
            loss_prob: 0.0,
            batch: 32,
            eval_every: 5.0,
            skew_alpha: 0.0,
            gamma_decay: None,
            scenario: None,
        }
    }
}

impl SimConfig {
    /// Paper §VI-A (logreg on CPU cores): fast steps, fast links.
    pub fn logreg_paper() -> SimConfig {
        SimConfig {
            gamma: 1e-3,
            compute_mean: 0.01,
            compute_jitter: 0.10,
            link_latency: 0.002,
            latency_cap: 0.05,
            eval_every: 0.25,
            ..SimConfig::default()
        }
    }

    /// Paper §VI-B (ResNet-50 proxy on 8 GPUs): ~200 ms steps. The jitter
    /// (lognormal σ=0.25) calibrates the per-step variance of a loaded GPU
    /// server — it is what makes synchronous barriers cost E[max of n]
    /// ≈ 1.4-1.5× the mean step, the paper's observed 1.5-2× gap between
    /// R-FAST and the synchronous baselines.
    pub fn resnet_paper() -> SimConfig {
        SimConfig {
            gamma: 0.05,
            compute_mean: 0.2,
            compute_jitter: 0.25,
            link_latency: 0.02,
            latency_cap: 0.5,
            eval_every: 20.0,
            ..SimConfig::default()
        }
    }

    /// Apply one `key=value` override; returns an error string for unknown
    /// keys or malformed values.
    pub fn apply_kv(&mut self, key: &str, value: &str) -> Result<(), String> {
        fn p<T: std::str::FromStr>(v: &str, key: &str) -> Result<T, String> {
            v.trim()
                .parse::<T>()
                .map_err(|_| format!("bad value {v:?} for key {key:?}"))
        }
        match key.trim() {
            "seed" => self.seed = p(value, key)?,
            "gamma" => self.gamma = p(value, key)?,
            "compute_mean" => self.compute_mean = p(value, key)?,
            "compute_jitter" => self.compute_jitter = p(value, key)?,
            "link_latency" => self.link_latency = p(value, key)?,
            "latency_jitter" => self.latency_jitter = p(value, key)?,
            "latency_cap" => self.latency_cap = p(value, key)?,
            "loss_prob" => self.loss_prob = p(value, key)?,
            "batch" => self.batch = p(value, key)?,
            "eval_every" => self.eval_every = p(value, key)?,
            "skew_alpha" => self.skew_alpha = p(value, key)?,
            "straggler" => {
                // "node:factor", e.g. "3:5.0"; "none" clears it
                if value.trim() == "none" {
                    self.straggler = None;
                } else {
                    let (node, factor) = value
                        .split_once(':')
                        .ok_or_else(|| format!("straggler wants node:factor, got {value:?}"))?;
                    self.straggler =
                        Some((p(node, "straggler.node")?, p(factor, "straggler.factor")?));
                }
            }
            "scenario" => {
                // preset name or a path to a scenario .json; "none" clears
                if value.trim() == "none" {
                    self.scenario = None;
                } else {
                    self.scenario = Some(Scenario::resolve(value.trim())?);
                }
            }
            other => return Err(format!("unknown config key {other:?}")),
        }
        Ok(())
    }

    /// Parse a config file of `key = value` lines (# comments, blank lines).
    pub fn from_file(path: &Path) -> Result<SimConfig, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let mut cfg = SimConfig::default();
        cfg.apply_text(&text)?;
        Ok(cfg)
    }

    pub fn apply_text(&mut self, text: &str) -> Result<(), String> {
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            self.apply_kv(k, v)
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
        }
        Ok(())
    }

    /// Validate ranges; called by the launcher before running.
    pub fn validate(&self) -> Result<(), String> {
        if !(self.gamma > 0.0) {
            return Err(format!("gamma must be > 0, got {}", self.gamma));
        }
        if self.compute_mean <= 0.0 || self.link_latency < 0.0 {
            return Err("compute_mean must be > 0 and link_latency ≥ 0".into());
        }
        if !(0.0..1.0).contains(&self.loss_prob) {
            return Err(format!("loss_prob must be in [0,1), got {}", self.loss_prob));
        }
        if let Some((_, f)) = self.straggler {
            if f < 1.0 {
                return Err(format!("straggler factor must be ≥ 1, got {f}"));
            }
        }
        if self.batch == 0 {
            return Err("batch must be ≥ 1".into());
        }
        if self.latency_cap < self.link_latency {
            return Err("latency_cap must be ≥ link_latency".into());
        }
        if let Some(s) = &self.scenario {
            // node-count-independent checks; the simulator re-validates
            // against the topology's n
            s.validate(None)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        SimConfig::default().validate().unwrap();
        SimConfig::logreg_paper().validate().unwrap();
        SimConfig::resnet_paper().validate().unwrap();
    }

    #[test]
    fn kv_overrides() {
        let mut c = SimConfig::default();
        c.apply_kv("gamma", "0.5").unwrap();
        c.apply_kv("straggler", "3:5.0").unwrap();
        c.apply_kv("batch", "64").unwrap();
        assert_eq!(c.gamma, 0.5);
        assert_eq!(c.straggler, Some((3, 5.0)));
        assert_eq!(c.batch, 64);
        c.apply_kv("straggler", "none").unwrap();
        assert_eq!(c.straggler, None);
    }

    #[test]
    fn unknown_key_rejected() {
        let mut c = SimConfig::default();
        assert!(c.apply_kv("nope", "1").is_err());
        assert!(c.apply_kv("gamma", "abc").is_err());
    }

    #[test]
    fn scenario_key_resolves_presets() {
        let mut c = SimConfig::default();
        c.apply_kv("scenario", "lossy_30pct").unwrap();
        let s = c.scenario.as_ref().expect("scenario set");
        assert_eq!(s.name, "lossy_30pct");
        assert_eq!(s.loss_prob(0.0, 10.0), 0.30);
        c.validate().unwrap();
        c.apply_kv("scenario", "none").unwrap();
        assert!(c.scenario.is_none());
        assert!(c.apply_kv("scenario", "no_such_preset").is_err());
    }

    #[test]
    fn text_parsing_with_comments() {
        let mut c = SimConfig::default();
        c.apply_text("# comment\n gamma = 0.25 # inline\n\nseed=9\n")
            .unwrap();
        assert_eq!(c.gamma, 0.25);
        assert_eq!(c.seed, 9);
        assert!(c.apply_text("gamma 0.5").is_err());
    }

    #[test]
    fn validation_catches_bad_ranges() {
        let mut c = SimConfig::default();
        c.gamma = -1.0;
        assert!(c.validate().is_err());
        let mut c = SimConfig::default();
        c.loss_prob = 1.5;
        assert!(c.validate().is_err());
        let mut c = SimConfig::default();
        c.straggler = Some((0, 0.5));
        assert!(c.validate().is_err());
        let mut c = SimConfig::default();
        c.latency_cap = 0.0;
        c.link_latency = 0.1;
        assert!(c.validate().is_err());
    }
}
