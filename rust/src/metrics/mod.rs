//! Run metrics: loss curves, tables, CSV/JSON emit.
//!
//! Benches regenerate the paper's figures as [`Series`] (x = virtual time
//! or epoch, y = loss/accuracy) and tables via [`Table`] — the same
//! rows/columns the paper reports, printed to stdout and written under
//! `runs/`.

use crate::jsonio::Json;
use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

/// One curve of an experiment figure.
#[derive(Clone, Debug, Default)]
pub struct Series {
    pub name: String,
    pub xlabel: String,
    pub ylabel: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: &str, xlabel: &str, ylabel: &str) -> Series {
        Series {
            name: name.to_string(),
            xlabel: xlabel.to_string(),
            ylabel: ylabel.to_string(),
            points: Vec::new(),
        }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }

    /// First x at which y drops to/below the threshold (time-to-target, the
    /// paper's Fig 4b metric). Linear interpolation between samples.
    pub fn time_to_reach(&self, threshold: f64) -> Option<f64> {
        let mut prev: Option<(f64, f64)> = None;
        for &(x, y) in &self.points {
            if y <= threshold {
                if let Some((px, py)) = prev {
                    if py > threshold && (py - y).abs() > 1e-30 {
                        let t = (py - threshold) / (py - y);
                        return Some(px + t * (x - px));
                    }
                }
                return Some(x);
            }
            prev = Some((x, y));
        }
        None
    }

    pub fn last_y(&self) -> Option<f64> {
        self.points.last().map(|&(_, y)| y)
    }

    /// Minimum y over the curve (best loss seen).
    pub fn min_y(&self) -> Option<f64> {
        self.points
            .iter()
            .map(|&(_, y)| y)
            .min_by(|a, b| a.total_cmp(b))
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", self.name.as_str().into()),
            ("xlabel", self.xlabel.as_str().into()),
            ("ylabel", self.ylabel.as_str().into()),
            (
                "points",
                Json::Arr(
                    self.points
                        .iter()
                        .map(|&(x, y)| Json::Arr(vec![x.into(), y.into()]))
                        .collect(),
                ),
            ),
        ])
    }
}

/// A full run report: named series + scalar summary values.
#[derive(Clone, Debug, Default)]
pub struct Report {
    pub label: String,
    pub series: BTreeMap<String, Series>,
    pub scalars: BTreeMap<String, f64>,
    /// Final distance to optimum (quadratic oracles expose x*).
    pub final_gap: Option<f64>,
}

impl Report {
    pub fn new(label: &str) -> Report {
        Report { label: label.to_string(), ..Default::default() }
    }

    pub fn series_mut(&mut self, name: &str, xlabel: &str,
                      ylabel: &str) -> &mut Series {
        self.series
            .entry(name.to_string())
            .or_insert_with(|| Series::new(name, xlabel, ylabel))
    }

    pub fn set_scalar(&mut self, name: &str, v: f64) {
        self.scalars.insert(name.to_string(), v);
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", self.label.as_str().into()),
            (
                "series",
                Json::Obj(
                    self.series
                        .iter()
                        .map(|(k, s)| (k.clone(), s.to_json()))
                        .collect(),
                ),
            ),
            (
                "scalars",
                Json::Obj(
                    self.scalars
                        .iter()
                        .map(|(k, &v)| (k.clone(), Json::Num(v)))
                        .collect(),
                ),
            ),
        ])
    }

    /// Write `runs/<name>.json`.
    pub fn save(&self, dir: &Path, name: &str) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        let mut f = std::fs::File::create(dir.join(format!("{name}.json")))?;
        f.write_all(self.to_json().to_string().as_bytes())
    }
}

/// Write several series as one CSV: `x, <name1>, <name2>, ...` aligned on
/// the union of x values (empty cells where a series has no sample).
pub fn save_series_csv(path: &Path, series: &[&Series]) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut xs: Vec<f64> = series
        .iter()
        .flat_map(|s| s.points.iter().map(|&(x, _)| x))
        .collect();
    xs.sort_by(f64::total_cmp);
    xs.dedup();
    let mut f = std::fs::File::create(path)?;
    write!(f, "x")?;
    for s in series {
        write!(f, ",{}", s.name)?;
    }
    writeln!(f)?;
    for &x in &xs {
        write!(f, "{x}")?;
        for s in series {
            match s.points.iter().find(|&&(px, _)| px == x) {
                Some(&(_, y)) => write!(f, ",{y}")?,
                None => write!(f, ",")?,
            }
        }
        writeln!(f)?;
    }
    Ok(())
}

/// Fixed-width console table (paper-style rows).
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut width = vec![0usize; ncol];
        for (c, h) in self.headers.iter().enumerate() {
            width[c] = width[c].max(h.chars().count());
        }
        for row in &self.rows {
            for (c, cell) in row.iter().enumerate() {
                width[c] = width[c].max(cell.chars().count());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], width: &[usize]| {
            let mut line = String::new();
            for (c, cell) in cells.iter().enumerate() {
                let pad = width[c] - cell.chars().count();
                line.push_str("| ");
                line.push_str(cell);
                line.push_str(&" ".repeat(pad + 1));
            }
            line.push('|');
            line
        };
        out.push_str(&fmt_row(&self.headers, &width));
        out.push('\n');
        let total: usize = width.iter().map(|w| w + 3).sum::<usize>() + 1;
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &width));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format seconds of virtual time like the paper's tables ("time(mins)").
pub fn fmt_mins(seconds: f64) -> String {
    format!("{:.1}", seconds / 60.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_to_reach_interpolates() {
        let mut s = Series::new("l", "t", "loss");
        s.push(0.0, 1.0);
        s.push(10.0, 0.5);
        s.push(20.0, 0.1);
        let t = s.time_to_reach(0.3).unwrap();
        assert!((t - 15.0).abs() < 1e-9, "{t}");
        assert_eq!(s.time_to_reach(0.05), None);
        assert_eq!(s.time_to_reach(2.0), Some(0.0));
    }

    #[test]
    fn series_json_roundtrip() {
        let mut s = Series::new("a", "x", "y");
        s.push(1.0, 2.0);
        let j = s.to_json();
        assert_eq!(j.at(&["name"]).unwrap().as_str(), Some("a"));
        assert_eq!(
            j.at(&["points"]).unwrap().as_arr().unwrap()[0].as_arr().unwrap()[1],
            Json::Num(2.0)
        );
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("T", &["algo", "time"]);
        t.row(vec!["rfast".into(), "1.0".into()]);
        t.row(vec!["ring-allreduce".into(), "2.5".into()]);
        let r = t.render();
        assert!(r.contains("== T =="));
        assert!(r.contains("| rfast"));
        let lines: Vec<&str> = r.lines().collect();
        // all data lines same width
        assert_eq!(lines[1].len(), lines[3].len());
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    fn csv_union_of_x() {
        let dir = std::env::temp_dir().join("rfast_test_csv");
        let mut a = Series::new("a", "x", "y");
        a.push(0.0, 1.0);
        a.push(2.0, 3.0);
        let mut b = Series::new("b", "x", "y");
        b.push(1.0, 5.0);
        let path = dir.join("out.csv");
        save_series_csv(&path, &[&a, &b]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "x,a,b");
        assert_eq!(lines.len(), 4);
        assert!(lines[2].starts_with("1,"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn report_scalars_and_save() {
        let mut r = Report::new("test");
        r.set_scalar("acc", 0.5);
        r.series_mut("loss", "t", "l").push(0.0, 1.0);
        let dir = std::env::temp_dir().join("rfast_test_report");
        r.save(&dir, "r1").unwrap();
        let text = std::fs::read_to_string(dir.join("r1.json")).unwrap();
        let j = crate::jsonio::parse(&text).unwrap();
        assert_eq!(j.at(&["scalars", "acc"]).unwrap().as_f64(), Some(0.5));
        std::fs::remove_dir_all(&dir).ok();
    }
}
