//! Perf-baseline harness (EXPERIMENTS.md): the hot-path micro suite and
//! the fig4b-style scaling sweep behind `repro bench-baseline` and
//! `cargo bench --bench micro_hotpath`.
//!
//! Three pieces:
//!
//! * [`CountingAllocator`] — a `GlobalAlloc` wrapper the *binaries*
//!   install (`#[global_allocator]` in `repro` and `micro_hotpath`) so
//!   [`measure`] can report allocations-per-iteration alongside ns/iter.
//!   When it is not installed (e.g. under `cargo test`), the allocation
//!   columns degrade to `null`/`None` — timing still works.
//! * [`hotpath_suite`] / [`scaling_sweep`] — the measured workloads:
//!   every per-wake cost center, and an 8→64-node R-FAST run on the
//!   binary tree (the Fig 4b setup) at a fixed epoch budget
//!   (`RFAST_BENCH_EPOCHS`).
//! * the `BENCH_*.json` emit + schema validators — the machine-readable
//!   perf trajectory every later optimisation PR is measured against
//!   (schema documented in EXPERIMENTS.md §Schema; the CI bench-smoke
//!   step fails on schema-invalid output).

use crate::algo::{AlgoKind, NodeState};
use crate::exp::{Experiment, QuadSpec, Stop, Workload};
use crate::graph::Topology;
use crate::jsonio::Json;
use crate::oracle::{GradOracle, LogRegOracle, MlpOracle, NodeOracle,
                    QuadraticOracle};
use crate::prng::Rng;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// Schema tag of `BENCH_hotpath.json` (bump on breaking changes).
pub const HOTPATH_SCHEMA: &str = "rfast-bench-hotpath/v1";
/// Schema tag of `BENCH_scaling.json`. v2: per-point `topology` and
/// `workload` strings (the sweep is no longer binary-tree/logreg-only).
pub const SCALING_SCHEMA: &str = "rfast-bench-scaling/v2";
/// Node counts of the baseline scaling sweep (binary tree, Fig 4b's
/// topology, 8→64 nodes).
pub const SCALING_NODES: &[usize] = &[8, 16, 32, 64];

/// One entry of the scaling sweep: a topology spec
/// ([`Topology::from_spec`] grammar) and a workload name at a node count.
#[derive(Clone, Copy, Debug)]
pub struct ScalingSpec {
    pub nodes: usize,
    pub topology: &'static str,
    pub workload: &'static str,
}

/// The sparse-era extension of the sweep (DESIGN.md §13): chain, random
/// tree, and star at 1k–50k nodes. Logreg shards its 10k-sample dataset,
/// so the 50k point switches to the closed-form quadratic workload
/// (steps, not epochs). Gate with `RFAST_BENCH_SCALE_MAX`.
pub const SCALING_LARGE: &[ScalingSpec] = &[
    ScalingSpec { nodes: 1_000, topology: "line", workload: "logreg" },
    ScalingSpec { nodes: 10_000, topology: "tree:random@0:7+random@0:21",
                  workload: "logreg" },
    ScalingSpec { nodes: 50_000, topology: "star", workload: "quadratic" },
];

static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);
static ALLOC_LIVE: AtomicU64 = AtomicU64::new(0);
static ALLOC_PEAK: AtomicU64 = AtomicU64::new(0);

fn track_alloc(bytes: u64) {
    ALLOC_COUNT.fetch_add(1, Ordering::Relaxed); // lint:allow(relaxed-counter): allocator hot path; gauges are read after quiescence (documented Relaxed overhead contract)
    ALLOC_BYTES.fetch_add(bytes, Ordering::Relaxed); // lint:allow(relaxed-counter): allocator hot path; gauges are read after quiescence (documented Relaxed overhead contract)
    let live = ALLOC_LIVE.fetch_add(bytes, Ordering::Relaxed) + bytes; // lint:allow(relaxed-counter): allocator hot path; gauges are read after quiescence (documented Relaxed overhead contract)
    ALLOC_PEAK.fetch_max(live, Ordering::Relaxed); // lint:allow(relaxed-counter): allocator hot path; gauges are read after quiescence (documented Relaxed overhead contract)
}

fn track_dealloc(bytes: u64) {
    // saturating: a buffer allocated before reset_peak() may be freed
    // after it, and the live gauge must not wrap
    let _ = ALLOC_LIVE.fetch_update(Ordering::Relaxed, // lint:allow(relaxed-counter): allocator hot path; gauges are read after quiescence (documented Relaxed overhead contract)
                                    Ordering::Relaxed,
                                    |l| Some(l.saturating_sub(bytes)));
}

/// Allocation-counting global allocator: delegates to [`System`] and
/// keeps running totals of calls and requested bytes plus a live-bytes
/// gauge with a high-water mark (the scale-smoke memory ceiling).
/// Install it in a binary with
/// `#[global_allocator] static A: CountingAllocator = CountingAllocator;`
/// — the overhead is a few relaxed atomic ops per allocation.
pub struct CountingAllocator;

// SAFETY: pure delegation to `System`; the counters never affect the
// returned pointers or layouts.
unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 { // lint:allow(unsync-shared): GlobalAlloc is raw-pointer by API contract; pure delegation to System
        track_alloc(layout.size() as u64);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 { // lint:allow(unsync-shared): GlobalAlloc is raw-pointer by API contract; pure delegation to System
        track_alloc(layout.size() as u64);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, // lint:allow(unsync-shared): GlobalAlloc is raw-pointer by API contract; pure delegation to System
                      new_size: usize) -> *mut u8 { // lint:allow(unsync-shared): GlobalAlloc is raw-pointer by API contract; pure delegation to System
        track_alloc(new_size as u64);
        track_dealloc(layout.size() as u64);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) { // lint:allow(unsync-shared): GlobalAlloc is raw-pointer by API contract; pure delegation to System
        track_dealloc(layout.size() as u64);
        System.dealloc(ptr, layout)
    }
}

/// Running totals of the counting allocator: (allocation calls, bytes
/// requested). Zeros forever when [`CountingAllocator`] is not the
/// installed global allocator.
pub fn alloc_stats() -> (u64, u64) {
    (ALLOC_COUNT.load(Ordering::Relaxed), // lint:allow(relaxed-counter): allocator hot path; gauges are read after quiescence (documented Relaxed overhead contract)
     ALLOC_BYTES.load(Ordering::Relaxed)) // lint:allow(relaxed-counter): allocator hot path; gauges are read after quiescence (documented Relaxed overhead contract)
}

/// (currently live heap bytes, high-water mark since the last
/// [`reset_peak`]). Zeros forever without the counting allocator.
pub fn live_peak_stats() -> (u64, u64) {
    (ALLOC_LIVE.load(Ordering::Relaxed), // lint:allow(relaxed-counter): allocator hot path; gauges are read after quiescence (documented Relaxed overhead contract)
     ALLOC_PEAK.load(Ordering::Relaxed)) // lint:allow(relaxed-counter): allocator hot path; gauges are read after quiescence (documented Relaxed overhead contract)
}

/// Rebase the high-water mark to the current live bytes, so a test can
/// assert a ceiling over just the region it brackets.
pub fn reset_peak() {
    ALLOC_PEAK.store(ALLOC_LIVE.load(Ordering::Relaxed), // lint:allow(relaxed-counter): allocator hot path; gauges are read after quiescence (documented Relaxed overhead contract)
                     Ordering::Relaxed);
}

/// Is [`CountingAllocator`] actually installed as the global allocator?
/// Probed by making a real allocation and watching the counter.
pub fn counting_allocator_active() -> bool {
    let before = alloc_stats().0;
    let v: Vec<u8> = std::hint::black_box(Vec::with_capacity(64));
    drop(v);
    alloc_stats().0 != before
}

/// One measured hot-path entry: ns/iter plus — when the counting
/// allocator is installed — allocations and allocated bytes per
/// iteration.
#[derive(Clone, Debug)]
pub struct HotpathResult {
    /// Stable bench name (the results-log key in EXPERIMENTS.md).
    pub name: String,
    /// Mean wall nanoseconds per iteration.
    pub ns_per_iter: f64,
    /// Iterations timed.
    pub iters: u64,
    /// Heap allocations per iteration (`None` without the counting
    /// allocator).
    pub allocs_per_iter: Option<f64>,
    /// Heap bytes requested per iteration (`None` without the counting
    /// allocator).
    pub alloc_bytes_per_iter: Option<f64>,
}

impl HotpathResult {
    /// One human-readable report line (the console twin of the JSON row).
    pub fn report(&self) -> String {
        let ns = self.ns_per_iter;
        let human = if ns >= 1e6 {
            format!("{:.3} ms", ns / 1e6)
        } else if ns >= 1e3 {
            format!("{:.3} µs", ns / 1e3)
        } else {
            format!("{ns:.1} ns")
        };
        let allocs = match self.allocs_per_iter {
            Some(a) => format!("{a:>10.2} allocs/iter"),
            None => "         - allocs/iter".to_string(),
        };
        format!("{:<44} {:>12}/iter  {}  ({} iters)",
                self.name, human, allocs, self.iters)
    }
}

/// Time a closure — THE micro-bench timing loop of the repo (criterion
/// is unavailable offline, DESIGN.md §6): 3 warmup calls, then doubling
/// batches until `min_time_s` is filled — and attribute the counting
/// allocator's deltas to it. Warmup runs happen before the counter
/// snapshot, so they don't pollute the per-iteration averages.
pub fn measure<F: FnMut()>(name: &str, min_time_s: f64,
                           mut f: F) -> HotpathResult {
    let counted = counting_allocator_active();
    for _ in 0..3 {
        f(); // warmup, outside the counter window
    }
    let (a0, b0) = alloc_stats();
    let start = std::time::Instant::now();
    let mut iters = 0u64;
    let mut batch = 1u64;
    let total_ns = loop {
        for _ in 0..batch {
            f();
        }
        iters += batch;
        let elapsed = start.elapsed();
        if elapsed.as_secs_f64() >= min_time_s {
            break elapsed.as_nanos();
        }
        batch = (batch * 2).min(1 << 20);
    };
    let (a1, b1) = alloc_stats();
    HotpathResult {
        name: name.to_string(),
        ns_per_iter: total_ns as f64 / iters as f64,
        iters,
        allocs_per_iter: counted
            .then(|| (a1 - a0) as f64 / iters as f64),
        alloc_bytes_per_iter: counted
            .then(|| (b1 - b0) as f64 / iters as f64),
    }
}

/// The L3 hot-path suite: every per-wake cost center (EXPERIMENTS.md
/// §Methodology). `quick` shrinks the per-bench timing window for smoke
/// runs (`RFAST_BENCH_QUICK` / CI).
pub fn hotpath_suite(quick: bool) -> Vec<HotpathResult> {
    let mut results: Vec<HotpathResult> = Vec::new();
    let t = if quick { 0.05 } else { 0.3 };

    // ---- linalg primitives at logreg and transformer-e2e sizes ---------
    for &p in &[785usize, 4_236_800] {
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..p).map(|_| rng.f32()).collect();
        let mut y: Vec<f32> = (0..p).map(|_| rng.f32()).collect();
        let label = if p < 1000 { "p=785" } else { "p=4.2M" };
        results.push(measure(&format!("linalg::axpy {label}"), t, || {
            crate::linalg::axpy(std::hint::black_box(&mut y), 0.5,
                                std::hint::black_box(&x));
        }));
        results.push(measure(&format!("linalg::dot  {label}"), t, || {
            std::hint::black_box(crate::linalg::dot(&x, &y));
        }));
        let a = x.clone();
        let b = y.clone();
        let mut z = vec![0.0f32; p];
        results.push(measure(&format!("linalg::add_diff {label}"), t, || {
            crate::linalg::add_diff(std::hint::black_box(&mut z), &a, &b);
        }));
    }

    // ---- full R-FAST wakes (coordination only, p=785) -------------------
    // ring-8: out-degree 1 in both graphs — the no-fan-out floor.
    {
        let topo = Topology::ring(8);
        let quad = QuadraticOracle::heterogeneous(785, 8, 0.5, 2.0, 3);
        let mut set = quad.into_set();
        let mut nodes = AlgoKind::RFast.build(&topo, &vec![0.0; 785], 0.01, 1);
        let mut out = Vec::new();
        results.push(measure("rfast wake+msgs (p=785, ring-8)", t, || {
            nodes[0].wake(set.nodes[0].as_mut(), &mut out);
            out.clear();
        }));
    }
    // exponential-16: out-degree 4 — the broadcast fan-out path the
    // zero-copy fabric collapses from O(out-degree) to O(1) v-payload
    // allocations per wake.
    {
        let topo = Topology::exponential(16);
        let quad = QuadraticOracle::heterogeneous(785, 16, 0.5, 2.0, 3);
        let mut set = quad.into_set();
        let mut nodes = AlgoKind::RFast.build(&topo, &vec![0.0; 785], 0.01, 1);
        let mut out = Vec::new();
        results.push(measure("rfast wake+msgs (p=785, exp-16 deg-4)", t, || {
            nodes[0].wake(set.nodes[0].as_mut(), &mut out);
            out.clear();
        }));
    }

    // ---- gradient oracles ------------------------------------------------
    {
        let o = LogRegOracle::paper_workload(1, 32, 0.0, 5);
        let mut set = o.into_set();
        let theta = vec![0.01f32; set.dim];
        let mut g = vec![0.0f32; set.dim];
        results.push(measure("logreg grad (rust, B=32, d=784)", t, || {
            set.nodes[0].grad(std::hint::black_box(&theta), &mut g);
        }));
    }
    {
        let o = MlpOracle::paper_workload(1, 32, 0.0, 5);
        let mut set = o.into_set();
        let theta = MlpOracle::init_theta(1);
        let mut g = vec![0.0f32; set.dim];
        results.push(measure("mlp grad (rust, B=32, 784-128-64-10)", t, || {
            set.nodes[0].grad(std::hint::black_box(&theta), &mut g);
        }));
    }

    // ---- simulator event throughput --------------------------------------
    {
        let topo = Topology::ring(8);
        results.push(measure("sim: 10k grad wakes (quad p=16, ring-8)",
                             if quick { 0.2 } else { 1.0 }, || {
            let quad = QuadraticOracle::heterogeneous(16, 8, 0.5, 2.0, 7);
            let cfg = crate::config::SimConfig {
                seed: 7,
                gamma: 0.02,
                compute_mean: 0.01,
                compute_jitter: 0.2,
                link_latency: 0.002,
                eval_every: 1e6, // no evals: pure engine cost
                ..crate::config::SimConfig::default()
            };
            let mut sim = crate::sim::Simulator::new(cfg, &topo,
                                                     AlgoKind::RFast,
                                                     quad.into_set());
            sim.run(Stop::Iterations(10_000));
        }));
    }

    // ---- PJRT round trip (optional) --------------------------------------
    if let Some(dir) = crate::runtime::default_artifact_dir() {
        use std::sync::Arc;
        // lint:allow(panic-path): bench harness fails fast on a broken artifact dir
        let manifest = crate::runtime::Manifest::load(&dir).unwrap();
        let (train, eval) = crate::data::Dataset::mnist01_like(3)
            .split_eval(2000);
        let task = crate::runtime::PjrtTask::LogReg {
            data: Arc::new(train.clone()),
            eval: Arc::new(eval),
            partition: crate::data::Partition::iid(&train, 1, 0),
        };
        let mut set =
            // lint:allow(panic-path): bench harness fails fast on a broken artifact dir
            crate::runtime::build_pjrt_set(&manifest, &task, 1, 3).unwrap();
        let theta = manifest.load_init("logreg").unwrap(); // lint:allow(panic-path): same fail-fast contract
        let mut g = vec![0.0f32; set.dim];
        results.push(measure("logreg grad (PJRT round trip, B=32)", t, || {
            set.nodes[0].grad(std::hint::black_box(&theta), &mut g);
        }));
    } else {
        // make the absence legible in the console AND the perf
        // trajectory: a comparator must be able to tell "bench skipped"
        // from "bench removed" when diffing BENCH_hotpath.json rows
        println!("(artifacts/ not built — skipping PJRT round-trip bench)");
        results.push(HotpathResult {
            name: "logreg grad (PJRT round trip, B=32) [SKIPPED: no \
                   artifacts/]"
                .to_string(),
            ns_per_iter: 0.0,
            iters: 0,
            allocs_per_iter: None,
            alloc_bytes_per_iter: None,
        });
    }

    results
}

/// One point of the scaling sweep: a full R-FAST simulator run on one
/// [`ScalingSpec`] at a fixed epoch budget.
#[derive(Clone, Debug)]
pub struct ScalingPoint {
    /// Node count.
    pub nodes: usize,
    /// Topology spec the point ran on.
    pub topology: String,
    /// Workload name (`logreg` or `quadratic`).
    pub workload: String,
    /// Virtual seconds the epoch budget took (the paper's Fig 4b axis).
    pub virtual_time: f64,
    /// Real wall seconds the single-threaded simulation took — the
    /// engine-cost number the perf trajectory tracks.
    pub wall_seconds: f64,
    /// Gradient computations across all nodes.
    pub grad_wakes: f64,
    /// Messages emitted (before loss/backpressure).
    pub msgs_sent: f64,
    /// Payload bytes put on the (virtual) wire.
    pub bytes_sent: f64,
    /// Global epochs completed when the run stopped.
    pub epoch: f64,
    /// Final evaluated loss of the mean model.
    pub final_loss: f64,
}

/// Run the baseline scaling sweep (R-FAST, logreg, binary tree — the
/// Fig 4b setup) over `node_counts`, each run stopped at `epochs` global
/// epochs. Deterministic given the fixed seed — only `wall_seconds`
/// varies between hosts.
pub fn scaling_sweep(node_counts: &[usize], epochs: f64) -> Vec<ScalingPoint> {
    let specs: Vec<ScalingSpec> = node_counts
        .iter()
        .map(|&n| ScalingSpec {
            nodes: n,
            topology: "binary_tree",
            workload: "logreg",
        })
        .collect();
    scaling_sweep_specs(&specs, epochs)
}

/// Run one R-FAST simulator point per [`ScalingSpec`]. `epochs` is the
/// budget: dataset workloads stop at `Stop::Epochs(epochs)`; the
/// quadratic workload has no epoch mapping, so it stops at
/// `epochs × nodes` iterations — the same per-node wake budget.
pub fn scaling_sweep_specs(specs: &[ScalingSpec],
                           epochs: f64) -> Vec<ScalingPoint> {
    specs
        .iter()
        .map(|spec| {
            let topo = Topology::from_spec(spec.topology, spec.nodes)
                // lint:allow(panic-path): bench harness fails fast on a misconfigured sweep
                .expect("scaling sweep topology spec");
            let workload = match spec.workload {
                "quadratic" => {
                    Workload::Quadratic(QuadSpec::heterogeneous(16, 0.5, 2.0))
                }
                _ => Workload::LogReg,
            };
            let mut cfg = workload.paper_config();
            cfg.seed = 2;
            let stop = if workload.has_epoch_mapping() {
                Stop::Epochs(epochs)
            } else {
                let iters = (epochs * spec.nodes as f64).ceil().max(1.0);
                Stop::Iterations(iters as u64)
            };
            let t0 = std::time::Instant::now();
            let report = Experiment::new(workload, AlgoKind::RFast)
                .topology(&topo)
                .config(cfg)
                .stop(stop)
                .run()
                // lint:allow(panic-path): bench harness fails fast on a misconfigured sweep
                .expect("scaling sweep run")
                .report;
            let wall = t0.elapsed().as_secs_f64();
            let s = |k: &str| report.scalars.get(k).copied().unwrap_or(0.0);
            ScalingPoint {
                nodes: spec.nodes,
                topology: spec.topology.to_string(),
                workload: spec.workload.to_string(),
                virtual_time: s("virtual_time"),
                wall_seconds: wall,
                grad_wakes: s("grad_wakes"),
                msgs_sent: s("msgs_sent"),
                bytes_sent: s("bytes_sent"),
                epoch: s("epoch"),
                final_loss: report.series["loss_vs_time"]
                    .last_y()
                    .unwrap_or(f64::INFINITY),
            }
        })
        .collect()
}

/// Build the `BENCH_hotpath.json` document (schema: EXPERIMENTS.md).
pub fn hotpath_json(results: &[HotpathResult], quick: bool) -> Json {
    let rows = results
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("name", r.name.as_str().into()),
                ("ns_per_iter", r.ns_per_iter.into()),
                ("iters", (r.iters as f64).into()),
                ("allocs_per_iter",
                 r.allocs_per_iter.map_or(Json::Null, Json::Num)),
                ("alloc_bytes_per_iter",
                 r.alloc_bytes_per_iter.map_or(Json::Null, Json::Num)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("schema", HOTPATH_SCHEMA.into()),
        ("quick", quick.into()),
        ("allocs_counted", counting_allocator_active().into()),
        ("results", Json::Arr(rows)),
    ])
}

/// Build the `BENCH_scaling.json` document (schema: EXPERIMENTS.md).
pub fn scaling_json(points: &[ScalingPoint], epochs: f64) -> Json {
    let rows = points
        .iter()
        .map(|p| {
            let per_epoch = if p.epoch > 0.0 {
                p.bytes_sent / p.epoch
            } else {
                0.0
            };
            Json::obj(vec![
                ("nodes", p.nodes.into()),
                ("topology", p.topology.as_str().into()),
                ("workload", p.workload.as_str().into()),
                ("virtual_time", p.virtual_time.into()),
                ("wall_seconds", p.wall_seconds.into()),
                ("grad_wakes", p.grad_wakes.into()),
                ("msgs_sent", p.msgs_sent.into()),
                ("bytes_sent", p.bytes_sent.into()),
                ("bytes_per_epoch", per_epoch.into()),
                ("epoch", p.epoch.into()),
                ("final_loss", p.final_loss.into()),
            ])
        })
        .collect();
    Json::obj(vec![
        ("schema", SCALING_SCHEMA.into()),
        ("algo", AlgoKind::RFast.name().into()),
        ("epoch_budget", epochs.into()),
        ("points", Json::Arr(rows)),
    ])
}

fn require_num(obj: &Json, key: &str, ctx: &str) -> Result<(), String> {
    match obj.get(key) {
        Some(Json::Num(_)) => Ok(()),
        Some(other) => Err(format!("{ctx}: {key} must be a number, got {other:?}")),
        None => Err(format!("{ctx}: missing {key}")),
    }
}

fn require_num_or_null(obj: &Json, key: &str, ctx: &str) -> Result<(), String> {
    match obj.get(key) {
        Some(Json::Num(_)) | Some(Json::Null) => Ok(()),
        Some(other) => {
            Err(format!("{ctx}: {key} must be number|null, got {other:?}"))
        }
        None => Err(format!("{ctx}: missing {key}")),
    }
}

/// Validate a parsed `BENCH_hotpath.json` against [`HOTPATH_SCHEMA`] —
/// the check the CI bench-smoke step gates on.
pub fn validate_hotpath_json(j: &Json) -> Result<(), String> {
    match j.get("schema").and_then(Json::as_str) {
        Some(s) if s == HOTPATH_SCHEMA => {}
        other => return Err(format!("schema must be {HOTPATH_SCHEMA:?}, got {other:?}")),
    }
    if !matches!(j.get("quick"), Some(Json::Bool(_))) {
        return Err("quick must be a bool".into());
    }
    if !matches!(j.get("allocs_counted"), Some(Json::Bool(_))) {
        return Err("allocs_counted must be a bool".into());
    }
    let rows = j
        .get("results")
        .and_then(Json::as_arr)
        .ok_or("results must be an array")?;
    if rows.is_empty() {
        return Err("results must be non-empty".into());
    }
    for (i, row) in rows.iter().enumerate() {
        let ctx = format!("results[{i}]");
        if row.get("name").and_then(Json::as_str).is_none() {
            return Err(format!("{ctx}: missing string name"));
        }
        require_num(row, "ns_per_iter", &ctx)?;
        require_num(row, "iters", &ctx)?;
        require_num_or_null(row, "allocs_per_iter", &ctx)?;
        require_num_or_null(row, "alloc_bytes_per_iter", &ctx)?;
    }
    Ok(())
}

/// Validate a parsed `BENCH_scaling.json` against [`SCALING_SCHEMA`].
pub fn validate_scaling_json(j: &Json) -> Result<(), String> {
    match j.get("schema").and_then(Json::as_str) {
        Some(s) if s == SCALING_SCHEMA => {}
        other => return Err(format!("schema must be {SCALING_SCHEMA:?}, got {other:?}")),
    }
    if j.get("algo").and_then(Json::as_str).is_none() {
        return Err("missing string algo".into());
    }
    require_num(j, "epoch_budget", "document")?;
    let rows = j
        .get("points")
        .and_then(Json::as_arr)
        .ok_or("points must be an array")?;
    if rows.is_empty() {
        return Err("points must be non-empty".into());
    }
    for (i, row) in rows.iter().enumerate() {
        let ctx = format!("points[{i}]");
        for key in ["topology", "workload"] {
            if row.get(key).and_then(Json::as_str).is_none() {
                return Err(format!("{ctx}: missing string {key}"));
            }
        }
        for key in ["nodes", "virtual_time", "wall_seconds", "grad_wakes",
                    "msgs_sent", "bytes_sent", "bytes_per_epoch", "epoch",
                    "final_loss"] {
            require_num(row, key, &ctx)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jsonio;

    #[test]
    fn measure_times_without_counting_allocator() {
        // cargo test does not install CountingAllocator: the timing side
        // must work and the allocation columns must degrade to None
        let mut acc = 0u64;
        let r = measure("noop-ish", 0.01, || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(r.iters > 100);
        assert!(r.ns_per_iter < 1e6);
        assert!(!counting_allocator_active());
        assert!(r.allocs_per_iter.is_none());
        assert!(r.alloc_bytes_per_iter.is_none());
        assert!(r.report().contains("allocs/iter"));
    }

    #[test]
    fn hotpath_json_validates_and_rejects_tampering() {
        let results = vec![HotpathResult {
            name: "x".into(),
            ns_per_iter: 12.5,
            iters: 1000,
            allocs_per_iter: None,
            alloc_bytes_per_iter: None,
        }];
        let j = hotpath_json(&results, true);
        // round-trip through text, like the CI gate does
        let parsed = jsonio::parse(&j.to_string()).unwrap();
        validate_hotpath_json(&parsed).unwrap();
        // tampered: wrong schema tag
        let bad = jsonio::parse(
            &j.to_string().replace(HOTPATH_SCHEMA, "bogus/v0")).unwrap();
        assert!(validate_hotpath_json(&bad).is_err());
        // tampered: a required field renamed away
        let bad = jsonio::parse(
            &j.to_string().replace("ns_per_iter", "ns")).unwrap();
        assert!(validate_hotpath_json(&bad).is_err());
        // empty results
        let empty = hotpath_json(&[], false);
        assert!(validate_hotpath_json(&empty).is_err());
    }

    #[test]
    fn scaling_sweep_point_is_schema_valid_and_sane() {
        // one small point keeps the test fast; the real sweep is CI's job
        let points = scaling_sweep(&[4], 0.2);
        assert_eq!(points.len(), 1);
        let p = &points[0];
        assert_eq!(p.nodes, 4);
        assert!(p.grad_wakes > 0.0, "{p:?}");
        assert!(p.bytes_sent > 0.0, "{p:?}");
        assert!(p.epoch >= 0.2, "{p:?}");
        assert!(p.virtual_time > 0.0, "{p:?}");
        assert!(p.final_loss.is_finite(), "{p:?}");
        assert_eq!(p.topology, "binary_tree");
        assert_eq!(p.workload, "logreg");
        let j = scaling_json(&points, 0.2);
        let parsed = jsonio::parse(&j.to_string()).unwrap();
        validate_scaling_json(&parsed).unwrap();
        // bytes_per_epoch is derived consistently
        let row = &parsed.get("points").unwrap().as_arr().unwrap()[0];
        let bpe = row.get("bytes_per_epoch").unwrap().as_f64().unwrap();
        assert!((bpe - p.bytes_sent / p.epoch).abs() < 1e-6 * bpe.max(1.0));
        // tampered: a point field removed
        let bad = jsonio::parse(
            &j.to_string().replace("bytes_per_epoch", "bpe")).unwrap();
        assert!(validate_scaling_json(&bad).is_err());
        // tampered: per-point topology removed (the v2 addition)
        let bad = jsonio::parse(
            &j.to_string().replace("\"topology\"", "\"topo\"")).unwrap();
        assert!(validate_scaling_json(&bad).is_err());
    }

    #[test]
    fn scaling_spec_quadratic_point_uses_iteration_budget() {
        // the 50k star point's shape at toy size: no epoch mapping, so
        // the budget maps to epochs × nodes iterations
        let specs = [ScalingSpec { nodes: 6, topology: "star",
                                   workload: "quadratic" }];
        let points = scaling_sweep_specs(&specs, 2.0);
        let p = &points[0];
        assert_eq!((p.nodes, p.workload.as_str()), (6, "quadratic"));
        assert_eq!(p.grad_wakes, 12.0, "Stop::Iterations(2 × 6): {p:?}");
        assert!(p.virtual_time > 0.0 && p.final_loss.is_finite(), "{p:?}");
        let j = scaling_json(&points, 2.0);
        validate_scaling_json(&jsonio::parse(&j.to_string()).unwrap())
            .unwrap();
    }

    #[test]
    fn live_peak_stats_degrade_without_counting_allocator() {
        // cargo test does not install CountingAllocator; the gauge and
        // high-water mark must read zero and reset_peak must be a no-op
        reset_peak();
        let v: Vec<u8> = std::hint::black_box(Vec::with_capacity(4096));
        drop(v);
        assert_eq!(live_peak_stats(), (0, 0));
    }
}
