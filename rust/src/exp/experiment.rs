//! The `Experiment` builder — ONE typed entry point over both engines.
//!
//! Every paper figure needs the same (workload, algorithm, topology,
//! scenario) run driven through *both* engines: virtual time for
//! controlled comparisons, wall clock for the straggler/async claims.
//! The builder replaces the positional-argument `run_*` free functions
//! (now deprecated shims) with one chain:
//!
//! ```text
//! Experiment::new(Workload::LogReg, AlgoKind::RFast)
//!     .topology(&topo)
//!     .config(cfg)
//!     .scenario(&sc)
//!     .engine(Engine::threaded(Some(0.01)))
//!     .stop(Stop::Epochs(10.0))
//!     .run()?
//! ```
//!
//! and returns a [`Run`]: the familiar [`Report`] plus a unified
//! [`RunStats`] whose scalar fields mean the same thing on both engines
//! (engine-specific extras are `Option`s). Misuse is a typed
//! [`ExpError`], never a panic or a bare string. Sweeps are native:
//! [`Experiment::sweep_algos`] / [`Experiment::sweep_topologies`] /
//! [`Experiment::sweep_architectures`] / [`Experiment::sweep_engines`]
//! return a [`Comparison`] that feeds
//! [`save_comparison_csvs`](super::save_comparison_csvs) directly.
//!
//! Stop-rule ↔ engine semantics (DESIGN.md §9):
//!
//! | `Stop`          | `Engine::Sim`                  | `Engine::Threaded`            |
//! |-----------------|--------------------------------|-------------------------------|
//! | `Time(s)`       | `s` *virtual* seconds          | `s` *wall* seconds            |
//! | `Iterations(k)` | `k` gradient steps, all nodes  | `k` gradient steps, all nodes |
//! | `Epochs(e)`     | global epoch counter ≥ `e`     | steps × epoch-mapping ≥ `e`   |
//! | `TargetLoss`    | eval loss ≤ target or deadline | eval loss ≤ target or deadline|

use super::{tuned_gamma, Workload};
use crate::algo::AlgoKind;
use crate::config::SimConfig;
use crate::graph::{ArchSpec, Topology, TopologyKind};
use crate::metrics::{Report, Series};
use crate::oracle::{LogRegFactory, OracleFactory};
use crate::runner::{MailboxCfg, RunnerStats, ThreadedRunner};
use crate::scenario::Scenario;
use crate::sim::{SimStats, Simulator};
use std::io::Write;
use std::path::Path;

/// Engine-agnostic stop rule — the merge of the simulator's old
/// `StopRule` and the runner's old `RunUntil`. `Time` reads the engine's
/// own clock: virtual seconds on [`Engine::Sim`], wall seconds on
/// [`Engine::Threaded`]; the other variants mean the same thing on both.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Stop {
    /// Seconds on the engine's clock (virtual for Sim, wall for Threaded).
    Time(f64),
    /// Total gradient computations across all nodes.
    Iterations(u64),
    /// Global epochs (needs a workload with an epoch mapping; the paper's
    /// Table II protocol).
    Epochs(f64),
    /// Stop once the evaluated loss reaches `loss`, or at `max_time`
    /// seconds on the engine's clock — whichever comes first.
    TargetLoss { loss: f64, max_time: f64 },
}

impl Stop {
    /// Default deadline for a bare `loss:L` spec (one hour on the
    /// engine's clock) — finite, so an unreachable loss target ends the
    /// run instead of hanging it.
    pub const DEFAULT_TARGET_DEADLINE: f64 = 3_600.0;

    /// Parse a CLI spec: `time:T`, `iters:K`, `epochs:E`,
    /// `loss:L[:MAX_TIME]` (the `repro train --stop` grammar; MAX_TIME
    /// defaults to [`Stop::DEFAULT_TARGET_DEADLINE`]).
    pub fn parse(spec: &str) -> Result<Stop, String> {
        let (kind, rest) = spec
            .split_once(':')
            .ok_or_else(|| format!("--stop wants kind:value, got {spec:?}"))?;
        // NaN/inf parse as valid f64 but make a stop rule that never
        // fires (every `>=` comparison is false against NaN) — reject
        // them here so a typo can't hang the run
        let num = |v: &str, what: &str| -> Result<f64, String> {
            let x = v
                .parse::<f64>()
                .map_err(|_| format!("--stop {what}: bad number {v:?}"))?;
            if !x.is_finite() || x < 0.0 {
                return Err(format!(
                    "--stop {what}: wants a finite non-negative number, \
                     got {v:?}"
                ));
            }
            Ok(x)
        };
        match kind {
            "time" => Ok(Stop::Time(num(rest, "time")?)),
            "iters" => Ok(Stop::Iterations(
                rest.parse::<u64>()
                    .map_err(|_| format!("--stop iters: bad count {rest:?}"))?,
            )),
            "epochs" => Ok(Stop::Epochs(num(rest, "epochs")?)),
            "loss" => {
                let (l, max) = match rest.split_once(':') {
                    Some((l, m)) => (num(l, "loss")?, num(m, "loss max")?),
                    // finite fallback deadline: an unreachable target
                    // must end the run, not hang it
                    None => (num(rest, "loss")?, Stop::DEFAULT_TARGET_DEADLINE),
                };
                Ok(Stop::TargetLoss { loss: l, max_time: max })
            }
            other => Err(format!(
                "--stop: unknown kind {other:?} (time|iters|epochs|loss)"
            )),
        }
    }
}

/// Which engine executes the run. (Not to be confused with the PJRT
/// executor `runtime::Engine` — this picks the *training* engine.)
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Engine {
    /// Deterministic discrete-event simulator (virtual time).
    Sim,
    /// Actor-pool wall-clock runner: M node actors multiplexed over N OS
    /// worker threads. `pace` bounds the minimum per-iteration duration
    /// in seconds (`None` when the oracle is naturally paced by real
    /// compute); `workers` sizes the pool (`None` = one per core,
    /// clamped to the node count); `mailbox` sets per-actor queue
    /// capacity and overflow policy. [`Engine::threaded`] fills the
    /// latter two with defaults.
    Threaded {
        pace: Option<f64>,
        workers: Option<usize>,
        mailbox: MailboxCfg,
    },
}

impl Engine {
    /// `Engine::Threaded` with default pool sizing and mailbox knobs —
    /// the spelling every call site that only cares about pacing uses.
    pub fn threaded(pace: Option<f64>) -> Engine {
        Engine::Threaded { pace, workers: None, mailbox: MailboxCfg::default() }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Engine::Sim => "sim",
            Engine::Threaded { .. } => "threaded",
        }
    }
}

/// Typed failure of [`Experiment::run`] — replaces the stringly
/// `Result<_, String>` of the old free functions.
#[derive(Clone, Debug, PartialEq)]
pub enum ExpError {
    /// `.topology(..)` was never called.
    MissingTopology,
    /// `.stop(..)` was never called.
    MissingStop,
    /// The workload cannot run on the chosen engine; `hint` says where
    /// that combination actually lives (e.g. the PJRT wall-clock path).
    UnsupportedWorkload {
        workload: &'static str,
        engine: &'static str,
        hint: String,
    },
    /// `Stop::Epochs` on a workload with no dataset-epoch mapping
    /// (closed-form quadratics count steps, not passes over data).
    NoEpochMapping { workload: &'static str },
    /// The topology violates Assumption 1 or 2
    /// ([`WeightMatrices::check_assumptions`](crate::graph::WeightMatrices::check_assumptions)
    /// found violations — e.g. an architecture pair whose spanning trees
    /// share no common root). `topology` names the offending topology or
    /// (G_R, G_C) pair; `detail` lists every violation. Pre-flighted by
    /// [`Experiment::run`], so an invalid pair can never start a silent
    /// divergent run.
    InvalidTopology { topology: String, detail: String },
    /// `SimConfig::validate` failed.
    InvalidConfig(String),
    /// Scenario validation failed; `field` is a JSON-path-like pointer to
    /// the offending entry (`"stragglers[0].factor"`).
    InvalidScenario {
        scenario: String,
        field: String,
        detail: String,
    },
}

impl std::fmt::Display for ExpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExpError::MissingTopology => {
                write!(f, "experiment has no topology (call .topology(..))")
            }
            ExpError::MissingStop => {
                write!(f, "experiment has no stop rule (call .stop(..))")
            }
            ExpError::UnsupportedWorkload { workload, engine, hint } => {
                write!(f, "workload {workload:?} does not run on the \
                           {engine} engine: {hint}")
            }
            ExpError::NoEpochMapping { workload } => {
                write!(f, "Stop::Epochs needs a workload with an epoch \
                           mapping; {workload:?} has none (use \
                           Stop::Iterations or Stop::Time)")
            }
            ExpError::InvalidTopology { topology, detail } => {
                write!(f, "invalid topology {topology:?}: {detail}")
            }
            ExpError::InvalidConfig(msg) => write!(f, "invalid config: {msg}"),
            ExpError::InvalidScenario { scenario, field, detail } => {
                write!(f, "invalid scenario {scenario:?} at {field}: {detail}")
            }
        }
    }
}

impl std::error::Error for ExpError {}

/// Unified run counters — the merge of [`SimStats`] and [`RunnerStats`]:
/// the shared fields mean the same thing on both engines; fields only
/// one engine can produce are `Option`s tagged with their engine.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RunStats {
    /// Messages emitted (before loss/backpressure verdicts).
    pub msgs_sent: u64,
    /// Sender-side Bernoulli drops (async algorithms only).
    pub msgs_lost: u64,
    /// Discarded because the link still had an unacked packet in flight.
    pub msgs_backpressured: u64,
    /// Sends delayed by scenario link degradation (bandwidth FIFO on both
    /// engines; the threaded runner also counts injected-latency sleeps).
    pub msgs_paced: u64,
    /// Payload bytes actually transmitted (Deliver verdicts only).
    pub bytes_sent: u64,
    /// Gradient steps per node (sums to the engines' total step count).
    pub steps_per_node: Vec<u64>,
    /// Sim only: deliveries are explicit events there.
    pub msgs_delivered: Option<u64>,
    /// Sim only: non-gradient wakes (ring phases etc.).
    pub comm_wakes: Option<u64>,
    /// Sim only: virtual seconds when the run stopped.
    pub virtual_time: Option<f64>,
    /// Threaded only: wall seconds the run took.
    pub wall_seconds: Option<f64>,
    /// Threaded only: messages discarded by a full actor mailbox under a
    /// drop overflow policy (zero under the default backpressure).
    pub msgs_dropped: Option<u64>,
    /// Threaded only: worker threads the actor pool ran on.
    pub workers: Option<usize>,
}

impl RunStats {
    pub fn from_sim(s: SimStats, steps_per_node: Vec<u64>) -> RunStats {
        RunStats {
            msgs_sent: s.msgs_sent,
            msgs_lost: s.msgs_lost,
            msgs_backpressured: s.msgs_backpressured,
            msgs_paced: s.msgs_paced,
            bytes_sent: s.bytes_sent,
            steps_per_node,
            msgs_delivered: Some(s.msgs_delivered),
            comm_wakes: Some(s.comm_wakes),
            virtual_time: Some(s.virtual_time),
            wall_seconds: None,
            msgs_dropped: None,
            workers: None,
        }
    }

    pub fn from_runner(s: RunnerStats) -> RunStats {
        RunStats {
            msgs_sent: s.msgs_sent,
            msgs_lost: s.msgs_lost,
            msgs_backpressured: s.msgs_backpressured,
            msgs_paced: s.msgs_paced,
            bytes_sent: s.bytes_sent,
            steps_per_node: s.steps_per_node,
            msgs_delivered: None,
            comm_wakes: None,
            virtual_time: None,
            wall_seconds: Some(s.wall_seconds),
            msgs_dropped: Some(s.msgs_dropped),
            workers: Some(s.workers),
        }
    }

    /// Total gradient steps across all nodes.
    pub fn total_steps(&self) -> u64 {
        self.steps_per_node.iter().sum()
    }

    /// Seconds on whichever clock the engine ran (virtual or wall).
    pub fn elapsed_seconds(&self) -> f64 {
        self.virtual_time.or(self.wall_seconds).unwrap_or(0.0)
    }
}

/// One finished experiment: the [`Report`] (series + scalar summary) plus
/// the unified [`RunStats`] and the engine that produced them.
#[derive(Clone, Debug)]
pub struct Run {
    pub report: Report,
    pub stats: RunStats,
    pub engine: Engine,
}

impl Run {
    /// The engine's eval-loss curve: `loss_vs_time` on Sim,
    /// `loss_vs_wall` on Threaded — so callers comparing engines never
    /// branch on the series name.
    pub fn loss_series(&self) -> Option<&Series> {
        let name = match self.engine {
            Engine::Sim => "loss_vs_time",
            Engine::Threaded { .. } => "loss_vs_wall",
        };
        self.report.series.get(name)
    }
}

/// A labeled set of [`Run`]s from a sweep; feeds
/// [`save_comparison_csvs`](super::save_comparison_csvs) directly.
#[derive(Clone, Debug, Default)]
pub struct Comparison {
    pub runs: Vec<Run>,
}

impl Comparison {
    pub fn reports(&self) -> Vec<&Report> {
        self.runs.iter().map(|r| &r.report).collect()
    }

    /// Write every shared series as `DIR/PREFIX_<series>.csv` (one column
    /// per run, like the benches always did) plus
    /// `DIR/PREFIX_scalars.csv` — the side-by-side scalar table that
    /// stays meaningful even when the runs share no series (e.g. a
    /// sim-vs-threaded engine sweep, whose curves live on different
    /// clocks but whose scalar keys are unified).
    pub fn save_csvs(&self, dir: &Path, prefix: &str) -> std::io::Result<()> {
        super::save_comparison_csvs(dir, prefix, &self.reports())?;
        self.save_scalars_csv(&dir.join(format!("{prefix}_scalars.csv")))
    }

    /// Column labels of the side-by-side scalar table (one per run).
    pub fn labels(&self) -> Vec<&str> {
        self.runs.iter().map(|r| r.report.label.as_str()).collect()
    }

    /// Rows of the side-by-side scalar table: the union of scalar keys
    /// (sorted) with one `Option<f64>` cell per run, in run order —
    /// the single source both the CSV emit and console renderings use.
    pub fn scalar_rows(&self) -> Vec<(String, Vec<Option<f64>>)> {
        use std::collections::BTreeSet;
        let mut keys: BTreeSet<&str> = BTreeSet::new();
        for r in &self.runs {
            keys.extend(r.report.scalars.keys().map(|k| k.as_str()));
        }
        keys.into_iter()
            .map(|key| {
                let cells = self
                    .runs
                    .iter()
                    .map(|r| r.report.scalars.get(key).copied())
                    .collect();
                (key.to_string(), cells)
            })
            .collect()
    }

    /// The scalar table alone: rows = union of scalar keys, one column
    /// per run (empty cell where a run lacks the key).
    pub fn save_scalars_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        write!(f, "metric")?;
        for label in self.labels() {
            write!(f, ",{label}")?;
        }
        writeln!(f)?;
        for (key, cells) in self.scalar_rows() {
            write!(f, "{key}")?;
            for cell in cells {
                match cell {
                    Some(v) => write!(f, ",{v}")?,
                    None => write!(f, ",")?,
                }
            }
            writeln!(f)?;
        }
        Ok(())
    }
}

/// Builder for one run (or a sweep of runs) — see the module docs for
/// the full chain. `Clone` so sweeps can fan a base experiment out.
#[derive(Clone, Debug)]
pub struct Experiment {
    workload: Workload,
    algo: AlgoKind,
    topology: Option<Topology>,
    cfg: Option<SimConfig>,
    /// Shortcut overrides, applied on top of the effective config at
    /// `run()` time so `.seed(..)`/`.gamma(..)` win regardless of where
    /// they sit in the chain relative to `.config(..)`.
    seed_override: Option<u64>,
    gamma_override: Option<f32>,
    scenario: Option<Scenario>,
    engine: Engine,
    stop: Option<Stop>,
}

impl Experiment {
    /// Start a builder; workload + algorithm are the two axes every
    /// experiment has. Defaults: no topology (required), the workload's
    /// paper-calibrated config, no scenario, [`Engine::Sim`], no stop
    /// rule (required).
    pub fn new(workload: Workload, algo: AlgoKind) -> Experiment {
        Experiment {
            workload,
            algo,
            topology: None,
            cfg: None,
            seed_override: None,
            gamma_override: None,
            scenario: None,
            engine: Engine::Sim,
            stop: None,
        }
    }

    /// Communication topology (required before [`Experiment::run`]).
    pub fn topology(mut self, topo: &Topology) -> Experiment {
        self.topology = Some(topo.clone());
        self
    }

    /// Full config override. Without it the workload's
    /// [`paper_config`](Workload::paper_config) is used. A scenario
    /// already embedded in the config is honored; one set through
    /// [`Experiment::scenario`] takes precedence (and labels the report).
    pub fn config(mut self, cfg: SimConfig) -> Experiment {
        self.cfg = Some(cfg);
        self
    }

    /// Seed shortcut — overrides the effective config's seed at `run()`
    /// time, so it wins no matter where it sits relative to `.config(..)`
    /// in the chain.
    pub fn seed(mut self, seed: u64) -> Experiment {
        self.seed_override = Some(seed);
        self
    }

    /// Step-size shortcut — overrides the effective config's γ at
    /// `run()` time, order-independent like [`Experiment::seed`].
    pub fn gamma(mut self, gamma: f32) -> Experiment {
        self.gamma_override = Some(gamma);
        self
    }

    /// Fault-injection scenario; the report label gains a ` [name]`
    /// suffix, like `run_sim_under` always did.
    pub fn scenario(mut self, sc: &Scenario) -> Experiment {
        self.scenario = Some(sc.clone());
        self
    }

    /// `Option`-shaped scenario setter — handy in clean-vs-faulty
    /// comparison loops.
    pub fn maybe_scenario(mut self, sc: Option<&Scenario>) -> Experiment {
        self.scenario = sc.cloned();
        self
    }

    /// Which engine runs it (default [`Engine::Sim`]).
    pub fn engine(mut self, engine: Engine) -> Experiment {
        self.engine = engine;
        self
    }

    /// Stop rule (required before [`Experiment::run`]).
    pub fn stop(mut self, stop: Stop) -> Experiment {
        self.stop = Some(stop);
        self
    }

    /// Can `workload` execute on `engine` at all? Checked up front (and
    /// by sweeps over every leg before running any), so an engine sweep
    /// never burns a full run on one engine only to error on the next.
    fn check_workload_on(&self, engine: Engine) -> Result<(), ExpError> {
        match (self.workload, engine) {
            (Workload::Mlp, Engine::Threaded { .. }) => {
                Err(ExpError::UnsupportedWorkload {
                    workload: self.workload.name(),
                    engine: "threaded",
                    hint: "the threaded engine drives the logreg and \
                           quadratic workloads with pure-rust oracles; the \
                           MLP proxy needs the PJRT path \
                           (examples/e2e_transformer.rs)"
                        .into(),
                })
            }
            _ => Ok(()),
        }
    }

    /// The shared pre-flight of [`Experiment::run`] and
    /// [`Experiment::run_sim_probed`]: required fields, Assumption 1-2,
    /// workload/engine compatibility, epoch mapping, the effective config
    /// (overrides + scenario precedence) and its validation. Returns the
    /// pieces execution needs.
    fn validated(
        &self, engine: Engine,
    ) -> Result<(&Topology, SimConfig, Stop), ExpError> {
        let topo = self.topology.as_ref().ok_or(ExpError::MissingTopology)?;
        let stop = self.stop.ok_or(ExpError::MissingStop)?;
        // Assumption 1-2 pre-flight: a hand-built (or architecture-pair)
        // topology with no common root would run "fine" and silently
        // diverge — surface it as the typed error instead
        let violations = topo.weights.check_assumptions();
        if !violations.is_empty() {
            return Err(ExpError::InvalidTopology {
                topology: topo.name().to_string(),
                detail: violations
                    .iter()
                    .map(|e| e.to_string())
                    .collect::<Vec<_>>()
                    .join("; "),
            });
        }
        self.check_workload_on(engine)?;
        if matches!(stop, Stop::Epochs(_)) && !self.workload.has_epoch_mapping()
        {
            return Err(ExpError::NoEpochMapping {
                workload: self.workload.name(),
            });
        }
        let mut cfg = self
            .cfg
            .clone()
            .unwrap_or_else(|| self.workload.paper_config());
        if let Some(s) = self.seed_override {
            cfg.seed = s;
        }
        if let Some(g) = self.gamma_override {
            cfg.gamma = g;
        }
        if self.scenario.is_some() {
            cfg.scenario = self.scenario.clone();
        }
        if let Some(sc) = &cfg.scenario {
            sc.validate_detailed(Some(topo.n())).map_err(
                |(field, detail)| ExpError::InvalidScenario {
                    scenario: sc.name.clone(),
                    field,
                    detail,
                },
            )?;
        }
        cfg.validate().map_err(ExpError::InvalidConfig)?;
        Ok((topo, cfg, stop))
    }

    /// Validate the chain and execute it on the configured engine.
    pub fn run(&self) -> Result<Run, ExpError> {
        let (topo, cfg, stop) = self.validated(self.engine)?;
        match self.engine {
            Engine::Sim => self.run_on_sim(topo, cfg, stop),
            Engine::Threaded { pace, workers, mailbox } => {
                self.run_on_threaded(topo, cfg, stop, pace, workers, mailbox)
            }
        }
    }

    /// [`Experiment::run`] on the virtual-time simulator with an
    /// invariant hook: after the run stops (and before the simulator is
    /// dropped) `probe` sees the final `&Simulator` — node state via
    /// [`Simulator::nodes`](crate::sim::Simulator::nodes) and the
    /// [`NodeState::as_any`](crate::algo::NodeState::as_any) downcast,
    /// heap/clock via its other accessors. This is how the fuzzer's
    /// oracles (e.g. ρ-mass conservation) inspect a finished run without
    /// the simulator growing oracle knowledge. Always executes on
    /// [`Engine::Sim`], whatever `.engine(..)` was set to.
    pub fn run_sim_probed<T>(
        &self, probe: impl FnOnce(&Simulator) -> T,
    ) -> Result<(Run, T), ExpError> {
        let (topo, cfg, stop) = self.validated(Engine::Sim)?;
        let set = self.workload.build_set(topo.n(), &cfg);
        let x0 = self.workload.x0(set.dim, cfg.seed);
        let mut sim = Simulator::with_x0(cfg, topo, self.algo, set, &x0);
        let mut report = sim.run(stop);
        self.label_scenario(&mut report);
        let probed = probe(&sim);
        let stats =
            RunStats::from_sim(sim.stats(), sim.steps_per_node().to_vec());
        Ok((Run { report, stats, engine: Engine::Sim }, probed))
    }

    fn label_scenario(&self, report: &mut Report) {
        if let Some(sc) = &self.scenario {
            report.label = format!("{} [{}]", report.label, sc.name);
        }
    }

    fn run_on_sim(&self, topo: &Topology, cfg: SimConfig,
                  stop: Stop) -> Result<Run, ExpError> {
        let set = self.workload.build_set(topo.n(), &cfg);
        let x0 = self.workload.x0(set.dim, cfg.seed);
        let mut sim = Simulator::with_x0(cfg, topo, self.algo, set, &x0);
        let mut report = sim.run(stop);
        self.label_scenario(&mut report);
        let stats =
            RunStats::from_sim(sim.stats(), sim.steps_per_node().to_vec());
        Ok(Run { report, stats, engine: Engine::Sim })
    }

    #[allow(clippy::too_many_arguments)]
    fn run_on_threaded(&self, topo: &Topology, cfg: SimConfig, stop: Stop,
                       pace: Option<f64>, workers: Option<usize>,
                       mailbox: MailboxCfg) -> Result<Run, ExpError> {
        let engine = Engine::Threaded { pace, workers, mailbox };
        match self.workload {
            Workload::LogReg => {
                let factory = LogRegFactory::paper_workload(
                    topo.n(), cfg.batch, cfg.skew_alpha, cfg.seed);
                let x0 = self.workload.x0(factory.dim(), cfg.seed);
                let mut runner =
                    ThreadedRunner::new(cfg, topo, self.algo, x0)
                        .with_mailbox(mailbox);
                if let Some(w) = workers {
                    runner = runner.with_workers(w);
                }
                if let Some(p) = pace {
                    runner = runner.with_pace(p);
                }
                let mut eval = factory.eval_fn();
                let (mut report, stats) = runner.run(&factory, &mut eval, stop);
                self.label_scenario(&mut report);
                Ok(Run {
                    report,
                    stats: RunStats::from_runner(stats),
                    engine,
                })
            }
            Workload::Quadratic(spec) => {
                let quad = spec.build(topo.n(), cfg.seed);
                let xs = quad.optimum();
                // same init source as the sim path — the engine-parity
                // contract needs both engines starting from one x0 rule
                let x0 = self.workload.x0(spec.dim, cfg.seed);
                let mut runner =
                    ThreadedRunner::new(cfg, topo, self.algo, x0)
                        .with_mailbox(mailbox);
                if let Some(w) = workers {
                    runner = runner.with_workers(w);
                }
                if let Some(p) = pace {
                    runner = runner.with_pace(p);
                }
                let (mut eval, last_mean) =
                    crate::testutil::tracking_quad_eval(quad.clone());
                let (mut report, stats) = runner.run(
                    &crate::testutil::QuadFactory(quad), &mut eval, stop);
                // wall-clock engines cannot snapshot at the exact stop
                // instant, so the gap is measured on the last evaluated
                // mean — the convention every quadratic runner test used
                report.final_gap = Some(crate::linalg::dist(
                    // lint:allow(panic-path): lock poisoning means a worker already panicked
                    &last_mean.lock().unwrap(), &xs));
                self.label_scenario(&mut report);
                Ok(Run {
                    report,
                    stats: RunStats::from_runner(stats),
                    engine,
                })
            }
            // unreachable in practice: run() pre-flights workload/engine
            // compatibility — kept as the authoritative error for direct
            // calls
            Workload::Mlp => {
                Err(self.check_workload_on(engine)
                    .expect_err("Mlp is not threadable"))
            }
        }
    }

    // ---- sweeps ---------------------------------------------------------

    /// Label for one sweep leg: the swept dimension's name, keeping the
    /// ` [scenario]` suffix when a scenario was set through the builder —
    /// sweep artifacts must stay distinguishable from their clean twins.
    fn sweep_label(&self, base: &str) -> String {
        match &self.scenario {
            Some(sc) => format!("{base} [{}]", sc.name),
            None => base.to_string(),
        }
    }

    /// Run once per algorithm; each run's report is labeled with the
    /// algorithm name.
    pub fn sweep_algos(&self,
                       algos: &[AlgoKind]) -> Result<Comparison, ExpError> {
        let mut runs = Vec::with_capacity(algos.len());
        for &algo in algos {
            let mut exp = self.clone();
            exp.algo = algo;
            let mut run = exp.run()?;
            run.report.label = self.sweep_label(algo.name());
            runs.push(run);
        }
        Ok(Comparison { runs })
    }

    /// [`sweep_algos`](Experiment::sweep_algos) with the per-algorithm
    /// [`tuned_gamma`] applied on top of the effective config — the Fig
    /// 5/6 protocol, where gradient-tracking methods get a larger step.
    pub fn sweep_algos_tuned(
        &self, algos: &[AlgoKind],
    ) -> Result<Comparison, ExpError> {
        let mut runs = Vec::with_capacity(algos.len());
        for &algo in algos {
            let mut exp = self.clone();
            exp.algo = algo;
            exp = exp.gamma(tuned_gamma(self.workload, algo));
            let mut run = exp.run()?;
            run.report.label = self.sweep_label(algo.name());
            runs.push(run);
        }
        Ok(Comparison { runs })
    }

    /// Run once per topology kind at `n` nodes; each run's report is
    /// labeled with the topology name.
    pub fn sweep_topologies(
        &self, kinds: &[TopologyKind], n: usize,
    ) -> Result<Comparison, ExpError> {
        let mut runs = Vec::with_capacity(kinds.len());
        for &kind in kinds {
            let exp = self.clone().topology(&kind.build(n));
            let mut run = exp.run()?;
            run.report.label = self.sweep_label(kind.name());
            runs.push(run);
        }
        Ok(Comparison { runs })
    }

    /// Run once per asymmetric (G_R, G_C) architecture pair at `n`
    /// nodes; each run's report is labeled with the pair's name
    /// (`bfs@0+star@0`). An unbuildable spec (out-of-range root) or a
    /// pair violating Assumption 2 (no common root) is the typed
    /// [`ExpError::InvalidTopology`] — the fig3 bench path.
    pub fn sweep_architectures(
        &self, specs: &[ArchSpec], n: usize,
    ) -> Result<Comparison, ExpError> {
        let mut runs = Vec::with_capacity(specs.len());
        for spec in specs {
            let topo = spec.build(n).map_err(|detail| {
                ExpError::InvalidTopology { topology: spec.name(), detail }
            })?;
            let mut run = self.clone().topology(&topo).run()?;
            run.report.label = self.sweep_label(&spec.name());
            runs.push(run);
        }
        Ok(Comparison { runs })
    }

    /// Run once per engine (the `repro train --engine both` path); each
    /// run's report is labeled `sim` / `threaded`. Every engine is
    /// pre-flighted against the workload before ANY leg runs, so an
    /// incompatible pairing fails fast instead of after a full first run.
    pub fn sweep_engines(
        &self, engines: &[Engine],
    ) -> Result<Comparison, ExpError> {
        for &engine in engines {
            self.check_workload_on(engine)?;
        }
        let mut runs = Vec::with_capacity(engines.len());
        for &engine in engines {
            let mut run = self.clone().engine(engine).run()?;
            run.report.label = self.sweep_label(engine.name());
            runs.push(run);
        }
        Ok(Comparison { runs })
    }
}
