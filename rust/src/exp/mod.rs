//! Experiment harness shared by `examples/` and `rust/benches/` — the glue
//! that turns (workload, topology, algorithm, timing model) into a
//! [`Report`], so every paper figure/table is regenerated through one code
//! path. The perf-baseline harness (allocation-counting micro benches,
//! scaling sweep, `BENCH_*.json` schema) lives in [`bench`].

pub mod bench;

use crate::algo::AlgoKind;
use crate::config::SimConfig;
use crate::graph::Topology;
use crate::metrics::Report;
use crate::oracle::{GradOracle, LogRegFactory, LogRegOracle, MlpOracle,
                    OracleFactory, OracleSet};
use crate::runner::{RunUntil, RunnerStats, ThreadedRunner};
use crate::scenario::Scenario;
use crate::sim::{Simulator, StopRule};
use std::path::Path;

/// Which training workload an experiment drives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Workload {
    /// §VI-A: regularized logreg on the synthetic two-digit set
    /// (pure-rust oracle — exact twin of the Pallas kernel).
    LogReg,
    /// §VI-B proxy: 10-class MLP on synthetic images (ResNet-50 stand-in;
    /// DESIGN.md §4).
    Mlp,
}

impl Workload {
    pub fn build_set(&self, n: usize, cfg: &SimConfig) -> OracleSet {
        match self {
            Workload::LogReg => LogRegOracle::paper_workload(
                n, cfg.batch, cfg.skew_alpha, cfg.seed,
            )
            .into_set(),
            Workload::Mlp => MlpOracle::paper_workload(
                n, cfg.batch, cfg.skew_alpha, cfg.seed,
            )
            .into_set(),
        }
    }

    /// Paper-calibrated timing model for this workload.
    pub fn paper_config(&self) -> SimConfig {
        match self {
            Workload::LogReg => SimConfig::logreg_paper(),
            Workload::Mlp => SimConfig::resnet_paper(),
        }
    }

    /// Initial parameters (matching scale of the python init).
    pub fn x0(&self, n_dim: usize, seed: u64) -> Vec<f32> {
        match self {
            Workload::LogReg => {
                let mut rng = crate::prng::Rng::stream(seed, 0x1091);
                (0..n_dim).map(|_| rng.normal_f32(0.0, 0.01)).collect()
            }
            Workload::Mlp => MlpOracle::init_theta(seed),
        }
    }
}

/// Per-algorithm step size on the MLP proxy, tuned for matched per-epoch
/// progress at the IID baseline. R-FAST/Push-Pull's descent enters through
/// `v = x − γz` with z the tracked *average* gradient and the mean-dynamics
/// stepping by γ·ψ_i·z_i (ψ the augmented-system left eigenvector), an
/// ≈ n·ψ ≈ 4-6× smaller effective step than D-PSGD's local-gradient update
/// at equal γ — so gradient-tracking methods get a proportionally larger γ.
/// (The paper uses one lr on its testbed; its per-update scaling differs
/// from our event-level model. Documented in DESIGN.md §4.)
pub fn tuned_gamma(workload: Workload, algo: AlgoKind) -> f32 {
    let base = workload.paper_config().gamma;
    match algo {
        AlgoKind::RFast | AlgoKind::RFastNaive | AlgoKind::PushPull => {
            base * 6.0
        }
        AlgoKind::SAb => base * 1.5,
        _ => base,
    }
}

/// One simulated run.
pub fn run_sim(workload: Workload, algo: AlgoKind, topo: &Topology,
               cfg: &SimConfig, stop: StopRule) -> Report {
    let set = workload.build_set(topo.n(), cfg);
    let x0 = workload.x0(set.dim, cfg.seed);
    let mut sim = Simulator::with_x0(cfg.clone(), topo, algo, set, &x0);
    sim.run(stop)
}

/// One simulated run under a fault-injection scenario: `cfg`'s scalar
/// knobs stay as the baseline and `scenario` layers on top (pass
/// `None` to run clean — handy for clean-vs-faulty comparison loops).
pub fn run_sim_under(workload: Workload, algo: AlgoKind, topo: &Topology,
                     cfg: &SimConfig, scenario: Option<&Scenario>,
                     stop: StopRule) -> Report {
    let mut cfg = cfg.clone();
    cfg.scenario = scenario.cloned();
    let mut report = run_sim(workload, algo, topo, &cfg, stop);
    if let Some(sc) = scenario {
        report.label = format!("{} [{}]", report.label, sc.name);
    }
    report
}

/// Wall-clock counterpart of [`run_sim_under`]: the same workload,
/// algorithm and scenario driven through the thread-per-node
/// [`ThreadedRunner`] instead of the simulator. `pace` (seconds) bounds
/// the minimum per-iteration duration — pass `Some(cfg.compute_mean)` to
/// emulate the virtual-time cadence on the wall clock, or `None` when the
/// oracle is naturally paced by real compute.
///
/// Currently supports [`Workload::LogReg`] with the pure-rust oracle; the
/// MLP proxy lives in the PJRT artifacts and has its own wall-clock
/// driver (`examples/e2e_transformer.rs`).
pub fn run_threaded_under(
    workload: Workload,
    algo: AlgoKind,
    topo: &Topology,
    cfg: &SimConfig,
    scenario: Option<&Scenario>,
    pace: Option<f64>,
    until: RunUntil,
) -> Result<(Report, RunnerStats), String> {
    let mut cfg = cfg.clone();
    cfg.scenario = scenario.cloned();
    match workload {
        Workload::LogReg => {
            let factory = LogRegFactory::paper_workload(
                topo.n(), cfg.batch, cfg.skew_alpha, cfg.seed);
            let x0 = workload.x0(factory.dim(), cfg.seed);
            let mut runner = ThreadedRunner::new(cfg, topo, algo, x0);
            if let Some(p) = pace {
                runner = runner.with_pace(p);
            }
            let mut eval = factory.eval_fn();
            let (mut report, stats) = runner.run(&factory, &mut eval, until);
            if let Some(sc) = scenario {
                report.label = format!("{} [{}]", report.label, sc.name);
            }
            Ok((report, stats))
        }
        Workload::Mlp => Err(
            "the threaded engine drives the logreg workload with the \
             pure-rust oracle; the MLP proxy needs the PJRT path \
             (examples/e2e_transformer.rs)"
                .into(),
        ),
    }
}

/// The six-algorithm comparison set of paper §VI-B (Figs 5/6, Table II).
pub const PAPER_BASELINES: [AlgoKind; 6] = [
    AlgoKind::RFast,
    AlgoKind::DPsgd,
    AlgoKind::SAb,
    AlgoKind::AdPsgd,
    AlgoKind::Osgp,
    AlgoKind::RingAllReduce,
];

/// Write every series of several reports as per-series CSVs under `dir`,
/// one file per series name with one column per report.
pub fn save_comparison_csvs(dir: &Path, prefix: &str,
                            reports: &[&Report]) -> std::io::Result<()> {
    use std::collections::BTreeSet;
    let mut names: BTreeSet<&str> = BTreeSet::new();
    for r in reports {
        names.extend(r.series.keys().map(|s| s.as_str()));
    }
    for name in names {
        let series: Vec<_> = reports
            .iter()
            .filter_map(|r| r.series.get(name))
            .collect();
        if series.is_empty() {
            continue;
        }
        // label each column with its report label
        let mut labeled: Vec<crate::metrics::Series> = Vec::new();
        for (r, s) in reports.iter().zip(&series) {
            let mut c = (*s).clone();
            c.name = r.label.clone();
            labeled.push(c);
        }
        let refs: Vec<&crate::metrics::Series> = labeled.iter().collect();
        crate::metrics::save_series_csv(
            &dir.join(format!("{prefix}_{name}.csv")),
            &refs,
        )?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logreg_sim_run_end_to_end() {
        let cfg = SimConfig {
            eval_every: 1.0,
            ..SimConfig::logreg_paper()
        };
        let topo = Topology::ring(4);
        let report = run_sim(Workload::LogReg, AlgoKind::RFast, &topo, &cfg,
                             StopRule::VirtualTime(10.0));
        let s = &report.series["loss_vs_time"];
        assert!(s.last_y().unwrap() < s.points[0].1);
        assert!(report.series.contains_key("acc_vs_time"));
    }

    #[test]
    fn scenario_run_labels_report_and_injects_faults() {
        let cfg = SimConfig {
            eval_every: 1.0,
            ..SimConfig::logreg_paper()
        };
        let topo = Topology::ring(3);
        let sc = Scenario::by_name("lossy_30pct").unwrap();
        let report = run_sim_under(Workload::LogReg, AlgoKind::RFast, &topo,
                                   &cfg, Some(&sc),
                                   StopRule::VirtualTime(3.0));
        assert!(report.label.contains("lossy_30pct"), "{}", report.label);
        assert!(report.scalars["msgs_lost"] > 0.0);
        let clean = run_sim_under(Workload::LogReg, AlgoKind::RFast, &topo,
                                  &cfg, None, StopRule::VirtualTime(3.0));
        assert_eq!(clean.scalars["msgs_lost"], 0.0);
    }

    #[test]
    fn threaded_run_end_to_end_with_scenario() {
        let cfg = SimConfig {
            eval_every: 0.05,
            ..SimConfig::logreg_paper()
        };
        let topo = Topology::ring(3);
        let sc = Scenario::by_name("lossy_30pct").unwrap();
        let (report, stats) = run_threaded_under(
            Workload::LogReg, AlgoKind::RFast, &topo, &cfg, Some(&sc),
            Some(5e-4), RunUntil::WallSeconds(0.3))
            .unwrap();
        assert!(report.label.contains("lossy_30pct"), "{}", report.label);
        assert!(stats.msgs_lost > 0, "loss ramp active in the runner");
        assert!(stats.steps_per_node.iter().sum::<u64>() > 0);
        // the MLP proxy is PJRT-only on this engine
        assert!(run_threaded_under(Workload::Mlp, AlgoKind::RFast, &topo,
                                   &cfg, None, None,
                                   RunUntil::WallSeconds(0.1))
            .is_err());
    }

    #[test]
    fn comparison_csvs_written() {
        let dir = std::env::temp_dir().join("rfast_cmp_csv");
        let mut r1 = Report::new("A");
        r1.series_mut("loss_vs_time", "t", "l").push(0.0, 1.0);
        let mut r2 = Report::new("B");
        r2.series_mut("loss_vs_time", "t", "l").push(0.5, 0.8);
        save_comparison_csvs(&dir, "test", &[&r1, &r2]).unwrap();
        let text =
            std::fs::read_to_string(dir.join("test_loss_vs_time.csv")).unwrap();
        assert!(text.starts_with("x,A,B"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
