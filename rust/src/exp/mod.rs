//! Experiment harness shared by `examples/`, `rust/benches/` and the
//! CLI. The canonical entry point is the [`Experiment`] builder
//! ([`experiment`] module): one typed chain that drives either engine,
//! returns unified [`RunStats`], and fans out into sweeps
//! ([`Comparison`]). The old `run_*` free functions survive as
//! `#[deprecated]` shims over it for one release. The perf-baseline
//! harness (allocation-counting micro benches, scaling sweep,
//! `BENCH_*.json` schema) lives in [`bench`].

pub mod bench;
pub mod experiment;

pub use experiment::{Comparison, Engine, ExpError, Experiment, Run, RunStats,
                     Stop};

use crate::algo::AlgoKind;
use crate::config::SimConfig;
use crate::graph::Topology;
use crate::metrics::Report;
use crate::oracle::{GradOracle, LogRegOracle, MlpOracle, OracleSet,
                    QuadraticOracle};
use crate::runner::RunnerStats;
use crate::scenario::Scenario;
use std::path::Path;

/// Parameters of a closed-form heterogeneous quadratic family
/// ([`Workload::Quadratic`]): the per-node curvature range, minimizer
/// spread (∝ ς of Definition 2) and gradient noise. The node count and
/// seed come from the experiment (topology / config), so one spec sweeps
/// cleanly across both.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuadSpec {
    pub dim: usize,
    /// Curvature range: per-coordinate H_i diagonals are log-uniform in
    /// `[h_min, h_max]`.
    pub h_min: f32,
    pub h_max: f32,
    /// Minimizer spread (0 = IID objectives, growing spread grows ς).
    pub spread: f32,
    /// Per-entry gradient noise σ (Assumption 5).
    pub noise: f32,
}

impl QuadSpec {
    /// The standard heterogeneous test instance (spread 1, no noise) —
    /// the builder twin of [`QuadraticOracle::heterogeneous`].
    pub fn heterogeneous(dim: usize, h_min: f32, h_max: f32) -> QuadSpec {
        QuadSpec { dim, h_min, h_max, spread: 1.0, noise: 0.0 }
    }

    /// With stochastic gradients — the twin of [`QuadraticOracle::noisy`].
    pub fn noisy(dim: usize, sigma: f32) -> QuadSpec {
        QuadSpec { dim, h_min: 0.5, h_max: 4.0, spread: 1.0, noise: sigma }
    }

    /// Materialize the family for `n` nodes from the experiment seed.
    pub fn build(&self, n: usize, seed: u64) -> QuadraticOracle {
        QuadraticOracle::new(self.dim, n, self.h_min, self.h_max, self.spread,
                             self.noise, seed)
    }
}

/// Which training workload an experiment drives.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Workload {
    /// §VI-A: regularized logreg on the synthetic two-digit set
    /// (pure-rust oracle — exact twin of the Pallas kernel).
    LogReg,
    /// §VI-B proxy: 10-class MLP on synthetic images (ResNet-50 stand-in;
    /// DESIGN.md §4).
    Mlp,
    /// Closed-form heterogeneous quadratics (exact optimality gap) —
    /// the convergence-proof workload of the test suites and ablations.
    Quadratic(QuadSpec),
}

impl Workload {
    pub fn build_set(&self, n: usize, cfg: &SimConfig) -> OracleSet {
        match self {
            Workload::LogReg => LogRegOracle::paper_workload(
                n, cfg.batch, cfg.skew_alpha, cfg.seed,
            )
            .into_set(),
            Workload::Mlp => MlpOracle::paper_workload(
                n, cfg.batch, cfg.skew_alpha, cfg.seed,
            )
            .into_set(),
            Workload::Quadratic(spec) => spec.build(n, cfg.seed).into_set(),
        }
    }

    /// Paper-calibrated timing model for this workload (quadratics are
    /// not a paper workload; they default to `SimConfig::default()`).
    pub fn paper_config(&self) -> SimConfig {
        match self {
            Workload::LogReg => SimConfig::logreg_paper(),
            Workload::Mlp => SimConfig::resnet_paper(),
            Workload::Quadratic(_) => SimConfig::default(),
        }
    }

    /// Initial parameters (matching scale of the python init).
    pub fn x0(&self, n_dim: usize, seed: u64) -> Vec<f32> {
        match self {
            Workload::LogReg => {
                let mut rng = crate::prng::Rng::stream(seed, 0x1091);
                (0..n_dim).map(|_| rng.normal_f32(0.0, 0.01)).collect()
            }
            Workload::Mlp => MlpOracle::init_theta(seed),
            Workload::Quadratic(_) => vec![0.0; n_dim],
        }
    }

    /// Stable lowercase name (error messages, CLI, report labels).
    pub fn name(&self) -> &'static str {
        match self {
            Workload::LogReg => "logreg",
            Workload::Mlp => "mlp",
            Workload::Quadratic(_) => "quadratic",
        }
    }

    /// Does one minibatch map onto a fraction of a dataset epoch?
    /// Dataset workloads do; closed-form quadratics have steps, not
    /// passes over data, so `Stop::Epochs` is a typed error there.
    pub fn has_epoch_mapping(&self) -> bool {
        !matches!(self, Workload::Quadratic(_))
    }
}

/// Per-algorithm step size on the MLP proxy, tuned for matched per-epoch
/// progress at the IID baseline. R-FAST/Push-Pull's descent enters through
/// `v = x − γz` with z the tracked *average* gradient and the mean-dynamics
/// stepping by γ·ψ_i·z_i (ψ the augmented-system left eigenvector), an
/// ≈ n·ψ ≈ 4-6× smaller effective step than D-PSGD's local-gradient update
/// at equal γ — so gradient-tracking methods get a proportionally larger γ.
/// (The paper uses one lr on its testbed; its per-update scaling differs
/// from our event-level model. Documented in DESIGN.md §4.)
pub fn tuned_gamma(workload: Workload, algo: AlgoKind) -> f32 {
    let base = workload.paper_config().gamma;
    match algo {
        AlgoKind::RFast | AlgoKind::RFastNaive | AlgoKind::PushPull => {
            base * 6.0
        }
        AlgoKind::SAb => base * 1.5,
        _ => base,
    }
}

/// One simulated run.
///
/// Migration: `run_sim(w, a, &topo, &cfg, stop)` ≡
/// `Experiment::new(w, a).topology(&topo).config(cfg.clone())
///      .stop(stop).run()?.report`.
#[deprecated(note = "use exp::Experiment")]
pub fn run_sim(workload: Workload, algo: AlgoKind, topo: &Topology,
               cfg: &SimConfig, stop: impl Into<Stop>) -> Report {
    Experiment::new(workload, algo)
        .topology(topo)
        .config(cfg.clone())
        .stop(stop.into())
        .run()
        // lint:allow(panic-path): deprecated shim keeps its historical panic-on-error contract
        .unwrap_or_else(|e| panic!("run_sim: {e}"))
        .report
}

/// One simulated run under a fault-injection scenario.
///
/// Migration: append `.maybe_scenario(scenario)` to the
/// [`run_sim`]-equivalent builder chain.
#[deprecated(note = "use exp::Experiment with .scenario(..)")]
pub fn run_sim_under(workload: Workload, algo: AlgoKind, topo: &Topology,
                     cfg: &SimConfig, scenario: Option<&Scenario>,
                     stop: impl Into<Stop>) -> Report {
    // historical contract: the scenario argument REPLACES cfg.scenario
    // unconditionally ("pass None to run clean"), so clear the embedded
    // one before handing over
    let mut cfg = cfg.clone();
    cfg.scenario = None;
    Experiment::new(workload, algo)
        .topology(topo)
        .config(cfg)
        .maybe_scenario(scenario)
        .stop(stop.into())
        .run()
        // lint:allow(panic-path): deprecated shim keeps its historical panic-on-error contract
        .unwrap_or_else(|e| panic!("run_sim_under: {e}"))
        .report
}

/// Wall-clock counterpart of [`run_sim_under`].
///
/// Migration: same chain with
/// `.engine(Engine::threaded(pace)).stop(stop)`; the builder returns
/// the unified [`RunStats`] instead of `RunnerStats` and a typed
/// [`ExpError`] instead of a `String`.
#[deprecated(note = "use exp::Experiment with .engine(Engine::Threaded { .. })")]
pub fn run_threaded_under(
    workload: Workload,
    algo: AlgoKind,
    topo: &Topology,
    cfg: &SimConfig,
    scenario: Option<&Scenario>,
    pace: Option<f64>,
    until: impl Into<Stop>,
) -> Result<(Report, RunnerStats), String> {
    // as in `run_sim_under`: the scenario argument replaces cfg.scenario
    let mut cfg = cfg.clone();
    cfg.scenario = None;
    let run = Experiment::new(workload, algo)
        .topology(topo)
        .config(cfg)
        .maybe_scenario(scenario)
        .engine(Engine::threaded(pace))
        .stop(until.into())
        .run()
        .map_err(|e| e.to_string())?;
    let stats = RunnerStats {
        wall_seconds: run.stats.wall_seconds.unwrap_or(0.0),
        steps_per_node: run.stats.steps_per_node.clone(),
        msgs_sent: run.stats.msgs_sent,
        msgs_lost: run.stats.msgs_lost,
        msgs_backpressured: run.stats.msgs_backpressured,
        msgs_paced: run.stats.msgs_paced,
        msgs_dropped: run.stats.msgs_dropped.unwrap_or(0),
        bytes_sent: run.stats.bytes_sent,
        workers: run.stats.workers.unwrap_or(0),
    };
    Ok((run.report, stats))
}

/// The six-algorithm comparison set of paper §VI-B (Figs 5/6, Table II).
pub const PAPER_BASELINES: [AlgoKind; 6] = [
    AlgoKind::RFast,
    AlgoKind::DPsgd,
    AlgoKind::SAb,
    AlgoKind::AdPsgd,
    AlgoKind::Osgp,
    AlgoKind::RingAllReduce,
];

/// Write every series of several reports as per-series CSVs under `dir`,
/// one file per series name with one column per report. ([`Comparison`]
/// wraps this plus a side-by-side scalar table.)
pub fn save_comparison_csvs(dir: &Path, prefix: &str,
                            reports: &[&Report]) -> std::io::Result<()> {
    use std::collections::BTreeSet;
    let mut names: BTreeSet<&str> = BTreeSet::new();
    for r in reports {
        names.extend(r.series.keys().map(|s| s.as_str()));
    }
    for name in names {
        // pair each series with ITS OWN report's label — reports missing
        // this series contribute no column (an engine sweep's curves live
        // on different clocks, so series sets are often disjoint)
        let labeled: Vec<crate::metrics::Series> = reports
            .iter()
            .filter_map(|r| {
                r.series.get(name).map(|s| {
                    let mut c = s.clone();
                    c.name = r.label.clone();
                    c
                })
            })
            .collect();
        if labeled.is_empty() {
            continue;
        }
        let refs: Vec<&crate::metrics::Series> = labeled.iter().collect();
        crate::metrics::save_series_csv(
            &dir.join(format!("{prefix}_{name}.csv")),
            &refs,
        )?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Per-test unique temp dir: seeded by test name + pid so parallel
    /// test binaries (and parallel CI shards) never collide on a shared
    /// fixed path.
    fn unique_tmp(test: &str) -> std::path::PathBuf {
        std::env::temp_dir()
            .join(format!("rfast_{test}_{}", std::process::id()))
    }

    #[test]
    fn logreg_sim_run_end_to_end() {
        let cfg = SimConfig {
            eval_every: 1.0,
            ..SimConfig::logreg_paper()
        };
        let topo = Topology::ring(4);
        let run = Experiment::new(Workload::LogReg, AlgoKind::RFast)
            .topology(&topo)
            .config(cfg)
            .stop(Stop::Time(10.0))
            .run()
            .unwrap();
        let s = &run.report.series["loss_vs_time"];
        assert!(s.last_y().unwrap() < s.points[0].1);
        assert!(run.report.series.contains_key("acc_vs_time"));
        assert_eq!(run.stats.total_steps(),
                   run.report.scalars["grad_wakes"] as u64);
        assert!(run.stats.virtual_time.is_some());
        assert!(run.stats.wall_seconds.is_none());
    }

    #[test]
    fn scenario_run_labels_report_and_injects_faults() {
        let cfg = SimConfig {
            eval_every: 1.0,
            ..SimConfig::logreg_paper()
        };
        let topo = Topology::ring(3);
        let sc = Scenario::by_name("lossy_30pct").unwrap();
        let base = Experiment::new(Workload::LogReg, AlgoKind::RFast)
            .topology(&topo)
            .config(cfg)
            .stop(Stop::Time(3.0));
        let run = base.clone().scenario(&sc).run().unwrap();
        assert!(run.report.label.contains("lossy_30pct"), "{}",
                run.report.label);
        assert!(run.report.scalars["msgs_lost"] > 0.0);
        assert!(run.stats.msgs_lost > 0);
        let clean = base.run().unwrap();
        assert_eq!(clean.report.scalars["msgs_lost"], 0.0);
    }

    #[test]
    fn threaded_run_end_to_end_with_scenario() {
        let cfg = SimConfig {
            eval_every: 0.05,
            ..SimConfig::logreg_paper()
        };
        let topo = Topology::ring(3);
        let sc = Scenario::by_name("lossy_30pct").unwrap();
        let run = Experiment::new(Workload::LogReg, AlgoKind::RFast)
            .topology(&topo)
            .config(cfg.clone())
            .scenario(&sc)
            .engine(Engine::threaded(Some(5e-4)))
            .stop(Stop::Time(0.3))
            .run()
            .unwrap();
        assert!(run.report.label.contains("lossy_30pct"), "{}",
                run.report.label);
        assert!(run.stats.msgs_lost > 0, "loss ramp active in the runner");
        assert!(run.stats.total_steps() > 0);
        assert!(run.stats.wall_seconds.is_some());
        // the MLP proxy is PJRT-only on this engine — typed error with
        // the pointer to the PJRT path
        let err = Experiment::new(Workload::Mlp, AlgoKind::RFast)
            .topology(&topo)
            .config(cfg)
            .engine(Engine::threaded(None))
            .stop(Stop::Time(0.1))
            .run()
            .unwrap_err();
        match err {
            ExpError::UnsupportedWorkload { hint, .. } => {
                assert!(hint.contains("PJRT"), "{hint}");
            }
            other => panic!("expected UnsupportedWorkload, got {other:?}"),
        }
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_shims_match_the_builder() {
        // one release of back-compat: the shims must reproduce the
        // builder's output exactly (they are thin wrappers over it)
        let cfg = SimConfig {
            eval_every: 1.0,
            ..SimConfig::logreg_paper()
        };
        let topo = Topology::ring(3);
        let via_shim = run_sim(Workload::LogReg, AlgoKind::RFast, &topo, &cfg,
                               Stop::Time(3.0));
        let via_builder = Experiment::new(Workload::LogReg, AlgoKind::RFast)
            .topology(&topo)
            .config(cfg)
            .stop(Stop::Time(3.0))
            .run()
            .unwrap();
        assert_eq!(via_shim.to_json().to_string(),
                   via_builder.report.to_json().to_string());
    }

    #[test]
    fn comparison_csvs_written() {
        let dir = unique_tmp("comparison_csvs_written");
        let mut r1 = Report::new("A");
        r1.series_mut("loss_vs_time", "t", "l").push(0.0, 1.0);
        let mut r2 = Report::new("B");
        r2.series_mut("loss_vs_time", "t", "l").push(0.5, 0.8);
        save_comparison_csvs(&dir, "test", &[&r1, &r2]).unwrap();
        let text =
            std::fs::read_to_string(dir.join("test_loss_vs_time.csv")).unwrap();
        assert!(text.starts_with("x,A,B"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sweep_algos_feeds_comparison_csvs() {
        let cfg = SimConfig {
            seed: 5,
            gamma: 0.03,
            compute_mean: 0.01,
            link_latency: 0.002,
            latency_cap: 0.05,
            eval_every: 2.0,
            ..SimConfig::default()
        };
        let topo = Topology::ring(4);
        let cmp = Experiment::new(
                Workload::Quadratic(QuadSpec::heterogeneous(8, 0.5, 2.0)),
                AlgoKind::RFast)
            .topology(&topo)
            .config(cfg)
            .stop(Stop::Iterations(2_000))
            .sweep_algos(&[AlgoKind::RFast, AlgoKind::DPsgd])
            .unwrap();
        assert_eq!(cmp.runs.len(), 2);
        assert_eq!(cmp.runs[0].report.label, "R-FAST");
        assert_eq!(cmp.runs[1].report.label, "D-PSGD");
        assert!(cmp.runs.iter().all(|r| r.report.final_gap.is_some()));
        let dir = unique_tmp("sweep_algos_csvs");
        cmp.save_csvs(&dir, "quad").unwrap();
        let scalars =
            std::fs::read_to_string(dir.join("quad_scalars.csv")).unwrap();
        assert!(scalars.starts_with("metric,R-FAST,D-PSGD"), "{scalars}");
        assert!(scalars.lines().any(|l| l.starts_with("msgs_lost,")));
        assert!(dir.join("quad_loss_vs_time.csv").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stop_parse_grammar() {
        assert_eq!(Stop::parse("iters:200").unwrap(), Stop::Iterations(200));
        assert_eq!(Stop::parse("time:2.5").unwrap(), Stop::Time(2.5));
        assert_eq!(Stop::parse("epochs:10").unwrap(), Stop::Epochs(10.0));
        assert_eq!(Stop::parse("loss:0.1:60").unwrap(),
                   Stop::TargetLoss { loss: 0.1, max_time: 60.0 });
        // bare loss target gets a FINITE fallback deadline (no hangs)
        assert_eq!(Stop::parse("loss:0.1").unwrap(),
                   Stop::TargetLoss {
                       loss: 0.1,
                       max_time: Stop::DEFAULT_TARGET_DEADLINE,
                   });
        assert!(Stop::parse("iters:abc").is_err());
        assert!(Stop::parse("bogus:1").is_err());
        assert!(Stop::parse("200").is_err());
        // non-finite/negative values would make a rule that never fires
        assert!(Stop::parse("time:nan").is_err());
        assert!(Stop::parse("epochs:inf").is_err());
        assert!(Stop::parse("time:-5").is_err());
    }
}
