//! Deterministic fault-space fuzzer with invariant oracles and
//! auto-shrinking repros (DESIGN.md §11).
//!
//! FoundationDB-style simulation testing over the R-FAST stack: a seed
//! deterministically generates a [`FuzzCase`] — node count, a random
//! asymmetric (G_R, G_C) spanning-tree pair, step size, iteration budget
//! and a random fault [`Scenario`] (stragglers, loss/latency ramps,
//! churn windows, bandwidth caps) — which runs on the virtual-time
//! simulator through the [`Experiment`] builder. After every run a fixed
//! catalog of invariant oracles ([`oracles`]) checks properties the
//! algorithm must hold under ANY fault schedule: bounded optimality gap,
//! ρ-mass conservation of the robust gradient tracker, no stuck
//! backpressure, and counter sanity. A violation is [`shrink`]-reduced
//! to a minimal JSON repro (`rust/tests/repros/`) that replays as a
//! permanent regression test.
//!
//! Everything is a pure function of the seed: no wall clock, no global
//! RNG — `repro fuzz --seed S --budget N` prints bitwise-identical
//! output on every invocation.

pub mod oracles;
pub mod shrink;

use crate::algo::AlgoKind;
use crate::config::SimConfig;
use crate::exp::{Engine, Experiment, QuadSpec, Stop, Workload};
use crate::graph::ArchSpec;
use crate::jsonio::{self, Json};
use crate::prng::Rng;
use crate::runner::MailboxCfg;
use crate::scenario::Scenario;

/// Schema tag of committed repro files — bump on breaking layout change.
pub const SCHEMA: &str = "rfast-fuzz-repro/v1";

/// Cases per `repro fuzz` run when neither `--budget` nor
/// `RFAST_FUZZ_BUDGET` is given.
pub const DEFAULT_BUDGET: u64 = 50;

/// Cases per `repro fuzz --engine threaded` run by default: wall-clock
/// cases cost real seconds each where virtual-time cases cost
/// milliseconds, so the actor-engine sweep keeps a small budget.
pub const DEFAULT_THREADED_BUDGET: u64 = 8;

/// Pacing floor of threaded fuzz runs (seconds per local iteration):
/// fast enough that a small budget finishes in CI, slow enough that the
/// actor scheduler's suspend/resume machinery actually engages.
const THREADED_PACE: f64 = 1e-4;

/// Worker-pool size of threaded fuzz runs — deliberately smaller than
/// most sampled node counts, so every case exercises M > N multiplexing.
const THREADED_WORKERS: usize = 4;

/// The shrinker never reduces the iteration budget below this.
pub const ITERS_FLOOR: u64 = 50;

/// Mean compute time per gradient step (seconds of virtual time) in the
/// fuzzer's fixed run configuration.
const COMPUTE_MEAN: f64 = 0.01;

/// One self-contained fuzz input: everything needed to reproduce a run
/// bit-for-bit. `PartialEq` is exact (f32/f64 bit values), so repro
/// round-trip tests can compare cases directly.
#[derive(Clone, Debug, PartialEq)]
pub struct FuzzCase {
    /// Node count (≥ 2).
    pub n: usize,
    /// Asymmetric (G_R, G_C) spanning-tree pair, both rooted at node 0
    /// so Assumption 2 survives any `n` the shrinker picks.
    pub arch: ArchSpec,
    /// Simulator seed (bounded below 2^48 so JSON keeps it exact).
    pub seed: u64,
    /// Step size; the generator stays in the contractive range for the
    /// fixed quadratic workload.
    pub gamma: f32,
    /// Total gradient steps across all nodes ([`Stop::Iterations`]).
    pub iters: u64,
    /// Fault schedule ([`Scenario::sample`]).
    pub scenario: Scenario,
}

impl FuzzCase {
    /// Case `case` of the corpus seeded by `fuzz_seed` — an independent
    /// PRNG stream per case, so verdicts never depend on corpus order or
    /// budget.
    pub fn sample(fuzz_seed: u64, case: u64) -> FuzzCase {
        let mut rng = Rng::stream(fuzz_seed, case);
        // every 8th case draws a large n (up to 256) to exercise the
        // sparse topology + calendar-queue path; the gate keeps all other
        // case indices bitwise identical to the pre-sparse corpus (both
        // branches consume exactly one `below` draw)
        let n = if case % 8 == 7 {
            10 + rng.below(247)
        } else {
            2 + rng.below(9)
        };
        let arch = ArchSpec::sample(&mut rng);
        // contractive for the h ∈ [0.5, 2] quadratics: |1 − γh| < 1
        let gamma = (0.01 + 0.04 * rng.f64()) as f32;
        let iters = 100 + 50 * rng.below(7) as u64;
        // rough virtual length of the run: iters steps at COMPUTE_MEAN
        // seconds each, spread over n concurrent nodes (×2 slack for
        // stragglers), so sampled fault windows overlap the run
        let horizon = iters as f64 / n as f64 * COMPUTE_MEAN * 2.0;
        let scenario = Scenario::sample(&mut rng, n, horizon);
        let seed = rng.below(1 << 48) as u64;
        FuzzCase { n, arch, seed, gamma, iters, scenario }
    }

    /// A case that violates `gap_bounded` by construction: γ = 16 on
    /// curvatures h ∈ [0.5, 2] gives a per-coordinate divergence factor
    /// |1 − γh| ≥ 7, so the quadratic dynamics blow up within a handful
    /// of steps at ANY n ≥ 2 and ANY fault schedule — every shrink
    /// candidate still fails, driving the shrinker to its floors. The
    /// seed-corpus test pins its shrink endpoint against
    /// `rust/tests/repros/diverging_gamma.json`.
    pub fn diverging_example() -> FuzzCase {
        use crate::scenario::{ChurnEvent, Phase, StragglerSchedule,
                              StragglerSpec};
        let mut scenario =
            Scenario::named("fuzz", "generated fault scenario");
        scenario.stragglers.push(StragglerSpec {
            node: 1,
            factor: 3.0,
            schedule: StragglerSchedule::Permanent,
        });
        scenario.loss_ramp.push(Phase { from_time: 0.0, value: 0.2 });
        scenario.churn.push(ChurnEvent {
            node: 0,
            pause_at: 0.1,
            resume_at: 0.3,
        });
        FuzzCase {
            n: 6,
            arch: ArchSpec::parse("balanced@0+star@0")
                // lint:allow(panic-path): literal spec, parse covered by arch tests
                .expect("literal spec parses"),
            seed: 7,
            gamma: 16.0,
            iters: 400,
            scenario,
        }
    }

    /// The fixed run configuration: paper-calibrated logreg timing
    /// (compute 10ms, link 2ms, cap 50ms) with the case's seed and γ.
    /// Faults come from the scenario, not the base config.
    fn config(&self) -> SimConfig {
        SimConfig {
            seed: self.seed,
            gamma: self.gamma,
            compute_mean: COMPUTE_MEAN,
            link_latency: 0.002,
            latency_cap: 0.05,
            eval_every: 0.25,
            ..SimConfig::default()
        }
    }

    /// Execute on the virtual-time simulator and check every oracle.
    /// Setup failures (unbuildable architecture, invalid config) are a
    /// `"setup"` violation — the generator is supposed to never produce
    /// them, so they are fuzz findings too, not panics.
    pub fn run(&self) -> CaseOutcome {
        let topo = match self.arch.build(self.n) {
            Ok(t) => t,
            Err(e) => {
                return CaseOutcome::fail("setup", format!("arch build: {e}"))
            }
        };
        let spec = QuadSpec::heterogeneous(4, 0.5, 2.0);
        let exp = Experiment::new(Workload::Quadratic(spec), AlgoKind::RFast)
            .topology(&topo)
            .config(self.config())
            .scenario(&self.scenario)
            .stop(Stop::Iterations(self.iters));
        match exp.run_sim_probed(oracles::MassProbe::capture) {
            Ok((run, probe)) => oracles::check(self, &run, &probe),
            Err(e) => CaseOutcome::fail("setup", e.to_string()),
        }
    }

    /// Execute on the wall-clock actor runner (small worker pool, default
    /// mailbox) and check the schedule-independent oracle subset
    /// ([`oracles::check_threaded`]): liveness and counter conservation
    /// must hold under real preemptive scheduling exactly as under the
    /// simulator's deterministic one.
    pub fn run_threaded(&self) -> CaseOutcome {
        let topo = match self.arch.build(self.n) {
            Ok(t) => t,
            Err(e) => {
                return CaseOutcome::fail("setup", format!("arch build: {e}"))
            }
        };
        let spec = QuadSpec::heterogeneous(4, 0.5, 2.0);
        let exp = Experiment::new(Workload::Quadratic(spec), AlgoKind::RFast)
            .topology(&topo)
            .config(self.config())
            .scenario(&self.scenario)
            .engine(Engine::Threaded {
                pace: Some(THREADED_PACE),
                workers: Some(THREADED_WORKERS),
                mailbox: MailboxCfg::default(),
            })
            .stop(Stop::Iterations(self.iters));
        match exp.run() {
            Ok(run) => oracles::check_threaded(self, &run),
            Err(e) => CaseOutcome::fail("setup", e.to_string()),
        }
    }
}

/// Verdict of one case: which oracle fired (if any) and a human-readable
/// detail line. Details are pure functions of the run, so two corpus
/// runs compare bitwise-equal.
#[derive(Clone, Debug, PartialEq)]
pub struct CaseOutcome {
    /// `None` = every oracle passed; otherwise the oracle's name (one of
    /// [`oracles::ORACLES`] or `"setup"`).
    pub violation: Option<&'static str>,
    pub detail: String,
}

impl CaseOutcome {
    pub fn pass() -> CaseOutcome {
        CaseOutcome { violation: None, detail: String::new() }
    }

    pub fn fail(oracle: &'static str, detail: String) -> CaseOutcome {
        CaseOutcome { violation: Some(oracle), detail }
    }

    pub fn is_fail(&self) -> bool {
        self.violation.is_some()
    }
}

/// A committed (or to-be-committed) repro file: the case plus its
/// recorded verdict. `expect: "pass"` pins a formerly-shrunk case that
/// has since been fixed; `expect: "fail"` demands the SAME oracle still
/// fires on replay (a different oracle or a pass is a regression of the
/// repro's meaning).
#[derive(Clone, Debug, PartialEq)]
pub struct Repro {
    pub case: FuzzCase,
    /// `"pass"` or `"fail"`.
    pub expect: String,
    /// The firing oracle's name when `expect == "fail"`.
    pub violation: Option<String>,
}

impl Repro {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", SCHEMA.into()),
            ("n", self.case.n.into()),
            ("arch", self.case.arch.name().into()),
            ("seed", (self.case.seed as f64).into()),
            ("gamma", (self.case.gamma as f64).into()),
            ("iters", (self.case.iters as f64).into()),
            ("scenario", self.case.scenario.to_json()),
            ("expect", self.expect.as_str().into()),
            (
                "violation",
                match &self.violation {
                    Some(v) => v.as_str().into(),
                    None => Json::Null,
                },
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Repro, String> {
        let schema = j
            .get("schema")
            .and_then(Json::as_str)
            .ok_or("repro: missing schema tag")?;
        if schema != SCHEMA {
            return Err(format!(
                "repro: schema {schema:?}, this build reads {SCHEMA:?}"
            ));
        }
        let int = |key: &str| -> Result<u64, String> {
            let x = j
                .get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("repro: missing number {key:?}"))?;
            if x.fract() != 0.0 || !(0.0..9.0e15).contains(&x) {
                return Err(format!("repro: {key} = {x} is not a valid count"));
            }
            Ok(x as u64)
        };
        let n = int("n")? as usize;
        if n < 2 {
            return Err(format!("repro: n = {n} (needs ≥ 2)"));
        }
        let arch_str = j
            .get("arch")
            .and_then(Json::as_str)
            .ok_or("repro: missing arch")?;
        let arch = ArchSpec::parse(arch_str)
            .map_err(|e| format!("repro: bad arch {arch_str:?}: {e}"))?;
        let gamma = j
            .get("gamma")
            .and_then(Json::as_f64)
            .ok_or("repro: missing gamma")? as f32;
        let iters = int("iters")?;
        let scenario = Scenario::from_json(
            j.get("scenario").ok_or("repro: missing scenario")?,
        )?;
        scenario
            .validate(Some(n))
            .map_err(|e| format!("repro: scenario invalid at n={n}: {e}"))?;
        let expect = j
            .get("expect")
            .and_then(Json::as_str)
            .ok_or("repro: missing expect")?
            .to_string();
        if expect != "pass" && expect != "fail" {
            return Err(format!("repro: expect {expect:?} (pass|fail)"));
        }
        let violation = match j.get("violation") {
            None | Some(Json::Null) => None,
            Some(v) => Some(
                v.as_str()
                    .ok_or("repro: violation must be a string or null")?
                    .to_string(),
            ),
        };
        if expect == "fail" && violation.is_none() {
            return Err("repro: expect \"fail\" needs a violation name".into());
        }
        Ok(Repro {
            case: FuzzCase { n, arch, seed: int("seed")?, gamma, iters,
                             scenario },
            expect,
            violation,
        })
    }

    /// Read and parse one repro file.
    pub fn load(path: &std::path::Path) -> Result<Repro, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        let j = jsonio::parse(&text)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        Repro::from_json(&j).map_err(|e| format!("{}: {e}", path.display()))
    }

    /// Replay the case and compare against the recorded verdict.
    /// `Ok(())` = behaves as committed; `Err` describes the mismatch.
    pub fn replay(&self) -> Result<(), String> {
        let outcome = self.case.run();
        match (self.expect.as_str(), outcome.violation) {
            ("pass", None) => Ok(()),
            ("pass", Some(v)) => Err(format!(
                "expected pass, oracle {v} fired: {}",
                outcome.detail
            )),
            ("fail", Some(v)) => {
                if Some(v) == self.violation.as_deref() {
                    Ok(())
                } else {
                    Err(format!(
                        "expected {:?} to fire, got {v}: {}",
                        self.violation.as_deref().unwrap_or("?"),
                        outcome.detail
                    ))
                }
            }
            ("fail", None) => Err(format!(
                "expected {:?} to fire, but every oracle passed — if the \
                 underlying bug is fixed, flip this repro to expect \
                 \"pass\"",
                self.violation.as_deref().unwrap_or("?")
            )),
            // lint:allow(panic-path): Repro::load rejects any expect value other than pass/fail
            _ => unreachable!("expect validated at parse"),
        }
    }
}

/// One corpus failure: the generated case, its verdict, and (with
/// shrinking on) the minimal case that still fires the same oracle.
#[derive(Clone, Debug, PartialEq)]
pub struct Failure {
    pub case_index: u64,
    pub case: FuzzCase,
    pub violation: &'static str,
    pub detail: String,
    pub shrunk: Option<FuzzCase>,
}

/// Result of a corpus run — `PartialEq` so the determinism tests compare
/// two full runs directly.
#[derive(Clone, Debug, PartialEq)]
pub struct FuzzReport {
    pub seed: u64,
    pub budget: u64,
    pub failures: Vec<Failure>,
}

/// Run `budget` generated cases from `seed`; optionally shrink each
/// failure to its minimal form. Pure function of `(seed, budget,
/// shrink_failures)`.
pub fn run_corpus(seed: u64, budget: u64,
                  shrink_failures: bool) -> FuzzReport {
    let mut failures = Vec::new();
    for case_index in 0..budget {
        let case = FuzzCase::sample(seed, case_index);
        let outcome = case.run();
        if let Some(violation) = outcome.violation {
            let shrunk = shrink_failures
                .then(|| shrink::shrink(&case, violation));
            failures.push(Failure {
                case_index,
                case,
                violation,
                detail: outcome.detail,
                shrunk,
            });
        }
    }
    FuzzReport { seed, budget, failures }
}

/// Replay `budget` generated cases on the actor runner (`repro fuzz
/// --engine threaded`). Case *generation* stays a pure function of the
/// seed; the verdict depends on real OS scheduling, so there is no
/// shrinker here — reproduce a failing case's fault schedule under
/// [`run_corpus`] for a deterministic minimal repro.
pub fn run_corpus_threaded(seed: u64, budget: u64) -> FuzzReport {
    let mut failures = Vec::new();
    for case_index in 0..budget {
        let case = FuzzCase::sample(seed, case_index);
        let outcome = case.run_threaded();
        if let Some(violation) = outcome.violation {
            failures.push(Failure {
                case_index,
                case,
                violation,
                detail: outcome.detail,
                shrunk: None,
            });
        }
    }
    FuzzReport { seed, budget, failures }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampling_is_deterministic_and_stream_independent() {
        let a = FuzzCase::sample(42, 3);
        let b = FuzzCase::sample(42, 3);
        assert_eq!(a, b);
        // neighboring case indices draw from independent streams
        assert_ne!(FuzzCase::sample(42, 3), FuzzCase::sample(42, 4));
    }

    #[test]
    fn large_n_cases_appear_only_on_the_gated_indices() {
        for i in 0..32 {
            let c = FuzzCase::sample(5, i);
            if i % 8 == 7 {
                assert!((10..=256).contains(&c.n), "case {i}: n = {}", c.n);
            } else {
                assert!((2..=10).contains(&c.n), "case {i}: n = {}", c.n);
            }
        }
    }

    #[test]
    fn sampled_seeds_survive_f64_json() {
        for i in 0..64 {
            let c = FuzzCase::sample(9, i);
            assert!(c.seed < (1 << 48));
            assert_eq!(c.seed as f64 as u64, c.seed);
        }
    }

    #[test]
    fn repro_json_rejects_garbage() {
        let bad = |src: &str| {
            Repro::from_json(&jsonio::parse(src).unwrap()).unwrap_err()
        };
        assert!(bad("{}").contains("schema"));
        assert!(bad(r#"{"schema":"rfast-fuzz-repro/v0"}"#)
            .contains("schema"));
        let repro = Repro {
            case: FuzzCase::diverging_example(),
            expect: "fail".into(),
            violation: None,
        };
        let err = Repro::from_json(&repro.to_json()).unwrap_err();
        assert!(err.contains("violation"), "{err}");
    }

    #[test]
    fn diverging_example_roundtrips() {
        let repro = Repro {
            case: FuzzCase::diverging_example(),
            expect: "fail".into(),
            violation: Some("gap_bounded".into()),
        };
        let text = repro.to_json().to_string();
        let back = Repro::from_json(&jsonio::parse(&text).unwrap()).unwrap();
        assert_eq!(back, repro);
        assert_eq!(back.to_json().to_string(), text);
    }
}
