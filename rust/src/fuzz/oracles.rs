//! The fuzzer's invariant catalog — properties every R-FAST run must
//! hold under ANY generated fault schedule, checked in a FIXED order so
//! one root cause always reports the same oracle name (shrinking
//! preserves "same violation", so order stability is load-bearing):
//!
//! 1. `gap_bounded` — the run converged to a neighborhood: `final_gap`
//!    exists, is finite and ≤ [`GAP_LIMIT`]. Owns every divergence/NaN
//!    failure, so later oracles never fire on fp noise at blown-up
//!    magnitudes.
//! 2. `mass_conservation` — the robust gradient tracker's ρ running-sum
//!    mass balance ([`crate::testutil::rho_mass_residual`], the Lemma 3
//!    analogue) holds on the final simulator state to f32 accumulation
//!    accuracy, scaled by the state's magnitude.
//! 3. `no_stuck` — the event heap never drained before the stop rule
//!    (`drained_early`) and the full iteration budget executed: a
//!    permanently-backpressured `LinkSlots` or a never-resumed node
//!    would starve the step counter.
//! 4. `scalar_sanity` — conservation of message counters (every verdict
//!    ≤ sends, verdicts don't double-count) and report/stats agreement.

use super::{CaseOutcome, FuzzCase};
use crate::algo::RFastNode;
use crate::exp::Run;
use crate::sim::Simulator;
use crate::testutil::rho_mass_residual;

/// Oracle names in check order (see module docs).
pub const ORACLES: [&str; 4] =
    ["gap_bounded", "mass_conservation", "no_stuck", "scalar_sanity"];

/// `gap_bounded` threshold: generated cases use contractive step sizes
/// on O(1)-scale quadratics, so a final gap anywhere near this is a
/// genuine blow-up, not a slow run.
pub const GAP_LIMIT: f64 = 1e3;

/// Relative tolerance of `mass_conservation`: the residual accumulates
/// f32 rounding from every z/gradient update, so it scales with the
/// final state's magnitude.
pub const MASS_RTOL: f64 = 1e-2;

/// Conservation evidence captured from the final simulator state (the
/// [`Experiment::run_sim_probed`](crate::exp::Experiment::run_sim_probed)
/// probe runs before the simulator drops).
#[derive(Clone, Debug, PartialEq)]
pub struct MassProbe {
    /// Max per-coordinate |Σz + Σ(ρ − ρ̃) − Σ∇f| — `None` when the nodes
    /// are not [`RFastNode`]s (nothing to probe).
    pub residual: Option<f64>,
    /// Σ|z| + Σ|∇f| over initialized nodes: the f32 magnitude the
    /// residual tolerance tracks.
    pub scale: f64,
}

impl MassProbe {
    pub fn capture(sim: &Simulator) -> MassProbe {
        let mut refs: Vec<&RFastNode> =
            Vec::with_capacity(sim.nodes().len());
        for nd in sim.nodes() {
            match nd.as_any().and_then(|a| a.downcast_ref::<RFastNode>()) {
                Some(r) => refs.push(r),
                None => return MassProbe { residual: None, scale: 0.0 },
            }
        }
        let mut scale = 0.0f64;
        for r in &refs {
            if !r.is_initialized() {
                continue;
            }
            scale +=
                r.z().iter().map(|&v| v.abs() as f64).sum::<f64>();
            scale +=
                r.last_grad().iter().map(|&v| v.abs() as f64).sum::<f64>();
        }
        MassProbe { residual: Some(rho_mass_residual(&refs)), scale }
    }
}

/// Run the full catalog against a finished run. Returns the FIRST
/// violation in catalog order, or a pass.
pub fn check(case: &FuzzCase, run: &Run, probe: &MassProbe) -> CaseOutcome {
    // 1. gap_bounded
    let gap = match run.report.final_gap {
        Some(g) => g,
        None => {
            return CaseOutcome::fail(
                "gap_bounded",
                "no final_gap on a quadratic run".into(),
            )
        }
    };
    if !gap.is_finite() || gap > GAP_LIMIT {
        return CaseOutcome::fail(
            "gap_bounded",
            format!("final gap {gap:e} exceeds {GAP_LIMIT:e}"),
        );
    }

    // 2. mass_conservation (only meaningful once magnitudes are bounded)
    if let Some(residual) = probe.residual {
        let tol = MASS_RTOL * probe.scale.max(1.0);
        if !(residual <= tol) {
            return CaseOutcome::fail(
                "mass_conservation",
                format!(
                    "residual {residual:e} > tol {tol:e} (state scale \
                     {:e})",
                    probe.scale
                ),
            );
        }
    }

    // 3. no_stuck
    if run.report.scalars.contains_key("drained_early") {
        return CaseOutcome::fail(
            "no_stuck",
            "event heap drained before the stop rule".into(),
        );
    }
    let steps = run.stats.total_steps();
    if steps < case.iters {
        return CaseOutcome::fail(
            "no_stuck",
            format!("only {steps} of {} budgeted steps ran", case.iters),
        );
    }

    // 4. scalar_sanity
    let s = &run.stats;
    let delivered = s.msgs_delivered.unwrap_or(0);
    for (what, count) in [
        ("msgs_lost", s.msgs_lost),
        ("msgs_backpressured", s.msgs_backpressured),
        ("msgs_delivered", delivered),
    ] {
        if count > s.msgs_sent {
            return CaseOutcome::fail(
                "scalar_sanity",
                format!("{what} {count} > msgs_sent {}", s.msgs_sent),
            );
        }
    }
    // verdicts are mutually exclusive per send; the remainder is in
    // flight at the stop instant
    let verdicts = s.msgs_lost + s.msgs_backpressured + delivered;
    if verdicts > s.msgs_sent {
        return CaseOutcome::fail(
            "scalar_sanity",
            format!(
                "verdicts double-counted: lost {} + backpressured {} + \
                 delivered {delivered} > sent {}",
                s.msgs_lost, s.msgs_backpressured, s.msgs_sent
            ),
        );
    }
    // the report's scalar table must agree with the engine counters
    for (key, expect) in [
        ("msgs_sent", s.msgs_sent as f64),
        ("msgs_lost", s.msgs_lost as f64),
        ("msgs_backpressured", s.msgs_backpressured as f64),
        ("msgs_delivered", delivered as f64),
    ] {
        if let Some(&got) = run.report.scalars.get(key) {
            if got != expect {
                return CaseOutcome::fail(
                    "scalar_sanity",
                    format!("report scalar {key} = {got}, stats say \
                             {expect}"),
                );
            }
        }
    }
    if let Some(vt) = s.virtual_time {
        if !vt.is_finite() || vt < 0.0 {
            return CaseOutcome::fail(
                "scalar_sanity",
                format!("virtual_time {vt} is not a valid clock reading"),
            );
        }
    }
    CaseOutcome::pass()
}

/// Schedule-independent subset of the catalog, for `repro fuzz --engine
/// threaded` runs on the actor pool. `gap_bounded` and
/// `mass_conservation` are calibrated against virtual-time delivery
/// ratios and stay sim-only; liveness and counter conservation must hold
/// under real preemptive scheduling too:
///
/// * `no_stuck` — the full iteration budget executed. A lost actor
///   wakeup, a wedged (link, channel) slot (e.g. a mailbox drop that
///   never released its channel) or a never-resumed suspend starves the
///   global step counter.
/// * `scalar_sanity` — terminal verdicts (lost / backpressured /
///   mailbox-dropped) never exceed or double-count sends, the report's
///   scalar table agrees with the engine counters, and the wall clock
///   and pool size read as valid.
pub fn check_threaded(case: &FuzzCase, run: &Run) -> CaseOutcome {
    // no_stuck
    let steps = run.stats.total_steps();
    if steps < case.iters {
        return CaseOutcome::fail(
            "no_stuck",
            format!("only {steps} of {} budgeted steps ran", case.iters),
        );
    }

    // scalar_sanity
    let s = &run.stats;
    let dropped = s.msgs_dropped.unwrap_or(0);
    for (what, count) in [
        ("msgs_lost", s.msgs_lost),
        ("msgs_backpressured", s.msgs_backpressured),
        ("msgs_paced", s.msgs_paced),
        ("msgs_dropped", dropped),
    ] {
        if count > s.msgs_sent {
            return CaseOutcome::fail(
                "scalar_sanity",
                format!("{what} {count} > msgs_sent {}", s.msgs_sent),
            );
        }
    }
    // lost / backpressured / dropped are terminal per send attempt, so
    // their sum never exceeds sends (paced messages still deliver and are
    // counted separately)
    let verdicts = s.msgs_lost + s.msgs_backpressured + dropped;
    if verdicts > s.msgs_sent {
        return CaseOutcome::fail(
            "scalar_sanity",
            format!(
                "verdicts double-counted: lost {} + backpressured {} + \
                 dropped {dropped} > sent {}",
                s.msgs_lost, s.msgs_backpressured, s.msgs_sent
            ),
        );
    }
    for (key, expect) in [
        ("msgs_sent", s.msgs_sent as f64),
        ("msgs_lost", s.msgs_lost as f64),
        ("msgs_backpressured", s.msgs_backpressured as f64),
        ("msgs_paced", s.msgs_paced as f64),
        ("msgs_dropped", dropped as f64),
        ("bytes_sent", s.bytes_sent as f64),
    ] {
        if let Some(&got) = run.report.scalars.get(key) {
            if got != expect {
                return CaseOutcome::fail(
                    "scalar_sanity",
                    format!("report scalar {key} = {got}, stats say \
                             {expect}"),
                );
            }
        }
    }
    match s.wall_seconds {
        Some(w) if w.is_finite() && w >= 0.0 => {}
        other => {
            return CaseOutcome::fail(
                "scalar_sanity",
                format!("wall_seconds {other:?} is not a valid clock \
                         reading"),
            )
        }
    }
    if s.workers.map_or(true, |w| w == 0) {
        return CaseOutcome::fail(
            "scalar_sanity",
            format!("threaded run reports workers = {:?}", s.workers),
        );
    }
    CaseOutcome::pass()
}
