//! Greedy auto-shrinker: reduce a failing [`FuzzCase`] to a minimal one
//! that still fires the SAME oracle (DESIGN.md §11).
//!
//! Classic property-testing shrink loop, specialized to the fault
//! grammar. Each round enumerates every single-step reduction of the
//! current case in a FIXED order — drop one fault clause, shrink the
//! node count, halve the iteration budget, halve one fault magnitude
//! toward its neutral value — and re-runs candidates until one
//! reproduces the violation; that candidate becomes current. The loop
//! ends at a fixpoint: no candidate still fails.
//!
//! Termination: clause drops and n/iters reductions strictly shrink
//! integers; magnitude halvings are only generated while the value is a
//! significance threshold away from neutral, so each clause admits
//! finitely many. [`MAX_STEPS`] is a defensive backstop, not the normal
//! exit.

use super::{FuzzCase, ITERS_FLOOR};
use crate::scenario::Scenario;

/// Backstop on accepted reductions (each strictly shrinks the case, so
/// real chains are far shorter).
const MAX_STEPS: usize = 512;

/// Shrink `case` — which must currently fire `violation` — to a minimal
/// case still firing it. Deterministic: candidate order is fixed and
/// every re-run is seeded by the case itself.
pub fn shrink(case: &FuzzCase, violation: &'static str) -> FuzzCase {
    let mut cur = case.clone();
    for _ in 0..MAX_STEPS {
        let next = candidates(&cur)
            .into_iter()
            .find(|c| c.run().violation == Some(violation));
        match next {
            Some(c) => cur = c,
            None => break,
        }
    }
    cur
}

/// Every single-step reduction of `case`, in acceptance-priority order:
/// structure first (fewer clauses beat smaller magnitudes in a minimal
/// repro), then scale (n, iters), then magnitudes.
fn candidates(case: &FuzzCase) -> Vec<FuzzCase> {
    let mut out = Vec::new();
    let sc = &case.scenario;

    // 1. drop one whole fault clause
    for i in 0..sc.stragglers.len() {
        let mut s = sc.clone();
        s.stragglers.remove(i);
        out.push(with_scenario(case, s));
    }
    for i in 0..sc.loss_ramp.len() {
        let mut s = sc.clone();
        s.loss_ramp.remove(i);
        out.push(with_scenario(case, s));
    }
    for i in 0..sc.latency_ramp.len() {
        let mut s = sc.clone();
        s.latency_ramp.remove(i);
        out.push(with_scenario(case, s));
    }
    for i in 0..sc.churn.len() {
        let mut s = sc.clone();
        s.churn.remove(i);
        out.push(with_scenario(case, s));
    }
    for i in 0..sc.bandwidth.len() {
        let mut s = sc.clone();
        s.bandwidth.remove(i);
        out.push(with_scenario(case, s));
    }

    // 2. shrink the node count (both trees are rooted at 0, so any
    //    n ≥ 2 builds; clauses naming dropped nodes go with them)
    let half = (case.n / 2).max(2);
    if half < case.n {
        out.push(with_n(case, half));
    }
    if case.n > 2 && case.n - 1 != half {
        out.push(with_n(case, case.n - 1));
    }

    // 3. halve the iteration budget
    let half_iters = (case.iters / 2).max(ITERS_FLOOR);
    if half_iters < case.iters {
        let mut c = case.clone();
        c.iters = half_iters;
        out.push(c);
    }

    // 4. halve one magnitude toward neutral (thresholds keep the
    //    chain finite; below them the clause is dropped, not dimmed)
    for i in 0..sc.stragglers.len() {
        let f = sc.stragglers[i].factor;
        if f - 1.0 >= 0.5 {
            let mut s = sc.clone();
            s.stragglers[i].factor = 1.0 + (f - 1.0) / 2.0;
            out.push(with_scenario(case, s));
        }
    }
    for i in 0..sc.loss_ramp.len() {
        let v = sc.loss_ramp[i].value;
        if v >= 0.05 {
            let mut s = sc.clone();
            s.loss_ramp[i].value = v / 2.0;
            out.push(with_scenario(case, s));
        }
    }
    for i in 0..sc.latency_ramp.len() {
        let v = sc.latency_ramp[i].value;
        if (v - 1.0).abs() >= 0.25 {
            let mut s = sc.clone();
            s.latency_ramp[i].value = 1.0 + (v - 1.0) / 2.0;
            out.push(with_scenario(case, s));
        }
    }
    for i in 0..sc.churn.len() {
        let dur = sc.churn[i].resume_at - sc.churn[i].pause_at;
        if dur >= 0.02 {
            let mut s = sc.clone();
            s.churn[i].resume_at = s.churn[i].pause_at + dur / 2.0;
            out.push(with_scenario(case, s));
        }
    }
    for i in 0..sc.bandwidth.len() {
        let rate = sc.bandwidth[i].bytes_per_sec;
        // a cap weakens as the rate grows; 1 MB/s ≈ uncapped for these
        // payloads
        if rate <= 1e6 {
            let mut s = sc.clone();
            s.bandwidth[i].bytes_per_sec = rate * 2.0;
            out.push(with_scenario(case, s));
        }
    }
    out
}

fn with_scenario(case: &FuzzCase, scenario: Scenario) -> FuzzCase {
    let mut c = case.clone();
    c.scenario = scenario;
    c
}

/// Reduce the node count, dropping every clause that names a node the
/// smaller run no longer has (a wildcard bandwidth endpoint survives).
fn with_n(case: &FuzzCase, n: usize) -> FuzzCase {
    let mut c = case.clone();
    c.n = n;
    c.scenario.stragglers.retain(|s| s.node < n);
    c.scenario.churn.retain(|e| e.node < n);
    c.scenario.bandwidth.retain(|b| {
        b.from.map_or(true, |f| f < n) && b.to.map_or(true, |t| t < n)
    });
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ArchSpec;
    use crate::scenario::{BandwidthCap, ChurnEvent, Phase,
                          StragglerSchedule, StragglerSpec};

    fn full_case() -> FuzzCase {
        let mut scenario = Scenario::named("fuzz", "test");
        scenario.stragglers.push(StragglerSpec {
            node: 5,
            factor: 4.0,
            schedule: StragglerSchedule::Permanent,
        });
        scenario.loss_ramp.push(Phase { from_time: 0.0, value: 0.4 });
        scenario.latency_ramp.push(Phase { from_time: 0.0, value: 3.0 });
        scenario.churn.push(ChurnEvent {
            node: 2,
            pause_at: 0.1,
            resume_at: 0.5,
        });
        scenario.bandwidth.push(BandwidthCap {
            from: Some(7),
            to: None,
            bytes_per_sec: 2e4,
        });
        FuzzCase {
            n: 8,
            arch: ArchSpec::parse("bfs@0+chain@0").unwrap(),
            seed: 1,
            gamma: 0.02,
            iters: 200,
            scenario,
        }
    }

    #[test]
    fn candidates_cover_every_reduction_family() {
        let c = full_case();
        let cands = candidates(&c);
        // 5 clause drops + 2 n-shrinks + 1 iters + 5 magnitude halvings
        assert_eq!(cands.len(), 13);
        // every candidate is strictly "smaller or dimmer", never equal
        for cand in &cands {
            assert_ne!(*cand, c);
            cand.scenario
                .validate(Some(cand.n))
                .expect("candidates stay valid");
        }
    }

    #[test]
    fn n_shrink_drops_out_of_range_clauses() {
        let c = with_n(&full_case(), 4);
        assert_eq!(c.n, 4);
        assert!(c.scenario.stragglers.is_empty()); // named node 5
        assert!(c.scenario.bandwidth.is_empty()); // from node 7
        assert_eq!(c.scenario.churn.len(), 1); // node 2 survives
        c.scenario.validate(Some(4)).unwrap();
    }

    #[test]
    fn minimal_case_is_a_fixpoint() {
        let c = FuzzCase {
            n: 2,
            arch: ArchSpec::parse("balanced@0+star@0").unwrap(),
            seed: 7,
            gamma: 16.0,
            iters: ITERS_FLOOR,
            scenario: Scenario::named("fuzz", "generated fault scenario"),
        };
        assert!(candidates(&c).is_empty());
        // shrink() on a fixpoint returns it unchanged without running
        // the simulator at all
        assert_eq!(shrink(&c, "gap_bounded"), c);
    }

    #[test]
    fn magnitude_halving_terminates() {
        let mut c = full_case();
        // keep only magnitude moves in play
        c.scenario.bandwidth.clear();
        for _ in 0..200 {
            let magnitude_only: Vec<FuzzCase> = candidates(&c)
                .into_iter()
                .filter(|k| {
                    k.n == c.n
                        && k.iters == c.iters
                        && k.scenario.stragglers.len()
                            == c.scenario.stragglers.len()
                        && k.scenario.loss_ramp.len()
                            == c.scenario.loss_ramp.len()
                        && k.scenario.latency_ramp.len()
                            == c.scenario.latency_ramp.len()
                        && k.scenario.churn.len() == c.scenario.churn.len()
                })
                .collect();
            match magnitude_only.into_iter().next() {
                Some(next) => c = next,
                None => return, // chain ended — finite as promised
            }
        }
        panic!("magnitude halving did not terminate in 200 steps");
    }
}
