//! Minimal JSON substrate (serde is unavailable offline — DESIGN.md §6).
//!
//! Two consumers: parsing `artifacts/manifest.json` (written by
//! `python/compile/aot.py`) and emitting run reports/metrics. Covers the
//! full JSON grammar except `\u` surrogate pairs beyond the BMP (the
//! manifest is ASCII).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Numbers are kept as f64 (the manifest only carries shapes,
/// dims and hyper-parameters — all exactly representable).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["a"]["b"]`-style access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Path access: `j.at(&["artifacts", "logreg_grad", "hlo"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for ParseError {}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest
                        .chars()
                        .next()
                        .ok_or_else(|| self.err("unterminated string"))?;
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.at(&["a"]).unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"x":[1,2.5,true,null,"s"],"y":{"z":-3}}"#;
        let j = parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(parse(&out).unwrap(), j);
    }

    #[test]
    fn escapes_roundtrip() {
        let j = Json::Str("quote\" slash\\ nl\n tab\t ctrl\u{1}".into());
        let out = j.to_string();
        assert_eq!(parse(&out).unwrap(), j);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""é""#).unwrap(), Json::Str("é".into()));
    }

    #[test]
    fn errors_carry_position() {
        let e = parse("[1, ]").unwrap_err();
        assert!(e.pos >= 3, "{e}");
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("[1,2").is_err());
        assert!(parse("12 34").is_err());
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
 "artifacts": {
  "logreg_grad": {
   "hlo": "logreg_grad.hlo.txt",
   "inputs": [{"dtype": "float32", "shape": [785]}],
   "meta": {"batch": 32, "l2": 0.0001, "model": "logreg"}
  }
 },
 "models": {"logreg": {"init": "logreg_init.f32", "p": 785}}
}"#;
        let j = parse(src).unwrap();
        assert_eq!(
            j.at(&["artifacts", "logreg_grad", "hlo"]).unwrap().as_str(),
            Some("logreg_grad.hlo.txt")
        );
        assert_eq!(
            j.at(&["models", "logreg", "p"]).unwrap().as_usize(),
            Some(785)
        );
    }
}
