//! Declarative fault-injection scenarios for both engines.
//!
//! The paper's robustness claims (§VI) are statements about *fault
//! regimes* — stragglers, latency, packet loss — that the seed encoded as
//! scattered [`SimConfig`](crate::config::SimConfig) scalars. A
//! [`Scenario`] composes those regimes from first-class primitives and is
//! the single object the engines consult (through the shared
//! [`faults`](crate::faults) layer) on every event:
//!
//! * **straggler schedules** — per-node compute slowdowns that are
//!   permanent, switch on at a time `T`, or cycle on/off
//!   ([`StragglerSchedule`]);
//! * **loss ramps** — piecewise-constant Bernoulli drop probability over
//!   virtual time (overrides `SimConfig::loss_prob` once the first phase
//!   starts; async algorithms only, exactly like the base knob);
//! * **latency ramps** — piecewise-constant multipliers on the mean link
//!   latency (the cap scales along, so Assumption 3 stays bounded);
//! * **churn** — pause/resume windows during which a node starts no new
//!   iterations (in-flight work and message receipt continue: this models
//!   a stalled worker, not a crashed one);
//! * **bandwidth caps** — per-link (or wildcard) byte rates; the
//!   simulator serializes capped payloads FIFO per directed link, so the
//!   rate is a real throughput bound, not just a fixed delay.
//!
//! Every query is a pure function of a time `t` and carries no time base
//! of its own: the simulator passes virtual seconds, the threaded runner
//! passes wall seconds since the run started (the [`Clock`]
//! mapping — see [`faults`](crate::faults)). Under the simulator a run
//! with a scenario is exactly as deterministic as a clean run: same seed
//! + same scenario ⇒ identical [`SimStats`](crate::sim::SimStats).
//!
//! Scenarios round-trip through the in-repo [`jsonio`](crate::jsonio)
//! (`Scenario::to_json` / `Scenario::from_json`), load from `.json` files,
//! and ship as named presets ([`Scenario::by_name`]) that make the
//! paper's §VI regimes one-line: `paper_fig5`, `paper_fig6_straggler`,
//! `lossy_30pct`, `late_straggler`, `degrading_network`, `churn` — each
//! runnable under `--engine sim` or `--engine threaded`.
//!
//! [`Clock`]: crate::faults::Clock

use crate::jsonio::{self, Json};
use crate::prng::Rng;
use std::path::Path;

/// When a straggler's slowdown is in effect.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StragglerSchedule {
    /// Slow for the whole run (the paper's §VI-B loaded GPU).
    Permanent,
    /// Full speed until `at` seconds of virtual time, slow afterwards.
    FromTime { at: f64 },
    /// Cycles: slow for the first `duty`-fraction of every `period`
    /// seconds, full speed for the rest.
    Intermittent { period: f64, duty: f64 },
}

/// One straggling node: its compute cost is multiplied by `factor`
/// whenever the schedule is active.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StragglerSpec {
    pub node: usize,
    /// Slowdown factor ≥ 1.
    pub factor: f64,
    pub schedule: StragglerSchedule,
}

impl StragglerSpec {
    /// Compute-time multiplier contributed by this spec at time `t`.
    pub fn factor_at(&self, t: f64) -> f64 {
        let active = match self.schedule {
            StragglerSchedule::Permanent => true,
            StragglerSchedule::FromTime { at } => t >= at,
            StragglerSchedule::Intermittent { period, duty } => {
                (t / period).fract() < duty
            }
        };
        if active {
            self.factor
        } else {
            1.0
        }
    }
}

/// One step of a piecewise-constant ramp: `value` holds from `from_time`
/// until the next phase (phases are kept sorted by `from_time`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Phase {
    pub from_time: f64,
    pub value: f64,
}

/// A pause window for one node: no new local iterations start while
/// `pause_at ≤ t < resume_at`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChurnEvent {
    pub node: usize,
    pub pause_at: f64,
    pub resume_at: f64,
}

/// A byte-rate cap on directed links. `None` endpoints are wildcards, so
/// `{ from: None, to: None }` caps every link.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BandwidthCap {
    pub from: Option<usize>,
    pub to: Option<usize>,
    pub bytes_per_sec: f64,
}

/// A named, composable fault-injection scenario (see module docs).
#[derive(Clone, Debug, PartialEq, Default)]
pub struct Scenario {
    pub name: String,
    pub description: String,
    pub stragglers: Vec<StragglerSpec>,
    pub loss_ramp: Vec<Phase>,
    pub latency_ramp: Vec<Phase>,
    pub churn: Vec<ChurnEvent>,
    pub bandwidth: Vec<BandwidthCap>,
}

impl Scenario {
    /// Empty scenario with a name (compose by pushing primitives).
    pub fn named(name: &str, description: &str) -> Scenario {
        Scenario {
            name: name.to_string(),
            description: description.to_string(),
            ..Scenario::default()
        }
    }

    /// One permanently slow node — the classic §VI-B regime.
    pub fn single_straggler(node: usize, factor: f64) -> Scenario {
        let mut s = Scenario::named(
            "single_straggler",
            "one node permanently slowed by a constant factor",
        );
        s.stragglers.push(StragglerSpec {
            node,
            factor,
            schedule: StragglerSchedule::Permanent,
        });
        s
    }

    /// Constant Bernoulli packet loss from t = 0 (async algorithms only).
    pub fn constant_loss(prob: f64) -> Scenario {
        let mut s = Scenario::named(
            "constant_loss",
            "constant Bernoulli packet loss on every async link",
        );
        s.loss_ramp.push(Phase { from_time: 0.0, value: prob });
        s
    }

    // ---- event-time queries (pure in `t`) ------------------------------

    /// Product of all active straggler factors for `node` at time `t`.
    pub fn compute_factor(&self, node: usize, t: f64) -> f64 {
        self.stragglers
            .iter()
            .filter(|s| s.node == node)
            .map(|s| s.factor_at(t))
            .product()
    }

    /// Effective drop probability at time `t`; `base` (the
    /// `SimConfig::loss_prob` scalar) applies before the first phase.
    pub fn loss_prob(&self, base: f64, t: f64) -> f64 {
        ramp_value(&self.loss_ramp, t).unwrap_or(base)
    }

    /// Multiplier on the mean link latency at time `t` (1.0 before the
    /// first phase).
    pub fn latency_multiplier(&self, t: f64) -> f64 {
        ramp_value(&self.latency_ramp, t).unwrap_or(1.0)
    }

    /// Is `node` inside any pause window at time `t`?
    pub fn is_paused(&self, node: usize, t: f64) -> bool {
        self.churn
            .iter()
            .any(|c| c.node == node && c.pause_at <= t && t < c.resume_at)
    }

    /// Latest `resume_at` over the windows pausing `node` at time `t`
    /// (the simulator re-examines the node then; chained windows are
    /// handled by re-checking on wake).
    pub fn next_resume(&self, node: usize, t: f64) -> Option<f64> {
        self.churn
            .iter()
            .filter(|c| c.node == node && c.pause_at <= t && t < c.resume_at)
            .map(|c| c.resume_at)
            .fold(None, |acc, r| Some(acc.map_or(r, |a: f64| a.max(r))))
    }

    /// Serialization delay for `bytes` on the link `from → to`: the
    /// tightest matching cap's `bytes / rate`, or 0 when uncapped.
    pub fn bandwidth_delay(&self, from: usize, to: usize, bytes: f64) -> f64 {
        let rate = self
            .bandwidth
            .iter()
            .filter(|c| {
                c.from.map_or(true, |f| f == from)
                    && c.to.map_or(true, |t| t == to)
            })
            .map(|c| c.bytes_per_sec)
            .fold(f64::INFINITY, f64::min);
        if rate.is_finite() && rate > 0.0 {
            bytes / rate
        } else {
            0.0
        }
    }

    // ---- random sampling (fuzzer) --------------------------------------

    /// Seeded random scenario for the fault-space fuzzer
    /// ([`fuzz`](crate::fuzz)). Every draw is range-bounded so the result
    /// always passes [`Scenario::validate_detailed`]`(Some(n))`:
    /// straggler factors in [1, 8], loss values in [0, 0.5], ramp times
    /// sorted, churn windows non-empty, byte rates positive, node indices
    /// < `n` (node 0 is eligible everywhere — root churn / a straggling
    /// root are exactly the regimes Assumption 2 makes interesting).
    /// `horizon` scales every event time; pass the run's expected virtual
    /// length. Deterministic per RNG state; `n` must be ≥ 1.
    pub fn sample(rng: &mut Rng, n: usize, horizon: f64) -> Scenario {
        let horizon = horizon.max(1e-3);
        let mut s = Scenario::named("fuzz", "generated fault scenario");
        for _ in 0..rng.below(3) {
            let schedule = match rng.below(3) {
                0 => StragglerSchedule::Permanent,
                1 => StragglerSchedule::FromTime { at: rng.f64() * horizon },
                _ => StragglerSchedule::Intermittent {
                    period: (0.05 + rng.f64()) * horizon,
                    duty: rng.f64(),
                },
            };
            s.stragglers.push(StragglerSpec {
                node: rng.below(n),
                factor: 1.0 + 7.0 * rng.f64(),
                schedule,
            });
        }
        if rng.chance(0.5) {
            let mut t = 0.0;
            for _ in 0..1 + rng.below(3) {
                s.loss_ramp.push(Phase { from_time: t, value: 0.5 * rng.f64() });
                t += rng.f64() * horizon;
            }
        }
        if rng.chance(0.4) {
            let mut t = 0.0;
            for _ in 0..1 + rng.below(3) {
                s.latency_ramp
                    .push(Phase { from_time: t, value: 0.5 + 3.5 * rng.f64() });
                t += rng.f64() * horizon;
            }
        }
        for _ in 0..rng.below(3) {
            let node = rng.below(n);
            let pause_at = rng.f64() * horizon;
            let resume_at = pause_at + (0.02 + 0.3 * rng.f64()) * horizon;
            s.churn.push(ChurnEvent { node, pause_at, resume_at });
        }
        if rng.chance(0.3) {
            let from = if rng.chance(0.5) { Some(rng.below(n)) } else { None };
            let to = if rng.chance(0.5) { Some(rng.below(n)) } else { None };
            s.bandwidth.push(BandwidthCap {
                from,
                to,
                bytes_per_sec: 1e3 * (1.0 + 99.0 * rng.f64()),
            });
        }
        s
    }

    /// Does this scenario carry any fault primitive at all?
    pub fn is_empty(&self) -> bool {
        self.stragglers.is_empty()
            && self.loss_ramp.is_empty()
            && self.latency_ramp.is_empty()
            && self.churn.is_empty()
            && self.bandwidth.is_empty()
    }

    // ---- validation ----------------------------------------------------

    /// Range checks; pass the node count to also bound-check node indices
    /// (the simulator does), or `None` for count-independent validation.
    pub fn validate(&self, n_nodes: Option<usize>) -> Result<(), String> {
        self.validate_detailed(n_nodes).map_err(|(field, detail)| {
            format!("scenario {:?}: {field}: {detail}", self.name)
        })
    }

    /// Structured twin of [`Scenario::validate`]: `Err((field, detail))`
    /// where `field` is a JSON-path-like pointer into the scenario
    /// (`"stragglers[0].factor"`, `"churn[2]"`, ...). The typed
    /// [`ExpError::InvalidScenario`](crate::exp::ExpError) surfaces both
    /// pieces so callers never parse an error string for the failing
    /// field.
    pub fn validate_detailed(
        &self, n_nodes: Option<usize>,
    ) -> Result<(), (String, String)> {
        let check_node =
            |node: usize, field: String| -> Result<(), (String, String)> {
                if let Some(n) = n_nodes {
                    if node >= n {
                        return Err((
                            field,
                            format!("node {node} out of range (n = {n})"),
                        ));
                    }
                }
                Ok(())
            };
        for (i, s) in self.stragglers.iter().enumerate() {
            check_node(s.node, format!("stragglers[{i}].node"))?;
            if !(s.factor >= 1.0) {
                return Err((
                    format!("stragglers[{i}].factor"),
                    format!("must be ≥ 1, got {}", s.factor),
                ));
            }
            match s.schedule {
                StragglerSchedule::Permanent => {}
                StragglerSchedule::FromTime { at } => {
                    if !(at >= 0.0) {
                        return Err((
                            format!("stragglers[{i}].schedule.at"),
                            format!("onset must be ≥ 0, got {at}"),
                        ));
                    }
                }
                StragglerSchedule::Intermittent { period, duty } => {
                    if !(period > 0.0) || !(0.0..=1.0).contains(&duty) {
                        return Err((
                            format!("stragglers[{i}].schedule"),
                            format!(
                                "intermittent wants period > 0 and duty in \
                                 [0,1], got period {period} duty {duty}"
                            ),
                        ));
                    }
                }
            }
        }
        for (ramp, what, lo, hi) in [
            (&self.loss_ramp, "loss_ramp", 0.0, 1.0),
            (&self.latency_ramp, "latency_ramp", 0.0, f64::INFINITY),
        ] {
            let mut prev = f64::NEG_INFINITY;
            for (i, p) in ramp.iter().enumerate() {
                if !(p.from_time >= 0.0) || p.from_time < prev {
                    return Err((
                        format!("{what}[{i}].from_time"),
                        "phase times must be ≥ 0 and non-decreasing".into(),
                    ));
                }
                prev = p.from_time;
                if !(p.value >= lo) || p.value >= hi && what == "loss_ramp" {
                    return Err((
                        format!("{what}[{i}].value"),
                        format!("value {} out of range", p.value),
                    ));
                }
            }
        }
        for (i, c) in self.churn.iter().enumerate() {
            check_node(c.node, format!("churn[{i}].node"))?;
            if !(c.pause_at >= 0.0 && c.resume_at > c.pause_at) {
                return Err((
                    format!("churn[{i}]"),
                    format!(
                        "window [{}, {}) is empty or negative",
                        c.pause_at, c.resume_at
                    ),
                ));
            }
        }
        for (i, b) in self.bandwidth.iter().enumerate() {
            if let Some(f) = b.from {
                check_node(f, format!("bandwidth[{i}].from"))?;
            }
            if let Some(t) = b.to {
                check_node(t, format!("bandwidth[{i}].to"))?;
            }
            if !(b.bytes_per_sec > 0.0) {
                return Err((
                    format!("bandwidth[{i}].bytes_per_sec"),
                    format!("rate must be > 0, got {}", b.bytes_per_sec),
                ));
            }
        }
        Ok(())
    }

    // ---- JSON ----------------------------------------------------------

    /// Serialize to the scenario JSON shape (round-trips via
    /// [`Scenario::from_json`]).
    pub fn to_json(&self) -> Json {
        let stragglers = self
            .stragglers
            .iter()
            .map(|s| {
                let schedule = match s.schedule {
                    StragglerSchedule::Permanent => {
                        Json::obj(vec![("kind", "permanent".into())])
                    }
                    StragglerSchedule::FromTime { at } => Json::obj(vec![
                        ("kind", "from_time".into()),
                        ("at", at.into()),
                    ]),
                    StragglerSchedule::Intermittent { period, duty } => {
                        Json::obj(vec![
                            ("kind", "intermittent".into()),
                            ("period", period.into()),
                            ("duty", duty.into()),
                        ])
                    }
                };
                Json::obj(vec![
                    ("node", s.node.into()),
                    ("factor", s.factor.into()),
                    ("schedule", schedule),
                ])
            })
            .collect();
        let ramp_json = |ramp: &[Phase]| {
            Json::Arr(
                ramp.iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("from_time", p.from_time.into()),
                            ("value", p.value.into()),
                        ])
                    })
                    .collect(),
            )
        };
        let churn = self
            .churn
            .iter()
            .map(|c| {
                Json::obj(vec![
                    ("node", c.node.into()),
                    ("pause_at", c.pause_at.into()),
                    ("resume_at", c.resume_at.into()),
                ])
            })
            .collect();
        let bandwidth = self
            .bandwidth
            .iter()
            .map(|b| {
                Json::obj(vec![
                    ("from", b.from.map_or(Json::Null, Json::from)),
                    ("to", b.to.map_or(Json::Null, Json::from)),
                    ("bytes_per_sec", b.bytes_per_sec.into()),
                ])
            })
            .collect();
        Json::obj(vec![
            ("name", self.name.as_str().into()),
            ("description", self.description.as_str().into()),
            ("stragglers", Json::Arr(stragglers)),
            ("loss_ramp", ramp_json(&self.loss_ramp)),
            ("latency_ramp", ramp_json(&self.latency_ramp)),
            ("churn", Json::Arr(churn)),
            ("bandwidth", Json::Arr(bandwidth)),
        ])
    }

    /// Parse the scenario JSON shape; every list is optional, unknown
    /// keys are ignored (forward compatibility).
    pub fn from_json(j: &Json) -> Result<Scenario, String> {
        if j.as_obj().is_none() {
            return Err("scenario: expected a JSON object".to_string());
        }
        fn str_field(j: &Json, key: &str) -> String {
            j.get(key)
                .and_then(Json::as_str)
                .unwrap_or_default()
                .to_string()
        }
        fn num(j: &Json, what: &str) -> Result<f64, String> {
            j.as_f64().ok_or_else(|| format!("scenario: {what} must be a number"))
        }
        fn node_of(j: &Json, what: &str) -> Result<usize, String> {
            j.as_usize()
                .ok_or_else(|| format!("scenario: {what} must be a node index"))
        }
        fn list<'a>(j: &'a Json, key: &str) -> &'a [Json] {
            j.get(key).and_then(Json::as_arr).unwrap_or(&[])
        }

        let mut out =
            Scenario::named(&str_field(j, "name"), &str_field(j, "description"));
        for s in list(j, "stragglers") {
            let node = node_of(s.get("node").unwrap_or(&Json::Null), "straggler.node")?;
            let factor = num(s.get("factor").unwrap_or(&Json::Null), "straggler.factor")?;
            let schedule = match s.get("schedule") {
                None => StragglerSchedule::Permanent,
                Some(sch) => {
                    match sch.get("kind").and_then(Json::as_str).unwrap_or("permanent") {
                        "permanent" => StragglerSchedule::Permanent,
                        "from_time" => StragglerSchedule::FromTime {
                            at: num(sch.get("at").unwrap_or(&Json::Null), "schedule.at")?,
                        },
                        "intermittent" => StragglerSchedule::Intermittent {
                            period: num(sch.get("period").unwrap_or(&Json::Null),
                                        "schedule.period")?,
                            duty: num(sch.get("duty").unwrap_or(&Json::Null),
                                      "schedule.duty")?,
                        },
                        other => {
                            return Err(format!(
                                "scenario: unknown straggler schedule kind {other:?}"
                            ))
                        }
                    }
                }
            };
            out.stragglers.push(StragglerSpec { node, factor, schedule });
        }
        fn parse_ramp(j: &Json, key: &str) -> Result<Vec<Phase>, String> {
            list(j, key)
                .iter()
                .map(|p| {
                    Ok(Phase {
                        from_time: num(p.get("from_time").unwrap_or(&Json::Null),
                                       "ramp.from_time")?,
                        value: num(p.get("value").unwrap_or(&Json::Null),
                                   "ramp.value")?,
                    })
                })
                .collect()
        }
        out.loss_ramp = parse_ramp(j, "loss_ramp")?;
        out.latency_ramp = parse_ramp(j, "latency_ramp")?;
        for c in list(j, "churn") {
            out.churn.push(ChurnEvent {
                node: node_of(c.get("node").unwrap_or(&Json::Null), "churn.node")?,
                pause_at: num(c.get("pause_at").unwrap_or(&Json::Null),
                              "churn.pause_at")?,
                resume_at: num(c.get("resume_at").unwrap_or(&Json::Null),
                               "churn.resume_at")?,
            });
        }
        for b in list(j, "bandwidth") {
            let endpoint = |key: &str| -> Result<Option<usize>, String> {
                match b.get(key) {
                    None | Some(Json::Null) => Ok(None),
                    Some(v) => node_of(v, key).map(Some),
                }
            };
            out.bandwidth.push(BandwidthCap {
                from: endpoint("from")?,
                to: endpoint("to")?,
                bytes_per_sec: num(b.get("bytes_per_sec").unwrap_or(&Json::Null),
                                   "bandwidth.bytes_per_sec")?,
            });
        }
        out.validate(None)?;
        Ok(out)
    }

    /// Load a scenario from a `.json` file.
    pub fn load(path: &Path) -> Result<Scenario, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let j = jsonio::parse(&text).map_err(|e| e.to_string())?;
        Scenario::from_json(&j)
    }

    /// Resolve a CLI spec: a preset name, or a path to a `.json` file.
    pub fn resolve(spec: &str) -> Result<Scenario, String> {
        if let Some(s) = Scenario::by_name(spec) {
            return Ok(s);
        }
        let path = Path::new(spec);
        if spec.ends_with(".json") || path.exists() {
            return Scenario::load(path);
        }
        Err(format!(
            "unknown scenario {spec:?}; presets: {}  (or pass a .json file)",
            Scenario::preset_names().join(", ")
        ))
    }

    // ---- presets -------------------------------------------------------

    /// Names of the built-in presets (see [`Scenario::by_name`]).
    pub fn preset_names() -> Vec<&'static str> {
        vec![
            "paper_fig5",
            "paper_fig6_straggler",
            "lossy_30pct",
            "late_straggler",
            "degrading_network",
            "churn",
        ]
    }

    /// Built-in presets covering the paper's §VI regimes and the
    /// robustness regimes surveyed in PAPERS.md (Assran et al. 2020).
    pub fn by_name(name: &str) -> Option<Scenario> {
        let mut s = match name {
            "paper_fig5" => {
                let mut s = Scenario::constant_loss(0.02);
                s.description = "§VI-B no-straggler comparison: 2% packet \
                                 loss on the async algorithms"
                    .to_string();
                s
            }
            "paper_fig6_straggler" => {
                let mut s = Scenario::single_straggler(3, 5.0);
                s.loss_ramp.push(Phase { from_time: 0.0, value: 0.02 });
                s.description = "§VI-B straggler comparison: node 3 slowed \
                                 5x, 2% packet loss on async algorithms"
                    .to_string();
                s
            }
            "lossy_30pct" => {
                let mut s = Scenario::constant_loss(0.30);
                s.description = "heavy loss regime: 30% of async packets \
                                 dropped, sender-side, send-until-ack"
                    .to_string();
                s
            }
            "late_straggler" => {
                let mut s = Scenario::named(
                    "late_straggler",
                    "node 1 healthy until t = 60 s, then slowed 5x \
                     (onset-at-time regime)",
                );
                s.stragglers.push(StragglerSpec {
                    node: 1,
                    factor: 5.0,
                    schedule: StragglerSchedule::FromTime { at: 60.0 },
                });
                s
            }
            "degrading_network" => {
                let mut s = Scenario::named(
                    "degrading_network",
                    "link quality decays in two steps: latency x1 -> x2 -> \
                     x4 and loss 2% -> 10% -> 25% at t = 40 s and t = 80 s",
                );
                s.latency_ramp = vec![
                    Phase { from_time: 0.0, value: 1.0 },
                    Phase { from_time: 40.0, value: 2.0 },
                    Phase { from_time: 80.0, value: 4.0 },
                ];
                s.loss_ramp = vec![
                    Phase { from_time: 0.0, value: 0.02 },
                    Phase { from_time: 40.0, value: 0.10 },
                    Phase { from_time: 80.0, value: 0.25 },
                ];
                s
            }
            "churn" => {
                let mut s = Scenario::named(
                    "churn",
                    "pause/resume churn: two nodes take turns going dark \
                     for 15 s windows while a third throbs 3x slow",
                );
                s.churn = vec![
                    ChurnEvent { node: 1, pause_at: 20.0, resume_at: 35.0 },
                    ChurnEvent { node: 2, pause_at: 50.0, resume_at: 65.0 },
                    ChurnEvent { node: 1, pause_at: 80.0, resume_at: 95.0 },
                ];
                s.stragglers.push(StragglerSpec {
                    node: 0,
                    factor: 3.0,
                    schedule: StragglerSchedule::Intermittent {
                        period: 30.0,
                        duty: 0.5,
                    },
                });
                s
            }
            _ => return None,
        };
        s.name = name.to_string();
        Some(s)
    }
}

/// Last phase with `from_time ≤ t`, or `None` before the first phase.
fn ramp_value(ramp: &[Phase], t: f64) -> Option<f64> {
    let mut cur = None;
    for p in ramp {
        if p.from_time <= t {
            cur = Some(p.value);
        } else {
            break;
        }
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn straggler_schedules() {
        let perm = StragglerSpec {
            node: 0,
            factor: 4.0,
            schedule: StragglerSchedule::Permanent,
        };
        assert_eq!(perm.factor_at(0.0), 4.0);
        assert_eq!(perm.factor_at(1e6), 4.0);

        let late = StragglerSpec {
            node: 0,
            factor: 4.0,
            schedule: StragglerSchedule::FromTime { at: 10.0 },
        };
        assert_eq!(late.factor_at(9.99), 1.0);
        assert_eq!(late.factor_at(10.0), 4.0);

        let inter = StragglerSpec {
            node: 0,
            factor: 4.0,
            schedule: StragglerSchedule::Intermittent { period: 10.0, duty: 0.3 },
        };
        assert_eq!(inter.factor_at(1.0), 4.0); // 0.1 < 0.3
        assert_eq!(inter.factor_at(5.0), 1.0); // 0.5 ≥ 0.3
        assert_eq!(inter.factor_at(12.0), 4.0); // wraps
    }

    #[test]
    fn ramps_are_piecewise_constant() {
        let s = Scenario::by_name("degrading_network").unwrap();
        assert_eq!(s.loss_prob(0.0, 0.0), 0.02);
        assert_eq!(s.loss_prob(0.0, 39.9), 0.02);
        assert_eq!(s.loss_prob(0.0, 40.0), 0.10);
        assert_eq!(s.loss_prob(0.0, 200.0), 0.25);
        assert_eq!(s.latency_multiplier(50.0), 2.0);
        // before any phase, base applies
        let empty = Scenario::default();
        assert_eq!(empty.loss_prob(0.07, 5.0), 0.07);
        assert_eq!(empty.latency_multiplier(5.0), 1.0);
    }

    #[test]
    fn churn_windows_and_resume() {
        let s = Scenario::by_name("churn").unwrap();
        assert!(!s.is_paused(1, 19.9));
        assert!(s.is_paused(1, 20.0));
        assert!(s.is_paused(1, 34.9));
        assert!(!s.is_paused(1, 35.0));
        assert_eq!(s.next_resume(1, 25.0), Some(35.0));
        assert_eq!(s.next_resume(1, 40.0), None);
        assert!(!s.is_paused(0, 25.0)); // other nodes untouched
    }

    #[test]
    fn bandwidth_caps_pick_tightest_match() {
        let mut s = Scenario::named("bw", "");
        s.bandwidth.push(BandwidthCap {
            from: None,
            to: None,
            bytes_per_sec: 1e6,
        });
        s.bandwidth.push(BandwidthCap {
            from: Some(0),
            to: Some(1),
            bytes_per_sec: 1e3,
        });
        // specific link: tightest (1 KB/s) wins
        assert!((s.bandwidth_delay(0, 1, 2e3) - 2.0).abs() < 1e-12);
        // other links: wildcard rate
        assert!((s.bandwidth_delay(1, 0, 2e6) - 2.0).abs() < 1e-12);
        // uncapped scenario: zero delay
        assert_eq!(Scenario::default().bandwidth_delay(0, 1, 1e9), 0.0);
    }

    #[test]
    fn compute_factor_multiplies_overlapping_specs() {
        let mut s = Scenario::single_straggler(2, 2.0);
        s.stragglers.push(StragglerSpec {
            node: 2,
            factor: 3.0,
            schedule: StragglerSchedule::FromTime { at: 10.0 },
        });
        assert_eq!(s.compute_factor(2, 0.0), 2.0);
        assert_eq!(s.compute_factor(2, 20.0), 6.0);
        assert_eq!(s.compute_factor(0, 20.0), 1.0);
    }

    #[test]
    fn presets_exist_and_validate() {
        for name in Scenario::preset_names() {
            let s = Scenario::by_name(name)
                .unwrap_or_else(|| panic!("missing preset {name}"));
            assert_eq!(s.name, name);
            assert!(!s.description.is_empty(), "{name}");
            assert!(!s.is_empty(), "{name}");
            s.validate(Some(8)).unwrap_or_else(|e| panic!("{name}: {e}"));
        }
        assert!(Scenario::by_name("nope").is_none());
    }

    #[test]
    fn validation_rejects_bad_specs() {
        let mut s = Scenario::single_straggler(3, 0.5); // factor < 1
        assert!(s.validate(None).is_err());
        s = Scenario::single_straggler(9, 2.0);
        assert!(s.validate(Some(4)).is_err()); // node out of range
        assert!(s.validate(None).is_ok()); // unknown n: allowed

        let mut bad_ramp = Scenario::named("r", "");
        bad_ramp.loss_ramp = vec![
            Phase { from_time: 10.0, value: 0.1 },
            Phase { from_time: 5.0, value: 0.2 }, // decreasing time
        ];
        assert!(bad_ramp.validate(None).is_err());

        let mut bad_loss = Scenario::named("l", "");
        bad_loss.loss_ramp = vec![Phase { from_time: 0.0, value: 1.5 }];
        assert!(bad_loss.validate(None).is_err());

        let mut bad_churn = Scenario::named("c", "");
        bad_churn.churn = vec![ChurnEvent { node: 0, pause_at: 5.0, resume_at: 5.0 }];
        assert!(bad_churn.validate(None).is_err());

        let mut bad_bw = Scenario::named("b", "");
        bad_bw.bandwidth =
            vec![BandwidthCap { from: None, to: None, bytes_per_sec: 0.0 }];
        assert!(bad_bw.validate(None).is_err());
    }

    #[test]
    fn validate_detailed_names_the_failing_field() {
        // the structured twin drives exp::ExpError::InvalidScenario —
        // field pointers must be stable JSON-path-like strings
        let s = Scenario::single_straggler(3, 0.5);
        let (field, detail) = s.validate_detailed(None).unwrap_err();
        assert_eq!(field, "stragglers[0].factor");
        assert!(detail.contains("0.5"), "{detail}");

        let s = Scenario::single_straggler(9, 2.0);
        let (field, _) = s.validate_detailed(Some(4)).unwrap_err();
        assert_eq!(field, "stragglers[0].node");

        let mut s = Scenario::named("b", "");
        s.bandwidth =
            vec![BandwidthCap { from: None, to: Some(9), bytes_per_sec: 1.0 }];
        let (field, _) = s.validate_detailed(Some(4)).unwrap_err();
        assert_eq!(field, "bandwidth[0].to");

        // the stringly wrapper embeds both pieces
        let err = Scenario::single_straggler(3, 0.5).validate(None).unwrap_err();
        assert!(err.contains("stragglers[0].factor"), "{err}");
    }

    #[test]
    fn sampled_scenarios_always_validate() {
        // the generator's contract: no draw can leave the valid range
        // (the fuzzer feeds these straight into Experiment::run)
        for seed in 0..200u64 {
            let mut rng = Rng::new(seed);
            let n = 1 + rng.below(10);
            let horizon = rng.f64() * 10.0; // including ~0: clamped inside
            let s = Scenario::sample(&mut rng, n, horizon);
            s.validate_detailed(Some(n))
                .unwrap_or_else(|(f, d)| panic!("seed {seed}: {f}: {d}"));
        }
        // deterministic per RNG state
        let mk = || Scenario::sample(&mut Rng::new(7), 5, 4.0);
        assert_eq!(mk(), mk());
    }

    #[test]
    fn json_roundtrip_all_presets() {
        for name in Scenario::preset_names() {
            let s = Scenario::by_name(name).unwrap();
            let text = s.to_json().to_string();
            let back = Scenario::from_json(&jsonio::parse(&text).unwrap())
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(back, s, "{name} did not round-trip");
        }
    }

    #[test]
    fn json_parses_sparse_documents() {
        // every list optional; schedule defaults to permanent
        let j = jsonio::parse(
            r#"{"name": "mini", "stragglers": [{"node": 1, "factor": 2.5}]}"#,
        )
        .unwrap();
        let s = Scenario::from_json(&j).unwrap();
        assert_eq!(s.name, "mini");
        assert_eq!(s.stragglers.len(), 1);
        assert_eq!(s.stragglers[0].schedule, StragglerSchedule::Permanent);
        assert_eq!(s.compute_factor(1, 0.0), 2.5);

        assert!(Scenario::from_json(&jsonio::parse("[1,2]").unwrap()).is_err());
    }

    #[test]
    fn resolve_finds_presets_and_rejects_unknown() {
        assert_eq!(Scenario::resolve("lossy_30pct").unwrap().name, "lossy_30pct");
        let e = Scenario::resolve("definitely_not_a_scenario").unwrap_err();
        assert!(e.contains("presets:"), "{e}");
    }

    #[test]
    fn load_from_file() {
        let dir = std::env::temp_dir().join("rfast_scenario_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("custom.json");
        let s = Scenario::by_name("churn").unwrap();
        std::fs::write(&path, s.to_json().to_string()).unwrap();
        let loaded = Scenario::load(&path).unwrap();
        assert_eq!(loaded, s);
        let via_resolve = Scenario::resolve(path.to_str().unwrap()).unwrap();
        assert_eq!(via_resolve, s);
        std::fs::remove_dir_all(&dir).ok();
    }
}
