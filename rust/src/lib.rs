//! # rfast — R-FAST: Robust Fully-Asynchronous Stochastic Gradient Tracking
//!
//! Production-oriented reproduction of Zhu et al., *"R-FAST: Robust
//! Fully-Asynchronous Stochastic Gradient Tracking over General Topology"*
//! (2023). The crate is the L3 layer of a three-layer rust + JAX + Pallas
//! stack (see `DESIGN.md`):
//!
//! * [`graph`] — directed topologies, row/column-stochastic weight matrices,
//!   spanning-tree root sets, Assumption 1-2 validation, and asymmetric
//!   (G_R, G_C) architectures built from two independent spanning trees
//!   ([`graph::arch`], the paper's Fig. 3 flexibility).
//! * [`algo`] — the R-FAST state machine plus six baselines (sync Push-Pull,
//!   D-PSGD, S-AB, Ring-AllReduce, AD-PSGD, OSGP), all event-driven, all
//!   emitting shared zero-copy payloads ([`algo::Payload`], DESIGN.md §8).
//! * [`sim`] — deterministic discrete-event simulator: per-node compute
//!   times, stragglers, link latency, packet loss with send-until-ack.
//! * [`scenario`] — declarative fault injection over both engines:
//!   straggler schedules, loss/latency ramps, churn, bandwidth caps,
//!   composed into named presets (`paper_fig6_straggler`, `lossy_30pct`,
//!   ...) or loaded from JSON.
//! * [`faults`] — the shared fault/link layer both engines drive: the
//!   one-unacked-packet channel discipline, scalar+scenario fault
//!   queries, and the [`Clock`](faults::Clock) abstraction mapping
//!   virtual seconds to wall seconds.
//! * [`fuzz`] — deterministic fault-space fuzzer: seeded case generation
//!   (random scenarios × random architecture pairs), invariant oracles
//!   (gap bound, ρ-mass conservation, stuck detection, counter sanity)
//!   and greedy auto-shrinking to JSON repros replayed as regression
//!   tests (`repro fuzz`; DESIGN.md §11).
//! * [`runner`] — real thread-per-node asynchronous engine (wall clock).
//! * [`runtime`] — PJRT execution of the AOT artifacts (`artifacts/*.hlo.txt`)
//!   produced by `python/compile/aot.py`; python is never on this path.
//! * [`oracle`] — gradient oracles: closed-form quadratics, pure-rust
//!   logistic regression, and PJRT-backed model gradients.
//! * [`exp`] — THE run API: the [`exp::Experiment`] builder drives both
//!   engines through one chain (unified [`exp::Stop`] rules, unified
//!   [`exp::RunStats`], native sweeps → [`exp::Comparison`]; DESIGN.md
//!   §9), plus the perf-baseline harness ([`exp::bench`]) behind
//!   `repro bench-baseline` (methodology and schema: EXPERIMENTS.md).
//! * [`data`] — synthetic datasets + heterogeneity-controlled partitioning.
//! * Substrates built in-repo because the offline registry only carries the
//!   `xla` crate closure: [`prng`], [`linalg`], [`jsonio`], [`config`],
//!   [`metrics`], [`testutil`].
//!
//! ## Quickstart
//!
//! One [`exp::Experiment`] chain drives either engine — the virtual-time
//! simulator for controlled comparisons, the thread-per-node wall-clock
//! runner for the asynchrony claims — with one [`exp::Stop`] vocabulary
//! and unified [`exp::RunStats`]:
//!
//! ```
//! use rfast::prelude::*;
//!
//! let topo = Topology::binary_tree(7);
//! let cfg = SimConfig { seed: 7, gamma: 0.05, compute_mean: 0.01,
//!                       eval_every: 1.0, ..SimConfig::default() };
//! let run = Experiment::new(
//!         Workload::Quadratic(QuadSpec::heterogeneous(16, 1.0, 4.0)),
//!         AlgoKind::RFast)
//!     .topology(&topo)
//!     .config(cfg)
//!     .engine(Engine::Sim) // Engine::threaded(pace) = wall clock
//!     .stop(Stop::Iterations(5_000))
//!     .run()
//!     .unwrap();
//! println!("final optimality gap: {:.3e}", run.report.final_gap.unwrap());
//! assert_eq!(run.stats.total_steps(), 5_000);
//! ```
//!
//! ## Fault-injection scenarios
//!
//! The paper's §VI regimes are named presets; any composition of
//! stragglers, loss/latency ramps, churn and bandwidth caps can also be
//! loaded from JSON (`--scenario file.json` on the CLI). A scenario slots
//! into the same chain — and misuse (bad scenario, missing topology, a
//! workload the engine can't drive) is a typed [`exp::ExpError`], not a
//! panic:
//!
//! ```
//! use rfast::prelude::*;
//!
//! let topo = Topology::ring(5);
//! let cfg = SimConfig { seed: 7, gamma: 0.04, compute_mean: 0.01,
//!                       eval_every: 1.0, ..SimConfig::default() };
//! let run = Experiment::new(
//!         Workload::Quadratic(QuadSpec::heterogeneous(8, 0.5, 2.0)),
//!         AlgoKind::RFast)
//!     .topology(&topo)
//!     .config(cfg)
//!     .scenario(&Scenario::by_name("lossy_30pct").unwrap())
//!     .stop(Stop::Iterations(2_000))
//!     .run()
//!     .unwrap();
//! assert!(run.stats.msgs_lost > 0); // the ramp was live
//! assert!(run.report.final_gap.is_some());
//! assert!(run.report.label.contains("lossy_30pct"));
//! ```
//!
//! Sweeps are native: [`exp::Experiment::sweep_algos`] /
//! [`sweep_topologies`](exp::Experiment::sweep_topologies) /
//! [`sweep_engines`](exp::Experiment::sweep_engines) return an
//! [`exp::Comparison`] whose `save_csvs` writes the per-series CSVs the
//! paper figures use plus a side-by-side scalar table.
//!
//! ## Zero-copy message fabric
//!
//! A broadcast allocates its payload once; every out-neighbor's message
//! shares it ([`algo::Payload`], an `Arc<[f32]>` newtype with a
//! copy-on-write escape hatch — DESIGN.md §8, perf numbers in
//! EXPERIMENTS.md):
//!
//! ```
//! use rfast::prelude::*;
//! use rfast::algo::MsgKind;
//! use rfast::oracle::GradOracle;
//!
//! let topo = Topology::binary_tree(3); // root 0 broadcasts v to {1, 2}
//! let quad = QuadraticOracle::heterogeneous(4, 3, 1.0, 1.0, 1);
//! let mut set = quad.into_set();
//! let mut nodes = AlgoKind::RFast.build(&topo, &[0.0; 4], 0.1, 1);
//! let mut out = Vec::new();
//! nodes[0].wake(set.nodes[0].as_mut(), &mut out);
//! let v: Vec<_> = out.iter().filter(|m| m.kind == MsgKind::V).collect();
//! assert_eq!(v.len(), 2);
//! // two out-neighbor messages, ONE payload allocation:
//! assert!(Payload::ptr_eq(&v[0].payload, &v[1].payload));
//! ```

pub mod algo;
pub mod cli;
pub mod config;
pub mod data;
pub mod exp;
pub mod faults;
pub mod fuzz;
pub mod graph;
pub mod jsonio;
pub mod linalg;
pub mod lint;
pub mod metrics;
pub mod oracle;
pub mod prng;
pub mod runner;
pub mod runtime;
pub mod scenario;
pub mod sim;
pub mod testutil;

/// Convenience re-exports for examples/benches.
pub mod prelude {
    pub use crate::algo::{AlgoKind, NodeState, Payload, Payload64, RFastParams};
    pub use crate::config::SimConfig;
    pub use crate::data::{Dataset, Partition};
    pub use crate::exp::{Comparison, Engine, ExpError, Experiment, QuadSpec,
                         Run, RunStats, Stop, Workload};
    pub use crate::graph::{ArchSpec, Topology, TopologyKind, TreeKind,
                           TreeSpec, WeightMatrices};
    pub use crate::linalg as la;
    pub use crate::metrics::{Report, Series};
    pub use crate::oracle::{GradOracle, LogRegOracle, QuadraticOracle};
    pub use crate::prng::Rng;
    pub use crate::scenario::Scenario;
    pub use crate::sim::Simulator;
    // kept for the one-release deprecation window of exp::Stop's
    // predecessor — downstream `prelude::*` users get a warning at THEIR
    // StopRule call sites, not a compile break here
    #[allow(deprecated)]
    pub use crate::sim::StopRule;
}
