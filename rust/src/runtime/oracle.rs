//! PJRT-backed gradient oracles — the production request path.
//!
//! A node's `grad(x)` marshals its minibatch + flat θ into literals,
//! executes the AOT `*_grad` executable (loss, grad = one fused XLA call —
//! a single host↔device round trip per step), and copies the gradient out.
//! Evaluation runs the `*_eval` executable over held-out chunks.
//!
//! Sharing: within one thread, all node oracles share one [`Engine`] via
//! `Rc` (compile once); across threads, [`PjrtFactory`] builds a fresh
//! engine per worker (the client is `Rc`-based — DESIGN.md §6).

use super::engine::{Engine, Input};
use super::manifest::Manifest;
use crate::data::{Batcher, Dataset, Partition, TokenStream};
use crate::oracle::{Eval, NodeOracle, OracleFactory, OracleSet};
use anyhow::{anyhow, Result};
use std::rc::Rc;
use std::sync::Arc;

/// Which model/workload an oracle set drives.
#[derive(Clone)]
pub enum PjrtTask {
    /// `logreg_*` artifacts; float {0,1} labels.
    LogReg { data: Arc<Dataset>, eval: Arc<Dataset>, partition: Partition },
    /// `mlp_*` artifacts; int32 class labels.
    Mlp { data: Arc<Dataset>, eval: Arc<Dataset>, partition: Partition },
    /// `transformer_<scale>_*` artifacts; per-node Markov token streams.
    Transformer { scale: String, vocab: usize, branching: usize },
}

impl PjrtTask {
    pub fn grad_artifact(&self) -> String {
        match self {
            PjrtTask::LogReg { .. } => "logreg_grad".into(),
            PjrtTask::Mlp { .. } => "mlp_grad".into(),
            PjrtTask::Transformer { scale, .. } => {
                format!("transformer_{scale}_grad")
            }
        }
    }

    pub fn eval_artifact(&self) -> String {
        match self {
            PjrtTask::LogReg { .. } => "logreg_eval".into(),
            PjrtTask::Mlp { .. } => "mlp_eval".into(),
            PjrtTask::Transformer { scale, .. } => {
                format!("transformer_{scale}_eval")
            }
        }
    }

    pub fn model_name(&self) -> String {
        match self {
            PjrtTask::LogReg { .. } => "logreg".into(),
            PjrtTask::Mlp { .. } => "mlp".into(),
            PjrtTask::Transformer { scale, .. } => format!("transformer_{scale}"),
        }
    }
}

/// Per-node data feed.
enum Feed {
    Supervised {
        data: Arc<Dataset>,
        batcher: Batcher,
        labels_i32: bool,
        xbuf: Vec<f32>,
        yf: Vec<f32>,
        yi: Vec<i32>,
    },
    Tokens {
        stream: TokenStream,
        batch: usize,
        seq_plus_one: usize,
    },
}

/// One node's PJRT gradient oracle.
pub struct PjrtOracle {
    engine: Rc<Engine>,
    grad_name: String,
    p: usize,
    feed: Feed,
}

impl NodeOracle for PjrtOracle {
    fn dim(&self) -> usize {
        self.p
    }

    fn grad(&mut self, x: &[f32], grad_out: &mut [f32]) -> f32 {
        assert_eq!(x.len(), self.p);
        let outputs = match &mut self.feed {
            Feed::Supervised { data, batcher, labels_i32, xbuf, yf, yi } => {
                let idx = batcher.next_batch();
                let d = data.dim;
                xbuf.clear();
                yf.clear();
                yi.clear();
                for &s in &idx {
                    xbuf.extend_from_slice(data.row(s));
                    if *labels_i32 {
                        yi.push(data.labels[s] as i32);
                    } else {
                        yf.push(data.labels[s] as f32);
                    }
                }
                debug_assert_eq!(xbuf.len(), idx.len() * d);
                let labels: Input<'_> = if *labels_i32 {
                    Input::I32(yi)
                } else {
                    Input::F32(yf)
                };
                self.engine
                    .run(&self.grad_name, &[Input::F32(x), Input::F32(xbuf), labels])
            }
            Feed::Tokens { stream, batch, seq_plus_one } => {
                let toks = stream.next_block(*batch, *seq_plus_one);
                self.engine
                    .run(&self.grad_name, &[Input::F32(x), Input::I32(&toks)])
            }
        }
        // lint:allow(panic-path): executable shapes/dtypes are fixed by the AOT manifest; a mismatch is a build error
        .expect("PJRT grad execution failed");
        // lint:allow(panic-path): executable shapes/dtypes are fixed by the AOT manifest; a mismatch is a build error
        let loss = outputs[0].scalar_f32().expect("loss scalar");
        let grad = match &outputs[1] {
            super::engine::Output::F32(v) => v,
            // lint:allow(panic-path): executable shapes/dtypes are fixed by the AOT manifest; a mismatch is a build error
            _ => panic!("grad output must be f32"),
        };
        grad_out.copy_from_slice(grad);
        loss
    }
}

/// Centralized PJRT evaluation (loss + accuracy over held-out data).
pub struct PjrtEval {
    engine: Rc<Engine>,
    eval_name: String,
    kind: EvalKind,
}

enum EvalKind {
    Supervised {
        eval: Arc<Dataset>,
        chunk: usize,
        labels_i32: bool,
    },
    /// Fixed deterministic token blocks generated at construction.
    Tokens { blocks: Vec<Vec<i32>> },
}

impl PjrtEval {
    pub fn eval(&mut self, x: &[f32]) -> Eval {
        match &self.kind {
            EvalKind::Supervised { eval, chunk, labels_i32 } => {
                let mut total_loss = 0.0f64;
                let mut total_correct = 0i64;
                let mut counted = 0usize;
                let mut xbuf = Vec::with_capacity(chunk * eval.dim);
                let mut yf = Vec::with_capacity(*chunk);
                let mut yi = Vec::with_capacity(*chunk);
                let full_chunks = eval.len() / chunk;
                for c in 0..full_chunks.max(1).min(full_chunks) {
                    xbuf.clear();
                    yf.clear();
                    yi.clear();
                    for s in c * chunk..(c + 1) * chunk {
                        xbuf.extend_from_slice(eval.row(s));
                        if *labels_i32 {
                            yi.push(eval.labels[s] as i32);
                        } else {
                            yf.push(eval.labels[s] as f32);
                        }
                    }
                    let labels: Input<'_> = if *labels_i32 {
                        Input::I32(&yi)
                    } else {
                        Input::F32(&yf)
                    };
                    let out = self
                        .engine
                        .run(&self.eval_name,
                             &[Input::F32(x), Input::F32(&xbuf), labels])
                        // lint:allow(panic-path): executable shapes/dtypes are fixed by the AOT manifest; a mismatch is a build error
                        .expect("PJRT eval failed");
                    // lint:allow(panic-path): executable shapes/dtypes are fixed by the AOT manifest; a mismatch is a build error
                    total_loss += out[0].scalar_f32().unwrap() as f64 * *chunk as f64;
                    // lint:allow(panic-path): executable shapes/dtypes are fixed by the AOT manifest; a mismatch is a build error
                    total_correct += out[1].scalar_i32().unwrap() as i64;
                    counted += chunk;
                }
                Eval {
                    loss: total_loss / counted.max(1) as f64,
                    accuracy: Some(total_correct as f64 / counted.max(1) as f64),
                }
            }
            EvalKind::Tokens { blocks } => {
                let mut total = 0.0f64;
                for b in blocks {
                    let out = self
                        .engine
                        .run(&self.eval_name, &[Input::F32(x), Input::I32(b)])
                        // lint:allow(panic-path): executable shapes/dtypes are fixed by the AOT manifest; a mismatch is a build error
                        .expect("PJRT eval failed");
                    // lint:allow(panic-path): executable shapes/dtypes are fixed by the AOT manifest; a mismatch is a build error
                    total += out[0].scalar_f32().unwrap() as f64;
                }
                Eval { loss: total / blocks.len() as f64, accuracy: None }
            }
        }
    }
}

/// Build a full [`OracleSet`] sharing ONE engine across this thread's node
/// oracles — the simulator path.
pub fn build_set(manifest: &Manifest, task: &PjrtTask, n_nodes: usize,
                 seed: u64) -> Result<OracleSet> {
    let grad_name = task.grad_artifact();
    let eval_name = task.eval_artifact();
    let engine = Rc::new(
        Engine::load(manifest, &[&grad_name, &eval_name])
            .map_err(|e| anyhow!("engine: {e}"))?,
    );
    build_set_with_engine(engine, manifest, task, n_nodes, seed)
}

fn build_set_with_engine(engine: Rc<Engine>, manifest: &Manifest,
                         task: &PjrtTask, n_nodes: usize,
                         seed: u64) -> Result<OracleSet> {
    let grad_name = task.grad_artifact();
    let eval_name = task.eval_artifact();
    let ginfo = engine
        .artifact_info(&grad_name)
        .ok_or_else(|| anyhow!("{grad_name} not loaded"))?;
    let p = ginfo.inputs[0].numel();
    let model = manifest.model(&task.model_name()).map_err(|e| anyhow!(e))?;
    if model.p != p {
        return Err(anyhow!("model p {} vs artifact p {}", model.p, p));
    }

    let mut nodes: Vec<Box<dyn NodeOracle>> = Vec::new();
    let mut epoch_frac: f64;
    match task {
        PjrtTask::LogReg { data, partition, .. }
        | PjrtTask::Mlp { data, partition, .. } => {
            let labels_i32 = matches!(task, PjrtTask::Mlp { .. });
            let batch = ginfo.inputs[1].shape[0];
            if partition.n_nodes() != n_nodes {
                return Err(anyhow!("partition has {} shards, want {n_nodes}",
                                   partition.n_nodes()));
            }
            // one node-batch advances the GLOBAL epoch by batch / N_total
            let total: usize =
                partition.shards.iter().map(|s| s.len()).sum();
            epoch_frac = batch as f64 / total as f64;
            for i in 0..n_nodes {
                let b = Batcher::new(&partition.shards[i], batch,
                                     seed ^ (0xb0 + i as u64));
                nodes.push(Box::new(PjrtOracle {
                    engine: Rc::clone(&engine),
                    grad_name: grad_name.clone(),
                    p,
                    feed: Feed::Supervised {
                        data: Arc::clone(data),
                        batcher: b,
                        labels_i32,
                        xbuf: Vec::new(),
                        yf: Vec::new(),
                        yi: Vec::new(),
                    },
                }));
            }
        }
        PjrtTask::Transformer { vocab, branching, .. } => {
            let batch = ginfo.inputs[1].shape[0];
            let spo = ginfo.inputs[1].shape[1];
            let base = TokenStream::new(*vocab, *branching, seed);
            for i in 0..n_nodes {
                nodes.push(Box::new(PjrtOracle {
                    engine: Rc::clone(&engine),
                    grad_name: grad_name.clone(),
                    p,
                    feed: Feed::Tokens {
                        stream: base.for_node(i, seed ^ 0x7ea),
                        batch,
                        seq_plus_one: spo,
                    },
                }));
            }
            // "epoch" for the LM = 1M tokens consumed globally
            epoch_frac = (batch * spo) as f64 / 1e6;
        }
    }

    // evaluation closure
    let mut ev = match task {
        PjrtTask::LogReg { eval, .. } | PjrtTask::Mlp { eval, .. } => {
            let einfo = engine
                .artifact_info(&eval_name)
                .ok_or_else(|| anyhow!("{eval_name} not loaded"))?;
            PjrtEval {
                engine: Rc::clone(&engine),
                eval_name: eval_name.clone(),
                kind: EvalKind::Supervised {
                    eval: Arc::clone(eval),
                    chunk: einfo.inputs[1].shape[0],
                    labels_i32: matches!(task, PjrtTask::Mlp { .. }),
                },
            }
        }
        PjrtTask::Transformer { vocab, branching, .. } => {
            let einfo = engine
                .artifact_info(&eval_name)
                .ok_or_else(|| anyhow!("{eval_name} not loaded"))?;
            let batch = einfo.inputs[1].shape[0];
            let spo = einfo.inputs[1].shape[1];
            let mut stream =
                TokenStream::new(*vocab, *branching, seed).for_node(999, seed ^ 0xe7a1);
            let blocks = (0..4).map(|_| stream.next_block(batch, spo)).collect();
            PjrtEval {
                engine: Rc::clone(&engine),
                eval_name: eval_name.clone(),
                kind: EvalKind::Tokens { blocks },
            }
        }
    };

    Ok(OracleSet {
        nodes,
        eval: Box::new(move |x| ev.eval(x)),
        optimum: None,
        dim: p,
        epoch_per_node_batch: epoch_frac,
    })
}

/// Thread-safe factory for the runner: each worker compiles its own engine.
pub struct PjrtFactory {
    pub manifest: Manifest,
    pub task: PjrtTask,
    pub seed: u64,
    pub dim: usize,
}

impl PjrtFactory {
    pub fn new(manifest: Manifest, task: PjrtTask, seed: u64) -> Result<PjrtFactory> {
        let model = manifest.model(&task.model_name()).map_err(|e| anyhow!(e))?;
        Ok(PjrtFactory { dim: model.p, manifest, task, seed })
    }
}

impl OracleFactory for PjrtFactory {
    fn dim(&self) -> usize {
        self.dim
    }

    /// Same epoch accounting as [`build_set`] (batch / total samples for
    /// the supervised tasks, tokens-per-step / 1M for the LM), read off
    /// the manifest so no engine compile is needed.
    fn epoch_per_node_batch(&self) -> f64 {
        let Ok(info) = self.manifest.artifact(&self.task.grad_artifact())
        else {
            return 1.0;
        };
        match &self.task {
            PjrtTask::LogReg { partition, .. }
            | PjrtTask::Mlp { partition, .. } => {
                let batch = info.inputs[1].shape[0];
                let total: usize =
                    partition.shards.iter().map(|s| s.len()).sum();
                batch as f64 / total.max(1) as f64
            }
            PjrtTask::Transformer { .. } => {
                let batch = info.inputs[1].shape[0];
                let spo = info.inputs[1].shape[1];
                (batch * spo) as f64 / 1e6
            }
        }
    }

    fn make(&self, node: usize) -> Box<dyn NodeOracle> {
        // Build a 1-node set on THIS thread and take its only oracle: the
        // engine is compiled here, inside the worker.
        let grad_name = self.task.grad_artifact();
        let eval_name = self.task.eval_artifact();
        let engine = Rc::new(
            Engine::load(&self.manifest, &[&grad_name, &eval_name])
                // lint:allow(panic-path): per-worker factory fails fast; the main thread validated the same manifest already
                .expect("worker engine"),
        );
        let mut set = build_single_node(engine, &self.manifest, &self.task,
                                        node, self.seed)
            // lint:allow(panic-path): per-worker factory fails fast; the main thread validated the same manifest already
            .expect("worker oracle");
        set.nodes.remove(0)
    }
}

/// One node's oracle (used by the factory; node id selects the shard /
/// stream so worker i sees the same data as simulator node i).
fn build_single_node(engine: Rc<Engine>, manifest: &Manifest, task: &PjrtTask,
                     node: usize, seed: u64) -> Result<OracleSet> {
    match task {
        PjrtTask::LogReg { data, eval, partition } => {
            let sub = PjrtTask::LogReg {
                data: Arc::clone(data),
                eval: Arc::clone(eval),
                partition: Partition {
                    shards: vec![partition.shards[node].clone()],
                },
            };
            build_set_with_engine(engine, manifest, &sub, 1,
                                  seed ^ (node as u64) << 32)
        }
        PjrtTask::Mlp { data, eval, partition } => {
            let sub = PjrtTask::Mlp {
                data: Arc::clone(data),
                eval: Arc::clone(eval),
                partition: Partition {
                    shards: vec![partition.shards[node].clone()],
                },
            };
            build_set_with_engine(engine, manifest, &sub, 1,
                                  seed ^ (node as u64) << 32)
        }
        PjrtTask::Transformer { .. } => {
            // per-node stream id must match build_set's node numbering
            let mut set =
                build_set_with_engine(engine, manifest, task, node + 1, seed)?;
            let only = set.nodes.remove(node);
            set.nodes = vec![only];
            Ok(set)
        }
    }
}
