//! `artifacts/manifest.json` parsing — the shape contract between
//! `python/compile/aot.py` and the rust runtime.

use crate::jsonio::{self, Json};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// dtype + shape of one executable input/output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String, // "float32" | "int32"
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec, String> {
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or("missing shape")?
            .iter()
            .map(|v| v.as_usize().ok_or("bad dim"))
            .collect::<Result<Vec<_>, _>>()?;
        let dtype = j
            .get("dtype")
            .and_then(Json::as_str)
            .ok_or("missing dtype")?
            .to_string();
        Ok(TensorSpec { shape, dtype })
    }
}

/// One AOT executable.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub hlo_path: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub meta: Json,
}

/// One model's initialization + dimensions.
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub name: String,
    pub init_path: PathBuf,
    pub p: usize,
    pub meta: Json,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
    pub models: BTreeMap<String, ModelInfo>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        Manifest::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest, String> {
        let j = jsonio::parse(text).map_err(|e| e.to_string())?;
        let mut artifacts = BTreeMap::new();
        for (name, a) in j.get("artifacts").and_then(Json::as_obj).ok_or("no artifacts key")? {
            let hlo = a.get("hlo").and_then(Json::as_str).ok_or("no hlo path")?;
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>, String> {
                a.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| format!("{name}: no {key}"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<Vec<_>, _>>()
                    .map_err(|e| format!("{name}: {e}"))
            };
            artifacts.insert(
                name.clone(),
                ArtifactInfo {
                    name: name.clone(),
                    hlo_path: dir.join(hlo),
                    inputs: parse_specs("inputs")?,
                    outputs: parse_specs("outputs")?,
                    meta: a.get("meta").cloned().unwrap_or(Json::Null),
                },
            );
        }
        let mut models = BTreeMap::new();
        for (name, m) in j.get("models").and_then(Json::as_obj).ok_or("no models key")? {
            models.insert(
                name.clone(),
                ModelInfo {
                    name: name.clone(),
                    init_path: dir.join(
                        m.get("init").and_then(Json::as_str).ok_or("no init")?,
                    ),
                    p: m.get("p").and_then(Json::as_usize).ok_or("no p")?,
                    meta: m.clone(),
                },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts, models })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactInfo, String> {
        self.artifacts
            .get(name)
            .ok_or_else(|| format!("artifact {name:?} not in manifest (have: {:?})",
                                   self.artifacts.keys().collect::<Vec<_>>()))
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo, String> {
        self.models
            .get(name)
            .ok_or_else(|| format!("model {name:?} not in manifest"))
    }

    /// Load a model's initial flat parameter vector.
    pub fn load_init(&self, model: &str) -> Result<Vec<f32>, String> {
        let info = self.model(model)?;
        let v = super::read_f32_file(&info.init_path).map_err(|e| e.to_string())?;
        if v.len() != info.p {
            return Err(format!(
                "{model}: init file has {} floats, manifest says p={}",
                v.len(),
                info.p
            ));
        }
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
 "artifacts": {
  "logreg_grad": {
   "hlo": "logreg_grad.hlo.txt",
   "inputs": [
    {"dtype": "float32", "shape": [785]},
    {"dtype": "float32", "shape": [32, 784]},
    {"dtype": "float32", "shape": [32]}
   ],
   "outputs": [
    {"dtype": "float32", "shape": []},
    {"dtype": "float32", "shape": [785]}
   ],
   "meta": {"batch": 32, "l2": 0.0001, "model": "logreg"}
  }
 },
 "models": {
  "logreg": {"init": "logreg_init.f32", "p": 785, "l2": 0.0001}
 }
}"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp/arts"), SAMPLE).unwrap();
        let a = m.artifact("logreg_grad").unwrap();
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[1].shape, vec![32, 784]);
        assert_eq!(a.inputs[1].numel(), 32 * 784);
        assert_eq!(a.outputs[0].shape, Vec::<usize>::new());
        assert_eq!(a.hlo_path, Path::new("/tmp/arts/logreg_grad.hlo.txt"));
        let model = m.model("logreg").unwrap();
        assert_eq!(model.p, 785);
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse(Path::new("."), "{}").is_err());
        assert!(Manifest::parse(Path::new("."), "not json").is_err());
        let missing_hlo = r#"{"artifacts": {"a": {"inputs": [], "outputs": []}}, "models": {}}"#;
        assert!(Manifest::parse(Path::new("."), missing_hlo).is_err());
    }

    #[test]
    fn real_manifest_parses_if_present() {
        if let Some(dir) = crate::runtime::default_artifact_dir() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.artifacts.contains_key("logreg_grad"));
            let init = m.load_init("logreg").unwrap();
            assert_eq!(init.len(), m.model("logreg").unwrap().p);
        }
    }
}
