//! Thread-local PJRT execution engine.
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` → `HloModuleProto::
//! from_text_file` → `compile` → `execute`, following the smoke-verified
//! pattern of /opt/xla-example/load_hlo. One engine per thread (the client
//! is `Rc`-based); executables are compiled once at construction and
//! reused for every step.

use super::manifest::{ArtifactInfo, Manifest};
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;

pub struct Engine {
    client: xla::PjRtClient,
    executables: BTreeMap<String, (ArtifactInfo, xla::PjRtLoadedExecutable)>,
}

impl Engine {
    /// Compile the named artifacts (compile-once; call off the hot path).
    pub fn load(manifest: &Manifest, names: &[&str]) -> Result<Engine> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;
        let mut executables = BTreeMap::new();
        for &name in names {
            let info = manifest
                .artifact(name)
                .map_err(|e| anyhow!(e))?
                .clone();
            let proto = xla::HloModuleProto::from_text_file(&info.hlo_path)
                .map_err(|e| anyhow!("parse {}: {e:?}", info.hlo_path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
            executables.insert(name.to_string(), (info, exe));
        }
        Ok(Engine { client, executables })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact_info(&self, name: &str) -> Option<&ArtifactInfo> {
        self.executables.get(name).map(|(i, _)| i)
    }

    /// Execute an artifact on f32/i32 host buffers. Inputs must match the
    /// manifest specs (checked); outputs come back as flat f32 vectors
    /// (int outputs are converted).
    pub fn run(&self, name: &str, inputs: &[Input<'_>]) -> Result<Vec<Output>> {
        let (info, exe) = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not loaded"))?;
        if inputs.len() != info.inputs.len() {
            return Err(anyhow!(
                "{name}: got {} inputs, artifact wants {}",
                inputs.len(),
                info.inputs.len()
            ));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (k, (input, spec)) in inputs.iter().zip(&info.inputs).enumerate() {
            let lit = match (input, spec.dtype.as_str()) {
                (Input::F32(data), "float32") => {
                    if data.len() != spec.numel() {
                        return Err(anyhow!(
                            "{name} input {k}: {} elems, spec {:?}",
                            data.len(),
                            spec.shape
                        ));
                    }
                    make_literal_f32(data, &spec.shape)?
                }
                (Input::I32(data), "int32") => {
                    if data.len() != spec.numel() {
                        return Err(anyhow!(
                            "{name} input {k}: {} elems, spec {:?}",
                            data.len(),
                            spec.shape
                        ));
                    }
                    make_literal_i32(data, &spec.shape)?
                }
                (inp, want) => {
                    return Err(anyhow!(
                        "{name} input {k}: host dtype {} vs artifact {want}",
                        inp.dtype_name()
                    ))
                }
            };
            literals.push(lit);
        }
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unpack the tuple.
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow!("to_tuple {name}: {e:?}"))?;
        if parts.len() != info.outputs.len() {
            return Err(anyhow!(
                "{name}: {} outputs, manifest says {}",
                parts.len(),
                info.outputs.len()
            ));
        }
        parts
            .into_iter()
            .zip(&info.outputs)
            .map(|(lit, spec)| match spec.dtype.as_str() {
                "float32" => Ok(Output::F32(
                    lit.to_vec::<f32>()
                        .map_err(|e| anyhow!("output read: {e:?}"))?,
                )),
                "int32" => Ok(Output::I32(
                    lit.to_vec::<i32>()
                        .map_err(|e| anyhow!("output read: {e:?}"))?,
                )),
                other => Err(anyhow!("unsupported output dtype {other}")),
            })
            .collect()
    }
}

/// Borrowed host input buffer.
pub enum Input<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

impl Input<'_> {
    fn dtype_name(&self) -> &'static str {
        match self {
            Input::F32(_) => "float32",
            Input::I32(_) => "int32",
        }
    }
}

/// Owned host output buffer.
#[derive(Debug, Clone)]
pub enum Output {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Output {
    pub fn f32(self) -> Result<Vec<f32>> {
        match self {
            Output::F32(v) => Ok(v),
            Output::I32(_) => Err(anyhow!("output is int32, wanted float32")),
        }
    }

    pub fn scalar_f32(&self) -> Result<f32> {
        match self {
            Output::F32(v) if v.len() == 1 => Ok(v[0]),
            Output::F32(v) => Err(anyhow!("expected scalar, got {} elems", v.len())),
            Output::I32(_) => Err(anyhow!("output is int32")),
        }
    }

    pub fn scalar_i32(&self) -> Result<i32> {
        match self {
            Output::I32(v) if v.len() == 1 => Ok(v[0]),
            _ => Err(anyhow!("expected scalar int32")),
        }
    }
}

fn make_literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    reshape(lit, shape)
}

fn make_literal_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    reshape(lit, shape)
}

fn reshape(lit: xla::Literal, shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims)
        .map_err(|e| anyhow!("reshape to {shape:?}: {e:?}"))
        .context("literal reshape")
}
