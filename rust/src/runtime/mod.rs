//! PJRT runtime — the request-path bridge to the AOT artifacts.
//!
//! `python/compile/aot.py` lowers the L2/L1 stack ONCE to
//! `artifacts/*.hlo.txt` (+ `manifest.json`, `*_init.f32`); this module
//! loads the HLO **text** (xla_extension 0.5.1 rejects jax≥0.5 serialized
//! protos — DESIGN.md §6), compiles it on the PJRT CPU client, and
//! executes it from the coordinator's hot path. Python never runs here.
//!
//! Thread model: the `xla` crate's client is `Rc`-based (!Send), so an
//! [`Engine`] is strictly thread-local. Each runner worker builds its own
//! engine from the shared artifact directory (compile happens once per
//! thread at startup, off the hot path).

mod engine;
mod manifest;
mod oracle;

pub use engine::{Engine, Input, Output};
pub use manifest::{ArtifactInfo, Manifest, ModelInfo, TensorSpec};
pub use oracle::{build_set as build_pjrt_set, PjrtEval, PjrtFactory,
                 PjrtOracle, PjrtTask};

use std::path::{Path, PathBuf};

/// Locate the artifact directory: `$RFAST_ARTIFACTS` or `./artifacts`
/// relative to the workspace root (walks up from cwd until it finds a
/// `manifest.json`).
pub fn default_artifact_dir() -> Option<PathBuf> {
    if let Ok(dir) = std::env::var("RFAST_ARTIFACTS") {
        let p = PathBuf::from(dir);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    let mut cur = std::env::current_dir().ok()?;
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").exists() {
            return Some(cand);
        }
        if !cur.pop() {
            return None;
        }
    }
}

/// Read a raw little-endian f32 file (the `*_init.f32` initial parameters).
pub fn read_f32_file(path: &Path) -> std::io::Result<Vec<f32>> {
    let bytes = std::fs::read(path)?;
    if bytes.len() % 4 != 0 {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("{}: length {} not a multiple of 4", path.display(), bytes.len()),
        ));
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_f32_roundtrip() {
        let dir = std::env::temp_dir().join("rfast_f32_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("x.f32");
        let vals = [1.5f32, -2.25, 0.0, 1e-9];
        let bytes: Vec<u8> =
            vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&path, bytes).unwrap();
        assert_eq!(read_f32_file(&path).unwrap(), vals);
        std::fs::write(&path, [0u8; 5]).unwrap();
        assert!(read_f32_file(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
