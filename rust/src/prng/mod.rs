//! Deterministic PRNG substrate (no `rand` crate offline).
//!
//! [`Rng`] is Xoshiro256\*\* seeded through SplitMix64 — the standard
//! combination: SplitMix64 decorrelates small integer seeds, Xoshiro256\*\*
//! passes BigCrush and is a few ns per draw. Everything in the simulator,
//! data generators and property tests draws from this, so a run is fully
//! reproducible from a single `u64` seed.

/// SplitMix64 step — used for seeding and as a cheap standalone mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Xoshiro256\*\* generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 (any u64, including 0, gives a good state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (e.g. per node / per link) from a parent
    /// seed and a stream id. Streams are decorrelated by the SplitMix64 mix.
    pub fn stream(seed: u64, id: u64) -> Self {
        Rng::new(seed ^ id.wrapping_mul(0xA24BAED4963EE407))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of mantissa.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free for our use).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 128-bit multiply avoids modulo bias to within 2^-64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (cached second draw skipped for
    /// simplicity; gradient-noise use doesn't need the throughput).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (std::f64::consts::TAU * u2).cos();
            }
        }
    }

    /// Normal with mean/std as f32 (data generation hot path).
    #[inline]
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Exponential with given mean (inter-arrival / latency draws).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        -mean * u.ln()
    }

    /// Log-normal with given *linear-space* mean and sigma of the underlying
    /// normal — used for compute-time jitter (heavy right tail like real
    /// steps).
    pub fn lognormal(&mut self, linear_mean: f64, sigma: f64) -> f64 {
        // E[exp(N(mu, s^2))] = exp(mu + s^2/2) ⇒ mu = ln(mean) − s²/2
        let mu = linear_mean.ln() - 0.5 * sigma * sigma;
        (mu + sigma * self.normal()).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (k << n assumed; rejection).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 3 > n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            return all;
        }
        let mut picked = Vec::with_capacity(k);
        while picked.len() < k {
            let c = self.below(n);
            if !picked.contains(&c) {
                picked.push(c);
            }
        }
        picked
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(12345);
        let mut b = Rng::new(12345);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn streams_are_decorrelated() {
        let mut a = Rng::stream(7, 0);
        let mut b = Rng::stream(7, 1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_roughly() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(17);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(3.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn lognormal_linear_mean() {
        let mut r = Rng::new(19);
        let n = 200_000;
        let mean: f64 =
            (0..n).map(|_| r.lognormal(5.0, 0.4)).sum::<f64>() / n as f64;
        assert!((mean - 5.0).abs() < 0.15, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(29);
        for _ in 0..100 {
            let s = r.sample_indices(20, 5);
            let mut d = s.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), 5);
        }
    }
}
