//! Ablation: data-heterogeneity robustness (Definition 2 / Remark 7).
//!
//! Gradient tracking makes R-FAST's rate ς-free; AD-PSGD/OSGP/D-PSGD carry
//! a ς-dependent term. We sweep the label-skew α of the partition from IID
//! (α=0) to fully class-segregated shards (α=1) on the logreg workload and
//! on quadratics with growing minimizer spread (where ς is exact).

use rfast::algo::AlgoKind;
use rfast::config::SimConfig;
use rfast::exp::{Experiment, QuadSpec, Stop, Workload};
use rfast::graph::Topology;
use rfast::metrics::Table;

const ALGOS: [AlgoKind; 4] = [
    AlgoKind::RFast,
    AlgoKind::DPsgd,
    AlgoKind::AdPsgd,
    AlgoKind::Osgp,
];

fn main() {
    // --- quadratics: exact ς via minimizer spread ------------------------
    let mut t1 = Table::new(
        "ablation: optimality gap vs heterogeneity ς (quadratics, fixed γ)",
        &["spread (∝ς)", "ς²@x*", "R-FAST", "D-PSGD", "AD-PSGD", "OSGP"],
    );
    for spread in [0.0f32, 0.5, 1.0, 2.0, 4.0] {
        let spec = QuadSpec { dim: 16, h_min: 0.5, h_max: 2.0, spread,
                              noise: 0.0 };
        let sigma2 = spec.build(6, 31).heterogeneity_at_optimum();
        let cfg = SimConfig {
            seed: 31,
            gamma: 0.03,
            compute_mean: 0.01,
            compute_jitter: 0.3,
            link_latency: 0.002,
            latency_cap: 0.05,
            eval_every: 5.0,
            ..SimConfig::default()
        };
        let cmp = Experiment::new(Workload::Quadratic(spec), AlgoKind::RFast)
            .topology(&Topology::ring(6))
            .config(cfg)
            .stop(Stop::Iterations(60_000))
            .sweep_algos(&ALGOS)
            .expect("quad sweep");
        let mut row = vec![format!("{spread}"), format!("{sigma2:.2}")];
        for run in &cmp.runs {
            let gap = run.report.final_gap.unwrap_or(f64::NAN);
            row.push(format!("{gap:.3e}"));
        }
        t1.row(row);
    }
    t1.print();

    // --- logreg: label-skew partitions -----------------------------------
    let mut t2 = Table::new(
        "ablation: logreg final loss / acc(%) vs label-skew α (8 nodes, \
         60 virtual s)",
        &["skew α", "R-FAST", "D-PSGD", "AD-PSGD", "OSGP"],
    );
    for alpha in [0.0, 0.5, 0.9, 1.0] {
        let mut cfg = Workload::LogReg.paper_config();
        cfg.seed = 13;
        cfg.skew_alpha = alpha;
        let cmp = Experiment::new(Workload::LogReg, AlgoKind::RFast)
            .topology(&Topology::ring(8))
            .config(cfg)
            .stop(Stop::Time(60.0))
            .sweep_algos(&ALGOS)
            .expect("logreg sweep");
        let mut row = vec![format!("{alpha}")];
        for run in &cmp.runs {
            let loss = run.report.series["loss_vs_time"].last_y().unwrap();
            let acc = run.report.series["acc_vs_time"].last_y().unwrap();
            row.push(format!("{loss:.3} / {:.1}", acc * 100.0));
        }
        t2.row(row);
    }
    t2.print();
    println!("\nExpected shape: R-FAST's columns barely move with ς / α \
              (gradient tracking); D-PSGD's fixed-step bias and AD-PSGD's \
              drift grow with heterogeneity (Remark 7).");
}
