//! Fig 4a — R-FAST training loss vs epoch over five topologies (7 nodes,
//! regularized logreg, B=32 per node). Regenerates the paper's figure as
//! `runs/fig4a_*.csv` plus a console summary.
//!
//! Paper claim reproduced: R-FAST converges on ALL of binary tree, line,
//! directed ring, exponential and mesh — including the two that are not
//! strongly connected (tree, line), which no strongly-connected-only
//! baseline supports.

use rfast::algo::AlgoKind;
use rfast::exp::{run_sim, save_comparison_csvs, Workload};
use rfast::graph::TopologyKind;
use rfast::metrics::Table;
use rfast::sim::StopRule;
use std::path::Path;

fn main() {
    let n = 7;
    let epochs = std::env::var("RFAST_BENCH_EPOCHS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(30.0);
    let kinds = [
        TopologyKind::BinaryTree,
        TopologyKind::Line,
        TopologyKind::Ring,
        TopologyKind::Exponential,
        TopologyKind::Mesh,
    ];
    let mut table = Table::new(
        &format!("Fig 4a: R-FAST loss vs epoch over topologies \
                  ({n} nodes, {epochs} epochs)"),
        &["topology", "loss@25%", "loss@50%", "final loss", "final acc(%)"],
    );
    let mut reports = Vec::new();
    for kind in kinds {
        let topo = kind.build(n);
        let mut cfg = Workload::LogReg.paper_config();
        cfg.seed = 1;
        cfg.gamma = 4e-3; // root-concentration makes ring/mesh slower at
                          // the paper's 1e-3; 4e-3 keeps all five in frame
        let mut r = run_sim(Workload::LogReg, AlgoKind::RFast, &topo, &cfg,
                            StopRule::Epochs(epochs));
        let s = &r.series["loss_vs_epoch"];
        let probe = |frac: f64| -> f64 {
            let target_x = epochs * frac;
            s.points
                .iter()
                .min_by(|a, b| {
                    (a.0 - target_x)
                        .abs()
                        .partial_cmp(&(b.0 - target_x).abs())
                        .unwrap()
                })
                .map(|&(_, y)| y)
                .unwrap_or(f64::NAN)
        };
        table.row(vec![
            kind.name().to_string(),
            format!("{:.4}", probe(0.25)),
            format!("{:.4}", probe(0.5)),
            format!("{:.4}", s.last_y().unwrap()),
            format!("{:.1}",
                    100.0 * r.series["acc_vs_epoch"].last_y().unwrap()),
        ]);
        r.label = kind.name().to_string();
        reports.push(r);
    }
    table.print();
    let refs: Vec<&_> = reports.iter().collect();
    save_comparison_csvs(Path::new("runs"), "fig4a", &refs).unwrap();
    println!("series: runs/fig4a_loss_vs_epoch.csv");
}
