//! Fig 4a — R-FAST training loss vs epoch over five topologies (7 nodes,
//! regularized logreg, B=32 per node). Regenerates the paper's figure as
//! `runs/fig4a_*.csv` plus a console summary.
//!
//! Paper claim reproduced: R-FAST converges on ALL of binary tree, line,
//! directed ring, exponential and mesh — including the two that are not
//! strongly connected (tree, line), which no strongly-connected-only
//! baseline supports.

use rfast::algo::AlgoKind;
use rfast::exp::{Experiment, Stop, Workload};
use rfast::graph::TopologyKind;
use rfast::metrics::Table;
use std::path::Path;

fn main() {
    let n = 7;
    let epochs = std::env::var("RFAST_BENCH_EPOCHS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(30.0);
    let kinds = [
        TopologyKind::BinaryTree,
        TopologyKind::Line,
        TopologyKind::Ring,
        TopologyKind::Exponential,
        TopologyKind::Mesh,
    ];
    let mut cfg = Workload::LogReg.paper_config();
    cfg.seed = 1;
    cfg.gamma = 4e-3; // root-concentration makes ring/mesh slower at
                      // the paper's 1e-3; 4e-3 keeps all five in frame
    // sweep-native: one chain, five topologies, labeled reports
    let cmp = Experiment::new(Workload::LogReg, AlgoKind::RFast)
        .config(cfg)
        .stop(Stop::Epochs(epochs))
        .sweep_topologies(&kinds, n)
        .expect("fig4a sweep");

    let mut table = Table::new(
        &format!("Fig 4a: R-FAST loss vs epoch over topologies \
                  ({n} nodes, {epochs} epochs)"),
        &["topology", "loss@25%", "loss@50%", "final loss", "final acc(%)"],
    );
    for run in &cmp.runs {
        let s = &run.report.series["loss_vs_epoch"];
        let probe = |frac: f64| -> f64 {
            let target_x = epochs * frac;
            s.points
                .iter()
                .min_by(|a, b| {
                    (a.0 - target_x)
                        .abs()
                        .partial_cmp(&(b.0 - target_x).abs())
                        .unwrap()
                })
                .map(|&(_, y)| y)
                .unwrap_or(f64::NAN)
        };
        table.row(vec![
            run.report.label.clone(),
            format!("{:.4}", probe(0.25)),
            format!("{:.4}", probe(0.5)),
            format!("{:.4}", s.last_y().unwrap()),
            format!("{:.1}",
                    100.0 * run.report.series["acc_vs_epoch"].last_y().unwrap()),
        ]);
    }
    table.print();
    cmp.save_csvs(Path::new("runs"), "fig4a").unwrap();
    println!("series: runs/fig4a_loss_vs_epoch.csv");
}
