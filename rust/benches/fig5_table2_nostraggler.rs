//! Fig 5 (a,b,c) + Table II columns 2-3 — the six-algorithm comparison with
//! no straggler: loss vs time, loss vs epoch, accuracy vs epoch, and the
//! (time, accuracy) table at a fixed epoch budget.
//!
//! Workload: the ResNet-50/ImageNet *coordination proxy* of DESIGN.md §4 —
//! a 10-class MLP on synthetic images with the paper-calibrated timing
//! model (≈200 ms grad steps, ≈20 ms links). Packet loss (2%) is applied
//! to the asynchronous algorithms exactly as in §VI ¶1.
//!
//! Paper claims reproduced (shape, not absolute minutes):
//!   * R-FAST finishes the epoch budget ~1.5-2× faster than the
//!     synchronous D-PSGD / S-AB / Ring-AllReduce;
//!   * async AD-PSGD / OSGP are similarly fast but land at lower accuracy
//!     under packet loss; R-FAST matches the synchronous accuracy.

use rfast::algo::AlgoKind;
use rfast::exp::{Experiment, Stop, Workload, PAPER_BASELINES};
use rfast::graph::Topology;
use rfast::metrics::{fmt_mins, Table};
use rfast::scenario::Scenario;
use std::path::Path;

fn main() {
    let n = 8;
    let epochs = std::env::var("RFAST_BENCH_EPOCHS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10.0);
    let topo = Topology::ring(n);

    // §VI ¶1 as a named scenario: 2% loss — the link layer applies it to
    // the loss-tolerant (async) algorithms only
    let mut cfg = Workload::Mlp.paper_config();
    cfg.seed = 4;
    cfg.gamma_decay = Some((5.0, 0.1)); // paper: lr ÷10 per 30 of 90 epochs — ÷10 per 5 of our 10
    cfg.scenario = Some(Scenario::by_name("paper_fig5").unwrap());
    // sweep-native: the per-algorithm tuned γ is applied by the sweep
    let cmp = Experiment::new(Workload::Mlp, AlgoKind::RFast)
        .topology(&topo)
        .config(cfg)
        .stop(Stop::Epochs(epochs))
        .sweep_algos_tuned(&PAPER_BASELINES)
        .expect("fig5 sweep");

    let mut table = Table::new(
        &format!("Table II (no straggler): {epochs} epochs on {n}-node ring, \
                  MLP proxy"),
        &["algorithm", "time(mins)", "acc(%)", "rel. time vs R-FAST"],
    );
    let mut rfast_time = None;
    for run in &cmp.runs {
        let time = run.report.scalars["virtual_time"];
        let acc = run.report.series["acc_vs_time"].last_y().unwrap_or(0.0);
        let base = *rfast_time.get_or_insert(time);
        table.row(vec![
            run.report.label.clone(),
            fmt_mins(time),
            format!("{:.2}", acc * 100.0),
            format!("{:.2}×", time / base),
        ]);
    }
    table.print();
    cmp.save_csvs(Path::new("runs"), "fig5").unwrap();
    println!("Fig 5a: runs/fig5_loss_vs_time.csv");
    println!("Fig 5b: runs/fig5_loss_vs_epoch.csv");
    println!("Fig 5c: runs/fig5_acc_vs_epoch.csv");
}
