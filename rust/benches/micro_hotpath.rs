//! L3 hot-path microbenches (EXPERIMENTS.md §Perf). Criterion is
//! unavailable offline; `BenchTimer` measures ns/iter with warmup and
//! batched timing.
//!
//! Covers every per-wake cost center:
//!   * linalg primitives at logreg (p=785) and transformer-e2e (p≈4.2M)
//!     sizes,
//!   * a full R-FAST wake (quadratic oracle; pure coordination cost),
//!   * rust logreg / MLP gradient oracles,
//!   * simulator event throughput,
//!   * PJRT logreg grad round trip (when artifacts are present).

use rfast::algo::{AlgoKind, NodeState};
use rfast::data::{Dataset, Partition};
use rfast::exp::BenchTimer;
use rfast::graph::Topology;
use rfast::oracle::{GradOracle, LogRegOracle, MlpOracle, QuadraticOracle};
use rfast::prng::Rng;
use rfast::sim::{Simulator, StopRule};
use std::sync::Arc;

fn main() {
    let mut results: Vec<BenchTimer> = Vec::new();
    let quick = std::env::var("RFAST_BENCH_QUICK").is_ok();
    let t = if quick { 0.05 } else { 0.3 };

    // ---- linalg ---------------------------------------------------------
    for &p in &[785usize, 4_236_800] {
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..p).map(|_| rng.f32()).collect();
        let mut y: Vec<f32> = (0..p).map(|_| rng.f32()).collect();
        let label = if p < 1000 { "p=785" } else { "p=4.2M" };
        results.push(BenchTimer::run(&format!("linalg::axpy {label}"), t, || {
            rfast::linalg::axpy(std::hint::black_box(&mut y), 0.5,
                                std::hint::black_box(&x));
        }));
        results.push(BenchTimer::run(&format!("linalg::dot  {label}"), t, || {
            std::hint::black_box(rfast::linalg::dot(&x, &y));
        }));
        let a = x.clone();
        let b = y.clone();
        let mut z = vec![0.0f32; p];
        results.push(BenchTimer::run(
            &format!("linalg::add_diff {label}"), t, || {
                rfast::linalg::add_diff(std::hint::black_box(&mut z), &a, &b);
            },
        ));
    }

    // ---- one full R-FAST wake (coordination only, p=785) ----------------
    {
        let topo = Topology::ring(8);
        let quad = QuadraticOracle::heterogeneous(785, 8, 0.5, 2.0, 3);
        let mut set = quad.into_set();
        let mut nodes = AlgoKind::RFast.build(&topo, &vec![0.0; 785], 0.01, 1);
        let mut out = Vec::new();
        results.push(BenchTimer::run("rfast wake+msgs (p=785, ring-8)", t, || {
            nodes[0].wake(set.nodes[0].as_mut(), &mut out);
            out.clear();
        }));
    }

    // ---- gradient oracles ------------------------------------------------
    {
        let o = LogRegOracle::paper_workload(1, 32, 0.0, 5);
        let mut set = o.into_set();
        let theta = vec![0.01f32; set.dim];
        let mut g = vec![0.0f32; set.dim];
        results.push(BenchTimer::run("logreg grad (rust, B=32, d=784)", t, || {
            set.nodes[0].grad(std::hint::black_box(&theta), &mut g);
        }));
    }
    {
        let o = MlpOracle::paper_workload(1, 32, 0.0, 5);
        let mut set = o.into_set();
        let theta = MlpOracle::init_theta(1);
        let mut g = vec![0.0f32; set.dim];
        results.push(BenchTimer::run("mlp grad (rust, B=32, 784-128-64-10)",
                                     t, || {
            set.nodes[0].grad(std::hint::black_box(&theta), &mut g);
        }));
    }

    // ---- simulator event throughput --------------------------------------
    {
        let timer = BenchTimer::run("sim: 10k grad wakes (quad p=16, ring-8)",
                                    if quick { 0.2 } else { 1.0 }, || {
            let topo = Topology::ring(8);
            let quad = QuadraticOracle::heterogeneous(16, 8, 0.5, 2.0, 7);
            let cfg = rfast::config::SimConfig {
                seed: 7,
                gamma: 0.02,
                compute_mean: 0.01,
                compute_jitter: 0.2,
                link_latency: 0.002,
                eval_every: 1e6, // no evals: pure engine cost
                ..rfast::config::SimConfig::default()
            };
            let mut sim = Simulator::new(cfg, &topo, AlgoKind::RFast,
                                         quad.into_set());
            sim.run(StopRule::Iterations(10_000));
        });
        println!(
            "sim throughput ≈ {:.2} M events/s (wakes+deliveries+acks)",
            // per grad wake ≈ 1 wake + 2 sends (deliver+ack each)
            10_000.0 * 5.0 / (timer.ns_per_iter() / 1e9) / 1e6
        );
        results.push(timer);
    }

    // ---- PJRT round trip (optional) ---------------------------------------
    if let Some(dir) = rfast::runtime::default_artifact_dir() {
        let manifest = rfast::runtime::Manifest::load(&dir).unwrap();
        let (train, eval) = Dataset::mnist01_like(3).split_eval(2000);
        let task = rfast::runtime::PjrtTask::LogReg {
            data: Arc::new(train.clone()),
            eval: Arc::new(eval),
            partition: Partition::iid(&train, 1, 0),
        };
        let mut set =
            rfast::runtime::build_pjrt_set(&manifest, &task, 1, 3).unwrap();
        let theta = manifest.load_init("logreg").unwrap();
        let mut g = vec![0.0f32; set.dim];
        results.push(BenchTimer::run(
            "logreg grad (PJRT round trip, B=32)", t, || {
                set.nodes[0].grad(std::hint::black_box(&theta), &mut g);
            },
        ));
    } else {
        println!("(artifacts/ not built — skipping PJRT round-trip bench)");
    }

    println!("\n== micro_hotpath results ==");
    for r in &results {
        println!("{}", r.report());
    }
}
