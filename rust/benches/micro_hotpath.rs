//! L3 hot-path microbenches (EXPERIMENTS.md §Methodology). Criterion is
//! unavailable offline; the suite lives in `rfast::exp::bench` so this
//! bench and `repro bench-baseline` measure the identical workloads —
//! this binary prints, the CLI verb also emits schema-checked
//! `BENCH_hotpath.json`.
//!
//! Covers every per-wake cost center:
//!   * linalg primitives at logreg (p=785) and transformer-e2e (p≈4.2M)
//!     sizes,
//!   * full R-FAST wakes on ring-8 (no fan-out) and exponential-16
//!     (out-degree 4 — the broadcast path the zero-copy payload fabric
//!     collapses to one allocation),
//!   * rust logreg / MLP gradient oracles,
//!   * simulator event throughput,
//!   * PJRT logreg grad round trip (when artifacts are present).
//!
//! The counting allocator below makes the allocs/iter column live;
//! `RFAST_BENCH_QUICK=1` shortens the timing windows.

use rfast::exp::bench::{hotpath_suite, CountingAllocator};

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

fn main() {
    let quick = std::env::var("RFAST_BENCH_QUICK").is_ok();
    let results = hotpath_suite(quick);
    println!("\n== micro_hotpath results ==");
    for r in &results {
        println!("{}", r.report());
    }
    println!("\n(methodology + results log: EXPERIMENTS.md; JSON emit: \
              `repro bench-baseline`)");
}
