//! Fig 4b — time to reach training loss 0.1 vs node count on the binary
//! tree (logreg, §VI-A). Paper claim: the time decreases almost linearly
//! with the number of nodes.

use rfast::algo::AlgoKind;
use rfast::exp::{Experiment, Stop, Workload};
use rfast::metrics::{save_series_csv, Series, Table};
use std::path::Path;

fn main() {
    let target = 0.1;
    let mut table = Table::new(
        "Fig 4b: time to training loss 0.1 vs #nodes (binary tree)",
        &["nodes", "virtual time (s)", "speedup vs n=3", "grad steps",
          "MB sent"],
    );
    let mut curve = Series::new("time_to_loss_0.1", "nodes", "virtual_seconds");
    let mut base = None;
    for n in [3usize, 7, 15, 31] {
        let topo = rfast::graph::Topology::binary_tree(n);
        let mut cfg = Workload::LogReg.paper_config();
        cfg.seed = 2;
        let run = Experiment::new(Workload::LogReg, AlgoKind::RFast)
            .topology(&topo)
            .config(cfg)
            .stop(Stop::TargetLoss { loss: target, max_time: 2_000.0 })
            .run()
            .expect("fig4b run");
        let t = run.report.series["loss_vs_time"]
            .time_to_reach(target)
            .unwrap_or(f64::INFINITY);
        let b = *base.get_or_insert(t);
        table.row(vec![
            n.to_string(),
            format!("{t:.2}"),
            format!("{:.2}×", b / t),
            format!("{}", run.stats.total_steps()),
            format!("{:.1}", run.stats.bytes_sent as f64 / 1e6),
        ]);
        curve.push(n as f64, t);
    }
    table.print();
    save_series_csv(Path::new("runs/fig4b_time_to_target.csv"), &[&curve])
        .unwrap();
    println!("series: runs/fig4b_time_to_target.csv");
    println!("Expected shape: near-linear speedup in n (paper Fig 4b).");
    println!("(A fixed-epoch-budget twin of this sweep seeds the perf \
              trajectory: `repro bench-baseline` → BENCH_scaling.json.)");
}
