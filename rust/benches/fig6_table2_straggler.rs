//! Fig 6 (a,b,c) + Table II columns 4-5 — the same six-algorithm
//! comparison with ONE STRAGGLER (a node slowed 5×, mimicking the paper's
//! artificially-loaded GPU).
//!
//! Paper claims reproduced (shape): synchronous algorithms inflate their
//! wall time by ≈ the straggler factor (every round waits for the slow
//! node; R-FAST runs ~3× faster than Ring-AllReduce here), while R-FAST /
//! AD-PSGD / OSGP barely move; R-FAST keeps the best accuracy among the
//! asynchronous ones.

use rfast::algo::AlgoKind;
use rfast::exp::{Comparison, Experiment, Stop, Workload, PAPER_BASELINES};
use rfast::graph::Topology;
use rfast::metrics::{fmt_mins, Table};
use rfast::scenario::Scenario;
use std::path::Path;

fn main() {
    let n = 8;
    let epochs = std::env::var("RFAST_BENCH_EPOCHS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10.0);
    // the paper's regime as a named scenario: node 3 slowed 5×, 2% loss
    // on the async algorithms (override: RFAST_BENCH_SCENARIO)
    let scenario_name = std::env::var("RFAST_BENCH_SCENARIO")
        .unwrap_or_else(|_| "paper_fig6_straggler".to_string());
    let scenario = Scenario::resolve(&scenario_name).expect("scenario");
    let clean_scenario = Scenario::by_name("paper_fig5").unwrap();
    let topo = Topology::ring(n);

    // one base chain, two scenario sweeps (clean = same 2% loss, no
    // straggler — the "slowdown vs clean" denominator)
    let sweep = |sc: &Scenario| -> Comparison {
        let mut cfg = Workload::Mlp.paper_config();
        cfg.seed = 4;
        cfg.gamma_decay = Some((5.0, 0.1)); // paper: lr ÷10 per 30 of 90 epochs — ÷10 per 5 of our 10
        cfg.scenario = Some(sc.clone());
        Experiment::new(Workload::Mlp, AlgoKind::RFast)
            .topology(&topo)
            .config(cfg)
            .stop(Stop::Epochs(epochs))
            .sweep_algos_tuned(&PAPER_BASELINES)
            .expect("fig6 sweep")
    };
    let clean = sweep(&clean_scenario);
    let faulty = sweep(&scenario);

    let mut table = Table::new(
        &format!("Table II (scenario {}): {epochs} epochs, \
                  {n}-node ring, MLP proxy",
                 scenario.name),
        &["algorithm", "time(mins)", "acc(%)", "slowdown vs clean",
          "rel. time vs R-FAST"],
    );
    let mut rfast_time = None;
    for (run, clean_run) in faulty.runs.iter().zip(&clean.runs) {
        let time = run.report.scalars["virtual_time"];
        let acc = run.report.series["acc_vs_time"].last_y().unwrap_or(0.0);
        let base = *rfast_time.get_or_insert(time);
        table.row(vec![
            run.report.label.clone(),
            fmt_mins(time),
            format!("{:.2}", acc * 100.0),
            format!("{:.2}×", time / clean_run.report.scalars["virtual_time"]),
            format!("{:.2}×", time / base),
        ]);
    }
    table.print();
    faulty.save_csvs(Path::new("runs"), "fig6").unwrap();
    println!("Fig 6a-c: runs/fig6_{{loss_vs_time,loss_vs_epoch,acc_vs_epoch}}.csv");
}
