//! Fig 6 (a,b,c) + Table II columns 4-5 — the same six-algorithm
//! comparison with ONE STRAGGLER (a node slowed 5×, mimicking the paper's
//! artificially-loaded GPU).
//!
//! Paper claims reproduced (shape): synchronous algorithms inflate their
//! wall time by ≈ the straggler factor (every round waits for the slow
//! node; R-FAST runs ~3× faster than Ring-AllReduce here), while R-FAST /
//! AD-PSGD / OSGP barely move; R-FAST keeps the best accuracy among the
//! asynchronous ones.

use rfast::exp::{run_sim, save_comparison_csvs, Workload, PAPER_BASELINES};
use rfast::graph::Topology;
use rfast::metrics::{fmt_mins, Table};
use rfast::scenario::Scenario;
use rfast::sim::StopRule;
use std::path::Path;

fn main() {
    let n = 8;
    let epochs = std::env::var("RFAST_BENCH_EPOCHS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10.0);
    // the paper's regime as a named scenario: node 3 slowed 5×, 2% loss
    // on the async algorithms (override: RFAST_BENCH_SCENARIO)
    let scenario_name = std::env::var("RFAST_BENCH_SCENARIO")
        .unwrap_or_else(|_| "paper_fig6_straggler".to_string());
    let scenario = Scenario::resolve(&scenario_name).expect("scenario");
    let clean_scenario = Scenario::by_name("paper_fig5").unwrap();
    let topo = Topology::ring(n);

    let mut table = Table::new(
        &format!("Table II (scenario {}): {epochs} epochs, \
                  {n}-node ring, MLP proxy",
                 scenario.name),
        &["algorithm", "time(mins)", "acc(%)", "slowdown vs clean",
          "rel. time vs R-FAST"],
    );
    let mut reports = Vec::new();
    let mut rfast_time = None;
    for algo in PAPER_BASELINES {
        // clean run (same 2% loss, no straggler) for the slowdown column
        let mut cfg = Workload::Mlp.paper_config();
        cfg.seed = 4;
        cfg.gamma = rfast::exp::tuned_gamma(Workload::Mlp, algo);
        cfg.gamma_decay = Some((5.0, 0.1)); // paper: lr ÷10 per 30 of 90 epochs — ÷10 per 5 of our 10
        cfg.scenario = Some(clean_scenario.clone());
        let clean = run_sim(Workload::Mlp, algo, &topo, &cfg,
                            StopRule::Epochs(epochs));
        // faulty run
        cfg.scenario = Some(scenario.clone());
        let mut r = run_sim(Workload::Mlp, algo, &topo, &cfg,
                            StopRule::Epochs(epochs));
        let time = r.scalars["virtual_time"];
        let acc = r.series["acc_vs_time"].last_y().unwrap_or(0.0);
        let base = *rfast_time.get_or_insert(time);
        table.row(vec![
            algo.name().to_string(),
            fmt_mins(time),
            format!("{:.2}", acc * 100.0),
            format!("{:.2}×", time / clean.scalars["virtual_time"]),
            format!("{:.2}×", time / base),
        ]);
        r.label = algo.name().to_string();
        reports.push(r);
    }
    table.print();
    let refs: Vec<&_> = reports.iter().collect();
    save_comparison_csvs(Path::new("runs"), "fig6", &refs).unwrap();
    println!("Fig 6a-c: runs/fig6_{{loss_vs_time,loss_vs_epoch,acc_vs_epoch}}.csv");
}
