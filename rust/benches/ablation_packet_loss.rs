//! Ablation: what does the robust ρ/ρ̃ running-sum scheme buy? (§IV iii)
//!
//! Sweeps the packet-loss probability on two workloads:
//!   * heterogeneous quadratics (exact optimality gap),
//!   * the §VI-A logreg problem (eval loss + accuracy),
//! comparing robust R-FAST, the naive one-shot-increment ablation, and the
//! loss-fragile baselines AD-PSGD / OSGP.

use rfast::algo::AlgoKind;
use rfast::config::SimConfig;
use rfast::exp::{Experiment, QuadSpec, Stop, Workload};
use rfast::graph::Topology;
use rfast::metrics::Table;

const ALGOS: [AlgoKind; 4] = [
    AlgoKind::RFast,
    AlgoKind::RFastNaive,
    AlgoKind::AdPsgd,
    AlgoKind::Osgp,
];

fn quad_gap(algo: AlgoKind, loss_prob: f64, seed: u64) -> f64 {
    let cfg = SimConfig {
        seed,
        gamma: 0.03,
        compute_mean: 0.01,
        compute_jitter: 0.3,
        link_latency: 0.002,
        latency_cap: 0.05,
        loss_prob,
        eval_every: 5.0,
        ..SimConfig::default()
    };
    let spec = QuadSpec { dim: 16, h_min: 0.5, h_max: 3.0, spread: 1.5,
                          noise: 0.0 };
    let run = Experiment::new(Workload::Quadratic(spec), algo)
        .topology(&Topology::ring(6))
        .config(cfg)
        .stop(Stop::Iterations(60_000))
        .run()
        .expect("quad run");
    let g = run.report.final_gap.unwrap();
    if g.is_finite() { g } else { f64::INFINITY }
}

fn main() {
    let sweeps = [0.0, 0.05, 0.1, 0.2, 0.3, 0.4];

    let mut t1 = Table::new(
        "ablation: optimality gap vs packet loss (quadratics, 6-node ring, \
         mean of 3 seeds)",
        &["loss prob", "R-FAST", "naive GT", "AD-PSGD", "OSGP"],
    );
    for &lp in &sweeps {
        let mut row = vec![format!("{:.0}%", lp * 100.0)];
        for algo in ALGOS {
            let g: f64 = (0..3).map(|s| quad_gap(algo, lp, 20 + s)).sum::<f64>() / 3.0;
            row.push(format!("{g:.3e}"));
        }
        t1.row(row);
    }
    t1.print();

    let mut t2 = Table::new(
        "ablation: logreg eval loss / acc(%) vs packet loss (8-node ring, \
         40 virtual s)",
        &["loss prob", "R-FAST", "naive GT", "AD-PSGD", "OSGP"],
    );
    for &lp in &sweeps {
        let mut cfg = Workload::LogReg.paper_config();
        cfg.seed = 9;
        cfg.loss_prob = lp;
        let cmp = Experiment::new(Workload::LogReg, AlgoKind::RFast)
            .topology(&Topology::ring(8))
            .config(cfg)
            .stop(Stop::Time(40.0))
            .sweep_algos(&ALGOS)
            .expect("logreg sweep");
        let mut row = vec![format!("{:.0}%", lp * 100.0)];
        for run in &cmp.runs {
            let loss = run.report.series["loss_vs_time"].last_y().unwrap();
            let acc = run.report.series["acc_vs_time"].last_y().unwrap();
            row.push(format!("{loss:.3} / {:.1}", acc * 100.0));
        }
        t2.row(row);
    }
    t2.print();
    println!("\nExpected shape: R-FAST column flat in the loss rate; naive GT \
              degrades sharply; OSGP biased; AD-PSGD loses accuracy (paper \
              Table II async columns).");
}
