//! Fig 7 + Table III — R-FAST scalability in the number of nodes on the
//! MLP proxy (fixed epoch budget): training time should drop near-linearly
//! with n while accuracy degrades only slightly.
//!
//! Topology substitution (documented in EXPERIMENTS.md): the paper uses a
//! directed ring; in our event-level proxy the ring's stable-γ window
//! closes at n=16 within this small epoch budget (the consensus spectral
//! gap shrinks as 1/n² while tracked-gradient noise grows with n), so the
//! scaling run uses the exponential graph — also from the paper's topology
//! set (Appendix G) — whose log-diameter keeps mixing fast at every n.

use rfast::algo::AlgoKind;
use rfast::exp::{tuned_gamma, Comparison, Experiment, Stop, Workload};
use rfast::graph::Topology;
use rfast::metrics::{fmt_mins, Table};
use std::path::Path;

fn main() {
    let epochs = std::env::var("RFAST_BENCH_EPOCHS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(20.0);
    let mut table = Table::new(
        &format!("Table III: R-FAST over 4/8/16 nodes ({epochs} epochs, \
                  MLP proxy)"),
        &["nodes", "time(mins)", "acc(%)", "speedup vs 4"],
    );
    let mut cmp = Comparison::default();
    let mut base = None;
    for n in [4usize, 8, 16] {
        let topo = Topology::exponential(n);
        let mut cfg = Workload::Mlp.paper_config();
        cfg.seed = 6;
        cfg.gamma = tuned_gamma(Workload::Mlp, AlgoKind::RFast);
        cfg.gamma_decay = Some((10.0, 0.1)); // paper: lr ÷10 per 30 of 90 epochs — scaled
        cfg.loss_prob = 0.02;
        let mut run = Experiment::new(Workload::Mlp, AlgoKind::RFast)
            .topology(&topo)
            .config(cfg)
            .stop(Stop::Epochs(epochs))
            .run()
            .expect("fig7 run");
        let time = run.report.scalars["virtual_time"];
        let acc = run.report.series["acc_vs_time"].last_y().unwrap_or(0.0);
        let b = *base.get_or_insert(time);
        table.row(vec![
            n.to_string(),
            fmt_mins(time),
            format!("{:.2}", acc * 100.0),
            format!("{:.2}×", b / time),
        ]);
        run.report.label = format!("{n}-nodes");
        cmp.runs.push(run);
    }
    table.print();
    cmp.save_csvs(Path::new("runs"), "fig7").unwrap();
    println!("Fig 7: runs/fig7_acc_vs_time.csv");
    println!("Expected shape: near-linear time scaling, small accuracy loss \
              (paper: 79.29/79.12/79.01%).");
}
