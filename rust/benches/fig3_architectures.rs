//! Fig 3 — asymmetric (G_R, G_C) architectures: R-FAST on four
//! structurally distinct pull+push spanning-tree pairs (logreg, 8 nodes)
//! under the paper's straggler regime (`paper_fig6_straggler`), vs the
//! same pairs clean. Regenerates the paper's architectural-flexibility
//! claim as `runs/fig3_*.csv` plus a console summary.
//!
//! Paper claim reproduced: R-FAST converges when the pull graph and the
//! push graph are **two different spanning trees** — chain-pull with
//! star-push, shallow-BFS-pull with deep-DFS-push, two independent
//! random trees — so long as they share a common root (Assumption 2).
//! The bench also demonstrates the guard rail: a pair whose trees have
//! different roots is rejected by `Experiment::run` with the typed
//! `ExpError::InvalidTopology`, never run.

use rfast::algo::AlgoKind;
use rfast::exp::{Comparison, Experiment, Stop, Workload};
use rfast::graph::ArchSpec;
use rfast::metrics::{fmt_mins, Table};
use rfast::scenario::Scenario;
use std::path::Path;

fn main() {
    let n = 8;
    let epochs = std::env::var("RFAST_BENCH_EPOCHS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10.0);
    let pairs = ArchSpec::paper_pairs();
    let scenario = Scenario::by_name("paper_fig6_straggler").unwrap();

    let sweep = |sc: Option<&Scenario>| -> Comparison {
        let mut cfg = Workload::LogReg.paper_config();
        cfg.seed = 3;
        cfg.gamma = 4e-3; // root-concentration: same calibration as fig4a
        Experiment::new(Workload::LogReg, AlgoKind::RFast)
            .config(cfg)
            .maybe_scenario(sc)
            .stop(Stop::Epochs(epochs))
            .sweep_architectures(&pairs, n)
            .expect("fig3 sweep")
    };
    let clean = sweep(None);
    let faulty = sweep(Some(&scenario));

    let mut table = Table::new(
        &format!(
            "Fig 3: R-FAST over asymmetric (G_R, G_C) spanning-tree pairs \
             ({n} nodes, {epochs} epochs, scenario {})",
            scenario.name
        ),
        &["architecture (pull+push)", "roots R", "time(mins)", "final loss",
          "acc(%)", "slowdown vs clean"],
    );
    for ((spec, run), clean_run) in
        pairs.iter().zip(&faulty.runs).zip(&clean.runs)
    {
        let topo = spec.build(n).expect("pair builds");
        let time = run.report.scalars["virtual_time"];
        table.row(vec![
            spec.name(),
            format!("{:?}", topo.weights.common_roots()),
            fmt_mins(time),
            format!("{:.4}",
                    run.report.series["loss_vs_epoch"].last_y().unwrap()),
            format!("{:.1}",
                    100.0 * run.report.series["acc_vs_epoch"]
                        .last_y()
                        .unwrap_or(0.0)),
            format!("{:.2}×",
                    time / clean_run.report.scalars["virtual_time"]),
        ]);
    }
    table.print();
    faulty.save_csvs(Path::new("runs"), "fig3").unwrap();
    clean.save_csvs(Path::new("runs"), "fig3_clean").unwrap();
    println!("series: runs/fig3_{{loss_vs_epoch,loss_vs_time}}.csv \
              (+ fig3_scalars.csv, fig3_clean_*)");

    // the guard rail: different roots ⇒ empty common-root set ⇒ typed
    // rejection before any event executes
    let bad = ArchSpec::no_common_root_pair();
    let err = Experiment::new(Workload::LogReg, AlgoKind::RFast)
        .stop(Stop::Epochs(epochs))
        .sweep_architectures(&[bad.clone()], n)
        .expect_err("no-common-root pair must be rejected");
    println!("\nrejected as designed: {} → {err}", bad.name());
}
